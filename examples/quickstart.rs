//! Quickstart: train a tiny transformer with DiLoCoX over two simulated
//! decentralized clusters and compare against vanilla AllReduce.
//!
//!     make artifacts            # once: AOT-lower the jax/pallas programs
//!     cargo run --release --example quickstart
//!
//! Prints loss curves and the wire-byte ledger — the paper's story in
//! thirty seconds: same convergence, orders of magnitude less traffic.
//!
//! Without artifacts (e.g. a fresh checkout or CI) it falls back to the
//! artifact-free stage-parallel demo: the real 1F1B executor over the
//! synthetic multi-stage workload, with a quantized per-stage ring.

use dilocox::config::{Algo, ExperimentConfig};
use dilocox::metrics::Table;
use dilocox::train::{run_experiment, RunOpts};
use dilocox::util::fmt_bytes;

/// Artifact-free path: D clusters × M stage executor threads on the 1F1B
/// schedule, per-stage dual optimizers, int8 pseudo-gradient rings with
/// one-step-delay overlap.
fn synthetic_pipeline_demo() -> anyhow::Result<()> {
    use dilocox::compress::Method;
    use dilocox::pipeline::exec::{
        local_stage_rings, run_pipeline, PipelineRunOpts, SyntheticPipeline,
    };

    let (dp, stages, micros, dim) = (2usize, 3usize, 4usize, 32usize);
    let wl = SyntheticPipeline::new(stages, micros, dim, 1234);
    let opts = PipelineRunOpts {
        rounds: 6,
        local_steps: 8,
        inner_lr: 0.05,
        weight_decay: 0.0,
        // Gentle outer settings: one-step-delayed updates at the paper's
        // transformer gains oscillate on this fast-converging toy chain.
        outer_lr: 0.3,
        outer_momentum: 0.3,
        overlap: true,
        error_feedback: false,
        method: Method::Quant { q_bits: 8 },
        seed: 1234,
        ..PipelineRunOpts::default()
    };
    let out = run_pipeline(&wl, dp, local_stage_rings(dp, stages), &opts)?;
    println!(
        "stage-parallel 1F1B demo: D={dp} clusters × M={stages} stages, \
         U={micros} microbatches, int8 ring, overlap on"
    );
    for (r, loss) in out.mean_loss_per_round() {
        println!("  round {r}: loss {loss:.4}");
    }
    println!(
        "final eval {:.4} | ring traffic {}",
        out.final_eval,
        fmt_bytes(out.total_wire_bytes)
    );
    Ok(())
}

fn main() -> anyhow::Result<()> {
    let artifacts = format!("{}/artifacts/tiny", env!("CARGO_MANIFEST_DIR"));
    if !std::path::Path::new(&artifacts).exists() {
        eprintln!(
            "artifacts/tiny missing (run `make artifacts` for the PJRT \
             path) — running the artifact-free stage-parallel demo"
        );
        return synthetic_pipeline_demo();
    }

    let opts = RunOpts { quiet: true, ..Default::default() };
    let mut rows = Table::new(&[
        "algorithm",
        "final eval loss",
        "WAN traffic",
        "compression",
        "modeled time @1Gbps",
    ]);

    let mut outcomes = Vec::new();
    for algo in [Algo::AllReduce, Algo::DiLoCoX] {
        let mut cfg = ExperimentConfig::default_for("tiny", algo);
        cfg.artifacts_dir = artifacts.clone();
        cfg.train.outer_steps = 8;
        cfg.train.local_steps = if algo == Algo::AllReduce { 5 } else { 5 };
        cfg.train.inner_lr = 3e-3;
        cfg.train.outer_lr = 0.5;
        cfg.compression.rank = 8;
        println!("running {} ...", algo.name());
        let out = run_experiment(&cfg, &opts)?;
        let m = &out.metrics;
        let ratio = if m.total_wire_bytes() > 0 {
            let full = 4.0
                * out.params.len() as f64
                * m.records.iter().filter(|r| r.wire_bytes > 0).count() as f64;
            full / m.total_wire_bytes() as f64
        } else {
            1.0
        };
        rows.row(&[
            algo.name().to_string(),
            format!("{:.4}", m.final_eval_loss.unwrap()),
            fmt_bytes(m.total_wire_bytes()),
            format!("{ratio:.0}x"),
            dilocox::util::fmt_secs(m.total_elapsed()),
        ]);
        outcomes.push((algo, out));
    }

    println!("\n{}", rows.render());

    println!("eval-loss curves (outer step -> loss):");
    for (algo, out) in &outcomes {
        let pts: Vec<String> = out
            .eval_curve
            .iter()
            .map(|(s, l)| format!("{s}:{l:.3}"))
            .collect();
        println!("  {:<10} {}", algo.name(), pts.join("  "));
    }
    println!(
        "\nDiLoCoX reaches AllReduce-class loss while moving a fraction of \
         the bytes — the paper's Figure 3 + 4 story at toy scale."
    );
    Ok(())
}
