//! Quickstart: train a tiny transformer with DiLoCoX over two simulated
//! decentralized clusters and compare against vanilla AllReduce.
//!
//!     make artifacts            # once: AOT-lower the jax/pallas programs
//!     cargo run --release --example quickstart
//!
//! Prints loss curves and the wire-byte ledger — the paper's story in
//! thirty seconds: same convergence, orders of magnitude less traffic.

use dilocox::config::{Algo, ExperimentConfig};
use dilocox::metrics::Table;
use dilocox::train::{run_experiment, RunOpts};
use dilocox::util::fmt_bytes;

fn main() -> anyhow::Result<()> {
    let artifacts = format!("{}/artifacts/tiny", env!("CARGO_MANIFEST_DIR"));
    if !std::path::Path::new(&artifacts).exists() {
        eprintln!("artifacts/tiny missing — run `make artifacts` first");
        std::process::exit(1);
    }

    let opts = RunOpts { quiet: true, ..Default::default() };
    let mut rows = Table::new(&[
        "algorithm",
        "final eval loss",
        "WAN traffic",
        "compression",
        "modeled time @1Gbps",
    ]);

    let mut outcomes = Vec::new();
    for algo in [Algo::AllReduce, Algo::DiLoCoX] {
        let mut cfg = ExperimentConfig::default_for("tiny", algo);
        cfg.artifacts_dir = artifacts.clone();
        cfg.train.outer_steps = 8;
        cfg.train.local_steps = if algo == Algo::AllReduce { 5 } else { 5 };
        cfg.train.inner_lr = 3e-3;
        cfg.train.outer_lr = 0.5;
        cfg.compression.rank = 8;
        println!("running {} ...", algo.name());
        let out = run_experiment(&cfg, &opts)?;
        let m = &out.metrics;
        let ratio = if m.total_wire_bytes() > 0 {
            let full = 4.0
                * out.params.len() as f64
                * m.records.iter().filter(|r| r.wire_bytes > 0).count() as f64;
            full / m.total_wire_bytes() as f64
        } else {
            1.0
        };
        rows.row(&[
            algo.name().to_string(),
            format!("{:.4}", m.final_eval_loss.unwrap()),
            fmt_bytes(m.total_wire_bytes()),
            format!("{ratio:.0}x"),
            dilocox::util::fmt_secs(m.total_elapsed()),
        ]);
        outcomes.push((algo, out));
    }

    println!("\n{}", rows.render());

    println!("eval-loss curves (outer step -> loss):");
    for (algo, out) in &outcomes {
        let pts: Vec<String> = out
            .eval_curve
            .iter()
            .map(|(s, l)| format!("{s}:{l:.3}"))
            .collect();
        println!("  {:<10} {}", algo.name(), pts.join("  "));
    }
    println!(
        "\nDiLoCoX reaches AllReduce-class loss while moving a fraction of \
         the bytes — the paper's Figure 3 + 4 story at toy scale."
    );
    Ok(())
}
