//! The paper's headline experiment, simulated at true scale: pre-training
//! Qwen1.5-107B across two decentralized clusters (160× A800-40G) over a
//! 1 Gbps WAN.  Reproduces Fig. 4, the §2.2 memory argument (OpenDiLoCo
//! OOM), and a bandwidth sweep showing where decentralized training
//! becomes practical.
//!
//!     cargo run --release --example decentralized_107b_sim
//!
//! With `--calibrate-from run.json` (a `coordinate --report` JSON from
//! either the threaded executor or the elastic TCP fleet — both ship
//! measured per-stage `step_secs`), the DES tables are recomputed from
//! the MEASURED step time instead of the FLOP model:
//!
//!     cargo run --release -- coordinate --transport tcp --pp 2 \
//!         --synthetic --report run.json
//!     cargo run --release --example decentralized_107b_sim -- \
//!         --calibrate-from run.json

use dilocox::config::{Algo, NetworkConfig};
use dilocox::metrics::Table;
use dilocox::netsim::{Link, LinkFaultModel, Topology};
use dilocox::pipeline::ScheduleKind;
use dilocox::transport::probe::{ring_bottleneck, ring_order, LinkMatrix};
use dilocox::report::{self, paper};
use dilocox::sim::{self, ScaleConfig, SimAlgo};
use dilocox::util::json::Json;
use dilocox::util::{fmt_bytes, fmt_secs};

fn main() {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    if let Some(i) = argv.iter().position(|a| a == "--calibrate-from") {
        let Some(path) = argv.get(i + 1) else {
            eprintln!("--calibrate-from needs a run-report JSON path");
            std::process::exit(2);
        };
        calibrate_from(path);
        return;
    }
    let rounds = 16;

    // ---- Figure 4 at both scales ---------------------------------------
    for scale in [ScaleConfig::opt_1_3b(), ScaleConfig::qwen_107b()] {
        let rows = sim::figure4_row(&scale, rounds);
        let paper_rows: &[(&str, f64)] = if scale.params > 10e9 {
            &paper::FIG4_107B
        } else {
            &paper::FIG4_1_3B
        };
        println!("{}", report::figure4_table(&scale.name, paper_rows, &rows));
    }

    // ---- §2.2 memory story ----------------------------------------------
    println!("Memory per GPU (A800-40G), Qwen1.5-107B:");
    let mut t = Table::new(&["configuration", "per-GPU", "worst GPU", "verdict"]);
    let hbm = 40_000_000_000u64;
    let od = sim::memory::opendiloco_memory(107e9, hbm);
    let dx = sim::memory::dilocox_memory(107e9, 80, hbm);
    for (name, r) in [("OpenDiLoCo (no MP)", od), ("DiLoCoX (PP=80, dual opt sharded)", dx)] {
        t.row(&[
            name.to_string(),
            fmt_bytes(r.per_gpu_bytes),
            fmt_bytes(r.worst_gpu_bytes),
            format!("{:?}", r.verdict),
        ]);
    }
    println!("{}", t.render());

    // ---- bandwidth sweep: when does decentralized training make sense? --
    println!("DiLoCoX 107B throughput vs inter-cluster bandwidth:");
    let mut t = Table::new(&[
        "bandwidth",
        "sync time",
        "tokens/s",
        "GPU util",
        "comm hidden?",
    ]);
    for gbps in [0.1, 0.5, 1.0, 2.0, 10.0, 100.0] {
        let mut scale = ScaleConfig::qwen_107b();
        scale.net.inter_bw_gbps = gbps;
        let algo = SimAlgo::paper_setting(Algo::DiLoCoX, &scale);
        let r = sim::simulate(&scale, &algo, rounds);
        let local_phase = r.step_secs * algo.local_steps as f64;
        t.row(&[
            format!("{gbps} Gbps"),
            fmt_secs(r.comm_secs),
            report::fmt_tps(r.tokens_per_sec),
            format!("{:.0}%", 100.0 * r.gpu_utilization),
            if r.comm_secs <= local_phase { "yes".into() } else { "NO".into() },
        ]);
    }
    println!("{}", t.render());

    // ---- local-step sweep: the H trade-off -------------------------------
    println!("DiLoCoX 107B: local steps H vs throughput (overlap on):");
    let mut t = Table::new(&["H", "tokens/s", "syncs/hour", "GPU util"]);
    let scale = ScaleConfig::qwen_107b();
    for h in [25, 50, 125, 250, 500] {
        let mut algo = SimAlgo::paper_setting(Algo::DiLoCoX, &scale);
        algo.local_steps = h;
        let r = sim::simulate(&scale, &algo, rounds);
        let round_secs = (r.step_secs * h as f64).max(r.comm_secs);
        t.row(&[
            h.to_string(),
            report::fmt_tps(r.tokens_per_sec),
            format!("{:.1}", 3600.0 / round_secs),
            format!("{:.0}%", 100.0 * r.gpu_utilization),
        ]);
    }
    println!("{}", t.render());

    // ---- microbatch schedule: killing the pipeline bubble ----------------
    // The 107B pipeline is deep (S = 80 executors, M = 160 microbatches),
    // so the fill/drain ramp is material: 1F1B idles (S−1)/(M+S−1) ≈ 33%
    // of each executor's step.  Interleaving v model chunks per executor
    // divides the ramp by v; the ZB-H1 split-backward stream back-fills
    // the drain with weight-grad work and removes it entirely.
    println!(
        "Qwen1.5-107B inner-step schedule (S=80 stages, M=160 microbatches):"
    );
    let mut t = Table::new(&[
        "schedule",
        "ideal bubble",
        "step time",
        "tokens/s",
        "vs 1f1b",
    ]);
    let scale = ScaleConfig::qwen_107b();
    let mut base_step = 0.0f64;
    for (kind, v) in [
        (ScheduleKind::OneFOneB, 1usize),
        (ScheduleKind::Interleaved, 2),
        (ScheduleKind::Interleaved, 4),
        (ScheduleKind::ZeroBubble, 1),
    ] {
        let mut topo = Topology::new(&scale.net, scale.pp_stages);
        let step = match sim::pipeline_step_secs_for(&scale, &mut topo, kind, v)
        {
            Ok(s) => s,
            Err(e) => {
                eprintln!("schedule {}: {e}", kind.name());
                continue;
            }
        };
        if kind == ScheduleKind::OneFOneB {
            base_step = step;
        }
        let algo = SimAlgo::paper_setting(Algo::DiLoCoX, &scale);
        let r = sim::simulate_calibrated(&scale, &algo, rounds, Some(step));
        let name = if v > 1 {
            format!("{} v={v}", kind.name())
        } else {
            kind.name().to_string()
        };
        t.row(&[
            name,
            format!(
                "{:.1}%",
                100.0
                    * kind.ideal_bubble_fraction(
                        scale.pp_stages,
                        v,
                        scale.microbatches
                    )
            ),
            fmt_secs(step),
            report::fmt_tps(r.tokens_per_sec),
            if base_step > 0.0 {
                format!("{:.2}x", base_step / step)
            } else {
                "-".into()
            },
        ]);
    }
    println!("{}", t.render());

    // ---- reduction topology: flat vs reordered vs hier -------------------
    // Four 107B clusters spread over two sites, deliberately interleaved
    // (site 0 holds clusters 0 and 2) so the naive rank-ascending ring
    // crosses the 1 Gbps WAN on every hop.  Bandwidth-aware reordering
    // groups each site contiguously; the hierarchical two-level reduce
    // sends only one leader per site onto the WAN, cutting the cross-site
    // payload from 2·(C−1)/C to 2·(S−1)/S of the sync.
    println!(
        "107B sync topology at 1 Gbps WAN (C=4 clusters, S=2 sites, \
         interleaved placement):"
    );
    let scale = ScaleConfig::qwen_107b();
    let net4 = NetworkConfig::paper_1gbps(4);
    let site_of = [0usize, 1, 0, 1];
    let dx = SimAlgo::paper_setting(Algo::DiLoCoX, &scale);
    for (label, payload) in [
        ("fp32 pseudo-gradient", (4.0 * scale.params) as u64),
        (
            "DiLoCoX compressed",
            sim::sync_payload_bytes(scale.params, scale.d_hidden, &dx.method),
        ),
    ] {
        let mut t = Table::new(&[
            "topology",
            "ring order",
            "WAN bytes/member",
            "WAN sync",
        ]);
        for r in sim::reduce_topology_rows(payload, &net4, &site_of) {
            t.row(&[
                r.topology.to_string(),
                format!("{:?}", r.order),
                fmt_bytes(r.wan_bytes_per_member),
                fmt_secs(r.wan_secs),
            ]);
        }
        println!("{label} ({}):\n{}", fmt_bytes(payload), t.render());
    }
    println!(
        "Exact fractions of the payload per member on the WAN: flat and \
         reordered rings move 2·(C−1)/C = 3/2; a hierarchical site leader \
         moves 2·(S−1)/S = 1/1.\n"
    );

    // ---- WAN churn: the fault-aware cost model hook ----------------------
    // Decentralized clusters live on real WANs: stragglers and packet loss
    // inflate sync rounds.  The deterministic (seeded) LinkFaultModel
    // perturbs per-round transfer durations; a round whose (possibly
    // inflated) sync still fits inside the H local steps stays hidden by
    // the one-step-delay overlap.
    println!("DiLoCoX 107B sync under seeded WAN churn (16 rounds, H=125):");
    let scale = ScaleConfig::qwen_107b();
    let algo = SimAlgo::paper_setting(Algo::DiLoCoX, &scale);
    let base = sim::simulate(&scale, &algo, 4);
    let clean_sync = base.comm_secs;
    let local_phase = base.step_secs * algo.local_steps as f64;
    let bw_bytes = scale.net.inter_bw_gbps * 1e9 / 8.0;
    let sync_bytes = (clean_sync * bw_bytes) as u64;
    let mut t = Table::new(&["scenario", "mean sync", "worst sync", "hidden rounds"]);
    for (name, s_prob, s_mult, d_prob) in [
        ("clean WAN", 0.0, 1.0, 0.0),
        ("5% stragglers (4x)", 0.05, 4.0, 0.0),
        ("2% loss (retransmit)", 0.0, 1.0, 0.02),
        ("lossy + straggling", 0.05, 4.0, 0.02),
    ] {
        let mut fm = LinkFaultModel::new(2026, s_prob, s_mult, d_prob);
        let mut link = Link::new("wan", scale.net.inter_bw_gbps, 0.0);
        let rounds = 16;
        let mut durs = Vec::with_capacity(rounds);
        for _ in 0..rounds {
            let ready = link.res.busy_until();
            let (s, e) = link.transfer_with_faults(ready, sync_bytes, &mut fm);
            durs.push(e - s);
        }
        let mean = durs.iter().sum::<f64>() / rounds as f64;
        let worst = durs.iter().cloned().fold(0.0f64, f64::max);
        let hidden = durs.iter().filter(|&&d| d <= local_phase).count();
        t.row(&[
            name.to_string(),
            fmt_secs(mean),
            fmt_secs(worst),
            format!("{hidden}/{rounds}"),
        ]);
    }
    println!("{}", t.render());
    println!(
        "At the paper's H=125 the {} sync hides entirely behind ~{} of local \
         compute — the one-step-delay overlap at work.",
        fmt_secs(sim::simulate(&scale, &SimAlgo::paper_setting(Algo::DiLoCoX, &scale), 4).comm_secs),
        fmt_secs(
            sim::simulate(&scale, &SimAlgo::paper_setting(Algo::DiLoCoX, &scale), 4).step_secs
                * 125.0
        )
    );

    // ---- measured vs modeled stage times (DES calibration hook) ----------
    // The stage-parallel executor now measures real per-stage wall times
    // per inner step (StageRoundReport::step_secs).  Here we drive a small
    // artifact-free pipeline and print the measured numbers next to the
    // modeled per-stage 1F1B step the DES assumes for the simulated scale
    // — the two sides of the calibration loop.  (The measured column is a
    // toy CPU chain, not an A800: compare *shapes* — per-stage balance and
    // straggler spread — not magnitudes.)
    measured_stage_times();
}

/// `--calibrate-from run.json`: recompute the modeled tables from the
/// measured per-stage step times a real run reported (the closing of the
/// DES calibration loop — ROADMAP: "feed measured stage times back into
/// the simulator").  The measured numbers come from whatever hardware
/// produced the report (a laptop CPU for the synthetic chain, an A800
/// node for a real bundle), so absolute throughput reflects THAT
/// hardware; the point is that the sync-hiding structure (comm hidden
/// behind H×step) is now computed from measurement, not a FLOP model.
fn calibrate_from(path: &str) {
    let text = match std::fs::read_to_string(path) {
        Ok(t) => t,
        Err(e) => {
            eprintln!("reading {path}: {e}");
            std::process::exit(1);
        }
    };
    let v = match Json::parse(&text) {
        Ok(v) => v,
        Err(e) => {
            eprintln!("parsing {path}: {e:#}");
            std::process::exit(1);
        }
    };
    // A `coordinate --trace` export is a superset of the `--report` JSON:
    // when the per-round accounting rides along, show where the measured
    // wall time actually went before calibrating from the step times.
    if let Some(rounds) = v.path("dilocox.rounds").and_then(|j| j.as_arr()) {
        println!("Measured round accounting from {path}:");
        let mut t = Table::new(&[
            "round",
            "compute s",
            "wire s",
            "barrier s",
            "recovery s",
            "hiding",
        ]);
        for r in rounds {
            let f = |k: &str| r.path(k).and_then(|j| j.as_f64()).unwrap_or(0.0);
            t.row(&[
                format!("{}", f("round") as u64),
                format!("{:.3}", f("compute_secs")),
                format!("{:.3}", f("wire_secs")),
                format!("{:.3}", f("barrier_secs")),
                format!("{:.3}", f("recovery_secs")),
                format!("{:.0}%", 100.0 * f("hiding_ratio")),
            ]);
        }
        println!("{}", t.render());
    }
    // A reordered-topology fleet ships its probed link matrix in the
    // report (`links` rows) — round-trip it the same way the measured
    // stage times are: rebuild the matrix, recompute the ring order the
    // coordinator would pick, and show what the reorder bought.
    if let Some(arr) = v.path("links").and_then(|j| j.as_arr()) {
        let mut entries: Vec<(u32, u32, f64, f64)> = Vec::new();
        let mut n = 0usize;
        for e in arr {
            let g = |k: &str| e.path(k).and_then(|j| j.as_f64()).unwrap_or(0.0);
            let (from, to) = (g("from") as u32, g("to") as u32);
            n = n.max(from as usize + 1).max(to as usize + 1);
            entries.push((from, to, g("gbps"), g("latency_ms")));
        }
        if !entries.is_empty() && n > 1 {
            let m = LinkMatrix::from_entries(n, &entries);
            println!("Measured links from {path} ({} directed pairs):", entries.len());
            let mut t = Table::new(&["from", "to", "Gbps", "latency ms"]);
            for (f, to, gbps, lat) in &entries {
                t.row(&[
                    f.to_string(),
                    to.to_string(),
                    format!("{gbps:.3}"),
                    format!("{lat:.3}"),
                ]);
            }
            println!("{}", t.render());
            let natural: Vec<usize> = (0..n).collect();
            let order = ring_order(&m);
            let (nat_bw, nat_lat) = ring_bottleneck(&m, &natural);
            let (opt_bw, opt_lat) = ring_bottleneck(&m, &order);
            println!(
                "natural ring {natural:?}: bottleneck {nat_bw:.3} Gbps, \
                 {nat_lat:.3} ms total hop latency"
            );
            println!(
                "reordered    {order:?}: bottleneck {opt_bw:.3} Gbps, \
                 {opt_lat:.3} ms total hop latency\n"
            );
        }
    }
    let Some(arr) = v.path("stage_times").and_then(|j| j.as_arr()) else {
        eprintln!(
            "{path} has no stage_times — produce it with \
             `dilocox coordinate --report {path}` (threaded or TCP fleet, \
             or the richer `--trace` export)"
        );
        std::process::exit(1);
    };
    let mut measured: Vec<(usize, f64, usize)> = Vec::new();
    for e in arr {
        let stage = e.path("stage").and_then(|j| j.as_usize()).unwrap_or(0);
        let mean = e
            .path("mean_step_secs")
            .and_then(|j| j.as_f64())
            .unwrap_or(0.0);
        let samples =
            e.path("samples").and_then(|j| j.as_usize()).unwrap_or(0);
        measured.push((stage, mean, samples));
    }
    // The 1F1B steady state is bounded by the slowest stage: calibrate
    // the per-step time to the worst measured stage mean.
    let step = measured.iter().map(|&(_, m, _)| m).fold(0.0f64, f64::max);
    if step <= 0.0 {
        eprintln!("{path} carries no usable step_secs samples");
        std::process::exit(1);
    }
    println!("Calibrating the DES from {path}:");
    let mut t = Table::new(&["stage", "measured mean/step", "samples"]);
    for (s, m, n) in &measured {
        t.row(&[s.to_string(), format!("{:.3} ms", 1e3 * m), n.to_string()]);
    }
    println!("{}", t.render());
    println!(
        "calibrated 1F1B step = {:.3} ms (slowest measured stage mean)\n",
        1e3 * step
    );

    // The H trade-off, recomputed from the measured step: where the sync
    // hides behind local compute on the hardware that was measured.
    let scale = ScaleConfig::qwen_107b();
    println!(
        "DiLoCoX H sweep with the MEASURED step (network: {} Gbps WAN):",
        scale.net.inter_bw_gbps
    );
    let mut t = Table::new(&["H", "sync time", "local phase", "comm hidden?", "GPU util"]);
    for h in [25, 50, 125, 250, 500] {
        let mut algo = SimAlgo::paper_setting(Algo::DiLoCoX, &scale);
        algo.local_steps = h;
        let r = sim::simulate_calibrated(&scale, &algo, 16, Some(step));
        let local_phase = step * h as f64;
        t.row(&[
            h.to_string(),
            fmt_secs(r.comm_secs),
            fmt_secs(local_phase),
            if r.comm_secs <= local_phase { "yes".into() } else { "NO".into() },
            format!("{:.0}%", 100.0 * r.gpu_utilization),
        ]);
    }
    println!("{}", t.render());

    // Modeled-vs-calibrated side by side for the paper setting.
    let algo = SimAlgo::paper_setting(Algo::DiLoCoX, &scale);
    let modeled = sim::simulate(&scale, &algo, 16);
    let calibrated = sim::simulate_calibrated(&scale, &algo, 16, Some(step));
    let mut t = Table::new(&["quantity", "FLOP model", "calibrated"]);
    t.row(&[
        "step time".into(),
        fmt_secs(modeled.step_secs),
        fmt_secs(calibrated.step_secs),
    ]);
    t.row(&[
        "GPU utilization".into(),
        format!("{:.0}%", 100.0 * modeled.gpu_utilization),
        format!("{:.0}%", 100.0 * calibrated.gpu_utilization),
    ]);
    println!("{}", t.render());
}

fn measured_stage_times() {
    use dilocox::compress::Method;
    use dilocox::pipeline::exec::{
        local_stage_rings, run_pipeline, PipelineRunOpts, SyntheticPipeline,
    };

    let (dp, stages, micros, dim) = (2usize, 4usize, 4usize, 4096usize);
    let wl = SyntheticPipeline::new(stages, micros, dim, 7);
    let opts = PipelineRunOpts {
        rounds: 3,
        local_steps: 8,
        inner_lr: 0.05,
        weight_decay: 0.0,
        outer_lr: 0.7,
        outer_momentum: 0.6,
        overlap: false,
        error_feedback: false,
        method: Method::None,
        seed: 7,
        ..PipelineRunOpts::default()
    };
    let out = match run_pipeline(&wl, dp, local_stage_rings(dp, stages), &opts) {
        Ok(o) => o,
        Err(e) => {
            eprintln!("measured stage-time run failed: {e:#}");
            return;
        }
    };
    let scale = ScaleConfig::qwen_107b();
    let mut topo =
        dilocox::netsim::Topology::new(&scale.net, scale.pp_stages);
    let modeled_step = sim::pipeline_step_secs(&scale, &mut topo);
    println!(
        "Measured per-stage step times (synthetic M={stages} executor run) \
         vs modeled 107B 1F1B step {}:",
        fmt_secs(modeled_step)
    );
    let mut t = Table::new(&[
        "stage",
        "measured mean/step",
        "measured max",
        "samples",
    ]);
    for s in out.stage_time_summary() {
        t.row(&[
            s.stage.to_string(),
            format!("{:.3} ms", 1e3 * s.mean_step_secs),
            format!("{:.3} ms", 1e3 * s.max_step_secs),
            s.samples.to_string(),
        ]);
    }
    println!("{}", t.render());
    println!(
        "These measured step_secs feed back into the DES calibration \
         (ROADMAP: replace the FLOP-model stage time with measured values \
         from real runs)."
    );
}
