//! Adaptive Gradient Compression (Algorithm 3) in action: trains the
//! small preset with DiLoCoX and traces how the controller's rank r_t and
//! local-step count H_t respond to the measured effective rank of the
//! averaged pseudo-gradients (Principle of Rank Diminishing).
//!
//!     cargo run --release --example adaptive_compression_demo

use dilocox::config::{Algo, ExperimentConfig};
use dilocox::metrics::Table;
use dilocox::train::{run_experiment, RunOpts};

fn main() -> anyhow::Result<()> {
    let artifacts = format!("{}/artifacts/small", env!("CARGO_MANIFEST_DIR"));
    if !std::path::Path::new(&artifacts).exists() {
        eprintln!("artifacts/small missing — run `make artifacts` first");
        std::process::exit(1);
    }

    let mut cfg = ExperimentConfig::default_for("small", Algo::DiLoCoX);
    cfg.artifacts_dir = artifacts;
    cfg.train.outer_steps = 12;
    cfg.train.local_steps = 6; // H₁
    cfg.train.inner_lr = 2e-3;
    cfg.train.outer_lr = 0.6;
    cfg.train.overlap = false; // sync mode: the controller sees every Δ
    cfg.compression.rank = 32; // r₁
    cfg.compression.adaptive = true;
    cfg.compression.rank_window = 3; // c
    cfg.compression.min_rank = 2;

    println!(
        "Adaptive compression on `small` ({}): r₁={}, H₁={}, window c={}",
        cfg.algo.name(),
        cfg.compression.rank,
        cfg.train.local_steps,
        cfg.compression.rank_window
    );

    let out = run_experiment(&cfg, &RunOpts { quiet: true, ..Default::default() })?;

    let mut t = Table::new(&[
        "outer",
        "rank r_t",
        "H_t",
        "train loss",
        "wire/sync",
        "ratio",
    ]);
    for r in &out.metrics.records {
        t.row(&[
            r.outer_step.to_string(),
            r.rank.to_string(),
            r.inner_steps.to_string(),
            format!("{:.4}", r.loss),
            dilocox::util::fmt_bytes(r.wire_bytes),
            format!("{:.0}x", r.compression_ratio),
        ]);
    }
    println!("{}", t.render());
    println!(
        "final eval loss {:.4}; total wire {}",
        out.metrics.final_eval_loss.unwrap(),
        dilocox::util::fmt_bytes(out.metrics.total_wire_bytes())
    );
    println!(
        "\nAs training enters its low-rank regime the controller shrinks r_t \
         (cheaper syncs) and rescales H_t = H₁·α — Algorithm 3 end to end."
    );
    Ok(())
}
