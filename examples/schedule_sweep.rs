//! Which microbatch schedule wins where?  Sweep (schedule × stages ×
//! micros × virtual_stages) over the calibrated pipeline model and the
//! DES, and print one time-to-target table naming the winner per cell.
//!
//! Each cell clones the OPT-1.3B testbed (2 clusters over a 1 Gbps WAN,
//! paper §4.1.2), resizes its pipeline to (S stages, M microbatches),
//! prices one inner step with [`sim::pipeline_step_secs_for`] under the
//! candidate schedule, then feeds that step time through
//! [`sim::simulate_calibrated`] with the paper's DiLoCoX settings — so
//! the ranking reflects end-to-end tokens/s (local phase + overlapped
//! WAN sync), not just the bubble fraction.
//!
//!     cargo run --release --example schedule_sweep
//!     cargo run --release --example schedule_sweep -- --out sweep.json

use dilocox::config::Algo;
use dilocox::metrics::Table;
use dilocox::netsim::Topology;
use dilocox::pipeline::ScheduleKind;
use dilocox::sim::{self, ScaleConfig, SimAlgo};
use dilocox::util::fmt_secs;
use dilocox::util::json::{obj, Json};

/// Time-to-target horizon: tokens one run must process.
const TARGET_TOKENS: f64 = 100e9;

/// (schedule, virtual_stages) candidates per cell.  Interleaved needs
/// micros % stages == 0 and v dividing the model evenly; cells where a
/// candidate is inapplicable simply omit it.
const CANDIDATES: [(ScheduleKind, usize); 5] = [
    (ScheduleKind::GPipe, 1),
    (ScheduleKind::OneFOneB, 1),
    (ScheduleKind::Interleaved, 2),
    (ScheduleKind::Interleaved, 4),
    (ScheduleKind::ZeroBubble, 1),
];

fn label(kind: ScheduleKind, v: usize) -> String {
    if v > 1 {
        format!("{} v={v}", kind.name())
    } else {
        kind.name().to_string()
    }
}

fn main() {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let out_path = argv
        .iter()
        .position(|a| a == "--out")
        .and_then(|i| argv.get(i + 1).cloned());

    let rounds = 16;
    let mut table = Table::new(&[
        "S", "M", "schedule", "ideal bubble", "step", "tokens/s",
        "time to 100B tok", "winner",
    ]);
    let mut cells: Vec<Json> = Vec::new();

    for stages in [2usize, 4, 8] {
        for micros in [8usize, 16] {
            // Price every applicable candidate for this (S, M) cell.
            let mut rows: Vec<(String, f64, f64, f64, f64)> = Vec::new();
            for (kind, v) in CANDIDATES {
                if kind == ScheduleKind::Interleaved && micros % stages != 0 {
                    continue;
                }
                let mut scale = ScaleConfig::opt_1_3b();
                scale.pp_stages = stages;
                scale.gpus_per_cluster = stages;
                scale.microbatches = micros;
                let mut topo = Topology::new(&scale.net, scale.pp_stages);
                let step = match sim::pipeline_step_secs_for(
                    &scale, &mut topo, kind, v,
                ) {
                    Ok(s) => s,
                    Err(_) => continue,
                };
                let algo = SimAlgo::paper_setting(Algo::DiLoCoX, &scale);
                let r =
                    sim::simulate_calibrated(&scale, &algo, rounds, Some(step));
                if r.tokens_per_sec <= 0.0 {
                    continue;
                }
                let ideal = kind.ideal_bubble_fraction(stages, v, micros);
                rows.push((
                    label(kind, v),
                    ideal,
                    step,
                    r.tokens_per_sec,
                    TARGET_TOKENS / r.tokens_per_sec,
                ));
            }
            let winner = rows
                .iter()
                .min_by(|a, b| a.4.total_cmp(&b.4))
                .map(|r| r.0.clone())
                .unwrap_or_default();
            for (name, ideal, step, tps, tts) in &rows {
                table.row(&[
                    stages.to_string(),
                    micros.to_string(),
                    name.clone(),
                    format!("{:.1}%", 100.0 * ideal),
                    format!("{:.2} s", step),
                    format!("{tps:.0}"),
                    fmt_secs(*tts),
                    if *name == winner { "<-".into() } else { String::new() },
                ]);
            }
            cells.push(obj(vec![
                ("stages", Json::Num(stages as f64)),
                ("micros", Json::Num(micros as f64)),
                ("winner", Json::Str(winner)),
                (
                    "rows",
                    Json::Arr(
                        rows.iter()
                            .map(|(name, ideal, step, tps, tts)| {
                                obj(vec![
                                    ("schedule", Json::Str(name.clone())),
                                    ("ideal_bubble", Json::Num(*ideal)),
                                    ("step_secs", Json::Num(*step)),
                                    ("tokens_per_sec", Json::Num(*tps)),
                                    ("time_to_target_secs", Json::Num(*tts)),
                                ])
                            })
                            .collect(),
                    ),
                ),
            ]));
        }
    }

    println!(
        "Schedule sweep on the OPT-1.3B testbed (DiLoCoX paper settings, \
         time-to-target = {:.0}B tokens):",
        TARGET_TOKENS / 1e9
    );
    println!("{}", table.render());

    let doc = obj(vec![
        ("scale", Json::Str("OPT-1.3B".into())),
        ("algo", Json::Str("dilocox".into())),
        ("target_tokens", Json::Num(TARGET_TOKENS)),
        ("cells", Json::Arr(cells)),
    ]);
    match out_path {
        Some(path) => {
            if let Err(e) = std::fs::write(&path, doc.to_string_pretty()) {
                eprintln!("failed to write {path}: {e}");
                std::process::exit(1);
            }
            println!("wrote {path}");
        }
        None => println!("{}", doc.to_string_pretty()),
    }
}
