//! End-to-end driver (DESIGN.md deliverable): pre-train a ~110M-parameter
//! GPT-style transformer with the full DiLoCoX stack — threaded
//! decentralized workers, dual optimizer, one-step-delay overlap, low-rank
//! + int4 compressed ring AllReduce — on the synthetic corpus, logging the
//! loss curve and the communication ledger.
//!
//!     make artifacts                       # exports e2e100m (~440 MB)
//!     cargo run --release --example pretrain_e2e -- \
//!         [--outer-steps N] [--local-steps H] [--dp D] [--preset e2e100m]
//!
//! On a laptop-class CPU a 100M step takes seconds; use --preset small for
//! a quick pass.  The recorded run lives in EXPERIMENTS.md §E2E.

use dilocox::config::{Algo, ExperimentConfig};
use dilocox::coordinator::run_threaded;
use dilocox::transport::elastic::{run_elastic, ElasticConfig, SpawnMode, Workload};
use dilocox::transport::TransportBackend;
use dilocox::util::cli::CliSpec;
use dilocox::util::{fmt_bytes, fmt_secs};
use std::time::Instant;

fn main() -> anyhow::Result<()> {
    let spec = CliSpec::new("pretrain_e2e", "~100M e2e DiLoCoX pre-training")
        .opt("preset", "e2e100m", "artifact preset")
        .opt("outer-steps", "10", "outer steps T")
        .opt("local-steps", "20", "local steps H")
        .opt("dp", "2", "decentralized clusters / replicas")
        .opt("pp-stages", "1", "pipeline stages M: >1 runs the stage-parallel 1F1B executor (local transport)")
        .opt("micros", "1", "in-flight microbatches U per inner step (with --pp-stages > 1)")
        .opt("rank", "128", "low-rank r₁")
        .opt("inner-lr", "6e-4", "inner AdamW lr")
        .opt("csv", "", "write per-round loss CSV here")
        .opt("transport", "local", "local (threads) | tcp (worker processes)")
        .opt("kill-round", "0", "tcp: kill --kill-rank at this round (churn demo)")
        .opt("kill-rank", "1", "tcp: rank to kill at --kill-round")
        .flag("no-overlap", "disable one-step-delay overlap");
    let args = match spec.parse(&std::env::args().skip(1).collect::<Vec<_>>()) {
        Ok(a) => a,
        Err(e) => {
            eprintln!("{e}");
            std::process::exit(2);
        }
    };

    let preset = args.get("preset").to_string();
    let artifacts = format!("{}/artifacts/{preset}", env!("CARGO_MANIFEST_DIR"));
    if !std::path::Path::new(&artifacts).exists() {
        eprintln!("{artifacts} missing — run `make artifacts` first");
        std::process::exit(1);
    }

    let mut cfg = ExperimentConfig::default_for(&preset, Algo::DiLoCoX);
    cfg.artifacts_dir = artifacts.clone();
    cfg.parallel.dp = args.get_usize("dp").unwrap();
    cfg.network.clusters = cfg.parallel.dp;
    cfg.train.outer_steps = args.get_usize("outer-steps").unwrap();
    cfg.train.local_steps = args.get_usize("local-steps").unwrap();
    cfg.train.inner_lr = args.get_f64("inner-lr").unwrap() as f32;
    cfg.train.outer_lr = 0.7;
    cfg.train.overlap = !args.flag("no-overlap");
    cfg.compression.rank = args.get_usize("rank").unwrap();
    cfg.compression.adaptive = false; // fixed rank for the recorded run
    cfg.parallel.pp = args
        .get_usize("pp-stages")
        .map_err(|e| anyhow::anyhow!("{e}"))?;
    cfg.parallel.microbatches = args
        .get_usize("micros")
        .map_err(|e| anyhow::anyhow!("{e}"))?;
    // Record the transport in the config BEFORE validating, so the
    // tcp+pp guard actually sees the requested backend (the elastic TCP
    // fleet runs single-stage workers; --pp-stages applies to local).
    let backend = TransportBackend::parse(args.get("transport"))
        .map_err(|e| anyhow::anyhow!("{e:#}"))?;
    cfg.transport.backend = backend;
    cfg.validate()?;

    println!(
        "pretrain_e2e: preset={preset} D={} M={} U={} T={} H={} rank={} overlap={} transport={}",
        cfg.parallel.dp,
        cfg.parallel.pp,
        cfg.parallel.microbatches,
        cfg.train.outer_steps,
        cfg.train.local_steps,
        cfg.compression.rank,
        cfg.train.overlap,
        args.get("transport")
    );

    // ---- elastic multi-process path (churn-tolerant scenario) ------------
    // One OS process per cluster over loopback TCP; optionally kill one
    // worker mid-run and watch the ring re-form with the survivors.
    if backend == TransportBackend::Tcp {
        let kill_round = args
            .get_usize("kill-round")
            .map_err(|e| anyhow::anyhow!("{e}"))?;
        if kill_round > 0 {
            cfg.faults.enabled = true;
            cfg.faults.kill_round = kill_round;
            cfg.faults.kill_rank = args
                .get_usize("kill-rank")
                .map_err(|e| anyhow::anyhow!("{e}"))?;
            // Range-checks kill_rank against dp — an out-of-range rank
            // would otherwise make the churn demo a silent no-op.
            cfg.validate()?;
            println!(
                "fault injection: kill rank {} at round {}",
                cfg.faults.kill_rank, kill_round
            );
        }
        let ecfg = ElasticConfig::from_experiment(
            &cfg,
            Workload::Runtime { artifacts_dir: artifacts.clone() },
        );
        let exe = std::env::current_exe()?;
        // The example binary is not the CLI; workers come from the dilocox
        // binary next to it (cargo puts examples in target/<p>/examples/).
        let dilocox_bin = exe
            .parent()
            .and_then(|p| p.parent())
            .map(|p| p.join("dilocox"))
            .filter(|p| p.exists())
            .ok_or_else(|| anyhow::anyhow!(
                "dilocox binary not found next to the example; \
                 run `cargo build --release` first"
            ))?;
        let t0 = Instant::now();
        let out = run_elastic(
            &ecfg,
            &SpawnMode::Process { exe: dilocox_bin.to_string_lossy().to_string() },
        )?;
        println!("\nround  mean-loss (heartbeats)");
        for (r, mean, n) in out.mean_loss_per_round() {
            println!("{r:>5}  {mean:>9.4}  ({n} workers)");
        }
        println!(
            "\nfinal eval {:.4} | survivors {:?} of {} | epochs {} | wall {} | ring traffic {}",
            out.final_loss,
            out.survivors,
            out.started,
            out.epochs,
            fmt_secs(t0.elapsed().as_secs_f64()),
            fmt_bytes(out.total_wire_bytes)
        );
        println!(
            "note: the elastic tcp path ships raw fp32 pseudo-gradients \
             (--rank does not apply; one-step-delay overlap does — churn \
             mid-reduction recovers via drain-or-discard)"
        );
        if !args.get("csv").is_empty() {
            let mut csv = String::from("round,mean_loss,workers\n");
            for (r, mean, n) in out.mean_loss_per_round() {
                csv.push_str(&format!("{r},{mean},{n}\n"));
            }
            std::fs::write(args.get("csv"), csv)?;
            println!("wrote {}", args.get("csv"));
        }
        return Ok(());
    }

    if cfg.parallel.pp > 1 {
        println!(
            "loading + compiling artifacts on {} workers × {} stage executor threads (1F1B, U={}) ...",
            cfg.parallel.dp, cfg.parallel.pp, cfg.parallel.microbatches
        );
    } else {
        println!("loading + compiling artifacts on {} worker threads ...", cfg.parallel.dp);
    }

    let t0 = Instant::now();
    let out = run_threaded(&cfg, &artifacts)?;
    let wall = t0.elapsed().as_secs_f64();

    println!("\nround  mean-loss  wire/worker");
    let rounds = cfg.train.outer_steps;
    let mut csv = String::from("round,mean_loss,wire_bytes\n");
    for r in 1..=rounds {
        let rs: Vec<&dilocox::coordinator::RoundReport> =
            out.reports.iter().filter(|x| x.round == r).collect();
        let loss: f32 =
            rs.iter().map(|x| x.mean_loss).sum::<f32>() / rs.len() as f32;
        let wire = rs.iter().map(|x| x.wire_bytes).max().unwrap_or(0);
        println!("{r:>5}  {loss:>9.4}  {}", fmt_bytes(wire));
        csv.push_str(&format!("{r},{loss},{wire}\n"));
    }

    let total_inner = rounds * cfg.train.local_steps * cfg.parallel.dp;
    let man = dilocox::runtime::Manifest::load(&artifacts)?;
    let tokens =
        (man.dims.microbatch * man.dims.seq_len * total_inner) as u64;
    println!(
        "\nfinal eval loss {:.4} | {} params | {} inner steps | {} tokens",
        out.final_eval,
        man.param_count,
        total_inner,
        tokens
    );
    println!(
        "wall {} | {:.1} tokens/s on this host | ring traffic {}",
        fmt_secs(wall),
        tokens as f64 / wall,
        fmt_bytes(out.total_wire_bytes)
    );
    // Modeled wire = per-round compressed payload (per worker); the fp32
    // alternative would ship the whole flat gradient each sync.
    let wire_per_worker: u64 = (1..=rounds)
        .map(|r| {
            out.reports
                .iter()
                .filter(|x| x.round == r)
                .map(|x| x.wire_bytes)
                .max()
                .unwrap_or(0)
        })
        .sum();
    let syncs = out.reports.iter().filter(|x| x.wire_bytes > 0).map(|x| x.round)
        .collect::<std::collections::HashSet<_>>().len() as u64;
    let fp32_per_worker = 4 * man.param_count as u64 * syncs;
    if wire_per_worker > 0 {
        println!(
            "compressed sync payload {}/worker vs fp32 {} — {}x reduction",
            fmt_bytes(wire_per_worker),
            fmt_bytes(fp32_per_worker),
            fp32_per_worker / wire_per_worker
        );
    }
    if !args.get("csv").is_empty() {
        std::fs::write(args.get("csv"), csv)?;
        println!("wrote {}", args.get("csv"));
    }
    Ok(())
}
