"""Pure-jnp reference oracles for every Pallas kernel.

These are the correctness ground truth: pytest (and hypothesis sweeps)
assert the Pallas kernels match these to float32 tolerance, and the rust
integration tests compare PJRT execution of the exported HLO against golden
outputs produced by these functions.
"""

import jax
import jax.numpy as jnp


def matmul(a, b):
    """Plain f32 matmul, the oracle for kernels.matmul.matmul_pallas."""
    return jnp.matmul(a, b, preferred_element_type=jnp.float32)


def layernorm(x, g, b, eps=1e-5):
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.var(x, axis=-1, keepdims=True)
    return (x - mu) * jax.lax.rsqrt(var + eps) * g + b


def causal_attention(q, k, v):
    """Causal multi-head attention oracle.

    q, k, v: [B, H, S, hd] -> [B, H, S, hd]
    """
    s = q.shape[-2]
    scale = 1.0 / jnp.sqrt(jnp.asarray(q.shape[-1], jnp.float32))
    scores = jnp.einsum("bhqd,bhkd->bhqk", q, k) * scale
    mask = jnp.tril(jnp.ones((s, s), dtype=bool))
    scores = jnp.where(mask, scores, -1e30)
    probs = jax.nn.softmax(scores, axis=-1)
    return jnp.einsum("bhqk,bhkd->bhqd", probs, v)


def quantize_dequantize(x, q_bits: int):
    """Symmetric uniform q-bit quantization, immediately dequantized.

    This is the *value* effect of wire quantization: the byte accounting
    (q bits/element + one f32 scale) lives in the rust compress module.
    Zero tensors round-trip exactly.
    """
    levels = jnp.asarray(2.0 ** (q_bits - 1) - 1.0, jnp.float32)
    amax = jnp.max(jnp.abs(x))
    scale = jnp.where(amax > 0, amax / levels, 1.0)
    xq = jnp.clip(jnp.round(x / scale), -levels, levels)
    return xq * scale


def orthonormalize(p):
    """Modified Gram-Schmidt over columns of p [m, r] (r static, small).

    Used instead of jnp.linalg.qr so the exported HLO contains no LAPACK
    custom-calls (xla_extension 0.5.1 cannot resolve jax>=0.5's FFI names).
    """
    m, r = p.shape
    cols = []
    for i in range(r):
        c = p[:, i]
        for cprev in cols:
            c = c - jnp.dot(cprev, c) * cprev
        n = jnp.sqrt(jnp.sum(c * c))
        c = c / jnp.maximum(n, 1e-8)
        cols.append(c)
    return jnp.stack(cols, axis=1)


def lowrank_iter(m, q):
    """One PowerSGD-style subspace (power) iteration.

    m: [rows, cols] matrix to compress; q: [cols, r] current basis.
    Returns (p, q_next) with p orthonormal [rows, r], q_next [cols, r].
    The rank-r reconstruction is p @ q_next.T.
    """
    p = matmul(m, q)
    p = orthonormalize(p)
    q_next = matmul(m.T, p)
    return p, q_next


def lowrank_reconstruct(p, q_next):
    return matmul(p, q_next.T)
