"""L1 Pallas kernel: symmetric uniform q-bit quantize -> dequantize.

TPU mapping (DESIGN.md §Hardware-Adaptation): a pure VPU elementwise kernel
(scale, round, clamp, rescale) tiled over VMEM-sized blocks.  The global
abs-max reduction runs as a separate jnp reduction (XLA fuses it); the
kernel consumes the resulting scalar via a (1,)-shaped operand so the whole
pipeline stays AllReduce-compatible (values land back on the q-bit grid on
every worker).

interpret=True: correctness path on CPU PJRT.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _quant_kernel(x_ref, scale_ref, o_ref, *, levels: float):
    scale = scale_ref[0]
    xq = jnp.clip(jnp.round(x_ref[...] / scale), -levels, levels)
    o_ref[...] = xq * scale


@functools.partial(jax.jit, static_argnames=("q_bits", "block"))
def quantize_dequantize_pallas(x, q_bits: int = 4, block: int = 1024):
    """Round x onto the symmetric q-bit grid spanned by its abs-max."""
    orig_shape = x.shape
    flat = x.reshape(-1)
    n = flat.shape[0]
    # Pad to a block multiple so the grid tiles exactly.
    pad = (-n) % block if n > block else 0
    if n <= block:
        block = n
    if pad:
        flat = jnp.concatenate([flat, jnp.zeros((pad,), flat.dtype)])
    levels = float(2 ** (q_bits - 1) - 1)
    amax = jnp.max(jnp.abs(flat))
    scale = jnp.where(amax > 0, amax / levels, 1.0).reshape(1)
    out = pl.pallas_call(
        functools.partial(_quant_kernel, levels=levels),
        grid=(flat.shape[0] // block,),
        in_specs=[
            pl.BlockSpec((block,), lambda i: (i,)),
            pl.BlockSpec((1,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((block,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct(flat.shape, jnp.float32),
        interpret=True,
    )(flat, scale)
    if pad:
        out = out[:n]
    return out.reshape(orig_shape)


def wire_bits(n_elems: int, q_bits: int) -> int:
    """Bits on the wire for a quantized tensor: payload + one f32 scale."""
    return n_elems * q_bits + 32
