"""L1 Pallas kernel: tiled matmul.

TPU mapping (see DESIGN.md §Hardware-Adaptation): the grid walks
(M/bm, N/bn, K/bk) tiles; each (bm, bk) x (bk, bn) product targets the MXU
systolic array, and the (bm, bn) accumulator lives in VMEM for the whole
K sweep (revisiting semantics of the output BlockSpec).  Block shapes are
chosen as 128-multiples when the operand allows, matching the 128x128 MXU
tile; smaller operands fall back to full-dimension blocks.

Runs with interpret=True: the CPU PJRT plugin cannot execute Mosaic
custom-calls, so interpret mode is the correctness path here (DESIGN.md).
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _pick_block(dim: int, target: int) -> int:
    """Largest divisor of `dim` that is <= target (prefers 128-multiples)."""
    if dim <= target:
        return dim
    for cand in (target, 256, 128, 64, 32, 16, 8, 4, 2, 1):
        if cand <= target and dim % cand == 0:
            return cand
    return dim


def _matmul_kernel(a_ref, b_ref, o_ref):
    # k is the innermost ("arbitrary"/sequential) grid axis: accumulate the
    # partial product into the revisited output block.
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[...] += jnp.dot(
        a_ref[...], b_ref[...], preferred_element_type=jnp.float32
    )


@functools.partial(jax.jit, static_argnames=("bm", "bn", "bk"))
def matmul_pallas(a, b, bm: int = 128, bn: int = 128, bk: int = 128):
    """Tiled matmul  a[m,k] @ b[k,n] -> [m,n]  via pallas_call."""
    m, k = a.shape
    k2, n = b.shape
    assert k == k2, (a.shape, b.shape)
    bm = _pick_block(m, bm)
    bn = _pick_block(n, bn)
    bk = _pick_block(k, bk)
    grid = (m // bm, n // bn, k // bk)
    return pl.pallas_call(
        _matmul_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
        interpret=True,
    )(a, b)


# Differentiable wrapper: the VJP of a matmul is two more matmuls, so the
# backward pass stays on the same tiled kernel (MXU work on real TPU).
@jax.custom_vjp
def matmul(a, b):
    return matmul_pallas(a, b)


def _matmul_fwd(a, b):
    return matmul_pallas(a, b), (a, b)


def _matmul_bwd(res, g):
    a, b = res
    return matmul_pallas(g, b.T), matmul_pallas(a.T, g)


matmul.defvjp(_matmul_fwd, _matmul_bwd)


def vmem_bytes(bm: int, bn: int, bk: int, dtype_bytes: int = 4) -> int:
    """Estimated VMEM working set for one grid step (DESIGN.md §Perf)."""
    return dtype_bytes * (bm * bk + bk * bn + bm * bn)


def mxu_utilization(bm: int, bn: int, bk: int, mxu: int = 128) -> float:
    """Fraction of MXU lanes busy for a (bm,bk)x(bk,bn) tile (estimate)."""
    eff_m = min(bm, mxu) / mxu
    eff_n = min(bn, mxu) / mxu
    eff_k = min(bk, mxu) / mxu
    return eff_m * eff_n * eff_k
