"""L1 Pallas kernel: fused causal attention (flash-style).

TPU mapping (DESIGN.md §Hardware-Adaptation): instead of materializing the
S x S score matrix in HBM (what a naive CUDA port would do with shared
memory staging), the kernel streams KV blocks through VMEM and keeps a
running max / running sum per query row — the classic flash recurrence.
Grid = (batch*heads, S/bq); each step holds one (bq, hd) query tile plus a
(bkv, hd) KV tile in VMEM.

interpret=True: correctness path on CPU PJRT (Mosaic is TPU-only).
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

NEG_INF = -1e30


def _attn_kernel(q_ref, k_ref, v_ref, o_ref, *, bq: int, bkv: int, seq: int):
    qi = pl.program_id(1)
    hd = q_ref.shape[-1]
    scale = 1.0 / jnp.sqrt(jnp.asarray(hd, jnp.float32))
    q = q_ref[0] * scale  # [bq, hd]

    q_pos = qi * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bkv), 0)

    def body(kv_i, carry):
        acc, m_prev, l_prev = carry
        k_blk = k_ref[0, pl.ds(kv_i * bkv, bkv), :]
        v_blk = v_ref[0, pl.ds(kv_i * bkv, bkv), :]
        s = jnp.dot(q, k_blk.T, preferred_element_type=jnp.float32)
        kv_pos = kv_i * bkv + jax.lax.broadcasted_iota(
            jnp.int32, (bq, bkv), 1
        )
        s = jnp.where(q_pos >= kv_pos, s, NEG_INF)
        m_cur = jnp.maximum(m_prev, jnp.max(s, axis=-1))
        # Guard fully-masked rows (can only happen transiently).
        alpha = jnp.exp(jnp.minimum(m_prev - m_cur, 0.0))
        p = jnp.exp(s - m_cur[:, None])
        l_cur = l_prev * alpha + jnp.sum(p, axis=-1)
        acc = acc * alpha[:, None] + jnp.dot(
            p, v_blk, preferred_element_type=jnp.float32
        )
        return acc, m_cur, l_cur

    # Causal: query block qi only attends to kv blocks <= qi.
    n_kv = qi + 1 if bq == bkv else seq // bkv
    acc0 = jnp.zeros((bq, hd), jnp.float32)
    m0 = jnp.full((bq,), NEG_INF, jnp.float32)
    l0 = jnp.zeros((bq,), jnp.float32)
    acc, _, l = jax.lax.fori_loop(0, n_kv, body, (acc0, m0, l0))
    o_ref[0] = acc / jnp.maximum(l, 1e-30)[:, None]


# Differentiable wrapper: forward runs the fused kernel; backward
# rematerializes through the reference math (on a real TPU this would be a
# dedicated flash-backward kernel — see DESIGN.md §Hardware-Adaptation).
@jax.custom_vjp
def causal_attention(q, k, v):
    return causal_attention_pallas(q, k, v)


def _attn_fwd(q, k, v):
    return causal_attention_pallas(q, k, v), (q, k, v)


def _attn_bwd(res, g):
    from . import ref

    q, k, v = res
    _, vjp = jax.vjp(ref.causal_attention, q, k, v)
    return vjp(g)


causal_attention.defvjp(_attn_fwd, _attn_bwd)


@functools.partial(jax.jit, static_argnames=("bq", "bkv"))
def causal_attention_pallas(q, k, v, bq: int = 32, bkv: int = 32):
    """Fused causal attention.  q,k,v: [B, H, S, hd] -> [B, H, S, hd]."""
    b, h, s, hd = q.shape
    bq = min(bq, s)
    bkv = min(bkv, s)
    while s % bq:
        bq //= 2
    while s % bkv:
        bkv //= 2
    bh = b * h
    qr = q.reshape(bh, s, hd)
    kr = k.reshape(bh, s, hd)
    vr = v.reshape(bh, s, hd)
    kernel = functools.partial(_attn_kernel, bq=bq, bkv=bkv, seq=s)
    out = pl.pallas_call(
        kernel,
        grid=(bh, s // bq),
        in_specs=[
            pl.BlockSpec((1, bq, hd), lambda bi, qi: (bi, qi, 0)),
            pl.BlockSpec((1, s, hd), lambda bi, qi: (bi, 0, 0)),
            pl.BlockSpec((1, s, hd), lambda bi, qi: (bi, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, bq, hd), lambda bi, qi: (bi, qi, 0)),
        out_shape=jax.ShapeDtypeStruct((bh, s, hd), jnp.float32),
        interpret=True,
    )(qr, kr, vr)
    return out.reshape(b, h, s, hd)
