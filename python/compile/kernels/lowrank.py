"""L1 Pallas kernel: PowerSGD-style low-rank power-iteration step.

The paper's Algorithm 1 compresses pseudo-gradients as
LOWRANK(delta, r) -> QUANTIZE(q).  The low-rank step is two matmuls
(P = M Q, Q' = M^T P) around an orthonormalization — MXU work, tiled by the
shared matmul kernel (DESIGN.md §Hardware-Adaptation).  Orthonormalization
is an unrolled modified Gram-Schmidt (rank r is small and static) so the
exported HLO contains no LAPACK custom-calls.
"""

import functools

import jax
import jax.numpy as jnp

from . import matmul as mm
from . import ref


@functools.partial(jax.jit, static_argnames=("use_pallas",))
def lowrank_iter_pallas(m, q, use_pallas: bool = True):
    """One subspace iteration.  m: [rows, cols], q: [cols, r].

    Returns (p, q_next); reconstruction is p @ q_next.T.
    """
    dot = mm.matmul_pallas if use_pallas else ref.matmul
    p = dot(m, q)
    p = ref.orthonormalize(p)
    q_next = dot(m.T, p)
    return p, q_next


def lowrank_reconstruct_pallas(p, q_next, use_pallas: bool = True):
    dot = mm.matmul_pallas if use_pallas else ref.matmul
    return dot(p, jnp.transpose(q_next))


def wire_floats(rows: int, cols: int, r: int) -> int:
    """f32 elements on the wire for the rank-r factors of a rows x cols
    matrix (P and Q'), before quantization."""
    return r * (rows + cols)
