"""AOT exporter: lower every L2 stage/optimizer program to HLO *text* and
write the artifact bundle the rust coordinator consumes.

artifacts/<preset>/
    manifest.json        — config, program I/O signatures, flat param layout
    <program>.hlo.txt    — HLO text (NOT serialized proto: xla_extension
                           0.5.1 rejects jax>=0.5's 64-bit instruction ids;
                           the text parser reassigns ids — see DESIGN.md)
    stage_<i>.init.bin   — little-endian f32 initial parameters per stage
    single.init.bin      — M=1 layout (== concatenation of the stage inits)
    goldens/             — input/output samples for rust numerics tests

Run once via `make artifacts`; python never appears on the request path.
"""

import argparse
import json
import os
import struct

import numpy as np
import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model as M
from .presets import PRESETS, ModelConfig


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def _sig(args):
    out = []
    for a in args:
        out.append({
            "dtype": str(a.dtype),
            "shape": [int(s) for s in a.shape],
        })
    return out


def _spec_json(spec):
    return [
        {"name": n, "shape": list(s), "offset": o}
        for n, s, o in M.spec_offsets(spec)
    ]


def write_f32(path, arr):
    arr = np.asarray(arr, dtype=np.float32)
    with open(path, "wb") as f:
        f.write(arr.tobytes())


def write_i32(path, arr):
    arr = np.asarray(arr, dtype=np.int32)
    with open(path, "wb") as f:
        f.write(arr.tobytes())


class Exporter:
    def __init__(self, cfg: ModelConfig, out_dir: str, use_pallas: bool,
                 seed: int = 1234):
        self.cfg = cfg
        self.out = out_dir
        self.use_pallas = use_pallas
        self.seed = seed
        self.programs = {}
        self.fns = {}
        os.makedirs(out_dir, exist_ok=True)

    def export(self, name, fn, example_args):
        """Lower fn at example_args, write HLO text, record the signature."""
        lowered = jax.jit(fn).lower(*example_args)
        text = to_hlo_text(lowered)
        fname = f"{name}.hlo.txt"
        with open(os.path.join(self.out, fname), "w") as f:
            f.write(text)
        outs = jax.eval_shape(fn, *example_args)
        self.programs[name] = {
            "file": fname,
            "inputs": _sig(example_args),
            "outputs": _sig(list(outs)),
        }
        self.fns[name] = fn
        return lowered

    # -- example input builders ------------------------------------------

    def shape_f32(self, *shape):
        return jax.ShapeDtypeStruct(shape, jnp.float32)

    def shape_i32(self, *shape):
        return jax.ShapeDtypeStruct(shape, jnp.int32)

    def run(self, cfg):
        c = cfg
        b, s, d = c.microbatch, c.seq_len, c.d_model
        kinds = ["single"]
        if c.pp_stages > 1:
            kinds += ["first", "last"] + (["mid"] if c.pp_stages > 2 else [])
        numel = {
            k: M.spec_numel(M.stage_param_spec(c, k)) for k in kinds
        }

        fns = M.make_stage_fns(c, use_pallas=self.use_pallas)
        sc = self.shape_f32()  # f32 scalar

        # ---- stage programs
        pn = numel["single"]
        self.export("step_single", fns["step_single"],
                    (self.shape_f32(pn), self.shape_i32(b, s),
                     self.shape_i32(b, s)))
        self.export("eval_single", fns["eval_single"],
                    (self.shape_f32(pn), self.shape_i32(b, s),
                     self.shape_i32(b, s)))
        if c.pp_stages > 1:
            acts = self.shape_f32(b, s, d)
            self.export("fwd_first", fns["fwd_first"],
                        (self.shape_f32(numel["first"]),
                         self.shape_i32(b, s)))
            self.export("bwd_first", fns["bwd_first"],
                        (self.shape_f32(numel["first"]),
                         self.shape_i32(b, s), acts))
            if c.pp_stages > 2:
                self.export("fwd_mid", fns["fwd_mid"],
                            (self.shape_f32(numel["mid"]), acts))
                self.export("bwd_mid", fns["bwd_mid"],
                            (self.shape_f32(numel["mid"]), acts, acts))
            self.export("fwd_last", fns["fwd_last"],
                        (self.shape_f32(numel["last"]), acts,
                         self.shape_i32(b, s)))
            self.export("bwd_last", fns["bwd_last"],
                        (self.shape_f32(numel["last"]), acts,
                         self.shape_i32(b, s)))

        # ---- optimizer programs, one per distinct flat size
        for kind in kinds:
            n = numel[kind]
            self.export(f"adamw_{kind}", M.adamw_step,
                        (self.shape_f32(n), self.shape_f32(n),
                         self.shape_f32(n), self.shape_f32(n), sc, sc, sc))
            self.export(f"nesterov_{kind}", M.nesterov_step,
                        (self.shape_f32(n), self.shape_f32(n),
                         self.shape_f32(n), sc, sc))

        # ---- compression programs (pallas L1 lowered into HLO), proving
        #      the L1->L2->L3 composition from rust (tiny/small scale).
        if c.name in ("tiny", "small"):
            from .kernels.lowrank import lowrank_iter_pallas
            from .kernels.quantize import quantize_dequantize_pallas
            rows, cols, r = d, 4 * d, 8
            self.export(
                "lowrank_iter",
                lambda m, q: lowrank_iter_pallas(
                    m, q, use_pallas=self.use_pallas),
                (self.shape_f32(rows, cols), self.shape_f32(cols, r)))
            self.export(
                "quantize_q4",
                lambda x: (quantize_dequantize_pallas(x, q_bits=4),),
                (self.shape_f32(rows, cols),))

        # ---- initial parameters
        init_files = {}
        stage_kinds = []
        if c.pp_stages > 1:
            stage_kinds = (["first"]
                           + ["mid"] * (c.pp_stages - 2)
                           + ["last"])
        stage_inits = []
        for idx, kind in enumerate(stage_kinds):
            w = M.init_stage_params(c, kind, self.seed + idx)
            fname = f"stage_{idx}.init.bin"
            write_f32(os.path.join(self.out, fname), w)
            init_files[f"stage_{idx}"] = {"kind": kind, "file": fname}
            stage_inits.append(w)
        if stage_inits:
            single = np.concatenate(stage_inits)
        else:
            single = M.init_stage_params(c, "single", self.seed)
        assert single.shape[0] == numel["single"], (
            single.shape, numel["single"])
        write_f32(os.path.join(self.out, "single.init.bin"), single)
        init_files["single"] = {"kind": "single", "file": "single.init.bin"}

        # ---- goldens (skip for the big preset: python-side fwd/bwd of
        #      110M params is build-time-only pain with no extra signal)
        goldens = {}
        if c.name != "e2e100m":
            goldens = self.write_goldens(c, single, numel, stage_inits)

        manifest = {
            "preset": c.name,
            "format": "hlo-text-v1",
            "use_pallas": self.use_pallas,
            "config": c.to_dict(),
            "param_count": int(numel["single"]),
            "programs": self.programs,
            "param_specs": {
                k: _spec_json(M.stage_param_spec(c, k)) for k in kinds
            },
            "stage_numel": {k: int(v) for k, v in numel.items()},
            "init": init_files,
            "goldens": goldens,
            "adam": {"b1": M.ADAM_B1, "b2": M.ADAM_B2, "eps": M.ADAM_EPS},
        }
        with open(os.path.join(self.out, "manifest.json"), "w") as f:
            json.dump(manifest, f, indent=1)
        return manifest

    def write_goldens(self, c, single_init, numel, stage_inits):
        """Run each exported program once on deterministic inputs; save the
        inputs and outputs for the rust cross-language numerics test."""
        gdir = os.path.join(self.out, "goldens")
        os.makedirs(gdir, exist_ok=True)
        rng = np.random.RandomState(7)
        b, s, d = c.microbatch, c.seq_len, c.d_model
        tokens = rng.randint(0, c.vocab_size, size=(b, s)).astype(np.int32)
        labels = rng.randint(0, c.vocab_size, size=(b, s)).astype(np.int32)
        acts = (rng.normal(0, 1, size=(b, s, d)).astype(np.float32))

        index = {}

        def golden(name, arrays):
            fn = self.fns[name]
            outs = jax.jit(fn)(*[jnp.asarray(a) for a in arrays])
            entry = {"inputs": [], "outputs": []}
            for i, a in enumerate(arrays):
                fname = f"{name}.in{i}.bin"
                path = os.path.join(gdir, fname)
                if a.dtype == np.int32:
                    write_i32(path, a)
                else:
                    write_f32(path, a)
                entry["inputs"].append(fname)
            for i, o in enumerate(outs):
                fname = f"{name}.out{i}.bin"
                write_f32(os.path.join(gdir, fname), np.asarray(o))
                entry["outputs"].append(fname)
            index[name] = entry

        golden("step_single", (single_init, tokens, labels))
        golden("eval_single", (single_init, tokens, labels))
        if c.pp_stages > 1:
            golden("fwd_first", (stage_inits[0], tokens))
            golden("bwd_first", (stage_inits[0], tokens, acts))
            if c.pp_stages > 2:
                golden("fwd_mid", (stage_inits[1], acts))
                golden("bwd_mid", (stage_inits[1], acts, acts))
            golden("fwd_last", (stage_inits[-1], acts, labels))
            golden("bwd_last", (stage_inits[-1], acts, labels))
        n = numel["single"]
        g = rng.normal(0, 1e-2, size=(n,)).astype(np.float32)
        m0 = np.zeros(n, np.float32)
        golden("adamw_single",
               (single_init, g, m0, m0,
                np.float32(1.0), np.float32(1e-3), np.float32(0.01)))
        golden("nesterov_single",
               (single_init, g, m0, np.float32(0.7), np.float32(0.9)))
        if f"lowrank_iter" in self.fns:
            rows, cols, r = d, 4 * d, 8
            mat = rng.normal(0, 1, size=(rows, cols)).astype(np.float32)
            q0 = rng.normal(0, 1, size=(cols, r)).astype(np.float32)
            golden("lowrank_iter", (mat, q0))
            golden("quantize_q4", (mat,))
        return index


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--preset", default="tiny")
    ap.add_argument("--out-dir", default=None)
    ap.add_argument("--pallas", action="store_true",
                    help="route matmul/attention through the Pallas kernels "
                         "(interpret=True) when lowering")
    ap.add_argument("--seed", type=int, default=1234)
    args = ap.parse_args()
    cfg = PRESETS[args.preset]
    out = args.out_dir or os.path.join(
        os.path.dirname(__file__), "..", "..", "artifacts", args.preset)
    out = os.path.abspath(out)
    ex = Exporter(cfg, out, use_pallas=args.pallas, seed=args.seed)
    man = ex.run(cfg)
    total = sum(
        os.path.getsize(os.path.join(out, f)) for f in os.listdir(out)
        if os.path.isfile(os.path.join(out, f)))
    print(f"[aot] preset={cfg.name} programs={len(man['programs'])} "
          f"params={man['param_count']:,} bytes={total:,} -> {out}")


if __name__ == "__main__":
    main()
