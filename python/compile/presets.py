"""Model presets shared by the L2 model, the AOT exporter, and (via
manifest.json) the rust coordinator.

Every preset fixes the transformer hyperparameters and the example-input
shapes the HLO programs are lowered with.  The rust side never re-derives
these: it reads them back from the manifest.
"""

from dataclasses import dataclass, asdict


@dataclass(frozen=True)
class ModelConfig:
    name: str
    vocab_size: int
    d_model: int
    n_heads: int
    n_layers: int
    seq_len: int
    microbatch: int
    # Pipeline degree the *pipeline-kind* programs are exported for.
    # Single-stage (M=1) programs are always exported as well.
    pp_stages: int

    @property
    def d_ff(self) -> int:
        return 4 * self.d_model

    @property
    def head_dim(self) -> int:
        assert self.d_model % self.n_heads == 0
        return self.d_model // self.n_heads

    @property
    def layers_per_stage(self) -> int:
        assert self.n_layers % self.pp_stages == 0
        return self.n_layers // self.pp_stages

    def to_dict(self):
        d = asdict(self)
        d["d_ff"] = self.d_ff
        d["head_dim"] = self.head_dim
        d["layers_per_stage"] = self.layers_per_stage
        return d


PRESETS = {
    # Unit/integration tests + the pallas-variant composition proof.
    # pp_stages=4 with one layer per stage exercises every stage kind
    # (first / mid / last) from rust.
    "tiny": ModelConfig(
        name="tiny", vocab_size=256, d_model=64, n_heads=2, n_layers=4,
        seq_len=32, microbatch=2, pp_stages=4,
    ),
    # Convergence benches (Fig 3 proxy): ~1M params, fast enough to run
    # thousands of inner steps on one CPU core.
    "small": ModelConfig(
        name="small", vocab_size=512, d_model=128, n_heads=4, n_layers=4,
        seq_len=64, microbatch=4, pp_stages=2,
    ),
    # End-to-end example (~110M params with untied embeddings).
    "e2e100m": ModelConfig(
        name="e2e100m", vocab_size=16384, d_model=768, n_heads=12,
        n_layers=12, seq_len=128, microbatch=2, pp_stages=4,
    ),
}


def param_count(cfg: ModelConfig) -> int:
    """Total parameter count (single-stage layout)."""
    d, v, s, f = cfg.d_model, cfg.vocab_size, cfg.seq_len, cfg.d_ff
    per_layer = (
        2 * d            # ln1
        + 4 * d * d + 4 * d  # wq wk wv wo + biases
        + 2 * d          # ln2
        + d * f + f      # w1 b1
        + f * d + d      # w2 b2
    )
    return v * d + s * d + cfg.n_layers * per_layer + 2 * d + d * v + v
