"""L2: the transformer model as *stage programs* over flat parameter vectors.

Every function here is a pure jax function whose parameters arrive as a
single flat f32 vector; the flat layout (name, shape, offset) is defined by
`stage_param_spec` and exported verbatim into manifest.json so the rust
coordinator can mirror it bit-for-bit.

Stage kinds (pipeline parallelism, DESIGN.md):
  first  — token+position embedding + K transformer layers
  mid    — K transformer layers
  last   — K transformer layers + final LN + LM head + cross-entropy loss
  single — the whole model (M=1), used by the data-parallel-only paths

Backward programs rematerialize the forward (jax.vjp over the stage fn), so
no activation stash crosses the rust/HLO boundary — the standard
pipeline-parallel recompute choice.
"""

import functools

import jax
import jax.numpy as jnp

from .presets import ModelConfig
from .kernels import ref
from .kernels.matmul import matmul as matmul_pallas
from .kernels.attention import causal_attention as causal_attention_pallas

# ---------------------------------------------------------------------------
# Flat parameter layout
# ---------------------------------------------------------------------------


def layer_param_spec(cfg: ModelConfig, prefix: str):
    d, f = cfg.d_model, cfg.d_ff
    return [
        (f"{prefix}.ln1_g", (d,)),
        (f"{prefix}.ln1_b", (d,)),
        (f"{prefix}.wq", (d, d)),
        (f"{prefix}.bq", (d,)),
        (f"{prefix}.wk", (d, d)),
        (f"{prefix}.bk", (d,)),
        (f"{prefix}.wv", (d, d)),
        (f"{prefix}.bv", (d,)),
        (f"{prefix}.wo", (d, d)),
        (f"{prefix}.bo", (d,)),
        (f"{prefix}.ln2_g", (d,)),
        (f"{prefix}.ln2_b", (d,)),
        (f"{prefix}.w1", (d, f)),
        (f"{prefix}.b1", (f,)),
        (f"{prefix}.w2", (f, d)),
        (f"{prefix}.b2", (d,)),
    ]


def stage_param_spec(cfg: ModelConfig, kind: str):
    """(name, shape) list for one stage kind; order == flat layout order."""
    v, d, s = cfg.vocab_size, cfg.d_model, cfg.seq_len
    k = cfg.n_layers if kind == "single" else cfg.layers_per_stage
    spec = []
    if kind in ("first", "single"):
        spec += [("tok_emb", (v, d)), ("pos_emb", (s, d))]
    for i in range(k):
        spec += layer_param_spec(cfg, f"layer{i}")
    if kind in ("last", "single"):
        spec += [
            ("lnf_g", (d,)),
            ("lnf_b", (d,)),
            ("head_w", (d, v)),
            ("head_b", (v,)),
        ]
    return spec


def spec_numel(spec) -> int:
    n = 0
    for _, shape in spec:
        c = 1
        for s in shape:
            c *= s
        n += c
    return n


def spec_offsets(spec):
    """[(name, shape, offset)] with offsets in f32 elements."""
    out, off = [], 0
    for name, shape in spec:
        c = 1
        for s in shape:
            c *= s
        out.append((name, shape, off))
        off += c
    return out


def unflatten(flat, spec):
    params = {}
    for name, shape, off in spec_offsets(spec):
        c = 1
        for s in shape:
            c *= s
        params[name] = jax.lax.dynamic_slice(flat, (off,), (c,)).reshape(shape)
    return params


# ---------------------------------------------------------------------------
# Initialization (numpy side; also writes the .bin artifacts)
# ---------------------------------------------------------------------------


def init_stage_params(cfg: ModelConfig, kind: str, seed: int):
    """Flat f32 numpy vector with GPT-2-style init (0.02 normal, residual
    projections scaled by 1/sqrt(2L))."""
    import numpy as np

    rng = np.random.RandomState(seed)
    resid_scale = 1.0 / np.sqrt(2.0 * cfg.n_layers)
    chunks = []
    for name, shape in stage_param_spec(cfg, kind):
        base = name.split(".")[-1]
        if base in ("ln1_g", "ln2_g", "lnf_g"):
            w = np.ones(shape, np.float32)
        elif base in ("ln1_b", "ln2_b", "lnf_b", "bq", "bk", "bv", "bo",
                      "b1", "b2", "head_b"):
            w = np.zeros(shape, np.float32)
        else:
            w = rng.normal(0.0, 0.02, size=shape).astype(np.float32)
            if base in ("wo", "w2"):
                w *= resid_scale
        chunks.append(w.reshape(-1))
    return np.concatenate(chunks)


# ---------------------------------------------------------------------------
# Model math
# ---------------------------------------------------------------------------


def _attention(x, p, prefix, cfg: ModelConfig, use_pallas: bool):
    b, s, d = x.shape
    h, hd = cfg.n_heads, cfg.head_dim

    def proj(w, bias):
        if use_pallas:
            y = matmul_pallas(x.reshape(b * s, d), w)
        else:
            y = ref.matmul(x.reshape(b * s, d), w)
        return (y + bias).reshape(b, s, h, hd).transpose(0, 2, 1, 3)

    q = proj(p[f"{prefix}.wq"], p[f"{prefix}.bq"])
    k = proj(p[f"{prefix}.wk"], p[f"{prefix}.bk"])
    v = proj(p[f"{prefix}.wv"], p[f"{prefix}.bv"])
    if use_pallas:
        o = causal_attention_pallas(q, k, v)
    else:
        o = ref.causal_attention(q, k, v)
    o = o.transpose(0, 2, 1, 3).reshape(b * s, d)
    if use_pallas:
        o = matmul_pallas(o, p[f"{prefix}.wo"])
    else:
        o = ref.matmul(o, p[f"{prefix}.wo"])
    return (o + p[f"{prefix}.bo"]).reshape(b, s, d)


def _mlp(x, p, prefix, use_pallas: bool):
    b, s, d = x.shape
    xf = x.reshape(b * s, d)
    dot = matmul_pallas if use_pallas else ref.matmul
    h = dot(xf, p[f"{prefix}.w1"]) + p[f"{prefix}.b1"]
    h = jax.nn.gelu(h, approximate=True)
    o = dot(h, p[f"{prefix}.w2"]) + p[f"{prefix}.b2"]
    return o.reshape(b, s, d)


def _layer(x, p, prefix, cfg, use_pallas):
    x = x + _attention(
        ref.layernorm(x, p[f"{prefix}.ln1_g"], p[f"{prefix}.ln1_b"]),
        p, prefix, cfg, use_pallas,
    )
    x = x + _mlp(
        ref.layernorm(x, p[f"{prefix}.ln2_g"], p[f"{prefix}.ln2_b"]),
        p, prefix, use_pallas,
    )
    return x


def _layers(x, p, n, cfg, use_pallas):
    for i in range(n):
        x = _layer(x, p, f"layer{i}", cfg, use_pallas)
    return x


def _embed(tokens, p):
    return jnp.take(p["tok_emb"], tokens, axis=0) + p["pos_emb"][None, :, :]


def _head_loss(x, p, labels, cfg, use_pallas):
    b, s, d = x.shape
    x = ref.layernorm(x, p["lnf_g"], p["lnf_b"])
    dot = matmul_pallas if use_pallas else ref.matmul
    logits = dot(x.reshape(b * s, d), p["head_w"]) + p["head_b"]
    logits = logits.astype(jnp.float32)
    lse = jax.scipy.special.logsumexp(logits, axis=-1)
    ll = jnp.take_along_axis(
        logits, labels.reshape(b * s, 1), axis=-1
    ).squeeze(-1)
    return jnp.mean(lse - ll)


# ---------------------------------------------------------------------------
# Stage programs (the functions aot.py lowers to HLO)
# ---------------------------------------------------------------------------


def make_stage_fns(cfg: ModelConfig, use_pallas: bool = False):
    """Returns dict of python callables keyed by program name."""
    k = cfg.layers_per_stage
    sp = {kind: stage_param_spec(cfg, kind)
          for kind in ("first", "mid", "last", "single")}

    def fwd_first(params, tokens):
        p = unflatten(params, sp["first"])
        return (_layers(_embed(tokens, p), p, k, cfg, use_pallas),)

    def fwd_mid(params, acts):
        p = unflatten(params, sp["mid"])
        return (_layers(acts, p, k, cfg, use_pallas),)

    def fwd_last(params, acts, labels):
        p = unflatten(params, sp["last"])
        x = _layers(acts, p, k, cfg, use_pallas)
        return (_head_loss(x, p, labels, cfg, use_pallas),)

    def fwd_single(params, tokens, labels):
        p = unflatten(params, sp["single"])
        x = _layers(_embed(tokens, p), p, cfg.n_layers, cfg, use_pallas)
        return (_head_loss(x, p, labels, cfg, use_pallas),)

    def bwd_first(params, tokens, g_out):
        def f(pp):
            return fwd_first(pp, tokens)[0]
        _, vjp = jax.vjp(f, params)
        return (vjp(g_out)[0],)

    def bwd_mid(params, acts, g_out):
        def f(pp, a):
            return fwd_mid(pp, a)[0]
        _, vjp = jax.vjp(f, params, acts)
        gp, ga = vjp(g_out)
        return (gp, ga)

    def bwd_last(params, acts, labels):
        def f(pp, a):
            return fwd_last(pp, a, labels)[0]
        loss, vjp = jax.vjp(f, params, acts)
        gp, ga = vjp(jnp.float32(1.0))
        return (loss, gp, ga)

    def step_single(params, tokens, labels):
        def f(pp):
            return fwd_single(pp, tokens, labels)[0]
        loss, vjp = jax.vjp(f, params)
        return (loss, vjp(jnp.float32(1.0))[0])

    def eval_single(params, tokens, labels):
        return (fwd_single(params, tokens, labels)[0],)

    return {
        "fwd_first": fwd_first,
        "fwd_mid": fwd_mid,
        "fwd_last": fwd_last,
        "bwd_first": bwd_first,
        "bwd_mid": bwd_mid,
        "bwd_last": bwd_last,
        "step_single": step_single,
        "eval_single": eval_single,
    }


# ---------------------------------------------------------------------------
# Optimizer programs (flat-vector AdamW inner / Nesterov outer)
# ---------------------------------------------------------------------------

ADAM_B1, ADAM_B2, ADAM_EPS = 0.9, 0.999, 1e-8


def adamw_step(p, g, m, v, t, lr, wd):
    """One AdamW step on a flat vector.  t is the 1-based step as f32."""
    m = ADAM_B1 * m + (1.0 - ADAM_B1) * g
    v = ADAM_B2 * v + (1.0 - ADAM_B2) * g * g
    mhat = m / (1.0 - jnp.power(ADAM_B1, t))
    vhat = v / (1.0 - jnp.power(ADAM_B2, t))
    p = p - lr * (mhat / (jnp.sqrt(vhat) + ADAM_EPS) + wd * p)
    return (p, m, v)


def nesterov_step(p, delta, buf, lr, mu):
    """DiLoCo outer update: SGD with Nesterov momentum applied to the
    averaged pseudo-gradient delta = theta_old - theta_new."""
    buf = mu * buf + delta
    p = p - lr * (delta + mu * buf)
    return (p, buf)
