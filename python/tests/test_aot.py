"""Artifact bundle integrity: manifests exist, signatures match presets,
init bins have the right sizes, and goldens re-verify against live jax."""

import json
import os

import numpy as np
import pytest
from numpy.testing import assert_allclose

from compile import model as M
from compile.presets import PRESETS

BUNDLES = ["tiny", "small", "tiny-pallas", "e2e100m"]


def _load(artifacts_dir, bundle):
    path = os.path.join(artifacts_dir, bundle, "manifest.json")
    if not os.path.exists(path):
        pytest.skip(f"{bundle} not exported (run `make artifacts`)")
    with open(path) as f:
        return json.load(f), os.path.join(artifacts_dir, bundle)


@pytest.mark.parametrize("bundle", BUNDLES)
def test_manifest_programs_exist(artifacts_dir, bundle):
    man, root = _load(artifacts_dir, bundle)
    assert man["format"] == "hlo-text-v1"
    for name, prog in man["programs"].items():
        p = os.path.join(root, prog["file"])
        assert os.path.exists(p), name
        head = open(p).read(200)
        assert "HloModule" in head, name


@pytest.mark.parametrize("bundle", ["tiny", "small", "e2e100m"])
def test_manifest_matches_preset(artifacts_dir, bundle):
    man, _ = _load(artifacts_dir, bundle)
    cfg = PRESETS[man["preset"]]
    assert man["config"]["d_model"] == cfg.d_model
    assert man["config"]["n_layers"] == cfg.n_layers
    assert man["param_count"] == M.spec_numel(
        M.stage_param_spec(cfg, "single"))


@pytest.mark.parametrize("bundle", ["tiny", "small"])
def test_init_bins_sizes(artifacts_dir, bundle):
    man, root = _load(artifacts_dir, bundle)
    for key, info in man["init"].items():
        kind = info["kind"]
        numel = man["stage_numel"][kind]
        size = os.path.getsize(os.path.join(root, info["file"]))
        assert size == 4 * numel, key


def test_single_init_is_concat_of_stages(artifacts_dir):
    man, root = _load(artifacts_dir, "tiny")
    stages = sorted(k for k in man["init"] if k.startswith("stage_"))
    parts = [
        np.fromfile(os.path.join(root, man["init"][k]["file"]), np.float32)
        for k in stages
    ]
    single = np.fromfile(
        os.path.join(root, man["init"]["single"]["file"]), np.float32)
    assert_allclose(np.concatenate(parts), single)


def test_param_spec_offsets_match_model(artifacts_dir):
    man, _ = _load(artifacts_dir, "tiny")
    cfg = PRESETS["tiny"]
    for kind, spec_json in man["param_specs"].items():
        live = M.spec_offsets(M.stage_param_spec(cfg, kind))
        assert len(live) == len(spec_json)
        for (name, shape, off), ent in zip(live, spec_json):
            assert ent["name"] == name
            assert tuple(ent["shape"]) == tuple(shape)
            assert ent["offset"] == off


@pytest.mark.parametrize("bundle", ["tiny"])
def test_goldens_reverify_against_live_jax(artifacts_dir, bundle):
    """Re-run each goldened program with live jax on the stored inputs and
    confirm the stored outputs — guards against layout or export drift."""
    import jax.numpy as jnp

    man, root = _load(artifacts_dir, bundle)
    cfg = PRESETS[man["preset"]]
    fns = M.make_stage_fns(cfg, use_pallas=man["use_pallas"])
    fns["adamw_single"] = M.adamw_step
    fns["nesterov_single"] = M.nesterov_step
    gdir = os.path.join(root, "goldens")
    for name, entry in man["goldens"].items():
        if name not in fns:
            continue
        sig = man["programs"][name]["inputs"]
        args = []
        for fname, s in zip(entry["inputs"], sig):
            dt = np.int32 if s["dtype"] == "int32" else np.float32
            a = np.fromfile(os.path.join(gdir, fname), dt)
            args.append(jnp.asarray(a.reshape(s["shape"])))
        outs = fns[name](*args)
        for fname, o in zip(entry["outputs"], outs):
            want = np.fromfile(os.path.join(gdir, fname), np.float32)
            assert_allclose(
                np.asarray(o).reshape(-1), want, rtol=1e-4, atol=1e-5,
                err_msg=f"{name}:{fname}")
