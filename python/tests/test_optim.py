"""Optimizer-program algebra: AdamW vs a literal numpy transcription, and
the DiLoCo outer Nesterov update, including the dual-optimizer interplay
the rust trainer relies on (outer step applied to the *delayed* delta)."""

import numpy as np
import jax.numpy as jnp
from numpy.testing import assert_allclose

from compile import model as M


def _np_adamw(p, g, m, v, t, lr, wd, b1=0.9, b2=0.999, eps=1e-8):
    m = b1 * m + (1 - b1) * g
    v = b2 * v + (1 - b2) * g * g
    mhat = m / (1 - b1 ** t)
    vhat = v / (1 - b2 ** t)
    p = p - lr * (mhat / (np.sqrt(vhat) + eps) + wd * p)
    return p, m, v


def test_adamw_matches_numpy():
    rng = np.random.RandomState(0)
    n = 257
    p = rng.normal(size=n).astype(np.float32)
    m = np.zeros(n, np.float32)
    v = np.zeros(n, np.float32)
    pj, mj, vj = jnp.asarray(p), jnp.asarray(m), jnp.asarray(v)
    for t in range(1, 6):
        g = rng.normal(size=n).astype(np.float32)
        p, m, v = _np_adamw(p, g, m, v, t, 1e-3, 0.01)
        pj, mj, vj = M.adamw_step(pj, jnp.asarray(g), mj, vj,
                                  jnp.float32(t), jnp.float32(1e-3),
                                  jnp.float32(0.01))
        assert_allclose(np.asarray(pj), p, rtol=1e-5, atol=1e-6)
        assert_allclose(np.asarray(vj), v, rtol=1e-5, atol=1e-7)


def test_adamw_bias_correction_first_step():
    # At t=1 with zero state, mhat == g exactly, so p' = p - lr*sign-ish(g).
    p = jnp.zeros(4)
    g = jnp.asarray(np.array([1.0, -1.0, 2.0, 0.0], np.float32))
    p1, _, _ = M.adamw_step(p, g, jnp.zeros(4), jnp.zeros(4),
                            jnp.float32(1.0), jnp.float32(0.1),
                            jnp.float32(0.0))
    # mhat/ (sqrt(vhat)+eps) == sign(g) for any nonzero g at t=1.
    assert_allclose(np.asarray(p1), [-0.1, 0.1, -0.1, 0.0],
                    rtol=1e-4, atol=1e-5)


def test_nesterov_momentum_accumulates():
    n = 8
    p = jnp.zeros(n)
    buf = jnp.zeros(n)
    delta = jnp.ones(n)
    lr, mu = jnp.float32(1.0), jnp.float32(0.9)
    p1, buf1 = M.nesterov_step(p, delta, buf, lr, mu)
    # buf' = mu*0 + 1 = 1 ; p' = 0 - 1*(1 + 0.9*1) = -1.9
    assert_allclose(np.asarray(buf1), 1.0)
    assert_allclose(np.asarray(p1), -1.9)
    p2, buf2 = M.nesterov_step(p1, delta, buf1, lr, mu)
    # buf'' = 0.9 + 1 = 1.9 ; p'' = -1.9 - (1 + 0.9*1.9) = -4.61
    assert_allclose(np.asarray(buf2), 1.9)
    assert_allclose(np.asarray(p2), -4.61, rtol=1e-6)


def test_nesterov_applies_descent_direction():
    # delta = theta_old - theta_new of a loss-reducing local run must move
    # the outer params toward theta_new.
    rng = np.random.RandomState(1)
    p_old = rng.normal(size=16).astype(np.float32)
    p_new = p_old - 0.1  # local training moved params down
    delta = p_old - p_new  # = +0.1
    p1, _ = M.nesterov_step(jnp.asarray(p_old), jnp.asarray(delta),
                            jnp.zeros(16), jnp.float32(0.7),
                            jnp.float32(0.9))
    assert np.all(np.asarray(p1) < p_old)  # moved in the local direction
