import os
import sys

import numpy as np
import pytest

# Make `compile` importable regardless of pytest invocation directory.
sys.path.insert(0, os.path.dirname(os.path.dirname(os.path.abspath(__file__))))


@pytest.fixture(scope="session")
def rng():
    return np.random.RandomState(0)


@pytest.fixture(scope="session")
def artifacts_dir():
    return os.path.abspath(
        os.path.join(os.path.dirname(__file__), "..", "..", "artifacts"))
