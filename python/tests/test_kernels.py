"""L1 correctness: every Pallas kernel against the pure-jnp oracle.

Hypothesis sweeps shapes (and q-bit widths); fixed-seed numpy drives the
values.  Tolerances are float32-accumulation level.
"""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st
from numpy.testing import assert_allclose

from compile.kernels import ref
from compile.kernels.matmul import (
    matmul, matmul_pallas, vmem_bytes, mxu_utilization, _pick_block)
from compile.kernels.attention import (
    causal_attention, causal_attention_pallas)
from compile.kernels.quantize import quantize_dequantize_pallas, wire_bits
from compile.kernels.lowrank import lowrank_iter_pallas, wire_floats

SETTINGS = dict(max_examples=12, deadline=None)


def _arr(rng, *shape):
    return rng.normal(0.0, 1.0, size=shape).astype(np.float32)


# ---------------------------------------------------------------- matmul


@settings(**SETTINGS)
@given(
    m=st.sampled_from([1, 3, 16, 48, 128]),
    k=st.sampled_from([2, 8, 64, 96, 256]),
    n=st.sampled_from([1, 4, 32, 128]),
    seed=st.integers(0, 2**16),
)
def test_matmul_matches_ref(m, k, n, seed):
    rng = np.random.RandomState(seed)
    a, b = _arr(rng, m, k), _arr(rng, k, n)
    got = np.asarray(matmul_pallas(a, b))
    want = np.asarray(ref.matmul(a, b))
    assert_allclose(got, want, rtol=1e-5, atol=1e-4)


@settings(**SETTINGS)
@given(
    m=st.sampled_from([32, 64, 128]),
    seed=st.integers(0, 2**16),
)
def test_matmul_vjp_matches_ref(m, seed):
    import jax

    rng = np.random.RandomState(seed)
    a, b = _arr(rng, m, 2 * m), _arr(rng, 2 * m, m)

    ga_p, gb_p = jax.grad(lambda a, b: matmul(a, b).sum(), argnums=(0, 1))(a, b)
    ga_r, gb_r = jax.grad(
        lambda a, b: ref.matmul(a, b).sum(), argnums=(0, 1))(a, b)
    assert_allclose(np.asarray(ga_p), np.asarray(ga_r), rtol=1e-5, atol=1e-4)
    assert_allclose(np.asarray(gb_p), np.asarray(gb_r), rtol=1e-5, atol=1e-4)


def test_pick_block_divides():
    for dim in (1, 2, 48, 64, 100, 128, 384, 1000):
        blk = _pick_block(dim, 128)
        assert dim % blk == 0 and blk <= max(dim, 128)


def test_vmem_estimates_monotone():
    assert vmem_bytes(128, 128, 128) > vmem_bytes(64, 64, 64)
    assert 0.0 < mxu_utilization(64, 64, 64) < mxu_utilization(128, 128, 128) <= 1.0


# ------------------------------------------------------------- attention


@settings(**SETTINGS)
@given(
    b=st.sampled_from([1, 2]),
    h=st.sampled_from([1, 2, 4]),
    s=st.sampled_from([8, 32, 64, 128]),
    hd=st.sampled_from([8, 16, 32]),
    seed=st.integers(0, 2**16),
)
def test_attention_matches_ref(b, h, s, hd, seed):
    rng = np.random.RandomState(seed)
    q, k, v = (_arr(rng, b, h, s, hd) for _ in range(3))
    got = np.asarray(causal_attention_pallas(q, k, v))
    want = np.asarray(ref.causal_attention(q, k, v))
    assert_allclose(got, want, rtol=1e-4, atol=1e-4)


def test_attention_is_causal():
    # Perturbing a future position must not change earlier outputs.
    rng = np.random.RandomState(3)
    q, k, v = (_arr(rng, 1, 1, 16, 8) for _ in range(3))
    base = np.asarray(causal_attention_pallas(q, k, v))
    k2, v2 = k.copy(), v.copy()
    k2[0, 0, -1] += 10.0
    v2[0, 0, -1] -= 5.0
    pert = np.asarray(causal_attention_pallas(q, k2, v2))
    assert_allclose(base[0, 0, :15], pert[0, 0, :15], rtol=1e-5, atol=1e-5)
    assert not np.allclose(base[0, 0, 15], pert[0, 0, 15])


def test_attention_vjp_matches_ref():
    import jax

    rng = np.random.RandomState(11)
    q, k, v = (_arr(rng, 1, 2, 16, 8) for _ in range(3))
    g_p = jax.grad(lambda q: causal_attention(q, k, v).sum())(q)
    g_r = jax.grad(lambda q: ref.causal_attention(q, k, v).sum())(q)
    assert_allclose(np.asarray(g_p), np.asarray(g_r), rtol=1e-4, atol=1e-4)


# ---------------------------------------------------------------- quantize


@settings(**SETTINGS)
@given(
    n=st.sampled_from([7, 64, 1000, 4096]),
    q=st.sampled_from([2, 4, 8]),
    seed=st.integers(0, 2**16),
)
def test_quantize_matches_ref(n, q, seed):
    rng = np.random.RandomState(seed)
    x = _arr(rng, n)
    got = np.asarray(quantize_dequantize_pallas(x, q_bits=q))
    want = np.asarray(ref.quantize_dequantize(x, q))
    assert_allclose(got, want, rtol=1e-6, atol=1e-6)


@settings(**SETTINGS)
@given(q=st.sampled_from([2, 4, 8]), seed=st.integers(0, 2**16))
def test_quantize_error_bounded_by_half_step(q, seed):
    rng = np.random.RandomState(seed)
    x = _arr(rng, 512)
    y = np.asarray(quantize_dequantize_pallas(x, q_bits=q))
    levels = 2 ** (q - 1) - 1
    step = np.abs(x).max() / levels
    assert np.abs(x - y).max() <= 0.5 * step + 1e-6


def test_quantize_zero_roundtrip_exact():
    x = np.zeros(33, np.float32)
    assert np.abs(np.asarray(quantize_dequantize_pallas(x, 4))).max() == 0.0


def test_wire_bits_accounting():
    assert wire_bits(1000, 4) == 4 * 1000 + 32
    assert wire_bits(0, 8) == 32


# ---------------------------------------------------------------- lowrank


@settings(**SETTINGS)
@given(
    rows=st.sampled_from([16, 64, 96]),
    cols=st.sampled_from([16, 48, 128]),
    r=st.sampled_from([1, 4, 8]),
    seed=st.integers(0, 2**16),
)
def test_lowrank_matches_ref(rows, cols, r, seed):
    rng = np.random.RandomState(seed)
    m = _arr(rng, rows, cols)
    q0 = _arr(rng, cols, r)
    p1, q1 = lowrank_iter_pallas(m, q0)
    p2, q2 = ref.lowrank_iter(m, q0)
    assert_allclose(np.asarray(p1), np.asarray(p2), rtol=1e-4, atol=1e-4)
    assert_allclose(np.asarray(q1), np.asarray(q2), rtol=1e-3, atol=1e-3)


def test_lowrank_p_is_orthonormal():
    rng = np.random.RandomState(5)
    m, q0 = _arr(rng, 64, 96), _arr(rng, 96, 8)
    p, _ = lowrank_iter_pallas(m, q0)
    gram = np.asarray(ref.matmul(np.asarray(p).T, np.asarray(p)))
    assert_allclose(gram, np.eye(8), rtol=0, atol=1e-4)


def test_lowrank_exact_for_lowrank_input():
    # A rank-r matrix must be reconstructed (near) exactly at rank r.
    rng = np.random.RandomState(9)
    u, w = _arr(rng, 64, 4), _arr(rng, 4, 96)
    m = u @ w
    q0 = _arr(rng, 96, 4)
    p, qn = ref.lowrank_iter(m, q0)
    rec = np.asarray(ref.lowrank_reconstruct(p, qn))
    assert_allclose(rec, m, rtol=1e-3, atol=1e-3)


def test_lowrank_error_decreases_with_rank():
    rng = np.random.RandomState(13)
    m = _arr(rng, 64, 96)
    errs = []
    for r in (1, 4, 16, 64):
        q0 = _arr(rng, 96, r)
        p, qn = ref.lowrank_iter(m, q0)
        rec = np.asarray(ref.lowrank_reconstruct(p, qn))
        errs.append(np.linalg.norm(rec - m) / np.linalg.norm(m))
    assert errs == sorted(errs, reverse=True)
    assert errs[-1] < 1e-3  # full rank -> exact


def test_wire_floats_accounting():
    assert wire_floats(100, 50, 4) == 4 * 150
