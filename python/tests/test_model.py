"""L2 correctness: flat layout invariants, stage composition, training signal.

The stage-composition tests are the load-bearing ones: the rust coordinator
assumes (a) concat(stage params) == single params, and (b) chaining
fwd_first -> fwd_mid* -> fwd_last reproduces fwd_single exactly.
"""

import numpy as np
import jax.numpy as jnp
import pytest
from numpy.testing import assert_allclose

from compile import model as M
from compile.presets import PRESETS, param_count

CFG = PRESETS["tiny"]


@pytest.fixture(scope="module")
def fns():
    return M.make_stage_fns(CFG, use_pallas=False)


@pytest.fixture(scope="module")
def stage_inits():
    kinds = ["first"] + ["mid"] * (CFG.pp_stages - 2) + ["last"]
    return [M.init_stage_params(CFG, k, 1234 + i) for i, k in enumerate(kinds)]


@pytest.fixture(scope="module")
def batch():
    rng = np.random.RandomState(42)
    tokens = rng.randint(0, CFG.vocab_size,
                         size=(CFG.microbatch, CFG.seq_len)).astype(np.int32)
    labels = rng.randint(0, CFG.vocab_size,
                         size=(CFG.microbatch, CFG.seq_len)).astype(np.int32)
    return tokens, labels


# ----------------------------------------------------------- param layout


def test_param_count_formula_matches_spec():
    for cfg in PRESETS.values():
        spec = M.stage_param_spec(cfg, "single")
        assert M.spec_numel(spec) == param_count(cfg), cfg.name


def test_stage_specs_concat_to_single():
    for cfg in PRESETS.values():
        kinds = ["first"] + ["mid"] * (cfg.pp_stages - 2) + ["last"]
        total = sum(
            M.spec_numel(M.stage_param_spec(cfg, k)) for k in kinds)
        assert total == M.spec_numel(M.stage_param_spec(cfg, "single"))


def test_offsets_are_contiguous():
    spec = M.stage_param_spec(CFG, "single")
    off = 0
    for name, shape, o in M.spec_offsets(spec):
        assert o == off
        c = 1
        for s in shape:
            c *= s
        off += c
    assert off == M.spec_numel(spec)


def test_unflatten_roundtrip():
    spec = M.stage_param_spec(CFG, "mid")
    n = M.spec_numel(spec)
    flat = np.arange(n, dtype=np.float32)
    params = M.unflatten(jnp.asarray(flat), spec)
    rebuilt = np.concatenate(
        [np.asarray(params[name]).reshape(-1) for name, _ in spec])
    assert_allclose(rebuilt, flat)


def test_init_deterministic_and_layernorm_ones():
    a = M.init_stage_params(CFG, "single", 7)
    b = M.init_stage_params(CFG, "single", 7)
    assert_allclose(a, b)
    params = M.unflatten(jnp.asarray(a), M.stage_param_spec(CFG, "single"))
    assert_allclose(np.asarray(params["layer0.ln1_g"]), 1.0)
    assert_allclose(np.asarray(params["layer0.bq"]), 0.0)


# ------------------------------------------------------ stage composition


def test_pipeline_fwd_equals_single(fns, stage_inits, batch):
    tokens, labels = batch
    single = jnp.asarray(np.concatenate(stage_inits))
    loss_single = fns["eval_single"](single, tokens, labels)[0]

    acts = fns["fwd_first"](jnp.asarray(stage_inits[0]), tokens)[0]
    for mid in stage_inits[1:-1]:
        acts = fns["fwd_mid"](jnp.asarray(mid), acts)[0]
    loss_pipe = fns["fwd_last"](jnp.asarray(stage_inits[-1]), acts, labels)[0]
    assert_allclose(float(loss_pipe), float(loss_single), rtol=1e-5)


def test_pipeline_bwd_equals_single(fns, stage_inits, batch):
    tokens, labels = batch
    single = jnp.asarray(np.concatenate(stage_inits))
    loss, g_single = fns["step_single"](single, tokens, labels)

    # Forward chain, stashing stage inputs.
    inputs = [tokens]
    acts = fns["fwd_first"](jnp.asarray(stage_inits[0]), tokens)[0]
    for mid in stage_inits[1:-1]:
        inputs.append(acts)
        acts = fns["fwd_mid"](jnp.asarray(mid), acts)[0]
    inputs.append(acts)

    # Backward chain.
    grads = [None] * len(stage_inits)
    loss_p, gp_last, ga = fns["bwd_last"](
        jnp.asarray(stage_inits[-1]), inputs[-1], labels)
    grads[-1] = gp_last
    for i in range(len(stage_inits) - 2, 0, -1):
        gp, ga = fns["bwd_mid"](jnp.asarray(stage_inits[i]), inputs[i], ga)
        grads[i] = gp
    grads[0] = fns["bwd_first"](jnp.asarray(stage_inits[0]), tokens, ga)[0]

    g_pipe = np.concatenate([np.asarray(g).reshape(-1) for g in grads])
    assert_allclose(float(loss_p), float(loss), rtol=1e-5)
    assert_allclose(g_pipe, np.asarray(g_single), rtol=1e-3, atol=1e-5)


def test_loss_is_lnV_at_init_scale(fns, stage_inits, batch):
    # With near-zero logits the cross entropy starts near ln(vocab).
    tokens, labels = batch
    single = jnp.asarray(np.concatenate(stage_inits))
    loss = float(fns["eval_single"](single, tokens, labels)[0])
    assert abs(loss - np.log(CFG.vocab_size)) < 1.0


# ------------------------------------------------------- training signal


def test_adamw_steps_reduce_loss(fns, stage_inits, batch):
    tokens, labels = batch
    p = jnp.asarray(np.concatenate(stage_inits))
    n = p.shape[0]
    m = jnp.zeros(n)
    v = jnp.zeros(n)
    loss0 = None
    for t in range(1, 9):
        loss, g = fns["step_single"](p, tokens, labels)
        if loss0 is None:
            loss0 = float(loss)
        p, m, v = M.adamw_step(p, g, m, v, jnp.float32(t),
                               jnp.float32(3e-3), jnp.float32(0.0))
    loss_end, _ = fns["step_single"](p, tokens, labels)
    assert float(loss_end) < loss0 - 0.5


def test_pallas_model_matches_ref_model(batch):
    tokens, labels = batch
    fns_ref = M.make_stage_fns(CFG, use_pallas=False)
    fns_pl = M.make_stage_fns(CFG, use_pallas=True)
    p = jnp.asarray(M.init_stage_params(CFG, "single", 99))
    l_ref, g_ref = fns_ref["step_single"](p, tokens, labels)
    l_pl, g_pl = fns_pl["step_single"](p, tokens, labels)
    assert_allclose(float(l_pl), float(l_ref), rtol=1e-4)
    assert_allclose(np.asarray(g_pl), np.asarray(g_ref),
                    rtol=1e-3, atol=1e-4)
