//! TOML-subset parser substrate (no `toml` crate offline).
//!
//! Supported grammar — everything the launcher configs need:
//!   * `[table]` and `[dotted.table]` headers
//!   * `key = value` with string / integer / float / bool / array values
//!   * dotted keys (`train.steps = 4`), `#` comments, blank lines
//!
//! Values land in the same `Json` tree the rest of the codebase uses, so
//! config handling and manifest handling share accessors.

use crate::util::json::Json;
use std::collections::BTreeMap;

#[derive(Debug)]
pub struct TomlError {
    pub line: usize,
    pub msg: String,
}

impl std::fmt::Display for TomlError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "toml error on line {}: {}", self.line, self.msg)
    }
}

impl std::error::Error for TomlError {}

pub fn parse(text: &str) -> Result<Json, TomlError> {
    let mut root = BTreeMap::new();
    let mut prefix: Vec<String> = vec![];
    for (ln, raw) in text.lines().enumerate() {
        let line = strip_comment(raw).trim().to_string();
        if line.is_empty() {
            continue;
        }
        if let Some(inner) = line.strip_prefix('[') {
            let inner = inner
                .strip_suffix(']')
                .ok_or_else(|| err(ln, "unterminated table header"))?;
            if inner.is_empty() {
                return Err(err(ln, "empty table header"));
            }
            prefix = inner.split('.').map(|s| s.trim().to_string()).collect();
            ensure_table(&mut root, &prefix, ln)?;
        } else {
            let (k, v) = line
                .split_once('=')
                .ok_or_else(|| err(ln, "expected key = value"))?;
            let mut path = prefix.clone();
            path.extend(k.trim().split('.').map(|s| s.trim().to_string()));
            let val = parse_value(v.trim(), ln)?;
            insert(&mut root, &path, val, ln)?;
        }
    }
    Ok(Json::Obj(root))
}

pub fn parse_file(path: &str) -> anyhow::Result<Json> {
    let text = std::fs::read_to_string(path)
        .map_err(|e| anyhow::anyhow!("reading {path}: {e}"))?;
    parse(&text).map_err(|e| anyhow::anyhow!("{path}: {e}"))
}

fn err(ln: usize, msg: &str) -> TomlError {
    TomlError { line: ln + 1, msg: msg.to_string() }
}

fn strip_comment(line: &str) -> &str {
    // '#' starts a comment unless inside a string.
    let mut in_str = false;
    for (i, c) in line.char_indices() {
        match c {
            '"' => in_str = !in_str,
            '#' if !in_str => return &line[..i],
            _ => {}
        }
    }
    line
}

fn ensure_table(
    root: &mut BTreeMap<String, Json>,
    path: &[String],
    ln: usize,
) -> Result<(), TomlError> {
    let mut cur = root;
    for part in path {
        let entry = cur
            .entry(part.clone())
            .or_insert_with(|| Json::Obj(BTreeMap::new()));
        match entry {
            Json::Obj(m) => cur = m,
            _ => return Err(err(ln, "key redefined as table")),
        }
    }
    Ok(())
}

fn insert(
    root: &mut BTreeMap<String, Json>,
    path: &[String],
    val: Json,
    ln: usize,
) -> Result<(), TomlError> {
    let (last, dirs) = path.split_last().unwrap();
    let mut cur = root;
    for part in dirs {
        let entry = cur
            .entry(part.clone())
            .or_insert_with(|| Json::Obj(BTreeMap::new()));
        match entry {
            Json::Obj(m) => cur = m,
            _ => return Err(err(ln, "key redefined as table")),
        }
    }
    if cur.contains_key(last) {
        return Err(err(ln, &format!("duplicate key '{last}'")));
    }
    cur.insert(last.clone(), val);
    Ok(())
}

fn parse_value(s: &str, ln: usize) -> Result<Json, TomlError> {
    if let Some(body) = s.strip_prefix('"') {
        let body = body
            .strip_suffix('"')
            .ok_or_else(|| err(ln, "unterminated string"))?;
        return Ok(Json::Str(unescape(body)));
    }
    if s == "true" {
        return Ok(Json::Bool(true));
    }
    if s == "false" {
        return Ok(Json::Bool(false));
    }
    if let Some(body) = s.strip_prefix('[') {
        let body = body
            .strip_suffix(']')
            .ok_or_else(|| err(ln, "unterminated array"))?;
        let mut items = vec![];
        for item in split_top_level(body) {
            let item = item.trim();
            if !item.is_empty() {
                items.push(parse_value(item, ln)?);
            }
        }
        return Ok(Json::Arr(items));
    }
    let clean = s.replace('_', "");
    if let Ok(i) = clean.parse::<i64>() {
        return Ok(Json::Num(i as f64));
    }
    if let Ok(f) = clean.parse::<f64>() {
        return Ok(Json::Num(f));
    }
    Err(err(ln, &format!("cannot parse value '{s}'")))
}

fn split_top_level(s: &str) -> Vec<String> {
    let mut out = vec![];
    let mut depth = 0usize;
    let mut in_str = false;
    let mut cur = String::new();
    for c in s.chars() {
        match c {
            '"' => {
                in_str = !in_str;
                cur.push(c);
            }
            '[' if !in_str => {
                depth += 1;
                cur.push(c);
            }
            ']' if !in_str => {
                depth = depth.saturating_sub(1);
                cur.push(c);
            }
            ',' if !in_str && depth == 0 => {
                out.push(std::mem::take(&mut cur));
            }
            _ => cur.push(c),
        }
    }
    if !cur.trim().is_empty() {
        out.push(cur);
    }
    out
}

fn unescape(s: &str) -> String {
    let mut out = String::new();
    let mut it = s.chars();
    while let Some(c) = it.next() {
        if c == '\\' {
            match it.next() {
                Some('n') => out.push('\n'),
                Some('t') => out.push('\t'),
                Some('"') => out.push('"'),
                Some('\\') => out.push('\\'),
                Some(other) => {
                    out.push('\\');
                    out.push(other);
                }
                None => out.push('\\'),
            }
        } else {
            out.push(c);
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_typical_config() {
        let src = r#"
# experiment config
algo = "dilocox"

[model]
preset = "small"

[train]
outer_steps = 8
local_steps = 125
inner_lr = 3e-3
overlap = true

[compression]
q_bits = 4
rank = 64
schedule = [1.0, 0.5, 0.25]
"#;
        let v = parse(src).unwrap();
        assert_eq!(v.get("algo").unwrap().as_str(), Some("dilocox"));
        assert_eq!(v.path("model.preset").unwrap().as_str(), Some("small"));
        assert_eq!(v.path("train.local_steps").unwrap().as_usize(), Some(125));
        assert_eq!(v.path("train.inner_lr").unwrap().as_f64(), Some(3e-3));
        assert_eq!(v.path("train.overlap").unwrap().as_bool(), Some(true));
        let sched = v.path("compression.schedule").unwrap().as_arr().unwrap();
        assert_eq!(sched.len(), 3);
        assert_eq!(sched[1].as_f64(), Some(0.5));
    }

    #[test]
    fn dotted_keys_and_underscored_ints() {
        let v = parse("a.b.c = 1_000_000\n[x]\ny.z = \"w\"").unwrap();
        assert_eq!(v.path("a.b.c").unwrap().as_usize(), Some(1_000_000));
        assert_eq!(v.path("x.y.z").unwrap().as_str(), Some("w"));
    }

    #[test]
    fn comments_inside_strings_survive() {
        let v = parse("k = \"a # b\" # real comment").unwrap();
        assert_eq!(v.get("k").unwrap().as_str(), Some("a # b"));
    }

    #[test]
    fn rejects_errors() {
        assert!(parse("[unclosed").is_err());
        assert!(parse("novalue").is_err());
        assert!(parse("k = @@").is_err());
        assert!(parse("k = 1\nk = 2").is_err());
    }

    #[test]
    fn nested_arrays() {
        let v = parse("m = [[1, 2], [3, 4]]").unwrap();
        let rows = v.get("m").unwrap().as_arr().unwrap();
        assert_eq!(rows[1].at(0).unwrap().as_f64(), Some(3.0));
    }
}
