//! Typed experiment configuration: parsed from TOML launcher files or built
//! programmatically by the benches.  Field names follow the paper (H local
//! steps, T outer steps, D data parallelism, M pipeline stages, rank r,
//! q-bit quantization, gradient-rank window c).

pub mod toml;

use crate::transport::TransportBackend;
use crate::util::json::Json;
use anyhow::{anyhow, bail, Result};

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Algo {
    /// Vanilla synchronous data parallelism (paper baseline 1).
    AllReduce,
    /// DiLoCo with H local steps, fp16-equivalent wire format, no overlap,
    /// outer optimizer on worker 0 only (paper baseline 2).
    OpenDiLoCo,
    /// TopK + random sparsification + Int4 with local steps (baseline 3).
    CocktailSgd,
    /// The paper's system (Algorithm 2).
    DiLoCoX,
}

impl Algo {
    pub fn parse(s: &str) -> Result<Algo> {
        Ok(match s.to_ascii_lowercase().as_str() {
            "allreduce" | "all-reduce" => Algo::AllReduce,
            "opendiloco" | "diloco" => Algo::OpenDiLoCo,
            "cocktailsgd" | "cocktail" => Algo::CocktailSgd,
            "dilocox" => Algo::DiLoCoX,
            other => bail!("unknown algo '{other}'"),
        })
    }

    pub fn name(&self) -> &'static str {
        match self {
            Algo::AllReduce => "AllReduce",
            Algo::OpenDiLoCo => "OpenDiLoCo",
            Algo::CocktailSgd => "CocktailSGD",
            Algo::DiLoCoX => "DiLoCoX",
        }
    }
}

#[derive(Clone, Debug)]
pub struct ParallelConfig {
    /// D — data-parallel replicas (one per decentralized cluster here:
    /// the slow links are *between* replicas).
    pub dp: usize,
    /// M — pipeline stages inside each replica.  With `pp > 1` the
    /// coordinator runs the stage-parallel 1F1B executor; the degree must
    /// match the artifact manifest (see
    /// [`ExperimentConfig::validate_with_manifest`]).
    pub pp: usize,
    /// U — in-flight microbatches per inner step of the pipeline
    /// schedule (only meaningful with `pp > 1`; must be ≥ 1).
    pub microbatches: usize,
    /// Pipeline schedule: `gpipe`, `1f1b`, `interleaved` (virtual-stage
    /// 1F1B), or `zero-bubble` (ZB-H1 split backward).  Parsed by
    /// [`crate::pipeline::ScheduleKind::parse`].
    pub schedule: String,
    /// v — virtual stages (model chunks) per executor.  Must be 1 unless
    /// `schedule = "interleaved"`; must divide `pp`, and `microbatches`
    /// must be a multiple of the executor count `pp / v` when v > 1.
    pub virtual_stages: usize,
}

#[derive(Clone, Debug)]
pub struct TrainConfig {
    /// T — outer optimizer steps.
    pub outer_steps: usize,
    /// H₁ — initial local (inner) steps per outer step.
    pub local_steps: usize,
    pub inner_lr: f32,
    pub weight_decay: f32,
    /// Outer Nesterov step size / momentum (DiLoCo defaults).
    pub outer_lr: f32,
    pub outer_momentum: f32,
    /// One-step-delay overlap of communication and local training (§2.3).
    pub overlap: bool,
    pub seed: u64,
}

#[derive(Clone, Debug)]
pub struct CompressionConfig {
    pub enabled: bool,
    /// q — quantization bits (0 disables quantization).
    pub q_bits: u32,
    /// r₁ — initial low-rank (0 disables the low-rank factorization).
    pub rank: usize,
    /// Alg 3 adaptive rank/H controller.
    pub adaptive: bool,
    /// c — gradient-rank window.
    pub rank_window: usize,
    pub min_rank: usize,
    /// Error feedback buffer (Algorithm 2's e_t).
    pub error_feedback: bool,
    /// CocktailSGD knobs (used only by that baseline).
    pub random_ratio: f32,
    pub topk_ratio: f32,
}

impl CompressionConfig {
    pub fn none() -> Self {
        CompressionConfig {
            enabled: false,
            q_bits: 0,
            rank: 0,
            adaptive: false,
            rank_window: 5,
            min_rank: 1,
            error_feedback: false,
            random_ratio: 0.0,
            topk_ratio: 0.0,
        }
    }
}

#[derive(Clone, Debug)]
pub struct NetworkConfig {
    /// C — number of decentralized clusters (== dp in our mapping).
    pub clusters: usize,
    /// Inter-cluster bandwidth in Gbit/s (the paper's 1 Gbps bottleneck).
    pub inter_bw_gbps: f64,
    /// Intra-cluster bandwidth in Gbit/s (NVLink/IB class).
    pub intra_bw_gbps: f64,
    /// One-way latency per inter-cluster message, milliseconds.
    pub latency_ms: f64,
}

impl NetworkConfig {
    pub fn paper_1gbps(clusters: usize) -> Self {
        NetworkConfig {
            clusters,
            inter_bw_gbps: 1.0,
            intra_bw_gbps: 100.0,
            latency_ms: 30.0,
        }
    }
}

/// `[transport]` — which wire the coordinator runs the collective over
/// and its socket timeouts (see [`crate::transport`]).
#[derive(Clone, Debug)]
pub struct TransportConfig {
    pub backend: TransportBackend,
    /// Ring socket read/write timeout (failure-detection latency), ms.
    pub ring_timeout_ms: u64,
    /// Dial/accept deadline during ring formation, ms.
    pub connect_timeout_ms: u64,
    /// Deterministic listener layout for the stage-parallel TCP fleet
    /// (`pp > 1` with the tcp backend): process (cluster c, stage s)
    /// binds its per-stage ring listener at `base + 2·(c·pp + s)` and its
    /// stage-link listener one above (see
    /// [`crate::transport::tcp::stage_ports`]).  0 (the default) =
    /// ephemeral OS-assigned ports, advertised via `StageHello`.
    /// Validation: when set, the base must be ≥ 1024 and the whole
    /// `2·dp·pp` block must fit below 65536.
    pub stage_listen_base_port: u16,
    /// Persistent comm-thread pool size (see [`crate::comm::pool`]).
    /// 1 (the default) keeps the historical spawn-per-round comm threads;
    /// ≥ 2 parks overlapped-reduce flights and TCP writer loops on the
    /// shared pool instead.  Must be ≥ 1.
    pub comm_pool_size: usize,
    /// Reduce-pipeline depth (see
    /// [`crate::rounds::WireCompressor::set_pipeline_depth`]).  1 (the
    /// default) runs the sequential per-entry reduce; ≥ 2 projects and
    /// quantizes entry k+1 while entry k's ring passes are on the wire.
    /// Results stay bit-for-bit identical at any depth.  Must be ≥ 1.
    pub pipeline_depth: usize,
    /// Reduce topology: `"flat"` (historical arbitrary-order ring),
    /// `"reordered"` (probe links at startup, ship the max-bottleneck
    /// order — see [`crate::transport::probe`]), or `"hier"` (per-site
    /// rings plus a leaders-only cross-site ring — see
    /// [`crate::transport::hier`]).  Validated against
    /// [`crate::transport::ReduceTopology::parse`].
    pub reduce_topology: String,
    /// This worker's site tag for the hierarchical topology (`worker
    /// --site`); 0 = the default single site.
    pub site: u32,
    /// Link-probe payload size in f32 elements (reordered topology).
    pub probe_payload_elems: usize,
    /// Echo trials per probed link; the minimum RTT wins.  Must be ≥ 1.
    pub probe_repeats: usize,
}

impl Default for TransportConfig {
    fn default() -> Self {
        TransportConfig {
            backend: TransportBackend::Local,
            ring_timeout_ms: 5000,
            connect_timeout_ms: 5000,
            stage_listen_base_port: 0,
            comm_pool_size: 1,
            pipeline_depth: 1,
            reduce_topology: "flat".to_string(),
            site: 0,
            probe_payload_elems: 65_536,
            probe_repeats: 3,
        }
    }
}

/// `[faults]` — deterministic churn injection for the elastic path
/// (see [`crate::transport::faulty`]).  Disabled by default.
#[derive(Clone, Debug)]
pub struct FaultConfig {
    pub enabled: bool,
    /// Seed for the per-worker delay streams.
    pub seed: u64,
    /// Probability each sent ring message is delayed.
    pub delay_prob: f64,
    /// Max injected delay per message, ms.
    pub delay_ms: u64,
    /// Kill `kill_rank` at the start of this round (0 = never).
    pub kill_round: usize,
    pub kill_rank: usize,
    /// Stage-parallel fleets only: which stage process of `kill_rank`
    /// dies at `kill_round` (ignored when `pp = 1`; must be < pp).
    pub kill_stage: usize,
    /// Soft churn: `break_rank` reports a broken ring at the start of
    /// this round (0 = never) without dying, then rejoins at the next
    /// membership epoch.  In a stage fleet the break applies to EVERY
    /// stage process of the cluster at once, so the intra-cluster data
    /// streams stay aligned.  Deterministically exercises the *discard*
    /// branch of in-flight overlap recovery.
    pub break_round: usize,
    pub break_rank: usize,
    /// Fixed extra send latency for `straggler_rank` (0 ms = off).
    pub straggler_rank: usize,
    pub straggler_ms: u64,
}

impl Default for FaultConfig {
    fn default() -> Self {
        FaultConfig {
            enabled: false,
            seed: 7,
            delay_prob: 0.0,
            delay_ms: 0,
            kill_round: 0,
            kill_rank: 0,
            kill_stage: 0,
            break_round: 0,
            break_rank: 0,
            straggler_rank: 0,
            straggler_ms: 0,
        }
    }
}

/// `[trace]` — structured tracing of the elastic TCP fleet (see
/// [`crate::obs`]).  Off by default; `coordinate --trace out.json` turns
/// it on for one run without touching the config file.
#[derive(Clone, Debug, Default)]
pub struct TraceConfig {
    /// Record spans fleet-wide; workers ship batches to the coordinator
    /// over their control sockets.  Bit-for-bit inert on the numerics
    /// and the wire ledger.
    pub enabled: bool,
    /// When non-empty, each traced process also tees its drained batches
    /// to `<dir>/<role>.jsonl` (e.g. `c1.jsonl`, `c0.s1.jsonl`,
    /// `coord.jsonl`); "" = journal off.
    pub dir: String,
}

#[derive(Clone, Debug)]
pub struct ExperimentConfig {
    /// Artifact preset name (tiny | small | e2e100m) for real-numerics runs.
    pub preset: String,
    pub artifacts_dir: String,
    pub algo: Algo,
    pub parallel: ParallelConfig,
    pub train: TrainConfig,
    pub compression: CompressionConfig,
    pub network: NetworkConfig,
    pub transport: TransportConfig,
    pub faults: FaultConfig,
    pub trace: TraceConfig,
}

impl ExperimentConfig {
    /// Defaults mirror the paper's OPT-1.3B DiLoCoX row scaled to the
    /// `small` preset: H₁=125, Int4, overlap on, error feedback on.
    pub fn default_for(preset: &str, algo: Algo) -> Self {
        let dp = 2;
        let compression = match algo {
            Algo::AllReduce => CompressionConfig::none(),
            Algo::OpenDiLoCo => CompressionConfig {
                // fp16 wire format == "16-bit quantization" accounting.
                enabled: true,
                q_bits: 16,
                rank: 0,
                adaptive: false,
                rank_window: 5,
                min_rank: 1,
                error_feedback: false,
                random_ratio: 0.0,
                topk_ratio: 0.0,
            },
            Algo::CocktailSgd => CompressionConfig {
                enabled: true,
                q_bits: 4,
                rank: 0,
                adaptive: false,
                rank_window: 5,
                min_rank: 1,
                error_feedback: true,
                random_ratio: 0.1,
                topk_ratio: 0.08,
            },
            Algo::DiLoCoX => CompressionConfig {
                enabled: true,
                q_bits: 4,
                rank: 64,
                adaptive: true,
                rank_window: 5,
                min_rank: 4,
                error_feedback: true,
                random_ratio: 0.0,
                topk_ratio: 0.0,
            },
        };
        let local_steps = match algo {
            Algo::AllReduce => 1,
            Algo::OpenDiLoCo => 500,
            _ => 125,
        };
        ExperimentConfig {
            preset: preset.to_string(),
            artifacts_dir: format!("artifacts/{preset}"),
            algo,
            parallel: ParallelConfig {
                dp,
                pp: 1,
                microbatches: 1,
                schedule: "1f1b".into(),
                virtual_stages: 1,
            },
            train: TrainConfig {
                outer_steps: 8,
                local_steps,
                inner_lr: 3e-3,
                weight_decay: 0.01,
                outer_lr: 0.7,
                outer_momentum: 0.9,
                overlap: algo == Algo::DiLoCoX,
                seed: 1234,
            },
            compression,
            network: NetworkConfig::paper_1gbps(dp),
            transport: TransportConfig::default(),
            faults: FaultConfig::default(),
            trace: TraceConfig::default(),
        }
    }

    pub fn from_toml_file(path: &str) -> Result<Self> {
        let v = toml::parse_file(path)?;
        Self::from_json(&v)
    }

    pub fn from_json(v: &Json) -> Result<Self> {
        let preset = v
            .path("model.preset")
            .and_then(|j| j.as_str())
            .unwrap_or("small");
        let algo = Algo::parse(
            v.get("algo").and_then(|j| j.as_str()).unwrap_or("dilocox"),
        )?;
        let mut cfg = Self::default_for(preset, algo);

        if let Some(d) = v.path("model.artifacts_dir").and_then(|j| j.as_str()) {
            cfg.artifacts_dir = d.to_string();
        }
        macro_rules! set_usize {
            ($path:literal, $field:expr) => {
                if let Some(x) = v.path($path).and_then(|j| j.as_usize()) {
                    $field = x;
                }
            };
        }
        macro_rules! set_f32 {
            ($path:literal, $field:expr) => {
                if let Some(x) = v.path($path).and_then(|j| j.as_f64()) {
                    $field = x as f32;
                }
            };
        }
        macro_rules! set_bool {
            ($path:literal, $field:expr) => {
                if let Some(x) = v.path($path).and_then(|j| j.as_bool()) {
                    $field = x;
                }
            };
        }
        set_usize!("parallel.dp", cfg.parallel.dp);
        set_usize!("parallel.pp", cfg.parallel.pp);
        set_usize!("parallel.microbatches", cfg.parallel.microbatches);
        if let Some(s) = v.path("parallel.schedule").and_then(|j| j.as_str()) {
            cfg.parallel.schedule = s.to_string();
        }
        set_usize!("parallel.virtual_stages", cfg.parallel.virtual_stages);
        set_usize!("train.outer_steps", cfg.train.outer_steps);
        set_usize!("train.local_steps", cfg.train.local_steps);
        set_f32!("train.inner_lr", cfg.train.inner_lr);
        set_f32!("train.weight_decay", cfg.train.weight_decay);
        set_f32!("train.outer_lr", cfg.train.outer_lr);
        set_f32!("train.outer_momentum", cfg.train.outer_momentum);
        set_bool!("train.overlap", cfg.train.overlap);
        if let Some(x) = v.path("train.seed").and_then(|j| j.as_usize()) {
            cfg.train.seed = x as u64;
        }
        set_bool!("compression.enabled", cfg.compression.enabled);
        if let Some(x) = v.path("compression.q_bits").and_then(|j| j.as_usize())
        {
            cfg.compression.q_bits = x as u32;
        }
        set_usize!("compression.rank", cfg.compression.rank);
        set_bool!("compression.adaptive", cfg.compression.adaptive);
        set_usize!("compression.rank_window", cfg.compression.rank_window);
        set_usize!("compression.min_rank", cfg.compression.min_rank);
        set_bool!("compression.error_feedback", cfg.compression.error_feedback);
        set_f32!("compression.random_ratio", cfg.compression.random_ratio);
        set_f32!("compression.topk_ratio", cfg.compression.topk_ratio);
        set_usize!("network.clusters", cfg.network.clusters);
        if let Some(x) = v.path("network.inter_bw_gbps").and_then(|j| j.as_f64())
        {
            cfg.network.inter_bw_gbps = x;
        }
        if let Some(x) = v.path("network.intra_bw_gbps").and_then(|j| j.as_f64())
        {
            cfg.network.intra_bw_gbps = x;
        }
        if let Some(x) = v.path("network.latency_ms").and_then(|j| j.as_f64()) {
            cfg.network.latency_ms = x;
        }
        if let Some(s) = v.path("transport.backend").and_then(|j| j.as_str()) {
            cfg.transport.backend = TransportBackend::parse(s)?;
        }
        if let Some(x) =
            v.path("transport.ring_timeout_ms").and_then(|j| j.as_usize())
        {
            cfg.transport.ring_timeout_ms = x as u64;
        }
        if let Some(x) =
            v.path("transport.connect_timeout_ms").and_then(|j| j.as_usize())
        {
            cfg.transport.connect_timeout_ms = x as u64;
        }
        if let Some(x) = v
            .path("transport.stage_listen_base_port")
            .and_then(|j| j.as_usize())
        {
            if x > u16::MAX as usize {
                return Err(anyhow!(
                    "transport.stage_listen_base_port {x} exceeds 65535"
                ));
            }
            cfg.transport.stage_listen_base_port = x as u16;
        }
        set_usize!("transport.comm_pool_size", cfg.transport.comm_pool_size);
        set_usize!("transport.pipeline_depth", cfg.transport.pipeline_depth);
        if let Some(s) = v.path("transport.reduce_topology").and_then(|j| j.as_str())
        {
            cfg.transport.reduce_topology = s.to_string();
        }
        if let Some(x) = v.path("transport.site").and_then(|j| j.as_usize()) {
            cfg.transport.site = x as u32;
        }
        set_usize!(
            "transport.probe_payload_elems",
            cfg.transport.probe_payload_elems
        );
        set_usize!("transport.probe_repeats", cfg.transport.probe_repeats);
        set_bool!("faults.enabled", cfg.faults.enabled);
        if let Some(x) = v.path("faults.seed").and_then(|j| j.as_usize()) {
            cfg.faults.seed = x as u64;
        }
        if let Some(x) = v.path("faults.delay_prob").and_then(|j| j.as_f64()) {
            cfg.faults.delay_prob = x;
        }
        if let Some(x) = v.path("faults.delay_ms").and_then(|j| j.as_usize()) {
            cfg.faults.delay_ms = x as u64;
        }
        set_usize!("faults.kill_round", cfg.faults.kill_round);
        set_usize!("faults.kill_rank", cfg.faults.kill_rank);
        set_usize!("faults.kill_stage", cfg.faults.kill_stage);
        set_usize!("faults.break_round", cfg.faults.break_round);
        set_usize!("faults.break_rank", cfg.faults.break_rank);
        set_usize!("faults.straggler_rank", cfg.faults.straggler_rank);
        if let Some(x) = v.path("faults.straggler_ms").and_then(|j| j.as_usize())
        {
            cfg.faults.straggler_ms = x as u64;
        }
        set_bool!("trace.enabled", cfg.trace.enabled);
        if let Some(s) = v.path("trace.dir").and_then(|j| j.as_str()) {
            cfg.trace.dir = s.to_string();
        }
        cfg.validate()?;
        Ok(cfg)
    }

    pub fn validate(&self) -> Result<()> {
        if self.parallel.dp == 0 || self.parallel.pp == 0 {
            return Err(anyhow!("parallel degrees must be >= 1"));
        }
        if self.parallel.microbatches == 0 {
            return Err(anyhow!(
                "parallel.microbatches must be >= 1 (the pipeline schedule \
                 needs at least one in-flight microbatch)"
            ));
        }
        let kind = crate::pipeline::ScheduleKind::parse(&self.parallel.schedule)
            .map_err(|e| anyhow!("parallel.schedule: {e}"))?;
        let v = self.parallel.virtual_stages;
        if v == 0 {
            return Err(anyhow!("parallel.virtual_stages must be >= 1"));
        }
        if v > 1 {
            if kind != crate::pipeline::ScheduleKind::Interleaved {
                return Err(anyhow!(
                    "parallel.virtual_stages = {v} needs parallel.schedule = \
                     \"interleaved\" (got \"{}\")",
                    self.parallel.schedule
                ));
            }
            if self.parallel.pp % v != 0 {
                return Err(anyhow!(
                    "parallel.virtual_stages = {v} must divide parallel.pp = {}",
                    self.parallel.pp
                ));
            }
            let execs = self.parallel.pp / v;
            if self.parallel.microbatches % execs != 0 {
                return Err(anyhow!(
                    "interleaved schedule needs parallel.microbatches ({}) \
                     to be a multiple of the executor count pp/v = {execs}",
                    self.parallel.microbatches
                ));
            }
        }
        if self.train.outer_steps == 0 || self.train.local_steps == 0 {
            return Err(anyhow!("outer_steps and local_steps must be >= 1"));
        }
        if self.compression.q_bits > 32 {
            return Err(anyhow!("q_bits must be <= 32"));
        }
        if self.compression.adaptive && self.compression.rank_window == 0 {
            return Err(anyhow!("rank_window (c) must be >= 1 when adaptive"));
        }
        if self.algo == Algo::CocktailSgd
            && self.compression.enabled
            && (self.compression.random_ratio <= 0.0
                || self.compression.topk_ratio <= 0.0)
        {
            return Err(anyhow!("cocktail needs random_ratio and topk_ratio"));
        }
        if self.transport.ring_timeout_ms == 0 || self.transport.connect_timeout_ms == 0
        {
            return Err(anyhow!("transport timeouts must be >= 1 ms"));
        }
        if self.transport.comm_pool_size == 0 {
            return Err(anyhow!(
                "transport.comm_pool_size must be >= 1 (1 = pool off)"
            ));
        }
        if self.transport.pipeline_depth == 0 {
            return Err(anyhow!(
                "transport.pipeline_depth must be >= 1 (1 = sequential reduce)"
            ));
        }
        crate::transport::ReduceTopology::parse(&self.transport.reduce_topology)
            .map_err(|e| anyhow!("transport.reduce_topology: {e}"))?;
        if self.transport.probe_payload_elems == 0 {
            return Err(anyhow!("transport.probe_payload_elems must be >= 1"));
        }
        if self.transport.probe_repeats == 0 {
            return Err(anyhow!("transport.probe_repeats must be >= 1"));
        }
        if !(0.0..=1.0).contains(&self.faults.delay_prob) {
            return Err(anyhow!("faults.delay_prob must be in [0, 1]"));
        }
        // Stage/ring address layout: when a deterministic listener base is
        // set, the whole 2·dp·pp port block must be bindable.
        let base = self.transport.stage_listen_base_port;
        if base > 0 {
            if base < 1024 {
                return Err(anyhow!(
                    "transport.stage_listen_base_port {base} is in the \
                     privileged range; use a base >= 1024 (or 0 for \
                     ephemeral ports)"
                ));
            }
            let block = 2 * (self.parallel.dp as u64) * (self.parallel.pp as u64);
            if base as u64 + block > 65536 {
                return Err(anyhow!(
                    "transport.stage_listen_base_port {base} + 2*dp*pp = \
                     {} ports overflows the port space; lower the base or \
                     the fleet size",
                    base as u64 + block
                ));
            }
        }
        if self.faults.enabled
            && self.faults.kill_round > 0
            && self.faults.kill_rank >= self.parallel.dp
        {
            return Err(anyhow!(
                "faults.kill_rank {} out of range for dp={}",
                self.faults.kill_rank,
                self.parallel.dp
            ));
        }
        if self.faults.enabled
            && self.faults.kill_round > 0
            && self.faults.kill_stage >= self.parallel.pp
        {
            return Err(anyhow!(
                "faults.kill_stage {} out of range for pp={}",
                self.faults.kill_stage,
                self.parallel.pp
            ));
        }
        if self.faults.enabled
            && self.faults.break_round > 0
            && self.faults.break_rank >= self.parallel.dp
        {
            return Err(anyhow!(
                "faults.break_rank {} out of range for dp={}",
                self.faults.break_rank,
                self.parallel.dp
            ));
        }
        Ok(())
    }

    /// Validate pipeline settings against an artifact manifest — called
    /// by every entry point that loads a bundle, so misconfigured PP
    /// degrees fail at load time with actionable errors instead of deep
    /// in stage execution.
    pub fn validate_with_manifest(
        &self,
        man: &crate::runtime::Manifest,
    ) -> Result<()> {
        self.validate()?;
        if self.parallel.pp > 1 {
            if self.parallel.pp != man.dims.pp_stages {
                return Err(anyhow!(
                    "parallel.pp = {} but artifact bundle '{}' exports \
                     pp_stages = {}; set parallel.pp = {} or re-export the \
                     artifacts with the desired stage count",
                    self.parallel.pp,
                    man.preset,
                    man.dims.pp_stages,
                    man.dims.pp_stages
                ));
            }
            crate::pipeline::layers_per_stage(man.dims.n_layers, self.parallel.pp)
                .map_err(|e| {
                    anyhow!(
                        "invalid stage partition for bundle '{}': {e}; \
                         parallel.pp must divide n_layers",
                        man.preset
                    )
                })?;
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn defaults_match_paper_rows() {
        let d = ExperimentConfig::default_for("small", Algo::DiLoCoX);
        assert_eq!(d.train.local_steps, 125);
        assert_eq!(d.compression.q_bits, 4);
        assert!(d.train.overlap);
        assert!(d.compression.error_feedback);

        let o = ExperimentConfig::default_for("small", Algo::OpenDiLoCo);
        assert_eq!(o.train.local_steps, 500);
        assert!(!o.train.overlap);
        assert_eq!(o.compression.q_bits, 16);

        let a = ExperimentConfig::default_for("small", Algo::AllReduce);
        assert_eq!(a.train.local_steps, 1);
        assert!(!a.compression.enabled);
    }

    #[test]
    fn toml_roundtrip_overrides() {
        let src = r#"
algo = "cocktail"
[model]
preset = "tiny"
[parallel]
dp = 4
[train]
outer_steps = 3
local_steps = 10
overlap = false
[compression]
random_ratio = 0.2
topk_ratio = 0.05
[network]
inter_bw_gbps = 0.5
"#;
        let v = toml::parse(src).unwrap();
        let cfg = ExperimentConfig::from_json(&v).unwrap();
        assert_eq!(cfg.algo, Algo::CocktailSgd);
        assert_eq!(cfg.preset, "tiny");
        assert_eq!(cfg.parallel.dp, 4);
        assert_eq!(cfg.train.outer_steps, 3);
        assert_eq!(cfg.train.local_steps, 10);
        assert_eq!(cfg.compression.random_ratio, 0.2);
        assert_eq!(cfg.network.inter_bw_gbps, 0.5);
    }

    #[test]
    fn validation_rejects_bad_configs() {
        let mut cfg = ExperimentConfig::default_for("tiny", Algo::DiLoCoX);
        cfg.parallel.dp = 0;
        assert!(cfg.validate().is_err());

        let mut cfg = ExperimentConfig::default_for("tiny", Algo::CocktailSgd);
        cfg.compression.topk_ratio = 0.0;
        assert!(cfg.validate().is_err());
    }

    #[test]
    fn transport_and_faults_sections_parse() {
        let src = r#"
algo = "dilocox"
[model]
preset = "tiny"
[parallel]
dp = 3
[transport]
backend = "tcp"
ring_timeout_ms = 750
connect_timeout_ms = 1500
comm_pool_size = 4
pipeline_depth = 3
reduce_topology = "hier"
site = 2
probe_payload_elems = 4096
probe_repeats = 5
[faults]
enabled = true
seed = 42
delay_prob = 0.25
delay_ms = 20
kill_round = 2
kill_rank = 1
straggler_rank = 2
straggler_ms = 5
"#;
        let v = toml::parse(src).unwrap();
        let cfg = ExperimentConfig::from_json(&v).unwrap();
        assert_eq!(cfg.transport.backend, TransportBackend::Tcp);
        assert_eq!(cfg.transport.ring_timeout_ms, 750);
        assert_eq!(cfg.transport.connect_timeout_ms, 1500);
        assert_eq!(cfg.transport.comm_pool_size, 4);
        assert_eq!(cfg.transport.pipeline_depth, 3);
        assert_eq!(cfg.transport.reduce_topology, "hier");
        assert_eq!(cfg.transport.site, 2);
        assert_eq!(cfg.transport.probe_payload_elems, 4096);
        assert_eq!(cfg.transport.probe_repeats, 5);
        assert!(cfg.faults.enabled);
        assert_eq!(cfg.faults.seed, 42);
        assert!((cfg.faults.delay_prob - 0.25).abs() < 1e-12);
        assert_eq!(cfg.faults.delay_ms, 20);
        assert_eq!(cfg.faults.kill_round, 2);
        assert_eq!(cfg.faults.kill_rank, 1);
        assert_eq!(cfg.faults.straggler_rank, 2);
        assert_eq!(cfg.faults.straggler_ms, 5);

        // Defaults when the sections are absent: pool and pipeline off
        // (historical behavior preserved).
        let d = ExperimentConfig::default_for("tiny", Algo::DiLoCoX);
        assert_eq!(d.transport.backend, TransportBackend::Local);
        assert_eq!(d.transport.comm_pool_size, 1);
        assert_eq!(d.transport.pipeline_depth, 1);
        assert!(!d.faults.enabled);
    }

    #[test]
    fn trace_section_parses() {
        let src = r#"
algo = "dilocox"
[model]
preset = "tiny"
[trace]
enabled = true
dir = "traces/run1"
"#;
        let v = toml::parse(src).unwrap();
        let cfg = ExperimentConfig::from_json(&v).unwrap();
        assert!(cfg.trace.enabled);
        assert_eq!(cfg.trace.dir, "traces/run1");

        // Off by default when the section is absent.
        let d = ExperimentConfig::default_for("tiny", Algo::DiLoCoX);
        assert!(!d.trace.enabled);
        assert!(d.trace.dir.is_empty());
    }

    #[test]
    fn fault_validation_rejects_bad_values() {
        let mut cfg = ExperimentConfig::default_for("tiny", Algo::DiLoCoX);
        cfg.faults.delay_prob = 1.5;
        assert!(cfg.validate().is_err());

        let mut cfg = ExperimentConfig::default_for("tiny", Algo::DiLoCoX);
        cfg.faults.enabled = true;
        cfg.faults.kill_round = 1;
        cfg.faults.kill_rank = 99; // dp defaults to 2
        assert!(cfg.validate().is_err());

        let mut cfg = ExperimentConfig::default_for("tiny", Algo::DiLoCoX);
        cfg.transport.ring_timeout_ms = 0;
        assert!(cfg.validate().is_err());

        let mut cfg = ExperimentConfig::default_for("tiny", Algo::DiLoCoX);
        cfg.transport.comm_pool_size = 0;
        assert!(cfg.validate().is_err());

        let mut cfg = ExperimentConfig::default_for("tiny", Algo::DiLoCoX);
        cfg.transport.pipeline_depth = 0;
        assert!(cfg.validate().is_err());

        let mut cfg = ExperimentConfig::default_for("tiny", Algo::DiLoCoX);
        cfg.transport.reduce_topology = "mesh".to_string();
        assert!(cfg.validate().is_err());

        let mut cfg = ExperimentConfig::default_for("tiny", Algo::DiLoCoX);
        cfg.transport.reduce_topology = "hierarchical".to_string();
        assert!(cfg.validate().is_ok(), "aliases must validate");

        let mut cfg = ExperimentConfig::default_for("tiny", Algo::DiLoCoX);
        cfg.transport.probe_repeats = 0;
        assert!(cfg.validate().is_err());
    }

    #[test]
    fn microbatches_parse_and_validate() {
        let src = r#"
algo = "dilocox"
[model]
preset = "tiny"
[parallel]
dp = 2
pp = 4
microbatches = 3
"#;
        let v = toml::parse(src).unwrap();
        let cfg = ExperimentConfig::from_json(&v).unwrap();
        assert_eq!(cfg.parallel.pp, 4);
        assert_eq!(cfg.parallel.microbatches, 3);

        let mut bad = ExperimentConfig::default_for("tiny", Algo::DiLoCoX);
        bad.parallel.microbatches = 0;
        assert!(bad.validate().is_err());

        // PP over the TCP worker fleet is a supported composition now —
        // one OS process per (cluster, stage).
        let mut tcp_pp = ExperimentConfig::default_for("tiny", Algo::DiLoCoX);
        tcp_pp.parallel.pp = 2;
        tcp_pp.transport.backend = TransportBackend::Tcp;
        tcp_pp.validate().unwrap();
    }

    #[test]
    fn stage_listen_base_port_layout_validation() {
        let mut cfg = ExperimentConfig::default_for("tiny", Algo::DiLoCoX);
        cfg.parallel.dp = 2;
        cfg.parallel.pp = 2;
        cfg.transport.stage_listen_base_port = 42000;
        cfg.validate().unwrap();

        // Privileged range rejected.
        cfg.transport.stage_listen_base_port = 80;
        assert!(cfg.validate().is_err());

        // Port block overflowing 65535 rejected.
        cfg.transport.stage_listen_base_port = 65530;
        assert!(cfg.validate().is_err());

        // 0 = ephemeral, always fine.
        cfg.transport.stage_listen_base_port = 0;
        cfg.validate().unwrap();
    }

    #[test]
    fn kill_stage_parses_and_validates() {
        let src = r#"
algo = "dilocox"
[model]
preset = "tiny"
[parallel]
dp = 2
pp = 2
[transport]
stage_listen_base_port = 43000
[faults]
enabled = true
kill_round = 2
kill_rank = 1
kill_stage = 1
"#;
        let v = toml::parse(src).unwrap();
        let cfg = ExperimentConfig::from_json(&v).unwrap();
        assert_eq!(cfg.faults.kill_stage, 1);
        assert_eq!(cfg.transport.stage_listen_base_port, 43000);

        let mut bad = cfg.clone();
        bad.faults.kill_stage = 5; // pp = 2
        assert!(bad.validate().is_err());
    }

    #[test]
    fn manifest_validation_catches_pp_mismatch_at_load_time() {
        use crate::runtime::Manifest;
        use crate::util::json::Json;
        use std::path::PathBuf;

        let text = r#"{
  "format": "hlo-text-v1",
  "preset": "synthetic",
  "param_count": 8,
  "config": {"vocab_size": 64, "d_model": 8, "n_heads": 2, "n_layers": 4,
             "seq_len": 16, "microbatch": 2, "pp_stages": 4,
             "layers_per_stage": 1, "d_ff": 16},
  "programs": {},
  "param_specs": {},
  "stage_numel": {},
  "init": {}
}"#;
        let v = Json::parse(text).unwrap();
        let man = Manifest::from_json(PathBuf::from("."), &v).unwrap();

        let mut cfg = ExperimentConfig::default_for("synthetic", Algo::DiLoCoX);
        cfg.parallel.pp = 4;
        cfg.validate_with_manifest(&man).unwrap();

        // pp = 1 never touches the stage programs — always fine.
        cfg.parallel.pp = 1;
        cfg.validate_with_manifest(&man).unwrap();

        // Mismatched degree fails with an actionable message.
        cfg.parallel.pp = 3;
        let err = cfg.validate_with_manifest(&man).unwrap_err().to_string();
        assert!(err.contains("pp_stages = 4"), "{err}");
    }

    #[test]
    fn break_round_parses_and_validates() {
        let src = r#"
algo = "dilocox"
[model]
preset = "tiny"
[parallel]
dp = 3
[faults]
enabled = true
break_round = 3
break_rank = 1
"#;
        let v = toml::parse(src).unwrap();
        let cfg = ExperimentConfig::from_json(&v).unwrap();
        assert_eq!(cfg.faults.break_round, 3);
        assert_eq!(cfg.faults.break_rank, 1);

        let mut bad = cfg.clone();
        bad.faults.break_rank = 7; // dp = 3
        assert!(bad.validate().is_err());
    }

    #[test]
    fn algo_parse_names() {
        assert_eq!(Algo::parse("DiLoCoX").unwrap(), Algo::DiLoCoX);
        assert_eq!(Algo::parse("diloco").unwrap(), Algo::OpenDiLoCo);
        assert!(Algo::parse("sgd").is_err());
        assert_eq!(Algo::DiLoCoX.name(), "DiLoCoX");
    }
}
