//! Structured tracing for the fleet's hot path (the observability layer).
//!
//! Cheap, always-compiled span/counter primitives: [`span`] records a
//! monotonic start/stop pair into a per-thread buffer, flushed to a
//! process-wide sink, optionally teed to a per-process JSONL journal,
//! and drained in batches — elastic workers ship drained batches to the
//! coordinator over their control sockets
//! ([`crate::transport::frame::Msg::TraceEvents`]), which merges them
//! into one fleet-wide timeline keyed by (cluster, stage, round, epoch).
//! [`report`] turns a merged timeline into the per-round accounting
//! table, the Chrome-trace/Perfetto export, and the schema validation
//! behind `dilocox trace-check`.
//!
//! Invariants the instrumentation relies on:
//!
//! * **Zero overhead when disabled** — every primitive starts with one
//!   relaxed atomic load and returns immediately when tracing is off;
//!   nothing allocates, locks, or reads the clock.  A span created while
//!   disabled stays dead even if tracing is enabled before it drops.
//! * **Bit-for-bit determinism** — tracing only *observes* wall time; it
//!   never touches RNG state, the numerics, or the ring's payload byte
//!   meter (trace batches ride the control sockets, not the data plane),
//!   so a traced run is bit-identical to an untraced one — the
//!   `integration_trace` suite asserts params, losses, and the wire
//!   ledger.
//! * **Self-carried attribution** — every event snapshots the recording
//!   thread's (cluster, stage, epoch, round) context at record time, so
//!   attribution survives no matter which thread later drains or ships
//!   the batch (thread-mode fleets share one process-wide sink).
//!
//! Timestamps are unix-anchored monotonic microseconds: the first clock
//! read anchors `Instant::now()` to wall time once per process, so the
//! loopback processes of one fleet land on a roughly aligned shared
//! timeline while spans within any one thread stay strictly monotonic
//! (which is what makes the well-nestedness validation sound).

pub mod report;

use crate::util::json::{obj, Json};
use std::cell::{Cell, RefCell};
use std::path::PathBuf;
use std::sync::atomic::{AtomicBool, AtomicU32, Ordering};
use std::sync::{Mutex, OnceLock};
use std::time::Instant;

/// Cluster id used for coordinator-side spans (no worker owns it).
pub const COORD: u32 = u32::MAX;

/// Per-thread buffer capacity before an automatic flush to the sink.
const FLUSH_AT: usize = 512;

/// One recorded span or instant (`dur_us == 0`).  Events self-carry
/// their full attribution so any thread may ship them.
#[derive(Clone, Debug, PartialEq)]
pub struct TraceEvent {
    pub cluster: u32,
    pub stage: u32,
    pub epoch: u32,
    pub round: u32,
    /// Recording thread (process-locally unique, dense from 1).
    pub tid: u32,
    /// Unix-anchored monotonic microseconds at span start.
    pub start_us: u64,
    pub dur_us: u64,
    /// Payload bytes attributed to the span (0 when not a wire span).
    pub bytes: u64,
    /// Subsystem, e.g. "driver", "wire", "pipeline".
    pub target: String,
    /// Phase within the subsystem, e.g. "compute", "allreduce".
    pub phase: String,
}

impl TraceEvent {
    /// JSON object form (the JSONL journal line).
    pub fn to_json(&self) -> Json {
        obj(vec![
            ("cluster", Json::Num(self.cluster as f64)),
            ("stage", Json::Num(self.stage as f64)),
            ("epoch", Json::Num(self.epoch as f64)),
            ("round", Json::Num(self.round as f64)),
            ("tid", Json::Num(self.tid as f64)),
            ("start_us", Json::Num(self.start_us as f64)),
            ("dur_us", Json::Num(self.dur_us as f64)),
            ("bytes", Json::Num(self.bytes as f64)),
            ("target", Json::Str(self.target.clone())),
            ("phase", Json::Str(self.phase.clone())),
        ])
    }
}

/// The thread-local attribution context events snapshot at record time.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct Ctx {
    pub cluster: u32,
    pub stage: u32,
    pub epoch: u32,
    pub round: u32,
}

static ENABLED: AtomicBool = AtomicBool::new(false);
static SINK: Mutex<Vec<TraceEvent>> = Mutex::new(Vec::new());
static JOURNAL: Mutex<Option<PathBuf>> = Mutex::new(None);
static NEXT_TID: AtomicU32 = AtomicU32::new(1);

/// Per-thread event buffer; the `Drop` impl flushes whatever a dying
/// thread still holds (overlap comm threads end mid-epoch).
struct LocalBuf {
    events: RefCell<Vec<TraceEvent>>,
}

impl Drop for LocalBuf {
    fn drop(&mut self) {
        let ev = std::mem::take(self.events.get_mut());
        if !ev.is_empty() {
            if let Ok(mut g) = SINK.lock() {
                g.extend(ev);
            }
        }
    }
}

thread_local! {
    static CTX: Cell<Ctx> = const {
        Cell::new(Ctx { cluster: 0, stage: 0, epoch: 0, round: 0 })
    };
    static TID: Cell<u32> = const { Cell::new(0) };
    static BUF: LocalBuf = const {
        LocalBuf { events: RefCell::new(Vec::new()) }
    };
}

fn anchor() -> &'static (Instant, u64) {
    static ANCHOR: OnceLock<(Instant, u64)> = OnceLock::new();
    ANCHOR.get_or_init(|| {
        let unix = std::time::SystemTime::now()
            .duration_since(std::time::UNIX_EPOCH)
            .unwrap_or_default();
        (Instant::now(), unix.as_micros() as u64)
    })
}

/// Unix-anchored monotonic microseconds (see the module docs).
pub fn now_us() -> u64 {
    let a = anchor();
    a.1 + a.0.elapsed().as_micros() as u64
}

/// Turn tracing on or off process-wide.
pub fn set_enabled(on: bool) {
    ENABLED.store(on, Ordering::Relaxed);
}

/// The one cost every primitive pays when tracing is off.
pub fn enabled() -> bool {
    ENABLED.load(Ordering::Relaxed)
}

/// Set this thread's (cluster, stage) attribution; call once per worker
/// thread/process at startup.  Epoch and round are preserved.
pub fn set_scope(cluster: u32, stage: u32) {
    CTX.with(|c| {
        let mut ctx = c.get();
        ctx.cluster = cluster;
        ctx.stage = stage;
        c.set(ctx);
    });
}

/// Update this thread's membership-epoch attribution.
pub fn set_epoch(epoch: u32) {
    CTX.with(|c| {
        let mut ctx = c.get();
        ctx.epoch = epoch;
        c.set(ctx);
    });
}

/// Update this thread's outer-round attribution.
pub fn set_round(round: u32) {
    CTX.with(|c| {
        let mut ctx = c.get();
        ctx.round = round;
        c.set(ctx);
    });
}

/// This thread's full context — capture before spawning a helper thread
/// (e.g. the overlap comm thread) and [`set_ctx`] it inside.
pub fn scope() -> Ctx {
    CTX.with(|c| c.get())
}

/// Replace this thread's full context (comm-thread inheritance).
pub fn set_ctx(ctx: Ctx) {
    CTX.with(|c| c.set(ctx));
}

/// Tee every drained batch to a JSONL journal at `path` (append mode);
/// `None` turns the journal off.  Journal IO failures are swallowed —
/// observability must never take the training run down.
pub fn set_journal(path: Option<PathBuf>) {
    if let Ok(mut g) = JOURNAL.lock() {
        *g = path;
    }
}

fn tid() -> u32 {
    TID.with(|t| {
        let v = t.get();
        if v != 0 {
            return v;
        }
        let v = NEXT_TID.fetch_add(1, Ordering::Relaxed);
        t.set(v);
        v
    })
}

fn push(ev: TraceEvent) {
    let full = BUF.with(|b| {
        let mut v = b.events.borrow_mut();
        v.push(ev);
        v.len() >= FLUSH_AT
    });
    if full {
        flush_local();
    }
}

fn flush_local() {
    let ev = BUF.with(|b| std::mem::take(&mut *b.events.borrow_mut()));
    if !ev.is_empty() {
        if let Ok(mut g) = SINK.lock() {
            g.extend(ev);
        }
    }
}

fn tee_journal(events: &[TraceEvent]) {
    let path = match JOURNAL.lock() {
        Ok(g) => g.clone(),
        Err(_) => None,
    };
    let Some(path) = path else { return };
    use std::io::Write as _;
    if let Ok(mut f) =
        std::fs::OpenOptions::new().create(true).append(true).open(&path)
    {
        let mut out = String::new();
        for e in events {
            out.push_str(&e.to_json().to_string_compact());
            out.push('\n');
        }
        let _ = f.write_all(out.as_bytes());
    }
}

/// Take everything recorded so far (this thread's buffer + the shared
/// sink), tee it to the journal, and return it for shipping.  Draining
/// removes: an event is shipped exactly once.
pub fn drain() -> Vec<TraceEvent> {
    flush_local();
    let ev = match SINK.lock() {
        Ok(mut g) => std::mem::take(&mut *g),
        Err(_) => Vec::new(),
    };
    if !ev.is_empty() {
        tee_journal(&ev);
    }
    ev
}

/// An in-progress span; records one [`TraceEvent`] when dropped (RAII,
/// so spans within a thread are always strictly nested).
#[must_use = "a span records on drop — bind it for the region's lifetime"]
pub struct Span {
    target: &'static str,
    phase: &'static str,
    ctx: Ctx,
    start_us: u64,
    bytes: u64,
    live: bool,
}

impl Span {
    /// Attribute wire payload bytes to the span (builder form).
    pub fn bytes(mut self, bytes: u64) -> Span {
        self.bytes = bytes;
        self
    }
}

impl Drop for Span {
    fn drop(&mut self) {
        if !self.live {
            return;
        }
        // End from the same truncated clock as the start: truncation is
        // then monotone, so a child's integer end never exceeds its
        // enclosing span's (the well-nestedness check is exact).
        push(TraceEvent {
            cluster: self.ctx.cluster,
            stage: self.ctx.stage,
            epoch: self.ctx.epoch,
            round: self.ctx.round,
            tid: tid(),
            start_us: self.start_us,
            dur_us: now_us().saturating_sub(self.start_us),
            bytes: self.bytes,
            target: self.target.to_string(),
            phase: self.phase.to_string(),
        });
    }
}

fn dead(target: &'static str, phase: &'static str) -> Span {
    Span {
        target,
        phase,
        ctx: Ctx::default(),
        start_us: 0,
        bytes: 0,
        live: false,
    }
}

/// Open a span under this thread's current context.
pub fn span(target: &'static str, phase: &'static str) -> Span {
    if !enabled() {
        return dead(target, phase);
    }
    span_live(target, phase, scope())
}

/// Open a span attributed to an explicit `round` (recovery spans name
/// the round being drained, not the thread's current one).
pub fn span_at(target: &'static str, phase: &'static str, round: u32) -> Span {
    if !enabled() {
        return dead(target, phase);
    }
    let mut ctx = scope();
    ctx.round = round;
    span_live(target, phase, ctx)
}

fn span_live(target: &'static str, phase: &'static str, ctx: Ctx) -> Span {
    Span {
        target,
        phase,
        ctx,
        start_us: now_us(),
        bytes: 0,
        live: true,
    }
}

/// Record a completed event whose start was captured earlier with
/// [`now_us`] — for waits that straddle a thread boundary (e.g. the comm
/// pool's queue wait: enqueue happens on the submitter, pickup on the
/// worker), where no RAII [`Span`] can live on one thread.  The event is
/// recorded on the *calling* thread's track under its current context.
/// Detail-only phases (anything outside the accounting set) are safe
/// here; their durations never enter the round accounting sums.
pub fn event_since(
    target: &'static str,
    phase: &'static str,
    start_us: u64,
    bytes: u64,
) {
    if !enabled() {
        return;
    }
    let ctx = scope();
    push(TraceEvent {
        cluster: ctx.cluster,
        stage: ctx.stage,
        epoch: ctx.epoch,
        round: ctx.round,
        tid: tid(),
        start_us,
        dur_us: now_us().saturating_sub(start_us),
        bytes,
        target: target.to_string(),
        phase: phase.to_string(),
    });
}

/// Record an instant event (zero duration) under the current context.
pub fn event(target: &'static str, phase: &'static str, bytes: u64) {
    if !enabled() {
        return;
    }
    let ctx = scope();
    push(TraceEvent {
        cluster: ctx.cluster,
        stage: ctx.stage,
        epoch: ctx.epoch,
        round: ctx.round,
        tid: tid(),
        start_us: now_us(),
        dur_us: 0,
        bytes,
        target: target.to_string(),
        phase: phase.to_string(),
    });
}

#[cfg(test)]
mod tests {
    use super::*;

    // ENABLED and SINK are process-global; serialize the tests that
    // toggle them so parallel `cargo test` stays deterministic.
    static LOCK: Mutex<()> = Mutex::new(());

    #[test]
    fn span_records_context_and_drains_once() {
        let _g = LOCK.lock().unwrap();
        drain();
        set_enabled(true);
        set_scope(9, 2);
        set_epoch(3);
        set_round(7);
        {
            let _s = span("obs.test", "alpha").bytes(40);
        }
        event("obs.test", "beta", 8);
        set_enabled(false);
        let ev = drain();
        let alpha = ev
            .iter()
            .find(|e| e.target == "obs.test" && e.phase == "alpha")
            .expect("span recorded");
        assert_eq!(
            (alpha.cluster, alpha.stage, alpha.epoch, alpha.round),
            (9, 2, 3, 7)
        );
        assert_eq!(alpha.bytes, 40);
        assert!(alpha.start_us > 0);
        let beta = ev
            .iter()
            .find(|e| e.target == "obs.test" && e.phase == "beta")
            .expect("event recorded");
        assert_eq!(beta.dur_us, 0);
        assert_eq!(beta.bytes, 8);
        // Drained once: a second drain has nothing of ours left.
        assert!(!drain().iter().any(|e| e.target == "obs.test"));
    }

    #[test]
    fn disabled_records_nothing() {
        let _g = LOCK.lock().unwrap();
        drain();
        set_enabled(false);
        {
            let _s = span("obs.test", "off");
        }
        event("obs.test", "off", 1);
        assert!(!drain().iter().any(|e| e.target == "obs.test"));
    }

    #[test]
    fn explicit_round_overrides_thread_round() {
        let _g = LOCK.lock().unwrap();
        drain();
        set_enabled(true);
        set_scope(1, 0);
        set_round(5);
        {
            let _s = span_at("obs.test", "drained", 3);
        }
        set_enabled(false);
        let ev = drain();
        let e = ev
            .iter()
            .find(|e| e.phase == "drained")
            .expect("span recorded");
        assert_eq!(e.round, 3);
    }

    #[test]
    fn helper_thread_inherits_captured_ctx() {
        let _g = LOCK.lock().unwrap();
        drain();
        set_enabled(true);
        set_scope(4, 1);
        set_epoch(2);
        set_round(6);
        let ctx = scope();
        std::thread::spawn(move || {
            set_ctx(ctx);
            let _s = span("obs.test", "inherited");
        })
        .join()
        .unwrap();
        set_enabled(false);
        let ev = drain();
        let e = ev
            .iter()
            .find(|e| e.phase == "inherited")
            .expect("comm-thread span flushed on thread exit");
        assert_eq!((e.cluster, e.stage, e.epoch, e.round), (4, 1, 2, 6));
    }
}
