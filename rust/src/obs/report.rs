//! Merged-timeline reports: the Chrome-trace/Perfetto export, the
//! per-round phase accounting (compute / compress / wire / barrier /
//! recovery seconds plus the §2.3 overlap hiding ratio), and the schema
//! validator behind `dilocox trace-check`.
//!
//! Phase classification is by event `phase`, matching what the
//! instrumentation records:
//!
//! * **compute** — the driver's `"compute"` span (H inner steps; the
//!   finer `fwd`/`bwd`/`wgrad` pipeline spans nest *inside* it and are
//!   not summed into the phase totals, to avoid double counting — but
//!   they DO feed the measured pipeline bubble fraction below);
//! * **compress** — `"compress.*"` (projection/quantization passes);
//! * **wire** — `"allreduce"` (one span per collective, carrying the
//!   compressed payload bytes; the per-hop `"hop"` spans nest inside);
//! * **barrier** — epoch machinery: `"epoch.wait"`, `"ring.form"`,
//!   `"consensus"`, `"epoch.prepare"`, `"epoch.commit"`;
//! * **recovery** — `"recovery.drain"` / `"recovery.discard"`.
//!
//! The hiding ratio of round t is the fraction of its wire time that
//! overlapped *any* compute interval of the same cluster — 0 in sync
//! mode, approaching 1 when one-step-delay overlap fully hides the
//! reduction of round t under the compute of round t+1.
//!
//! The **bubble fraction** of round t is measured from the pipeline op
//! spans (`fwd`, `bwd`, `wgrad` — link stalls excluded): with busy time
//! summed over every stage and the round's pipeline window taken per
//! cluster from first op start to last op end,
//! `bubble = 1 − Σ busy / Σ_c (stages_c · window_c)`.  It is 0 when the
//! round ran no pipeline ops (dp-only training), ≈(S−1)/(M+S−1) for
//! GPipe/1F1B, shrinking with interleaved virtual stages and toward
//! the α/β ratio noise floor for the zero-bubble schedule.

use super::TraceEvent;
use crate::metrics::Table;
use crate::util::json::{obj, Json};
use anyhow::{anyhow, Result};
use std::collections::BTreeMap;

const BARRIER_PHASES: [&str; 5] =
    ["epoch.wait", "ring.form", "consensus", "epoch.prepare", "epoch.commit"];

/// Per-round phase accounting over a merged fleet timeline.
#[derive(Clone, Debug, Default)]
pub struct RoundAccount {
    pub round: u32,
    pub compute_secs: f64,
    pub compress_secs: f64,
    pub wire_secs: f64,
    pub barrier_secs: f64,
    pub recovery_secs: f64,
    /// Compressed payload bytes of the round's collectives.
    pub wire_bytes: u64,
    /// Fraction of wire time overlapped by same-cluster compute.
    pub hiding_ratio: f64,
    /// Measured pipeline bubble: 1 − Σ op busy / Σ (stages · window).
    pub bubble_fraction: f64,
}

/// Pipeline op spans counted as busy time for the bubble fraction.
/// `link.acts` / `link.grads` are stalls (waiting on a peer stage) and
/// deliberately excluded — they ARE the bubble.
const PIPELINE_OPS: [&str; 3] = ["fwd", "bwd", "wgrad"];

fn secs(e: &TraceEvent) -> f64 {
    e.dur_us as f64 / 1e6
}

/// Merge possibly-overlapping `(start, end)` intervals in place.
fn merge_intervals(iv: &mut Vec<(u64, u64)>) {
    iv.sort_unstable();
    let mut out: Vec<(u64, u64)> = Vec::with_capacity(iv.len());
    for &(s, e) in iv.iter() {
        match out.last_mut() {
            Some(last) if s <= last.1 => last.1 = last.1.max(e),
            _ => out.push((s, e)),
        }
    }
    *iv = out;
}

/// Microseconds of `(s, e)` covered by the merged interval set.
fn covered_us(iv: &[(u64, u64)], s: u64, e: u64) -> u64 {
    iv.iter()
        .map(|&(a, b)| b.min(e).saturating_sub(a.max(s)))
        .sum()
}

/// Aggregate a merged timeline into per-round phase accounting, sorted
/// by round.  Rounds are the events' self-carried attribution, so the
/// sums cover every worker of the fleet.
pub fn round_accounting(events: &[TraceEvent]) -> Vec<RoundAccount> {
    // Merged compute intervals per cluster: the §2.3 question is whether
    // wire time hid under ANY compute of the same cluster (under overlap
    // that compute belongs to the next round).
    let mut compute: BTreeMap<u32, Vec<(u64, u64)>> = BTreeMap::new();
    for e in events {
        if e.phase == "compute" {
            compute
                .entry(e.cluster)
                .or_default()
                .push((e.start_us, e.start_us + e.dur_us));
        }
    }
    for iv in compute.values_mut() {
        merge_intervals(iv);
    }

    let mut acct: BTreeMap<u32, RoundAccount> = BTreeMap::new();
    let mut wire_us: BTreeMap<u32, u64> = BTreeMap::new();
    let mut hidden_us: BTreeMap<u32, u64> = BTreeMap::new();
    // Bubble accounting: per-round busy op time, plus per-(round,
    // cluster) pipeline window and the distinct stages that ran ops.
    let mut pipe_busy_us: BTreeMap<u32, u64> = BTreeMap::new();
    let mut pipe_window: BTreeMap<(u32, u32), (u64, u64)> = BTreeMap::new();
    let mut pipe_stages: BTreeMap<(u32, u32), std::collections::BTreeSet<u32>> =
        BTreeMap::new();
    for e in events {
        let a = acct.entry(e.round).or_insert_with(|| RoundAccount {
            round: e.round,
            ..RoundAccount::default()
        });
        if e.phase == "compute" {
            a.compute_secs += secs(e);
        } else if e.phase.starts_with("compress.") {
            a.compress_secs += secs(e);
        } else if e.phase == "allreduce" {
            a.wire_secs += secs(e);
            a.wire_bytes += e.bytes;
            *wire_us.entry(e.round).or_default() += e.dur_us;
            if let Some(iv) = compute.get(&e.cluster) {
                *hidden_us.entry(e.round).or_default() +=
                    covered_us(iv, e.start_us, e.start_us + e.dur_us);
            }
        } else if BARRIER_PHASES.contains(&e.phase.as_str()) {
            a.barrier_secs += secs(e);
        } else if e.phase.starts_with("recovery.") {
            a.recovery_secs += secs(e);
        }
        if PIPELINE_OPS.contains(&e.phase.as_str()) {
            *pipe_busy_us.entry(e.round).or_default() += e.dur_us;
            let end = e.start_us + e.dur_us;
            pipe_window
                .entry((e.round, e.cluster))
                .and_modify(|w| {
                    w.0 = w.0.min(e.start_us);
                    w.1 = w.1.max(end);
                })
                .or_insert((e.start_us, end));
            pipe_stages.entry((e.round, e.cluster)).or_default().insert(e.stage);
        }
    }
    for (round, a) in acct.iter_mut() {
        let w = wire_us.get(round).copied().unwrap_or(0);
        if w > 0 {
            a.hiding_ratio =
                hidden_us.get(round).copied().unwrap_or(0) as f64 / w as f64;
        }
        // Slot capacity: every stage of a cluster could have been busy
        // for the cluster's whole pipeline window.
        let capacity_us: u64 = pipe_window
            .range((*round, 0)..=(*round, u32::MAX))
            .map(|(&(_, c), &(start, end))| {
                let stages = pipe_stages
                    .get(&(*round, c))
                    .map(|s| s.len() as u64)
                    .unwrap_or(0);
                stages * (end - start)
            })
            .sum();
        if capacity_us > 0 {
            let busy = pipe_busy_us.get(round).copied().unwrap_or(0);
            a.bubble_fraction =
                (1.0 - busy as f64 / capacity_us as f64).max(0.0);
        }
    }
    acct.into_values().collect()
}

/// Render the accounting as a table (what `coordinate --trace` prints).
pub fn accounting_table(accounts: &[RoundAccount]) -> String {
    let mut t = Table::new(&[
        "round", "compute s", "compress s", "wire s", "barrier s",
        "recovery s", "wire bytes", "hiding", "bubble",
    ]);
    for a in accounts {
        t.row(&[
            a.round.to_string(),
            format!("{:.3}", a.compute_secs),
            format!("{:.3}", a.compress_secs),
            format!("{:.3}", a.wire_secs),
            format!("{:.3}", a.barrier_secs),
            format!("{:.3}", a.recovery_secs),
            a.wire_bytes.to_string(),
            format!("{:.2}", a.hiding_ratio),
            format!("{:.3}", a.bubble_fraction),
        ]);
    }
    t.render()
}

/// The accounting as JSON (the report's `dilocox.rounds` array).
pub fn accounting_json(accounts: &[RoundAccount]) -> Json {
    Json::Arr(
        accounts
            .iter()
            .map(|a| {
                obj(vec![
                    ("round", Json::Num(a.round as f64)),
                    ("compute_secs", Json::Num(a.compute_secs)),
                    ("compress_secs", Json::Num(a.compress_secs)),
                    ("wire_secs", Json::Num(a.wire_secs)),
                    ("barrier_secs", Json::Num(a.barrier_secs)),
                    ("recovery_secs", Json::Num(a.recovery_secs)),
                    ("wire_bytes", Json::Num(a.wire_bytes as f64)),
                    ("hiding_ratio", Json::Num(a.hiding_ratio)),
                    ("bubble_fraction", Json::Num(a.bubble_fraction)),
                ])
            })
            .collect(),
    )
}

/// The merged timeline as a Chrome-trace `traceEvents` array (complete
/// "X" events): pid = cluster, tid = stage·10⁶ + thread, so Perfetto
/// groups tracks by cluster and keeps stages apart within one.
pub fn chrome_trace_events(events: &[TraceEvent]) -> Json {
    Json::Arr(
        events
            .iter()
            .map(|e| {
                obj(vec![
                    ("name", Json::Str(e.phase.clone())),
                    ("cat", Json::Str(e.target.clone())),
                    ("ph", Json::Str("X".to_string())),
                    ("ts", Json::Num(e.start_us as f64)),
                    ("dur", Json::Num(e.dur_us as f64)),
                    ("pid", Json::Num(e.cluster as f64)),
                    (
                        "tid",
                        Json::Num(
                            (e.stage as u64 * 1_000_000 + e.tid as u64) as f64,
                        ),
                    ),
                    (
                        "args",
                        obj(vec![
                            ("round", Json::Num(e.round as f64)),
                            ("epoch", Json::Num(e.epoch as f64)),
                            ("bytes", Json::Num(e.bytes as f64)),
                        ]),
                    ),
                ])
            })
            .collect(),
    )
}

struct CheckEvent {
    ts: u64,
    dur: u64,
    name: String,
    round: u64,
}

/// Validate a `--trace` report against the schema `dilocox trace-check`
/// enforces in CI: a non-empty Chrome-trace `traceEvents` array of
/// complete events with all required keys, spans well-nested within
/// every (pid, tid) track (RAII guarantees this for an honest trace),
/// `"round"` spans nondecreasing per track, and — with
/// `expect_recovery` — at least one `recovery.*` event.  Returns the
/// validated event count.
pub fn validate_chrome_trace(doc: &Json, expect_recovery: bool) -> Result<usize> {
    let events = doc
        .get("traceEvents")
        .and_then(Json::as_arr)
        .ok_or_else(|| anyhow!("report has no traceEvents array"))?;
    if events.is_empty() {
        return Err(anyhow!("traceEvents is empty"));
    }
    let mut tracks: BTreeMap<(u64, u64), Vec<CheckEvent>> = BTreeMap::new();
    let mut saw_recovery = false;
    for (i, e) in events.iter().enumerate() {
        let num = |key: &str| {
            e.get(key)
                .and_then(Json::as_f64)
                .ok_or_else(|| anyhow!("event {i}: missing numeric '{key}'"))
        };
        let name = e
            .get("name")
            .and_then(Json::as_str)
            .ok_or_else(|| anyhow!("event {i}: missing 'name'"))?
            .to_string();
        e.get("cat")
            .and_then(Json::as_str)
            .ok_or_else(|| anyhow!("event {i}: missing 'cat'"))?;
        let ph = e
            .get("ph")
            .and_then(Json::as_str)
            .ok_or_else(|| anyhow!("event {i}: missing 'ph'"))?;
        if ph != "X" {
            return Err(anyhow!("event {i}: ph '{ph}' != complete event 'X'"));
        }
        let round = e
            .path("args.round")
            .and_then(Json::as_f64)
            .ok_or_else(|| anyhow!("event {i}: missing 'args.round'"))?;
        if name.starts_with("recovery.") {
            saw_recovery = true;
        }
        tracks
            .entry((num("pid")? as u64, num("tid")? as u64))
            .or_default()
            .push(CheckEvent {
                ts: num("ts")? as u64,
                dur: num("dur")? as u64,
                name,
                round: round as u64,
            });
    }
    for ((pid, tid), track) in tracks.iter_mut() {
        // Start ascending, then duration descending: at equal start
        // timestamps (microsecond resolution) the enclosing span sorts
        // first, which is exactly the nesting order.
        track.sort_by(|a, b| a.ts.cmp(&b.ts).then(b.dur.cmp(&a.dur)));
        let mut stack: Vec<u64> = Vec::new();
        let mut last_round: u64 = 0;
        for e in track.iter() {
            let end = e.ts + e.dur;
            while stack.last().is_some_and(|&top| top <= e.ts) {
                stack.pop();
            }
            if let Some(&top) = stack.last() {
                if end > top {
                    return Err(anyhow!(
                        "track ({pid}, {tid}): span '{}' [{}..{end}] \
                         partially overlaps an enclosing span ending at \
                         {top} — not well-nested",
                        e.name,
                        e.ts
                    ));
                }
            }
            stack.push(end);
            if e.name == "round" {
                if e.round < last_round {
                    return Err(anyhow!(
                        "track ({pid}, {tid}): round went backwards \
                         ({} after {last_round})",
                        e.round
                    ));
                }
                last_round = e.round;
            }
        }
    }
    if expect_recovery && !saw_recovery {
        return Err(anyhow!(
            "expected recovery events (recovery.drain / recovery.discard) \
             but the trace has none"
        ));
    }
    Ok(events.len())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ev(
        cluster: u32,
        round: u32,
        phase: &str,
        start_us: u64,
        dur_us: u64,
        bytes: u64,
    ) -> TraceEvent {
        TraceEvent {
            cluster,
            stage: 0,
            epoch: 1,
            round,
            tid: 1,
            start_us,
            dur_us,
            bytes,
            target: "t".to_string(),
            phase: phase.to_string(),
        }
    }

    #[test]
    fn accounting_classifies_and_sums_phases() {
        let events = vec![
            ev(0, 1, "round", 0, 1000, 0),
            ev(0, 1, "compute", 0, 600, 0),
            ev(0, 1, "compress.quant", 600, 100, 0),
            ev(0, 1, "allreduce", 700, 200, 512),
            ev(0, 1, "consensus", 900, 100, 0),
            ev(0, 2, "recovery.drain", 1000, 50, 0),
        ];
        let acct = round_accounting(&events);
        assert_eq!(acct.len(), 2);
        let r1 = &acct[0];
        assert_eq!(r1.round, 1);
        assert!((r1.compute_secs - 6e-4).abs() < 1e-9);
        assert!((r1.compress_secs - 1e-4).abs() < 1e-9);
        assert!((r1.wire_secs - 2e-4).abs() < 1e-9);
        assert!((r1.barrier_secs - 1e-4).abs() < 1e-9);
        assert_eq!(r1.wire_bytes, 512);
        let r2 = &acct[1];
        assert!((r2.recovery_secs - 5e-5).abs() < 1e-9);
    }

    fn ev_stage(
        stage: u32,
        round: u32,
        phase: &str,
        start_us: u64,
        dur_us: u64,
    ) -> TraceEvent {
        TraceEvent { stage, ..ev(0, round, phase, start_us, dur_us, 0) }
    }

    #[test]
    fn bubble_fraction_is_idle_slot_share() {
        // Two stages over a [0..300] window: 4 ops of 100us each fill
        // 400 of the 600 stage-slots, so the bubble is 1/3.  Link
        // stalls must not count as busy.
        let events = vec![
            ev_stage(0, 1, "fwd", 0, 100),
            ev_stage(0, 1, "bwd", 200, 100),
            ev_stage(1, 1, "fwd", 100, 100),
            ev_stage(1, 1, "link.grads", 200, 50),
            ev_stage(1, 1, "wgrad", 250, 50),
            ev_stage(1, 1, "bwd", 200, 50),
        ];
        let acct = round_accounting(&events);
        assert_eq!(acct.len(), 1);
        assert!((acct[0].bubble_fraction - 1.0 / 3.0).abs() < 1e-9);

        // No pipeline ops at all: bubble reads 0, not NaN.
        let flat = vec![ev(0, 1, "compute", 0, 100, 0)];
        assert_eq!(round_accounting(&flat)[0].bubble_fraction, 0.0);
    }

    #[test]
    fn hiding_ratio_is_compute_overlap_fraction() {
        // Round-1 wire [0..100] fully under compute; round-2 wire
        // [200..300] half-covered by compute ending at 250.
        let events = vec![
            ev(0, 2, "compute", 0, 250, 0),
            ev(0, 1, "allreduce", 0, 100, 64),
            ev(0, 2, "allreduce", 200, 100, 64),
        ];
        let acct = round_accounting(&events);
        assert!((acct[0].hiding_ratio - 1.0).abs() < 1e-9);
        assert!((acct[1].hiding_ratio - 0.5).abs() < 1e-9);
    }

    #[test]
    fn validator_accepts_a_nested_trace_and_counts() {
        let events = vec![
            ev(0, 1, "round", 0, 1000, 0),
            ev(0, 1, "compute", 100, 400, 0),
            ev(0, 1, "allreduce", 500, 400, 64),
            ev(0, 2, "round", 1000, 500, 0),
            ev(0, 2, "recovery.drain", 1100, 50, 0),
        ];
        let doc = obj(vec![("traceEvents", chrome_trace_events(&events))]);
        assert_eq!(validate_chrome_trace(&doc, true).unwrap(), 5);
    }

    #[test]
    fn validator_rejects_partial_overlap_and_round_regression() {
        let overlap = vec![
            ev(0, 1, "round", 0, 100, 0),
            // Starts inside the round span but ends beyond it.
            ev(0, 1, "compute", 50, 100, 0),
        ];
        let doc = obj(vec![("traceEvents", chrome_trace_events(&overlap))]);
        assert!(validate_chrome_trace(&doc, false).is_err());

        let regress = vec![
            ev(0, 5, "round", 0, 100, 0),
            ev(0, 4, "round", 200, 100, 0),
        ];
        let doc = obj(vec![("traceEvents", chrome_trace_events(&regress))]);
        assert!(validate_chrome_trace(&doc, false).is_err());

        let empty = obj(vec![("traceEvents", Json::Arr(Vec::new()))]);
        assert!(validate_chrome_trace(&empty, false).is_err());
    }

    #[test]
    fn validator_demands_recovery_when_expected() {
        let events = vec![ev(0, 1, "round", 0, 100, 0)];
        let doc = obj(vec![("traceEvents", chrome_trace_events(&events))]);
        assert!(validate_chrome_trace(&doc, false).is_ok());
        assert!(validate_chrome_trace(&doc, true).is_err());
    }
}
