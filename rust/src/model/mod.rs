//! Host-side parameter store: flat f32 vectors laid out exactly as the
//! manifest's param specs (which mirror python/compile/model.py).  The
//! single-stage layout is the concatenation of the pipeline stage layouts —
//! an invariant exported by aot.py and re-checked here.

use crate::runtime::manifest::{Manifest, ParamEntry};
use anyhow::{anyhow, Result};
use std::ops::Range;

/// Flat parameter vector + its layout.
#[derive(Clone, Debug)]
pub struct ParamStore {
    pub kind: String,
    pub flat: Vec<f32>,
    pub spec: Vec<ParamEntry>,
}

impl ParamStore {
    pub fn from_manifest(man: &Manifest, init_key: &str) -> Result<ParamStore> {
        let init = man
            .init
            .get(init_key)
            .ok_or_else(|| anyhow!("no init entry '{init_key}'"))?;
        let flat = man.read_f32(&init.file)?;
        let spec = man
            .param_specs
            .get(&init.kind)
            .ok_or_else(|| anyhow!("no param spec '{}'", init.kind))?
            .clone();
        let store = ParamStore { kind: init.kind.clone(), flat, spec };
        store.validate()?;
        Ok(store)
    }

    pub fn zeros_like(&self) -> Vec<f32> {
        vec![0.0; self.flat.len()]
    }

    pub fn validate(&self) -> Result<()> {
        let total: usize = self.spec.iter().map(|e| e.numel()).sum();
        if total != self.flat.len() {
            return Err(anyhow!(
                "flat len {} != spec total {total}",
                self.flat.len()
            ));
        }
        let mut off = 0;
        for e in &self.spec {
            if e.offset != off {
                return Err(anyhow!("non-contiguous spec at {}", e.name));
            }
            off += e.numel();
        }
        Ok(())
    }

    pub fn entry(&self, name: &str) -> Option<&ParamEntry> {
        self.spec.iter().find(|e| e.name == name)
    }

    pub fn view(&self, name: &str) -> Option<&[f32]> {
        self.entry(name)
            .map(|e| &self.flat[e.offset..e.offset + e.numel()])
    }

    /// Entries that are 2-D matrices (the low-rank compressor targets
    /// these; 1-D params are quantize-only, mirroring PowerSGD practice).
    pub fn matrix_entries(spec: &[ParamEntry]) -> Vec<&ParamEntry> {
        spec.iter().filter(|e| e.shape.len() == 2).collect()
    }
}

/// Ranges of each pipeline stage's parameters inside the single flat
/// layout (single == concat(stage layouts), validated by tests/aot).
pub fn stage_ranges(man: &Manifest) -> Vec<Range<usize>> {
    let kinds = man.stage_kinds();
    let mut out = Vec::with_capacity(kinds.len());
    let mut off = 0usize;
    for kind in kinds {
        let n = man.stage_numel[kind];
        out.push(off..off + n);
        off += n;
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny_man() -> Option<Manifest> {
        let dir = concat!(env!("CARGO_MANIFEST_DIR"), "/artifacts/tiny");
        std::path::Path::new(dir)
            .exists()
            .then(|| Manifest::load(dir).unwrap())
    }

    #[test]
    fn loads_and_validates_single() {
        let Some(man) = tiny_man() else { return };
        let ps = ParamStore::from_manifest(&man, "single").unwrap();
        assert_eq!(ps.flat.len(), man.param_count);
        // LayerNorm gains are exported as ones.
        let g = ps.view("layer0.ln1_g").unwrap();
        assert!(g.iter().all(|&x| x == 1.0));
        let bq = ps.view("layer0.bq").unwrap();
        assert!(bq.iter().all(|&x| x == 0.0));
        assert!(ps.view("nope").is_none());
    }

    #[test]
    fn stage_ranges_tile_the_single_layout() {
        let Some(man) = tiny_man() else { return };
        let ranges = stage_ranges(&man);
        assert_eq!(ranges.len(), man.dims.pp_stages);
        assert_eq!(ranges[0].start, 0);
        for w in ranges.windows(2) {
            assert_eq!(w[0].end, w[1].start);
        }
        assert_eq!(ranges.last().unwrap().end, man.param_count);
    }

    #[test]
    fn stage_init_concat_equals_single_init() {
        let Some(man) = tiny_man() else { return };
        let single = ParamStore::from_manifest(&man, "single").unwrap();
        let mut concat = Vec::new();
        for i in 0..man.dims.pp_stages {
            let s = ParamStore::from_manifest(&man, &format!("stage_{i}")).unwrap();
            concat.extend_from_slice(&s.flat);
        }
        assert_eq!(concat, single.flat);
    }

    #[test]
    fn matrix_entries_are_2d() {
        let Some(man) = tiny_man() else { return };
        let spec = &man.param_specs["single"];
        let mats = ParamStore::matrix_entries(spec);
        assert!(mats.iter().all(|e| e.shape.len() == 2));
        // tok_emb, pos_emb, per-layer wq/wk/wv/wo/w1/w2, head_w
        assert_eq!(mats.len(), 2 + 6 * man.dims.n_layers + 1);
    }
}
