//! GPU compute model for the throughput simulator.
//!
//! The paper's testbed is NVIDIA A800-40G (A100-class silicon, 312 bf16
//! TFLOPs peak).  We model achieved per-GPU throughput as a calibrated
//! *effective* TFLOPs figure (peak × MFU, absorbing kernel efficiency and
//! pipeline-interleave losses) — the single free parameter per model
//! scale, calibrated against the paper's AllReduce rows (DESIGN.md
//! substitution table); every ratio between algorithms then comes out of
//! the mechanism, not the calibration.

/// Training FLOPs per token for a dense decoder transformer: ~6·θ
/// (2 fwd + 4 bwd).
pub const FLOPS_PER_TOKEN_FACTOR: f64 = 6.0;

#[derive(Clone, Debug)]
pub struct GpuModel {
    pub name: String,
    /// Peak dense bf16 TFLOPs (A800 = 312).
    pub peak_tflops: f64,
    /// Calibrated achieved fraction of peak.
    pub mfu: f64,
    /// HBM per GPU, bytes (A800-40G).
    pub hbm_bytes: u64,
}

impl GpuModel {
    pub fn a800_40g(mfu: f64) -> Self {
        GpuModel {
            name: "A800-40G".into(),
            peak_tflops: 312.0,
            mfu,
            hbm_bytes: 40_000_000_000,
        }
    }

    pub fn effective_flops(&self) -> f64 {
        self.peak_tflops * 1e12 * self.mfu
    }

    /// Seconds for one *cluster-local* training step of `tokens` tokens on
    /// a model of `params` parameters spread over `gpus` pipeline workers,
    /// including the fill-drain bubble for `micros` in-flight microbatches.
    pub fn step_seconds(
        &self,
        params: f64,
        tokens: f64,
        gpus: usize,
        stages: usize,
        micros: usize,
    ) -> f64 {
        let flops = FLOPS_PER_TOKEN_FACTOR * params * tokens;
        let ideal = flops / (gpus as f64 * self.effective_flops());
        let bubble = crate::pipeline::bubble_fraction(stages, micros.max(1));
        ideal / (1.0 - bubble).max(1e-6)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn step_time_scales_linearly() {
        let g = GpuModel::a800_40g(0.04);
        let t1 = g.step_seconds(1.3e9, 16384.0, 8, 1, 1);
        let t2 = g.step_seconds(2.6e9, 16384.0, 8, 1, 1);
        assert!((t2 / t1 - 2.0).abs() < 1e-9);
        let t_half = g.step_seconds(1.3e9, 8192.0, 8, 1, 1);
        assert!((t1 / t_half - 2.0).abs() < 1e-9);
    }

    #[test]
    fn bubble_inflates_pipeline_time() {
        let g = GpuModel::a800_40g(0.05);
        let no_pp = g.step_seconds(1e11, 16384.0, 80, 1, 8);
        let pp = g.step_seconds(1e11, 16384.0, 80, 8, 8);
        assert!(pp > no_pp);
        // 8 stages, 8 micros → bubble 7/15 → 1/(1-b) = 15/8.
        assert!((pp / no_pp - 15.0 / 8.0).abs() < 1e-9);
    }

    #[test]
    fn a800_order_of_magnitude() {
        // 107B over 80 GPUs at ~5% MFU: ~8-9 s per 16k-token step.
        let g = GpuModel::a800_40g(0.048);
        let t = g.step_seconds(107e9, 16384.0, 80, 1, 1);
        assert!(t > 6.0 && t < 12.0, "t={t}");
    }
}
