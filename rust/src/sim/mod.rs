//! Discrete-event throughput simulator for the paper-scale experiments
//! (Fig. 4, Table 1 throughput column, §2.4.1 analysis): true model sizes
//! (OPT-1.3B, Qwen1.5-107B), A800 compute model, 1 Gbps WAN.
//!
//! Mechanism, not curve-fitting: the inner step time comes from a DES run
//! of the 1F1B pipeline schedule over per-stage GPU resources and
//! intra-cluster activation links; the sync time comes from ring/PS
//! transfers over the WAN links; overlap is modeled by scheduling comm on
//! the NIC resource while the GPUs start the next local phase.  The only
//! calibrated constant is the per-scale effective TFLOPs (see gpu.rs).

pub mod gpu;
pub mod memory;

use crate::compress::Method;
use crate::config::{Algo, NetworkConfig};
use crate::netsim::{Topology, WorkerId};
use crate::pipeline;
use gpu::GpuModel;
use memory::{MemVerdict, MemoryReport};

#[derive(Clone, Debug)]
pub struct ScaleConfig {
    pub name: String,
    /// Total parameters θ.
    pub params: f64,
    /// Hidden width (drives the low-rank factor shapes).
    pub d_hidden: usize,
    pub clusters: usize,
    pub gpus_per_cluster: usize,
    /// Pipeline stages inside a cluster (== gpus_per_cluster here).
    pub pp_stages: usize,
    /// In-flight microbatches per step.
    pub microbatches: usize,
    /// Tokens one cluster processes per local step.
    pub tokens_per_cluster_step: f64,
    pub gpu: GpuModel,
    pub net: NetworkConfig,
}

impl ScaleConfig {
    /// OPT-1.3B testbed: 2 nodes × 8 A800 (paper §4.1.2), 1 Gbps between.
    pub fn opt_1_3b() -> Self {
        ScaleConfig {
            name: "OPT-1.3B".into(),
            params: 1.3e9,
            d_hidden: 2048,
            clusters: 2,
            gpus_per_cluster: 8,
            pp_stages: 8,
            microbatches: 16,
            tokens_per_cluster_step: 16384.0,
            // Calibrated against the paper's AllReduce row (745 tok/s):
            // comm-dominated, so the row pins t_step only loosely; the
            // same figure reproduces the DiLoCoX row within a few percent.
            gpu: GpuModel::a800_40g(0.045),
            net: NetworkConfig::paper_1gbps(2),
        }
    }

    /// Qwen1.5-107B testbed: 20 nodes × 8 A800 = 160 GPUs, 2 clusters.
    pub fn qwen_107b() -> Self {
        ScaleConfig {
            name: "Qwen1.5-107B".into(),
            params: 107e9,
            d_hidden: 8192,
            clusters: 2,
            gpus_per_cluster: 80,
            pp_stages: 80,
            microbatches: 160,
            tokens_per_cluster_step: 16384.0,
            gpu: GpuModel::a800_40g(0.055),
            net: NetworkConfig::paper_1gbps(2),
        }
    }
}

#[derive(Clone, Debug)]
pub struct SimAlgo {
    pub algo: Algo,
    pub local_steps: usize,
    pub overlap: bool,
    pub method: Method,
}

impl SimAlgo {
    /// The paper's per-algorithm settings at each scale (§4.1.3).
    pub fn paper_setting(algo: Algo, scale: &ScaleConfig) -> SimAlgo {
        let big = scale.params > 10e9;
        match algo {
            Algo::AllReduce => SimAlgo {
                algo,
                local_steps: 1,
                overlap: false,
                method: Method::None,
            },
            Algo::OpenDiLoCo => SimAlgo {
                algo,
                local_steps: 500,
                overlap: false,
                method: Method::Quant { q_bits: 16 },
            },
            Algo::CocktailSgd => SimAlgo {
                algo,
                local_steps: 1,
                overlap: false,
                method: Method::Cocktail {
                    random_ratio: 0.1,
                    topk_ratio: if big { 0.04 } else { 0.08 },
                    q_bits: 4,
                },
            },
            Algo::DiLoCoX => SimAlgo {
                algo,
                local_steps: 125,
                overlap: true,
                method: if big {
                    Method::LowRankQuant { rank: 2048, q_bits: 4 }
                } else {
                    Method::Quant { q_bits: 4 }
                },
            },
        }
    }
}

#[derive(Clone, Debug)]
pub struct SimResult {
    pub algo: Algo,
    pub scale: String,
    pub oom: bool,
    pub memory: MemoryReport,
    /// Seconds per cluster-local training step (from the pipeline DES).
    pub step_secs: f64,
    /// WAN seconds per pseudo-gradient sync.
    pub comm_secs: f64,
    /// Bytes per worker per sync on the WAN.
    pub wire_bytes: u64,
    pub compression_ratio: f64,
    pub tokens_per_sec: f64,
    /// GPU busy fraction over the simulated horizon.
    pub gpu_utilization: f64,
}

/// Wire payload for one sync of a θ-parameter pseudo-gradient under a
/// compression method, using the d_hidden shape model (θ treated as
/// square d_h × d_h matrices — transformer weights are within 4× of
/// square, and the factor-size formula is linear in rows+cols).
pub fn sync_payload_bytes(params: f64, d_hidden: usize, method: &Method) -> u64 {
    let full = 4.0 * params;
    let bytes = match method {
        Method::None => full,
        Method::Quant { q_bits } => params * (*q_bits as f64) / 8.0,
        Method::LowRankQuant { rank, q_bits } => {
            let d = d_hidden as f64;
            let n_mats = params / (d * d);
            let factor_elems = n_mats * (*rank as f64) * 2.0 * d;
            factor_elems * (*q_bits as f64) / 8.0
        }
        Method::TopK { ratio, q_bits } => {
            let k = params * (*ratio as f64);
            2.0 * k * ((*q_bits as f64) / 8.0 + 4.0)
        }
        Method::RandomK { ratio } => params * (*ratio as f64) * 4.0,
        Method::Cocktail { random_ratio, topk_ratio, q_bits } => {
            // Values-only accounting, up + down legs: positions are
            // implicit in CocktailSGD's shared-seed mask encoding, which
            // is how the paper's declared 500x (1.3B) / 1000x (107B)
            // ratios come out: 2·k·q/8 = 4θ·rr·tr·q/16.
            let k = params * (*random_ratio as f64) * (*topk_ratio as f64);
            2.0 * k * (*q_bits as f64) / 8.0
        }
    };
    bytes.max(1.0) as u64
}

/// One inner training step's makespan from a DES run of the 1F1B pipeline
/// over per-stage GPU resources + intra-cluster activation links.
pub fn pipeline_step_secs(scale: &ScaleConfig, topo: &mut Topology) -> f64 {
    pipeline_step_secs_for(scale, topo, pipeline::ScheduleKind::OneFOneB, 1)
        .expect("1F1B schedule is valid")
}

/// Like [`pipeline_step_secs`], but for any [`pipeline::ScheduleKind`]
/// and virtual-stage count: `pp_stages` executors each own `v` model
/// chunks of θ/(S·v) parameters, so per-executor compute per step is
/// unchanged while the schedule's cell granularity shrinks.
///
/// The dependency structure comes from [`pipeline::execute_streams`] —
/// the same oracle the schedule validator uses and the same streams the
/// real stage-parallel executor runs, so the simulated bubble structure
/// can never drift from the executed one.  Split-backward schedules
/// (zero-bubble) spend half the fused backward on the input grad (the
/// critical-path B cell) and half on the back-filled weight grad W.
pub fn pipeline_step_secs_for(
    scale: &ScaleConfig,
    topo: &mut Topology,
    kind: pipeline::ScheduleKind,
    virtual_stages: usize,
) -> Result<f64, String> {
    let s_execs = scale.pp_stages;
    let v = virtual_stages.max(1);
    let u = scale.microbatches;
    let k_total = s_execs * v;
    let tok_micro = scale.tokens_per_cluster_step / u as f64;
    // Per-chunk, per-microbatch compute: fwd = 2θ_k·tok, bwd = 4θ_k·tok
    // (bwd includes the rematerialized forward, matching the L2 export).
    let theta_chunk = scale.params / k_total as f64;
    let eff = scale.gpu.effective_flops();
    let fwd = 2.0 * theta_chunk * tok_micro / eff;
    let bwd = 4.0 * theta_chunk * tok_micro / eff;
    // Activation tensor crossing stage boundaries.
    let act_bytes = (tok_micro * scale.d_hidden as f64 * 4.0) as u64;

    let streams = kind.streams(s_execs, v, u)?;
    let split = streams.iter().flatten().any(|c| c.op == pipeline::OpKind::W);
    let (b_cost, w_cost) = if split { (bwd / 2.0, bwd / 2.0) } else { (bwd, 0.0) };

    // Event-graph execution for cluster 0 (all clusters identical):
    // each cell's completion time = GPU acquire after its dependencies
    // land, with activation/grad transfers on the intra-cluster links
    // (the chunk hand-off from executor S−1 back to 0 rides the wrap
    // link; a same-executor hand-off at S = 1 is a local move).
    let c = 0usize;
    let trace = pipeline::execute_streams(&streams, u, |cell, dep_a, dep_b| {
        let e = cell.stage;
        let k = cell.model_stage(s_execs);
        let (ready, dur) = match cell.op {
            pipeline::OpKind::F => {
                let ready = match dep_a {
                    None => 0.0, // model stage 0 reads the microbatch locally
                    Some(&t) => {
                        let p = (k - 1) % s_execs; // producer executor
                        if p == e {
                            t
                        } else if p + 1 == s_execs {
                            topo.wrap_link(c).transfer(t, act_bytes).1
                        } else {
                            topo.intra_link(c, p).transfer(t, act_bytes).1
                        }
                    }
                };
                (ready, fwd)
            }
            pipeline::OpKind::B => {
                let own_fwd = *dep_a.expect("backward depends on its forward");
                let ready = match dep_b {
                    None => own_fwd, // last model stage: loss grad is local
                    Some(&tb) => {
                        let q = (k + 1) % s_execs; // producer executor
                        let arrive = if q == e {
                            tb
                        } else if e + 1 == s_execs {
                            topo.wrap_link(c).transfer(tb, act_bytes).1
                        } else {
                            topo.intra_link(c, e).transfer(tb, act_bytes).1
                        };
                        arrive.max(own_fwd)
                    }
                };
                (ready, b_cost)
            }
            pipeline::OpKind::W => {
                // Weight grad consumes stashed local state only.
                let own_fwd = *dep_a.expect("weight grad depends on forward");
                let own_bwd = *dep_b.expect("weight grad depends on backward");
                (own_fwd.max(own_bwd), w_cost)
            }
        };
        topo.gpu(WorkerId { cluster: c, stage: e }).acquire(ready, dur).1
    })?;

    let mut makespan = 0.0f64;
    for row in trace.fwd.iter().chain(trace.bwd.iter()) {
        for &t in row {
            makespan = makespan.max(t);
        }
    }
    for row in &trace.wgrad {
        for t in row.iter().flatten() {
            makespan = makespan.max(*t);
        }
    }
    Ok(makespan)
}

/// Simulate `outer_rounds` outer steps and return throughput + breakdown.
pub fn simulate(scale: &ScaleConfig, algo: &SimAlgo, outer_rounds: usize) -> SimResult {
    simulate_calibrated(scale, algo, outer_rounds, None)
}

/// Like [`simulate`], but with an optional *measured* per-stage 1F1B
/// step time replacing the FLOP-model DES step — the calibration loop:
/// real runs measure `step_secs` (threaded `StageRoundReport`s or fleet
/// heartbeats, shipped in the `coordinate --report` JSON) and feed it
/// back so the modeled table reflects the hardware actually measured.
pub fn simulate_calibrated(
    scale: &ScaleConfig,
    algo: &SimAlgo,
    outer_rounds: usize,
    step_secs_override: Option<f64>,
) -> SimResult {
    // ---- memory verdict -------------------------------------------------
    let hbm = scale.gpu.hbm_bytes;
    let memory = match algo.algo {
        Algo::OpenDiLoCo => memory::opendiloco_memory(scale.params, hbm),
        Algo::DiLoCoX => {
            memory::dilocox_memory(scale.params, scale.pp_stages, hbm)
        }
        _ => {
            // AllReduce / Cocktail: Megatron-style PP shard, inner opt only.
            let mut r =
                memory::dilocox_memory(scale.params, scale.pp_stages, hbm);
            r.per_gpu_bytes = (scale.params / scale.pp_stages as f64
                * memory::INNER_BYTES_PER_PARAM) as u64;
            r.worst_gpu_bytes = r.per_gpu_bytes;
            r.verdict = if r.per_gpu_bytes <= hbm {
                MemVerdict::Fits
            } else {
                MemVerdict::Oom
            };
            r
        }
    };
    if memory.verdict == MemVerdict::Oom {
        return SimResult {
            algo: algo.algo,
            scale: scale.name.clone(),
            oom: true,
            memory,
            step_secs: 0.0,
            comm_secs: 0.0,
            wire_bytes: 0,
            compression_ratio: 0.0,
            tokens_per_sec: 0.0,
            gpu_utilization: 0.0,
        };
    }

    // ---- inner step time (pipeline DES, or a measured calibration) ------
    let step_secs = match step_secs_override {
        Some(measured) => measured,
        None => {
            let mut topo = Topology::new(&scale.net, scale.pp_stages);
            pipeline_step_secs(scale, &mut topo)
        }
    };

    // ---- sync time over the WAN -----------------------------------------
    let payload = sync_payload_bytes(scale.params, scale.d_hidden, &algo.method);
    let comm_secs = if algo.method.allreduce_compatible() {
        crate::comm::ring_allreduce_seconds(payload, &scale.net)
    } else {
        crate::comm::parameter_server_seconds(payload / 2, payload / 2, &scale.net)
    };

    // ---- outer loop over virtual time ------------------------------------
    // GPUs and NIC are separate resources: with overlap the sync occupies
    // the NIC while the next local phase runs on the GPUs; the outer
    // update at the end of round t+1 must wait for sync_t to finish.
    let local_phase = algo.local_steps as f64 * step_secs;
    let mut gpu_free = 0.0f64;
    let mut nic_free = 0.0f64;
    let mut pending_sync_end: Option<f64> = None;
    let mut clock = 0.0f64;
    for _round in 0..outer_rounds {
        // local training
        let start = clock.max(gpu_free);
        let local_end = start + local_phase;
        gpu_free = local_end;
        if algo.overlap {
            // outer update waits for the PREVIOUS sync (one-step delay).
            let wait = pending_sync_end.take().unwrap_or(local_end);
            clock = local_end.max(wait);
            // launch this round's sync on the NIC.
            let s = clock.max(nic_free);
            nic_free = s + comm_secs;
            pending_sync_end = Some(nic_free);
        } else {
            // synchronous: GPUs idle during the sync.
            let s = local_end.max(nic_free);
            nic_free = s + comm_secs;
            clock = nic_free;
            gpu_free = clock;
        }
    }
    // trailing sync drains (overlap) — count it in the horizon.
    if let Some(end) = pending_sync_end {
        clock = clock.max(end);
    }

    let total_tokens = scale.clusters as f64
        * scale.tokens_per_cluster_step
        * algo.local_steps as f64
        * outer_rounds as f64;
    let horizon = clock.max(1e-9);
    let busy = local_phase * outer_rounds as f64;

    SimResult {
        algo: algo.algo,
        scale: scale.name.clone(),
        oom: false,
        memory,
        step_secs,
        comm_secs,
        wire_bytes: payload,
        compression_ratio: 4.0 * scale.params / payload as f64,
        tokens_per_sec: total_tokens / horizon,
        gpu_utilization: (busy / horizon).min(1.0),
    }
}

/// One row of the flat / reordered / hier reduction-topology comparison.
#[derive(Clone, Debug)]
pub struct TopologyRow {
    pub topology: &'static str,
    /// Global reduce order over the cluster ids.
    pub order: Vec<usize>,
    /// Modeled WAN seconds for one sync of the payload.
    pub wan_secs: f64,
    /// Bytes a WAN-crossing member moves over cross-site links per sync:
    /// 2·(C−1)/C·payload for the flat/reordered rings, 2·(S−1)/S·payload
    /// for a hierarchical site leader.
    pub wan_bytes_per_member: u64,
}

/// Model the three reduction topologies over one heterogeneous link
/// matrix: `site_of[i]` is cluster i's site; same-site links run at
/// `net.intra_bw_gbps` with negligible latency, cross-site links at
/// `net.inter_bw_gbps` with `net.latency_ms` per hop.
///
/// - flat: the natural rank-ascending ring.  With interleaved placement
///   every hop crosses the WAN and every member moves 2·(C−1)/C·payload
///   on it.
/// - reordered: [`crate::transport::probe::ring_order`] groups each site
///   contiguously, so only one link per site boundary crosses the WAN —
///   but a crossing member still moves the full 2·(C−1)/C·payload, and
///   the synchronous ring is still paced by the slowest hop.
/// - hier: the two-level reduce — only the S site leaders touch the WAN,
///   each moving exactly 2·(S−1)/S·payload.
pub fn reduce_topology_rows(
    payload: u64,
    net: &NetworkConfig,
    site_of: &[usize],
) -> Vec<TopologyRow> {
    use crate::transport::probe::{ring_order, ring_step_seconds, LinkMatrix};
    let c = site_of.len();
    let mut m = LinkMatrix::new(c);
    for i in 0..c {
        for j in 0..c {
            if i == j {
                continue;
            }
            if site_of[i] == site_of[j] {
                m.set(i, j, net.intra_bw_gbps, 0.0);
            } else {
                m.set(i, j, net.inter_bw_gbps, net.latency_ms);
            }
        }
    }
    // Site sizes in order of first appearance (what the hier model needs).
    let mut sites: Vec<usize> = Vec::new();
    let mut site_sizes: Vec<usize> = Vec::new();
    for &s in site_of {
        match sites.iter().position(|&x| x == s) {
            Some(i) => site_sizes[i] += 1,
            None => {
                sites.push(s);
                site_sizes.push(1);
            }
        }
    }
    let per_member = crate::comm::ring_wire_bytes_per_worker(payload, c);
    let natural: Vec<usize> = (0..c).collect();
    let reordered = ring_order(&m);
    // The hierarchical global order is (site, rank) ascending — the same
    // order the elastic coordinator commits for a hier fleet.
    let mut hier_order: Vec<usize> = (0..c).collect();
    hier_order.sort_by_key(|&i| (site_of[i], i));
    let s = site_sizes.len();
    vec![
        TopologyRow {
            topology: "flat",
            wan_secs: ring_step_seconds(&m, &natural, payload),
            order: natural,
            wan_bytes_per_member: per_member,
        },
        TopologyRow {
            topology: "reordered",
            wan_secs: ring_step_seconds(&m, &reordered, payload),
            order: reordered,
            wan_bytes_per_member: per_member,
        },
        TopologyRow {
            topology: "hier",
            wan_secs: crate::comm::hier_allreduce_seconds(
                payload, net, &site_sizes,
            ),
            order: hier_order,
            wan_bytes_per_member:
                crate::transport::hier::hier_cross_bytes_per_leader(payload, s),
        },
    ]
}

/// Paper Fig. 4: all four algorithms at one scale.
pub fn figure4_row(scale: &ScaleConfig, outer_rounds: usize) -> Vec<SimResult> {
    [Algo::AllReduce, Algo::OpenDiLoCo, Algo::CocktailSgd, Algo::DiLoCoX]
        .iter()
        .map(|&a| simulate(scale, &SimAlgo::paper_setting(a, scale), outer_rounds))
        .collect()
}

/// Paper Table 1 (throughput column): DiLoCoX ablations at 107B.
pub fn table1_throughput(outer_rounds: usize) -> Vec<(String, SimResult)> {
    let scale = ScaleConfig::qwen_107b();
    let full = SimAlgo::paper_setting(Algo::DiLoCoX, &scale);
    let mut no_overlap = full.clone();
    no_overlap.overlap = false;
    let mut no_comp = full.clone();
    no_comp.method = Method::None;
    let ar = SimAlgo::paper_setting(Algo::AllReduce, &scale);
    vec![
        ("Full DiLoCoX".to_string(), simulate(&scale, &full, outer_rounds)),
        ("w/o Overlap".to_string(), simulate(&scale, &no_overlap, outer_rounds)),
        ("w/o Compression".to_string(), simulate(&scale, &no_comp, outer_rounds)),
        ("AllReduce".to_string(), simulate(&scale, &ar, outer_rounds)),
    ]
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn payload_model_matches_paper_arithmetic() {
        // 107B rank-2048 int4 on 8192-wide mats: "≈2x low-rank" × 8x int4.
        let p = sync_payload_bytes(
            107e9,
            8192,
            &Method::LowRankQuant { rank: 2048, q_bits: 4 },
        );
        let ratio = 4.0 * 107e9 / p as f64;
        assert!((ratio - 16.0).abs() < 0.5, "ratio={ratio}");
        // fp32 = θ·4.
        assert_eq!(sync_payload_bytes(1e9, 2048, &Method::None), 4_000_000_000);
    }

    #[test]
    fn fig4_107b_shape_matches_paper() {
        let scale = ScaleConfig::qwen_107b();
        let rows = figure4_row(&scale, 12);
        let by = |a: Algo| rows.iter().find(|r| r.algo == a).unwrap().clone();
        let ar = by(Algo::AllReduce);
        let od = by(Algo::OpenDiLoCo);
        let ck = by(Algo::CocktailSgd);
        let dx = by(Algo::DiLoCoX);
        // OpenDiLoCo OOMs at 107B (paper §4.2.1).
        assert!(od.oom);
        assert!(!ar.oom && !dx.oom);
        // Paper: 10.4 / 2427 / 3728 tokens/s → shape: DiLoCoX > Cocktail
        // >> AllReduce, speedup vs AllReduce in the hundreds.
        assert!(dx.tokens_per_sec > ck.tokens_per_sec);
        let speedup = dx.tokens_per_sec / ar.tokens_per_sec;
        assert!(
            speedup > 200.0 && speedup < 600.0,
            "speedup={speedup} (paper: 357x)"
        );
        let vs_ck = dx.tokens_per_sec / ck.tokens_per_sec;
        assert!(vs_ck > 1.1 && vs_ck < 2.0, "vs cocktail {vs_ck} (paper 1.35x)");
        // Absolute order of magnitude sanity.
        assert!(ar.tokens_per_sec > 4.0 && ar.tokens_per_sec < 25.0,
                "AR={}", ar.tokens_per_sec);
        assert!(dx.tokens_per_sec > 2500.0 && dx.tokens_per_sec < 5000.0,
                "DX={}", dx.tokens_per_sec);
    }

    #[test]
    fn fig4_1_3b_shape_matches_paper() {
        let scale = ScaleConfig::opt_1_3b();
        let rows = figure4_row(&scale, 12);
        let by = |a: Algo| rows.iter().find(|r| r.algo == a).unwrap().clone();
        let ar = by(Algo::AllReduce);
        let dx = by(Algo::DiLoCoX);
        let ck = by(Algo::CocktailSgd);
        assert!(!by(Algo::OpenDiLoCo).oom); // 1.3B fits
        // Paper: 745 / 16161 / 23880 → DiLoCoX ~32x AllReduce.
        let speedup = dx.tokens_per_sec / ar.tokens_per_sec;
        assert!(speedup > 15.0 && speedup < 60.0, "speedup={speedup}");
        assert!(dx.tokens_per_sec > ck.tokens_per_sec);
    }

    #[test]
    fn table1_ordering_matches_paper() {
        let rows = table1_throughput(10);
        let tps: Vec<f64> = rows.iter().map(|(_, r)| r.tokens_per_sec).collect();
        // Full > w/o Overlap > w/o Compression > AllReduce (paper: 3728 >
        // 2197 > 1168 > 10.4).
        assert!(tps[0] > tps[1], "{tps:?}");
        assert!(tps[1] > tps[2], "{tps:?}");
        assert!(tps[2] > tps[3], "{tps:?}");
        assert!(tps[0] / tps[3] > 100.0);
    }

    #[test]
    fn overlap_hides_comm_when_local_phase_dominates() {
        let scale = ScaleConfig::qwen_107b();
        let mut a = SimAlgo::paper_setting(Algo::DiLoCoX, &scale);
        let with = simulate(&scale, &a, 10);
        a.overlap = false;
        let without = simulate(&scale, &a, 10);
        // comm < local phase → overlap makes it (nearly) free.
        assert!(with.comm_secs < with.step_secs * a.local_steps as f64);
        assert!(with.tokens_per_sec > without.tokens_per_sec);
        assert!(with.gpu_utilization > 0.95, "{}", with.gpu_utilization);
    }

    #[test]
    fn topology_rows_show_the_exact_two_level_fraction() {
        // 4 clusters interleaved over 2 sites (0,1,0,1) at the paper's
        // 1 Gbps WAN: the naive flat ring crosses the WAN on every hop.
        let net = NetworkConfig::paper_1gbps(4);
        let payload = 4_000_000_000u64;
        let rows = reduce_topology_rows(payload, &net, &[0, 1, 0, 1]);
        let by = |t: &str| rows.iter().find(|r| r.topology == t).unwrap();
        let (flat, reordered, hier) = (by("flat"), by("reordered"), by("hier"));
        // Exact §2.4.1 byte math: 2(C−1)/C vs 2(S−1)/S of the payload.
        assert_eq!(flat.wan_bytes_per_member, 2 * 3 * payload / 4);
        assert_eq!(reordered.wan_bytes_per_member, 2 * 3 * payload / 4);
        assert_eq!(hier.wan_bytes_per_member, 2 * 1 * payload / 2);
        // Reordering groups the sites: consecutive same-site pairs exist.
        let ro = &reordered.order;
        let site = [0usize, 1, 0, 1];
        let crossings = (0..4)
            .filter(|&i| site[ro[i]] != site[ro[(i + 1) % 4]])
            .count();
        assert_eq!(crossings, 2, "reordered={ro:?}");
        // Hier order is (site, rank) ascending.
        assert_eq!(hier.order, vec![0, 2, 1, 3]);
        // On this uniform two-tier matrix the synchronous ring is paced
        // by its one unavoidable WAN hop either way, so reordering can't
        // beat flat on time (it wins on aggregate WAN bytes and on
        // heterogeneous cross-links); hier strictly wins on both.
        assert!(reordered.wan_secs <= flat.wan_secs + 1e-9);
        assert!(hier.wan_secs < reordered.wan_secs);
        let ratio = hier.wan_secs / flat.wan_secs;
        // Latency terms are second-order at 4 GB payload; the ratio lands
        // near (2(S−1)/S)/(2(C−1)/C) = 1.0/1.5.
        assert!(
            (ratio - (1.0 / 1.5)).abs() < 0.05,
            "ratio={ratio}"
        );
    }

    #[test]
    fn des_pipeline_matches_bubble_formula() {
        // With (near) free links the DES makespan must approach the
        // analytic fill-drain bound: (U + M − 1) cell pairs.
        let mut scale = ScaleConfig::opt_1_3b();
        scale.net.latency_ms = 0.0;
        scale.net.intra_bw_gbps = 1e9; // effectively infinite
        let mut topo = Topology::new(&scale.net, scale.pp_stages);
        let t = pipeline_step_secs(&scale, &mut topo);
        let m = scale.pp_stages as f64;
        let u = scale.microbatches as f64;
        let theta_stage = scale.params / m;
        let tok_micro = scale.tokens_per_cluster_step / u;
        let eff = scale.gpu.effective_flops();
        let cell = (2.0 + 4.0) * theta_stage * tok_micro / eff;
        let ideal = (u + m - 1.0) * cell;
        // 1F1B with uneven fwd/bwd cells runs within ~2x of the ideal
        // fill-drain bound; it must never beat it.
        assert!(t >= ideal * 0.999, "DES {t} < ideal {ideal}");
        assert!(t <= ideal * 2.0, "DES {t} vs ideal {ideal}");
    }
}
