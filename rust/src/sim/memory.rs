//! Per-GPU memory model (paper §2.2): reproduces the dual-optimizer VRAM
//! balance argument and OpenDiLoCo's 107B OOM.
//!
//! Byte accounting per parameter held on a GPU (fp32 master weights,
//! Adam m+v, gradients; the outer optimizer adds a momentum buffer and a
//! parameter anchor):
//!   inner-only worker:           4 (p) + 4 (g) + 8 (adam)       = 16 B
//!   + outer state (DiLoCoX,      + 4 (nesterov buf) + 4 (anchor) =  8 B
//!     sharded over the stage)
//!   OpenDiLoCo worker 0 extra:   + 8 B for the WHOLE model (outer opt
//!                                  lives unsharded on the first worker)

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum MemVerdict {
    Fits,
    Oom,
}

#[derive(Clone, Debug)]
pub struct MemoryReport {
    pub per_gpu_bytes: u64,
    pub worst_gpu_bytes: u64,
    pub hbm_bytes: u64,
    pub verdict: MemVerdict,
    pub detail: String,
}

pub const INNER_BYTES_PER_PARAM: f64 = 16.0;
pub const OUTER_BYTES_PER_PARAM: f64 = 8.0;

/// DiLoCoX / pipeline case: every worker holds θ/M params plus its shard
/// of BOTH optimizers (balanced by construction).
pub fn dilocox_memory(params: f64, stages: usize, hbm: u64) -> MemoryReport {
    let per_stage = params / stages as f64;
    let bytes = per_stage * (INNER_BYTES_PER_PARAM + OUTER_BYTES_PER_PARAM);
    let b = bytes as u64;
    MemoryReport {
        per_gpu_bytes: b,
        worst_gpu_bytes: b,
        hbm_bytes: hbm,
        verdict: if b <= hbm { MemVerdict::Fits } else { MemVerdict::Oom },
        detail: format!(
            "stage params {per_stage:.3e}, 24 B/param (dual optimizer, sharded)"
        ),
    }
}

/// OpenDiLoCo case: no model parallelism — every worker holds the WHOLE
/// model + inner optimizer; worker 0 additionally holds the outer state
/// (unbalanced, the §2.2 criticism).
pub fn opendiloco_memory(params: f64, hbm: u64) -> MemoryReport {
    let base = params * INNER_BYTES_PER_PARAM;
    let worker0 = base + params * OUTER_BYTES_PER_PARAM;
    MemoryReport {
        per_gpu_bytes: base as u64,
        worst_gpu_bytes: worker0 as u64,
        hbm_bytes: hbm,
        verdict: if worker0 as u64 <= hbm {
            MemVerdict::Fits
        } else {
            MemVerdict::Oom
        },
        detail: format!(
            "full replica {:.3e} params/GPU; worker0 carries the outer opt",
            params
        ),
    }
}

/// AllReduce / CocktailSGD data-parallel case: full replica + inner
/// optimizer on every GPU (no outer optimizer).
pub fn dp_memory(params: f64, hbm: u64) -> MemoryReport {
    let bytes = (params * INNER_BYTES_PER_PARAM) as u64;
    MemoryReport {
        per_gpu_bytes: bytes,
        worst_gpu_bytes: bytes,
        hbm_bytes: hbm,
        verdict: if bytes <= hbm { MemVerdict::Fits } else { MemVerdict::Oom },
        detail: "full replica, inner optimizer only".into(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    const HBM: u64 = 40_000_000_000;

    #[test]
    fn opendiloco_1_3b_fits_but_107b_ooms() {
        // The paper's §4.2.1 observation.
        assert_eq!(opendiloco_memory(1.3e9, HBM).verdict, MemVerdict::Fits);
        assert_eq!(opendiloco_memory(107e9, HBM).verdict, MemVerdict::Oom);
    }

    #[test]
    fn dilocox_107b_fits_with_80_stages() {
        let r = dilocox_memory(107e9, 80, HBM);
        assert_eq!(r.verdict, MemVerdict::Fits);
        // ~32 GB — tight but under 40 GB, as the paper reports for A800-40G.
        assert!(r.per_gpu_bytes > 30_000_000_000);
        assert!(r.per_gpu_bytes < 40_000_000_000);
    }

    #[test]
    fn dilocox_balance_vs_opendiloco_imbalance() {
        let d = dilocox_memory(1.3e9, 8, HBM);
        assert_eq!(d.per_gpu_bytes, d.worst_gpu_bytes); // balanced
        let o = opendiloco_memory(1.3e9, HBM);
        assert!(o.worst_gpu_bytes > o.per_gpu_bytes); // worker-0 heavy
    }

    #[test]
    fn dp_107b_ooms_too() {
        assert_eq!(dp_memory(107e9, HBM).verdict, MemVerdict::Oom);
        assert_eq!(dp_memory(1.3e9, HBM).verdict, MemVerdict::Fits);
    }
}
