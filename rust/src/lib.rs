//! # DiLoCoX — low-communication decentralized training (reproduction)
//!
//! Rust + JAX + Pallas three-layer reproduction of *"DiLoCoX: A
//! Low-Communication Large-Scale Training Framework for Decentralized
//! Cluster"* (Qi et al., 2025).  See DESIGN.md for the system inventory and
//! EXPERIMENTS.md for paper-vs-measured results.
//!
//! Layer map:
//! * L3 (this crate): coordinator, trainers, collectives, compression,
//!   optimizers, pipeline schedules + the stage-parallel 1F1B executor,
//!   DES throughput simulator.
//! * L3 rounds: the single outer-round engine ([`rounds::RoundEngine`])
//!   owning Algorithm 2's delta/error-feedback/outer-step/overlap
//!   ordering, plus the AllReduce-compatible wire compressor, the
//!   comm-thread overlap lane (reseedable across membership epochs), and
//!   the ONE epoch-aware worker round loop ([`rounds::driver`]) — the
//!   drain-or-discard recovery of in-flight overlapped reductions lives
//!   there.  Consumed by [`train`], [`coordinator`],
//!   [`transport::elastic`], and [`pipeline::exec`] — the ordering and
//!   the round loop exist in exactly one place.
//! * L3 pipeline: 1F1B/GPipe schedules as per-stage op streams with one
//!   dependency oracle ([`pipeline::execute_streams`]) shared by the
//!   validator and the DES, and the real stage-parallel executor
//!   ([`pipeline::exec`]): one thread per stage per cluster, activations
//!   and grad-activations over channels, per-stage dual optimizers,
//!   per-stage DP rings (the §2.2 PP + Dual Optimizer Policy executed,
//!   not simulated).
//! * L3 transport: the collective wire behind the
//!   [`transport::RingTransport`] trait — `local` (in-memory mpsc ring,
//!   worker threads), `tcp` (length-delimited frames over loopback TCP,
//!   one `dilocox worker` OS process per cluster — or per (cluster,
//!   stage) with `pp > 1`, where the 1F1B dataflow crosses processes as
//!   Acts/Grads frames over [`transport::tcp::TcpStageLink`] and each
//!   stage joins its own cross-cluster DP ring — spawned and supervised
//!   by the elastic coordinator with 2PC membership epochs and ring
//!   recovery), and `faulty` (deterministic seeded delay/straggler/kill
//!   injection wrapping either wire).  See [`transport`] for the frame
//!   format and the membership epoch protocol, and README.md / CONFIG.md
//!   for the operator-facing documentation.
//! * L3 protocol: the elastic membership protocol as pure, I/O-free
//!   state machines ([`protocol::CoordinatorSm`], [`protocol::WorkerSm`])
//!   — 2PC epoch formation, membership pruning, the drain-or-discard
//!   ruling, and fleet completion, consumed by the
//!   [`transport::elastic`] shell over real sockets and by the
//!   deterministic simulator ([`protocol::sim`]): a virtual-time
//!   harness with a seeded fuzzer, minimized repros, and a bounded
//!   exhaustive interleaving explorer asserting the safety and
//!   liveness invariants (`protocol-verify` in CI).
//! * L3 observability: always-compiled structured tracing ([`obs`]) —
//!   RAII spans with self-carried (cluster, stage, epoch, round)
//!   attribution recorded on every hot-path layer, shipped to the
//!   elastic coordinator as `TraceEvents` control frames, and merged
//!   into a per-round accounting table plus a Chrome-trace export
//!   ([`obs::report`], `coordinate --trace`).  Disabled it is one
//!   relaxed atomic load per span; enabled it never touches the wire
//!   ledger or the data plane, so traced runs stay bit-for-bit
//!   identical to untraced ones.
//! * L2/L1 (python/, build-time only): jax stage programs + pallas kernels,
//!   AOT-lowered to `artifacts/<preset>/*.hlo.txt` consumed by [`runtime`]
//!   — monolithic `step_single`/`eval_single` plus the per-stage
//!   `fwd_*`/`bwd_*` programs the stage executor drives.

pub mod comm;
pub mod compress;
pub mod config;
pub mod coordinator;
pub mod data;
pub mod linalg;
pub mod metrics;
pub mod model;
pub mod netsim;
pub mod obs;
pub mod optim;
pub mod pipeline;
pub mod protocol;
pub mod report;
pub mod rounds;
pub mod runtime;
pub mod sim;
pub mod train;
pub mod transport;
pub mod util;
