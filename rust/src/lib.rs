//! # DiLoCoX — low-communication decentralized training (reproduction)
//!
//! Rust + JAX + Pallas three-layer reproduction of *"DiLoCoX: A
//! Low-Communication Large-Scale Training Framework for Decentralized
//! Cluster"* (Qi et al., 2025).  See DESIGN.md for the system inventory and
//! EXPERIMENTS.md for paper-vs-measured results.
//!
//! Layer map:
//! * L3 (this crate): coordinator, trainers, collectives, compression,
//!   optimizers, pipeline schedules, DES throughput simulator.
//! * L3 transport: the collective wire behind the
//!   [`transport::RingTransport`] trait — `local` (in-memory mpsc ring,
//!   worker threads), `tcp` (length-delimited frames over loopback TCP,
//!   one `dilocox worker` OS process per cluster, spawned and supervised
//!   by the elastic coordinator with 2PC membership epochs and ring
//!   recovery), and `faulty` (deterministic seeded delay/straggler/kill
//!   injection wrapping either wire).  See [`transport`] for the frame
//!   format and the membership epoch protocol.
//! * L2/L1 (python/, build-time only): jax stage programs + pallas kernels,
//!   AOT-lowered to `artifacts/<preset>/*.hlo.txt` consumed by [`runtime`].

pub mod comm;
pub mod compress;
pub mod config;
pub mod coordinator;
pub mod data;
pub mod linalg;
pub mod metrics;
pub mod model;
pub mod netsim;
pub mod optim;
pub mod pipeline;
pub mod report;
pub mod runtime;
pub mod sim;
pub mod train;
pub mod transport;
pub mod util;
