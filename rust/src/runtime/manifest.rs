//! Typed view over `artifacts/<preset>/manifest.json` (written by
//! python/compile/aot.py).  The manifest is the single source of truth for
//! program signatures, flat parameter layouts, and initialization files —
//! rust never re-derives shapes.

use crate::util::json::Json;
use anyhow::{anyhow, Context, Result};
use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

#[derive(Clone, Debug, PartialEq, Eq)]
pub enum DType {
    F32,
    I32,
}

impl DType {
    fn parse(s: &str) -> Result<DType> {
        match s {
            "float32" => Ok(DType::F32),
            "int32" => Ok(DType::I32),
            other => Err(anyhow!("unsupported dtype {other}")),
        }
    }

    pub fn bytes(&self) -> usize {
        4
    }
}

#[derive(Clone, Debug)]
pub struct TensorSig {
    pub dtype: DType,
    pub shape: Vec<usize>,
}

impl TensorSig {
    pub fn numel(&self) -> usize {
        self.shape.iter().product()
    }
}

#[derive(Clone, Debug)]
pub struct ProgramSig {
    pub name: String,
    pub file: String,
    pub inputs: Vec<TensorSig>,
    pub outputs: Vec<TensorSig>,
}

#[derive(Clone, Debug)]
pub struct ParamEntry {
    pub name: String,
    pub shape: Vec<usize>,
    pub offset: usize,
}

impl ParamEntry {
    pub fn numel(&self) -> usize {
        self.shape.iter().product()
    }
}

#[derive(Clone, Debug)]
pub struct ModelDims {
    pub vocab_size: usize,
    pub d_model: usize,
    pub n_heads: usize,
    pub n_layers: usize,
    pub seq_len: usize,
    pub microbatch: usize,
    pub pp_stages: usize,
    pub layers_per_stage: usize,
    pub d_ff: usize,
}

#[derive(Clone, Debug)]
pub struct StageInit {
    pub kind: String,
    pub file: String,
}

#[derive(Clone, Debug)]
pub struct Manifest {
    pub root: PathBuf,
    pub preset: String,
    pub use_pallas: bool,
    pub param_count: usize,
    pub dims: ModelDims,
    pub programs: BTreeMap<String, ProgramSig>,
    pub param_specs: BTreeMap<String, Vec<ParamEntry>>,
    pub stage_numel: BTreeMap<String, usize>,
    pub init: BTreeMap<String, StageInit>,
    pub goldens: BTreeMap<String, (Vec<String>, Vec<String>)>,
}

impl Manifest {
    pub fn load(dir: impl AsRef<Path>) -> Result<Manifest> {
        let root = dir.as_ref().to_path_buf();
        let path = root.join("manifest.json");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {}", path.display()))?;
        let v = Json::parse(&text)
            .map_err(|e| anyhow!("parsing {}: {e}", path.display()))?;
        Self::from_json(root, &v)
    }

    pub fn from_json(root: PathBuf, v: &Json) -> Result<Manifest> {
        let need = |p: &str| {
            v.path(p).ok_or_else(|| anyhow!("manifest missing '{p}'"))
        };
        if need("format")?.as_str() != Some("hlo-text-v1") {
            return Err(anyhow!("unsupported artifact format"));
        }
        let dims_j = need("config")?;
        let d = |k: &str| -> Result<usize> {
            dims_j
                .get(k)
                .and_then(|x| x.as_usize())
                .ok_or_else(|| anyhow!("config missing '{k}'"))
        };
        let dims = ModelDims {
            vocab_size: d("vocab_size")?,
            d_model: d("d_model")?,
            n_heads: d("n_heads")?,
            n_layers: d("n_layers")?,
            seq_len: d("seq_len")?,
            microbatch: d("microbatch")?,
            pp_stages: d("pp_stages")?,
            layers_per_stage: d("layers_per_stage")?,
            d_ff: d("d_ff")?,
        };

        let mut programs = BTreeMap::new();
        for (name, pj) in need("programs")?
            .as_obj()
            .ok_or_else(|| anyhow!("programs not an object"))?
        {
            let sig = |key: &str| -> Result<Vec<TensorSig>> {
                pj.get(key)
                    .and_then(|x| x.as_arr())
                    .ok_or_else(|| anyhow!("program {name} missing {key}"))?
                    .iter()
                    .map(|t| {
                        Ok(TensorSig {
                            dtype: DType::parse(
                                t.get("dtype")
                                    .and_then(|x| x.as_str())
                                    .unwrap_or(""),
                            )?,
                            shape: t
                                .get("shape")
                                .and_then(|x| x.as_arr())
                                .ok_or_else(|| anyhow!("bad shape"))?
                                .iter()
                                .map(|s| {
                                    s.as_usize()
                                        .ok_or_else(|| anyhow!("bad dim"))
                                })
                                .collect::<Result<Vec<_>>>()?,
                        })
                    })
                    .collect()
            };
            programs.insert(
                name.clone(),
                ProgramSig {
                    name: name.clone(),
                    file: pj
                        .get("file")
                        .and_then(|x| x.as_str())
                        .ok_or_else(|| anyhow!("program {name} missing file"))?
                        .to_string(),
                    inputs: sig("inputs")?,
                    outputs: sig("outputs")?,
                },
            );
        }

        let mut param_specs = BTreeMap::new();
        for (kind, arr) in need("param_specs")?
            .as_obj()
            .ok_or_else(|| anyhow!("param_specs not an object"))?
        {
            let entries = arr
                .as_arr()
                .ok_or_else(|| anyhow!("param spec not an array"))?
                .iter()
                .map(|e| {
                    Ok(ParamEntry {
                        name: e
                            .get("name")
                            .and_then(|x| x.as_str())
                            .ok_or_else(|| anyhow!("param missing name"))?
                            .to_string(),
                        shape: e
                            .get("shape")
                            .and_then(|x| x.as_arr())
                            .ok_or_else(|| anyhow!("param missing shape"))?
                            .iter()
                            .map(|s| {
                                s.as_usize().ok_or_else(|| anyhow!("bad dim"))
                            })
                            .collect::<Result<Vec<_>>>()?,
                        offset: e
                            .get("offset")
                            .and_then(|x| x.as_usize())
                            .ok_or_else(|| anyhow!("param missing offset"))?,
                    })
                })
                .collect::<Result<Vec<_>>>()?;
            param_specs.insert(kind.clone(), entries);
        }

        let stage_numel = need("stage_numel")?
            .as_obj()
            .ok_or_else(|| anyhow!("stage_numel not an object"))?
            .iter()
            .map(|(k, x)| (k.clone(), x.as_usize().unwrap_or(0)))
            .collect();

        let mut init = BTreeMap::new();
        for (key, e) in need("init")?
            .as_obj()
            .ok_or_else(|| anyhow!("init not an object"))?
        {
            init.insert(
                key.clone(),
                StageInit {
                    kind: e
                        .get("kind")
                        .and_then(|x| x.as_str())
                        .unwrap_or("")
                        .to_string(),
                    file: e
                        .get("file")
                        .and_then(|x| x.as_str())
                        .unwrap_or("")
                        .to_string(),
                },
            );
        }

        let mut goldens = BTreeMap::new();
        if let Some(g) = v.get("goldens").and_then(|x| x.as_obj()) {
            for (name, e) in g {
                let files = |key: &str| -> Vec<String> {
                    e.get(key)
                        .and_then(|x| x.as_arr())
                        .map(|a| {
                            a.iter()
                                .filter_map(|s| s.as_str())
                                .map(|s| s.to_string())
                                .collect()
                        })
                        .unwrap_or_default()
                };
                goldens.insert(
                    name.clone(),
                    (files("inputs"), files("outputs")),
                );
            }
        }

        Ok(Manifest {
            root,
            preset: need("preset")?
                .as_str()
                .ok_or_else(|| anyhow!("preset not a string"))?
                .to_string(),
            use_pallas: v
                .get("use_pallas")
                .and_then(|x| x.as_bool())
                .unwrap_or(false),
            param_count: need("param_count")?
                .as_usize()
                .ok_or_else(|| anyhow!("bad param_count"))?,
            dims,
            programs,
            param_specs,
            stage_numel,
            init,
            goldens,
        })
    }

    pub fn program(&self, name: &str) -> Result<&ProgramSig> {
        self.programs
            .get(name)
            .ok_or_else(|| anyhow!("artifact bundle has no program '{name}'"))
    }

    /// Stage kinds in pipeline order for the exported pp degree.
    pub fn stage_kinds(&self) -> Vec<&'static str> {
        let m = self.dims.pp_stages;
        if m <= 1 {
            return vec!["single"];
        }
        let mut kinds = vec!["first"];
        for _ in 0..m.saturating_sub(2) {
            kinds.push("mid");
        }
        kinds.push("last");
        kinds
    }

    /// Load a little-endian f32 .bin artifact (init params, goldens).
    pub fn read_f32(&self, rel: &str) -> Result<Vec<f32>> {
        let path = self.root.join(rel);
        let bytes = std::fs::read(&path)
            .with_context(|| format!("reading {}", path.display()))?;
        if bytes.len() % 4 != 0 {
            return Err(anyhow!("{rel}: length not a multiple of 4"));
        }
        Ok(bytes
            .chunks_exact(4)
            .map(|b| f32::from_le_bytes([b[0], b[1], b[2], b[3]]))
            .collect())
    }

    pub fn read_i32(&self, rel: &str) -> Result<Vec<i32>> {
        let path = self.root.join(rel);
        let bytes = std::fs::read(&path)
            .with_context(|| format!("reading {}", path.display()))?;
        Ok(bytes
            .chunks_exact(4)
            .map(|b| i32::from_le_bytes([b[0], b[1], b[2], b[3]]))
            .collect())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny_dir() -> PathBuf {
        PathBuf::from(concat!(env!("CARGO_MANIFEST_DIR"), "/artifacts/tiny"))
    }

    #[test]
    fn loads_tiny_manifest() {
        if !tiny_dir().exists() {
            return; // artifacts not built in this checkout
        }
        let m = Manifest::load(tiny_dir()).unwrap();
        assert_eq!(m.preset, "tiny");
        assert_eq!(m.dims.d_model, 64);
        assert_eq!(m.stage_kinds(), vec!["first", "mid", "mid", "last"]);
        let prog = m.program("step_single").unwrap();
        assert_eq!(prog.inputs.len(), 3);
        assert_eq!(prog.inputs[0].dtype, DType::F32);
        assert_eq!(prog.inputs[1].dtype, DType::I32);
        assert_eq!(prog.inputs[0].numel(), m.param_count);
        // single spec covers param_count contiguously
        let spec = &m.param_specs["single"];
        let last = spec.last().unwrap();
        assert_eq!(last.offset + last.numel(), m.param_count);
    }

    #[test]
    fn init_bins_match_numel() {
        if !tiny_dir().exists() {
            return;
        }
        let m = Manifest::load(tiny_dir()).unwrap();
        for (key, init) in &m.init {
            let data = m.read_f32(&init.file).unwrap();
            assert_eq!(data.len(), m.stage_numel[&init.kind], "{key}");
        }
    }

    #[test]
    fn missing_program_is_error() {
        if !tiny_dir().exists() {
            return;
        }
        let m = Manifest::load(tiny_dir()).unwrap();
        assert!(m.program("nope").is_err());
    }
}
