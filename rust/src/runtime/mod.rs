//! PJRT runtime: load AOT artifacts (HLO text) and execute them on the CPU
//! PJRT client.  Pattern follows /opt/xla-example/load_hlo: text -> proto ->
//! XlaComputation -> compile -> execute; HLO *text* is the interchange
//! format because xla_extension 0.5.1 rejects jax>=0.5's 64-bit-id protos.
//!
//! A `Runtime` is intentionally **not** Send: the xla wrappers hold raw
//! pointers.  Each replica worker thread builds its own `Runtime` over the
//! same artifact directory (XLA compilation is per-thread, execution is
//! the hot path).

pub mod manifest;

pub use manifest::{DType, Manifest, ProgramSig, TensorSig};

use anyhow::{anyhow, Context, Result};
use std::cell::RefCell;
use std::collections::HashMap;
use std::path::Path;
use std::time::Instant;

/// Host-side tensor (everything the coordinator touches is f32 or i32).
#[derive(Clone, Debug)]
pub enum HostTensor {
    F32(Vec<f32>),
    I32(Vec<i32>),
}

impl HostTensor {
    pub fn as_f32(&self) -> Result<&[f32]> {
        match self {
            HostTensor::F32(v) => Ok(v),
            _ => Err(anyhow!("expected f32 tensor")),
        }
    }

    pub fn into_f32(self) -> Result<Vec<f32>> {
        match self {
            HostTensor::F32(v) => Ok(v),
            _ => Err(anyhow!("expected f32 tensor")),
        }
    }

    pub fn scalar_f32(&self) -> Result<f32> {
        match self {
            HostTensor::F32(v) if v.len() == 1 => Ok(v[0]),
            _ => Err(anyhow!("expected f32 scalar")),
        }
    }
}

/// Borrowed-slice argument for the zero-copy hot path ([`Runtime::exec_ref`]).
#[derive(Clone, Copy, Debug)]
pub enum HostArg<'a> {
    F32(&'a [f32]),
    I32(&'a [i32]),
}

/// Cumulative execution statistics (perf pass instrumentation).
#[derive(Clone, Debug, Default)]
pub struct RuntimeStats {
    pub executions: u64,
    pub exec_seconds: f64,
    pub compile_seconds: f64,
    pub per_program: HashMap<String, (u64, f64)>,
}

pub struct Runtime {
    pub manifest: Manifest,
    client: xla::PjRtClient,
    exes: RefCell<HashMap<String, xla::PjRtLoadedExecutable>>,
    stats: RefCell<RuntimeStats>,
}

impl Runtime {
    pub fn load(dir: impl AsRef<Path>) -> Result<Runtime> {
        let manifest = Manifest::load(&dir)?;
        let client = xla::PjRtClient::cpu()
            .map_err(|e| anyhow!("creating PJRT CPU client: {e:?}"))?;
        Ok(Runtime {
            manifest,
            client,
            exes: RefCell::new(HashMap::new()),
            stats: RefCell::new(RuntimeStats::default()),
        })
    }

    /// Compile (and cache) one program from HLO text.
    pub fn ensure_compiled(&self, name: &str) -> Result<()> {
        if self.exes.borrow().contains_key(name) {
            return Ok(());
        }
        let prog = self.manifest.program(name)?;
        let path = self.manifest.root.join(&prog.file);
        let t0 = Instant::now();
        let proto = xla::HloModuleProto::from_text_file(&path)
            .map_err(|e| anyhow!("parsing {}: {e:?}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .map_err(|e| anyhow!("compiling {name}: {e:?}"))?;
        self.stats.borrow_mut().compile_seconds += t0.elapsed().as_secs_f64();
        self.exes.borrow_mut().insert(name.to_string(), exe);
        Ok(())
    }

    pub fn precompile(&self, names: &[&str]) -> Result<()> {
        for n in names {
            self.ensure_compiled(n)
                .with_context(|| format!("precompiling {n}"))?;
        }
        Ok(())
    }

    /// Execute on borrowed slices — the hot-path entry point (§Perf):
    /// avoids the intermediate `Vec` copy of [`exec`]'s owned arguments
    /// (at the 110M-param scale that copy is 440 MB per call).
    pub fn exec_ref(&self, name: &str, inputs: &[HostArg<'_>]) -> Result<Vec<HostTensor>> {
        self.ensure_compiled(name)?;
        let prog = self.manifest.program(name)?.clone();
        if inputs.len() != prog.inputs.len() {
            return Err(anyhow!(
                "{name}: expected {} inputs, got {}",
                prog.inputs.len(),
                inputs.len()
            ));
        }
        let mut lits = Vec::with_capacity(inputs.len());
        for (i, (t, sig)) in inputs.iter().zip(&prog.inputs).enumerate() {
            lits.push(self.arg_to_literal(t, sig).with_context(|| {
                format!("{name}: input {i} ({:?})", sig.shape)
            })?);
        }
        self.run_compiled(name, &prog, lits)
    }

    fn arg_to_literal(&self, t: &HostArg<'_>, sig: &TensorSig) -> Result<xla::Literal> {
        let dims: Vec<i64> = sig.shape.iter().map(|&d| d as i64).collect();
        let reshape = |lit: xla::Literal| -> Result<xla::Literal> {
            lit.reshape(&dims).map_err(|e| anyhow!("{e:?}"))
        };
        match (t, &sig.dtype) {
            (HostArg::F32(v), DType::F32) => {
                if v.len() != sig.numel() {
                    return Err(anyhow!("size mismatch: {} vs {:?}", v.len(), sig.shape));
                }
                reshape(xla::Literal::vec1(v))
            }
            (HostArg::I32(v), DType::I32) => {
                if v.len() != sig.numel() {
                    return Err(anyhow!("size mismatch: {} vs {:?}", v.len(), sig.shape));
                }
                reshape(xla::Literal::vec1(v))
            }
            _ => Err(anyhow!("dtype mismatch")),
        }
    }

    fn run_compiled(
        &self,
        name: &str,
        prog: &ProgramSig,
        lits: Vec<xla::Literal>,
    ) -> Result<Vec<HostTensor>> {
        let t0 = Instant::now();
        let exes = self.exes.borrow();
        let exe = exes.get(name).unwrap();
        let result = exe
            .execute::<xla::Literal>(&lits)
            .map_err(|e| anyhow!("executing {name}: {e:?}"))?;
        let tuple = result[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow!("fetching {name} result: {e:?}"))?;
        let parts = tuple
            .to_tuple()
            .map_err(|e| anyhow!("untupling {name} result: {e:?}"))?;
        drop(exes);
        let dt = t0.elapsed().as_secs_f64();
        {
            let mut st = self.stats.borrow_mut();
            st.executions += 1;
            st.exec_seconds += dt;
            let e = st.per_program.entry(name.to_string()).or_insert((0, 0.0));
            e.0 += 1;
            e.1 += dt;
        }
        if parts.len() != prog.outputs.len() {
            return Err(anyhow!(
                "{name}: expected {} outputs, got {}",
                prog.outputs.len(),
                parts.len()
            ));
        }
        parts
            .into_iter()
            .zip(&prog.outputs)
            .map(|(lit, sig)| self.from_literal(lit, sig))
            .collect()
    }

    /// Execute a program on host tensors, validating the signature.
    pub fn exec(&self, name: &str, inputs: &[HostTensor]) -> Result<Vec<HostTensor>> {
        self.ensure_compiled(name)?;
        let prog = self.manifest.program(name)?.clone();
        if inputs.len() != prog.inputs.len() {
            return Err(anyhow!(
                "{name}: expected {} inputs, got {}",
                prog.inputs.len(),
                inputs.len()
            ));
        }
        let mut lits = Vec::with_capacity(inputs.len());
        for (i, (t, sig)) in inputs.iter().zip(&prog.inputs).enumerate() {
            lits.push(self.to_literal(t, sig).with_context(|| {
                format!("{name}: input {i} ({:?})", sig.shape)
            })?);
        }
        self.run_compiled(name, &prog, lits)
    }

    fn to_literal(&self, t: &HostTensor, sig: &TensorSig) -> Result<xla::Literal> {
        let dims: Vec<i64> = sig.shape.iter().map(|&d| d as i64).collect();
        match (t, &sig.dtype) {
            (HostTensor::F32(v), DType::F32) => {
                if v.len() != sig.numel() {
                    return Err(anyhow!(
                        "size mismatch: {} vs {:?}",
                        v.len(),
                        sig.shape
                    ));
                }
                let lit = xla::Literal::vec1(v);
                if dims.is_empty() {
                    // rank-0 scalar
                    Ok(lit.reshape(&[]).map_err(|e| anyhow!("{e:?}"))?)
                } else {
                    Ok(lit.reshape(&dims).map_err(|e| anyhow!("{e:?}"))?)
                }
            }
            (HostTensor::I32(v), DType::I32) => {
                if v.len() != sig.numel() {
                    return Err(anyhow!(
                        "size mismatch: {} vs {:?}",
                        v.len(),
                        sig.shape
                    ));
                }
                let lit = xla::Literal::vec1(v);
                if dims.is_empty() {
                    Ok(lit.reshape(&[]).map_err(|e| anyhow!("{e:?}"))?)
                } else {
                    Ok(lit.reshape(&dims).map_err(|e| anyhow!("{e:?}"))?)
                }
            }
            _ => Err(anyhow!("dtype mismatch")),
        }
    }

    fn from_literal(&self, lit: xla::Literal, sig: &TensorSig) -> Result<HostTensor> {
        match sig.dtype {
            DType::F32 => Ok(HostTensor::F32(
                lit.to_vec::<f32>().map_err(|e| anyhow!("{e:?}"))?,
            )),
            DType::I32 => Ok(HostTensor::I32(
                lit.to_vec::<i32>().map_err(|e| anyhow!("{e:?}"))?,
            )),
        }
    }

    pub fn stats(&self) -> RuntimeStats {
        self.stats.borrow().clone()
    }

    // -- convenience wrappers used by trainers ------------------------------

    /// (loss, grads) = step_single(params, tokens, labels)
    pub fn step_single(
        &self,
        params: &[f32],
        tokens: &[i32],
        labels: &[i32],
    ) -> Result<(f32, Vec<f32>)> {
        let mut out = self.exec_ref(
            "step_single",
            &[
                HostArg::F32(params),
                HostArg::I32(tokens),
                HostArg::I32(labels),
            ],
        )?;
        let loss = out[0].scalar_f32()?;
        let grads = out.remove(1).into_f32()?;
        Ok((loss, grads))
    }

    pub fn eval_single(
        &self,
        params: &[f32],
        tokens: &[i32],
        labels: &[i32],
    ) -> Result<f32> {
        let out = self.exec_ref(
            "eval_single",
            &[
                HostArg::F32(params),
                HostArg::I32(tokens),
                HostArg::I32(labels),
            ],
        )?;
        out[0].scalar_f32()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny() -> Option<Runtime> {
        let dir = concat!(env!("CARGO_MANIFEST_DIR"), "/artifacts/tiny");
        if std::path::Path::new(dir).exists() {
            Some(Runtime::load(dir).unwrap())
        } else {
            None
        }
    }

    #[test]
    fn rejects_wrong_arity_and_shape() {
        let Some(rt) = tiny() else { return };
        assert!(rt.exec("step_single", &[]).is_err());
        let bad = vec![
            HostTensor::F32(vec![0.0; 3]), // wrong param size
            HostTensor::I32(vec![0; 64]),
            HostTensor::I32(vec![0; 64]),
        ];
        assert!(rt.exec("step_single", &bad).is_err());
    }

    #[test]
    fn stats_accumulate() {
        let Some(rt) = tiny() else { return };
        let man = &rt.manifest;
        let params = man.read_f32(&man.init["single"].file).unwrap();
        let n_tok = man.dims.microbatch * man.dims.seq_len;
        let tokens = vec![1i32; n_tok];
        let labels = vec![2i32; n_tok];
        let (loss, grads) = rt.step_single(&params, &tokens, &labels).unwrap();
        assert!(loss.is_finite());
        assert_eq!(grads.len(), man.param_count);
        let st = rt.stats();
        assert_eq!(st.executions, 1);
        assert!(st.compile_seconds > 0.0);
        assert!(st.per_program.contains_key("step_single"));
    }
}
