//! Adaptive Gradient Compression (paper Algorithm 3).
//!
//! After each outer AllReduce the controller estimates the effective rank
//! r'_t of the globally averaged pseudo-gradient, keeps a window of the
//! last c estimates, and emits
//!
//!   r_t = mean(window),   α = (r₁ − r_t)/r₁,   H_t = H₁ · α
//!
//! exactly as written in the paper, with two practical floors the paper
//! leaves implicit: r_t ≥ min_rank and H_t ≥ 1 (α = 0 in the warm-up
//! window keeps H = H₁).  The paper does not specify the rank estimator;
//! we use the *stable rank* ‖M‖²_F / σ²_max (σ_max via power iteration),
//! averaged over the 2-D parameter matrices weighted by element count —
//! documented in DESIGN.md as a substitution.

use crate::linalg::Mat;
use crate::runtime::manifest::ParamEntry;
use std::collections::VecDeque;

#[derive(Debug)]
pub struct AdaptiveCompression {
    /// r₁ — initial rank.
    pub r1: usize,
    /// H₁ — initial local steps.
    pub h1: usize,
    /// c — gradient-rank window.
    pub c: usize,
    pub min_rank: usize,
    window: VecDeque<f64>,
    t: usize,
    last_rank: usize,
    last_h: usize,
}

impl AdaptiveCompression {
    pub fn new(r1: usize, h1: usize, c: usize, min_rank: usize) -> Self {
        AdaptiveCompression {
            r1,
            h1,
            c: c.max(1),
            min_rank: min_rank.max(1),
            window: VecDeque::new(),
            t: 0,
            last_rank: r1,
            last_h: h1,
        }
    }

    pub fn current(&self) -> (usize, usize) {
        (self.last_rank, self.last_h)
    }

    /// Feed the globally averaged pseudo-gradient after an outer step;
    /// returns (r_{t+1}, H_{t+1}).
    pub fn observe(&mut self, avg: &[f32], spec: &[ParamEntry]) -> (usize, usize) {
        let r_prime = effective_rank_estimate(avg, spec)
            .clamp(self.min_rank as f64, self.r1 as f64);
        self.window.push_back(r_prime);
        while self.window.len() > self.c {
            self.window.pop_front();
        }
        self.t += 1;

        let (rank, h) = if self.t < self.c {
            // Warm-up: r_t = r₁, α = 1 (paper), H = H₁.
            (self.r1, self.h1)
        } else {
            let r_t = self.window.iter().sum::<f64>() / self.window.len() as f64;
            let alpha = ((self.r1 as f64 - r_t) / self.r1 as f64).max(0.0);
            let rank = (r_t.round() as usize)
                .clamp(self.min_rank, self.r1);
            let h = if alpha <= 0.0 {
                self.h1
            } else {
                ((self.h1 as f64 * alpha).round() as usize).max(1)
            };
            (rank, h)
        };
        self.last_rank = rank;
        self.last_h = h;
        (rank, h)
    }
}

/// Stable-rank estimate of the averaged pseudo-gradient: element-weighted
/// mean over the 2-D matrices of ‖M‖²_F / σ²_max.
pub fn effective_rank_estimate(avg: &[f32], spec: &[ParamEntry]) -> f64 {
    let mut num = 0.0f64;
    let mut den = 0.0f64;
    for e in spec {
        if e.shape.len() != 2 {
            continue;
        }
        let m = Mat::from_slice(e.shape[0], e.shape[1], &avg[e.offset..e.offset + e.numel()]);
        let sr = stable_rank(&m);
        let w = e.numel() as f64;
        num += sr * w;
        den += w;
    }
    if den == 0.0 {
        1.0
    } else {
        num / den
    }
}

/// ‖M‖²_F / σ²_max with σ_max from a few power iterations on MᵀM.
pub fn stable_rank(m: &Mat) -> f64 {
    let fro2: f64 = m.data.iter().map(|&x| (x as f64).powi(2)).sum();
    if fro2 == 0.0 {
        return 0.0;
    }
    // Power iteration: v <- normalize(Mᵀ (M v)).
    let mut v = vec![1.0f32; m.cols];
    let mut sigma2 = 0.0f64;
    for _ in 0..12 {
        // u = M v
        let mut u = vec![0.0f32; m.rows];
        for i in 0..m.rows {
            let row = &m.data[i * m.cols..(i + 1) * m.cols];
            u[i] = crate::linalg::dot(row, &v);
        }
        // w = Mᵀ u
        let mut w = vec![0.0f32; m.cols];
        for i in 0..m.rows {
            let row = &m.data[i * m.cols..(i + 1) * m.cols];
            let ui = u[i];
            for (wj, &rj) in w.iter_mut().zip(row) {
                *wj += ui * rj;
            }
        }
        let norm = crate::util::l2(&w);
        if norm < 1e-30 {
            return 1.0;
        }
        sigma2 = norm; // ||M^T M v|| -> sigma^2 as v converges
        let inv = (1.0 / norm) as f32;
        for (vi, &wi) in v.iter_mut().zip(&w) {
            *vi = wi * inv;
        }
    }
    (fro2 / sigma2).max(1.0)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg32;

    fn mat_spec(rows: usize, cols: usize) -> Vec<ParamEntry> {
        vec![ParamEntry { name: "w".into(), shape: vec![rows, cols], offset: 0 }]
    }

    #[test]
    fn stable_rank_of_rank1_is_1() {
        let mut m = Mat::zeros(20, 30);
        for i in 0..20 {
            for j in 0..30 {
                m.data[i * 30 + j] = (i as f32 + 1.0) * (j as f32 + 1.0);
            }
        }
        let sr = stable_rank(&m);
        assert!((sr - 1.0).abs() < 0.05, "sr={sr}");
    }

    #[test]
    fn stable_rank_of_identity_is_n() {
        let n = 16;
        let mut m = Mat::zeros(n, n);
        for i in 0..n {
            m.data[i * n + i] = 1.0;
        }
        let sr = stable_rank(&m);
        assert!((sr - n as f64).abs() < 0.5, "sr={sr}");
    }

    #[test]
    fn random_matrix_has_high_stable_rank() {
        let mut rng = Pcg32::seed_from(1);
        let mut m = Mat::zeros(64, 64);
        rng.fill_normal(&mut m.data, 0.0, 1.0);
        assert!(stable_rank(&m) > 10.0);
    }

    #[test]
    fn warmup_keeps_initial_settings() {
        let mut ctl = AdaptiveCompression::new(32, 100, 5, 2);
        let mut rng = Pcg32::seed_from(2);
        let mut g = vec![0.0f32; 24 * 24];
        rng.fill_normal(&mut g, 0.0, 1.0);
        let spec = mat_spec(24, 24);
        for _ in 0..4 {
            let (r, h) = ctl.observe(&g, &spec);
            assert_eq!((r, h), (32, 100));
        }
    }

    #[test]
    fn low_rank_gradients_shrink_rank_and_h_follows_alpha() {
        // Rank-1 pseudo-gradients: r' ≈ 1, so after the window fills,
        // r_t ≈ 1 and α ≈ (r1-1)/r1 → H_t ≈ H1·α.
        let (r1, h1, c) = (32usize, 100usize, 3usize);
        let mut ctl = AdaptiveCompression::new(r1, h1, c, 1);
        let rows = 20;
        let cols = 24;
        let mut g = vec![0.0f32; rows * cols];
        for i in 0..rows {
            for j in 0..cols {
                g[i * cols + j] = (i + 1) as f32 * 0.1 * (j + 1) as f32;
            }
        }
        let spec = mat_spec(rows, cols);
        let mut last = (0, 0);
        for _ in 0..c + 2 {
            last = ctl.observe(&g, &spec);
        }
        let (r, h) = last;
        assert!(r <= 2, "rank should collapse, got {r}");
        let alpha = (r1 as f64 - r as f64) / r1 as f64;
        let expect_h = (h1 as f64 * alpha).round() as usize;
        assert!(
            (h as i64 - expect_h as i64).abs() <= 3,
            "h={h} expect≈{expect_h}"
        );
    }

    #[test]
    fn full_rank_gradients_keep_h1() {
        // α clamps to 0 when r_t ≈ r1 → H stays at H1 (documented floor).
        let mut ctl = AdaptiveCompression::new(8, 50, 2, 1);
        let mut rng = Pcg32::seed_from(5);
        let mut g = vec![0.0f32; 40 * 40];
        rng.fill_normal(&mut g, 0.0, 1.0);
        let spec = mat_spec(40, 40);
        let mut last = (0, 0);
        for _ in 0..4 {
            last = ctl.observe(&g, &spec);
        }
        assert_eq!(last.0, 8);
        assert_eq!(last.1, 50);
    }
}
