//! Symmetric uniform q-bit quantization (value semantics identical to the
//! L1 pallas kernel / ref.quantize_dequantize): scale = absmax / (2^(q-1)-1),
//! round, clamp, rescale.  Wire accounting: q bits per element + one f32
//! scale per tensor.

/// Quantize-dequantize in place; returns the scale used.
pub fn quantize_dequantize(x: &mut [f32], q_bits: u32) -> f32 {
    assert!((1..=32).contains(&q_bits), "q_bits must be in 1..=32");
    let levels = ((1u64 << (q_bits - 1)) - 1) as f32;
    if levels == 0.0 {
        // 1-bit: sign * mean(|x|) (standard 1-bit SGD semantics).
        let mean_abs =
            x.iter().map(|v| v.abs() as f64).sum::<f64>() / x.len().max(1) as f64;
        for v in x.iter_mut() {
            *v = if *v >= 0.0 { mean_abs as f32 } else { -(mean_abs as f32) };
        }
        return mean_abs as f32;
    }
    let amax = x.iter().fold(0.0f32, |m, v| m.max(v.abs()));
    if amax == 0.0 {
        return 1.0;
    }
    let scale = amax / levels;
    let inv = 1.0 / scale;
    for v in x.iter_mut() {
        let q = (*v * inv).round().clamp(-levels, levels);
        *v = q * scale;
    }
    scale
}

/// Bytes on the wire for n elements at q bits (+ f32 scale), rounded up.
pub fn wire_bytes(n: usize, q_bits: u32) -> u64 {
    ((n as u64 * q_bits as u64) + 7) / 8 + 4
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::check::props;
    use crate::util::rng::Pcg32;

    #[test]
    fn error_bounded_by_half_step_property() {
        props(21).runs(60).check(|g| {
            let n = g.usize_in(1, 4096);
            let q = *g.pick(&[2u32, 4, 8, 16]);
            let x = g.vec_normal(n, 1.0);
            let mut y = x.clone();
            quantize_dequantize(&mut y, q);
            let levels = ((1u64 << (q - 1)) - 1) as f32;
            let amax = x.iter().fold(0.0f32, |m, v| m.max(v.abs()));
            let half_step = 0.5 * amax / levels;
            for (a, b) in x.iter().zip(&y) {
                if (a - b).abs() > half_step + 1e-6 {
                    return Err(format!("err {} > {half_step}", (a - b).abs()));
                }
            }
            Ok(())
        });
    }

    #[test]
    fn lemma_3_6_omega_bound_on_random_vectors() {
        // Assumption 3.5 / Lemma 3.6: E||C(x)-x||^2 <= omega^2 ||x||^2 with
        // omega^2 = 1 - (r/d) 2^{-q}.  Quantization alone satisfies the
        // far tighter half-step bound; verify the coarse bound holds too.
        // (At q=2 the idealized 2^{-q} factor is violated by ~1% on normal
        // data — the paper's bound is heuristic below q=3; recorded in
        // EXPERIMENTS.md.)
        props(22).runs(40).check(|g| {
            let n = g.usize_in(8, 2048);
            let q = *g.pick(&[3u32, 4, 8]);
            let x = g.vec_normal(n, 1.0);
            let mut y = x.clone();
            quantize_dequantize(&mut y, q);
            let err2: f64 = x
                .iter()
                .zip(&y)
                .map(|(a, b)| ((a - b) as f64).powi(2))
                .sum();
            let norm2: f64 = x.iter().map(|a| (*a as f64).powi(2)).sum();
            let omega2 = 1.0 - 2f64.powi(-(q as i32)); // r = d case
            if err2 <= omega2 * norm2 + 1e-9 {
                Ok(())
            } else {
                Err(format!("err2={err2} > omega2*norm2={}", omega2 * norm2))
            }
        });
    }

    #[test]
    fn zero_and_constant_inputs() {
        let mut z = vec![0.0f32; 16];
        quantize_dequantize(&mut z, 4);
        assert!(z.iter().all(|&v| v == 0.0));
        let mut c = vec![3.0f32; 16];
        quantize_dequantize(&mut c, 4);
        assert!(c.iter().all(|&v| (v - 3.0).abs() < 1e-6));
    }

    #[test]
    fn one_bit_is_scaled_sign() {
        let mut x = vec![2.0f32, -4.0, 6.0, -8.0];
        quantize_dequantize(&mut x, 1);
        assert_eq!(x, vec![5.0, -5.0, 5.0, -5.0]);
    }

    #[test]
    fn idempotent_on_grid() {
        let mut rng = Pcg32::seed_from(1);
        let mut x = vec![0.0f32; 256];
        rng.fill_normal(&mut x, 0.0, 2.0);
        quantize_dequantize(&mut x, 4);
        let once = x.clone();
        quantize_dequantize(&mut x, 4);
        assert_eq!(once, x);
    }

    #[test]
    fn wire_accounting() {
        assert_eq!(wire_bytes(1000, 4), 504);
        assert_eq!(wire_bytes(1000, 16), 2004);
        assert_eq!(wire_bytes(3, 4), 2 + 4);
        // fp32 passthrough is 32 bits
        assert_eq!(wire_bytes(10, 32), 44);
    }
}
