//! Pseudo-gradient compression framework (paper §2.4).
//!
//! A [`GroupReducer`] consumes every DP worker's pseudo-gradient for one
//! outer step and produces the decompressed global average plus the bytes
//! one worker puts on the wire — the quantity the paper's §2.4.1 analysis
//! and the throughput simulator consume.  Error feedback (Algorithm 2's
//! `e_t`) lives in the *trainer*: `e_t = δ_{t-1} − Δ_{t-1}` needs only the
//! reducer's output.

pub mod adaptive;
pub mod lowrank;
pub mod quantize;
pub mod sparsify;

use crate::runtime::manifest::ParamEntry;

/// Compression method, mirroring the paper's design space analysis.
#[derive(Clone, Debug, PartialEq)]
pub enum Method {
    /// fp32 on the wire (AllReduce baseline).
    None,
    /// Quantize-only, q bits (OpenDiLoCo's fp16 wire = Quant{16}).
    Quant { q_bits: u32 },
    /// The paper's Algorithm 1: Low-Rank ∘ Quantize, AllReduce-compatible.
    LowRankQuant { rank: usize, q_bits: u32 },
    /// Top-K (not AllReduce-compatible: parameter-server + double
    /// compression, §2.4.2).
    TopK { ratio: f32, q_bits: u32 },
    /// Random-K with shared seed.
    RandomK { ratio: f32 },
    /// CocktailSGD: random mask → top-k within the mask → quantize.
    Cocktail { random_ratio: f32, topk_ratio: f32, q_bits: u32 },
}

impl Method {
    pub fn allreduce_compatible(&self) -> bool {
        !matches!(self, Method::TopK { .. } | Method::Cocktail { .. })
    }

    pub fn name(&self) -> &'static str {
        match self {
            Method::None => "fp32",
            Method::Quant { .. } => "quantize",
            Method::LowRankQuant { .. } => "lowrank+quant",
            Method::TopK { .. } => "topk",
            Method::RandomK { .. } => "randomk",
            Method::Cocktail { .. } => "cocktail",
        }
    }
}

#[derive(Clone, Debug)]
pub struct ReduceOutcome {
    /// Decompressed global average Δ (same layout as inputs).
    pub avg: Vec<f32>,
    /// Bytes one worker contributes to the wire per outer sync.
    pub payload_bytes: u64,
    /// Achieved compression ratio vs fp32.
    pub ratio: f64,
}

pub struct GroupReducer {
    pub method: Method,
    pub seed: u64,
    lowrank_state: lowrank::LowRankState,
}

impl GroupReducer {
    pub fn new(method: Method, seed: u64) -> Self {
        GroupReducer {
            method,
            seed,
            lowrank_state: lowrank::LowRankState::default(),
        }
    }

    /// Change the low-rank target (adaptive controller, Alg 3).
    pub fn set_rank(&mut self, rank: usize) {
        if let Method::LowRankQuant { rank: r, .. } = &mut self.method {
            *r = rank;
        }
    }

    pub fn reduce(
        &mut self,
        deltas: &[Vec<f32>],
        spec: &[ParamEntry],
        step: u64,
    ) -> ReduceOutcome {
        assert!(!deltas.is_empty());
        let n = deltas[0].len();
        debug_assert!(deltas.iter().all(|d| d.len() == n));
        let full_bytes = 4 * n as u64;
        let d_workers = deltas.len() as f32;

        let (avg, payload_bytes) = match &self.method {
            Method::None => (mean(deltas), full_bytes),
            Method::Quant { q_bits } => {
                // Each worker quantizes its own delta; the averaged result
                // is the mean of the quantized payloads (AllReduce of the
                // dequantized grid values).
                let mut acc = vec![0.0f32; n];
                for d in deltas {
                    let mut q = d.clone();
                    quantize::quantize_dequantize(&mut q, *q_bits);
                    for (a, b) in acc.iter_mut().zip(&q) {
                        *a += b / d_workers;
                    }
                }
                (acc, quantize::wire_bytes(n, *q_bits))
            }
            Method::LowRankQuant { rank, q_bits } => {
                let cfg = lowrank::LowRankConfig {
                    rank: *rank,
                    q_bits: *q_bits,
                    seed: self.seed,
                };
                let out = lowrank::reduce(
                    deltas,
                    spec,
                    &cfg,
                    &mut self.lowrank_state,
                    step,
                );
                (out.avg, out.payload_bytes)
            }
            Method::TopK { ratio, q_bits } => {
                let k = ((n as f64) * *ratio as f64).round().max(1.0) as usize;
                // Up: every worker sends its own top-k (values+indices).
                let mut acc = vec![0.0f32; n];
                for d in deltas {
                    let mut s = d.clone();
                    sparsify::top_k_mask(&mut s, k);
                    if *q_bits > 0 && *q_bits < 32 {
                        quantize::quantize_dequantize(&mut s, *q_bits);
                    }
                    for (a, b) in acc.iter_mut().zip(&s) {
                        *a += b / d_workers;
                    }
                }
                // Down: server re-compresses the aggregate (double
                // compression, §2.4.2) and broadcasts.
                sparsify::top_k_mask(&mut acc, k);
                let vb = if *q_bits > 0 && *q_bits < 32 {
                    (*q_bits as u64 * k as u64 + 7) / 8 + 4
                } else {
                    4 * k as u64
                };
                // index list (u32) + values, up + down legs.
                let payload = 2 * (vb + 4 * k as u64);
                (acc, payload)
            }
            Method::RandomK { ratio } => {
                let mut acc = vec![0.0f32; n];
                for d in deltas {
                    let mut s = d.clone();
                    sparsify::random_k_mask(&mut s, *ratio, self.seed, step);
                    for (a, b) in acc.iter_mut().zip(&s) {
                        *a += b / d_workers;
                    }
                }
                let k = ((n as f64) * *ratio as f64).round() as usize;
                (acc, sparsify::random_k_wire_bytes(k))
            }
            Method::Cocktail { random_ratio, topk_ratio, q_bits } => {
                // CocktailSGD: shared random mask, then per-worker top-k
                // inside the mask, then quantize the surviving values.
                let mut acc = vec![0.0f32; n];
                let k_rand =
                    ((n as f64) * *random_ratio as f64).round() as usize;
                let k_top = ((k_rand as f64) * *topk_ratio as f64)
                    .round()
                    .max(1.0) as usize;
                for d in deltas {
                    let mut s = d.clone();
                    sparsify::random_k_mask(
                        &mut s,
                        *random_ratio,
                        self.seed,
                        step,
                    );
                    sparsify::top_k_mask(&mut s, k_top);
                    if *q_bits > 0 && *q_bits < 32 {
                        quantize::quantize_dequantize(&mut s, *q_bits);
                    }
                    for (a, b) in acc.iter_mut().zip(&s) {
                        *a += b / d_workers;
                    }
                }
                // Wire: per kept element, q-bit value + u32 index within
                // the shared random mask, up+down parameter-server legs,
                // plus the 8-byte mask seed.
                let vb = (*q_bits as u64 * k_top as u64 + 7) / 8 + 4;
                let payload = 2 * (vb + 4 * k_top as u64) + 8;
                (acc, payload)
            }
        };

        ReduceOutcome {
            avg,
            payload_bytes,
            ratio: full_bytes as f64 / payload_bytes.max(1) as f64,
        }
    }
}

fn mean(deltas: &[Vec<f32>]) -> Vec<f32> {
    let n = deltas[0].len();
    let inv = 1.0 / deltas.len() as f32;
    let mut acc = vec![0.0f32; n];
    for d in deltas {
        for (a, b) in acc.iter_mut().zip(d) {
            *a += b * inv;
        }
    }
    acc
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::check::props;

    fn flat_spec(n: usize) -> Vec<ParamEntry> {
        vec![ParamEntry { name: "v".into(), shape: vec![n], offset: 0 }]
    }

    fn mat_spec(rows: usize, cols: usize) -> Vec<ParamEntry> {
        vec![ParamEntry {
            name: "w".into(),
            shape: vec![rows, cols],
            offset: 0,
        }]
    }

    #[test]
    fn none_is_exact_mean() {
        let a = vec![1.0f32, 2.0, 3.0];
        let b = vec![3.0f32, 2.0, 1.0];
        let mut r = GroupReducer::new(Method::None, 0);
        let out = r.reduce(&[a, b], &flat_spec(3), 0);
        assert_eq!(out.avg, vec![2.0, 2.0, 2.0]);
        assert_eq!(out.payload_bytes, 12);
        assert_eq!(out.ratio, 1.0);
    }

    #[test]
    fn compression_error_ordering_matches_paper_analysis() {
        // §2.4: for dense gradients, lowrank+quant (keeping a rank-8
        // sketch) beats cocktail-style 8%-sparse aggregation in l2 error.
        props(51).runs(15).check(|g| {
            let rows = 32;
            let cols = 32;
            let n = rows * cols;
            let deltas = vec![g.vec_normal(n, 1.0), g.vec_normal(n, 1.0)];
            let want = mean(&deltas);

            let mut lr = GroupReducer::new(
                Method::LowRankQuant { rank: 8, q_bits: 4 },
                7,
            );
            let o_lr = lr.reduce(&deltas, &mat_spec(rows, cols), 0);

            let mut ck = GroupReducer::new(
                Method::Cocktail {
                    random_ratio: 0.1,
                    topk_ratio: 0.8,
                    q_bits: 4,
                },
                7,
            );
            let o_ck = ck.reduce(&deltas, &mat_spec(rows, cols), 0);

            let err = |o: &ReduceOutcome| -> f64 {
                o.avg
                    .iter()
                    .zip(&want)
                    .map(|(a, b)| ((a - b) as f64).powi(2))
                    .sum()
            };
            if err(&o_lr) < err(&o_ck) {
                Ok(())
            } else {
                Err(format!("lr={} ck={}", err(&o_lr), err(&o_ck)))
            }
        });
    }

    #[test]
    fn paper_compression_ratios_in_range() {
        // Rank-64 + int4 on a 256x256 slab: factors are 64*(256+256)
        // elements at 4 bits vs 256KiB fp32 → ~16x, matching the paper's
        // "2x low-rank x 8x int4" arithmetic at their shapes.
        let rows = 256;
        let cols = 256;
        let mut r = GroupReducer::new(
            Method::LowRankQuant { rank: 64, q_bits: 4 },
            1,
        );
        let deltas = vec![vec![0.1f32; rows * cols]];
        let out = r.reduce(&deltas, &mat_spec(rows, cols), 0);
        assert!(out.ratio > 14.0 && out.ratio < 18.0, "ratio={}", out.ratio);
    }

    #[test]
    fn quant_reduces_payload_by_bits_ratio() {
        let n = 10_000;
        let mut r4 = GroupReducer::new(Method::Quant { q_bits: 4 }, 0);
        let mut r16 = GroupReducer::new(Method::Quant { q_bits: 16 }, 0);
        let d = vec![vec![0.5f32; n]];
        let spec = flat_spec(n);
        let o4 = r4.reduce(&d, &spec, 0);
        let o16 = r16.reduce(&d, &spec, 0);
        assert!((o4.ratio - 8.0).abs() < 0.1, "{}", o4.ratio);
        assert!((o16.ratio - 2.0).abs() < 0.1, "{}", o16.ratio);
    }

    #[test]
    fn topk_not_allreduce_compatible() {
        assert!(!Method::TopK { ratio: 0.1, q_bits: 4 }.allreduce_compatible());
        assert!(!Method::Cocktail {
            random_ratio: 0.1,
            topk_ratio: 0.1,
            q_bits: 4
        }
        .allreduce_compatible());
        assert!(Method::LowRankQuant { rank: 4, q_bits: 4 }
            .allreduce_compatible());
        assert!(Method::RandomK { ratio: 0.1 }.allreduce_compatible());
    }

    #[test]
    fn randomk_unbiased_in_expectation() {
        // Averaged over many steps (fresh masks), random-k recovers the
        // signal scaled by the keep ratio.
        let n = 512;
        let truth = vec![1.0f32; n];
        let mut r = GroupReducer::new(Method::RandomK { ratio: 0.25 }, 3);
        let spec = flat_spec(n);
        let mut acc = vec![0.0f32; n];
        let trials = 200;
        for t in 0..trials {
            let out = r.reduce(&[truth.clone()], &spec, t);
            for (a, b) in acc.iter_mut().zip(&out.avg) {
                *a += b / trials as f32;
            }
        }
        let m = crate::util::mean(&acc);
        assert!((m - 0.25).abs() < 0.02, "mean={m}");
    }

    #[test]
    fn cocktail_ratio_is_aggressive() {
        // 0.1 random x 0.08 topk x int4 → the paper's "hundreds x" regime.
        let n = 100_000;
        let mut r = GroupReducer::new(
            Method::Cocktail { random_ratio: 0.1, topk_ratio: 0.08, q_bits: 4 },
            0,
        );
        let out = r.reduce(&[vec![0.3f32; n]], &flat_spec(n), 0);
        assert!(out.ratio > 50.0, "ratio={}", out.ratio);
    }

    #[test]
    fn set_rank_updates_lowrank_method() {
        let mut r = GroupReducer::new(
            Method::LowRankQuant { rank: 64, q_bits: 4 },
            0,
        );
        r.set_rank(8);
        assert_eq!(r.method, Method::LowRankQuant { rank: 8, q_bits: 4 });
    }
}
