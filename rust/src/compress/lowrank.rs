//! Low-rank pseudo-gradient compression (paper Algorithm 1, PowerSGD-style,
//! AllReduce-compatible).
//!
//! Per 2-D parameter matrix M (rows x cols), with shared basis Q
//! (cols x r, warm-started across outer steps, identical on every worker):
//!
//!   P_i   = M_i Q            (worker-local, MXU work)
//!   P̄    = mean_i P_i       (AllReduce #1)   ← quantized on the wire
//!   P̂    = orthonormalize(P̄)
//!   Q'_i  = M_iᵀ P̂           (worker-local)
//!   Q̄'   = mean_i Q'_i      (AllReduce #2)   ← quantized on the wire
//!   M̂    = P̂ Q̄'ᵀ           (identical on every worker)
//!
//! 1-D parameters (biases, layernorms) are quantize-only: they are a tiny
//! fraction of the volume and low-rank is meaningless for vectors.

use crate::linalg::{matmul, matmul_at_b, matmul_bt, orthonormalize_columns, Mat};
use crate::runtime::manifest::ParamEntry;
use crate::util::rng::Pcg32;
use std::collections::HashMap;

use super::quantize;

/// Warm-started Q bases keyed by parameter name (one per 2-D entry).
#[derive(Default)]
pub struct LowRankState {
    bases: HashMap<String, Mat>,
}

pub struct LowRankConfig {
    pub rank: usize,
    /// Quantization applied to the P / Q' wire payloads (0 = fp32 wire).
    pub q_bits: u32,
    pub seed: u64,
}

pub struct LowRankOutcome {
    /// Mean decompressed update (same flat layout as the inputs).
    pub avg: Vec<f32>,
    /// Payload bytes one worker puts on the wire per AllReduce round
    /// (both P and Q' passes + the quantize-only 1-D segment).
    pub payload_bytes: u64,
}

/// Effective rank to use for a rows x cols matrix: cannot exceed min dim.
pub fn effective_rank(rank: usize, rows: usize, cols: usize) -> usize {
    rank.max(1).min(rows).min(cols)
}

/// fp32 elements a rank-r factorization puts on the wire for one matrix.
pub fn factor_elems(rows: usize, cols: usize, r: usize) -> usize {
    r * (rows + cols)
}

/// Run the full AllReduce-compatible low-rank + quantize reduction over
/// D workers' flat pseudo-gradients.  `spec` gives the 2-D/1-D split.
pub fn reduce(
    deltas: &[Vec<f32>],
    spec: &[ParamEntry],
    cfg: &LowRankConfig,
    state: &mut LowRankState,
    step: u64,
) -> LowRankOutcome {
    let d_workers = deltas.len();
    assert!(d_workers > 0);
    let n = deltas[0].len();
    let mut avg = vec![0.0f32; n];
    let mut payload_elems_q: usize = 0; // elements that travel quantized
    let mut scales = 0usize; // per-tensor f32 scale overhead count

    for entry in spec {
        let lo = entry.offset;
        let hi = entry.offset + entry.numel();
        if entry.shape.len() == 2 {
            let (rows, cols) = (entry.shape[0], entry.shape[1]);
            let r = effective_rank(cfg.rank, rows, cols);
            // Shared warm-started basis (deterministic seed on first use).
            let q = state.bases.entry(entry.name.clone()).or_insert_with(|| {
                let mut rng =
                    Pcg32::new(cfg.seed ^ hash_name(&entry.name), step);
                let mut m = Mat::zeros(cols, r);
                rng.fill_normal(&mut m.data, 0.0, 1.0);
                m
            });
            if q.cols != r {
                // Adaptive rank changed: re-project the basis.
                let mut rng =
                    Pcg32::new(cfg.seed ^ hash_name(&entry.name), step);
                let mut m = Mat::zeros(cols, r);
                for i in 0..cols {
                    for j in 0..r {
                        m.data[i * r + j] = if j < q.cols {
                            q.data[i * q.cols + j]
                        } else {
                            rng.normal()
                        };
                    }
                }
                *q = m;
            }

            // P_i = M_i Q ; P̄ = mean.
            let mut p_bar = Mat::zeros(rows, r);
            for delta in deltas {
                let m = Mat::from_slice(rows, cols, &delta[lo..hi]);
                let p = matmul(&m, q);
                for (a, b) in p_bar.data.iter_mut().zip(&p.data) {
                    *a += b / d_workers as f32;
                }
            }
            // Wire pass 1: P (rows x r) per worker, quantized.
            payload_elems_q += rows * r;
            scales += 1;
            if cfg.q_bits > 0 && cfg.q_bits < 32 {
                quantize::quantize_dequantize(&mut p_bar.data, cfg.q_bits);
            }
            orthonormalize_columns(&mut p_bar);

            // Q'_i = M_iᵀ P̂ ; Q̄' = mean.
            let mut q_bar = Mat::zeros(cols, r);
            for delta in deltas {
                let m = Mat::from_slice(rows, cols, &delta[lo..hi]);
                let qn = matmul_at_b(&m, &p_bar);
                for (a, b) in q_bar.data.iter_mut().zip(&qn.data) {
                    *a += b / d_workers as f32;
                }
            }
            payload_elems_q += cols * r;
            scales += 1;
            if cfg.q_bits > 0 && cfg.q_bits < 32 {
                quantize::quantize_dequantize(&mut q_bar.data, cfg.q_bits);
            }

            // Warm start for the next outer step.
            state.bases.insert(entry.name.clone(), q_bar.clone());

            // M̂ = P̂ Q̄'ᵀ
            let rec = matmul_bt(&p_bar, &q_bar);
            avg[lo..hi].copy_from_slice(&rec.data);
        } else {
            // 1-D segment: plain mean, quantized on the wire.
            let mut seg = vec![0.0f32; hi - lo];
            for delta in deltas {
                for (a, b) in seg.iter_mut().zip(&delta[lo..hi]) {
                    *a += b / d_workers as f32;
                }
            }
            payload_elems_q += hi - lo;
            scales += 1;
            if cfg.q_bits > 0 && cfg.q_bits < 32 {
                quantize::quantize_dequantize(&mut seg, cfg.q_bits);
            }
            avg[lo..hi].copy_from_slice(&seg);
        }
    }

    let bits = if cfg.q_bits == 0 { 32 } else { cfg.q_bits } as u64;
    let payload_bytes = (payload_elems_q as u64 * bits + 7) / 8
        + 4 * scales as u64;
    LowRankOutcome { avg, payload_bytes }
}

fn hash_name(s: &str) -> u64 {
    // FNV-1a
    let mut h: u64 = 0xcbf29ce484222325;
    for b in s.as_bytes() {
        h ^= *b as u64;
        h = h.wrapping_mul(0x100000001b3);
    }
    h
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::check::props;
    use crate::util::rng::Pcg32;

    fn spec_2d(name: &str, rows: usize, cols: usize, off: usize) -> ParamEntry {
        ParamEntry { name: name.into(), shape: vec![rows, cols], offset: off }
    }

    fn spec_1d(name: &str, n: usize, off: usize) -> ParamEntry {
        ParamEntry { name: name.into(), shape: vec![n], offset: off }
    }

    #[test]
    fn exact_at_full_rank_no_quant() {
        let mut rng = Pcg32::seed_from(1);
        let (rows, cols) = (24, 16);
        let mut d0 = vec![0.0f32; rows * cols + 8];
        let mut d1 = d0.clone();
        rng.fill_normal(&mut d0, 0.0, 1.0);
        rng.fill_normal(&mut d1, 0.0, 1.0);
        let spec = vec![
            spec_2d("w", rows, cols, 0),
            spec_1d("b", 8, rows * cols),
        ];
        let cfg = LowRankConfig { rank: 16, q_bits: 0, seed: 3 };
        let mut st = LowRankState::default();
        let out = reduce(&[d0.clone(), d1.clone()], &spec, &cfg, &mut st, 0);
        // Full rank reconstructs mean exactly (up to GS roundoff).
        for i in 0..d0.len() {
            let want = 0.5 * (d0[i] + d1[i]);
            assert!(
                (out.avg[i] - want).abs() < 1e-3,
                "i={i}: {} vs {want}",
                out.avg[i]
            );
        }
    }

    #[test]
    fn lemma_3_6_error_bound_property() {
        // E||C(x)-x||^2 <= (1 - (r/d) 2^{-q}) ||x||^2 for the combined
        // low-rank + quantize compressor (single worker -> pure compression).
        props(41).runs(25).check(|g| {
            let rows = g.usize_in(8, 40);
            let cols = g.usize_in(8, 40);
            let r = g.usize_in(1, rows.min(cols));
            let q_bits = *g.pick(&[4u32, 8]);
            let x = g.vec_normal(rows * cols, 1.0);
            let spec = vec![spec_2d("w", rows, cols, 0)];
            let cfg = LowRankConfig { rank: r, q_bits, seed: 5 };
            let mut st = LowRankState::default();
            let out = reduce(&[x.clone()], &spec, &cfg, &mut st, 0);
            let err2: f64 = x
                .iter()
                .zip(&out.avg)
                .map(|(a, b)| ((a - b) as f64).powi(2))
                .sum();
            let norm2: f64 = x.iter().map(|a| (*a as f64).powi(2)).sum();
            let d = rows.min(cols) as f64;
            let omega2 =
                1.0 - (r as f64 / d) * 2f64.powi(-(q_bits as i32));
            if err2 <= omega2 * norm2 * 1.05 + 1e-6 {
                Ok(())
            } else {
                Err(format!(
                    "err2/norm2={} > omega2={omega2} (r={r} d={d} q={q_bits})",
                    err2 / norm2
                ))
            }
        });
    }

    #[test]
    fn warm_start_improves_reconstruction() {
        // Repeated reduction of the same matrix must not get worse: the
        // warm-started basis converges to the top-r subspace.
        let mut rng = Pcg32::seed_from(9);
        let (rows, cols, r) = (32, 48, 4);
        let x: Vec<f32> = {
            // Construct a matrix with decaying spectrum.
            let mut u = Mat::zeros(rows, 8);
            let mut v = Mat::zeros(8, cols);
            rng.fill_normal(&mut u.data, 0.0, 1.0);
            rng.fill_normal(&mut v.data, 0.0, 1.0);
            for k in 0..8 {
                let s = 1.0 / (1 << k) as f32;
                for i in 0..rows {
                    u.data[i * 8 + k] *= s;
                }
            }
            matmul(&u, &v).data
        };
        let spec = vec![spec_2d("w", rows, cols, 0)];
        let cfg = LowRankConfig { rank: r, q_bits: 0, seed: 7 };
        let mut st = LowRankState::default();
        let err_at = |st: &mut LowRankState, step: u64| -> f64 {
            let out = reduce(&[x.clone()], &spec, &cfg, st, step);
            x.iter()
                .zip(&out.avg)
                .map(|(a, b)| ((a - b) as f64).powi(2))
                .sum::<f64>()
        };
        let e0 = err_at(&mut st, 0);
        let mut last = e0;
        for t in 1..5 {
            last = err_at(&mut st, t);
        }
        assert!(last <= e0 * 1.01, "e0={e0} last={last}");
    }

    #[test]
    fn payload_accounting_matches_formula() {
        let (rows, cols, r) = (100, 60, 8);
        let spec = vec![spec_2d("w", rows, cols, 0), spec_1d("b", 10, 6000)];
        let cfg = LowRankConfig { rank: r, q_bits: 4, seed: 1 };
        let mut st = LowRankState::default();
        let x = vec![0.5f32; rows * cols + 10];
        let out = reduce(&[x], &spec, &cfg, &mut st, 0);
        let elems = factor_elems(rows, cols, r) + 10;
        assert_eq!(out.payload_bytes, (elems as u64 * 4 + 7) / 8 + 12);
        // Compression ratio vs fp32 baseline is large.
        let full = 4 * (rows * cols + 10) as u64;
        assert!(full as f64 / out.payload_bytes as f64 > 15.0);
    }

    #[test]
    fn adaptive_rank_change_reprojects_basis() {
        let mut rng = Pcg32::seed_from(11);
        let (rows, cols) = (16, 20);
        let mut x = vec![0.0f32; rows * cols];
        rng.fill_normal(&mut x, 0.0, 1.0);
        let spec = vec![spec_2d("w", rows, cols, 0)];
        let mut st = LowRankState::default();
        let c8 = LowRankConfig { rank: 8, q_bits: 0, seed: 2 };
        reduce(&[x.clone()], &spec, &c8, &mut st, 0);
        assert_eq!(st.bases["w"].cols, 8);
        let c4 = LowRankConfig { rank: 4, q_bits: 0, seed: 2 };
        let out = reduce(&[x.clone()], &spec, &c4, &mut st, 1);
        assert_eq!(st.bases["w"].cols, 4);
        assert!(out.avg.iter().all(|v| v.is_finite()));
    }
}
