//! Sparsification compressors: Top-K (largest magnitude; needs index
//! transport, not AllReduce-compatible — §2.4.2) and Random-K (shared-seed
//! mask; only a seed + values travel).  Used by the CocktailSGD baseline
//! and by ablation benches comparing against the paper's Low-Rank choice.

use crate::util::rng::Pcg32;

/// Keep the k largest-|.|, zero the rest; returns kept indices (sorted).
pub fn top_k_mask(x: &mut [f32], k: usize) -> Vec<u32> {
    let n = x.len();
    if k >= n {
        return (0..n as u32).collect();
    }
    if k == 0 {
        x.iter_mut().for_each(|v| *v = 0.0);
        return vec![];
    }
    // Select the k-th magnitude via select_nth on an index permutation.
    let mut idx: Vec<u32> = (0..n as u32).collect();
    idx.select_nth_unstable_by(k - 1, |&a, &b| {
        x[b as usize]
            .abs()
            .partial_cmp(&x[a as usize].abs())
            .unwrap_or(std::cmp::Ordering::Equal)
    });
    let mut kept: Vec<u32> = idx[..k].to_vec();
    kept.sort_unstable();
    let keep: std::collections::HashSet<u32> = kept.iter().copied().collect();
    for (i, v) in x.iter_mut().enumerate() {
        if !keep.contains(&(i as u32)) {
            *v = 0.0;
        }
    }
    kept
}

/// Zero all but a seed-derived fraction `ratio` of entries.  Every worker
/// with the same (seed, step) derives the same mask — AllReduce friendly.
pub fn random_k_mask(x: &mut [f32], ratio: f32, seed: u64, step: u64) {
    assert!((0.0..=1.0).contains(&ratio));
    let n = x.len();
    let k = ((n as f64) * ratio as f64).round() as usize;
    let mut rng = Pcg32::new(seed ^ 0x5eed, step);
    let keep = rng.sample_indices(n, k.min(n));
    let keep: std::collections::HashSet<usize> = keep.into_iter().collect();
    for (i, v) in x.iter_mut().enumerate() {
        if !keep.contains(&i) {
            *v = 0.0;
        }
    }
}

/// Wire bytes for a top-k payload: values (f32) + index list (u32) —
/// the `K log2 d` cost §2.4.2 calls out.
pub fn top_k_wire_bytes(k: usize) -> u64 {
    (k as u64) * (4 + 4)
}

/// Wire bytes for a random-k payload: values only + the 8-byte seed.
pub fn random_k_wire_bytes(k: usize) -> u64 {
    (k as u64) * 4 + 8
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::check::props;

    #[test]
    fn top_k_keeps_largest_magnitudes() {
        let mut x = vec![0.1f32, -5.0, 2.0, 0.01, 3.0];
        let kept = top_k_mask(&mut x, 2);
        assert_eq!(kept, vec![1, 4]);
        assert_eq!(x, vec![0.0, -5.0, 0.0, 0.0, 3.0]);
    }

    #[test]
    fn top_k_error_leq_random_k_property() {
        // §2.4.2: "top-k has fewer compression errors (l2) than random".
        props(31).runs(40).check(|g| {
            let n = g.usize_in(16, 1024);
            let ratio = 0.1f32;
            let k = ((n as f32) * ratio).round() as usize;
            let x = g.vec_normal(n, 1.0);
            let mut xt = x.clone();
            top_k_mask(&mut xt, k);
            let mut xr = x.clone();
            random_k_mask(&mut xr, ratio, 7, g.rng.next_u64());
            let err = |y: &[f32]| -> f64 {
                x.iter()
                    .zip(y)
                    .map(|(a, b)| ((a - b) as f64).powi(2))
                    .sum()
            };
            if err(&xt) <= err(&xr) + 1e-9 {
                Ok(())
            } else {
                Err(format!("topk {} > randk {}", err(&xt), err(&xr)))
            }
        });
    }

    #[test]
    fn top_k_edge_cases() {
        let mut x = vec![1.0f32, 2.0];
        assert_eq!(top_k_mask(&mut x, 5).len(), 2); // k > n keeps all
        let mut y = vec![1.0f32, 2.0];
        assert!(top_k_mask(&mut y, 0).is_empty());
        assert_eq!(y, vec![0.0, 0.0]);
    }

    #[test]
    fn random_k_is_deterministic_per_seed_step() {
        let base: Vec<f32> = (0..100).map(|i| i as f32 + 1.0).collect();
        let mut a = base.clone();
        let mut b = base.clone();
        random_k_mask(&mut a, 0.3, 42, 5);
        random_k_mask(&mut b, 0.3, 42, 5);
        assert_eq!(a, b);
        let mut c = base.clone();
        random_k_mask(&mut c, 0.3, 42, 6);
        assert_ne!(a, c);
        let kept = a.iter().filter(|&&v| v != 0.0).count();
        assert_eq!(kept, 30);
    }

    #[test]
    fn wire_accounting() {
        assert_eq!(top_k_wire_bytes(100), 800);
        assert_eq!(random_k_wire_bytes(100), 408);
    }
}
