//! Synthetic corpus substrate (replaces WikiText-103 — DESIGN.md
//! substitution table): an order-1 Markov chain over the vocabulary with
//! Zipf-biased successor tables.  The chain has low conditional entropy
//! (≈2 bits) but near-uniform-looking unigrams, so a language model has
//! real structure to learn and losses fall well below ln(V); DP shards
//! draw from replica-specific document streams (the paper's 𝒟_i,
//! heterogeneity across clusters).

use crate::util::rng::Pcg32;

/// Per-token successor table: `succ` candidate next-tokens with fixed
/// sampling weights (Zipf-flavored toward low token ids).
pub struct MarkovCorpus {
    pub vocab: usize,
    succ: Vec<[u32; 4]>,
    weights: [f32; 4],
}

impl MarkovCorpus {
    pub fn new(vocab: usize, seed: u64) -> Self {
        assert!(vocab >= 8);
        let mut rng = Pcg32::new(seed, 0xc0ffee);
        let mut succ = Vec::with_capacity(vocab);
        for _tok in 0..vocab {
            // Candidates biased toward small ids: id ~ floor(v * u^3).
            let mut cand = [0u32; 4];
            for c in cand.iter_mut() {
                let u = rng.next_f32();
                *c = ((vocab as f32) * u * u * u) as u32 % vocab as u32;
            }
            succ.push(cand);
        }
        MarkovCorpus { vocab, succ, weights: [0.55, 0.25, 0.12, 0.08] }
    }

    /// Conditional entropy of the transition distribution, in nats — the
    /// loss floor a perfect model converges to.
    pub fn entropy_floor(&self) -> f64 {
        // Candidates may collide, merging probability mass; compute the
        // exact per-state entropy and average (stationary ≈ uniform is a
        // fine approximation for the floor check in tests).
        let mut total = 0.0f64;
        for cand in &self.succ {
            let mut probs = std::collections::HashMap::new();
            for (c, w) in cand.iter().zip(&self.weights) {
                *probs.entry(*c).or_insert(0.0f64) += *w as f64;
            }
            total -= probs.values().map(|p| p * p.ln()).sum::<f64>();
        }
        total / self.succ.len() as f64
    }

    /// Sample a continuation stream starting from `state`.
    fn next(&self, state: u32, rng: &mut Pcg32) -> u32 {
        let u = rng.next_f32();
        let cand = &self.succ[state as usize];
        let mut acc = 0.0;
        for (c, w) in cand.iter().zip(&self.weights) {
            acc += w;
            if u < acc {
                return *c;
            }
        }
        cand[3]
    }
}

/// One DP replica's shard: an endless stream of (tokens, labels) batches.
pub struct ShardIter {
    corpus: std::sync::Arc<MarkovCorpus>,
    rng: Pcg32,
    state: u32,
    pub batch: usize,
    pub seq: usize,
}

impl ShardIter {
    pub fn new(
        corpus: std::sync::Arc<MarkovCorpus>,
        replica: usize,
        seed: u64,
        batch: usize,
        seq: usize,
    ) -> Self {
        let mut rng = Pcg32::new(seed ^ 0xdada, replica as u64 + 1);
        let state = rng.below(corpus.vocab as u32);
        ShardIter { corpus, rng, state, batch, seq }
    }

    /// Next (tokens, labels): labels are the next-token targets, i.e. the
    /// stream shifted by one.
    pub fn next_batch(&mut self) -> (Vec<i32>, Vec<i32>) {
        let n = self.batch * self.seq;
        let mut tokens = Vec::with_capacity(n);
        let mut labels = Vec::with_capacity(n);
        for _ in 0..self.batch {
            // Start each row from a fresh jump to decorrelate rows.
            if self.rng.next_f32() < 0.05 {
                self.state = self.rng.below(self.corpus.vocab as u32);
            }
            let mut cur = self.state;
            for _ in 0..self.seq {
                let nxt = self.corpus.next(cur, &mut self.rng);
                tokens.push(cur as i32);
                labels.push(nxt as i32);
                cur = nxt;
            }
            self.state = cur;
        }
        (tokens, labels)
    }

    pub fn tokens_per_batch(&self) -> u64 {
        (self.batch * self.seq) as u64
    }
}

/// Bigram-model cross entropy of a sample from the shard — a sanity
/// reference: a transformer should end up between `entropy_floor` and the
/// unigram entropy.
pub fn empirical_bigram_nats(corpus: &MarkovCorpus, samples: usize, seed: u64) -> f64 {
    let mut rng = Pcg32::seed_from(seed);
    let mut counts: std::collections::HashMap<(u32, u32), u64> =
        std::collections::HashMap::new();
    let mut margin: std::collections::HashMap<u32, u64> =
        std::collections::HashMap::new();
    let mut s = rng.below(corpus.vocab as u32);
    let mut seqv = Vec::with_capacity(samples);
    for _ in 0..samples {
        let n = corpus.next(s, &mut rng);
        seqv.push((s, n));
        *counts.entry((s, n)).or_default() += 1;
        *margin.entry(s).or_default() += 1;
        s = n;
    }
    let mut nll = 0.0f64;
    for (pair, _) in seqv.iter().map(|p| (*p, ())) {
        let c = counts[&pair] as f64;
        let m = margin[&pair.0] as f64;
        nll -= (c / m).ln();
    }
    nll / samples as f64
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::Arc;

    #[test]
    fn deterministic_batches_per_replica_seed() {
        let c = Arc::new(MarkovCorpus::new(256, 9));
        let mut a = ShardIter::new(Arc::clone(&c), 0, 1, 2, 16);
        let mut b = ShardIter::new(Arc::clone(&c), 0, 1, 2, 16);
        assert_eq!(a.next_batch(), b.next_batch());
        let mut other = ShardIter::new(Arc::clone(&c), 1, 1, 2, 16);
        assert_ne!(a.next_batch(), other.next_batch());
    }

    #[test]
    fn labels_are_shifted_continuations() {
        let c = Arc::new(MarkovCorpus::new(64, 2));
        let mut it = ShardIter::new(Arc::clone(&c), 0, 3, 1, 32);
        let (tokens, labels) = it.next_batch();
        // Within a row, token[i+1] == label[i].
        for i in 0..31 {
            assert_eq!(tokens[i + 1], labels[i]);
        }
        assert!(tokens.iter().all(|&t| (t as usize) < 64));
    }

    #[test]
    fn entropy_floor_is_well_below_uniform() {
        let c = MarkovCorpus::new(512, 4);
        let floor = c.entropy_floor();
        let uniform = (512f64).ln();
        assert!(floor < 1.6, "floor={floor}");
        assert!(floor > 0.4);
        assert!(floor < uniform / 3.0);
    }

    #[test]
    fn empirical_bigram_matches_floor() {
        let c = MarkovCorpus::new(128, 5);
        let emp = empirical_bigram_nats(&c, 40_000, 11);
        let floor = c.entropy_floor();
        assert!(
            (emp - floor).abs() < 0.15,
            "empirical {emp} vs floor {floor}"
        );
    }

    #[test]
    fn zipf_bias_toward_low_ids() {
        let c = Arc::new(MarkovCorpus::new(1024, 6));
        let mut it = ShardIter::new(Arc::clone(&c), 0, 7, 4, 256);
        let mut low = 0usize;
        let mut total = 0usize;
        for _ in 0..8 {
            let (tokens, _) = it.next_batch();
            for t in tokens {
                total += 1;
                if (t as usize) < 256 {
                    low += 1;
                }
            }
        }
        // Uniform would be 25%; the cubic bias should push well past 50%.
        assert!(low as f64 / total as f64 > 0.5, "{low}/{total}");
    }
}
