//! The epoch-aware worker round loop: ONE implementation of "train H
//! local steps, close the round through the [`RoundEngine`], survive
//! membership churn" shared by every deployment shape —
//!
//! * the elastic DP fleet worker ([`crate::transport::elastic::run_worker`]),
//! * the elastic stage fleet worker
//!   ([`crate::transport::elastic::run_stage_worker`]),
//! * the threaded stage executor ([`crate::pipeline::exec`]'s
//!   `stage_main`), and
//! * the threaded coordinator worker ([`crate::coordinator`]'s
//!   `worker_main`),
//!
//! which differ only in what a "local round" is (the [`RoundWork`] they
//! plug in) and in whether epochs can turn (the elastic paths call
//! [`RoundDriver::begin_epoch`] per committed membership epoch; the
//! threaded paths run a single epoch on a pre-seeded lane).
//!
//! # Drain-or-discard (in-flight overlap recovery)
//!
//! With one-step-delay overlap a worker holds one δ-reduction in flight
//! across every round boundary, so ring churn catches it mid-reduction.
//! The module invariant, split between this driver and the elastic 2PC
//! protocol:
//!
//! * every churn survivor reports `(applied_rounds, in_flight_round)`
//!   with its `RingBroken`;
//! * the coordinator's commit carries ONE decision per re-formed ring —
//!   **drain** (every member of the proposed ring reported the *same*
//!   in-flight round t: the new ring finishes the reduction of δ^t, the
//!   collective mean rescaling to the survivor count automatically, and
//!   applies its outer update exactly once) or **discard** (mixed or
//!   absent in-flight rounds: each survivor folds its own in-flight
//!   delta back into the engine's error buffer, where it re-enters the
//!   next round's δ and is consumed exactly once) — a *partial* drain
//!   collective would stall on the members with nothing to reduce, so
//!   unanimity is the precondition;
//! * a third, local case: an abandoned flight that COMPLETED before the
//!   epoch turned late-joins at [`RoundDriver::begin_epoch`] (peers
//!   already applied that mean; see
//!   [`RoundEngine::complete_in_flight_with`]);
//! * so no gradient signal is silently dropped and no outer update is
//!   applied twice ([`RoundEngine`] restores the in-flight delta on a
//!   failed join, so the delta survives until exactly one of the
//!   branches consumes it) — with one bounded-staleness carve-out: a
//!   delta discarded in a *finishing* epoch (no rounds left to run, the
//!   peers already done) has no next δ to re-enter and is dropped, the
//!   same tail a sync-mode final-round break has always had.
//!
//! Error channels are deliberately split: [`RingLane::begin_round`]
//! errors are FATAL transport faults (injected kills) and propagate out
//! of [`RoundDriver::run_rounds`]; everything else mid-round (a broken
//! collective, a dead dataflow neighbor) is CHURN and returns
//! [`EpochEnd::Broken`] so the caller can report `RingBroken` and park
//! for the next epoch.

use super::{movement, RingLane, RoundEngine};
use crate::protocol::{resume_plan, ResumePlan};
use crate::transport::RingTransport;
use anyhow::Result;

/// The committed per-ring recovery decision, re-exported from the pure
/// protocol core ([`crate::protocol`]) where it is produced; the driver
/// consumes it in [`RoundDriver::begin_epoch`].
pub use crate::protocol::Recovery;

/// What one worker trains between outer syncs, as seen by the driver:
/// the driver owns the engine/lane algebra, the work owns the local
/// parameters and the inner optimizer.
pub trait RoundWork {
    /// Current local parameters (flat).
    fn params(&self) -> &[f32];
    /// Resync local parameters to the global track.
    fn set_params(&mut self, p: &[f32]);
    /// Run `h` inner steps from the current params.  Returns (loss
    /// telemetry — NaN when this work never sees the labels, and
    /// measured compute seconds per inner step).  An `Err` is CHURN
    /// (broken dataflow), not a fatal fault.
    fn local_round(&mut self, h: usize) -> Result<(f32, f64)>;
}

/// Per-completed-round telemetry handed to the caller's sink (heartbeats
/// on the fleet, `StageRoundReport`s in the threaded executor).
#[derive(Clone, Copy, Debug)]
pub struct RoundTelemetry {
    pub round: usize,
    /// Loss over the round's inner steps (NaN on label-less stages).
    pub loss: f32,
    /// Measured compute seconds per inner step.
    pub step_secs: f64,
    /// Payload bytes of the reduction completed during this round (0 on
    /// the first overlap round — the wire ledger's overlap signature).
    pub wire_bytes: u64,
}

/// How one epoch's round loop ended.
#[derive(Debug)]
pub enum EpochEnd {
    /// Every scheduled round ran.
    Completed,
    /// The wire broke mid-round (churn): report `RingBroken` with
    /// [`RoundDriver::applied`] / [`RoundDriver::in_flight_round`] and
    /// park for the next committed epoch.  Carries the underlying cause
    /// for callers without a recovery path (the threaded executor).
    Broken(anyhow::Error),
}

/// The shared epoch-aware round loop (see module docs).
pub struct RoundDriver {
    engine: RoundEngine,
    lane: RingLane,
    rounds: usize,
    local_steps: usize,
    /// Soft fault injection: report churn at the start of this round
    /// (once) without dying — see
    /// [`FaultPlan::break_round`](crate::transport::faulty::FaultPlan).
    break_round: usize,
    applied: usize,
}

impl RoundDriver {
    pub fn new(
        engine: RoundEngine,
        lane: RingLane,
        rounds: usize,
        local_steps: usize,
    ) -> RoundDriver {
        RoundDriver { engine, lane, rounds, local_steps, break_round: 0, applied: 0 }
    }

    /// Arm the soft-churn injection (0 = never).
    pub fn set_break_round(&mut self, round: usize) {
        self.break_round = round;
    }

    pub fn engine(&self) -> &RoundEngine {
        &self.engine
    }

    /// Highest round whose outer update is applied to θ_g (what
    /// `RingBroken.applied_rounds` reports; with overlap this trails the
    /// last heartbeat by one until the trailing drain).
    pub fn applied(&self) -> usize {
        self.applied
    }

    /// Wire encoding of the held in-flight round (0 = none) for
    /// `RingBroken.in_flight_round`.
    pub fn in_flight_round(&self) -> u32 {
        self.engine.in_flight_round().unwrap_or(0) as u32
    }

    /// Cumulative reduction payload bytes across all epochs.
    pub fn wire_total(&self) -> u64 {
        self.lane.wire_total
    }

    /// Enter a committed membership epoch: install the fresh ring
    /// (joining/aborting any in-flight reduction), consensus-resync θ_g
    /// over the survivors, restart the outer momentum, then apply the
    /// committed drain-or-discard decision.  `Err` means the fresh ring
    /// broke already — report `RingBroken` and park; the engine state
    /// (incl. any undrained in-flight delta) is preserved for the next
    /// epoch.
    ///
    /// An abandoned flight that COMPLETED before the epoch turned is a
    /// special case: the collective finished, so the peers already
    /// applied its mean at their own joins — the driver *late-joins* it
    /// (same error-refresh + outer-step as an in-band join, with the
    /// pre-restart momentum) so the delta is counted exactly once
    /// fleet-wide instead of being re-injected by the discard fold.  A
    /// committed drain takes precedence (the collective re-reduction on
    /// the fresh ring must have every member).
    pub fn begin_epoch(
        &mut self,
        ring: Box<dyn RingTransport>,
        recovery: Recovery,
    ) -> Result<()> {
        let late = self.lane.reseed(ring);
        let plan =
            resume_plan(recovery, self.engine.in_flight_round(), late.is_some());
        if let ResumePlan::LateJoin { .. } = plan {
            let avg = late.expect("late join without a completed collective");
            if let Some(r) = self.engine.complete_in_flight_with(&avg) {
                self.applied = self.applied.max(r as usize);
            }
        }
        {
            let _s = crate::obs::span("driver", "consensus");
            let mut theta = self.engine.theta().to_vec();
            self.lane.consensus_mean(&mut theta)?;
            self.engine.set_theta(&theta);
        }
        self.engine.reset_outer();
        match plan {
            ResumePlan::Nothing | ResumePlan::LateJoin { .. } => {}
            ResumePlan::Drain { round } => {
                let _s =
                    crate::obs::span_at("driver", "recovery.drain", round as u32);
                self.engine.drain(&mut self.lane)?;
                self.applied = self.applied.max(round as usize);
            }
            // Discard: the delta still in flight folds into the error
            // buffer.  When rounds remain it re-enters the next δ
            // exactly once; in a finishing epoch (no rounds left, peers
            // already done) it is bounded staleness — the same tail a
            // sync-mode final-round break has always had.
            ResumePlan::Discard { round } => {
                let _s = crate::obs::span_at(
                    "driver",
                    "recovery.discard",
                    round as u32,
                );
                self.engine.discard_in_flight();
            }
        }
        Ok(())
    }

    /// Run rounds `start..=rounds` (resyncing the work's params to θ_g
    /// first), emitting telemetry per completed round.  `Err` is a fatal
    /// transport fault; [`EpochEnd::Broken`] is churn.
    pub fn run_rounds(
        &mut self,
        start: usize,
        work: &mut dyn RoundWork,
        telemetry: &mut dyn FnMut(RoundTelemetry),
    ) -> Result<EpochEnd> {
        work.set_params(self.engine.theta());
        for round in start..=self.rounds {
            crate::obs::set_round(round as u32);
            let _round_span = crate::obs::span("driver", "round");
            if self.break_round != 0 && round == self.break_round {
                self.break_round = 0;
                return Ok(EpochEnd::Broken(anyhow::anyhow!(
                    "fault injection: soft ring break at round {round}"
                )));
            }
            // Fatal fault hook (injected kills surface here; a deferred
            // overlap hook's fault is delivered by the next call).
            self.lane.begin_round(round)?;
            // The round's anchor is the STARTING local params — under
            // overlap these trail θ_g by one join, so θ_g is not a
            // substitute.
            let anchor = work.params().to_vec();
            let (loss, step_secs) = {
                let _s = crate::obs::span("driver", "compute");
                match work.local_round(self.local_steps) {
                    Ok(x) => x,
                    Err(e) => return Ok(EpochEnd::Broken(e)),
                }
            };
            let mv = movement(&anchor, work.params());
            match self.engine.finish_round(vec![mv], round as u64, &mut self.lane)
            {
                Ok(Some(_)) => {
                    self.applied =
                        if self.engine.overlap() { round - 1 } else { round };
                    work.set_params(self.engine.theta());
                }
                Ok(None) => {} // first overlap round: nothing applied yet
                Err(e) => return Ok(EpochEnd::Broken(e)),
            }
            telemetry(RoundTelemetry {
                round,
                loss,
                step_secs,
                wire_bytes: self.lane.wire_last,
            });
        }
        Ok(EpochEnd::Completed)
    }

    /// Flush the trailing in-flight reduction after the last round so
    /// the final parameters include every worker's last contribution.
    /// `Err` is churn (a peer died during the final collective): report
    /// `RingBroken` — the delta is preserved and the next epoch's drain
    /// decision finishes it.
    pub fn finish(&mut self, work: &mut dyn RoundWork) -> Result<()> {
        if self.engine.drain(&mut self.lane)?.is_some() {
            self.applied = self.rounds;
            work.set_params(self.engine.theta());
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::comm::ring::build_ring;
    use crate::compress::Method;
    use crate::optim::Nesterov;
    use crate::runtime::manifest::ParamEntry;

    /// Gradient descent toward a fixed target — the minimal RoundWork.
    struct ToyWork {
        params: Vec<f32>,
        target: Vec<f32>,
        lr: f32,
    }

    impl RoundWork for ToyWork {
        fn params(&self) -> &[f32] {
            &self.params
        }

        fn set_params(&mut self, p: &[f32]) {
            self.params.copy_from_slice(p);
        }

        fn local_round(&mut self, h: usize) -> Result<(f32, f64)> {
            for _ in 0..h {
                for (p, t) in self.params.iter_mut().zip(&self.target) {
                    *p -= self.lr * (*p - *t);
                }
            }
            Ok((0.0, 0.0))
        }
    }

    fn flat_spec(n: usize) -> Vec<ParamEntry> {
        vec![ParamEntry { name: "flat".into(), shape: vec![n], offset: 0 }]
    }

    fn driver(n: usize, rounds: usize, overlap: bool) -> RoundDriver {
        let engine = RoundEngine::new(
            vec![0.0; n],
            1,
            Nesterov::new(n, 0.5, 0.0),
            overlap,
            false,
        );
        let lane = RingLane::unseeded(Method::None, 7, flat_spec(n), overlap);
        RoundDriver::new(engine, lane, rounds, 4)
    }

    #[test]
    fn single_member_epoch_runs_to_completion_sync_and_overlap() {
        for overlap in [false, true] {
            let mut d = driver(4, 3, overlap);
            let member = build_ring(1).remove(0);
            d.begin_epoch(Box::new(member), Recovery::Discard).unwrap();
            let mut work =
                ToyWork { params: vec![0.0; 4], target: vec![1.0; 4], lr: 0.5 };
            let mut rounds_seen = Vec::new();
            let end = d
                .run_rounds(1, &mut work, &mut |t| rounds_seen.push(t.round))
                .unwrap();
            assert!(matches!(end, EpochEnd::Completed));
            d.finish(&mut work).unwrap();
            assert_eq!(rounds_seen, vec![1, 2, 3]);
            assert_eq!(d.applied(), 3, "overlap={overlap}");
            assert_eq!(d.in_flight_round(), 0);
            // θ moved toward the target and work resynced to it.
            assert!(d.engine().theta()[0] > 0.0);
            assert_eq!(work.params(), d.engine().theta());
        }
    }

    #[test]
    fn overlap_wire_ledger_defers_one_round() {
        let mut d = driver(4, 3, true);
        let member = build_ring(1).remove(0);
        d.begin_epoch(Box::new(member), Recovery::Discard).unwrap();
        let mut work =
            ToyWork { params: vec![0.0; 4], target: vec![1.0; 4], lr: 0.5 };
        let mut wire = Vec::new();
        d.run_rounds(1, &mut work, &mut |t| wire.push((t.round, t.wire_bytes)))
            .unwrap();
        // Round 1 completes no reduction; rounds 2..T complete the
        // previous round's — the ledger signature of the one-step delay.
        assert_eq!(wire[0], (1, 0));
        assert!(wire[1..].iter().all(|&(_, b)| b > 0), "{wire:?}");
        d.finish(&mut work).unwrap();
    }

    #[test]
    fn soft_break_fires_once_and_preserves_in_flight() {
        let mut d = driver(2, 4, true);
        let member = build_ring(1).remove(0);
        d.begin_epoch(Box::new(member), Recovery::Discard).unwrap();
        d.set_break_round(3);
        let mut work =
            ToyWork { params: vec![0.0; 2], target: vec![1.0; 2], lr: 0.5 };
        let end = d.run_rounds(1, &mut work, &mut |_| {}).unwrap();
        assert!(matches!(end, EpochEnd::Broken(_)));
        // δ² went in flight at the end of round 2 and survives the break.
        assert_eq!(d.in_flight_round(), 2);
        assert_eq!(d.applied(), 1);
        // Next epoch: drain the held round on the fresh ring, resume, and
        // the break does not re-fire.
        let member = build_ring(1).remove(0);
        d.begin_epoch(Box::new(member), Recovery::Drain { round: 2 }).unwrap();
        assert_eq!(d.in_flight_round(), 0);
        assert_eq!(d.applied(), 2);
        let end = d.run_rounds(3, &mut work, &mut |_| {}).unwrap();
        assert!(matches!(end, EpochEnd::Completed));
        d.finish(&mut work).unwrap();
        assert_eq!(d.applied(), 4);
    }

    #[test]
    fn completed_flight_late_joins_instead_of_double_counting() {
        // Accounting check for the late-join rule: a soft-breaker's
        // in-flight reduction COMPLETES (its comm thread kept relaying),
        // so the peers applied that mean — the breaker must apply it
        // exactly once at reseed, not re-inject it via the discard fold.
        // With a size-1 ring the reduced mean equals the submitted delta,
        // so θ's trajectory exposes exactly which deltas were applied.
        let n = 1;
        let mut d = driver(n, 3, true);
        let member = build_ring(1).remove(0);
        d.begin_epoch(Box::new(member), Recovery::Discard).unwrap();
        // lr chosen so each 4-step local round moves params fully to the
        // target: movement per round is (target − θ).
        let mut work =
            ToyWork { params: vec![0.0; n], target: vec![8.0; n], lr: 1.0 };
        d.set_break_round(2);
        let end = d.run_rounds(1, &mut work, &mut |_| {}).unwrap();
        assert!(matches!(end, EpochEnd::Broken(_)));
        // δ¹ = −8 (movement = anchor − params) is in flight — and its
        // size-1 collective has already completed.
        assert_eq!(d.in_flight_round(), 1);
        let member = build_ring(1).remove(0);
        d.begin_epoch(Box::new(member), Recovery::Discard).unwrap();
        assert_eq!(d.in_flight_round(), 0, "late-joined at reseed");
        assert_eq!(d.applied(), 1, "the completed round counts as applied");
        // Δ¹ = −8 applied once with outer lr 0.5: θ = 4.
        assert!(
            (d.engine().theta()[0] - 4.0).abs() < 1e-5,
            "late join applied Δ¹ exactly once: θ = {}",
            d.engine().theta()[0]
        );
        // Resume at round 2: params resync to 4, local moves to 8
        // (δ² = −4, NOT −12 — no re-injected remnant of δ¹), round 3
        // joins it: θ = 4 + 0.5·4 = 6; round 3 moves nothing.
        let end = d.run_rounds(2, &mut work, &mut |_| {}).unwrap();
        assert!(matches!(end, EpochEnd::Completed));
        d.finish(&mut work).unwrap();
        assert!(
            (d.engine().theta()[0] - 6.0).abs() < 1e-5,
            "every delta applied exactly once: θ = {}",
            d.engine().theta()[0]
        );
    }

    #[test]
    fn two_member_drain_rescales_to_survivors() {
        // Two members run one overlap round each on a shared ring, then
        // "churn" hands each a fresh size-1 ring with a Drain decision:
        // each finishes its own δ¹ alone (the degenerate rescale) and θ
        // moves by exactly its own delta — no signal lost, none doubled.
        let members = build_ring(2);
        let outs: Vec<f32> = std::thread::scope(|scope| {
            members
                .into_iter()
                .enumerate()
                .map(|(i, m)| {
                    scope.spawn(move || {
                        let mut d = driver(1, 1, true);
                        d.begin_epoch(Box::new(m), Recovery::Discard).unwrap();
                        let target = if i == 0 { 2.0 } else { 6.0 };
                        let mut work = ToyWork {
                            params: vec![0.0],
                            target: vec![target],
                            lr: 1.0,
                        };
                        // Round 1 launches δ¹ = −target and defers.
                        let end =
                            d.run_rounds(1, &mut work, &mut |_| {}).unwrap();
                        assert!(matches!(end, EpochEnd::Completed));
                        assert_eq!(d.in_flight_round(), 1);
                        let solo = build_ring(1).remove(0);
                        d.begin_epoch(
                            Box::new(solo),
                            Recovery::Drain { round: 1 },
                        )
                        .unwrap();
                        d.engine().theta()[0]
                    })
                })
                .collect::<Vec<_>>()
                .into_iter()
                .map(|h| h.join().unwrap())
                .collect()
        });
        // Outer lr 0.5: θ = 0 − 0.5·(−target)… except round 1's launch
        // happened on the SHARED ring in overlap mode, so the drain on
        // the size-1 ring reduces the raw per-member delta.
        assert!((outs[0] - 1.0).abs() < 1e-6, "{outs:?}");
        assert!((outs[1] - 3.0).abs() < 1e-6, "{outs:?}");
    }
}
