//! Outer-round engine (paper Algorithm 2): the one place that owns the
//! delta/error-feedback/outer-step/overlap ordering.
//!
//! Every execution path — the single-process reference trainer
//! ([`crate::train`]), the threaded coordinator ([`crate::coordinator`]),
//! the elastic multi-process workers ([`crate::transport::elastic`]), and
//! the stage-parallel 1F1B executor ([`crate::pipeline::exec`]) — used to
//! carry its own copy of the same delicate state machine; they now all
//! drive a [`RoundEngine`] and differ only in *how* the pseudo-gradients
//! get reduced to their global mean (the [`DeltaReducer`] they plug in).
//!
//! The invariant algebra, per outer round t:
//!
//! 1. (overlap only) **join** the in-flight reduction of δ^{t-1};
//! 2. refresh the error buffer e^t = δ^{t-1} − Δ^{t-1} (error feedback);
//! 3. form δ^t = (anchor^t − θ^t_local) + e^t against THIS round's anchor
//!    — in-flight progress is never counted twice;
//! 4. start reducing δ^t (a real comm thread with overlap, inline without);
//! 5. apply the outer Nesterov update with the *delayed* mean Δ^{t-1}
//!    (overlap) or the fresh mean Δ^t (sync), then resync local params to
//!    the global track θ_g.
//!
//! [`WireCompressor`] (AllReduce-compatible compression over a
//! [`RingTransport`]) and [`RingLane`] (the comm-thread overlap pattern)
//! live here too so the per-stage executor and the per-worker coordinator
//! share them.  The low-rank base seed is derived from the *round* in both
//! the sync and the overlap path — the two paths produce bit-identical
//! bases (regression-tested below).
//!
//! Invariants to keep when changing this module:
//!
//! * **Overlap join ordering** — step 1 (join δ^{t-1}) must happen before
//!   step 3 (form δ^t): the error buffer refresh in between is what keeps
//!   in-flight progress from being counted twice.  The first overlap
//!   round applies nothing (`finish_round` returns `None`) and a trailing
//!   in-flight reduction must be [`RoundEngine::drain`]ed at shutdown or
//!   the final parameters silently miss the last contribution.
//! * **Drain-or-discard (the in-flight churn rule)** — a failed join
//!   RESTORES the in-flight delta instead of dropping it, so after ring
//!   churn exactly one of two things happens to δ^t: the re-formed ring
//!   *drains* it ([`RoundEngine::drain`] — finish the reduction with
//!   survivor-rescaled means and apply its outer update once), or the
//!   engine *discards* it ([`RoundEngine::discard_in_flight`] — the
//!   delta becomes the error buffer, re-entering the next round's δ and
//!   consumed exactly once even with error feedback disabled).  Either
//!   way no gradient signal is silently dropped and none is applied
//!   twice.  The epoch-aware loop that wires this to the elastic 2PC
//!   protocol lives in [`driver`].
//! * **θ_g moves only by outer updates** — `set_theta` exists solely for
//!   the elastic consensus resync after churn; anything else mutating the
//!   global track breaks cross-worker agreement.
//! * **Round-seeded bases** — `WireCompressor::reduce` must receive the
//!   round the delta *belongs to* (not the wall-clock round), identically
//!   in sync and overlap mode, or ring peers derive different low-rank
//!   bases and the collective silently degrades.
//! * **One engine per independent shard** — the stage-parallel paths run
//!   one `RoundEngine` per stage; the algebra is elementwise, so engines
//!   compose exactly and per-stage wire payloads sum to the flat-vector
//!   total.

pub mod driver;

use crate::compress::{lowrank, quantize, Method};
use crate::linalg::{matmul, matmul_at_b, matmul_bt, orthonormalize_columns, Mat};
use crate::optim::Nesterov;
use crate::runtime::manifest::ParamEntry;
use crate::transport::RingTransport;
use crate::util::rng::Pcg32;
use anyhow::{anyhow, Result};
use std::collections::HashMap;

/// How a round's pseudo-gradients become their global mean.
///
/// `begin` is called the moment δ^t is formed; `complete` when the mean is
/// needed — immediately after `begin` in sync mode, one round later with
/// overlap.  Implementations that reduce inline leave `begin` a no-op and
/// do the work in `complete`; implementations that overlap launch a comm
/// thread in `begin` and join it in `complete` (the `deltas` argument of a
/// `complete` that joins an already-launched reduction may be ignored).
pub trait DeltaReducer {
    fn begin(&mut self, deltas: &[Vec<f32>], round: u64) -> Result<()>;
    fn complete(&mut self, deltas: &[Vec<f32>], round: u64) -> Result<Vec<f32>>;
}

/// The Algorithm-2 outer-round state machine over a flat parameter track.
///
/// One engine per independent parameter shard: the whole model for the
/// single-vector paths, one per pipeline stage for the stage-parallel
/// path (the algebra is elementwise, so per-stage engines compose
/// exactly).  `lanes` is the number of local pseudo-gradient sources the
/// caller feeds per round: 1 for a real distributed worker (its peers are
/// behind the reducer), D for the in-process reference trainer that holds
/// every replica itself.
pub struct RoundEngine {
    theta_g: Vec<f32>,
    outer: Nesterov,
    error: Vec<Vec<f32>>,
    in_flight: Option<(Vec<Vec<f32>>, u64)>,
    overlap: bool,
    error_feedback: bool,
}

impl RoundEngine {
    pub fn new(
        theta0: Vec<f32>,
        lanes: usize,
        outer: Nesterov,
        overlap: bool,
        error_feedback: bool,
    ) -> RoundEngine {
        let n = theta0.len();
        assert!(lanes >= 1, "need at least one lane");
        assert_eq!(outer.buf.len(), n, "outer optimizer size mismatch");
        RoundEngine {
            theta_g: theta0,
            outer,
            error: vec![vec![0.0; n]; lanes],
            in_flight: None,
            overlap,
            error_feedback,
        }
    }

    /// The global parameter track (moves only by outer updates).
    pub fn theta(&self) -> &[f32] {
        &self.theta_g
    }

    /// Overwrite the global track (elastic consensus resync after churn).
    pub fn set_theta(&mut self, theta: &[f32]) {
        self.theta_g.copy_from_slice(theta);
    }

    /// Restart the outer momentum (elastic ring re-formation policy).
    pub fn reset_outer(&mut self) {
        self.outer.buf.iter_mut().for_each(|x| *x = 0.0);
    }

    pub fn lanes(&self) -> usize {
        self.error.len()
    }

    pub fn has_in_flight(&self) -> bool {
        self.in_flight.is_some()
    }

    /// One-step-delay overlap enabled?
    pub fn overlap(&self) -> bool {
        self.overlap
    }

    /// The round of the in-flight δ-reduction, if any (what a churn
    /// survivor reports so the coordinator can decide drain vs discard).
    pub fn in_flight_round(&self) -> Option<u64> {
        self.in_flight.as_ref().map(|(_, r)| *r)
    }

    /// Join an in-flight reduction OUT OF BAND: the abandoned comm thread
    /// had already completed the collective when the membership epoch
    /// turned, so `avg` is the same mean the surviving peers applied at
    /// their own in-band joins.  Applying it here — error refresh + outer
    /// step, exactly like [`Self::finish_round`]'s join — keeps this
    /// worker's accounting aligned with its peers: the delta is neither
    /// dropped nor re-injected for a second application.  Returns the
    /// joined round.
    pub fn complete_in_flight_with(&mut self, avg: &[f32]) -> Option<u64> {
        let (raws, r) = self.in_flight.take()?;
        self.refresh_error(&raws, avg);
        self.outer.step(&mut self.theta_g, avg);
        Some(r)
    }

    /// The *discard* branch of in-flight churn recovery: the reduction of
    /// δ^t cannot be finished (survivors hold mixed in-flight rounds), so
    /// the delta becomes the error buffer — δ^t already subsumes the old
    /// error term (it was formed as movement + e), so this is an
    /// overwrite, not an add.  The signal re-enters the next round's δ
    /// via `add_error` and is consumed exactly once (the buffer is zeroed
    /// on consumption when error feedback is off, and refreshed from the
    /// next reduction when it is on).  Returns the discarded round.
    pub fn discard_in_flight(&mut self) -> Option<u64> {
        let (raws, r) = self.in_flight.take()?;
        for (e, raw) in self.error.iter_mut().zip(&raws) {
            e.copy_from_slice(raw);
        }
        Some(r)
    }

    fn add_error(&mut self, mut movement: Vec<Vec<f32>>) -> Vec<Vec<f32>> {
        for (lane, e) in movement.iter_mut().zip(self.error.iter_mut()) {
            for (d, ei) in lane.iter_mut().zip(e.iter_mut()) {
                *d += *ei;
                // Without error feedback the buffer is only ever
                // populated by a churn discard; consume it exactly once
                // so a discarded delta cannot be re-counted every round.
                if !self.error_feedback {
                    *ei = 0.0;
                }
            }
        }
        movement
    }

    fn refresh_error(&mut self, raws: &[Vec<f32>], avg: &[f32]) {
        if !self.error_feedback {
            return;
        }
        for (e, raw) in self.error.iter_mut().zip(raws) {
            for i in 0..e.len() {
                e[i] = raw[i] - avg[i];
            }
        }
    }

    /// Finish round `round` given the per-lane local movement
    /// (anchor − params, WITHOUT error feedback — the engine adds e^t).
    ///
    /// Returns the reduced mean applied to θ_g this round: `Some` means
    /// the caller must resync its local params to [`Self::theta`];
    /// `None` only on the first overlap round (nothing in flight yet).
    pub fn finish_round(
        &mut self,
        movement: Vec<Vec<f32>>,
        round: u64,
        red: &mut dyn DeltaReducer,
    ) -> Result<Option<Vec<f32>>> {
        if movement.len() != self.error.len() {
            return Err(anyhow!(
                "engine has {} lanes, got {} movements",
                self.error.len(),
                movement.len()
            ));
        }
        if self.overlap {
            let prev = self.in_flight.take();
            // A failed join restores the in-flight delta: churn recovery
            // (drain-or-discard) needs it — dropping it here would lose a
            // whole round of local training.
            let avg_prev = match &prev {
                Some((raws, r)) => match red.complete(raws, *r) {
                    Ok(avg) => Some(avg),
                    Err(e) => {
                        self.in_flight = prev;
                        return Err(e);
                    }
                },
                None => None,
            };
            if let (Some((raws, _)), Some(avg)) = (&prev, &avg_prev) {
                self.refresh_error(raws, avg);
            }
            let deltas = self.add_error(movement);
            red.begin(&deltas, round)?;
            self.in_flight = Some((deltas, round));
            Ok(match avg_prev {
                Some(avg) => {
                    self.outer.step(&mut self.theta_g, &avg);
                    Some(avg)
                }
                None => None,
            })
        } else {
            let deltas = self.add_error(movement);
            red.begin(&deltas, round)?;
            let avg = red.complete(&deltas, round)?;
            self.refresh_error(&deltas, &avg);
            self.outer.step(&mut self.theta_g, &avg);
            Ok(Some(avg))
        }
    }

    /// Flush a trailing in-flight reduction: at shutdown so the final
    /// params include every lane's last contribution, and as the *drain*
    /// branch of churn recovery (the re-formed ring finishes the
    /// reduction — the collective mean rescales to the survivor count
    /// automatically — and the outer update applies exactly once).  A
    /// failed reduction restores the in-flight delta, like
    /// [`Self::finish_round`].
    pub fn drain(&mut self, red: &mut dyn DeltaReducer) -> Result<Option<Vec<f32>>> {
        let Some((raws, r)) = self.in_flight.take() else {
            return Ok(None);
        };
        let avg = match red.complete(&raws, r) {
            Ok(avg) => avg,
            Err(e) => {
                self.in_flight = Some((raws, r));
                return Err(e);
            }
        };
        self.outer.step(&mut self.theta_g, &avg);
        Ok(Some(avg))
    }
}

/// δ components: this round's local movement against its anchor.
pub fn movement(anchor: &[f32], params: &[f32]) -> Vec<f32> {
    anchor.iter().zip(params).map(|(a, p)| a - p).collect()
}

// ---------------------------------------------------------------------------
// AllReduce-compatible wire compression
// ---------------------------------------------------------------------------

/// AllReduce-compatible compression state for ring-transport paths.
///
/// Quantize-only runs one ring pass; Low-Rank ∘ Quantize runs the PowerSGD
/// two-pass algebra (allreduce P̄, orthonormalize, allreduce Q̄') — every
/// worker derives identical bases from a shared seed + the round number,
/// so no parameter server is needed.
pub struct WireCompressor {
    method: Method,
    seed: u64,
    bases: HashMap<String, Mat>,
    /// Software-pipeline depth for the low-rank path: ≤ 1 reduces each
    /// ring pass strictly in sequence (the historical behavior), ≥ 2
    /// projects/quantizes entry k+1 on the caller's thread while entry
    /// k's ring pass is on the wire.  Must be identical on every ring
    /// member — the wire-op order is a pure function of (spec, depth).
    pipeline_depth: usize,
    /// Reusable scratch for the 1-D segment path (and recycled wire
    /// buffers in the pipelined path) — kills a per-entry-per-round
    /// allocation on the hot path.
    scratch: Vec<Vec<f32>>,
}

impl WireCompressor {
    pub fn new(method: Method, seed: u64) -> Self {
        WireCompressor {
            method,
            seed,
            bases: HashMap::new(),
            pipeline_depth: 1,
            scratch: Vec::new(),
        }
    }

    /// Set the low-rank software-pipeline depth (see
    /// [`Self::lowrank_reduce`]); ≤ 1 preserves the sequential behavior.
    pub fn set_pipeline_depth(&mut self, depth: usize) {
        self.pipeline_depth = depth;
    }

    /// Cached low-rank base for a parameter (tests / inspection).
    pub fn base(&self, name: &str) -> Option<&Mat> {
        self.bases.get(name)
    }

    /// Pop a recycled buffer (cleared) or allocate a fresh one.
    fn take_scratch(&mut self) -> Vec<f32> {
        let mut b = self.scratch.pop().unwrap_or_default();
        b.clear();
        b
    }

    /// Return a spent buffer to the scratch pool (bounded).
    fn put_scratch(&mut self, buf: Vec<f32>) {
        if self.scratch.len() < 8 {
            self.scratch.push(buf);
        }
    }

    /// Reduce `delta` across the ring in place (result = global mean of
    /// the compressed deltas); returns payload bytes this worker sent.
    /// Speaks only to the [`RingTransport`] trait, so the same compressor
    /// runs over the local mpsc ring, loopback TCP, or a fault-injecting
    /// wrapper.  `step` seeds fresh low-rank bases; callers must pass the
    /// round the delta belongs to — identically in sync and overlap mode.
    pub fn reduce(
        &mut self,
        member: &mut dyn RingTransport,
        delta: &mut [f32],
        spec: &[ParamEntry],
        step: u64,
    ) -> Result<u64> {
        // Match on a reference — the method is only read, never consumed,
        // and this runs once per ring pass on the hot path.
        match &self.method {
            Method::None => {
                let payload = 4 * delta.len() as u64;
                let _w = crate::obs::span("wire", "allreduce").bytes(payload);
                member.allreduce_mean(delta)?;
                Ok(payload)
            }
            Method::Quant { q_bits } => {
                let q_bits = *q_bits;
                {
                    let _c = crate::obs::span("compress", "compress.quant");
                    quantize::quantize_dequantize(delta, q_bits);
                }
                let payload = quantize::wire_bytes(delta.len(), q_bits);
                let _w = crate::obs::span("wire", "allreduce").bytes(payload);
                member.allreduce_mean(delta)?;
                Ok(payload)
            }
            Method::LowRankQuant { rank, q_bits } => {
                let (rank, q_bits) = (*rank, *q_bits);
                if self.pipeline_depth > 1 && spec.len() > 1 {
                    self.lowrank_reduce_pipelined(
                        member, delta, spec, step, rank, q_bits,
                    )
                } else {
                    self.lowrank_reduce(member, delta, spec, step, rank, q_bits)
                }
            }
            other => Err(anyhow!(
                "method {:?} is not AllReduce-compatible (ring path)",
                other.name()
            )),
        }
    }

    fn lowrank_reduce(
        &mut self,
        member: &mut dyn RingTransport,
        delta: &mut [f32],
        spec: &[ParamEntry],
        step: u64,
        rank: usize,
        q_bits: u32,
    ) -> Result<u64> {
        let mut payload_elems = 0usize;
        let mut scales = 0usize;
        let bits = if q_bits == 0 { 32 } else { q_bits } as u64;
        let pass_bytes = |elems: usize| (elems as u64 * bits + 7) / 8 + 4;
        for entry in spec {
            let lo = entry.offset;
            let hi = entry.offset + entry.numel();
            if entry.shape.len() == 2 {
                let (rows, cols) = (entry.shape[0], entry.shape[1]);
                let r = lowrank::effective_rank(rank, rows, cols);
                let q = self.bases.entry(entry.name.clone()).or_insert_with(|| {
                    // Same seeding rule as compress::lowrank → identical
                    // bases on every worker.
                    let mut rng =
                        Pcg32::new(self.seed ^ fnv(&entry.name), step);
                    let mut m = Mat::zeros(cols, r);
                    rng.fill_normal(&mut m.data, 0.0, 1.0);
                    m
                });
                if q.cols != r {
                    let mut rng =
                        Pcg32::new(self.seed ^ fnv(&entry.name), step);
                    let mut m = Mat::zeros(cols, r);
                    for i in 0..cols {
                        for j in 0..r {
                            m.data[i * r + j] = if j < q.cols {
                                q.data[i * q.cols + j]
                            } else {
                                rng.normal()
                            };
                        }
                    }
                    *q = m;
                }
                let mslab = Mat::from_slice(rows, cols, &delta[lo..hi]);
                // Pass 1: P = M Q, ring-mean, quantize, orthonormalize.
                let mut p = {
                    let _c = crate::obs::span("compress", "compress.project");
                    matmul(&mslab, q)
                };
                {
                    let _w = crate::obs::span("wire", "allreduce")
                        .bytes(pass_bytes(rows * r));
                    member.allreduce_mean(&mut p.data)?;
                }
                payload_elems += rows * r;
                scales += 1;
                {
                    let _c = crate::obs::span("compress", "compress.quant");
                    if q_bits > 0 && q_bits < 32 {
                        quantize::quantize_dequantize(&mut p.data, q_bits);
                    }
                    orthonormalize_columns(&mut p);
                }
                // Pass 2: Q' = Mᵀ P̂, ring-mean, quantize.
                let mut qn = {
                    let _c = crate::obs::span("compress", "compress.project");
                    matmul_at_b(&mslab, &p)
                };
                {
                    let _w = crate::obs::span("wire", "allreduce")
                        .bytes(pass_bytes(cols * r));
                    member.allreduce_mean(&mut qn.data)?;
                }
                payload_elems += cols * r;
                scales += 1;
                if q_bits > 0 && q_bits < 32 {
                    let _c = crate::obs::span("compress", "compress.quant");
                    quantize::quantize_dequantize(&mut qn.data, q_bits);
                }
                let rec = {
                    let _c = crate::obs::span("compress", "compress.project");
                    matmul_bt(&p, &qn)
                };
                // The reconstruction is done with qn, so the base cache
                // takes it by move — no clone on the hot path.
                self.bases.insert(entry.name.clone(), qn);
                delta[lo..hi].copy_from_slice(&rec.data);
            } else {
                // 1-D segment: ring-mean, then snap to the q-bit grid —
                // the same order as compress::lowrank so the threaded and
                // reference paths agree bit-for-bit (up to ring fp order).
                // The staging buffer is recycled across entries and
                // rounds instead of reallocated per segment.
                let mut seg = self.take_scratch();
                seg.extend_from_slice(&delta[lo..hi]);
                {
                    let _w = crate::obs::span("wire", "allreduce")
                        .bytes(pass_bytes(hi - lo));
                    member.allreduce_mean(&mut seg)?;
                }
                if q_bits > 0 && q_bits < 32 {
                    let _c = crate::obs::span("compress", "compress.quant");
                    quantize::quantize_dequantize(&mut seg, q_bits);
                }
                payload_elems += hi - lo;
                scales += 1;
                delta[lo..hi].copy_from_slice(&seg);
                self.put_scratch(seg);
            }
        }
        Ok((payload_elems as u64 * bits + 7) / 8 + 4 * scales as u64)
    }

    /// The two-lane software pipeline behind `pipeline_depth ≥ 2`: the
    /// caller's thread (the compute lane) projects/quantizes parameter
    /// entry k+1 while entry k's ring pass is on the wire, connected by a
    /// bounded channel to a scoped wire thread that runs the collectives
    /// strictly in submission order.
    ///
    /// Correctness: entries are mutually independent (per-entry bases,
    /// per-entry seeding), so per-entry numerics are byte-identical to
    /// the sequential path; the wire-op *order* differs from sequential
    /// at depth ≥ 2 but is a pure deterministic function of
    /// (spec, depth), so every ring member — which shares both via
    /// config — lines its collectives up.  Results, payload bytes, and
    /// the per-member wire ledger are bit-for-bit equal to the
    /// sequential reference (regression-tested on all three backends).
    #[allow(clippy::too_many_arguments)]
    fn lowrank_reduce_pipelined(
        &mut self,
        member: &mut dyn RingTransport,
        delta: &mut [f32],
        spec: &[ParamEntry],
        step: u64,
        rank: usize,
        q_bits: u32,
    ) -> Result<u64> {
        struct WireJob {
            buf: Vec<f32>,
            bytes: u64,
        }
        /// An op whose ring pass is in flight, FIFO with the channel.
        enum Op {
            /// P = M·Q on the wire; completion quantizes/orthonormalizes
            /// P̂ and submits pass 2.
            Pass1 { idx: usize, mslab: Mat, r: usize },
            /// Q' = Mᵀ·P̂ on the wire; completion reconstructs the entry.
            Pass2 { idx: usize, p: Mat, r: usize },
            /// A 1-D segment mean on the wire.
            Seg { idx: usize },
        }

        let depth = self.pipeline_depth;
        let bits = if q_bits == 0 { 32 } else { q_bits } as u64;
        let pass_bytes = |elems: usize| (elems as u64 * bits + 7) / 8 + 4;
        let (op_tx, op_rx) = std::sync::mpsc::sync_channel::<WireJob>(depth);
        let (res_tx, res_rx) = std::sync::mpsc::channel::<Result<Vec<f32>>>();
        let ctx = crate::obs::scope();

        let (payload_elems, scales) =
            std::thread::scope(|s| -> Result<(usize, usize)> {
                s.spawn(move || {
                    // The wire lane inherits the compute lane's trace
                    // context so its allreduce spans attribute to the
                    // right (cluster, stage, epoch, round).
                    crate::obs::set_ctx(ctx);
                    while let Ok(mut job) = op_rx.recv() {
                        let res = {
                            let _w = crate::obs::span("wire", "allreduce")
                                .bytes(job.bytes);
                            member.allreduce_mean(&mut job.buf)
                        };
                        match res {
                            Ok(()) => {
                                if res_tx.send(Ok(job.buf)).is_err() {
                                    break;
                                }
                            }
                            Err(e) => {
                                let _ = res_tx.send(Err(e));
                                break;
                            }
                        }
                    }
                });

                let submit = |job: WireJob| -> Result<()> {
                    if op_tx.send(job).is_err() {
                        // The wire lane died; drain any queued Ok
                        // results from earlier ops so the lane's actual
                        // transport error surfaces, not a generic
                        // hang-up.
                        loop {
                            match res_rx.recv() {
                                Ok(Ok(_)) => continue,
                                Ok(Err(e)) => return Err(e),
                                Err(_) => {
                                    return Err(anyhow!(
                                        "reduce wire lane hung up"
                                    ))
                                }
                            }
                        }
                    }
                    Ok(())
                };

                let mut queue: std::collections::VecDeque<Op> =
                    std::collections::VecDeque::new();
                let mut next = 0usize;
                let mut payload_elems = 0usize;
                let mut scales = 0usize;
                loop {
                    // Fill: submit the first ring pass of upcoming
                    // entries until the pipeline is `depth` deep.  The
                    // submission sequence is a pure function of
                    // (spec, depth) — no timing-dependent choices.
                    while queue.len() < depth && next < spec.len() {
                        let entry = &spec[next];
                        let lo = entry.offset;
                        let hi = entry.offset + entry.numel();
                        if entry.shape.len() == 2 {
                            let (rows, cols) = (entry.shape[0], entry.shape[1]);
                            let r = lowrank::effective_rank(rank, rows, cols);
                            let q = self
                                .bases
                                .entry(entry.name.clone())
                                .or_insert_with(|| {
                                    let mut rng = Pcg32::new(
                                        self.seed ^ fnv(&entry.name),
                                        step,
                                    );
                                    let mut m = Mat::zeros(cols, r);
                                    rng.fill_normal(&mut m.data, 0.0, 1.0);
                                    m
                                });
                            if q.cols != r {
                                let mut rng = Pcg32::new(
                                    self.seed ^ fnv(&entry.name),
                                    step,
                                );
                                let mut m = Mat::zeros(cols, r);
                                for i in 0..cols {
                                    for j in 0..r {
                                        m.data[i * r + j] = if j < q.cols {
                                            q.data[i * q.cols + j]
                                        } else {
                                            rng.normal()
                                        };
                                    }
                                }
                                *q = m;
                            }
                            let mslab =
                                Mat::from_slice(rows, cols, &delta[lo..hi]);
                            let p = {
                                let _c = crate::obs::span(
                                    "compress",
                                    "compress.project",
                                );
                                matmul(&mslab, q)
                            };
                            submit(WireJob {
                                buf: p.data,
                                bytes: pass_bytes(rows * r),
                            })?;
                            queue.push_back(Op::Pass1 { idx: next, mslab, r });
                        } else {
                            let mut seg = self.take_scratch();
                            seg.extend_from_slice(&delta[lo..hi]);
                            submit(WireJob {
                                buf: seg,
                                bytes: pass_bytes(hi - lo),
                            })?;
                            queue.push_back(Op::Seg { idx: next });
                        }
                        next += 1;
                    }
                    // Drain: results arrive in submission order.
                    let Some(op) = queue.pop_front() else { break };
                    let buf = match res_rx.recv() {
                        Ok(Ok(b)) => b,
                        Ok(Err(e)) => return Err(e),
                        Err(_) => {
                            return Err(anyhow!("reduce wire lane hung up"))
                        }
                    };
                    match op {
                        Op::Pass1 { idx, mslab, r } => {
                            let entry = &spec[idx];
                            let (rows, cols) =
                                (entry.shape[0], entry.shape[1]);
                            payload_elems += rows * r;
                            scales += 1;
                            let mut p = Mat { rows, cols: r, data: buf };
                            {
                                let _c = crate::obs::span(
                                    "compress",
                                    "compress.quant",
                                );
                                if q_bits > 0 && q_bits < 32 {
                                    quantize::quantize_dequantize(
                                        &mut p.data,
                                        q_bits,
                                    );
                                }
                                orthonormalize_columns(&mut p);
                            }
                            let qn = {
                                let _c = crate::obs::span(
                                    "compress",
                                    "compress.project",
                                );
                                matmul_at_b(&mslab, &p)
                            };
                            submit(WireJob {
                                buf: qn.data,
                                bytes: pass_bytes(cols * r),
                            })?;
                            self.put_scratch(mslab.data);
                            queue.push_back(Op::Pass2 { idx, p, r });
                        }
                        Op::Pass2 { idx, p, r } => {
                            let entry = &spec[idx];
                            let cols = entry.shape[1];
                            payload_elems += cols * r;
                            scales += 1;
                            let mut qn =
                                Mat { rows: cols, cols: r, data: buf };
                            if q_bits > 0 && q_bits < 32 {
                                let _c = crate::obs::span(
                                    "compress",
                                    "compress.quant",
                                );
                                quantize::quantize_dequantize(
                                    &mut qn.data,
                                    q_bits,
                                );
                            }
                            let rec = {
                                let _c = crate::obs::span(
                                    "compress",
                                    "compress.project",
                                );
                                matmul_bt(&p, &qn)
                            };
                            let lo = entry.offset;
                            let hi = entry.offset + entry.numel();
                            delta[lo..hi].copy_from_slice(&rec.data);
                            self.bases.insert(entry.name.clone(), qn);
                            self.put_scratch(p.data);
                            self.put_scratch(rec.data);
                        }
                        Op::Seg { idx } => {
                            let entry = &spec[idx];
                            let lo = entry.offset;
                            let hi = entry.offset + entry.numel();
                            let mut seg = buf;
                            if q_bits > 0 && q_bits < 32 {
                                let _c = crate::obs::span(
                                    "compress",
                                    "compress.quant",
                                );
                                quantize::quantize_dequantize(
                                    &mut seg, q_bits,
                                );
                            }
                            payload_elems += hi - lo;
                            scales += 1;
                            delta[lo..hi].copy_from_slice(&seg);
                            self.put_scratch(seg);
                        }
                    }
                }
                drop(submit);
                drop(op_tx); // wire lane exits; the scope joins it
                Ok((payload_elems, scales))
            })?;
        Ok((payload_elems as u64 * bits + 7) / 8 + 4 * scales as u64)
    }
}

pub(crate) fn fnv(s: &str) -> u64 {
    let mut h: u64 = 0xcbf29ce484222325;
    for b in s.as_bytes() {
        h ^= *b as u64;
        h = h.wrapping_mul(0x100000001b3);
    }
    h
}

// ---------------------------------------------------------------------------
// RingLane: a single-lane DeltaReducer over a ring transport
// ---------------------------------------------------------------------------

type FlightResult =
    Result<(Box<dyn RingTransport>, WireCompressor, Vec<f32>, u64)>;

/// An overlapped reduction in flight: either its own spawned comm thread
/// (the historical shape) or a job on the persistent comm pool, joined
/// through a completion channel.  Both joins are blocking and total — a
/// parked pool thread never holds lane state past the join.
enum Flight {
    Thread(std::thread::JoinHandle<FlightResult>),
    Pooled(std::sync::mpsc::Receiver<FlightResult>),
}

impl Flight {
    /// Block until the reduction finishes; `None` means the comm thread
    /// panicked (or the pool worker died), which callers treat exactly
    /// like a failed reduction.
    fn join(self) -> Option<FlightResult> {
        match self {
            Flight::Thread(h) => h.join().ok(),
            Flight::Pooled(rx) => rx.recv().ok(),
        }
    }
}

/// One worker's (or one stage executor's) reducing lane: owns the ring
/// transport and the wire compressor, and realizes the engine's overlap
/// contract *structurally* — `begin` hands the pseudo-gradient to a comm
/// thread that runs the ring collective while the caller trains the next
/// H local steps; `complete` joins it.  In sync mode `begin` is a no-op
/// and `complete` reduces inline.
///
/// The lane survives membership churn: [`Self::reseed`] aborts any
/// in-flight reduction (its result is discarded — the raw delta stays
/// with the engine for the drain-or-discard decision) and installs the
/// new epoch's ring.  Compressor state resets on reseed so every
/// survivor re-derives low-rank bases identically from the shared
/// seed+round rule, whether or not it lost its bases to a dead comm
/// thread.
pub struct RingLane {
    member: Option<Box<dyn RingTransport>>,
    compressor: Option<WireCompressor>,
    method: Method,
    seed: u64,
    spec: Vec<ParamEntry>,
    overlap: bool,
    in_flight: Option<Flight>,
    /// Round hook deferred while the member is away on the comm thread
    /// (overlap): delivered as soon as the member returns, so
    /// round-indexed fault injection still fires.
    pending_round: Option<usize>,
    /// Fatal transport fault raised by a *deferred* round hook (e.g. an
    /// injected kill that fired while the member was away on the comm
    /// thread): delivered by the next [`Self::begin_round`] call, so
    /// fault-injection failures stay distinguishable from churn (reduce
    /// errors surface from `complete`, fatal faults from `begin_round`).
    pending_fault: Option<anyhow::Error>,
    /// Payload bytes of the most recently completed reduction.
    pub wire_last: u64,
    /// Cumulative payload bytes over the lane's lifetime.
    pub wire_total: u64,
    /// Low-rank software-pipeline depth applied to the compressor
    /// (1 = sequential; survives reseeds).
    pipeline_depth: usize,
    /// Run overlapped reductions on the persistent comm pool instead of
    /// spawning a thread per round.
    use_pool: bool,
}

impl RingLane {
    pub fn new(
        member: Box<dyn RingTransport>,
        method: Method,
        seed: u64,
        spec: Vec<ParamEntry>,
        overlap: bool,
    ) -> RingLane {
        RingLane {
            member: Some(member),
            compressor: Some(WireCompressor::new(method.clone(), seed)),
            method,
            seed,
            spec,
            overlap,
            in_flight: None,
            pending_round: None,
            pending_fault: None,
            wire_last: 0,
            wire_total: 0,
            pipeline_depth: 1,
            use_pool: false,
        }
    }

    /// A lane with no ring yet (elastic workers: the ring arrives with
    /// the first committed membership epoch via [`Self::reseed`]).
    pub fn unseeded(
        method: Method,
        seed: u64,
        spec: Vec<ParamEntry>,
        overlap: bool,
    ) -> RingLane {
        RingLane {
            member: None,
            compressor: None,
            method,
            seed,
            spec,
            overlap,
            in_flight: None,
            pending_round: None,
            pending_fault: None,
            wire_last: 0,
            wire_total: 0,
            pipeline_depth: 1,
            use_pool: false,
        }
    }

    /// Set the compressor's low-rank pipeline depth (≤ 1 = sequential).
    /// Must be set identically on every ring member; sticks across
    /// [`Self::reseed`].
    pub fn set_pipeline_depth(&mut self, depth: usize) {
        self.pipeline_depth = depth.max(1);
        if let Some(c) = self.compressor.as_mut() {
            c.set_pipeline_depth(self.pipeline_depth);
        }
    }

    /// Run overlapped reductions on the persistent comm pool
    /// ([`crate::comm::pool`]) instead of spawning one thread per round.
    /// Joins stay blocking, so a parked pool thread never outlives
    /// [`Self::reseed`]'s takeover of the lane state.
    pub fn set_use_pool(&mut self, on: bool) {
        self.use_pool = on;
    }

    /// Install a fresh ring for a new membership epoch, joining any
    /// never-joined in-flight reduction first.  Returns `Some(mean)` when
    /// that abandoned flight had actually COMPLETED before the epoch
    /// turned — the collective finished, so surviving peers already
    /// applied this very mean at their own joins; the caller must treat
    /// it as a late in-band join ([`RoundEngine::complete_in_flight_with`])
    /// rather than letting drain/discard re-count the delta.  A failed
    /// flight returns `None` (the engine still holds the raw delta for
    /// the drain-or-discard decision).  The compressor is recreated so
    /// all survivors re-derive identical low-rank bases.
    pub fn reseed(&mut self, member: Box<dyn RingTransport>) -> Option<Vec<f32>> {
        let mut completed = None;
        if let Some(handle) = self.in_flight.take() {
            if let Some(Ok((_, _, avg, bytes))) = handle.join() {
                self.wire_total += bytes;
                completed = Some(avg);
            }
        }
        self.member = Some(member);
        let mut c = WireCompressor::new(self.method.clone(), self.seed);
        c.set_pipeline_depth(self.pipeline_depth);
        self.compressor = Some(c);
        self.pending_round = None;
        self.wire_last = 0;
        completed
    }

    /// Raw (uncompressed, unmetered-by-the-ledger) ring mean over the
    /// current member — the elastic consensus resync after churn.
    pub fn consensus_mean(&mut self, buf: &mut [f32]) -> Result<()> {
        self.member
            .as_mut()
            .ok_or_else(|| anyhow!("lane has no ring member"))?
            .allreduce_mean(buf)
    }

    /// Fault-injection round hook.  While the member is away on a comm
    /// thread (overlap) the hook is deferred and delivered when the
    /// member returns in [`DeltaReducer::complete`]; a fatal fault raised
    /// by that deferred delivery surfaces from the NEXT `begin_round`
    /// call — one round late, but never silently dropped and never
    /// conflated with a churn error.
    pub fn begin_round(&mut self, round: usize) -> Result<()> {
        if let Some(e) = self.pending_fault.take() {
            return Err(e);
        }
        match self.member.as_mut() {
            Some(m) => m.begin_round(round),
            None => {
                self.pending_round = Some(round);
                Ok(())
            }
        }
    }

    /// The compressor, when not in flight (tests / inspection).
    pub fn compressor(&self) -> Option<&WireCompressor> {
        self.compressor.as_ref()
    }

    fn record(&mut self, bytes: u64) {
        self.wire_last = bytes;
        self.wire_total += bytes;
    }
}

impl DeltaReducer for RingLane {
    fn begin(&mut self, deltas: &[Vec<f32>], round: u64) -> Result<()> {
        if !self.overlap {
            return Ok(());
        }
        if deltas.len() != 1 {
            return Err(anyhow!("RingLane reduces exactly one lane"));
        }
        let mut m = self
            .member
            .take()
            .ok_or_else(|| anyhow!("ring member already in flight"))?;
        let mut c = self
            .compressor
            .take()
            .ok_or_else(|| anyhow!("compressor already in flight"))?;
        let spec = self.spec.clone();
        let mut delta = deltas[0].clone();
        // The comm thread inherits the launching worker's trace context:
        // its spans must attribute to the round the delta belongs to,
        // not whatever round the worker has advanced to by join time.
        let ctx = crate::obs::scope();
        let job = move || -> FlightResult {
            crate::obs::set_ctx(ctx);
            crate::obs::set_round(round as u32);
            let _s = crate::obs::span("lane", "reduce");
            let bytes = c.reduce(&mut *m, &mut delta, &spec, round)?;
            Ok((m, c, delta, bytes))
        };
        self.in_flight = Some(if self.use_pool {
            let (tx, rx) = std::sync::mpsc::channel();
            crate::comm::pool::shared().submit(move || {
                let _ = tx.send(job());
            });
            Flight::Pooled(rx)
        } else {
            Flight::Thread(std::thread::spawn(job))
        });
        Ok(())
    }

    fn complete(&mut self, deltas: &[Vec<f32>], round: u64) -> Result<Vec<f32>> {
        if let Some(handle) = self.in_flight.take() {
            let (m, c, avg, bytes) = handle
                .join()
                .ok_or_else(|| anyhow!("comm thread panicked"))??;
            self.member = Some(m);
            self.compressor = Some(c);
            self.record(bytes);
            if let Some(r) = self.pending_round.take() {
                // A fatal fault here (injected kill) must not masquerade
                // as a churn error: stash it for the next begin_round.
                if let Err(e) = self.member.as_mut().unwrap().begin_round(r) {
                    self.pending_fault = Some(e);
                }
            }
            return Ok(avg);
        }
        if deltas.len() != 1 {
            return Err(anyhow!("RingLane reduces exactly one lane"));
        }
        let mut delta = deltas[0].clone();
        let m = self
            .member
            .as_mut()
            .ok_or_else(|| anyhow!("ring member missing"))?;
        let c = self
            .compressor
            .as_mut()
            .ok_or_else(|| anyhow!("compressor missing"))?;
        let bytes = {
            let _s = crate::obs::span_at("lane", "reduce", round as u32);
            c.reduce(&mut **m, &mut delta, &self.spec, round)?
        };
        self.record(bytes);
        Ok(delta)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::comm::ring::build_ring;

    /// Reducer that averages the lanes in process (no wire).
    struct LocalMean;

    impl DeltaReducer for LocalMean {
        fn begin(&mut self, _d: &[Vec<f32>], _r: u64) -> Result<()> {
            Ok(())
        }

        fn complete(&mut self, deltas: &[Vec<f32>], _r: u64) -> Result<Vec<f32>> {
            let n = deltas[0].len();
            let mut avg = vec![0.0f32; n];
            for d in deltas {
                for i in 0..n {
                    avg[i] += d[i];
                }
            }
            let inv = 1.0 / deltas.len() as f32;
            avg.iter_mut().for_each(|x| *x *= inv);
            Ok(avg)
        }
    }

    /// Lossy reducer (halves the mean) to make error feedback observable.
    struct HalfMean;

    impl DeltaReducer for HalfMean {
        fn begin(&mut self, _d: &[Vec<f32>], _r: u64) -> Result<()> {
            Ok(())
        }

        fn complete(&mut self, deltas: &[Vec<f32>], _r: u64) -> Result<Vec<f32>> {
            let mut avg = LocalMean.complete(deltas, 0)?;
            avg.iter_mut().for_each(|x| *x *= 0.5);
            Ok(avg)
        }
    }

    #[test]
    fn sync_round_matches_manual_nesterov() {
        let n = 4;
        let mut eng = RoundEngine::new(
            vec![0.0; n],
            2,
            Nesterov::new(n, 0.5, 0.9),
            false,
            false,
        );
        let m0 = vec![1.0f32; n];
        let m1 = vec![3.0f32; n];
        let avg = eng
            .finish_round(vec![m0, m1], 1, &mut LocalMean)
            .unwrap()
            .unwrap();
        assert!(avg.iter().all(|&x| (x - 2.0).abs() < 1e-6));
        // Manual Nesterov: buf = 2, θ -= 0.5·(2 + 0.9·2) = 1.9.
        assert!(eng.theta().iter().all(|&x| (x + 1.9).abs() < 1e-6));
    }

    #[test]
    fn overlap_defers_first_application_and_drains() {
        let n = 3;
        let mut eng = RoundEngine::new(
            vec![0.0; n],
            1,
            Nesterov::new(n, 1.0, 0.0),
            true,
            false,
        );
        let r1 = eng
            .finish_round(vec![vec![1.0; n]], 1, &mut LocalMean)
            .unwrap();
        assert!(r1.is_none(), "round 1 must defer");
        assert_eq!(eng.theta(), &[0.0; 3][..]);
        // Round 2 applies round 1's delta.
        let r2 = eng
            .finish_round(vec![vec![5.0; n]], 2, &mut LocalMean)
            .unwrap()
            .unwrap();
        assert!(r2.iter().all(|&x| (x - 1.0).abs() < 1e-6));
        assert!(eng.theta().iter().all(|&x| (x + 1.0).abs() < 1e-6));
        // Drain applies round 2's delta.
        let d = eng.drain(&mut LocalMean).unwrap().unwrap();
        assert!(d.iter().all(|&x| (x - 5.0).abs() < 1e-6));
        assert!(eng.theta().iter().all(|&x| (x + 6.0).abs() < 1e-6));
        assert!(eng.drain(&mut LocalMean).unwrap().is_none());
    }

    #[test]
    fn error_feedback_accumulates_the_lost_half() {
        let n = 2;
        let mut eng = RoundEngine::new(
            vec![0.0; n],
            1,
            Nesterov::new(n, 1.0, 0.0),
            false,
            true,
        );
        let avg = eng
            .finish_round(vec![vec![2.0; n]], 1, &mut HalfMean)
            .unwrap()
            .unwrap();
        assert!(avg.iter().all(|&x| (x - 1.0).abs() < 1e-6));
        // e = raw − avg = 1; next round's δ = movement + 1.
        let avg2 = eng
            .finish_round(vec![vec![0.0; n]], 2, &mut HalfMean)
            .unwrap()
            .unwrap();
        assert!(avg2.iter().all(|&x| (x - 0.5).abs() < 1e-6));
    }

    #[test]
    fn overlap_error_feedback_matches_algorithm2_ordering() {
        // e^t must refresh from (δ^{t-1}, Δ^{t-1}) BEFORE δ^t forms.
        let n = 1;
        let mut eng = RoundEngine::new(
            vec![0.0; n],
            1,
            Nesterov::new(n, 1.0, 0.0),
            true,
            true,
        );
        assert!(eng
            .finish_round(vec![vec![4.0]], 1, &mut HalfMean)
            .unwrap()
            .is_none());
        // Join reduces δ¹=4 → Δ¹=2, e²=2; δ²=1+2=3 goes in flight.
        let a = eng
            .finish_round(vec![vec![1.0]], 2, &mut HalfMean)
            .unwrap()
            .unwrap();
        assert!((a[0] - 2.0).abs() < 1e-6);
        let d = eng.drain(&mut HalfMean).unwrap().unwrap();
        assert!((d[0] - 1.5).abs() < 1e-6, "Δ² = 3/2, got {}", d[0]);
    }

    #[test]
    fn discard_in_flight_folds_delta_and_consumes_it_once() {
        // The discard branch of churn recovery, error feedback OFF: the
        // in-flight delta becomes the error buffer, re-enters the next
        // round's δ exactly once, and is never re-counted.
        let mut eng = RoundEngine::new(
            vec![0.0; 1],
            1,
            Nesterov::new(1, 1.0, 0.0),
            true,
            false,
        );
        assert!(eng
            .finish_round(vec![vec![3.0]], 1, &mut LocalMean)
            .unwrap()
            .is_none());
        assert_eq!(eng.in_flight_round(), Some(1));
        assert_eq!(eng.discard_in_flight(), Some(1));
        assert_eq!(eng.in_flight_round(), None);
        // δ² = movement 2 + folded 3 = 5 goes in flight …
        assert!(eng
            .finish_round(vec![vec![2.0]], 2, &mut LocalMean)
            .unwrap()
            .is_none());
        let a = eng
            .finish_round(vec![vec![0.0]], 3, &mut LocalMean)
            .unwrap()
            .unwrap();
        assert!((a[0] - 5.0).abs() < 1e-6, "folded exactly once: {}", a[0]);
        // … and the buffer was consumed: δ³ carries nothing extra.
        let d = eng.drain(&mut LocalMean).unwrap().unwrap();
        assert!(d[0].abs() < 1e-6, "no re-count after the fold: {}", d[0]);
    }

    #[test]
    fn complete_in_flight_with_applies_like_an_in_band_join() {
        // The late-join rule (a churn-abandoned reduction that actually
        // completed): error refresh + outer step must match what an
        // in-band join would have done, with nothing left in flight.
        let mut eng = RoundEngine::new(
            vec![0.0; 1],
            1,
            Nesterov::new(1, 1.0, 0.0),
            true,
            true,
        );
        assert!(eng
            .finish_round(vec![vec![4.0]], 1, &mut HalfMean)
            .unwrap()
            .is_none());
        // The collective completed elsewhere with mean 2 (HalfMean of 4).
        assert_eq!(eng.complete_in_flight_with(&[2.0]), Some(1));
        assert_eq!(eng.in_flight_round(), None);
        // θ = 0 − 1.0·2 = −2, and e = δ¹ − Δ¹ = 2 (error feedback on).
        assert!((eng.theta()[0] + 2.0).abs() < 1e-6);
        // The next round behaves like a first overlap round (nothing in
        // flight) with δ² = 1 + e 2 = 3 → Δ² = 1.5 at the drain.
        assert!(eng
            .finish_round(vec![vec![1.0]], 2, &mut HalfMean)
            .unwrap()
            .is_none());
        let d = eng.drain(&mut HalfMean).unwrap().unwrap();
        assert!((d[0] - 1.5).abs() < 1e-6, "Δ² = 3/2, got {}", d[0]);
    }

    #[test]
    fn lane_count_mismatch_is_an_error() {
        let mut eng = RoundEngine::new(
            vec![0.0; 2],
            2,
            Nesterov::new(2, 1.0, 0.0),
            false,
            false,
        );
        assert!(eng
            .finish_round(vec![vec![0.0; 2]], 1, &mut LocalMean)
            .is_err());
    }

    #[test]
    fn ring_lane_overlap_and_sync_seed_identical_bases() {
        // Regression for the coordinator base-seeding bug: the overlap
        // path used to reduce with step = 0 while the sync path passed
        // the round, seeding different low-rank bases.  Both paths must
        // thread the round through to the compressor.
        let spec = vec![ParamEntry {
            name: "w".to_string(),
            shape: vec![8, 6],
            offset: 0,
        }];
        let delta: Vec<f32> = (0..48).map(|i| (i as f32 * 0.37).sin()).collect();
        let method = Method::LowRankQuant { rank: 2, q_bits: 0 };

        let m_sync = build_ring(1).remove(0);
        let mut sync = RingLane::new(
            Box::new(m_sync),
            method.clone(),
            99,
            spec.clone(),
            false,
        );
        let avg_sync = sync.complete(&[delta.clone()], 3).unwrap();

        let m_over = build_ring(1).remove(0);
        let mut over =
            RingLane::new(Box::new(m_over), method, 99, spec, true);
        over.begin(&[delta.clone()], 3).unwrap();
        let avg_over = over.complete(&[], 3).unwrap();

        assert_eq!(avg_sync, avg_over, "reduced outputs diverged");
        let b_sync = sync.compressor().unwrap().base("w").unwrap();
        let b_over = over.compressor().unwrap().base("w").unwrap();
        assert_eq!(b_sync.data, b_over.data, "base seeds diverged");
        assert!(sync.wire_total > 0);
        assert_eq!(sync.wire_total, over.wire_total);
    }

    #[test]
    fn ring_lane_sync_reduces_mean_across_members() {
        let members = build_ring(2);
        let spec = vec![ParamEntry {
            name: "b".to_string(),
            shape: vec![4],
            offset: 0,
        }];
        let inputs = [vec![1.0f32; 4], vec![3.0f32; 4]];
        let outs: Vec<Vec<f32>> = std::thread::scope(|scope| {
            members
                .into_iter()
                .zip(inputs.clone())
                .map(|(m, d)| {
                    let spec = spec.clone();
                    scope.spawn(move || {
                        let mut lane = RingLane::new(
                            Box::new(m),
                            Method::None,
                            7,
                            spec,
                            false,
                        );
                        lane.complete(&[d], 1).unwrap()
                    })
                })
                .collect::<Vec<_>>()
                .into_iter()
                .map(|h| h.join().unwrap())
                .collect()
        });
        for o in outs {
            assert!(o.iter().all(|&x| (x - 2.0).abs() < 1e-6));
        }
    }

    // -- pipelined low-rank reduce: bit-for-bit vs the sequential path --

    /// A multi-entry spec mixing 2-D and 1-D entries — the pipelined path
    /// only engages with more than one entry, and the mix exercises every
    /// `Op` variant (Pass1, Pass2, Seg) in flight together.
    fn pipelined_spec() -> (Vec<ParamEntry>, usize) {
        let shapes: &[(&str, &[usize])] = &[
            ("w0", &[8, 6]),
            ("b0", &[10]),
            ("w1", &[5, 4]),
            ("b1", &[7]),
            ("w2", &[6, 6]),
        ];
        let mut spec = Vec::new();
        let mut off = 0usize;
        for (name, shape) in shapes {
            let numel: usize = shape.iter().product();
            spec.push(ParamEntry {
                name: name.to_string(),
                shape: shape.to_vec(),
                offset: off,
            });
            off += numel;
        }
        (spec, off)
    }

    /// Reduce one deterministic per-rank delta on every member
    /// concurrently; returns `(reduced delta, payload bytes, meter
    /// total)` per rank.
    fn reduce_all(
        members: Vec<Box<dyn RingTransport>>,
        depth: usize,
    ) -> Vec<(Vec<f32>, u64, u64)> {
        let (spec, n) = pipelined_spec();
        std::thread::scope(|scope| {
            let handles: Vec<_> = members
                .into_iter()
                .enumerate()
                .map(|(rank, mut m)| {
                    let spec = spec.clone();
                    scope.spawn(move || {
                        let mut c = WireCompressor::new(
                            Method::LowRankQuant { rank: 2, q_bits: 4 },
                            42,
                        );
                        c.set_pipeline_depth(depth);
                        let mut delta: Vec<f32> = (0..n)
                            .map(|i| {
                                ((i + 1) as f32 * 0.13 + rank as f32).sin()
                            })
                            .collect();
                        let bytes =
                            c.reduce(&mut *m, &mut delta, &spec, 5).unwrap();
                        (delta, bytes, m.meter().total())
                    })
                })
                .collect();
            handles.into_iter().map(|h| h.join().unwrap()).collect()
        })
    }

    fn assert_bit_for_bit(
        seq: &[(Vec<f32>, u64, u64)],
        pip: &[(Vec<f32>, u64, u64)],
    ) {
        for (rank, (s, p)) in seq.iter().zip(pip).enumerate() {
            assert_eq!(s.0, p.0, "rank {rank}: reduced deltas diverged");
            assert_eq!(s.1, p.1, "rank {rank}: payload bytes diverged");
            assert_eq!(s.2, p.2, "rank {rank}: wire ledger diverged");
        }
    }

    #[test]
    fn pipelined_reduce_is_bit_for_bit_on_local_ring() {
        let seq = reduce_all(
            build_ring(2).into_iter().map(|m| Box::new(m) as _).collect(),
            1,
        );
        let pip = reduce_all(
            build_ring(2).into_iter().map(|m| Box::new(m) as _).collect(),
            3,
        );
        assert!(seq[0].1 > 0 && seq[0].2 > 0);
        assert_bit_for_bit(&seq, &pip);
    }

    #[test]
    fn pipelined_reduce_is_bit_for_bit_under_fault_wrapper() {
        use crate::transport::faulty::{FaultPlan, FaultyRing};
        let wrap = || -> Vec<Box<dyn RingTransport>> {
            build_ring(2)
                .into_iter()
                .map(|m| {
                    Box::new(FaultyRing::new(m, FaultPlan::quiet(9))) as _
                })
                .collect()
        };
        assert_bit_for_bit(&reduce_all(wrap(), 1), &reduce_all(wrap(), 3));
    }

    #[test]
    fn pipelined_reduce_is_bit_for_bit_on_loopback_tcp() {
        use crate::transport::tcp::form_ring;
        use std::net::TcpListener;
        use std::time::Duration;
        // Each member forms its TCP ring and runs the sequential and the
        // pipelined reduction back to back over the same sockets — the
        // collectives act as barriers, so the two runs stay in lockstep
        // across the ring.
        let (spec, n) = pipelined_spec();
        let listeners: Vec<TcpListener> = (0..2)
            .map(|_| TcpListener::bind("127.0.0.1:0").unwrap())
            .collect();
        let members: Vec<(u32, u16)> = listeners
            .iter()
            .enumerate()
            .map(|(i, l)| (i as u32, l.local_addr().unwrap().port()))
            .collect();
        let per_rank: Vec<((Vec<f32>, u64, u64), (Vec<f32>, u64, u64))> =
            std::thread::scope(|scope| {
                let handles: Vec<_> = listeners
                    .iter()
                    .enumerate()
                    .map(|(rank, listener)| {
                        let members = members.clone();
                        let spec = spec.clone();
                        scope.spawn(move || {
                            let mut ring = form_ring(
                                rank as u32,
                                1,
                                &members,
                                listener,
                                Duration::from_secs(10),
                                Duration::from_secs(10),
                            )
                            .unwrap();
                            let delta0: Vec<f32> = (0..n)
                                .map(|i| {
                                    ((i + 1) as f32 * 0.13 + rank as f32)
                                        .sin()
                                })
                                .collect();
                            let mut run = |depth: usize, base: u64| {
                                let mut c = WireCompressor::new(
                                    Method::LowRankQuant {
                                        rank: 2,
                                        q_bits: 4,
                                    },
                                    42,
                                );
                                c.set_pipeline_depth(depth);
                                let mut d = delta0.clone();
                                let bytes = c
                                    .reduce(&mut ring, &mut d, &spec, 5)
                                    .unwrap();
                                (d, bytes, ring.meter().total() - base)
                            };
                            let seq = run(1, 0);
                            let wire_after_seq = seq.2;
                            let pip = run(3, wire_after_seq);
                            (seq, pip)
                        })
                    })
                    .collect();
                handles.into_iter().map(|h| h.join().unwrap()).collect()
            });
        for (rank, (seq, pip)) in per_rank.iter().enumerate() {
            assert!(seq.2 > 0, "rank {rank}: nothing crossed the wire");
            assert_eq!(seq.0, pip.0, "rank {rank}: deltas diverged over TCP");
            assert_eq!(seq.1, pip.1, "rank {rank}: payload bytes diverged");
            assert_eq!(seq.2, pip.2, "rank {rank}: wire ledger diverged");
        }
    }

    #[test]
    fn pipelined_reduce_handles_fewer_elements_than_members() {
        // Every ring pass in this spec is shorter than the 5-member ring
        // (w: 2×2 → 4-elem P/Q′ passes, b: a 3-elem segment), so each
        // chunked allreduce runs with empty chunks on some ranks.  The
        // sequential and the pipelined path must still agree bit for bit,
        // payload bytes and wire ledger included.
        let shapes: &[(&str, &[usize])] = &[("w", &[2, 2]), ("b", &[3])];
        let mut spec = Vec::new();
        let mut off = 0usize;
        for (name, shape) in shapes {
            spec.push(ParamEntry {
                name: name.to_string(),
                shape: shape.to_vec(),
                offset: off,
            });
            off += shape.iter().product::<usize>();
        }
        let n = off;
        // Returns per-rank (delta, payload bytes) plus the fleet-wide
        // wire total, read after every thread joined (the shared meter is
        // only deterministic once the whole collective has finished).
        let run = |depth: usize| -> (Vec<(Vec<f32>, u64)>, u64) {
            let raw = build_ring(5);
            let meter = std::sync::Arc::clone(&raw[0].meter);
            let members: Vec<Box<dyn RingTransport>> =
                raw.into_iter().map(|m| Box::new(m) as _).collect();
            let per_rank = std::thread::scope(|scope| {
                let handles: Vec<_> = members
                    .into_iter()
                    .enumerate()
                    .map(|(rank, mut m)| {
                        let spec = spec.clone();
                        scope.spawn(move || {
                            let mut c = WireCompressor::new(
                                Method::LowRankQuant { rank: 2, q_bits: 4 },
                                42,
                            );
                            c.set_pipeline_depth(depth);
                            let mut delta: Vec<f32> = (0..n)
                                .map(|i| {
                                    ((i + 1) as f32 * 0.31 + rank as f32)
                                        .cos()
                                })
                                .collect();
                            let bytes = c
                                .reduce(&mut *m, &mut delta, &spec, 3)
                                .unwrap();
                            (delta, bytes)
                        })
                    })
                    .collect();
                handles
                    .into_iter()
                    .map(|h| h.join().unwrap())
                    .collect::<Vec<_>>()
            });
            (per_rank, meter.total())
        };
        let (seq, seq_wire) = run(1);
        let (pip, pip_wire) = run(3);
        assert!(seq_wire > 0, "nothing crossed the wire");
        assert_eq!(seq_wire, pip_wire, "wire ledger diverged");
        for (rank, (s, p)) in seq.iter().zip(&pip).enumerate() {
            assert_eq!(s.0, p.0, "rank {rank}: reduced deltas diverged");
            assert_eq!(s.1, p.1, "rank {rank}: payload bytes diverged");
        }
        // All ranks agree on the reduced delta (it is a mean).
        for (rank, s) in seq.iter().enumerate().skip(1) {
            assert_eq!(s.0, seq[0].0, "rank {rank} disagrees with rank 0");
        }
    }

    #[test]
    fn pooled_lane_flight_joins_and_survives_reseed() {
        // Overlapped flights on the persistent comm pool: the join-then-
        // begin cadence reuses a parked worker round after round, and
        // `reseed` joins an abandoned completed flight so no pool thread
        // holds lane state past the epoch turn.
        crate::comm::pool::configure(2);
        let spec = vec![ParamEntry {
            name: "b".to_string(),
            shape: vec![4],
            offset: 0,
        }];
        let m = build_ring(1).remove(0);
        let mut lane =
            RingLane::new(Box::new(m), Method::None, 7, spec, true);
        lane.set_use_pool(true);
        for round in 1..=10u64 {
            let d = vec![round as f32; 4];
            lane.begin(&[d.clone()], round).unwrap();
            // Size-1 ring: the mean is the member's own delta.
            assert_eq!(lane.complete(&[], round).unwrap(), d);
        }
        let wire_before = lane.wire_total;
        assert!(wire_before > 0);

        // Abandon a completed pooled flight, then turn the epoch: reseed
        // must join it and hand back the mean (the late-join rule), with
        // the lane immediately usable on the new ring.
        lane.begin(&[vec![6.0; 4]], 11).unwrap();
        let late = lane.reseed(Box::new(build_ring(1).remove(0)));
        assert_eq!(late, Some(vec![6.0; 4]));
        assert!(lane.wire_total > wire_before, "abandoned flight unmetered");
        assert_eq!(lane.complete(&[vec![1.5; 4]], 12).unwrap(), vec![1.5; 4]);
    }
}
