//! Dense f32 linear algebra substrate for the compression hot path
//! (PowerSGD factors are small: rows x r and cols x r with r <= 2048).
//!
//! Row-major matrices; the matmul is blocked + transposed-B so the inner
//! loop is a contiguous dot product the compiler auto-vectorizes.  This is
//! the L3-native path used for arbitrary pseudo-gradient shapes; the
//! pallas/HLO `lowrank_iter` program is the L1 path for artifact-shaped
//! matrices (see DESIGN.md).

#[derive(Clone, Debug, PartialEq)]
pub struct Mat {
    pub rows: usize,
    pub cols: usize,
    pub data: Vec<f32>,
}

impl Mat {
    pub fn zeros(rows: usize, cols: usize) -> Mat {
        Mat { rows, cols, data: vec![0.0; rows * cols] }
    }

    pub fn from_vec(rows: usize, cols: usize, data: Vec<f32>) -> Mat {
        assert_eq!(data.len(), rows * cols);
        Mat { rows, cols, data }
    }

    pub fn from_slice(rows: usize, cols: usize, s: &[f32]) -> Mat {
        Self::from_vec(rows, cols, s.to_vec())
    }

    #[inline]
    pub fn at(&self, r: usize, c: usize) -> f32 {
        self.data[r * self.cols + c]
    }

    #[inline]
    pub fn at_mut(&mut self, r: usize, c: usize) -> &mut f32 {
        &mut self.data[r * self.cols + c]
    }

    pub fn row(&self, r: usize) -> &[f32] {
        &self.data[r * self.cols..(r + 1) * self.cols]
    }

    pub fn transpose(&self) -> Mat {
        let mut t = Mat::zeros(self.cols, self.rows);
        for r in 0..self.rows {
            for c in 0..self.cols {
                t.data[c * self.rows + r] = self.data[r * self.cols + c];
            }
        }
        t
    }

    pub fn frob_norm(&self) -> f64 {
        self.data.iter().map(|&x| (x as f64).powi(2)).sum::<f64>().sqrt()
    }
}

/// c = a @ b.  Blocked over k with B pre-transposed: the inner loop is a
/// contiguous dot product over `k`, which LLVM vectorizes.
pub fn matmul(a: &Mat, b: &Mat) -> Mat {
    assert_eq!(a.cols, b.rows, "matmul shape mismatch");
    let bt = b.transpose();
    matmul_bt(a, &bt)
}

/// c = a @ bt.T where bt is already transposed (bt: [n, k]).
pub fn matmul_bt(a: &Mat, bt: &Mat) -> Mat {
    assert_eq!(a.cols, bt.cols, "matmul_bt shape mismatch");
    let (m, k, n) = (a.rows, a.cols, bt.rows);
    let mut c = Mat::zeros(m, n);
    for i in 0..m {
        let arow = &a.data[i * k..(i + 1) * k];
        let crow = &mut c.data[i * n..(i + 1) * n];
        for j in 0..n {
            let brow = &bt.data[j * k..(j + 1) * k];
            crow[j] = dot(arow, brow);
        }
    }
    c
}

/// c = a.T @ b computed without materializing a.T (a: [k, m], b: [k, n]).
/// Accumulates rank-1 updates row by row — cache-friendly for tall a, b.
pub fn matmul_at_b(a: &Mat, b: &Mat) -> Mat {
    assert_eq!(a.rows, b.rows, "matmul_at_b shape mismatch");
    let (k, m, n) = (a.rows, a.cols, b.cols);
    let mut c = Mat::zeros(m, n);
    for t in 0..k {
        let arow = &a.data[t * m..(t + 1) * m];
        let brow = &b.data[t * n..(t + 1) * n];
        for i in 0..m {
            let ai = arow[i];
            if ai != 0.0 {
                let crow = &mut c.data[i * n..(i + 1) * n];
                for (cj, &bj) in crow.iter_mut().zip(brow) {
                    *cj += ai * bj;
                }
            }
        }
    }
    c
}

#[inline]
pub fn dot(a: &[f32], b: &[f32]) -> f32 {
    debug_assert_eq!(a.len(), b.len());
    // chunks_exact gives LLVM bounds-check-free 8-lane bodies it can
    // vectorize (§Perf: ~1.8x over the indexed form on the reducer path).
    let mut acc = [0.0f32; 8];
    let (ca, cb) = (a.chunks_exact(8), b.chunks_exact(8));
    let (ra, rb) = (ca.remainder(), cb.remainder());
    for (x, y) in ca.zip(cb) {
        for l in 0..8 {
            acc[l] += x[l] * y[l];
        }
    }
    let mut s: f32 = acc.iter().sum();
    for (x, y) in ra.iter().zip(rb) {
        s += x * y;
    }
    s
}

pub fn axpy(alpha: f32, x: &[f32], y: &mut [f32]) {
    debug_assert_eq!(x.len(), y.len());
    for (yi, &xi) in y.iter_mut().zip(x) {
        *yi += alpha * xi;
    }
}

pub fn scale(alpha: f32, x: &mut [f32]) {
    for xi in x.iter_mut() {
        *xi *= alpha;
    }
}

/// In-place modified Gram-Schmidt orthonormalization of the *columns* of p.
/// Mirrors `ref.orthonormalize` (python) including the 1e-8 norm floor.
pub fn orthonormalize_columns(p: &mut Mat) {
    let (m, r) = (p.rows, p.cols);
    for j in 0..r {
        for prev in 0..j {
            // proj = <col_prev, col_j>
            let mut proj = 0.0f32;
            for i in 0..m {
                proj += p.data[i * r + prev] * p.data[i * r + j];
            }
            for i in 0..m {
                let sub = proj * p.data[i * r + prev];
                p.data[i * r + j] -= sub;
            }
        }
        let mut norm = 0.0f32;
        for i in 0..m {
            norm += p.data[i * r + j].powi(2);
        }
        let norm = norm.sqrt().max(1e-8);
        for i in 0..m {
            p.data[i * r + j] /= norm;
        }
    }
}

/// One PowerSGD-style power iteration (mirrors ref.lowrank_iter):
/// p = orth(m @ q); q_next = m.T @ p.  Reconstruction = p @ q_next.T.
pub fn lowrank_iter(m: &Mat, q: &Mat) -> (Mat, Mat) {
    let mut p = matmul(m, q);
    orthonormalize_columns(&mut p);
    let q_next = matmul_at_b(m, &p);
    (p, q_next)
}

pub fn lowrank_reconstruct(p: &Mat, q_next: &Mat) -> Mat {
    matmul_bt(p, q_next)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::check::{close_slice, props};
    use crate::util::rng::Pcg32;

    fn randmat(rng: &mut Pcg32, r: usize, c: usize) -> Mat {
        let mut m = Mat::zeros(r, c);
        rng.fill_normal(&mut m.data, 0.0, 1.0);
        m
    }

    fn naive_matmul(a: &Mat, b: &Mat) -> Mat {
        let mut c = Mat::zeros(a.rows, b.cols);
        for i in 0..a.rows {
            for j in 0..b.cols {
                let mut s = 0.0;
                for t in 0..a.cols {
                    s += a.at(i, t) * b.at(t, j);
                }
                *c.at_mut(i, j) = s;
            }
        }
        c
    }

    #[test]
    fn matmul_matches_naive_property() {
        props(10).runs(30).check(|g| {
            let (m, k, n) = (
                g.usize_in(1, 33),
                g.usize_in(1, 40),
                g.usize_in(1, 29),
            );
            let mut rng = Pcg32::seed_from(g.rng.next_u64());
            let a = randmat(&mut rng, m, k);
            let b = randmat(&mut rng, k, n);
            close_slice(
                &matmul(&a, &b).data,
                &naive_matmul(&a, &b).data,
                1e-4,
                "matmul",
            )
        });
    }

    #[test]
    fn matmul_at_b_matches_transpose_form() {
        props(11).runs(30).check(|g| {
            let (k, m, n) = (
                g.usize_in(1, 37),
                g.usize_in(1, 24),
                g.usize_in(1, 31),
            );
            let mut rng = Pcg32::seed_from(g.rng.next_u64());
            let a = randmat(&mut rng, k, m);
            let b = randmat(&mut rng, k, n);
            close_slice(
                &matmul_at_b(&a, &b).data,
                &matmul(&a.transpose(), &b).data,
                1e-4,
                "atb",
            )
        });
    }

    #[test]
    fn transpose_involution() {
        let mut rng = Pcg32::seed_from(1);
        let a = randmat(&mut rng, 7, 13);
        assert_eq!(a.transpose().transpose(), a);
    }

    #[test]
    fn orthonormalized_columns_have_identity_gram() {
        let mut rng = Pcg32::seed_from(2);
        let mut p = randmat(&mut rng, 40, 8);
        orthonormalize_columns(&mut p);
        let gram = matmul_at_b(&p, &p);
        for i in 0..8 {
            for j in 0..8 {
                let want = if i == j { 1.0 } else { 0.0 };
                assert!(
                    (gram.at(i, j) - want).abs() < 1e-4,
                    "gram[{i}][{j}]={}",
                    gram.at(i, j)
                );
            }
        }
    }

    #[test]
    fn lowrank_exact_on_lowrank_input() {
        let mut rng = Pcg32::seed_from(3);
        let u = randmat(&mut rng, 30, 4);
        let w = randmat(&mut rng, 4, 50);
        let m = matmul(&u, &w); // rank 4
        let q0 = randmat(&mut rng, 50, 4);
        let (p, qn) = lowrank_iter(&m, &q0);
        let rec = lowrank_reconstruct(&p, &qn);
        let err: f64 = rec
            .data
            .iter()
            .zip(&m.data)
            .map(|(&a, &b)| ((a - b) as f64).powi(2))
            .sum::<f64>()
            .sqrt();
        assert!(err / m.frob_norm() < 1e-3, "rel err {}", err / m.frob_norm());
    }

    #[test]
    fn lowrank_error_monotone_in_rank() {
        let mut rng = Pcg32::seed_from(4);
        let m = randmat(&mut rng, 48, 64);
        let mut errs = vec![];
        for r in [1usize, 4, 16, 48] {
            let q0 = randmat(&mut rng, 64, r);
            let (p, qn) = lowrank_iter(&m, &q0);
            let rec = lowrank_reconstruct(&p, &qn);
            let err: f64 = rec
                .data
                .iter()
                .zip(&m.data)
                .map(|(&a, &b)| ((a - b) as f64).powi(2))
                .sum::<f64>()
                .sqrt();
            errs.push(err / m.frob_norm());
        }
        for w in errs.windows(2) {
            assert!(w[1] <= w[0] + 1e-6, "{errs:?}");
        }
        assert!(errs[3] < 1e-3, "full rank should be near-exact: {errs:?}");
    }

    #[test]
    fn axpy_scale_dot() {
        let mut y = vec![1.0, 2.0, 3.0];
        axpy(2.0, &[1.0, 1.0, 1.0], &mut y);
        assert_eq!(y, vec![3.0, 4.0, 5.0]);
        scale(0.5, &mut y);
        assert_eq!(y, vec![1.5, 2.0, 2.5]);
        assert_eq!(dot(&[1.0, 2.0], &[3.0, 4.0]), 11.0);
    }
}
