//! Trainers: DiLoCoX (paper Algorithm 2) and the three baselines
//! (AllReduce, OpenDiLoCo, CocktailSGD), all running *real numerics*
//! through the PJRT runtime on a small preset while metering wire bytes
//! and modeling WAN time at the configured bandwidth.
//!
//! One-step-delay overlap (§2.3) and Algorithm 2's error feedback
//! (e^t = δ^{t-1} − Δ^{t-1}) are NOT implemented here: the trainer drives
//! the shared outer-round engine ([`crate::rounds::RoundEngine`]) — the
//! same state machine the threaded coordinator, the elastic workers, and
//! the stage-parallel executor consume — plugging in an in-process
//! [`GroupReducer`]-backed [`DeltaReducer`] that reduces every replica
//! lane at once and feeds the Alg-3 adaptive rank/H controller.  With
//! overlap disabled the engine synchronizes immediately (the "w/o
//! Overlap" ablation).

use crate::comm::{parameter_server_seconds, ring_allreduce_seconds};
use crate::compress::adaptive::AdaptiveCompression;
use crate::compress::{GroupReducer, Method};
use crate::config::{Algo, ExperimentConfig};
use crate::data::{MarkovCorpus, ShardIter};
use crate::metrics::{RunMetrics, StepRecord};
use crate::optim::{AdamW, Nesterov};
use crate::rounds::{movement, DeltaReducer, RoundEngine};
use crate::runtime::manifest::ParamEntry;
use crate::runtime::Runtime;
use anyhow::{Context, Result};
use std::sync::Arc;
use std::time::Instant;

#[derive(Clone, Debug)]
pub struct RunOpts {
    /// Batches in the fixed held-out eval set.
    pub eval_batches: usize,
    /// Evaluate every k outer steps (0 = only at the end).
    pub eval_every: usize,
    pub log_every: usize,
    /// Override artifacts dir (tests use the tiny bundle).
    pub artifacts_dir: Option<String>,
    pub quiet: bool,
}

impl Default for RunOpts {
    fn default() -> Self {
        RunOpts {
            eval_batches: 4,
            eval_every: 1,
            log_every: 1,
            artifacts_dir: None,
            quiet: false,
        }
    }
}

pub struct TrainOutcome {
    pub metrics: RunMetrics,
    /// Final global parameters (for checkpoint-style comparisons).
    pub params: Vec<f32>,
    pub eval_curve: Vec<(usize, f32)>,
}

struct Replica {
    params: Vec<f32>,
    inner: AdamW,
    shard: ShardIter,
    /// Per-inner-step error feedback (CocktailSGD only; local-SGD error
    /// feedback lives in the round engine).
    error: Vec<f32>,
}

/// [`DeltaReducer`] over the in-process [`GroupReducer`]: reduces all
/// replica lanes at once, meters the payload, and lets the adaptive
/// controller observe each completed mean.
struct TrainReducer<'a> {
    reducer: &'a mut GroupReducer,
    spec: &'a [ParamEntry],
    adaptive: &'a mut Option<AdaptiveCompression>,
    /// H for the next round, when the controller adjusted it.
    h_next: Option<usize>,
    payload: u64,
    ratio: f64,
}

impl DeltaReducer for TrainReducer<'_> {
    fn begin(&mut self, _deltas: &[Vec<f32>], _round: u64) -> Result<()> {
        Ok(())
    }

    fn complete(&mut self, deltas: &[Vec<f32>], round: u64) -> Result<Vec<f32>> {
        let out = self.reducer.reduce(deltas, self.spec, round);
        if let Some(ctl) = self.adaptive.as_mut() {
            let (r_next, h_next) = ctl.observe(&out.avg, self.spec);
            self.reducer.set_rank(r_next);
            self.h_next = Some(h_next);
        }
        self.payload = out.payload_bytes;
        self.ratio = out.ratio;
        Ok(out.avg)
    }
}

/// Map an experiment config onto a compression method (paper table of
/// per-algorithm settings).
pub fn method_for(cfg: &ExperimentConfig) -> Method {
    let c = &cfg.compression;
    if !c.enabled {
        return Method::None;
    }
    match cfg.algo {
        Algo::AllReduce => Method::None,
        Algo::OpenDiLoCo => Method::Quant { q_bits: c.q_bits.max(16) },
        Algo::CocktailSgd => Method::Cocktail {
            random_ratio: c.random_ratio,
            topk_ratio: c.topk_ratio,
            q_bits: c.q_bits,
        },
        Algo::DiLoCoX => {
            if c.rank > 0 {
                Method::LowRankQuant { rank: c.rank, q_bits: c.q_bits }
            } else {
                Method::Quant { q_bits: c.q_bits }
            }
        }
    }
}

/// WAN seconds for one sync of `payload` bytes under this method.
fn comm_seconds(method: &Method, payload: u64, cfg: &ExperimentConfig) -> f64 {
    if method.allreduce_compatible() {
        ring_allreduce_seconds(payload, &cfg.network)
    } else {
        parameter_server_seconds(payload / 2, payload / 2, &cfg.network)
    }
}

pub fn run_experiment(cfg: &ExperimentConfig, opts: &RunOpts) -> Result<TrainOutcome> {
    cfg.validate()?;
    let dir = opts
        .artifacts_dir
        .clone()
        .unwrap_or_else(|| cfg.artifacts_dir.clone());
    let rt = Runtime::load(&dir)
        .with_context(|| format!("loading artifacts from {dir}"))?;
    cfg.validate_with_manifest(&rt.manifest)?;
    rt.precompile(&["step_single", "eval_single"])?;
    run_with_runtime(cfg, opts, &rt)
}

/// Core loop, reusing an already-loaded runtime (benches share one).
pub fn run_with_runtime(
    cfg: &ExperimentConfig,
    opts: &RunOpts,
    rt: &Runtime,
) -> Result<TrainOutcome> {
    if cfg.parallel.pp > 1 {
        return Err(anyhow::anyhow!(
            "the single-process trainer runs the monolithic model; \
             stage-parallel execution (parallel.pp > 1) runs under \
             `dilocox coordinate`"
        ));
    }
    let man = &rt.manifest;
    let spec = man.param_specs["single"].clone();
    let n = man.param_count;
    let d = cfg.parallel.dp;
    let (b, s) = (man.dims.microbatch, man.dims.seq_len);
    let tokens_per_step = (b * s) as u64;

    let corpus = Arc::new(MarkovCorpus::new(man.dims.vocab_size, cfg.train.seed));
    let theta0 = man.read_f32(&man.init["single"].file)?;

    let mut replicas: Vec<Replica> = (0..d)
        .map(|i| Replica {
            params: theta0.clone(),
            inner: AdamW::new(n, cfg.train.inner_lr, cfg.train.weight_decay),
            shard: ShardIter::new(
                Arc::clone(&corpus),
                i,
                cfg.train.seed,
                b,
                s,
            ),
            error: vec![0.0; n],
        })
        .collect();

    let is_local_sgd = matches!(cfg.algo, Algo::DiLoCoX | Algo::OpenDiLoCo);

    // Global parameter track.  Local-SGD algorithms drive the shared
    // outer-round engine (D lanes, one per replica); AllReduce/Cocktail
    // keep a plain synchronized vector stepped by the inner optimizer —
    // the engine (θ copy + momentum + D error lanes) is only built when
    // a path actually consumes it.
    let mut engine = is_local_sgd.then(|| {
        RoundEngine::new(
            theta0.clone(),
            d,
            Nesterov::new(n, cfg.train.outer_lr, cfg.train.outer_momentum),
            cfg.train.overlap,
            cfg.compression.error_feedback,
        )
    });
    let mut theta_g = theta0.clone();

    let method = method_for(cfg);
    let mut reducer = GroupReducer::new(method.clone(), cfg.train.seed);
    let mut adaptive = if cfg.compression.adaptive && cfg.compression.rank > 0 {
        Some(AdaptiveCompression::new(
            cfg.compression.rank,
            cfg.train.local_steps,
            cfg.compression.rank_window,
            cfg.compression.min_rank,
        ))
    } else {
        None
    };

    // Held-out eval set (shared across algorithms for comparability).
    let mut eval_iter = ShardIter::new(Arc::clone(&corpus), 9999, cfg.train.seed ^ 0xe7a1, b, s);
    let eval_set: Vec<(Vec<i32>, Vec<i32>)> =
        (0..opts.eval_batches).map(|_| eval_iter.next_batch()).collect();
    let eval = |params: &[f32]| -> Result<f32> {
        let mut acc = 0.0f32;
        for (t, l) in &eval_set {
            acc += rt.eval_single(params, t, l)?;
        }
        Ok(acc / eval_set.len() as f32)
    };

    let mut metrics = RunMetrics::new(cfg.algo.name());
    let mut eval_curve = Vec::new();
    let mut inner_steps_done = 0usize;
    let mut h_current = cfg.train.local_steps;

    for t in 1..=cfg.train.outer_steps {
        let t0 = Instant::now();
        let mut loss_acc = 0.0f64;
        let mut loss_count = 0usize;

        // Per-replica anchors: δ^t measures this round's local movement
        // (Alg 2's θ^{t-1}_{i,j}), so in-flight progress is never counted
        // twice when the outer update lags by one step.
        let anchors: Vec<Vec<f32>> = if is_local_sgd {
            replicas.iter().map(|r| r.params.clone()).collect()
        } else {
            Vec::new()
        };

        if is_local_sgd {
            // H local AdamW steps per replica.
            for rep in replicas.iter_mut() {
                for _ in 0..h_current {
                    let (tok, lab) = rep.shard.next_batch();
                    let (loss, grads) = rt.step_single(&rep.params, &tok, &lab)?;
                    rep.inner.step(&mut rep.params, &grads);
                    loss_acc += loss as f64;
                    loss_count += 1;
                }
            }
        } else {
            // AllReduce / CocktailSGD: every "outer step" here is
            // h_current fully synchronous data-parallel steps.
            for _ in 0..h_current {
                let mut grads_all: Vec<Vec<f32>> = Vec::with_capacity(d);
                for rep in replicas.iter_mut() {
                    let (tok, lab) = rep.shard.next_batch();
                    let (loss, mut grads) =
                        rt.step_single(&rep.params, &tok, &lab)?;
                    loss_acc += loss as f64;
                    loss_count += 1;
                    if cfg.algo == Algo::CocktailSgd {
                        // Error feedback on the gradient itself.
                        for (g, e) in grads.iter_mut().zip(&rep.error) {
                            *g += e;
                        }
                    }
                    grads_all.push(grads);
                }
                let out = reducer.reduce(&grads_all, &spec, inner_steps_done as u64);
                if cfg.algo == Algo::CocktailSgd {
                    for (rep, g) in replicas.iter_mut().zip(&grads_all) {
                        for i in 0..n {
                            rep.error[i] = g[i] - out.avg[i];
                        }
                    }
                }
                // Shared AdamW step on the averaged gradient: all replicas
                // stay identical; step replica 0's optimizer and copy.
                replicas[0].inner.step(&mut theta_g, &out.avg);
                for rep in replicas.iter_mut() {
                    rep.params.copy_from_slice(&theta_g);
                }
                inner_steps_done += 1;
            }
        }

        let compute_secs = t0.elapsed().as_secs_f64();

        // ---- synchronization phase -------------------------------------
        let (wire_bytes, comm_secs, ratio, rank_used) = if is_local_sgd {
            inner_steps_done += h_current;
            let rank_used = adaptive
                .as_ref()
                .map(|a| a.current().0)
                .unwrap_or(cfg.compression.rank);

            // This round's raw movement per replica; the engine owns the
            // error feedback, the overlap join ordering, and the outer
            // update (Algorithm 2 — see crate::rounds).
            let movements: Vec<Vec<f32>> = replicas
                .iter()
                .zip(&anchors)
                .map(|(rep, anchor)| movement(anchor, &rep.params))
                .collect();
            let mut red = TrainReducer {
                reducer: &mut reducer,
                spec: &spec,
                adaptive: &mut adaptive,
                h_next: None,
                payload: 0,
                ratio: 1.0,
            };
            let eng = engine.as_mut().expect("local-SGD engine");
            let applied = eng.finish_round(movements, t as u64, &mut red)?;
            if let Some(h) = red.h_next {
                h_current = h;
            }
            let (payload, ratio) = (red.payload, red.ratio);
            if applied.is_some() {
                for rep in replicas.iter_mut() {
                    rep.params.copy_from_slice(eng.theta());
                }
            }
            let comm = if payload > 0 {
                comm_seconds(&method, payload, cfg)
            } else {
                0.0 // first overlap round: nothing was in flight
            };
            (payload, comm, ratio, rank_used)
        } else {
            // AllReduce/Cocktail synced every inner step already; account
            // the per-step payloads for this block of h_current steps.
            let payload = match &method {
                Method::None => 4 * n as u64,
                Method::Cocktail { .. } => {
                    // recompute the payload accounting from the reducer's
                    // outcome ratio is noisy; derive from method directly.
                    let k_rand = ((n as f64)
                        * cfg.compression.random_ratio as f64)
                        .round() as usize;
                    let k_top = ((k_rand as f64)
                        * cfg.compression.topk_ratio as f64)
                        .round()
                        .max(1.0) as usize;
                    let q = cfg.compression.q_bits.max(1) as u64;
                    2 * ((q * k_top as u64 + 7) / 8 + 4 + 4 * k_top as u64) + 8
                }
                _ => 4 * n as u64,
            };
            let per_step = comm_seconds(&method, payload, cfg);
            (
                payload * h_current as u64,
                per_step * h_current as f64,
                (4 * n as u64) as f64 / payload as f64,
                0,
            )
        };

        // Modeled elapsed: with overlap, WAN time hides behind compute.
        let elapsed = if cfg.train.overlap && is_local_sgd {
            compute_secs.max(comm_secs)
        } else {
            compute_secs + comm_secs
        };

        let mean_loss = if loss_count > 0 {
            (loss_acc / loss_count as f64) as f32
        } else {
            f32::NAN
        };

        metrics.push(StepRecord {
            outer_step: t,
            loss: mean_loss,
            inner_steps: h_current * if is_local_sgd { 1 } else { 1 },
            tokens: tokens_per_step * h_current as u64 * d as u64,
            wire_bytes,
            compression_ratio: ratio,
            rank: rank_used,
            compute_secs,
            comm_secs,
            elapsed_secs: elapsed,
        });

        if opts.eval_every > 0 && t % opts.eval_every == 0 {
            let el = eval(match &engine {
                Some(eng) => eng.theta(),
                None => &theta_g,
            })?;
            eval_curve.push((t, el));
            if !opts.quiet && t % opts.log_every.max(1) == 0 {
                crate::info!(
                    "train",
                    "{} outer={t}/{} H={h_current} train_loss={mean_loss:.4} eval={el:.4} wire={} ratio={ratio:.0}x",
                    cfg.algo.name(),
                    cfg.train.outer_steps,
                    crate::util::fmt_bytes(wire_bytes)
                );
            }
        }
    }

    // Drain a trailing in-flight reduction so the final params include
    // every replica's last contribution (flush at shutdown).
    if let Some(eng) = engine.as_mut() {
        if eng.has_in_flight() {
            let mut red = TrainReducer {
                reducer: &mut reducer,
                spec: &spec,
                adaptive: &mut adaptive,
                h_next: None,
                payload: 0,
                ratio: 1.0,
            };
            eng.drain(&mut red)?;
        }
    }

    let final_params: Vec<f32> = match engine {
        Some(eng) => eng.theta().to_vec(),
        None => theta_g,
    };
    let final_eval = eval(&final_params)?;
    metrics.final_eval_loss = Some(final_eval);
    eval_curve.push((cfg.train.outer_steps + 1, final_eval));

    Ok(TrainOutcome { metrics, params: final_params, eval_curve })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::ExperimentConfig;

    fn tiny_dir() -> Option<String> {
        let dir = concat!(env!("CARGO_MANIFEST_DIR"), "/artifacts/tiny");
        std::path::Path::new(dir).exists().then(|| dir.to_string())
    }

    fn quick_cfg(algo: Algo) -> ExperimentConfig {
        let mut cfg = ExperimentConfig::default_for("tiny", algo);
        cfg.train.outer_steps = 4;
        cfg.train.local_steps = match algo {
            Algo::AllReduce | Algo::CocktailSgd => 4,
            _ => 8,
        };
        cfg.train.inner_lr = 3e-3;
        cfg.train.outer_lr = 0.5;
        cfg.compression.rank = 8;
        cfg.compression.rank_window = 2;
        cfg
    }

    fn opts() -> RunOpts {
        RunOpts { eval_batches: 2, quiet: true, ..Default::default() }
    }

    #[test]
    fn dilocox_loss_decreases_and_meters_bytes() {
        let Some(dir) = tiny_dir() else { return };
        let mut cfg = quick_cfg(Algo::DiLoCoX);
        cfg.artifacts_dir = dir;
        let out = run_experiment(&cfg, &opts()).unwrap();
        let first = out.eval_curve.first().unwrap().1;
        let last = out.eval_curve.last().unwrap().1;
        assert!(last < first, "eval should improve: {first} -> {last}");
        // Overlap: step 1 has nothing in flight → zero wire bytes; later
        // steps meter the compressed payload.
        assert_eq!(out.metrics.records[0].wire_bytes, 0);
        assert!(out.metrics.records[1].wire_bytes > 0);
        assert!(out.metrics.records[1].compression_ratio > 4.0);
    }

    #[test]
    fn allreduce_replicas_stay_identical_and_learn() {
        let Some(dir) = tiny_dir() else { return };
        let mut cfg = quick_cfg(Algo::AllReduce);
        cfg.artifacts_dir = dir;
        let out = run_experiment(&cfg, &opts()).unwrap();
        let first = out.eval_curve.first().unwrap().1;
        let last = out.eval_curve.last().unwrap().1;
        assert!(last < first);
        // fp32 ring payload metered every inner step.
        let n = out.params.len() as u64;
        let rec = &out.metrics.records[0];
        assert_eq!(rec.wire_bytes, 4 * n * cfg.train.local_steps as u64);
    }

    #[test]
    fn overlap_defers_first_update() {
        let Some(dir) = tiny_dir() else { return };
        // With overlap, outer step 1 must leave global params unchanged
        // (nothing has been reduced yet).
        let mut cfg = quick_cfg(Algo::DiLoCoX);
        cfg.artifacts_dir = dir.clone();
        cfg.train.outer_steps = 1;
        let out = run_experiment(&cfg, &opts()).unwrap();
        // After the trailing flush the params DO move; but the recorded
        // step-1 wire bytes stay zero (the sync ran after the step).
        assert_eq!(out.metrics.records[0].wire_bytes, 0);

        let mut cfg2 = quick_cfg(Algo::DiLoCoX);
        cfg2.artifacts_dir = dir;
        cfg2.train.outer_steps = 1;
        cfg2.train.overlap = false;
        let out2 = run_experiment(&cfg2, &opts()).unwrap();
        assert!(out2.metrics.records[0].wire_bytes > 0);
    }

    #[test]
    fn opendiloco_wire_is_fp16_equivalent() {
        let Some(dir) = tiny_dir() else { return };
        let mut cfg = quick_cfg(Algo::OpenDiLoCo);
        cfg.artifacts_dir = dir;
        let out = run_experiment(&cfg, &opts()).unwrap();
        let n = out.params.len() as u64;
        let rec = &out.metrics.records[0];
        // fp16 = 2 bytes/elem + scale overhead.
        assert!(rec.wire_bytes >= 2 * n && rec.wire_bytes < 2 * n + 64,
                "wire={} n={n}", rec.wire_bytes);
        assert!((rec.compression_ratio - 2.0).abs() < 0.1);
    }

    #[test]
    fn cocktail_compresses_aggressively() {
        let Some(dir) = tiny_dir() else { return };
        let mut cfg = quick_cfg(Algo::CocktailSgd);
        cfg.artifacts_dir = dir;
        let out = run_experiment(&cfg, &opts()).unwrap();
        let rec = &out.metrics.records[0];
        assert!(rec.compression_ratio > 30.0, "{}", rec.compression_ratio);
        let first = out.eval_curve.first().unwrap().1;
        let last = out.eval_curve.last().unwrap().1;
        assert!(last < first + 0.5, "cocktail should still roughly learn");
    }

    #[test]
    fn adaptive_controller_updates_rank_and_h() {
        let Some(dir) = tiny_dir() else { return };
        let mut cfg = quick_cfg(Algo::DiLoCoX);
        cfg.artifacts_dir = dir;
        cfg.train.outer_steps = 5;
        cfg.train.overlap = false;
        cfg.compression.adaptive = true;
        cfg.compression.rank_window = 2;
        let out = run_experiment(&cfg, &opts()).unwrap();
        // After the window fills the recorded rank should track r_t (and
        // usually drop below r1 on structured pseudo-gradients).
        let ranks: Vec<usize> =
            out.metrics.records.iter().map(|r| r.rank).collect();
        assert_eq!(ranks[0], 8);
        assert!(ranks.iter().all(|&r| r >= 1 && r <= 8));
    }
}
