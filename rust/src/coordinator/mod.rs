//! Threaded coordinator: the decentralized process structure of the paper
//! run for real — one OS thread per DP replica ("cluster"), each owning
//! its own PJRT runtime, data shard, and dual optimizer, synchronizing
//! pseudo-gradients with the chunked ring AllReduce from [`crate::comm`].
//!
//! One-step-delay overlap (§2.3) is realized *structurally*: each worker
//! hands its pseudo-gradient to a communication thread that runs the ring
//! collective while the worker immediately starts the next H local steps;
//! the outer update at the end of round t+1 joins the round-t collective.
//!
//! All compression here is AllReduce-compatible (the paper's requirement):
//! quantize-only runs one ring pass; Low-Rank ∘ Quantize runs the PowerSGD
//! two-pass algebra (allreduce P̄, orthonormalize, allreduce Q̄') — every
//! worker derives identical bases from a shared seed, so no parameter
//! server is needed.

use crate::comm::ring::{build_ring, RingMember};
use crate::compress::{lowrank, quantize, Method};
use crate::transport::RingTransport;
use crate::config::{Algo, ExperimentConfig};
use crate::data::{MarkovCorpus, ShardIter};
use crate::linalg::{matmul, matmul_at_b, matmul_bt, orthonormalize_columns, Mat};
use crate::optim::{AdamW, Nesterov};
use crate::runtime::manifest::ParamEntry;
use crate::runtime::Runtime;
use crate::util::rng::Pcg32;
use anyhow::{anyhow, Context, Result};
use std::collections::HashMap;
use std::sync::mpsc;
use std::sync::Arc;

/// Per-round report a worker sends to the leader.
#[derive(Clone, Debug)]
pub struct RoundReport {
    pub worker: usize,
    pub round: usize,
    pub mean_loss: f32,
    pub wire_bytes: u64,
    pub h_steps: usize,
}

#[derive(Debug)]
pub struct CoordinatorOutcome {
    pub reports: Vec<RoundReport>,
    pub final_eval: f32,
    pub final_params: Vec<f32>,
    pub total_wire_bytes: u64,
}

/// AllReduce-compatible compression state for the threaded path.
struct WireCompressor {
    method: Method,
    seed: u64,
    bases: HashMap<String, Mat>,
}

impl WireCompressor {
    fn new(method: Method, seed: u64) -> Self {
        WireCompressor { method, seed, bases: HashMap::new() }
    }

    /// Reduce `delta` across the ring in place (result = global mean of
    /// the compressed deltas); returns payload bytes this worker sent.
    /// Speaks only to the [`RingTransport`] trait, so the same compressor
    /// runs over the local mpsc ring, loopback TCP, or a fault-injecting
    /// wrapper.
    fn reduce(
        &mut self,
        member: &mut dyn RingTransport,
        delta: &mut [f32],
        spec: &[ParamEntry],
        step: u64,
    ) -> Result<u64> {
        match self.method.clone() {
            Method::None => {
                let payload = 4 * delta.len() as u64;
                member.allreduce_mean(delta)?;
                Ok(payload)
            }
            Method::Quant { q_bits } => {
                quantize::quantize_dequantize(delta, q_bits);
                member.allreduce_mean(delta)?;
                Ok(quantize::wire_bytes(delta.len(), q_bits))
            }
            Method::LowRankQuant { rank, q_bits } => {
                self.lowrank_reduce(member, delta, spec, step, rank, q_bits)
            }
            other => Err(anyhow!(
                "method {:?} is not AllReduce-compatible (threaded path)",
                other.name()
            )),
        }
    }

    fn lowrank_reduce(
        &mut self,
        member: &mut dyn RingTransport,
        delta: &mut [f32],
        spec: &[ParamEntry],
        step: u64,
        rank: usize,
        q_bits: u32,
    ) -> Result<u64> {
        let mut payload_elems = 0usize;
        let mut scales = 0usize;
        for entry in spec {
            let lo = entry.offset;
            let hi = entry.offset + entry.numel();
            if entry.shape.len() == 2 {
                let (rows, cols) = (entry.shape[0], entry.shape[1]);
                let r = lowrank::effective_rank(rank, rows, cols);
                let q = self.bases.entry(entry.name.clone()).or_insert_with(|| {
                    // Same seeding rule as compress::lowrank → identical
                    // bases on every worker.
                    let mut rng =
                        Pcg32::new(self.seed ^ fnv(&entry.name), step);
                    let mut m = Mat::zeros(cols, r);
                    rng.fill_normal(&mut m.data, 0.0, 1.0);
                    m
                });
                if q.cols != r {
                    let mut rng =
                        Pcg32::new(self.seed ^ fnv(&entry.name), step);
                    let mut m = Mat::zeros(cols, r);
                    for i in 0..cols {
                        for j in 0..r {
                            m.data[i * r + j] = if j < q.cols {
                                q.data[i * q.cols + j]
                            } else {
                                rng.normal()
                            };
                        }
                    }
                    *q = m;
                }
                let mslab = Mat::from_slice(rows, cols, &delta[lo..hi]);
                // Pass 1: P = M Q, ring-mean, quantize, orthonormalize.
                let mut p = matmul(&mslab, q);
                member.allreduce_mean(&mut p.data)?;
                payload_elems += rows * r;
                scales += 1;
                if q_bits > 0 && q_bits < 32 {
                    quantize::quantize_dequantize(&mut p.data, q_bits);
                }
                orthonormalize_columns(&mut p);
                // Pass 2: Q' = Mᵀ P̂, ring-mean, quantize.
                let mut qn = matmul_at_b(&mslab, &p);
                member.allreduce_mean(&mut qn.data)?;
                payload_elems += cols * r;
                scales += 1;
                if q_bits > 0 && q_bits < 32 {
                    quantize::quantize_dequantize(&mut qn.data, q_bits);
                }
                self.bases.insert(entry.name.clone(), qn.clone());
                let rec = matmul_bt(&p, &qn);
                delta[lo..hi].copy_from_slice(&rec.data);
            } else {
                // 1-D segment: ring-mean, then snap to the q-bit grid —
                // the same order as compress::lowrank so the threaded and
                // reference paths agree bit-for-bit (up to ring fp order).
                let mut seg = delta[lo..hi].to_vec();
                member.allreduce_mean(&mut seg)?;
                if q_bits > 0 && q_bits < 32 {
                    quantize::quantize_dequantize(&mut seg, q_bits);
                }
                payload_elems += hi - lo;
                scales += 1;
                delta[lo..hi].copy_from_slice(&seg);
            }
        }
        let bits = if q_bits == 0 { 32 } else { q_bits } as u64;
        Ok((payload_elems as u64 * bits + 7) / 8 + 4 * scales as u64)
    }
}

fn fnv(s: &str) -> u64 {
    let mut h: u64 = 0xcbf29ce484222325;
    for b in s.as_bytes() {
        h ^= *b as u64;
        h = h.wrapping_mul(0x100000001b3);
    }
    h
}

/// Run the full threaded coordinator: D worker threads + leader aggregation.
pub fn run_threaded(cfg: &ExperimentConfig, artifacts_dir: &str) -> Result<CoordinatorOutcome> {
    cfg.validate()?;
    if !matches!(cfg.algo, Algo::DiLoCoX | Algo::OpenDiLoCo) {
        return Err(anyhow!("threaded coordinator runs local-SGD algorithms"));
    }
    let d = cfg.parallel.dp;
    let members = build_ring(d);
    let meter = Arc::clone(&members[0].meter);
    let (report_tx, report_rx) = mpsc::channel::<RoundReport>();

    let method = crate::train::method_for(cfg);
    if !method.allreduce_compatible() {
        return Err(anyhow!("threaded coordinator needs AllReduce-compatible compression"));
    }

    let results: Vec<Result<(Vec<f32>, f32)>> = std::thread::scope(|scope| {
        let handles: Vec<_> = members
            .into_iter()
            .enumerate()
            .map(|(w, member)| {
                let tx = report_tx.clone();
                let cfg = cfg.clone();
                let dir = artifacts_dir.to_string();
                let method = method.clone();
                scope.spawn(move || -> Result<(Vec<f32>, f32)> {
                    worker_main(w, member, &cfg, &dir, method, tx)
                })
            })
            .collect();
        handles.into_iter().map(|h| h.join().unwrap()).collect()
    });
    drop(report_tx);

    let mut reports: Vec<RoundReport> = report_rx.into_iter().collect();
    reports.sort_by_key(|r| (r.round, r.worker));

    let mut finals = Vec::new();
    for r in results {
        finals.push(r.context("worker thread failed")?);
    }
    // All workers must agree on the final parameters (ring algebra is
    // symmetric); verify instead of trusting.
    let (p0, eval0) = &finals[0];
    for (pi, _) in &finals[1..] {
        let max_dev = p0
            .iter()
            .zip(pi)
            .map(|(a, b)| (a - b).abs())
            .fold(0.0f32, f32::max);
        if max_dev > 1e-4 {
            return Err(anyhow!("workers diverged: max param dev {max_dev}"));
        }
    }

    Ok(CoordinatorOutcome {
        reports,
        final_eval: *eval0,
        final_params: p0.clone(),
        total_wire_bytes: meter.total(),
    })
}

fn worker_main(
    w: usize,
    member: RingMember,
    cfg: &ExperimentConfig,
    dir: &str,
    method: Method,
    tx: mpsc::Sender<RoundReport>,
) -> Result<(Vec<f32>, f32)> {
    let rt = Runtime::load(dir)?;
    rt.precompile(&["step_single", "eval_single"])?;
    let man = &rt.manifest;
    let spec = man.param_specs["single"].clone();
    let n = man.param_count;
    let (b, s) = (man.dims.microbatch, man.dims.seq_len);

    let corpus = Arc::new(MarkovCorpus::new(man.dims.vocab_size, cfg.train.seed));
    let mut shard = ShardIter::new(Arc::clone(&corpus), w, cfg.train.seed, b, s);
    let mut params = man.read_f32(&man.init["single"].file)?;
    // Global parameter track: moves only by outer updates; every worker
    // computes the identical sequence (ring algebra is symmetric).
    let mut theta_g = params.clone();
    let mut inner = AdamW::new(n, cfg.train.inner_lr, cfg.train.weight_decay);
    let mut outer = Nesterov::new(n, cfg.train.outer_lr, cfg.train.outer_momentum);
    let mut error = vec![0.0f32; n];
    let compressor = WireCompressor::new(method, cfg.train.seed);
    let h = cfg.train.local_steps;

    // Comm-thread handle for the in-flight reduction (overlap).  The ring
    // member travels to the comm thread and back.
    type Flight = std::thread::JoinHandle<Result<(RingMember, WireCompressor, Vec<f32>, u64)>>;
    let mut member = Some(member);
    let mut compressor_slot: Option<WireCompressor> = Some(compressor);
    let mut in_flight: Option<(Flight, Vec<f32>)> = None;

    for round in 1..=cfg.train.outer_steps {
        let anchor = params.clone();
        let mut loss_acc = 0.0f64;
        for _ in 0..h {
            let (tok, lab) = shard.next_batch();
            let (loss, grads) = rt.step_single(&params, &tok, &lab)?;
            inner.step(&mut params, &grads);
            loss_acc += loss as f64;
        }

        let mut wire = 0u64;
        if cfg.train.overlap {
            // Join the previous round's collective (one-step delay),
            // refresh e^t, THEN form δ^t, THEN apply the delayed outer
            // update and resync — the Algorithm 2 ordering.
            let mut delayed_avg: Option<Vec<f32>> = None;
            if let Some((handle, raw_prev)) = in_flight.take() {
                let (m, c, avg, bytes) = handle
                    .join()
                    .map_err(|_| anyhow!("comm thread panicked"))??;
                member = Some(m);
                compressor_slot = Some(c);
                wire = bytes;
                if cfg.compression.error_feedback {
                    for i in 0..n {
                        error[i] = raw_prev[i] - avg[i];
                    }
                }
                delayed_avg = Some(avg);
            }
            // δ for this round, measured against this round's anchor.
            let mut delta = vec![0.0f32; n];
            for i in 0..n {
                delta[i] = (anchor[i] - params[i]) + error[i];
            }
            let raw = delta.clone();
            let mut m = member.take().expect("ring member in flight twice");
            let mut c = compressor_slot.take().expect("compressor in flight");
            let spec_cl = spec.clone();
            let handle = std::thread::spawn(move || {
                let bytes = c.reduce(&mut m, &mut delta, &spec_cl, 0)?;
                Ok((m, c, delta, bytes))
            });
            in_flight = Some((handle, raw));
            if let Some(avg) = delayed_avg {
                outer.step(&mut theta_g, &avg);
                params.copy_from_slice(&theta_g);
            }
        } else {
            let mut delta = vec![0.0f32; n];
            for i in 0..n {
                delta[i] = (anchor[i] - params[i]) + error[i];
            }
            let raw = delta.clone();
            let m = member.as_mut().unwrap();
            let c = compressor_slot.as_mut().unwrap();
            wire = c.reduce(m, &mut delta, &spec, round as u64)?;
            if cfg.compression.error_feedback {
                for i in 0..n {
                    error[i] = raw[i] - delta[i];
                }
            }
            outer.step(&mut theta_g, &delta);
            params.copy_from_slice(&theta_g);
        }

        tx.send(RoundReport {
            worker: w,
            round,
            mean_loss: (loss_acc / h as f64) as f32,
            wire_bytes: wire,
            h_steps: h,
        })
        .ok();
    }

    // Drain a trailing in-flight reduction.
    if let Some((handle, _)) = in_flight.take() {
        let (m, _, avg, _) =
            handle.join().map_err(|_| anyhow!("comm thread panicked"))??;
        member = Some(m);
        outer.step(&mut theta_g, &avg);
        params.copy_from_slice(&theta_g);
    }
    let _ = member;

    // Shared eval set (same construction as the reference trainer).
    let mut eval_iter =
        ShardIter::new(Arc::clone(&corpus), 9999, cfg.train.seed ^ 0xe7a1, b, s);
    let mut acc = 0.0f32;
    let eval_batches = 3;
    for _ in 0..eval_batches {
        let (t, l) = eval_iter.next_batch();
        acc += rt.eval_single(&params, &t, &l)?;
    }
    Ok((params, acc / eval_batches as f32))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny_dir() -> Option<String> {
        let dir = concat!(env!("CARGO_MANIFEST_DIR"), "/artifacts/tiny");
        std::path::Path::new(dir).exists().then(|| dir.to_string())
    }

    fn cfg(overlap: bool) -> ExperimentConfig {
        let mut c = ExperimentConfig::default_for("tiny", Algo::DiLoCoX);
        c.train.outer_steps = 3;
        c.train.local_steps = 4;
        c.train.inner_lr = 3e-3;
        c.train.outer_lr = 0.5;
        c.train.overlap = overlap;
        c.compression.rank = 8;
        c.compression.adaptive = false;
        c
    }

    #[test]
    fn threaded_workers_agree_and_learn_sync() {
        let Some(dir) = tiny_dir() else { return };
        let out = run_threaded(&cfg(false), &dir).unwrap();
        assert_eq!(out.reports.len(), 3 * 2);
        assert!(out.final_eval.is_finite());
        assert!(out.total_wire_bytes > 0);
        // Loss at round 3 below round 1 (averaged over workers).
        let r1: f32 = out.reports[..2].iter().map(|r| r.mean_loss).sum::<f32>() / 2.0;
        let r3: f32 = out.reports[4..].iter().map(|r| r.mean_loss).sum::<f32>() / 2.0;
        assert!(r3 < r1 + 0.1, "r1={r1} r3={r3}");
    }

    #[test]
    fn threaded_overlap_runs_and_converges() {
        let Some(dir) = tiny_dir() else { return };
        let out = run_threaded(&cfg(true), &dir).unwrap();
        assert_eq!(out.reports.len(), 6);
        assert!(out.final_eval.is_finite());
        assert!(out.final_eval < 6.0, "eval={}", out.final_eval);
    }

    #[test]
    fn rejects_non_allreduce_methods() {
        let Some(dir) = tiny_dir() else { return };
        let mut c = ExperimentConfig::default_for("tiny", Algo::CocktailSgd);
        c.train.outer_steps = 1;
        assert!(run_threaded(&c, &dir).is_err());
    }
}
