//! Threaded coordinator: the decentralized process structure of the paper
//! run for real — one OS thread per DP replica ("cluster"), each owning
//! its own PJRT runtime, data shard, and dual optimizer, synchronizing
//! pseudo-gradients with the chunked ring AllReduce from [`crate::comm`].
//!
//! With `parallel.pp > 1` each cluster additionally splits into
//! `pp_stages` **stage executor threads** driving the real per-stage HLO
//! programs on the 1F1B schedule (see [`crate::pipeline::exec`] for the
//! threading model): activations and grad-activations flow between stage
//! threads over channels, each stage holds only its own parameter shard
//! and per-stage dual optimizer, and per-stage pseudo-gradients reduce
//! over per-stage DP rings — [`run_threaded`] dispatches to
//! [`run_threaded_pp`] automatically.
//!
//! One-step-delay overlap (§2.3) is realized *structurally*: each worker
//! (or stage executor) hands its pseudo-gradient to a communication
//! thread that runs the ring collective while the worker immediately
//! starts the next H local steps; the outer update at the end of round
//! t+1 joins the round-t collective.  The delta/error-feedback/outer-step
//! ordering lives in the shared [`crate::rounds::RoundEngine`];
//! compression is the AllReduce-compatible [`crate::rounds::WireCompressor`]
//! (quantize = one ring pass; Low-Rank ∘ Quantize = the PowerSGD
//! two-pass algebra with round-seeded shared bases — no parameter server).
//!
//! Invariants a new contributor should know before touching this module:
//!
//! * **Overlap join ordering** — a round's outer update must join the
//!   *previous* round's collective before forming this round's delta
//!   against this round's anchor; the engine owns that ordering and the
//!   coordinator must never reduce a delta outside `finish_round` /
//!   `drain` (the trailing drain at shutdown is part of the contract).
//! * **Wire accounting** — `total_wire_bytes` sums compressed sync
//!   payloads per worker (and per stage lane with `pp > 1`, where the
//!   per-stage payloads add up to the same fp32 total as the flat
//!   vector), so PP-on/PP-off and local/TCP ledgers compare directly.
//! * **Final-params agreement** — the ring algebra is symmetric, so all
//!   workers must land on identical parameters; both coordinators verify
//!   this instead of trusting it.
//!
//! The multi-*process* deployment of the same structure (TCP transport,
//! elastic membership, one OS process per cluster — or per (cluster,
//! stage) with `pp > 1`) lives in [`crate::transport::elastic`].

use crate::comm::ring::build_ring;
use crate::compress::Method;
use crate::config::{Algo, ExperimentConfig};
use crate::data::{MarkovCorpus, ShardIter};
use crate::optim::{AdamW, Nesterov};
use crate::pipeline::exec::{
    local_stage_rings, run_pipeline, PipelineRunOpts, PipelineWorkload,
    StageCompute, StageTimeSummary,
};
use crate::rounds::driver::{EpochEnd, RoundDriver, RoundWork};
use crate::rounds::{RingLane, RoundEngine};
use crate::runtime::manifest::ParamEntry;
use crate::runtime::{HostArg, Manifest, Runtime};
use anyhow::{anyhow, Context, Result};
use std::collections::HashMap;
use std::sync::mpsc;
use std::sync::Arc;
use std::time::Instant;

/// Per-round report a worker sends to the leader.
#[derive(Clone, Debug)]
pub struct RoundReport {
    pub worker: usize,
    pub round: usize,
    pub mean_loss: f32,
    pub wire_bytes: u64,
    pub h_steps: usize,
}

#[derive(Debug)]
pub struct CoordinatorOutcome {
    pub reports: Vec<RoundReport>,
    pub final_eval: f32,
    pub final_params: Vec<f32>,
    /// Sum of per-worker compressed sync payloads (including trailing
    /// overlap drains) — the same accounting in the single-stage and the
    /// stage-parallel arm, so PP-on/PP-off ledgers compare directly.
    pub total_wire_bytes: u64,
    /// Measured per-stage wall times (empty when `pp = 1`); feeds the run
    /// report JSON and the DES calibration.
    pub stage_times: Vec<StageTimeSummary>,
}

/// Run the full threaded coordinator: D worker threads + leader
/// aggregation.  Dispatches to the stage-parallel executor when the
/// config asks for `parallel.pp > 1`.
pub fn run_threaded(cfg: &ExperimentConfig, artifacts_dir: &str) -> Result<CoordinatorOutcome> {
    cfg.validate()?;
    if !matches!(cfg.algo, Algo::DiLoCoX | Algo::OpenDiLoCo) {
        return Err(anyhow!("threaded coordinator runs local-SGD algorithms"));
    }
    let method = crate::train::method_for(cfg);
    if !method.allreduce_compatible() {
        return Err(anyhow!("threaded coordinator needs AllReduce-compatible compression"));
    }
    if cfg.parallel.pp > 1 {
        return run_threaded_pp(cfg, artifacts_dir);
    }
    let d = cfg.parallel.dp;
    let members = build_ring(d);
    let (report_tx, report_rx) = mpsc::channel::<RoundReport>();

    let results: Vec<Result<(Vec<f32>, f32, u64)>> = std::thread::scope(|scope| {
        let handles: Vec<_> = members
            .into_iter()
            .enumerate()
            .map(|(w, member)| {
                let tx = report_tx.clone();
                let cfg = cfg.clone();
                let dir = artifacts_dir.to_string();
                let method = method.clone();
                scope.spawn(move || -> Result<(Vec<f32>, f32, u64)> {
                    worker_main(w, Box::new(member), &cfg, &dir, method, tx)
                })
            })
            .collect();
        handles.into_iter().map(|h| h.join().unwrap()).collect()
    });
    drop(report_tx);

    let mut reports: Vec<RoundReport> = report_rx.into_iter().collect();
    reports.sort_by_key(|r| (r.round, r.worker));

    let mut finals = Vec::new();
    for r in results {
        finals.push(r.context("worker thread failed")?);
    }
    // All workers must agree on the final parameters (ring algebra is
    // symmetric); verify instead of trusting.
    let (p0, eval0, _) = &finals[0];
    for (pi, _, _) in &finals[1..] {
        let max_dev = p0
            .iter()
            .zip(pi)
            .map(|(a, b)| (a - b).abs())
            .fold(0.0f32, f32::max);
        if max_dev > 1e-4 {
            return Err(anyhow!("workers diverged: max param dev {max_dev}"));
        }
    }

    Ok(CoordinatorOutcome {
        reports,
        final_eval: *eval0,
        final_params: p0.clone(),
        total_wire_bytes: finals.iter().map(|(_, _, w)| w).sum(),
        stage_times: Vec::new(),
    })
}

/// One worker's real-numerics local work: H `step_single` steps through
/// the PJRT runtime + inner AdamW per round, plus the shared held-out
/// eval.  The ONE copy of the single-program inner loop, used by both
/// the threaded coordinator (`worker_main`) and the elastic fleet's
/// runtime workload ([`crate::transport::elastic`]) — keep it that way.
pub(crate) struct RuntimeStepWork {
    pub(crate) rt: Runtime,
    shard: ShardIter,
    inner: AdamW,
    params: Vec<f32>,
    corpus: Arc<MarkovCorpus>,
    seed: u64,
    microbatch: usize,
    seq_len: usize,
}

impl RuntimeStepWork {
    /// Load the bundle, precompile the single-program pair, and shard
    /// the corpus for `rank`.
    pub(crate) fn new(
        dir: &str,
        rank: usize,
        seed: u64,
        inner_lr: f32,
        weight_decay: f32,
    ) -> Result<RuntimeStepWork> {
        let rt = Runtime::load(dir)
            .with_context(|| format!("loading artifacts from {dir}"))?;
        rt.precompile(&["step_single", "eval_single"])?;
        let man = &rt.manifest;
        let (b, s) = (man.dims.microbatch, man.dims.seq_len);
        let corpus = Arc::new(MarkovCorpus::new(man.dims.vocab_size, seed));
        let shard = ShardIter::new(Arc::clone(&corpus), rank, seed, b, s);
        let params = man.read_f32(&man.init["single"].file)?;
        let inner = AdamW::new(man.param_count, inner_lr, weight_decay);
        Ok(RuntimeStepWork {
            shard,
            inner,
            params,
            corpus,
            seed,
            microbatch: b,
            seq_len: s,
            rt,
        })
    }

    /// Shared eval set (same construction as the reference trainer).
    pub(crate) fn eval_loss(&mut self) -> Result<f32> {
        let mut it = ShardIter::new(
            Arc::clone(&self.corpus),
            9999,
            self.seed ^ 0xe7a1,
            self.microbatch,
            self.seq_len,
        );
        let mut acc = 0.0f32;
        let batches = 3;
        for _ in 0..batches {
            let (t, l) = it.next_batch();
            acc += self.rt.eval_single(&self.params, &t, &l)?;
        }
        Ok(acc / batches as f32)
    }
}

impl RoundWork for RuntimeStepWork {
    fn params(&self) -> &[f32] {
        &self.params
    }

    fn set_params(&mut self, p: &[f32]) {
        self.params.copy_from_slice(p);
    }

    fn local_round(&mut self, h: usize) -> Result<(f32, f64)> {
        let mut loss_acc = 0.0f64;
        let mut busy = 0.0f64;
        for _ in 0..h {
            let (tok, lab) = self.shard.next_batch();
            let t0 = Instant::now();
            let (loss, grads) = self.rt.step_single(&self.params, &tok, &lab)?;
            self.inner.step(&mut self.params, &grads);
            busy += t0.elapsed().as_secs_f64();
            loss_acc += loss as f64;
        }
        Ok(((loss_acc / h.max(1) as f64) as f32, busy / h.max(1) as f64))
    }
}

fn worker_main(
    w: usize,
    member: Box<dyn crate::transport::RingTransport>,
    cfg: &ExperimentConfig,
    dir: &str,
    method: Method,
    tx: mpsc::Sender<RoundReport>,
) -> Result<(Vec<f32>, f32, u64)> {
    let mut work = RuntimeStepWork::new(
        dir,
        w,
        cfg.train.seed,
        cfg.train.inner_lr,
        cfg.train.weight_decay,
    )?;
    let spec = work.rt.manifest.param_specs["single"].clone();
    let n = work.rt.manifest.param_count;

    // Shared outer-round engine: the global track θ_g moves only by outer
    // updates; every worker computes the identical sequence.  The round
    // loop itself is the one epoch-aware driver (single epoch here: the
    // threaded coordinator has no membership churn).
    let engine = RoundEngine::new(
        work.params.clone(),
        1,
        Nesterov::new(n, cfg.train.outer_lr, cfg.train.outer_momentum),
        cfg.train.overlap,
        cfg.compression.error_feedback,
    );
    crate::comm::pool::configure(cfg.transport.comm_pool_size);
    let mut lane =
        RingLane::new(member, method, cfg.train.seed, spec, cfg.train.overlap);
    lane.set_pipeline_depth(cfg.transport.pipeline_depth);
    lane.set_use_pool(cfg.transport.comm_pool_size >= 2);
    let h = cfg.train.local_steps;

    let mut driver =
        RoundDriver::new(engine, lane, cfg.train.outer_steps, h);
    let end = driver.run_rounds(1, &mut work, &mut |t| {
        tx.send(RoundReport {
            worker: w,
            round: t.round,
            mean_loss: t.loss,
            wire_bytes: t.wire_bytes,
            h_steps: h,
        })
        .ok();
    })?;
    if let EpochEnd::Broken(e) = end {
        return Err(e.context("ring broke in the threaded coordinator"));
    }
    // Drain a trailing in-flight reduction.
    driver.finish(&mut work)?;

    let eval = work.eval_loss()?;
    Ok((work.params, eval, driver.wire_total()))
}

// ---------------------------------------------------------------------------
// Stage-parallel path: real per-stage HLO programs on the 1F1B schedule
// ---------------------------------------------------------------------------

/// Run `pp_stages` stage executors per DP cluster over the artifact
/// bundle's per-stage programs.  Per-stage pseudo-gradients reduce over
/// per-stage DP rings; the manifest guarantees the concatenation of stage
/// layouts equals the `single` layout, so outcomes compare directly with
/// [`run_threaded`].
pub fn run_threaded_pp(
    cfg: &ExperimentConfig,
    artifacts_dir: &str,
) -> Result<CoordinatorOutcome> {
    cfg.validate()?;
    if !matches!(cfg.algo, Algo::DiLoCoX | Algo::OpenDiLoCo) {
        return Err(anyhow!("threaded coordinator runs local-SGD algorithms"));
    }
    let method = crate::train::method_for(cfg);
    if !method.allreduce_compatible() {
        return Err(anyhow!("stage-parallel path needs AllReduce-compatible compression"));
    }
    let man = Manifest::load(artifacts_dir)?;
    cfg.validate_with_manifest(&man)?;
    let workload = RuntimeStagePipeline::new(
        artifacts_dir,
        &man,
        cfg.parallel.microbatches.max(1),
        cfg.train.seed,
    )?;
    let dp = cfg.parallel.dp;
    let rings = local_stage_rings(dp, workload.stages());
    let schedule = crate::pipeline::ScheduleKind::parse(&cfg.parallel.schedule)
        .map_err(|e| anyhow!(e))?;
    let opts = PipelineRunOpts {
        rounds: cfg.train.outer_steps,
        local_steps: cfg.train.local_steps,
        inner_lr: cfg.train.inner_lr,
        weight_decay: cfg.train.weight_decay,
        outer_lr: cfg.train.outer_lr,
        outer_momentum: cfg.train.outer_momentum,
        overlap: cfg.train.overlap,
        error_feedback: cfg.compression.error_feedback,
        method,
        seed: cfg.train.seed,
        comm_pool_size: cfg.transport.comm_pool_size,
        pipeline_depth: cfg.transport.pipeline_depth,
        schedule,
        virtual_stages: cfg.parallel.virtual_stages.max(1),
    };
    let out = run_pipeline(&workload, dp, rings, &opts)?;

    // Adapt stage-level telemetry to the per-worker report shape: one
    // pass grouping by (round, worker) — loss from the labels-bearing
    // stage, wire summed over the stage lanes.
    let mut grouped: HashMap<(usize, usize), (f32, u64)> = HashMap::new();
    for r in &out.reports {
        let slot = grouped.entry((r.round, r.worker)).or_insert((f32::NAN, 0));
        if !r.mean_loss.is_nan() {
            slot.0 = r.mean_loss;
        }
        slot.1 += r.wire_bytes;
    }
    let mut reports = Vec::with_capacity(dp * opts.rounds);
    for round in 1..=opts.rounds {
        for w in 0..dp {
            let (mean_loss, wire_bytes) =
                grouped.get(&(round, w)).copied().unwrap_or((f32::NAN, 0));
            reports.push(RoundReport {
                worker: w,
                round,
                mean_loss,
                wire_bytes,
                h_steps: opts.local_steps,
            });
        }
    }
    let stage_times = out.stage_time_summary();
    Ok(CoordinatorOutcome {
        reports,
        final_eval: out.final_eval,
        final_params: out.final_params,
        total_wire_bytes: out.total_wire_bytes,
        stage_times,
    })
}

/// PJRT-artifact-backed [`PipelineWorkload`]: stage kinds and layouts come
/// from the manifest; each stage executor thread compiles only its own
/// stage's programs (`fwd_first`/`bwd_first`, `fwd_mid`/`bwd_mid`,
/// `fwd_last`/`bwd_last`).  The first and last stages draw the identical
/// shard stream (same corpus seed and replica id), consuming the tokens
/// and labels of the same microbatches in lockstep.
pub struct RuntimeStagePipeline {
    dir: String,
    seed: u64,
    micros: usize,
    kinds: Vec<&'static str>,
    stage_numels: Vec<usize>,
    vocab: usize,
    microbatch: usize,
    seq_len: usize,
}

impl RuntimeStagePipeline {
    pub fn new(
        dir: &str,
        man: &Manifest,
        micros: usize,
        seed: u64,
    ) -> Result<RuntimeStagePipeline> {
        if man.dims.pp_stages <= 1 {
            return Err(anyhow!(
                "artifact bundle '{}' was exported without pipeline stages \
                 (pp_stages = {}); re-export with pp_stages > 1 or run the \
                 single-stage coordinator",
                man.preset,
                man.dims.pp_stages
            ));
        }
        let kinds = man.stage_kinds();
        let stage_numels: Vec<usize> = kinds
            .iter()
            .map(|k| {
                man.stage_numel
                    .get(*k)
                    .copied()
                    .ok_or_else(|| anyhow!("manifest missing stage_numel for '{k}'"))
            })
            .collect::<Result<_>>()?;
        Ok(RuntimeStagePipeline {
            dir: dir.to_string(),
            seed,
            micros: micros.max(1),
            kinds,
            stage_numels,
            vocab: man.dims.vocab_size,
            microbatch: man.dims.microbatch,
            seq_len: man.dims.seq_len,
        })
    }
}

impl PipelineWorkload for RuntimeStagePipeline {
    fn stages(&self) -> usize {
        self.kinds.len()
    }

    fn micros(&self) -> usize {
        self.micros
    }

    fn stage_numel(&self, stage: usize) -> usize {
        self.stage_numels[stage]
    }

    fn make_stage(&self, worker: usize, stage: usize) -> Result<Box<dyn StageCompute>> {
        let kind = *self
            .kinds
            .get(stage)
            .ok_or_else(|| anyhow!("stage {stage} out of range"))?;
        let rt = Runtime::load(&self.dir)?;
        let programs: &[&str] = match kind {
            "first" => &["fwd_first", "bwd_first"],
            "mid" => &["fwd_mid", "bwd_mid"],
            "last" => &["bwd_last"],
            other => return Err(anyhow!("unexpected stage kind '{other}'")),
        };
        rt.precompile(programs)?;
        let man = &rt.manifest;
        let init_key = format!("stage_{stage}");
        let init = man
            .init
            .get(&init_key)
            .ok_or_else(|| anyhow!("manifest has no init '{init_key}'"))?;
        let params0 = man.read_f32(&init.file)?;
        let spec = man
            .param_specs
            .get(kind)
            .ok_or_else(|| anyhow!("manifest has no param spec '{kind}'"))?
            .clone();
        let shard = if kind == "first" || kind == "last" {
            let corpus = Arc::new(MarkovCorpus::new(self.vocab, self.seed));
            Some(ShardIter::new(
                corpus,
                worker,
                self.seed,
                self.microbatch,
                self.seq_len,
            ))
        } else {
            None
        };
        Ok(Box::new(RuntimeStageCompute {
            rt,
            kind,
            params0,
            spec,
            micros: self.micros,
            worker,
            seed: self.seed,
            vocab: self.vocab,
            microbatch: self.microbatch,
            seq_len: self.seq_len,
            shard,
            tokens: Vec::new(),
            labels: Vec::new(),
            stash: HashMap::new(),
        }))
    }

    fn eval(&self, full_params: &[f32]) -> Result<f32> {
        let rt = Runtime::load(&self.dir)?;
        rt.precompile(&["eval_single"])?;
        let corpus = Arc::new(MarkovCorpus::new(self.vocab, self.seed));
        let mut eval_iter = ShardIter::new(
            corpus,
            9999,
            self.seed ^ 0xe7a1,
            self.microbatch,
            self.seq_len,
        );
        let mut acc = 0.0f32;
        let batches = 3;
        for _ in 0..batches {
            let (t, l) = eval_iter.next_batch();
            acc += rt.eval_single(full_params, &t, &l)?;
        }
        Ok(acc / batches as f32)
    }
}

struct RuntimeStageCompute {
    rt: Runtime,
    kind: &'static str,
    params0: Vec<f32>,
    spec: Vec<ParamEntry>,
    micros: usize,
    worker: usize,
    seed: u64,
    vocab: usize,
    microbatch: usize,
    seq_len: usize,
    shard: Option<ShardIter>,
    /// This inner step's microbatch tokens (first & last stages).
    tokens: Vec<Vec<i32>>,
    /// This inner step's microbatch labels (last stage).
    labels: Vec<Vec<i32>>,
    /// Activations entering this stage, per in-flight micro (mid & last;
    /// the backward programs take the stage *input* and rematerialize).
    stash: HashMap<usize, Vec<f32>>,
}

impl StageCompute for RuntimeStageCompute {
    fn numel(&self) -> usize {
        self.params0.len()
    }

    fn init(&self) -> Result<Vec<f32>> {
        Ok(self.params0.clone())
    }

    fn param_spec(&self) -> Vec<ParamEntry> {
        self.spec.clone()
    }

    fn next_step(&mut self) -> Result<()> {
        if let Some(shard) = self.shard.as_mut() {
            self.tokens.clear();
            self.labels.clear();
            for _ in 0..self.micros {
                let (t, l) = shard.next_batch();
                self.tokens.push(t);
                self.labels.push(l);
            }
        }
        Ok(())
    }

    fn reset_data(&mut self, round: usize) -> Result<()> {
        // Elastic churn recovery: re-derive the shard stream as a pure
        // function of (seed, worker, round) so the first and last stage
        // of one cluster re-align no matter where the break caught each
        // of them (see `StageCompute::reset_data`).
        if self.shard.is_some() {
            let corpus = Arc::new(MarkovCorpus::new(self.vocab, self.seed));
            self.shard = Some(ShardIter::new(
                corpus,
                self.worker,
                self.seed ^ (round as u64).wrapping_mul(0x9e3779b97f4a7c15),
                self.microbatch,
                self.seq_len,
            ));
        }
        self.tokens.clear();
        self.labels.clear();
        self.stash.clear();
        Ok(())
    }

    fn forward(
        &mut self,
        params: &[f32],
        micro: usize,
        acts_in: Option<Vec<f32>>,
    ) -> Result<Option<Vec<f32>>> {
        match self.kind {
            "first" => {
                let tok = self
                    .tokens
                    .get(micro)
                    .ok_or_else(|| anyhow!("micro {micro} not drawn"))?;
                let mut out = self.rt.exec_ref(
                    "fwd_first",
                    &[HostArg::F32(params), HostArg::I32(tok)],
                )?;
                Ok(Some(out.remove(0).into_f32()?))
            }
            "mid" => {
                let acts = acts_in.ok_or_else(|| anyhow!("mid stage needs acts"))?;
                let mut out = self.rt.exec_ref(
                    "fwd_mid",
                    &[HostArg::F32(params), HostArg::F32(&acts)],
                )?;
                self.stash.insert(micro, acts);
                Ok(Some(out.remove(0).into_f32()?))
            }
            "last" => {
                // bwd_last rematerializes the forward and returns the
                // loss, so the forward cell only stashes its input.
                let acts = acts_in.ok_or_else(|| anyhow!("last stage needs acts"))?;
                self.stash.insert(micro, acts);
                Ok(None)
            }
            other => Err(anyhow!("unexpected stage kind '{other}'")),
        }
    }

    fn backward(
        &mut self,
        params: &[f32],
        micro: usize,
        grad_in: Option<Vec<f32>>,
    ) -> Result<(Vec<f32>, Option<Vec<f32>>, Option<f32>)> {
        match self.kind {
            "last" => {
                let acts = self
                    .stash
                    .remove(&micro)
                    .ok_or_else(|| anyhow!("no stashed acts for micro {micro}"))?;
                let lab = self
                    .labels
                    .get(micro)
                    .ok_or_else(|| anyhow!("micro {micro} not drawn"))?;
                let mut out = self.rt.exec_ref(
                    "bwd_last",
                    &[
                        HostArg::F32(params),
                        HostArg::F32(&acts),
                        HostArg::I32(lab),
                    ],
                )?;
                let loss = out[0].scalar_f32()?;
                let g_acts = out.remove(2).into_f32()?;
                let grads = out.remove(1).into_f32()?;
                Ok((grads, Some(g_acts), Some(loss)))
            }
            "mid" => {
                let acts = self
                    .stash
                    .remove(&micro)
                    .ok_or_else(|| anyhow!("no stashed acts for micro {micro}"))?;
                let g_in =
                    grad_in.ok_or_else(|| anyhow!("mid stage needs grad_in"))?;
                let mut out = self.rt.exec_ref(
                    "bwd_mid",
                    &[
                        HostArg::F32(params),
                        HostArg::F32(&acts),
                        HostArg::F32(&g_in),
                    ],
                )?;
                let g_acts = out.remove(1).into_f32()?;
                let grads = out.remove(0).into_f32()?;
                Ok((grads, Some(g_acts), None))
            }
            "first" => {
                let tok = self
                    .tokens
                    .get(micro)
                    .ok_or_else(|| anyhow!("micro {micro} not drawn"))?;
                let g_in =
                    grad_in.ok_or_else(|| anyhow!("first stage needs grad_in"))?;
                let mut out = self.rt.exec_ref(
                    "bwd_first",
                    &[
                        HostArg::F32(params),
                        HostArg::I32(tok),
                        HostArg::F32(&g_in),
                    ],
                )?;
                Ok((out.remove(0).into_f32()?, None, None))
            }
            other => Err(anyhow!("unexpected stage kind '{other}'")),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny_dir() -> Option<String> {
        let dir = concat!(env!("CARGO_MANIFEST_DIR"), "/artifacts/tiny");
        std::path::Path::new(dir).exists().then(|| dir.to_string())
    }

    fn cfg(overlap: bool) -> ExperimentConfig {
        let mut c = ExperimentConfig::default_for("tiny", Algo::DiLoCoX);
        c.train.outer_steps = 3;
        c.train.local_steps = 4;
        c.train.inner_lr = 3e-3;
        c.train.outer_lr = 0.5;
        c.train.overlap = overlap;
        c.compression.rank = 8;
        c.compression.adaptive = false;
        c
    }

    #[test]
    fn threaded_workers_agree_and_learn_sync() {
        let Some(dir) = tiny_dir() else { return };
        let out = run_threaded(&cfg(false), &dir).unwrap();
        assert_eq!(out.reports.len(), 3 * 2);
        assert!(out.final_eval.is_finite());
        assert!(out.total_wire_bytes > 0);
        // Loss at round 3 below round 1 (averaged over workers).
        let r1: f32 = out.reports[..2].iter().map(|r| r.mean_loss).sum::<f32>() / 2.0;
        let r3: f32 = out.reports[4..].iter().map(|r| r.mean_loss).sum::<f32>() / 2.0;
        assert!(r3 < r1 + 0.1, "r1={r1} r3={r3}");
    }

    #[test]
    fn threaded_overlap_runs_and_converges() {
        let Some(dir) = tiny_dir() else { return };
        let out = run_threaded(&cfg(true), &dir).unwrap();
        assert_eq!(out.reports.len(), 6);
        assert!(out.final_eval.is_finite());
        assert!(out.final_eval < 6.0, "eval={}", out.final_eval);
    }

    #[test]
    fn rejects_non_allreduce_methods() {
        let Some(dir) = tiny_dir() else { return };
        let mut c = ExperimentConfig::default_for("tiny", Algo::CocktailSgd);
        c.train.outer_steps = 1;
        assert!(run_threaded(&c, &dir).is_err());
    }

    #[test]
    fn pp_dispatch_requires_staged_artifacts_config() {
        let Some(dir) = tiny_dir() else { return };
        // tiny exports pp_stages = 4; asking for a mismatched pp degree
        // must fail validation up front, not deep in execution.
        let mut c = cfg(false);
        c.parallel.pp = 3;
        assert!(run_threaded(&c, &dir).is_err());
    }

    #[test]
    fn stage_parallel_matches_single_stage_run() {
        // The headline §2.2 equivalence: a pp-threaded run over the real
        // per-stage HLO programs must land on the same final parameters
        // as the monolithic step_single run (manifest invariant:
        // single.init == concat of stage inits; both paths consume the
        // identical shard streams and optimizer algebra).
        let Some(dir) = tiny_dir() else { return };
        let man = Manifest::load(&dir).unwrap();
        let mut c = cfg(false);
        c.train.outer_steps = 2;
        c.train.local_steps = 3;
        c.compression.enabled = false; // fp32 ring: exact per-element sums
        let single = run_threaded(&c, &dir).unwrap();

        let mut cpp = c.clone();
        cpp.parallel.pp = man.dims.pp_stages;
        cpp.parallel.microbatches = 1;
        let staged = run_threaded(&cpp, &dir).unwrap();

        assert_eq!(single.final_params.len(), staged.final_params.len());
        let mut max_dev = 0.0f32;
        let mut sum_dev = 0.0f64;
        for (a, b) in single.final_params.iter().zip(&staged.final_params) {
            let d = (a - b).abs();
            max_dev = max_dev.max(d);
            sum_dev += d as f64;
        }
        let mean_dev = sum_dev / single.final_params.len() as f64;
        // Stage-chained grads differ from the monolithic program only by
        // fp reassociation (~1e-3 relative per step, see
        // integration_pipeline); AdamW can amplify a near-zero sign flip
        // to ~lr per element, so bound mean tightly and max loosely.
        assert!(mean_dev < 2e-3, "mean param dev {mean_dev}");
        assert!(max_dev < 5e-2, "max param dev {max_dev}");
        assert!(
            (single.final_eval - staged.final_eval).abs() < 0.05,
            "evals diverged: {} vs {}",
            single.final_eval,
            staged.final_eval
        );
        // Wire accounting: per-stage payloads must sum to the same fp32
        // total as the single flat vector.
        let w1: u64 = single.reports.iter().map(|r| r.wire_bytes).sum();
        let w2: u64 = staged.reports.iter().map(|r| r.wire_bytes).sum();
        assert_eq!(w1, w2, "fp32 payload accounting differs");
    }

    #[test]
    fn stage_parallel_runs_with_microbatching_and_overlap() {
        let Some(dir) = tiny_dir() else { return };
        let man = Manifest::load(&dir).unwrap();
        let mut c = cfg(true);
        c.train.outer_steps = 2;
        c.train.local_steps = 2;
        c.parallel.pp = man.dims.pp_stages;
        c.parallel.microbatches = 3;
        let out = run_threaded(&c, &dir).unwrap();
        assert!(out.final_eval.is_finite());
        // Overlap defers: round 1 ships nothing, round 2 does.
        let r1: u64 = out
            .reports
            .iter()
            .filter(|r| r.round == 1)
            .map(|r| r.wire_bytes)
            .sum();
        let r2: u64 = out
            .reports
            .iter()
            .filter(|r| r.round == 2)
            .map(|r| r.wire_bytes)
            .sum();
        assert_eq!(r1, 0);
        assert!(r2 > 0);
    }
}
