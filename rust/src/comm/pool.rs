//! Persistent comm-thread pool: parked push-workers for the reduce hot
//! path.
//!
//! Every overlapped round used to pay a `std::thread::spawn` (runtime
//! stack setup + teardown) per reduction, and the TCP transport spawned a
//! fresh writer thread per connection event.  This pool keeps those
//! threads **parked between jobs**: a worker finishes a job, registers
//! itself on the idle list, and blocks on its own channel until the next
//! `submit` hands it work — the push-worker shape, with `mpsc::recv` as
//! the parking primitive.
//!
//! Shape and guarantees:
//!
//! * **Cached, not fixed.** `submit` never queues behind a busy worker:
//!   if no idle worker exists one is spawned.  Long-lived jobs (the TCP
//!   writer loops park a worker for a whole connection) therefore cannot
//!   deadlock short jobs.  The `cap` only bounds how many *idle* workers
//!   stay parked — a worker that finishes when the parking lot is full
//!   retires, so the pool converges back to `cap` threads after a burst.
//! * **Blocking joins stay sound.** The pool itself never holds results;
//!   callers pair a job with their own completion channel (see
//!   `rounds::RingLane`), so "join the in-flight reduction" remains a
//!   blocking `recv` with exactly the semantics of `JoinHandle::join` —
//!   a parked pool thread never holds lane state past the join, and a
//!   job that panics drops its sender, surfacing as the same error a
//!   panicked comm thread would.
//! * **Observable.** Each job carries its enqueue timestamp; the worker
//!   records a detail-only `pool/queue.wait` trace event on pickup, so
//!   `--trace` shows dispatch latency without perturbing the round
//!   accounting (which only sums the well-known phases).
//!
//! The process-wide [`shared`] pool is what the fleet paths use; it is
//! off (`enabled() == false`) until a worker's config asks for
//! `transport.comm_pool_size ≥ 2`, so defaults preserve the historical
//! spawn-per-round behavior.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::mpsc::{channel, Sender};
use std::sync::{Arc, Mutex, OnceLock};

type Job = Box<dyn FnOnce() + Send + 'static>;

/// What travels to a worker: the job plus its enqueue timestamp, so the
/// worker can record the queue wait on its own trace track (clamped to
/// its park time — events on one track must stay well-nested).
type Dispatch = (u64, Job);

struct Inner {
    /// Parked workers, each reachable over its own job channel.
    idle: Mutex<Vec<Sender<Dispatch>>>,
    /// Max workers kept parked; excess workers retire on completion.
    cap: AtomicUsize,
    /// Threads currently alive (working or parked).
    live: AtomicUsize,
    /// Threads currently parked on their channel.
    parked: AtomicUsize,
    /// Threads ever spawned — a non-growing total across steady-state
    /// epochs is the "no thread churn" probe the tests assert.
    spawned_total: AtomicUsize,
}

/// A cached pool of parked comm worker threads.  See the module docs.
pub struct CommPool {
    inner: Arc<Inner>,
}

impl CommPool {
    /// A pool keeping at most `cap` workers parked (min 1).
    pub fn new(cap: usize) -> CommPool {
        CommPool {
            inner: Arc::new(Inner {
                idle: Mutex::new(Vec::new()),
                cap: AtomicUsize::new(cap.max(1)),
                live: AtomicUsize::new(0),
                parked: AtomicUsize::new(0),
                spawned_total: AtomicUsize::new(0),
            }),
        }
    }

    /// Raise/lower the parked-worker cap (monotonic growth is typical:
    /// every fleet worker calls [`configure`] with its own knob).
    pub fn set_cap(&self, cap: usize) {
        self.inner.cap.store(cap.max(1), Ordering::SeqCst);
    }

    /// Run `f` on a pool worker: an idle worker is woken, or a new one
    /// spawned — `submit` never queues behind a busy worker.
    pub fn submit<F: FnOnce() + Send + 'static>(&self, f: F) {
        let enqueued = crate::obs::now_us();
        let job: Job = Box::new(f);
        let slot = self.inner.idle.lock().unwrap().pop();
        match slot {
            Some(tx) => {
                self.inner.parked.fetch_sub(1, Ordering::SeqCst);
                if let Err(e) = tx.send((enqueued, job)) {
                    // The worker died between parking and dispatch
                    // (defensive — the loop below never does): recover
                    // the job and run it on a fresh worker.
                    self.spawn_worker(e.0);
                }
            }
            None => self.spawn_worker((enqueued, job)),
        }
    }

    /// Threads currently alive (working or parked).
    pub fn live_threads(&self) -> usize {
        self.inner.live.load(Ordering::SeqCst)
    }

    /// Threads currently parked waiting for work.
    pub fn parked_threads(&self) -> usize {
        self.inner.parked.load(Ordering::SeqCst)
    }

    /// Threads ever spawned by this pool.
    pub fn spawned_total(&self) -> usize {
        self.inner.spawned_total.load(Ordering::SeqCst)
    }

    /// Drop every parked worker's channel so they retire (tests; the
    /// shared pool lives for the process).
    pub fn drain_idle(&self) {
        self.inner.idle.lock().unwrap().clear();
    }

    fn spawn_worker(&self, first: Dispatch) {
        let inner = Arc::clone(&self.inner);
        inner.live.fetch_add(1, Ordering::SeqCst);
        inner.spawned_total.fetch_add(1, Ordering::SeqCst);
        std::thread::spawn(move || {
            // Decrement `live` even if a job panics and unwinds us.
            struct LiveGuard(Arc<Inner>);
            impl Drop for LiveGuard {
                fn drop(&mut self) {
                    self.0.live.fetch_sub(1, Ordering::SeqCst);
                }
            }
            let guard = LiveGuard(inner);
            let inner = &guard.0;
            let mut dispatch = Some(first);
            // When this worker last became able to take work — clamps
            // the queue-wait event so it can never overlap the previous
            // job's spans on this thread's trace track.  0 for the first
            // dispatch: a fresh thread has no prior spans, so the full
            // enqueue→pickup wait (including spawn latency) is safe.
            let mut ready_at = 0u64;
            loop {
                if let Some((enqueued, job)) = dispatch.take() {
                    crate::obs::event_since(
                        "pool",
                        "queue.wait",
                        enqueued.max(ready_at),
                        0,
                    );
                    job();
                }
                // Park on a fresh channel each time, moving its only
                // Sender into the idle list: dropping that entry (a
                // `drain_idle`, or the pool itself dropping) hangs up
                // `prx.recv()` and the worker retires.  `parked` is
                // bumped in the same critical section as the push, so a
                // concurrent `submit`'s pop + `fetch_sub` can never
                // precede the matching `fetch_add` and underflow.
                let (ptx, prx) = channel::<Dispatch>();
                {
                    let mut idle = inner.idle.lock().unwrap();
                    if idle.len() >= inner.cap.load(Ordering::SeqCst) {
                        break; // parking lot full — retire
                    }
                    idle.push(ptx);
                    inner.parked.fetch_add(1, Ordering::SeqCst);
                }
                ready_at = crate::obs::now_us();
                match prx.recv() {
                    // A successful dispatch already un-counted us.
                    Ok(d) => dispatch = Some(d),
                    Err(_) => {
                        // drain_idle dropped our channel: retire.
                        inner.parked.fetch_sub(1, Ordering::SeqCst);
                        break;
                    }
                }
            }
        });
    }
}

static SHARED: OnceLock<CommPool> = OnceLock::new();
static CONFIGURED: AtomicUsize = AtomicUsize::new(0);

/// The process-wide pool used by the fleet paths (RingLane flights, TCP
/// writer loops).  Always constructible; whether hot paths *route* onto
/// it is gated by [`enabled`].
pub fn shared() -> &'static CommPool {
    SHARED.get_or_init(|| CommPool::new(2))
}

/// Record a worker's `transport.comm_pool_size` knob.  Monotonic max
/// across callers (thread-mode fleets share the process); a size ≥ 2
/// turns [`enabled`] on for pool-gated paths like the TCP writers.
pub fn configure(size: usize) {
    CONFIGURED.fetch_max(size, Ordering::SeqCst);
    let cap = CONFIGURED.load(Ordering::SeqCst).max(2);
    shared().set_cap(cap);
}

/// Has any worker in this process asked for the pool (size ≥ 2)?
pub fn enabled() -> bool {
    CONFIGURED.load(Ordering::SeqCst) >= 2
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::mpsc;
    use std::time::{Duration, Instant};

    fn spin_until(what: &str, f: impl Fn() -> bool) {
        let t0 = Instant::now();
        while !f() {
            assert!(
                t0.elapsed() < Duration::from_secs(10),
                "timed out waiting for {what}"
            );
            std::thread::yield_now();
        }
    }

    #[test]
    fn sequential_jobs_reuse_one_parked_thread() {
        // The whole point of the pool: a round-per-round cadence (submit,
        // join, train, submit …) must not spawn a thread per round.
        let pool = CommPool::new(2);
        for i in 0..10u32 {
            let (tx, rx) = mpsc::channel();
            pool.submit(move || tx.send(i).unwrap());
            assert_eq!(rx.recv().unwrap(), i);
            // Wait for the worker to park again before the next round —
            // exactly the lane's join-then-begin cadence.
            spin_until("worker parked", || pool.parked_threads() == 1);
        }
        assert_eq!(pool.spawned_total(), 1, "thread churn across rounds");
        assert_eq!(pool.live_threads(), 1);
    }

    #[test]
    fn queue_contention_burst_converges_back_to_cap() {
        // Many small concurrent jobs: everything runs (nothing queues
        // behind a busy worker), and after the burst the pool retires
        // down to `cap` parked threads — no leak across "epochs".
        let pool = CommPool::new(3);
        let ran = Arc::new(AtomicUsize::new(0));
        for _epoch in 0..4 {
            let (tx, rx) = mpsc::channel();
            for _ in 0..32 {
                let ran = Arc::clone(&ran);
                let tx = tx.clone();
                pool.submit(move || {
                    ran.fetch_add(1, Ordering::SeqCst);
                    tx.send(()).unwrap();
                });
            }
            drop(tx);
            for _ in 0..32 {
                rx.recv().unwrap();
            }
            // Excess workers retire once the parking lot is full.
            spin_until("pool quiesced to cap", || {
                pool.live_threads() <= 3 && pool.parked_threads() <= 3
            });
        }
        assert_eq!(ran.load(Ordering::SeqCst), 4 * 32);
        // Steady state after epoch 1: bursts reuse the parked cap
        // workers plus at most (burst − cap) fresh ones per burst; the
        // leak signature this guards against is live_threads growing
        // per epoch, checked by the quiesce above.
        assert!(pool.live_threads() >= 1);
    }

    #[test]
    fn drain_idle_retires_parked_workers() {
        let pool = CommPool::new(2);
        let (tx, rx) = mpsc::channel();
        pool.submit(move || tx.send(()).unwrap());
        rx.recv().unwrap();
        spin_until("worker parked", || pool.parked_threads() == 1);
        pool.drain_idle();
        spin_until("workers retired", || pool.live_threads() == 0);
        assert_eq!(pool.parked_threads(), 0);
    }

    #[test]
    fn panicked_job_does_not_leak_live_count() {
        let pool = CommPool::new(1);
        pool.submit(|| panic!("job panic"));
        spin_until("panicked worker reaped", || pool.live_threads() == 0);
        // The pool recovers: the next job spawns a fresh worker.
        let (tx, rx) = mpsc::channel();
        pool.submit(move || tx.send(7u8).unwrap());
        assert_eq!(rx.recv().unwrap(), 7);
    }

    #[test]
    fn shared_pool_configure_is_monotonic() {
        assert!(shared().live_threads() < 10_000); // constructible
        configure(1);
        configure(3);
        configure(2); // must not shrink below 3
        assert!(enabled());
    }
}
