//! Local (in-memory) transport backend: the chunked ring AllReduce
//! (Baidu 2017) over mpsc channels — one OS thread per "cluster".
//!
//! The collective algebra itself lives in
//! [`crate::transport::RingTransport`] as a provided method; this module
//! only supplies the wire (send to successor / receive from predecessor)
//! so the threaded coordinator and the TCP multi-process path run the
//! byte-identical schedule.  Reduce-scatter (C−1 hops) then all-gather
//! (C−1 hops); each worker sends 2·(C−1)/C·payload bytes total — the
//! §2.4.1 factor.

use crate::transport::RingTransport;
use anyhow::anyhow;
use std::sync::mpsc::{Receiver, Sender};
use std::sync::Arc;

pub use crate::transport::ByteMeter;

/// One worker's view of the ring: a sender to its successor and a receiver
/// from its predecessor.
pub struct RingMember {
    pub rank: usize,
    pub size: usize,
    pub tx_next: Sender<Vec<f32>>,
    pub rx_prev: Receiver<Vec<f32>>,
    pub meter: Arc<ByteMeter>,
    /// Spent chunk buffers handed back by the collective via `recycle`;
    /// `send_next` drains this instead of allocating per hop.
    pool: Vec<Vec<f32>>,
}

/// Build a ring of `size` members (move each into its worker thread).
pub fn build_ring(size: usize) -> Vec<RingMember> {
    let meter = Arc::new(ByteMeter::default());
    let mut txs = Vec::with_capacity(size);
    let mut rxs = Vec::with_capacity(size);
    for _ in 0..size {
        let (tx, rx) = std::sync::mpsc::channel::<Vec<f32>>();
        txs.push(tx);
        rxs.push(Some(rx));
    }
    let mut members = Vec::with_capacity(size);
    for rank in 0..size {
        members.push(RingMember {
            rank,
            size,
            // member r sends to r+1, so it holds tx of channel (r+1)'s rx.
            tx_next: txs[(rank + 1) % size].clone(),
            rx_prev: rxs[rank].take().unwrap(),
            meter: Arc::clone(&meter),
            pool: Vec::new(),
        });
    }
    members
}

impl RingTransport for RingMember {
    fn rank(&self) -> usize {
        self.rank
    }

    fn size(&self) -> usize {
        self.size
    }

    fn send_next(&mut self, chunk: &[f32]) -> anyhow::Result<()> {
        // Reuse a recycled buffer when one is available: the ring hot
        // path then circulates a fixed set of chunk buffers instead of
        // allocating one per hop.
        let mut buf = self.pool.pop().unwrap_or_default();
        buf.clear();
        buf.extend_from_slice(chunk);
        self.tx_next
            .send(buf)
            .map_err(|_| anyhow!("ring peer hung up (send)"))
    }

    fn recv_prev(&mut self) -> anyhow::Result<Vec<f32>> {
        self.rx_prev
            .recv()
            .map_err(|_| anyhow!("ring peer hung up (recv)"))
    }

    fn recycle(&mut self, buf: Vec<f32>) {
        if self.pool.len() < 4 {
            self.pool.push(buf);
        }
    }

    fn meter(&self) -> &ByteMeter {
        &self.meter
    }
}

/// Wire bytes per worker for a ring all-reduce of `payload` bytes across
/// `c` members: 2 · (c−1)/c · payload (paper §2.4.1).
pub fn ring_wire_bytes_per_worker(payload: u64, c: usize) -> u64 {
    if c <= 1 {
        0
    } else {
        2 * (c as u64 - 1) * payload / c as u64
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg32;

    fn run_ring(c: usize, n: usize) -> (Vec<Vec<f32>>, u64) {
        let members = build_ring(c);
        let mut inputs: Vec<Vec<f32>> = Vec::new();
        let mut rng = Pcg32::seed_from(7);
        for _ in 0..c {
            let mut v = vec![0.0f32; n];
            rng.fill_normal(&mut v, 0.0, 1.0);
            inputs.push(v);
        }
        let expected: Vec<f32> = (0..n)
            .map(|i| inputs.iter().map(|v| v[i]).sum())
            .collect();
        let meter = Arc::clone(&members[0].meter);
        let results: Vec<Vec<f32>> = std::thread::scope(|scope| {
            let handles: Vec<_> = members
                .into_iter()
                .zip(inputs.clone())
                .map(|(mut m, mut buf)| {
                    scope.spawn(move || {
                        m.allreduce_sum(&mut buf).unwrap();
                        buf
                    })
                })
                .collect();
            handles.into_iter().map(|h| h.join().unwrap()).collect()
        });
        for r in &results {
            for (a, b) in r.iter().zip(&expected) {
                assert!((a - b).abs() < 1e-4 * (1.0 + b.abs()), "{a} vs {b}");
            }
        }
        (results, meter.total())
    }

    #[test]
    fn allreduce_sums_across_2_and_5_members() {
        run_ring(2, 1000);
        run_ring(5, 999); // non-divisible chunking
    }

    #[test]
    fn fewer_elements_than_members_yields_empty_chunks() {
        // n < c: some chunk bounds collapse to zero length; the collective
        // must still converge, moving only the 4·(hi−lo) bytes per hop
        // that the non-empty chunks actually carry.
        run_ring(5, 3);
        run_ring(4, 1);
        // n = 0: every chunk is empty — still a valid (if pointless)
        // collective, not a crash.
        let members = build_ring(3);
        std::thread::scope(|scope| {
            for mut m in members {
                scope.spawn(move || {
                    let mut buf: Vec<f32> = Vec::new();
                    m.allreduce_sum(&mut buf).unwrap();
                });
            }
        });
    }

    #[test]
    fn wire_bytes_match_ring_formula() {
        let n = 1000usize;
        let c = 4usize;
        let (_, bytes) = run_ring(c, n);
        // Total across all workers = c * 2(c-1)/c * payload = 2(c-1)*payload.
        let payload = 4 * n as u64;
        assert_eq!(bytes, 2 * (c as u64 - 1) * payload);
        assert_eq!(
            ring_wire_bytes_per_worker(payload, c),
            2 * (c as u64 - 1) * payload / c as u64
        );
    }

    #[test]
    fn single_member_is_noop() {
        let members = build_ring(1);
        let mut m = members.into_iter().next().unwrap();
        let mut buf = vec![1.0f32, 2.0];
        m.allreduce_sum(&mut buf).unwrap();
        assert_eq!(buf, vec![1.0, 2.0]);
        assert_eq!(m.meter.total(), 0);
    }

    #[test]
    fn mean_divides_by_size() {
        let members = build_ring(2);
        let bufs = vec![vec![2.0f32; 10], vec![4.0f32; 10]];
        let results: Vec<Vec<f32>> = std::thread::scope(|scope| {
            members
                .into_iter()
                .zip(bufs)
                .map(|(mut m, mut b)| {
                    scope.spawn(move || {
                        m.allreduce_mean(&mut b).unwrap();
                        b
                    })
                })
                .collect::<Vec<_>>()
                .into_iter()
                .map(|h| h.join().unwrap())
                .collect()
        });
        for r in results {
            assert!(r.iter().all(|&v| (v - 3.0).abs() < 1e-6));
        }
    }
}
