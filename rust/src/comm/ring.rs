//! Chunked ring AllReduce over message channels (Baidu 2017): the actual
//! collective the coordinator's worker threads run, with per-hop byte
//! metering.  Reduce-scatter (C−1 hops) then all-gather (C−1 hops); each
//! worker sends 2·(C−1)/C·payload bytes total — the §2.4.1 factor.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::mpsc::{Receiver, Sender};
use std::sync::Arc;

/// Byte meter shared by all ring members (one per "link budget").
#[derive(Default, Debug)]
pub struct ByteMeter {
    pub sent: AtomicU64,
    pub messages: AtomicU64,
}

impl ByteMeter {
    pub fn add(&self, bytes: u64) {
        self.sent.fetch_add(bytes, Ordering::Relaxed);
        self.messages.fetch_add(1, Ordering::Relaxed);
    }

    pub fn total(&self) -> u64 {
        self.sent.load(Ordering::Relaxed)
    }
}

/// One worker's view of the ring: a sender to its successor and a receiver
/// from its predecessor.
pub struct RingMember {
    pub rank: usize,
    pub size: usize,
    pub tx_next: Sender<Vec<f32>>,
    pub rx_prev: Receiver<Vec<f32>>,
    pub meter: Arc<ByteMeter>,
}

/// Build a ring of `size` members (move each into its worker thread).
pub fn build_ring(size: usize) -> Vec<RingMember> {
    let meter = Arc::new(ByteMeter::default());
    let mut txs = Vec::with_capacity(size);
    let mut rxs = Vec::with_capacity(size);
    for _ in 0..size {
        let (tx, rx) = std::sync::mpsc::channel::<Vec<f32>>();
        txs.push(tx);
        rxs.push(Some(rx));
    }
    let mut members = Vec::with_capacity(size);
    for rank in 0..size {
        members.push(RingMember {
            rank,
            size,
            // member r sends to r+1, so it holds tx of channel (r+1)'s rx.
            tx_next: txs[(rank + 1) % size].clone(),
            rx_prev: rxs[rank].take().unwrap(),
            meter: Arc::clone(&meter),
        });
    }
    members
}

impl RingMember {
    /// In-place ring all-reduce (sum) of `buf` across all members.
    /// Every member must call this with an equal-length buffer.
    pub fn allreduce_sum(&self, buf: &mut [f32]) -> anyhow::Result<()> {
        let c = self.size;
        if c == 1 {
            return Ok(());
        }
        let n = buf.len();
        // Chunk boundaries (c chunks, last absorbs the remainder).
        let bounds: Vec<(usize, usize)> = (0..c)
            .map(|i| {
                let lo = i * n / c;
                let hi = (i + 1) * n / c;
                (lo, hi)
            })
            .collect();

        // Phase 1: reduce-scatter.  At step s, send chunk (rank - s) and
        // accumulate incoming chunk (rank - s - 1).
        for s in 0..c - 1 {
            let send_idx = (self.rank + c - s) % c;
            let (lo, hi) = bounds[send_idx];
            let payload = buf[lo..hi].to_vec();
            self.meter.add(4 * payload.len() as u64);
            self.tx_next
                .send(payload)
                .map_err(|_| anyhow::anyhow!("ring peer hung up (send)"))?;
            let recv_idx = (self.rank + c - s - 1) % c;
            let incoming = self
                .rx_prev
                .recv()
                .map_err(|_| anyhow::anyhow!("ring peer hung up (recv)"))?;
            let (lo, hi) = bounds[recv_idx];
            for (dst, src) in buf[lo..hi].iter_mut().zip(&incoming) {
                *dst += src;
            }
        }
        // Phase 2: all-gather.  Send the chunk just completed.
        for s in 0..c - 1 {
            let send_idx = (self.rank + 1 + c - s) % c;
            let (lo, hi) = bounds[send_idx];
            let payload = buf[lo..hi].to_vec();
            self.meter.add(4 * payload.len() as u64);
            self.tx_next
                .send(payload)
                .map_err(|_| anyhow::anyhow!("ring peer hung up (send)"))?;
            let recv_idx = (self.rank + c - s) % c;
            let incoming = self
                .rx_prev
                .recv()
                .map_err(|_| anyhow::anyhow!("ring peer hung up (recv)"))?;
            let (lo, hi) = bounds[recv_idx];
            buf[lo..hi].copy_from_slice(&incoming);
        }
        Ok(())
    }

    /// Mean across members.
    pub fn allreduce_mean(&self, buf: &mut [f32]) -> anyhow::Result<()> {
        self.allreduce_sum(buf)?;
        let inv = 1.0 / self.size as f32;
        for v in buf.iter_mut() {
            *v *= inv;
        }
        Ok(())
    }
}

/// Wire bytes per worker for a ring all-reduce of `payload` bytes across
/// `c` members: 2 · (c−1)/c · payload (paper §2.4.1).
pub fn ring_wire_bytes_per_worker(payload: u64, c: usize) -> u64 {
    if c <= 1 {
        0
    } else {
        2 * (c as u64 - 1) * payload / c as u64
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg32;

    fn run_ring(c: usize, n: usize) -> (Vec<Vec<f32>>, u64) {
        let members = build_ring(c);
        let mut inputs: Vec<Vec<f32>> = Vec::new();
        let mut rng = Pcg32::seed_from(7);
        for _ in 0..c {
            let mut v = vec![0.0f32; n];
            rng.fill_normal(&mut v, 0.0, 1.0);
            inputs.push(v);
        }
        let expected: Vec<f32> = (0..n)
            .map(|i| inputs.iter().map(|v| v[i]).sum())
            .collect();
        let meter = Arc::clone(&members[0].meter);
        let results: Vec<Vec<f32>> = std::thread::scope(|scope| {
            let handles: Vec<_> = members
                .into_iter()
                .zip(inputs.clone())
                .map(|(m, mut buf)| {
                    scope.spawn(move || {
                        m.allreduce_sum(&mut buf).unwrap();
                        buf
                    })
                })
                .collect();
            handles.into_iter().map(|h| h.join().unwrap()).collect()
        });
        for r in &results {
            for (a, b) in r.iter().zip(&expected) {
                assert!((a - b).abs() < 1e-4 * (1.0 + b.abs()), "{a} vs {b}");
            }
        }
        (results, meter.total())
    }

    #[test]
    fn allreduce_sums_across_2_and_5_members() {
        run_ring(2, 1000);
        run_ring(5, 999); // non-divisible chunking
    }

    #[test]
    fn wire_bytes_match_ring_formula() {
        let n = 1000usize;
        let c = 4usize;
        let (_, bytes) = run_ring(c, n);
        // Total across all workers = c * 2(c-1)/c * payload = 2(c-1)*payload.
        let payload = 4 * n as u64;
        assert_eq!(bytes, 2 * (c as u64 - 1) * payload);
        assert_eq!(
            ring_wire_bytes_per_worker(payload, c),
            2 * (c as u64 - 1) * payload / c as u64
        );
    }

    #[test]
    fn single_member_is_noop() {
        let members = build_ring(1);
        let mut buf = vec![1.0f32, 2.0];
        members[0].allreduce_sum(&mut buf).unwrap();
        assert_eq!(buf, vec![1.0, 2.0]);
        assert_eq!(members[0].meter.total(), 0);
    }

    #[test]
    fn mean_divides_by_size() {
        let members = build_ring(2);
        let bufs = vec![vec![2.0f32; 10], vec![4.0f32; 10]];
        let results: Vec<Vec<f32>> = std::thread::scope(|scope| {
            members
                .into_iter()
                .zip(bufs)
                .map(|(m, mut b)| {
                    scope.spawn(move || {
                        m.allreduce_mean(&mut b).unwrap();
                        b
                    })
                })
                .collect::<Vec<_>>()
                .into_iter()
                .map(|h| h.join().unwrap())
                .collect()
        });
        for r in results {
            assert!(r.iter().all(|&v| (v - 3.0).abs() < 1e-6));
        }
    }
}
