//! Collective communication: the local (mpsc) ring backend ([`ring`])
//! behind the [`crate::transport::RingTransport`] trait, plus the wire
//! cost model shared with the throughput simulator.  The TCP multi-process
//! backend and fault injection live in [`crate::transport`].

pub mod pool;
pub mod ring;

pub use crate::transport::RingTransport;
pub use ring::{build_ring, ring_wire_bytes_per_worker, ByteMeter, RingMember};

use crate::config::NetworkConfig;

/// Time for one ring all-reduce of `payload` bytes per worker across the
/// WAN: each of the 2(C−1) hops moves payload/C bytes over the slowest
/// inter-cluster link, plus per-hop latency.  (§2.4.1's model.)
pub fn ring_allreduce_seconds(payload: u64, net: &NetworkConfig) -> f64 {
    let c = net.clusters;
    if c <= 1 {
        return 0.0;
    }
    let hops = 2 * (c - 1);
    let chunk = payload as f64 / c as f64;
    let bw = net.inter_bw_gbps * 1e9 / 8.0;
    hops as f64 * (chunk / bw + net.latency_ms * 1e-3)
}

/// Parameter-server exchange time (TopK/Cocktail path): every cluster
/// pushes `up` bytes and pulls `down` bytes over its WAN link, serialized
/// at the server's link.  The server handles the (c−1) uploads and (c−1)
/// downloads one message at a time, so each of the 2·(c−1) serialized
/// messages pays the per-message WAN latency — not a flat 2·latency.
pub fn parameter_server_seconds(up: u64, down: u64, net: &NetworkConfig) -> f64 {
    let c = net.clusters;
    if c <= 1 {
        return 0.0;
    }
    let bw = net.inter_bw_gbps * 1e9 / 8.0;
    // server link carries (c-1) uploads then (c-1) downloads.
    let xfer = ((c - 1) as f64) * (up as f64 + down as f64) / bw;
    xfer + 2.0 * ((c - 1) as f64) * net.latency_ms * 1e-3
}

#[cfg(test)]
mod tests {
    use super::*;

    fn net(c: usize, gbps: f64) -> NetworkConfig {
        NetworkConfig {
            clusters: c,
            inter_bw_gbps: gbps,
            intra_bw_gbps: 100.0,
            latency_ms: 0.0,
        }
    }

    #[test]
    fn paper_2_4_1_time_reproduced() {
        // 100B fp32 across 3 clusters at 1 Gbps ≈ 1.18 h.
        let payload = 100_000_000_000u64 * 4;
        let secs = ring_allreduce_seconds(payload, &net(3, 1.0));
        let hours = secs / 3600.0;
        assert!((hours - 1.185).abs() < 0.01, "hours={hours}");
    }

    #[test]
    fn single_cluster_is_free() {
        assert_eq!(ring_allreduce_seconds(1_000_000, &net(1, 1.0)), 0.0);
        assert_eq!(parameter_server_seconds(10, 10, &net(1, 1.0)), 0.0);
    }

    #[test]
    fn time_scales_inversely_with_bandwidth() {
        let p = 1_000_000_000u64;
        let t1 = ring_allreduce_seconds(p, &net(2, 1.0));
        let t10 = ring_allreduce_seconds(p, &net(2, 10.0));
        assert!((t1 / t10 - 10.0).abs() < 1e-9);
    }

    #[test]
    fn latency_adds_per_hop() {
        let mut n = net(4, 1.0);
        n.latency_ms = 50.0;
        let t = ring_allreduce_seconds(0, &n);
        // 2*(4-1) hops * 50 ms
        assert!((t - 0.3).abs() < 1e-9, "t={t}");
    }

    #[test]
    fn parameter_server_latency_is_per_message() {
        // Regression: the server serializes (c-1) uploads and (c-1)
        // downloads, so latency scales with cluster count instead of the
        // old flat 2·latency.
        let mut n = net(4, 1e12); // effectively infinite bandwidth
        n.latency_ms = 50.0;
        let t = parameter_server_seconds(0, 0, &n);
        // 2*(4-1) messages * 50 ms.
        assert!((t - 0.3).abs() < 1e-9, "t={t}");

        // Transfer term unchanged: (c-1)*(up+down)/bw on top of latency.
        let mut n2 = net(3, 1.0);
        n2.latency_ms = 10.0;
        let t2 = parameter_server_seconds(1_000_000_000, 500_000_000, &n2);
        let bw = 1e9 / 8.0;
        let expect = 2.0 * 1.5e9 / bw + 2.0 * 2.0 * 0.010;
        assert!((t2 - expect).abs() < 1e-9, "t2={t2} expect={expect}");
    }
}
