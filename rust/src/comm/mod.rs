//! Collective communication: the local (mpsc) ring backend ([`ring`])
//! behind the [`crate::transport::RingTransport`] trait, plus the wire
//! cost model shared with the throughput simulator.  The TCP multi-process
//! backend and fault injection live in [`crate::transport`].

pub mod pool;
pub mod ring;

pub use crate::transport::RingTransport;
pub use ring::{build_ring, ring_wire_bytes_per_worker, ByteMeter, RingMember};

use crate::config::NetworkConfig;

/// Time for one ring all-reduce of `payload` bytes per worker across the
/// WAN: each of the 2(C−1) hops moves payload/C bytes over the slowest
/// inter-cluster link, plus per-hop latency.  (§2.4.1's model.)
pub fn ring_allreduce_seconds(payload: u64, net: &NetworkConfig) -> f64 {
    let c = net.clusters;
    if c <= 1 {
        return 0.0;
    }
    let hops = 2 * (c - 1);
    let chunk = payload as f64 / c as f64;
    let bw = net.inter_bw_gbps * 1e9 / 8.0;
    hops as f64 * (chunk / bw + net.latency_ms * 1e-3)
}

/// Time for one hierarchical two-level all-reduce of `payload` bytes:
/// each site ring-reduces over the LAN (`intra_bw_gbps`, negligible
/// latency), one leader per site joins the WAN ring over
/// `inter_bw_gbps`, then the result is broadcast back through each
/// site's ring store-and-forward.  `site_sizes[i]` is the number of
/// clusters at site `i` (the sizes sum to C).
///
/// The WAN term moves 2·(S−1)/S·payload per leader instead of the flat
/// ring's 2·(C−1)/C — the whole point of the topology.  With one
/// cluster per site (`site_sizes = [1; C]`) this degenerates to exactly
/// [`ring_allreduce_seconds`].
pub fn hier_allreduce_seconds(
    payload: u64,
    net: &NetworkConfig,
    site_sizes: &[usize],
) -> f64 {
    let s = site_sizes.len();
    if s == 0 {
        return 0.0;
    }
    let intra_bw = net.intra_bw_gbps * 1e9 / 8.0;
    // LAN phases run concurrently per site; the slowest site bounds them.
    let intra = site_sizes
        .iter()
        .map(|&n| {
            if n <= 1 {
                return 0.0;
            }
            let reduce =
                (2 * (n - 1)) as f64 * (payload as f64 / n as f64) / intra_bw;
            let bcast = (n - 1) as f64 * payload as f64 / intra_bw;
            reduce + bcast
        })
        .fold(0.0, f64::max);
    let cross = if s <= 1 {
        0.0
    } else {
        let bw = net.inter_bw_gbps * 1e9 / 8.0;
        (2 * (s - 1)) as f64
            * (payload as f64 / s as f64 / bw + net.latency_ms * 1e-3)
    };
    intra + cross
}

/// Parameter-server exchange time (TopK/Cocktail path): every cluster
/// pushes `up` bytes and pulls `down` bytes over its WAN link, serialized
/// at the server's link.  The server handles the (c−1) uploads and (c−1)
/// downloads one message at a time, so each of the 2·(c−1) serialized
/// messages pays the per-message WAN latency — not a flat 2·latency.
pub fn parameter_server_seconds(up: u64, down: u64, net: &NetworkConfig) -> f64 {
    let c = net.clusters;
    if c <= 1 {
        return 0.0;
    }
    let bw = net.inter_bw_gbps * 1e9 / 8.0;
    // server link carries (c-1) uploads then (c-1) downloads.
    let xfer = ((c - 1) as f64) * (up as f64 + down as f64) / bw;
    xfer + 2.0 * ((c - 1) as f64) * net.latency_ms * 1e-3
}

#[cfg(test)]
mod tests {
    use super::*;

    fn net(c: usize, gbps: f64) -> NetworkConfig {
        NetworkConfig {
            clusters: c,
            inter_bw_gbps: gbps,
            intra_bw_gbps: 100.0,
            latency_ms: 0.0,
        }
    }

    #[test]
    fn paper_2_4_1_time_reproduced() {
        // 100B fp32 across 3 clusters at 1 Gbps ≈ 1.18 h.
        let payload = 100_000_000_000u64 * 4;
        let secs = ring_allreduce_seconds(payload, &net(3, 1.0));
        let hours = secs / 3600.0;
        assert!((hours - 1.185).abs() < 0.01, "hours={hours}");
    }

    #[test]
    fn single_cluster_is_free() {
        assert_eq!(ring_allreduce_seconds(1_000_000, &net(1, 1.0)), 0.0);
        assert_eq!(parameter_server_seconds(10, 10, &net(1, 1.0)), 0.0);
    }

    #[test]
    fn time_scales_inversely_with_bandwidth() {
        let p = 1_000_000_000u64;
        let t1 = ring_allreduce_seconds(p, &net(2, 1.0));
        let t10 = ring_allreduce_seconds(p, &net(2, 10.0));
        assert!((t1 / t10 - 10.0).abs() < 1e-9);
    }

    #[test]
    fn latency_adds_per_hop() {
        let mut n = net(4, 1.0);
        n.latency_ms = 50.0;
        let t = ring_allreduce_seconds(0, &n);
        // 2*(4-1) hops * 50 ms
        assert!((t - 0.3).abs() < 1e-9, "t={t}");
    }

    #[test]
    fn hier_with_one_cluster_per_site_is_the_flat_ring() {
        let mut n = net(4, 1.0);
        n.latency_ms = 30.0;
        let p = 1_000_000_000u64;
        let flat = ring_allreduce_seconds(p, &n);
        let hier = hier_allreduce_seconds(p, &n, &[1, 1, 1, 1]);
        assert!((flat - hier).abs() < 1e-12, "flat={flat} hier={hier}");
    }

    #[test]
    fn hier_wan_term_moves_the_two_level_fraction() {
        // 4 clusters as 2 sites of 2 at 1 Gbps WAN, (near) free LAN: the
        // WAN term drops from 2·(C−1)/C to 2·(S−1)/S of the payload.
        let mut n = net(4, 1.0);
        n.intra_bw_gbps = 1e12; // LAN effectively free
        n.latency_ms = 0.0;
        let p = 1_000_000_000u64;
        let flat = ring_allreduce_seconds(p, &n);
        let hier = hier_allreduce_seconds(p, &n, &[2, 2]);
        let flat_frac = 2.0 * 3.0 / 4.0; // 2(C-1)/C
        let hier_frac = 2.0 * 1.0 / 2.0; // 2(S-1)/S
        assert!(
            (hier / flat - hier_frac / flat_frac).abs() < 1e-9,
            "hier={hier} flat={flat}"
        );
    }

    #[test]
    fn hier_single_site_pays_no_wan() {
        let mut n = net(4, 0.001); // terrible WAN
        n.latency_ms = 500.0;
        let t = hier_allreduce_seconds(1_000_000_000, &n, &[4]);
        // Pure LAN: 2·(4−1) hops of payload/4 plus a 3-hop broadcast.
        let bw = 100.0 * 1e9 / 8.0;
        let expect = 6.0 * 0.25e9 / bw + 3.0 * 1e9 / bw;
        assert!((t - expect).abs() < 1e-9, "t={t} expect={expect}");
    }

    #[test]
    fn parameter_server_latency_is_per_message() {
        // Regression: the server serializes (c-1) uploads and (c-1)
        // downloads, so latency scales with cluster count instead of the
        // old flat 2·latency.
        let mut n = net(4, 1e12); // effectively infinite bandwidth
        n.latency_ms = 50.0;
        let t = parameter_server_seconds(0, 0, &n);
        // 2*(4-1) messages * 50 ms.
        assert!((t - 0.3).abs() < 1e-9, "t={t}");

        // Transfer term unchanged: (c-1)*(up+down)/bw on top of latency.
        let mut n2 = net(3, 1.0);
        n2.latency_ms = 10.0;
        let t2 = parameter_server_seconds(1_000_000_000, 500_000_000, &n2);
        let bw = 1e9 / 8.0;
        let expect = 2.0 * 1.5e9 / bw + 2.0 * 2.0 * 0.010;
        assert!((t2 - expect).abs() < 1e-9, "t2={t2} expect={expect}");
    }
}
