//! Mini property-testing framework (no proptest offline).
//!
//! `props(seed).runs(n).check(|g| { ... })` draws generator inputs from a
//! deterministic PCG stream; on failure it reports the failing case index
//! and re-runs with a fixed seed printed for reproduction.  Shrinking is
//! size-biased generation (small cases are tried first) rather than
//! post-hoc shrinking — adequate for the numeric invariants tested here.

use super::rng::Pcg32;

pub struct Gen {
    pub rng: Pcg32,
    /// Grows 0.0 -> 1.0 across the run so early cases are small.
    pub size: f64,
}

impl Gen {
    pub fn usize_in(&mut self, lo: usize, hi: usize) -> usize {
        debug_assert!(lo <= hi);
        // Bias toward the low end early in the run.
        let span = (hi - lo) as f64;
        let cap = lo as f64 + 1.0 + span * self.size;
        let hi_eff = (cap.min(hi as f64)) as usize;
        if hi_eff <= lo {
            return lo;
        }
        lo + self.rng.below((hi_eff - lo + 1) as u32) as usize
    }

    pub fn f32_in(&mut self, lo: f32, hi: f32) -> f32 {
        lo + (hi - lo) * self.rng.next_f32()
    }

    pub fn bool(&mut self) -> bool {
        self.rng.next_u32() & 1 == 1
    }

    pub fn vec_f32(&mut self, len: usize, lo: f32, hi: f32) -> Vec<f32> {
        (0..len).map(|_| self.f32_in(lo, hi)).collect()
    }

    pub fn vec_normal(&mut self, len: usize, std: f32) -> Vec<f32> {
        let mut v = vec![0.0; len];
        self.rng.fill_normal(&mut v, 0.0, std);
        v
    }

    pub fn pick<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.rng.below(xs.len() as u32) as usize]
    }
}

pub struct Props {
    seed: u64,
    runs: usize,
}

pub fn props(seed: u64) -> Props {
    Props { seed, runs: 64 }
}

impl Props {
    pub fn runs(mut self, n: usize) -> Self {
        self.runs = n;
        self
    }

    /// Panics (failing the enclosing #[test]) on the first property
    /// violation, reporting the case number and seed.
    pub fn check<F: FnMut(&mut Gen) -> Result<(), String>>(self, mut f: F) {
        for case in 0..self.runs {
            let mut g = Gen {
                rng: Pcg32::seed_from(self.seed).split(case as u64),
                size: (case as f64 + 1.0) / self.runs as f64,
            };
            if let Err(msg) = f(&mut g) {
                panic!(
                    "property failed at case {case}/{} (seed {}): {msg}",
                    self.runs, self.seed
                );
            }
        }
    }
}

/// Helper: approximate equality with context for Result-style properties.
pub fn close(a: f32, b: f32, tol: f32, what: &str) -> Result<(), String> {
    if (a - b).abs() <= tol * (1.0 + a.abs().max(b.abs())) {
        Ok(())
    } else {
        Err(format!("{what}: {a} != {b} (tol {tol})"))
    }
}

pub fn close_slice(a: &[f32], b: &[f32], tol: f32, what: &str) -> Result<(), String> {
    if a.len() != b.len() {
        return Err(format!("{what}: length {} != {}", a.len(), b.len()));
    }
    for (i, (&x, &y)) in a.iter().zip(b).enumerate() {
        if (x - y).abs() > tol * (1.0 + x.abs().max(y.abs())) {
            return Err(format!("{what}[{i}]: {x} != {y} (tol {tol})"));
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passes_a_true_property() {
        props(1).runs(50).check(|g| {
            let n = g.usize_in(1, 64);
            let v = g.vec_f32(n, -1.0, 1.0);
            let s: f32 = v.iter().sum();
            let s2: f32 = v.iter().rev().sum();
            close(s, s2, 1e-5, "sum commutes")
        });
    }

    #[test]
    #[should_panic(expected = "property failed")]
    fn fails_a_false_property() {
        props(2).runs(50).check(|g| {
            let n = g.usize_in(1, 100);
            if n > 50 {
                Err(format!("found n={n}"))
            } else {
                Ok(())
            }
        });
    }

    #[test]
    fn early_cases_are_small() {
        let mut first_sizes = vec![];
        props(3).runs(20).check(|g| {
            first_sizes.push(g.usize_in(0, 1000));
            Ok(())
        });
        assert!(first_sizes[0] <= 60, "{first_sizes:?}");
    }
}
