//! Tiny leveled logger with wall-clock timestamps, level filtering via the
//! `DILOCOX_LOG` env var (error|warn|info|debug|trace), and a capture mode
//! for tests.  All trainer/coordinator progress lines flow through this.

use std::io::Write;
use std::sync::atomic::{AtomicU8, Ordering};
use std::sync::Mutex;
use std::time::{SystemTime, UNIX_EPOCH};

#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord)]
pub enum Level {
    Error = 0,
    Warn = 1,
    Info = 2,
    Debug = 3,
    Trace = 4,
}

impl Level {
    pub fn parse(s: &str) -> Level {
        match s.to_ascii_lowercase().as_str() {
            "error" => Level::Error,
            "warn" => Level::Warn,
            "debug" => Level::Debug,
            "trace" => Level::Trace,
            _ => Level::Info,
        }
    }

    fn tag(self) -> &'static str {
        match self {
            Level::Error => "ERROR",
            Level::Warn => " WARN",
            Level::Info => " INFO",
            Level::Debug => "DEBUG",
            Level::Trace => "TRACE",
        }
    }
}

static MAX_LEVEL: AtomicU8 = AtomicU8::new(255); // 255 = uninitialized
static CAPTURE: Mutex<Option<Vec<String>>> = Mutex::new(None);

fn max_level() -> u8 {
    let v = MAX_LEVEL.load(Ordering::Relaxed);
    if v != 255 {
        return v;
    }
    let lvl = std::env::var("DILOCOX_LOG")
        .map(|s| Level::parse(&s))
        .unwrap_or(Level::Info) as u8;
    MAX_LEVEL.store(lvl, Ordering::Relaxed);
    lvl
}

pub fn set_level(l: Level) {
    MAX_LEVEL.store(l as u8, Ordering::Relaxed);
}

/// Route log lines into a buffer (tests); returns previous buffer.
pub fn capture(enable: bool) -> Vec<String> {
    let mut g = CAPTURE.lock().unwrap();
    let prev = g.take().unwrap_or_default();
    *g = if enable { Some(Vec::new()) } else { None };
    prev
}

pub fn log(level: Level, target: &str, msg: &str) {
    if (level as u8) > max_level() {
        return;
    }
    let now = SystemTime::now()
        .duration_since(UNIX_EPOCH)
        .unwrap_or_default();
    let secs = now.as_secs();
    let line = format!(
        "[{}.{:03} {} {}] {}",
        secs % 100_000,
        now.subsec_millis(),
        level.tag(),
        target,
        msg
    );
    let mut g = CAPTURE.lock().unwrap();
    if let Some(buf) = g.as_mut() {
        buf.push(line);
    } else {
        let _ = writeln!(std::io::stderr(), "{line}");
    }
}

#[macro_export]
macro_rules! info {
    ($target:expr, $($arg:tt)*) => {
        $crate::util::log::log($crate::util::log::Level::Info, $target,
                               &format!($($arg)*))
    };
}

#[macro_export]
macro_rules! warnln {
    ($target:expr, $($arg:tt)*) => {
        $crate::util::log::log($crate::util::log::Level::Warn, $target,
                               &format!($($arg)*))
    };
}

#[macro_export]
macro_rules! debugln {
    ($target:expr, $($arg:tt)*) => {
        $crate::util::log::log($crate::util::log::Level::Debug, $target,
                               &format!($($arg)*))
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn filters_by_level_and_captures() {
        set_level(Level::Info);
        capture(true);
        log(Level::Info, "t", "hello");
        log(Level::Debug, "t", "hidden");
        let lines = capture(false);
        assert_eq!(lines.len(), 1);
        assert!(lines[0].contains("hello"));
        assert!(lines[0].contains("INFO"));
    }

    #[test]
    fn level_parsing() {
        assert_eq!(Level::parse("TRACE"), Level::Trace);
        assert_eq!(Level::parse("bogus"), Level::Info);
        assert!(Level::Error < Level::Trace);
    }
}
