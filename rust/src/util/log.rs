//! Tiny leveled logger with wall-clock timestamps, level filtering via the
//! `DILOCOX_LOG` env var (error|warn|info|debug|trace), and a capture mode
//! for tests.  All trainer/coordinator progress lines flow through this.
//!
//! Multi-process fleets interleave every worker's stderr on the
//! coordinator's terminal, so each process may stamp a **role tag**
//! (`c3` / `c3.s1`-style, set once at worker startup via [`set_role`])
//! that is printed on every line between the level and the target.
//! Capture is **thread-local**: a test sees exactly the lines logged on
//! its own thread, so `cargo test`'s parallel test threads never steal
//! each other's output (the old single global buffer did).

use std::cell::RefCell;
use std::io::Write;
use std::sync::atomic::{AtomicU8, Ordering};
use std::sync::OnceLock;
use std::time::{SystemTime, UNIX_EPOCH};

#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord)]
pub enum Level {
    Error = 0,
    Warn = 1,
    Info = 2,
    Debug = 3,
    Trace = 4,
}

impl Level {
    pub fn parse(s: &str) -> Level {
        match s.to_ascii_lowercase().as_str() {
            "error" => Level::Error,
            "warn" => Level::Warn,
            "debug" => Level::Debug,
            "trace" => Level::Trace,
            _ => Level::Info,
        }
    }

    fn tag(self) -> &'static str {
        match self {
            Level::Error => "ERROR",
            Level::Warn => " WARN",
            Level::Info => " INFO",
            Level::Debug => "DEBUG",
            Level::Trace => "TRACE",
        }
    }
}

static MAX_LEVEL: AtomicU8 = AtomicU8::new(255); // 255 = uninitialized
static ROLE: OnceLock<String> = OnceLock::new();

thread_local! {
    static CAPTURE: RefCell<Option<Vec<String>>> = const { RefCell::new(None) };
}

fn max_level() -> u8 {
    let v = MAX_LEVEL.load(Ordering::Relaxed);
    if v != 255 {
        return v;
    }
    let lvl = std::env::var("DILOCOX_LOG")
        .map(|s| Level::parse(&s))
        .unwrap_or(Level::Info) as u8;
    MAX_LEVEL.store(lvl, Ordering::Relaxed);
    lvl
}

pub fn set_level(l: Level) {
    MAX_LEVEL.store(l as u8, Ordering::Relaxed);
}

/// Stamp this process's fleet role (`c3` or `c3.s1`) onto every log line.
/// First call wins; meant to be called exactly once at worker startup.
pub fn set_role(tag: &str) {
    let _ = ROLE.set(tag.to_string());
}

/// Route this thread's log lines into a buffer (tests); returns the
/// previous buffer.  Thread-local, so parallel tests don't interfere.
pub fn capture(enable: bool) -> Vec<String> {
    CAPTURE.with(|c| {
        let mut g = c.borrow_mut();
        let prev = g.take().unwrap_or_default();
        *g = if enable { Some(Vec::new()) } else { None };
        prev
    })
}

pub fn log(level: Level, target: &str, msg: &str) {
    if (level as u8) > max_level() {
        return;
    }
    let now = SystemTime::now()
        .duration_since(UNIX_EPOCH)
        .unwrap_or_default();
    let secs = now.as_secs();
    let line = match ROLE.get() {
        Some(role) => format!(
            "[{}.{:03} {} {} {}] {}",
            secs % 100_000,
            now.subsec_millis(),
            level.tag(),
            role,
            target,
            msg
        ),
        None => format!(
            "[{}.{:03} {} {}] {}",
            secs % 100_000,
            now.subsec_millis(),
            level.tag(),
            target,
            msg
        ),
    };
    let captured = CAPTURE.with(|c| {
        let mut g = c.borrow_mut();
        match g.as_mut() {
            Some(buf) => {
                buf.push(line.clone());
                true
            }
            None => false,
        }
    });
    if !captured {
        let _ = writeln!(std::io::stderr(), "{line}");
    }
}

#[macro_export]
macro_rules! info {
    ($target:expr, $($arg:tt)*) => {
        $crate::util::log::log($crate::util::log::Level::Info, $target,
                               &format!($($arg)*))
    };
}

#[macro_export]
macro_rules! warnln {
    ($target:expr, $($arg:tt)*) => {
        $crate::util::log::log($crate::util::log::Level::Warn, $target,
                               &format!($($arg)*))
    };
}

#[macro_export]
macro_rules! debugln {
    ($target:expr, $($arg:tt)*) => {
        $crate::util::log::log($crate::util::log::Level::Debug, $target,
                               &format!($($arg)*))
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn filters_by_level_and_captures() {
        set_level(Level::Info);
        capture(true);
        log(Level::Info, "t", "hello");
        log(Level::Debug, "t", "hidden");
        let lines = capture(false);
        assert_eq!(lines.len(), 1);
        assert!(lines[0].contains("hello"));
        assert!(lines[0].contains("INFO"));
    }

    #[test]
    fn level_parsing() {
        assert_eq!(Level::parse("TRACE"), Level::Trace);
        assert_eq!(Level::parse("bogus"), Level::Info);
        assert!(Level::Error < Level::Trace);
    }

    #[test]
    fn capture_is_thread_local() {
        set_level(Level::Info);
        capture(true);
        log(Level::Info, "t", "mine");
        std::thread::spawn(|| {
            // Uncaptured on this thread: goes to stderr, not our buffer.
            log(Level::Info, "t", "other-thread");
        })
        .join()
        .unwrap();
        let lines = capture(false);
        assert_eq!(lines.len(), 1);
        assert!(lines[0].contains("mine"));
    }
}
