//! Minimal JSON substrate (no serde offline): a recursive-descent parser and
//! a writer, sufficient for manifest.json, metrics export, and bench reports.
//!
//! Full JSON grammar is supported (objects, arrays, strings with escapes,
//! numbers, bools, null); numbers are stored as f64 (manifest values are
//! shape ints and hyperparameters, all exactly representable).

use std::collections::BTreeMap;
use std::fmt;

#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

#[derive(Debug)]
pub struct JsonError {
    pub msg: String,
    pub pos: usize,
}

impl fmt::Display for JsonError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json error at byte {}: {}", self.pos, self.msg)
    }
}

impl std::error::Error for JsonError {}

impl Json {
    pub fn parse(s: &str) -> Result<Json, JsonError> {
        let mut p = Parser { b: s.as_bytes(), i: 0 };
        p.ws();
        let v = p.value()?;
        p.ws();
        if p.i != p.b.len() {
            return Err(p.err("trailing characters"));
        }
        Ok(v)
    }

    // -- typed accessors ---------------------------------------------------

    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    pub fn at(&self, idx: usize) -> Option<&Json> {
        match self {
            Json::Arr(a) => a.get(idx),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|n| n as usize)
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Some(m),
            _ => None,
        }
    }

    /// `obj.path("a.b.c")`
    pub fn path(&self, dotted: &str) -> Option<&Json> {
        let mut cur = self;
        for part in dotted.split('.') {
            cur = cur.get(part)?;
        }
        Some(cur)
    }

    // -- writer ------------------------------------------------------------

    pub fn to_string_pretty(&self) -> String {
        let mut s = String::new();
        self.write(&mut s, 0, true);
        s
    }

    /// Single-line form (no newlines) — one JSONL record per value.
    pub fn to_string_compact(&self) -> String {
        let mut s = String::new();
        self.write(&mut s, 0, false);
        s
    }

    fn write(&self, out: &mut String, indent: usize, pretty: bool) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 9e15 {
                    out.push_str(&format!("{}", *n as i64));
                } else {
                    out.push_str(&format!("{}", n));
                }
            }
            Json::Str(s) => write_escaped(out, s),
            Json::Arr(a) => {
                out.push('[');
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    if pretty {
                        out.push('\n');
                        out.push_str(&" ".repeat(indent + 1));
                    }
                    v.write(out, indent + 1, pretty);
                }
                if pretty && !a.is_empty() {
                    out.push('\n');
                    out.push_str(&" ".repeat(indent));
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    if pretty {
                        out.push('\n');
                        out.push_str(&" ".repeat(indent + 1));
                    }
                    write_escaped(out, k);
                    out.push_str(": ");
                    v.write(out, indent + 1, pretty);
                }
                if pretty && !m.is_empty() {
                    out.push('\n');
                    out.push_str(&" ".repeat(indent));
                }
                out.push('}');
            }
        }
    }
}

impl From<&str> for Json {
    fn from(s: &str) -> Self {
        Json::Str(s.to_string())
    }
}
impl From<f64> for Json {
    fn from(n: f64) -> Self {
        Json::Num(n)
    }
}
impl From<usize> for Json {
    fn from(n: usize) -> Self {
        Json::Num(n as f64)
    }
}
impl From<bool> for Json {
    fn from(b: bool) -> Self {
        Json::Bool(b)
    }
}

/// Convenience builder for objects.
pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
    Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
}

fn write_escaped(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                out.push_str(&format!("\\u{:04x}", c as u32))
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError { msg: msg.to_string(), pos: self.i }
    }

    fn ws(&mut self) {
        while self.i < self.b.len()
            && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r')
        {
            self.i += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn expect(&mut self, c: u8) -> Result<(), JsonError> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", c as char)))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("unexpected character")),
        }
    }

    fn lit(&mut self, word: &str, v: Json) -> Result<Json, JsonError> {
        if self.b[self.i..].starts_with(word.as_bytes()) {
            self.i += word.len();
            Ok(v)
        } else {
            Err(self.err("bad literal"))
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.expect(b'{')?;
        let mut m = BTreeMap::new();
        self.ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.ws();
            let k = self.string()?;
            self.ws();
            self.expect(b':')?;
            self.ws();
            let v = self.value()?;
            m.insert(k, v);
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Json::Obj(m));
                }
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.expect(b'[')?;
        let mut a = Vec::new();
        self.ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Json::Arr(a));
        }
        loop {
            self.ws();
            a.push(self.value()?);
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b']') => {
                    self.i += 1;
                    return Ok(Json::Arr(a));
                }
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            match self.peek() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => {
                    self.i += 1;
                    return Ok(s);
                }
                Some(b'\\') => {
                    self.i += 1;
                    match self.peek() {
                        Some(b'"') => s.push('"'),
                        Some(b'\\') => s.push('\\'),
                        Some(b'/') => s.push('/'),
                        Some(b'n') => s.push('\n'),
                        Some(b't') => s.push('\t'),
                        Some(b'r') => s.push('\r'),
                        Some(b'b') => s.push('\u{8}'),
                        Some(b'f') => s.push('\u{c}'),
                        Some(b'u') => {
                            if self.i + 4 >= self.b.len() {
                                return Err(self.err("bad \\u escape"));
                            }
                            let hex = std::str::from_utf8(
                                &self.b[self.i + 1..self.i + 5],
                            )
                            .map_err(|_| self.err("bad \\u escape"))?;
                            let cp = u32::from_str_radix(hex, 16)
                                .map_err(|_| self.err("bad \\u escape"))?;
                            s.push(char::from_u32(cp).unwrap_or('\u{fffd}'));
                            self.i += 4;
                        }
                        _ => return Err(self.err("bad escape")),
                    }
                    self.i += 1;
                }
                Some(_) => {
                    // UTF-8 passthrough: copy the full code point.
                    let start = self.i;
                    let len = utf8_len(self.b[self.i]);
                    self.i += len;
                    if self.i > self.b.len() {
                        return Err(self.err("bad utf8"));
                    }
                    s.push_str(
                        std::str::from_utf8(&self.b[start..self.i])
                            .map_err(|_| self.err("bad utf8"))?,
                    );
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.i;
        if self.peek() == Some(b'-') {
            self.i += 1;
        }
        while self
            .peek()
            .map(|c| c.is_ascii_digit() || matches!(c, b'.' | b'e' | b'E' | b'+' | b'-'))
            .unwrap_or(false)
        {
            self.i += 1;
        }
        std::str::from_utf8(&self.b[start..self.i])
            .ok()
            .and_then(|s| s.parse::<f64>().ok())
            .map(Json::Num)
            .ok_or_else(|| self.err("bad number"))
    }
}

fn utf8_len(b: u8) -> usize {
    match b {
        0x00..=0x7f => 1,
        0xc0..=0xdf => 2,
        0xe0..=0xef => 3,
        _ => 4,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_scalars() {
        assert_eq!(Json::parse("42").unwrap(), Json::Num(42.0));
        assert_eq!(Json::parse("-3.5e2").unwrap(), Json::Num(-350.0));
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(
            Json::parse("\"a\\nb\"").unwrap(),
            Json::Str("a\nb".into())
        );
    }

    #[test]
    fn parses_nested() {
        let v = Json::parse(
            r#"{"a": [1, 2, {"b": "c"}], "d": {"e": false}}"#,
        )
        .unwrap();
        assert_eq!(v.path("d.e"), Some(&Json::Bool(false)));
        assert_eq!(
            v.get("a").unwrap().at(2).unwrap().get("b").unwrap().as_str(),
            Some("c")
        );
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("12 34").is_err());
        assert!(Json::parse("\"open").is_err());
    }

    #[test]
    fn roundtrip() {
        let src = r#"{"cfg": {"d": 64, "names": ["a", "b"], "f": 1.5, "on": true, "x": null}}"#;
        let v = Json::parse(src).unwrap();
        let re = Json::parse(&v.to_string_pretty()).unwrap();
        assert_eq!(v, re);
    }

    #[test]
    fn compact_is_single_line_and_roundtrips() {
        let src = r#"{"a": [1, {"b": "c\nd"}], "e": null}"#;
        let v = Json::parse(src).unwrap();
        let compact = v.to_string_compact();
        assert!(!compact.contains('\n'));
        assert_eq!(Json::parse(&compact).unwrap(), v);
    }

    #[test]
    fn unicode_and_escapes_roundtrip() {
        let v = Json::Str("héllo \"w\"\n\tπ".into());
        let re = Json::parse(&v.to_string_pretty()).unwrap();
        assert_eq!(v, re);
    }

    #[test]
    fn parses_real_manifest() {
        // Shape check against an actual exported manifest when present.
        let path = concat!(
            env!("CARGO_MANIFEST_DIR"),
            "/artifacts/tiny/manifest.json"
        );
        if let Ok(text) = std::fs::read_to_string(path) {
            let man = Json::parse(&text).unwrap();
            assert_eq!(man.get("preset").unwrap().as_str(), Some("tiny"));
            assert!(man.path("programs.step_single.file").is_some());
            assert!(man.get("param_count").unwrap().as_usize().unwrap() > 0);
        }
    }
}
