//! CLI argument parser substrate (no clap offline).
//!
//! Supports `--key value`, `--key=value`, boolean `--flag`, repeated keys,
//! positional args, and generates usage text from registered options.

use std::collections::BTreeMap;

#[derive(Debug, Clone)]
pub struct OptSpec {
    pub name: String,
    pub help: String,
    pub default: Option<String>,
    pub is_flag: bool,
}

#[derive(Debug, Default)]
pub struct Args {
    opts: BTreeMap<String, Vec<String>>,
    flags: Vec<String>,
    pub positional: Vec<String>,
}

#[derive(Debug, Default)]
pub struct CliSpec {
    pub name: String,
    pub about: String,
    specs: Vec<OptSpec>,
}

impl CliSpec {
    pub fn new(name: &str, about: &str) -> Self {
        CliSpec { name: name.into(), about: about.into(), specs: vec![] }
    }

    pub fn opt(mut self, name: &str, default: &str, help: &str) -> Self {
        self.specs.push(OptSpec {
            name: name.into(),
            help: help.into(),
            default: Some(default.into()),
            is_flag: false,
        });
        self
    }

    pub fn req(mut self, name: &str, help: &str) -> Self {
        self.specs.push(OptSpec {
            name: name.into(),
            help: help.into(),
            default: None,
            is_flag: false,
        });
        self
    }

    pub fn flag(mut self, name: &str, help: &str) -> Self {
        self.specs.push(OptSpec {
            name: name.into(),
            help: help.into(),
            default: None,
            is_flag: true,
        });
        self
    }

    pub fn usage(&self) -> String {
        let mut s = format!("{} — {}\n\nOptions:\n", self.name, self.about);
        for o in &self.specs {
            let head = if o.is_flag {
                format!("  --{}", o.name)
            } else {
                format!("  --{} <v>", o.name)
            };
            let def = match &o.default {
                Some(d) if !o.is_flag => format!(" [default: {}]", d),
                _ => String::new(),
            };
            s.push_str(&format!("{:<28}{}{}\n", head, o.help, def));
        }
        s
    }

    /// Parse; returns Err with a usage-style message on unknown options or
    /// missing required values.
    pub fn parse(&self, argv: &[String]) -> Result<Args, String> {
        let mut args = Args::default();
        let known: BTreeMap<&str, &OptSpec> =
            self.specs.iter().map(|s| (s.name.as_str(), s)).collect();
        let mut it = argv.iter().peekable();
        while let Some(a) = it.next() {
            if a == "--help" || a == "-h" {
                return Err(self.usage());
            }
            if let Some(body) = a.strip_prefix("--") {
                let (key, inline_val) = match body.split_once('=') {
                    Some((k, v)) => (k.to_string(), Some(v.to_string())),
                    None => (body.to_string(), None),
                };
                let spec = known
                    .get(key.as_str())
                    .ok_or_else(|| format!("unknown option --{key}\n\n{}", self.usage()))?;
                if spec.is_flag {
                    if inline_val.is_some() {
                        return Err(format!("--{key} is a flag, takes no value"));
                    }
                    args.flags.push(key);
                } else {
                    let val = match inline_val {
                        Some(v) => v,
                        None => it
                            .next()
                            .cloned()
                            .ok_or_else(|| format!("--{key} requires a value"))?,
                    };
                    args.opts.entry(key).or_default().push(val);
                }
            } else {
                args.positional.push(a.clone());
            }
        }
        // Fill defaults, check required.
        for spec in &self.specs {
            if spec.is_flag {
                continue;
            }
            if !args.opts.contains_key(&spec.name) {
                match &spec.default {
                    Some(d) => {
                        args.opts
                            .insert(spec.name.clone(), vec![d.clone()]);
                    }
                    None => {
                        return Err(format!(
                            "missing required option --{}\n\n{}",
                            spec.name,
                            self.usage()
                        ))
                    }
                }
            }
        }
        Ok(args)
    }
}

impl Args {
    pub fn get(&self, key: &str) -> &str {
        self.opts
            .get(key)
            .and_then(|v| v.last())
            .map(|s| s.as_str())
            .unwrap_or("")
    }

    pub fn get_all(&self, key: &str) -> Vec<&str> {
        self.opts
            .get(key)
            .map(|v| v.iter().map(|s| s.as_str()).collect())
            .unwrap_or_default()
    }

    pub fn flag(&self, key: &str) -> bool {
        self.flags.iter().any(|f| f == key)
    }

    pub fn get_usize(&self, key: &str) -> Result<usize, String> {
        self.get(key)
            .parse()
            .map_err(|_| format!("--{key}: expected integer, got '{}'", self.get(key)))
    }

    pub fn get_u64(&self, key: &str) -> Result<u64, String> {
        self.get(key)
            .parse()
            .map_err(|_| format!("--{key}: expected integer, got '{}'", self.get(key)))
    }

    pub fn get_f64(&self, key: &str) -> Result<f64, String> {
        self.get(key)
            .parse()
            .map_err(|_| format!("--{key}: expected number, got '{}'", self.get(key)))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn argv(s: &[&str]) -> Vec<String> {
        s.iter().map(|x| x.to_string()).collect()
    }

    fn spec() -> CliSpec {
        CliSpec::new("t", "test")
            .opt("preset", "tiny", "model preset")
            .req("steps", "outer steps")
            .flag("verbose", "chatty")
    }

    #[test]
    fn parses_forms() {
        let a = spec()
            .parse(&argv(&["--steps", "10", "--preset=small", "--verbose", "pos"]))
            .unwrap();
        assert_eq!(a.get("steps"), "10");
        assert_eq!(a.get("preset"), "small");
        assert!(a.flag("verbose"));
        assert_eq!(a.positional, vec!["pos"]);
        assert_eq!(a.get_usize("steps").unwrap(), 10);
        assert_eq!(a.get_u64("steps").unwrap(), 10);
        assert!(a.get_u64("preset").is_err());
    }

    #[test]
    fn defaults_and_required() {
        let a = spec().parse(&argv(&["--steps", "5"])).unwrap();
        assert_eq!(a.get("preset"), "tiny");
        assert!(!a.flag("verbose"));
        assert!(spec().parse(&argv(&[])).is_err()); // missing --steps
    }

    #[test]
    fn rejects_unknown() {
        assert!(spec().parse(&argv(&["--steps", "1", "--nope", "x"])).is_err());
    }

    #[test]
    fn help_returns_usage() {
        let err = spec().parse(&argv(&["--help"])).unwrap_err();
        assert!(err.contains("--preset"));
        assert!(err.contains("default: tiny"));
    }

    #[test]
    fn repeated_keys_keep_last_and_all() {
        let a = spec()
            .parse(&argv(&["--steps", "1", "--steps", "2"]))
            .unwrap();
        assert_eq!(a.get("steps"), "2");
        assert_eq!(a.get_all("steps"), vec!["1", "2"]);
    }
}
