//! Deterministic PRNG substrate (no `rand` crate offline): PCG32 core plus
//! the distributions the trainer needs (uniform, normal via Box–Muller,
//! shuffle, subset sampling).  Streams are splittable so every DP replica /
//! worker / data shard derives an independent, reproducible stream.

/// PCG-XSH-RR 64/32 (O'Neill 2014).
#[derive(Clone, Debug)]
pub struct Pcg32 {
    state: u64,
    inc: u64,
}

const PCG_MULT: u64 = 6364136223846793005;

impl Pcg32 {
    pub fn new(seed: u64, stream: u64) -> Self {
        let mut rng = Pcg32 { state: 0, inc: (stream << 1) | 1 };
        rng.state = rng.state.wrapping_mul(PCG_MULT).wrapping_add(rng.inc);
        rng.state = rng.state.wrapping_add(seed);
        rng.state = rng.state.wrapping_mul(PCG_MULT).wrapping_add(rng.inc);
        rng
    }

    pub fn seed_from(seed: u64) -> Self {
        Self::new(seed, 0xda3e39cb94b95bdb)
    }

    /// Derive an independent stream (for replica i, shard j, ...).
    pub fn split(&self, salt: u64) -> Pcg32 {
        Pcg32::new(
            self.state ^ salt.wrapping_mul(0x9e3779b97f4a7c15),
            self.inc ^ salt,
        )
    }

    #[inline]
    pub fn next_u32(&mut self) -> u32 {
        let old = self.state;
        self.state = old.wrapping_mul(PCG_MULT).wrapping_add(self.inc);
        let xorshifted = (((old >> 18) ^ old) >> 27) as u32;
        let rot = (old >> 59) as u32;
        xorshifted.rotate_right(rot)
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        ((self.next_u32() as u64) << 32) | self.next_u32() as u64
    }

    /// Uniform in [0, 1).
    #[inline]
    pub fn next_f32(&mut self) -> f32 {
        (self.next_u32() >> 8) as f32 * (1.0 / (1 << 24) as f32)
    }

    #[inline]
    pub fn next_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform integer in [0, bound) without modulo bias (Lemire).
    #[inline]
    pub fn below(&mut self, bound: u32) -> u32 {
        debug_assert!(bound > 0);
        loop {
            let x = self.next_u32() as u64;
            let m = x * bound as u64;
            let l = m as u32;
            if l >= bound || l >= (u32::MAX - bound + 1) % bound {
                return (m >> 32) as u32;
            }
        }
    }

    /// Standard normal via Box–Muller (one value per call; simple > fast
    /// here — init happens once per run).
    pub fn normal(&mut self) -> f32 {
        loop {
            let u1 = self.next_f32();
            if u1 > 1e-9 {
                let u2 = self.next_f32();
                let r = (-2.0 * (u1 as f64).ln()).sqrt();
                return (r * (2.0 * std::f64::consts::PI * u2 as f64).cos())
                    as f32;
            }
        }
    }

    pub fn fill_normal(&mut self, out: &mut [f32], mean: f32, std: f32) {
        for v in out.iter_mut() {
            *v = mean + std * self.normal();
        }
    }

    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i as u32 + 1) as usize;
            xs.swap(i, j);
        }
    }

    /// k distinct indices from [0, n) (Floyd's algorithm).
    pub fn sample_indices(&mut self, n: usize, k: usize) -> Vec<usize> {
        assert!(k <= n);
        let mut chosen = std::collections::HashSet::with_capacity(k);
        let mut out = Vec::with_capacity(k);
        for j in (n - k)..n {
            let t = self.below(j as u32 + 1) as usize;
            let pick = if chosen.contains(&t) { j } else { t };
            chosen.insert(pick);
            out.push(pick);
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_per_seed() {
        let mut a = Pcg32::seed_from(42);
        let mut b = Pcg32::seed_from(42);
        for _ in 0..100 {
            assert_eq!(a.next_u32(), b.next_u32());
        }
        let mut c = Pcg32::seed_from(43);
        assert_ne!(a.next_u32(), c.next_u32());
    }

    #[test]
    fn split_streams_diverge() {
        let root = Pcg32::seed_from(7);
        let mut s1 = root.split(1);
        let mut s2 = root.split(2);
        let same = (0..64).filter(|_| s1.next_u32() == s2.next_u32()).count();
        assert!(same < 4);
    }

    #[test]
    fn uniform_mean_and_range() {
        let mut r = Pcg32::seed_from(1);
        let n = 20_000;
        let mut sum = 0.0f64;
        for _ in 0..n {
            let x = r.next_f32();
            assert!((0.0..1.0).contains(&x));
            sum += x as f64;
        }
        assert!((sum / n as f64 - 0.5).abs() < 0.01);
    }

    #[test]
    fn below_is_unbiased_ish() {
        let mut r = Pcg32::seed_from(2);
        let mut counts = [0usize; 7];
        for _ in 0..70_000 {
            counts[r.below(7) as usize] += 1;
        }
        for &c in &counts {
            assert!((c as f64 - 10_000.0).abs() < 600.0, "{counts:?}");
        }
    }

    #[test]
    fn normal_moments() {
        let mut r = Pcg32::seed_from(3);
        let n = 50_000;
        let (mut s, mut s2) = (0.0f64, 0.0f64);
        for _ in 0..n {
            let x = r.normal() as f64;
            s += x;
            s2 += x * x;
        }
        let mean = s / n as f64;
        let var = s2 / n as f64 - mean * mean;
        assert!(mean.abs() < 0.02, "mean={mean}");
        assert!((var - 1.0).abs() < 0.05, "var={var}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Pcg32::seed_from(4);
        let mut xs: Vec<usize> = (0..100).collect();
        r.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
        assert_ne!(xs, (0..100).collect::<Vec<_>>());
    }

    #[test]
    fn sample_indices_distinct_and_in_range() {
        let mut r = Pcg32::seed_from(5);
        let idx = r.sample_indices(50, 20);
        assert_eq!(idx.len(), 20);
        let set: std::collections::HashSet<_> = idx.iter().collect();
        assert_eq!(set.len(), 20);
        assert!(idx.iter().all(|&i| i < 50));
    }
}
