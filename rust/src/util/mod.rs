//! Substrates implemented in-repo (offline crate policy, DESIGN.md):
//! PRNG, JSON, CLI parsing, logging, property testing, and small helpers.

pub mod check;
pub mod cli;
pub mod json;
pub mod log;
pub mod rng;

/// Human-readable byte counts for logs and reports.
pub fn fmt_bytes(b: u64) -> String {
    const UNITS: [&str; 6] = ["B", "KiB", "MiB", "GiB", "TiB", "PiB"];
    let mut v = b as f64;
    let mut u = 0;
    while v >= 1024.0 && u < UNITS.len() - 1 {
        v /= 1024.0;
        u += 1;
    }
    if u == 0 {
        format!("{b} B")
    } else {
        format!("{v:.2} {}", UNITS[u])
    }
}

/// Human-readable durations (simulated or wall).
pub fn fmt_secs(s: f64) -> String {
    if s < 1e-3 {
        format!("{:.1} µs", s * 1e6)
    } else if s < 1.0 {
        format!("{:.1} ms", s * 1e3)
    } else if s < 120.0 {
        format!("{s:.2} s")
    } else if s < 7200.0 {
        format!("{:.1} min", s / 60.0)
    } else {
        format!("{:.2} h", s / 3600.0)
    }
}

/// Mean of an f32 slice (0.0 for empty).
pub fn mean(xs: &[f32]) -> f32 {
    if xs.is_empty() {
        0.0
    } else {
        xs.iter().map(|&x| x as f64).sum::<f64>() as f32 / xs.len() as f32
    }
}

/// L2 norm.
pub fn l2(xs: &[f32]) -> f64 {
    xs.iter().map(|&x| (x as f64) * (x as f64)).sum::<f64>().sqrt()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bytes_formatting() {
        assert_eq!(fmt_bytes(512), "512 B");
        assert_eq!(fmt_bytes(2048), "2.00 KiB");
        assert_eq!(fmt_bytes(533_300_000_000), "496.67 GiB");
    }

    #[test]
    fn secs_formatting() {
        assert_eq!(fmt_secs(0.5), "500.0 ms");
        assert_eq!(fmt_secs(4248.0), "70.8 min");
        assert_eq!(fmt_secs(7300.0), "2.03 h");
    }

    #[test]
    fn mean_and_l2() {
        assert_eq!(mean(&[1.0, 2.0, 3.0]), 2.0);
        assert!((l2(&[3.0, 4.0]) - 5.0).abs() < 1e-9);
        assert_eq!(mean(&[]), 0.0);
    }
}
