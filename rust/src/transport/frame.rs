//! Length-delimited wire frames for the TCP transport (no serde offline).
//!
//! Layout: `u32 LE length(kind + body) | u8 kind | body`, all integers
//! little-endian, f32 as LE bit patterns.  One [`Msg`] per frame.  The
//! same framing carries the ring data plane ([`Msg::Data`]) and the
//! membership/epoch control plane (see the module docs in
//! [`crate::transport`]).

use crate::obs::TraceEvent;
use anyhow::{anyhow, Result};
use std::io::{Read, Write};

/// Refuse frames above this size (corrupt length prefix guard): 1 GiB.
pub const MAX_FRAME_BYTES: u32 = 1 << 30;

/// One committed ring member as shipped in [`Msg::Prepare`]: the global
/// rank, where its two listeners are (the flat/intra ring listener and
/// the hierarchical cross-site listener), and its site tag.  The order of
/// the member list IS the committed ring order — flat fleets use it
/// directly, `reordered` fleets receive the probe-optimized order, and
/// `hier` fleets receive (site, rank) order and slice it per site.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct MemberInfo {
    pub rank: u32,
    pub ring_port: u16,
    /// Listener for the leaders-only cross-site ring (hier topology).
    pub hier_port: u16,
    /// Site tag (0 = default single site).
    pub site: u32,
}

/// One directed link measurement reported by a worker probe
/// ([`Msg::ProbeReport`]): destination rank, throughput, latency.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct ProbeLink {
    pub to: u32,
    pub gbps: f64,
    pub latency_ms: f64,
}

/// Everything that crosses a transport socket.
#[derive(Clone, Debug, PartialEq)]
pub enum Msg {
    /// One ring chunk (data plane).
    Data { payload: Vec<f32> },
    /// Worker → coordinator, once at startup: where my listeners are
    /// (flat/intra ring, hierarchical cross-site ring, link-probe echo —
    /// `probe_port` 0 = no echo server running) and which site I am in.
    Hello {
        rank: u32,
        ring_port: u16,
        hier_port: u16,
        probe_port: u16,
        site: u32,
    },
    /// Coordinator → workers: proposed membership for `epoch`.
    /// `members` is the committed ring order ([`MemberInfo`] rows on
    /// 127.0.0.1).  `drain_round` is the committed drain-or-discard
    /// decision for one-step-delay overlap recovery: non-zero means every
    /// member of this epoch reported the SAME in-flight round, so the
    /// re-formed ring finishes that reduction (survivor-rescaled mean)
    /// before training resumes; zero means any in-flight delta is
    /// discarded back into error feedback (see [`crate::rounds::driver`]).
    Prepare {
        epoch: u32,
        resume_round: u32,
        members: Vec<MemberInfo>,
        drain_round: u32,
    },
    /// Worker → coordinator: membership proposal accepted.
    PrepareAck { epoch: u32 },
    /// Coordinator → workers: every live member acked; form the ring.
    Commit { epoch: u32 },
    /// Worker → coordinator: my ring collective failed at this epoch;
    /// `applied_rounds` outer updates are applied on my side, and
    /// `in_flight_round` is the round of the δ-reduction I still hold in
    /// flight (0 = none) — the coordinator's commit decides drain vs
    /// discard from the survivors' reports.
    RingBroken { epoch: u32, applied_rounds: u32, in_flight_round: u32 },
    /// Worker → coordinator: round finished (liveness + telemetry:
    /// loss, measured compute seconds per inner step, and the payload
    /// bytes of the reduction completed during this round — 0 on the
    /// first overlap round, so the wire ledger shows the one-step delay).
    Heartbeat { round: u32, loss: f32, step_secs: f32, wire_bytes: u64 },
    /// Worker → coordinator: all rounds done.
    Done { rounds: u32, wire_bytes: u64, final_loss: f32, params: Vec<f32> },
    /// Coordinator → workers: exit cleanly.
    Shutdown,
    /// Ring-socket handshake: dialer identifies (rank, epoch); the
    /// acceptor drops connections from the wrong predecessor or a stale
    /// epoch.  Also reused by the intra-cluster stage-link chain (`rank`
    /// then carries the *stage* index).
    RingHello { rank: u32, epoch: u32 },
    /// Stage-link data plane: activations for one (virtual-stage chunk,
    /// microbatch) flowing stage s → s+1 inside one cluster (pipeline
    /// dataflow over TCP; `chunk` is 0 except under interleaved
    /// schedules, where the wrap link S−1 → 0 carries chunk ≥ 1).
    Acts { chunk: u32, micro: u32, payload: Vec<f32> },
    /// Stage-link data plane: grad-activations for one (chunk,
    /// microbatch) flowing stage s+1 → s inside one cluster.
    Grads { chunk: u32, micro: u32, payload: Vec<f32> },
    /// Stage worker → coordinator, once at startup: one frame per
    /// (cluster, stage) OS process, advertising both of its listeners —
    /// the per-stage DP ring port and the intra-cluster stage-link port.
    StageHello { cluster: u32, stage: u32, ring_port: u16, link_port: u16 },
    /// Coordinator → one stage worker: *tailored* membership proposal for
    /// `epoch` — the recipient's own per-stage DP ring in committed order
    /// (`(cluster, ring_port)` on 127.0.0.1) plus the stage-link port of
    /// its downstream neighbor stage in the same cluster (0 = none: last
    /// stage, or a finishing epoch that forms no dataflow).
    /// `drain_round` is this *stage ring's* drain-or-discard decision
    /// (rings recover independently — stage rings can break one round
    /// apart under overlap, so the decision is per stage).
    StagePrepare {
        epoch: u32,
        resume_round: u32,
        ring_members: Vec<(u32, u16)>,
        link_down_port: u16,
        drain_round: u32,
    },
    /// Coordinator → one worker, before the first membership epoch: probe
    /// the listed peers' echo listeners (`(rank, probe_port)` on
    /// 127.0.0.1) with a seeded payload of `payload_elems` f32s,
    /// `repeats` trials each, and answer with a [`Msg::ProbeReport`].
    ProbeRequest { payload_elems: u32, repeats: u32, peers: Vec<(u32, u16)> },
    /// Worker → coordinator: measured outgoing links, one row per probed
    /// peer.
    ProbeReport { links: Vec<ProbeLink> },
    /// Worker → coordinator: a drained batch of structured trace events
    /// (see [`crate::obs`]) riding the control socket, so the
    /// coordinator can merge a fleet-wide timeline.  Control plane only
    /// — never crosses a ring socket, never metered, so tracing leaves
    /// the wire ledger bit-for-bit unchanged.
    TraceEvents { events: Vec<TraceEvent> },
}

impl Msg {
    fn kind(&self) -> u8 {
        match self {
            Msg::Data { .. } => 0,
            Msg::Hello { .. } => 1,
            Msg::Prepare { .. } => 2,
            Msg::PrepareAck { .. } => 3,
            Msg::Commit { .. } => 4,
            Msg::RingBroken { .. } => 5,
            Msg::Heartbeat { .. } => 6,
            Msg::Done { .. } => 7,
            Msg::Shutdown => 8,
            Msg::RingHello { .. } => 9,
            Msg::Acts { .. } => 10,
            Msg::Grads { .. } => 11,
            Msg::StageHello { .. } => 12,
            Msg::StagePrepare { .. } => 13,
            Msg::TraceEvents { .. } => 14,
            Msg::ProbeRequest { .. } => 15,
            Msg::ProbeReport { .. } => 16,
        }
    }

    /// Short name for error messages.
    pub fn name(&self) -> &'static str {
        match self {
            Msg::Data { .. } => "Data",
            Msg::Hello { .. } => "Hello",
            Msg::Prepare { .. } => "Prepare",
            Msg::PrepareAck { .. } => "PrepareAck",
            Msg::Commit { .. } => "Commit",
            Msg::RingBroken { .. } => "RingBroken",
            Msg::Heartbeat { .. } => "Heartbeat",
            Msg::Done { .. } => "Done",
            Msg::Shutdown => "Shutdown",
            Msg::RingHello { .. } => "RingHello",
            Msg::Acts { .. } => "Acts",
            Msg::Grads { .. } => "Grads",
            Msg::StageHello { .. } => "StageHello",
            Msg::StagePrepare { .. } => "StagePrepare",
            Msg::TraceEvents { .. } => "TraceEvents",
            Msg::ProbeRequest { .. } => "ProbeRequest",
            Msg::ProbeReport { .. } => "ProbeReport",
        }
    }
}

// ---- encode helpers -------------------------------------------------------

fn put_u16(buf: &mut Vec<u8>, v: u16) {
    buf.extend_from_slice(&v.to_le_bytes());
}

fn put_u32(buf: &mut Vec<u8>, v: u32) {
    buf.extend_from_slice(&v.to_le_bytes());
}

fn put_u64(buf: &mut Vec<u8>, v: u64) {
    buf.extend_from_slice(&v.to_le_bytes());
}

fn put_f32(buf: &mut Vec<u8>, v: f32) {
    buf.extend_from_slice(&v.to_le_bytes());
}

fn put_f64(buf: &mut Vec<u8>, v: f64) {
    buf.extend_from_slice(&v.to_bits().to_le_bytes());
}

fn put_f32s(buf: &mut Vec<u8>, vs: &[f32]) {
    put_u32(buf, vs.len() as u32);
    buf.reserve(4 * vs.len());
    for v in vs {
        buf.extend_from_slice(&v.to_le_bytes());
    }
}

fn put_str(buf: &mut Vec<u8>, s: &str) {
    put_u16(buf, s.len() as u16);
    buf.extend_from_slice(s.as_bytes());
}

// ---- decode helpers -------------------------------------------------------

struct Cursor<'a> {
    buf: &'a [u8],
    pos: usize,
}

impl<'a> Cursor<'a> {
    fn take(&mut self, n: usize) -> Result<&'a [u8]> {
        if self.pos + n > self.buf.len() {
            return Err(anyhow!("truncated frame body"));
        }
        let s = &self.buf[self.pos..self.pos + n];
        self.pos += n;
        Ok(s)
    }

    fn u16(&mut self) -> Result<u16> {
        Ok(u16::from_le_bytes(self.take(2)?.try_into().unwrap()))
    }

    fn u32(&mut self) -> Result<u32> {
        Ok(u32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }

    fn u64(&mut self) -> Result<u64> {
        Ok(u64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }

    fn f32(&mut self) -> Result<f32> {
        Ok(f32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }

    fn f64(&mut self) -> Result<f64> {
        Ok(f64::from_bits(u64::from_le_bytes(
            self.take(8)?.try_into().unwrap(),
        )))
    }

    fn f32s(&mut self) -> Result<Vec<f32>> {
        let n = self.u32()? as usize;
        let raw = self.take(4 * n)?;
        let mut out = Vec::with_capacity(n);
        for chunk in raw.chunks_exact(4) {
            out.push(f32::from_le_bytes(chunk.try_into().unwrap()));
        }
        Ok(out)
    }

    fn str(&mut self) -> Result<String> {
        let n = self.u16()? as usize;
        let raw = self.take(n)?;
        Ok(std::str::from_utf8(raw)
            .map_err(|_| anyhow!("non-utf8 string in frame"))?
            .to_string())
    }
}

/// Serialize `msg` into `kind + body` bytes (without the length prefix).
pub fn encode(msg: &Msg) -> Vec<u8> {
    let mut b = Vec::new();
    encode_into(&mut b, msg);
    b
}

/// Serialize `msg` into a caller-owned scratch buffer (appended; callers
/// `clear()` between frames).  The hot TCP send paths reuse one pre-sized
/// scratch per connection so steady-state framing allocates nothing.
pub fn encode_into(b: &mut Vec<u8>, msg: &Msg) {
    b.push(msg.kind());
    match msg {
        Msg::Data { payload } => put_f32s(&mut b, payload),
        Msg::Hello { rank, ring_port, hier_port, probe_port, site } => {
            put_u32(&mut b, *rank);
            put_u16(&mut b, *ring_port);
            put_u16(&mut b, *hier_port);
            put_u16(&mut b, *probe_port);
            put_u32(&mut b, *site);
        }
        Msg::Prepare { epoch, resume_round, members, drain_round } => {
            put_u32(&mut b, *epoch);
            put_u32(&mut b, *resume_round);
            put_u16(&mut b, members.len() as u16);
            for m in members {
                put_u32(&mut b, m.rank);
                put_u16(&mut b, m.ring_port);
                put_u16(&mut b, m.hier_port);
                put_u32(&mut b, m.site);
            }
            put_u32(&mut b, *drain_round);
        }
        Msg::PrepareAck { epoch } => put_u32(&mut b, *epoch),
        Msg::Commit { epoch } => put_u32(&mut b, *epoch),
        Msg::RingBroken { epoch, applied_rounds, in_flight_round } => {
            put_u32(&mut b, *epoch);
            put_u32(&mut b, *applied_rounds);
            put_u32(&mut b, *in_flight_round);
        }
        Msg::Heartbeat { round, loss, step_secs, wire_bytes } => {
            put_u32(&mut b, *round);
            put_f32(&mut b, *loss);
            put_f32(&mut b, *step_secs);
            put_u64(&mut b, *wire_bytes);
        }
        Msg::Done { rounds, wire_bytes, final_loss, params } => {
            put_u32(&mut b, *rounds);
            put_u64(&mut b, *wire_bytes);
            put_f32(&mut b, *final_loss);
            put_f32s(&mut b, params);
        }
        Msg::Shutdown => {}
        Msg::RingHello { rank, epoch } => {
            put_u32(&mut b, *rank);
            put_u32(&mut b, *epoch);
        }
        Msg::Acts { chunk, micro, payload } | Msg::Grads { chunk, micro, payload } => {
            put_u32(&mut b, *chunk);
            put_u32(&mut b, *micro);
            put_f32s(&mut b, payload);
        }
        Msg::StageHello { cluster, stage, ring_port, link_port } => {
            put_u32(&mut b, *cluster);
            put_u32(&mut b, *stage);
            put_u16(&mut b, *ring_port);
            put_u16(&mut b, *link_port);
        }
        Msg::StagePrepare {
            epoch,
            resume_round,
            ring_members,
            link_down_port,
            drain_round,
        } => {
            put_u32(&mut b, *epoch);
            put_u32(&mut b, *resume_round);
            put_u16(&mut b, ring_members.len() as u16);
            for (cluster, port) in ring_members {
                put_u32(&mut b, *cluster);
                put_u16(&mut b, *port);
            }
            put_u16(&mut b, *link_down_port);
            put_u32(&mut b, *drain_round);
        }
        Msg::ProbeRequest { payload_elems, repeats, peers } => {
            put_u32(&mut b, *payload_elems);
            put_u32(&mut b, *repeats);
            put_u16(&mut b, peers.len() as u16);
            for (rank, port) in peers {
                put_u32(&mut b, *rank);
                put_u16(&mut b, *port);
            }
        }
        Msg::ProbeReport { links } => {
            put_u16(&mut b, links.len() as u16);
            for l in links {
                put_u32(&mut b, l.to);
                put_f64(&mut b, l.gbps);
                put_f64(&mut b, l.latency_ms);
            }
        }
        Msg::TraceEvents { events } => {
            put_u32(&mut b, events.len() as u32);
            for e in events {
                put_u32(&mut b, e.cluster);
                put_u32(&mut b, e.stage);
                put_u32(&mut b, e.epoch);
                put_u32(&mut b, e.round);
                put_u32(&mut b, e.tid);
                put_u64(&mut b, e.start_us);
                put_u64(&mut b, e.dur_us);
                put_u64(&mut b, e.bytes);
                put_str(&mut b, &e.target);
                put_str(&mut b, &e.phase);
            }
        }
    }
}

/// Parse `kind + body` bytes back into a [`Msg`].
pub fn decode(bytes: &[u8]) -> Result<Msg> {
    if bytes.is_empty() {
        return Err(anyhow!("empty frame"));
    }
    let mut c = Cursor { buf: bytes, pos: 1 };
    let msg = match bytes[0] {
        0 => Msg::Data { payload: c.f32s()? },
        1 => Msg::Hello {
            rank: c.u32()?,
            ring_port: c.u16()?,
            hier_port: c.u16()?,
            probe_port: c.u16()?,
            site: c.u32()?,
        },
        2 => {
            let epoch = c.u32()?;
            let resume_round = c.u32()?;
            let n = c.u16()? as usize;
            let mut members = Vec::with_capacity(n);
            for _ in 0..n {
                members.push(MemberInfo {
                    rank: c.u32()?,
                    ring_port: c.u16()?,
                    hier_port: c.u16()?,
                    site: c.u32()?,
                });
            }
            Msg::Prepare { epoch, resume_round, members, drain_round: c.u32()? }
        }
        3 => Msg::PrepareAck { epoch: c.u32()? },
        4 => Msg::Commit { epoch: c.u32()? },
        5 => Msg::RingBroken {
            epoch: c.u32()?,
            applied_rounds: c.u32()?,
            in_flight_round: c.u32()?,
        },
        6 => Msg::Heartbeat {
            round: c.u32()?,
            loss: c.f32()?,
            step_secs: c.f32()?,
            wire_bytes: c.u64()?,
        },
        7 => Msg::Done {
            rounds: c.u32()?,
            wire_bytes: c.u64()?,
            final_loss: c.f32()?,
            params: c.f32s()?,
        },
        8 => Msg::Shutdown,
        9 => Msg::RingHello { rank: c.u32()?, epoch: c.u32()? },
        10 => Msg::Acts { chunk: c.u32()?, micro: c.u32()?, payload: c.f32s()? },
        11 => Msg::Grads { chunk: c.u32()?, micro: c.u32()?, payload: c.f32s()? },
        12 => Msg::StageHello {
            cluster: c.u32()?,
            stage: c.u32()?,
            ring_port: c.u16()?,
            link_port: c.u16()?,
        },
        13 => {
            let epoch = c.u32()?;
            let resume_round = c.u32()?;
            let n = c.u16()? as usize;
            let mut ring_members = Vec::with_capacity(n);
            for _ in 0..n {
                let cluster = c.u32()?;
                let port = c.u16()?;
                ring_members.push((cluster, port));
            }
            Msg::StagePrepare {
                epoch,
                resume_round,
                ring_members,
                link_down_port: c.u16()?,
                drain_round: c.u32()?,
            }
        }
        14 => {
            let n = c.u32()? as usize;
            let mut events = Vec::with_capacity(n.min(65536));
            for _ in 0..n {
                events.push(TraceEvent {
                    cluster: c.u32()?,
                    stage: c.u32()?,
                    epoch: c.u32()?,
                    round: c.u32()?,
                    tid: c.u32()?,
                    start_us: c.u64()?,
                    dur_us: c.u64()?,
                    bytes: c.u64()?,
                    target: c.str()?,
                    phase: c.str()?,
                });
            }
            Msg::TraceEvents { events }
        }
        15 => {
            let payload_elems = c.u32()?;
            let repeats = c.u32()?;
            let n = c.u16()? as usize;
            let mut peers = Vec::with_capacity(n);
            for _ in 0..n {
                let rank = c.u32()?;
                let port = c.u16()?;
                peers.push((rank, port));
            }
            Msg::ProbeRequest { payload_elems, repeats, peers }
        }
        16 => {
            let n = c.u16()? as usize;
            let mut links = Vec::with_capacity(n);
            for _ in 0..n {
                links.push(ProbeLink {
                    to: c.u32()?,
                    gbps: c.f64()?,
                    latency_ms: c.f64()?,
                });
            }
            Msg::ProbeReport { links }
        }
        k => return Err(anyhow!("unknown frame kind {k}")),
    };
    Ok(msg)
}

/// Write one length-delimited frame.
pub fn write_msg(w: &mut impl Write, msg: &Msg) -> Result<()> {
    let mut scratch = Vec::new();
    write_msg_with(w, &mut scratch, msg)
}

/// Write one length-delimited frame, encoding through a caller-owned
/// scratch buffer.  Persistent send paths (TCP ring hops, stage-link
/// writers) keep one scratch per connection so the per-frame `Vec`
/// allocation disappears from the hot path.
pub fn write_msg_with(
    w: &mut impl Write,
    scratch: &mut Vec<u8>,
    msg: &Msg,
) -> Result<()> {
    scratch.clear();
    encode_into(scratch, msg);
    if scratch.len() as u64 > MAX_FRAME_BYTES as u64 {
        return Err(anyhow!("frame too large: {} bytes", scratch.len()));
    }
    w.write_all(&(scratch.len() as u32).to_le_bytes())?;
    w.write_all(scratch)?;
    w.flush()?;
    Ok(())
}

/// Read one length-delimited frame (blocks per the stream's timeout).
pub fn read_msg(r: &mut impl Read) -> Result<Msg> {
    let mut len_bytes = [0u8; 4];
    r.read_exact(&mut len_bytes)?;
    let len = u32::from_le_bytes(len_bytes);
    if len == 0 || len > MAX_FRAME_BYTES {
        return Err(anyhow!("bad frame length {len}"));
    }
    let mut body = vec![0u8; len as usize];
    r.read_exact(&mut body)?;
    decode(&body)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg32;

    fn roundtrip(m: Msg) {
        let bytes = encode(&m);
        assert_eq!(decode(&bytes).unwrap(), m);
    }

    #[test]
    fn all_kinds_roundtrip() {
        roundtrip(Msg::Data { payload: vec![1.5, -2.25, 0.0, f32::MIN_POSITIVE] });
        roundtrip(Msg::Hello {
            rank: 3,
            ring_port: 40123,
            hier_port: 40124,
            probe_port: 40125,
            site: 2,
        });
        roundtrip(Msg::Prepare {
            epoch: 7,
            resume_round: 4,
            members: vec![
                MemberInfo { rank: 0, ring_port: 1111, hier_port: 3111, site: 0 },
                MemberInfo { rank: 2, ring_port: 2222, hier_port: 3222, site: 1 },
                MemberInfo { rank: 5, ring_port: 65535, hier_port: 0, site: 1 },
            ],
            drain_round: 0,
        });
        roundtrip(Msg::Prepare {
            epoch: 8,
            resume_round: 5,
            members: vec![MemberInfo {
                rank: 0,
                ring_port: 1111,
                hier_port: 0,
                site: 0,
            }],
            drain_round: 4,
        });
        roundtrip(Msg::PrepareAck { epoch: 7 });
        roundtrip(Msg::Commit { epoch: 7 });
        roundtrip(Msg::RingBroken {
            epoch: 7,
            applied_rounds: 3,
            in_flight_round: 4,
        });
        roundtrip(Msg::Heartbeat {
            round: 9,
            loss: 0.125,
            step_secs: 0.25,
            wire_bytes: 4096,
        });
        roundtrip(Msg::Done {
            rounds: 10,
            wire_bytes: u64::MAX / 3,
            final_loss: 1e-3,
            params: vec![0.5; 17],
        });
        roundtrip(Msg::Shutdown);
        roundtrip(Msg::RingHello { rank: 1, epoch: 2 });
        roundtrip(Msg::Acts { chunk: 1, micro: 3, payload: vec![1.0, -0.5] });
        roundtrip(Msg::Grads { chunk: 0, micro: 0, payload: vec![0.25; 9] });
        roundtrip(Msg::StageHello {
            cluster: 2,
            stage: 1,
            ring_port: 40001,
            link_port: 40002,
        });
        roundtrip(Msg::StagePrepare {
            epoch: 5,
            resume_round: 3,
            ring_members: vec![(0, 1111), (2, 2222)],
            link_down_port: 0,
            drain_round: 2,
        });
        roundtrip(Msg::StagePrepare {
            epoch: 1,
            resume_round: 1,
            ring_members: vec![(7, 65535)],
            link_down_port: 40100,
            drain_round: 0,
        });
        roundtrip(Msg::ProbeRequest {
            payload_elems: 65536,
            repeats: 3,
            peers: vec![(1, 40200), (2, 40201)],
        });
        roundtrip(Msg::ProbeRequest {
            payload_elems: 0,
            repeats: 0,
            peers: Vec::new(),
        });
        roundtrip(Msg::ProbeReport {
            links: vec![
                ProbeLink { to: 1, gbps: 94.25, latency_ms: 0.125 },
                ProbeLink { to: 2, gbps: 0.0, latency_ms: 0.0 },
                ProbeLink { to: 3, gbps: f64::INFINITY, latency_ms: 30.0 },
            ],
        });
        roundtrip(Msg::ProbeReport { links: Vec::new() });
        roundtrip(Msg::TraceEvents { events: Vec::new() });
        roundtrip(Msg::TraceEvents {
            events: vec![
                TraceEvent {
                    cluster: 2,
                    stage: 1,
                    epoch: 3,
                    round: 17,
                    tid: 5,
                    start_us: u64::MAX / 7,
                    dur_us: 1234,
                    bytes: 1 << 40,
                    target: "wire".to_string(),
                    phase: "allreduce".to_string(),
                },
                TraceEvent {
                    cluster: 0,
                    stage: 0,
                    epoch: 1,
                    round: 1,
                    tid: 0,
                    start_us: 0,
                    dur_us: 0,
                    bytes: 0,
                    target: "driver".to_string(),
                    phase: "recovery.discard".to_string(),
                },
            ],
        });
    }

    #[test]
    fn stream_roundtrip_over_a_pipe() {
        let mut buf: Vec<u8> = Vec::new();
        let msgs = vec![
            Msg::Hello {
                rank: 0,
                ring_port: 9,
                hier_port: 10,
                probe_port: 11,
                site: 1,
            },
            Msg::Data { payload: vec![3.0; 5] },
            Msg::Shutdown,
        ];
        for m in &msgs {
            write_msg(&mut buf, m).unwrap();
        }
        let mut r = &buf[..];
        for m in &msgs {
            assert_eq!(&read_msg(&mut r).unwrap(), m);
        }
        // Stream exhausted → io error surfaces as Err.
        assert!(read_msg(&mut r).is_err());
    }

    #[test]
    fn decode_rejects_garbage() {
        assert!(decode(&[]).is_err());
        assert!(decode(&[42]).is_err());
        // Truncated Data payload.
        let mut b = encode(&Msg::Data { payload: vec![1.0; 8] });
        b.truncate(b.len() - 3);
        assert!(decode(&b).is_err());
        // Oversized length prefix.
        let mut s: Vec<u8> = Vec::new();
        s.extend_from_slice(&(MAX_FRAME_BYTES + 1).to_le_bytes());
        s.push(0);
        assert!(read_msg(&mut &s[..]).is_err());
    }

    /// One instance of every frame kind with non-empty variable-length
    /// parts — the corpus the truncation fuzz slices apart.
    fn fuzz_corpus() -> Vec<Msg> {
        vec![
            Msg::Data { payload: vec![1.0, -2.5, 3.25] },
            Msg::Hello {
                rank: 7,
                ring_port: 40001,
                hier_port: 40002,
                probe_port: 40003,
                site: 1,
            },
            Msg::Prepare {
                epoch: 3,
                resume_round: 2,
                members: vec![
                    MemberInfo {
                        rank: 0,
                        ring_port: 1111,
                        hier_port: 3111,
                        site: 0,
                    },
                    MemberInfo {
                        rank: 4,
                        ring_port: 2222,
                        hier_port: 3222,
                        site: 1,
                    },
                ],
                drain_round: 1,
            },
            Msg::PrepareAck { epoch: 3 },
            Msg::Commit { epoch: 3 },
            Msg::RingBroken { epoch: 3, applied_rounds: 1, in_flight_round: 2 },
            Msg::Heartbeat {
                round: 5,
                loss: 0.5,
                step_secs: 0.01,
                wire_bytes: 1024,
            },
            Msg::Done {
                rounds: 6,
                wire_bytes: 1 << 20,
                final_loss: 0.25,
                params: vec![0.5; 3],
            },
            Msg::Shutdown,
            Msg::RingHello { rank: 2, epoch: 4 },
            Msg::Acts { chunk: 2, micro: 1, payload: vec![9.0; 2] },
            Msg::Grads { chunk: 0, micro: 2, payload: vec![-9.0; 2] },
            Msg::StageHello {
                cluster: 1,
                stage: 2,
                ring_port: 40002,
                link_port: 40003,
            },
            Msg::StagePrepare {
                epoch: 4,
                resume_round: 3,
                ring_members: vec![(0, 1111), (2, 2222)],
                link_down_port: 40004,
                drain_round: 0,
            },
            Msg::TraceEvents {
                events: vec![TraceEvent {
                    cluster: 1,
                    stage: 0,
                    epoch: 2,
                    round: 3,
                    tid: 4,
                    start_us: 5,
                    dur_us: 6,
                    bytes: 7,
                    target: "wire".to_string(),
                    phase: "send".to_string(),
                }],
            },
            Msg::ProbeRequest {
                payload_elems: 4096,
                repeats: 2,
                peers: vec![(1, 40200), (3, 40201)],
            },
            Msg::ProbeReport {
                links: vec![ProbeLink { to: 1, gbps: 2.5, latency_ms: 30.0 }],
            },
        ]
    }

    /// Seeded random byte soup must never panic or blow memory in
    /// `decode` — every outcome is Ok(some Msg) or a clean Err.  Covers
    /// all kind tags (the first byte cycles through 0..=255 far past the
    /// 0..=14 valid range) and wildly lying length fields inside bodies.
    #[test]
    fn decode_fuzz_random_bytes_never_panic() {
        let mut rng = Pcg32::new(0xf2a3_1e0d, 0);
        for case in 0..20_000u32 {
            let len = (rng.below(257)) as usize;
            let mut bytes = vec![0u8; len];
            for b in bytes.iter_mut() {
                *b = rng.next_u32() as u8;
            }
            if !bytes.is_empty() {
                // Make sure every kind tag gets dense coverage.
                bytes[0] = (case % 256) as u8;
            }
            let _ = decode(&bytes); // must return, not panic
        }
    }

    /// Every strict prefix of every valid encoding decodes to a clean
    /// `Err` — a truncated frame can never be misread as a (different)
    /// complete message, and the cursor never reads past the slice.
    #[test]
    fn decode_fuzz_all_truncations_err() {
        for msg in fuzz_corpus() {
            let bytes = encode(&msg);
            assert_eq!(decode(&bytes).unwrap(), msg);
            for cut in 0..bytes.len() {
                // Shutdown is 1 byte; its only strict prefix is empty.
                let r = decode(&bytes[..cut]);
                assert!(
                    r.is_err(),
                    "truncation to {cut}/{} bytes of {} decoded to {:?}",
                    bytes.len(),
                    msg.name(),
                    r
                );
            }
        }
    }

    /// Valid encodings with random trailing garbage and random single-byte
    /// corruption must never panic (corruption may still decode to SOME
    /// message — the frame has no checksum — but it must return cleanly,
    /// and count-bearing corruption must not allocate unboundedly).
    #[test]
    fn decode_fuzz_mutations_never_panic() {
        let mut rng = Pcg32::new(0x5eed_cafe, 1);
        for msg in fuzz_corpus() {
            let clean = encode(&msg);
            for _ in 0..200 {
                let mut bytes = clean.clone();
                match rng.below(3) {
                    0 => {
                        // Flip one byte anywhere (length/count fields
                        // included — f32s/str/member counts now lie).
                        let i = rng.below(bytes.len() as u32) as usize;
                        bytes[i] ^= (rng.next_u32() as u8) | 1;
                    }
                    1 => {
                        // Append garbage: decode reads a prefix and
                        // returns; trailing bytes are simply unread.
                        for _ in 0..rng.below(16) {
                            bytes.push(rng.next_u32() as u8);
                        }
                    }
                    _ => {
                        // Both.
                        let i = rng.below(bytes.len() as u32) as usize;
                        bytes[i] = bytes[i].wrapping_add(1 + rng.below(255) as u8);
                        bytes.push(rng.next_u32() as u8);
                    }
                }
                let _ = decode(&bytes);
            }
        }
    }

    /// `read_msg` rejects hostile length prefixes — zero and anything
    /// above [`MAX_FRAME_BYTES`] — *before* allocating the body buffer,
    /// so a corrupt prefix cannot OOM the process.
    #[test]
    fn read_msg_rejects_hostile_length_prefixes() {
        for len in [0u32, MAX_FRAME_BYTES + 1, u32::MAX] {
            let mut s: Vec<u8> = Vec::new();
            s.extend_from_slice(&len.to_le_bytes());
            s.extend_from_slice(&[0u8; 16]);
            let err = read_msg(&mut &s[..]).unwrap_err();
            assert!(
                err.to_string().contains("bad frame length"),
                "len {len}: {err}"
            );
        }
        // Truncated streams (mid-prefix and mid-body) error cleanly too.
        let full = {
            let mut buf = Vec::new();
            write_msg(
                &mut buf,
                &Msg::Hello {
                    rank: 1,
                    ring_port: 2,
                    hier_port: 3,
                    probe_port: 4,
                    site: 5,
                },
            )
            .unwrap();
            buf
        };
        for cut in 0..full.len() {
            assert!(read_msg(&mut &full[..cut]).is_err());
        }
    }
}
