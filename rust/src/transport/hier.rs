//! Hierarchical two-level ring reduce: fast intra-site rings, one elected
//! leader per site on the slow cross-site ring.
//!
//! A flat ring puts 2·(C−1)/C of the payload on *every* link, including
//! the WAN links between sites.  [`HierRing`] composes two
//! [`RingTransport`]s instead:
//!
//! 1. **intra-site reduce** — every member of a site runs the chunked ring
//!    all-reduce over the site's fast links; afterwards all site members
//!    hold the site sum (bit-identically — the flat collective already
//!    guarantees that).
//! 2. **cross-site reduce** — only the site *leaders* (the first member of
//!    each site in the committed order, i.e. the minimum rank alive) run a
//!    second ring over the S sites; each leader ends with the global sum.
//!    Cross-site payload per leader is 2·(S−1)/S·payload — the WAN now
//!    carries the §2.4.1 factor in S, not C, and non-leaders never touch
//!    it.
//! 3. **intra-site broadcast** — the leader relays the global sum around
//!    the intra ring (C_site−1 store-and-forward hops), so every member
//!    ends bit-identical to its leader.
//!
//! # Invariants
//!
//! * **The float schedule is fixed by (site, rank) order.**  The
//!   coordinator commits members sorted by (site, rank); intra rings form
//!   over that order and leaders join the cross ring in ascending site
//!   order.  Any two backends (local mpsc, loopback TCP) therefore
//!   produce bit-for-bit identical results.
//! * **A single-site fleet is the flat ring.**  When every member shares
//!   one site, `allreduce_sum` delegates verbatim to the intra transport —
//!   same floats, same metered bytes, no broadcast pass — so
//!   `reduce_topology = hier` with one site is indistinguishable from
//!   today's flat ring.
//! * **Leader election is epoch-scoped.**  Leadership is a pure function
//!   of the committed member list (first member of each site), so a dead
//!   leader is replaced at the next membership epoch by re-running the
//!   same rule over the survivors — no extra protocol states.
//! * `size()` reports the *total* member count (so the provided
//!   `allreduce_mean` divides globally) and `rank()` the member's position
//!   in the global (site, rank) order; the chunk math of the overridden
//!   collective never consults them.
//! * `recycle` feeds the intra transport (the hot path); `begin_round`
//!   reaches both transports so fault injection wrapped around either
//!   sub-ring still fires on schedule.

use crate::comm::ring::build_ring;
use crate::transport::frame::MemberInfo;
use crate::transport::{ByteMeter, RingTransport};
use anyhow::{anyhow, Result};

/// Two composed rings: `intra` spans this member's site, `cross` (leaders
/// only) spans the sites.  See the module docs for the algorithm and its
/// invariants.
pub struct HierRing {
    intra: Box<dyn RingTransport>,
    cross: Option<Box<dyn RingTransport>>,
    global_rank: usize,
    total: usize,
    single_site: bool,
}

impl HierRing {
    /// Compose an intra-site transport (positions = site members in
    /// committed order; the leader is position 0) with an optional
    /// cross-site transport (present iff this member leads its site).
    pub fn new(
        intra: Box<dyn RingTransport>,
        cross: Option<Box<dyn RingTransport>>,
        global_rank: usize,
        total: usize,
    ) -> Result<HierRing> {
        if intra.size() > total {
            return Err(anyhow!(
                "hier: intra ring of {} exceeds fleet of {total}",
                intra.size()
            ));
        }
        let single_site = intra.size() == total;
        if single_site && cross.is_some() {
            return Err(anyhow!("hier: single-site fleet has no cross ring"));
        }
        if let Some(c) = &cross {
            if intra.rank() != 0 {
                return Err(anyhow!(
                    "hier: cross ring on a non-leader (intra position {})",
                    intra.rank()
                ));
            }
            if c.size() < 2 {
                return Err(anyhow!("hier: cross ring needs >= 2 sites"));
            }
        }
        Ok(HierRing { intra, cross, global_rank, total, single_site })
    }

    /// Payload bytes this member put on the cross-site (WAN) ring —
    /// non-zero only on leaders.  Separate from [`RingTransport::meter`],
    /// which stays intra-site (the hot, cheap links).
    pub fn wan_bytes(&self) -> u64 {
        self.cross.as_ref().map(|c| c.meter().total()).unwrap_or(0)
    }

    /// Does this member lead its site (run the cross-site ring)?
    pub fn is_leader(&self) -> bool {
        self.cross.is_some() || self.single_site
    }
}

impl RingTransport for HierRing {
    fn rank(&self) -> usize {
        self.global_rank
    }

    fn size(&self) -> usize {
        self.total
    }

    fn send_next(&mut self, chunk: &[f32]) -> Result<()> {
        self.intra.send_next(chunk)
    }

    fn recv_prev(&mut self) -> Result<Vec<f32>> {
        self.intra.recv_prev()
    }

    fn meter(&self) -> &ByteMeter {
        self.intra.meter()
    }

    fn begin_round(&mut self, round: usize) -> Result<()> {
        self.intra.begin_round(round)?;
        if let Some(c) = self.cross.as_mut() {
            c.begin_round(round)?;
        }
        Ok(())
    }

    fn recycle(&mut self, buf: Vec<f32>) {
        self.intra.recycle(buf)
    }

    fn allreduce_sum(&mut self, buf: &mut [f32]) -> Result<()> {
        if self.single_site {
            // Bit-for-bit the flat ring: same transport, same schedule,
            // same metered bytes, no broadcast pass.
            return self.intra.allreduce_sum(buf);
        }
        let _s = crate::obs::span("hier", "allreduce")
            .bytes(4 * buf.len() as u64);
        // 1. Site sum over the fast intra ring.
        self.intra.allreduce_sum(buf)?;
        // 2. Global sum over the leaders-only cross ring (WAN).
        if let Some(c) = self.cross.as_mut() {
            c.allreduce_sum(buf)?;
        }
        // 3. Broadcast the leader's global sum around the intra ring:
        //    store-and-forward, C_site−1 hops, each metered like a ring
        //    hop (the provided collective meters inside itself; this pass
        //    is ours to account for).
        let c = self.intra.size();
        if c > 1 {
            let pos = self.intra.rank();
            if pos == 0 {
                let hop =
                    crate::obs::span("hier", "bcast").bytes(4 * buf.len() as u64);
                self.intra.meter().add(4 * buf.len() as u64);
                self.intra.send_next(buf)?;
                drop(hop);
            } else {
                let incoming = self.intra.recv_prev()?;
                if incoming.len() != buf.len() {
                    return Err(anyhow!(
                        "hier broadcast size mismatch: got {}, want {}",
                        incoming.len(),
                        buf.len()
                    ));
                }
                buf.copy_from_slice(&incoming);
                self.intra.recycle(incoming);
                if pos < c - 1 {
                    let hop = crate::obs::span("hier", "bcast")
                        .bytes(4 * buf.len() as u64);
                    self.intra.meter().add(4 * buf.len() as u64);
                    self.intra.send_next(buf)?;
                    drop(hop);
                }
            }
        }
        Ok(())
    }
}

/// Cross-site (WAN) payload bytes per *leader* for one hierarchical
/// all-reduce of `payload` bytes across `s` sites: 2·(S−1)/S·payload —
/// the §2.4.1 factor in S instead of C.
pub fn hier_cross_bytes_per_leader(payload: u64, s: usize) -> u64 {
    crate::comm::ring::ring_wire_bytes_per_worker(payload, s)
}

// ---------------------------------------------------------------------------
// Local (mpsc) builder — the threaded reference fleet
// ---------------------------------------------------------------------------

/// Build one [`HierRing`] per member over in-memory mpsc channels, from a
/// rank → site map.  Returned in *original rank order* (index = rank);
/// the global hierarchical order is (site, rank) ascending, exactly what
/// the elastic coordinator commits for a TCP fleet — so this is the
/// bit-for-bit local reference for the hierarchical schedule.
pub fn build_hier_rings(sites: &[u32]) -> Vec<HierRing> {
    let n = sites.len();
    let mut order: Vec<usize> = (0..n).collect();
    order.sort_by_key(|&r| (sites[r], r));
    // Contiguous site groups in global order.
    let mut groups: Vec<Vec<usize>> = Vec::new();
    for &r in &order {
        match groups.last_mut() {
            Some(g) if sites[*g.last().unwrap()] == sites[r] => g.push(r),
            _ => groups.push(vec![r]),
        }
    }
    let s = groups.len();
    let mut cross: Vec<Option<Box<dyn RingTransport>>> = if s > 1 {
        build_ring(s)
            .into_iter()
            .map(|m| Some(Box::new(m) as Box<dyn RingTransport>))
            .collect()
    } else {
        vec![None]
    };
    let mut slots: Vec<Option<HierRing>> = (0..n).map(|_| None).collect();
    let mut global_rank = 0usize;
    for (si, group) in groups.iter().enumerate() {
        let intra = build_ring(group.len());
        for (pos, (&r, member)) in group.iter().zip(intra).enumerate() {
            let cross_ring =
                if pos == 0 && s > 1 { cross[si].take() } else { None };
            slots[r] = Some(
                HierRing::new(Box::new(member), cross_ring, global_rank, n)
                    .expect("local hier ring composition is well-formed"),
            );
            global_rank += 1;
        }
    }
    slots.into_iter().map(|o| o.unwrap()).collect()
}

// ---------------------------------------------------------------------------
// Site plan — how a TCP worker slices a committed member list
// ---------------------------------------------------------------------------

/// One worker's slice of a committed (site, rank)-ordered member list:
/// who to form the intra ring with, whether to lead the cross ring, and
/// where this member sits in the global order.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct SitePlan {
    /// (rank, ring_port) of this member's site, in committed order — the
    /// intra ring.
    pub intra: Vec<(u32, u16)>,
    /// (rank, hier_port) of every site leader in committed (site) order —
    /// `Some` iff this member leads its site.
    pub cross: Option<Vec<(u32, u16)>>,
    /// Position in the committed global order.
    pub global_rank: usize,
    /// Total committed members.
    pub total: usize,
    /// Number of sites in the epoch.
    pub site_count: usize,
}

/// Slice a committed member list for `my_rank`.  The list must keep each
/// site contiguous (the coordinator commits (site, rank) order); a site
/// split across two runs means a coordinator bug and is rejected rather
/// than silently forming a mis-shapen ring.
pub fn site_plan(members: &[MemberInfo], my_rank: u32) -> Result<SitePlan> {
    if members.is_empty() {
        return Err(anyhow!("hier: empty member list"));
    }
    // Runs of equal site, preserving committed order.
    let mut runs: Vec<(u32, Vec<&MemberInfo>)> = Vec::new();
    for m in members {
        match runs.last_mut() {
            Some((site, run)) if *site == m.site => run.push(m),
            _ => {
                if runs.iter().any(|(s, _)| *s == m.site) {
                    return Err(anyhow!(
                        "hier: site {} is not contiguous in the committed \
                         member order",
                        m.site
                    ));
                }
                runs.push((m.site, vec![m]));
            }
        }
    }
    let global_rank = members
        .iter()
        .position(|m| m.rank == my_rank)
        .ok_or_else(|| anyhow!("hier: rank {my_rank} not in member list"))?;
    let my_site = members[global_rank].site;
    let (_, my_run) = runs
        .iter()
        .find(|(s, _)| *s == my_site)
        .expect("own site present");
    let intra: Vec<(u32, u16)> =
        my_run.iter().map(|m| (m.rank, m.ring_port)).collect();
    let leader = my_run[0].rank == my_rank;
    let cross = if leader && runs.len() > 1 {
        Some(runs.iter().map(|(_, run)| (run[0].rank, run[0].hier_port)).collect())
    } else {
        None
    };
    Ok(SitePlan {
        intra,
        cross,
        global_rank,
        total: members.len(),
        site_count: runs.len(),
    })
}

/// Sort members into the committed hierarchical order: (site, rank)
/// ascending — the order every backend derives the float schedule from.
pub fn site_sorted(mut members: Vec<MemberInfo>) -> Vec<MemberInfo> {
    members.sort_by_key(|m| (m.site, m.rank));
    members
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg32;
    use std::sync::Arc;

    fn inputs(n: usize, dim: usize) -> Vec<Vec<f32>> {
        let mut rng = Pcg32::seed_from(42);
        (0..n)
            .map(|_| {
                let mut v = vec![0.0f32; dim];
                rng.fill_normal(&mut v, 0.0, 1.0);
                v
            })
            .collect()
    }

    fn run_hier(sites: &[u32], dim: usize) -> (Vec<Vec<f32>>, u64) {
        let rings = build_hier_rings(sites);
        let wan = Arc::new(std::sync::atomic::AtomicU64::new(0));
        let bufs = inputs(sites.len(), dim);
        let results: Vec<Vec<f32>> = std::thread::scope(|scope| {
            let handles: Vec<_> = rings
                .into_iter()
                .zip(bufs)
                .map(|(mut ring, mut buf)| {
                    let wan = Arc::clone(&wan);
                    scope.spawn(move || {
                        ring.allreduce_sum(&mut buf).unwrap();
                        // Leaders share one cross meter in the local
                        // builder; taking the max yields the fleet total.
                        wan.fetch_max(
                            ring.wan_bytes(),
                            std::sync::atomic::Ordering::Relaxed,
                        );
                        buf
                    })
                })
                .collect();
            handles.into_iter().map(|h| h.join().unwrap()).collect()
        });
        (results, wan.load(std::sync::atomic::Ordering::Relaxed))
    }

    #[test]
    fn hier_sum_matches_flat_sum_and_is_bit_identical_across_members() {
        let sites = [0u32, 0, 1, 1, 1];
        let dim = 257;
        let (results, _) = run_hier(&sites, dim);
        let expect: Vec<f64> = (0..dim)
            .map(|i| inputs(5, dim).iter().map(|v| v[i] as f64).sum())
            .collect();
        for r in &results {
            assert_eq!(r, &results[0], "all members end bit-identical");
            for (a, b) in r.iter().zip(&expect) {
                assert!(
                    ((*a as f64) - b).abs() < 1e-3 * (1.0 + b.abs()),
                    "{a} vs {b}"
                );
            }
        }
    }

    #[test]
    fn wan_bytes_follow_the_two_level_formula() {
        // 2 sites × 2 members, payload = dim f32s. Cross ring: 2 leaders,
        // total WAN bytes = 2·(S−1)·payload = payload·2 for S=2 (summed
        // over both leaders; per leader it's the 2·(S−1)/S factor).
        let dim = 64;
        let (_, wan) = run_hier(&[0, 0, 1, 1], dim);
        let payload = 4 * dim as u64;
        assert_eq!(wan, 2 * payload);
        assert_eq!(hier_cross_bytes_per_leader(payload, 2), payload);
        // The §2.4.1 shape: S=3 leaders each send 2·2/3 of the payload.
        assert_eq!(hier_cross_bytes_per_leader(300, 3), 400);
    }

    #[test]
    fn single_site_is_bit_for_bit_the_flat_ring() {
        let dim = 129;
        let n = 4;
        let bufs = inputs(n, dim);
        // Flat reference.
        let flat: Vec<Vec<f32>> = std::thread::scope(|scope| {
            let handles: Vec<_> = build_ring(n)
                .into_iter()
                .zip(bufs.clone())
                .map(|(mut m, mut b)| {
                    scope.spawn(move || {
                        m.allreduce_sum(&mut b).unwrap();
                        b
                    })
                })
                .collect();
            handles.into_iter().map(|h| h.join().unwrap()).collect()
        });
        // Hierarchical with one site.
        let (hier, wan) = run_hier(&[7, 7, 7, 7], dim);
        assert_eq!(wan, 0, "no WAN traffic with a single site");
        for (a, b) in hier.iter().zip(&flat) {
            assert_eq!(a, b, "single-site hier must equal the flat ring bits");
        }
    }

    #[test]
    fn provided_mean_divides_by_the_global_size() {
        let rings = build_hier_rings(&[0, 0, 1, 1]);
        let bufs =
            vec![vec![2.0f32; 8], vec![4.0f32; 8], vec![6.0f32; 8], vec![8.0f32; 8]];
        let results: Vec<Vec<f32>> = std::thread::scope(|scope| {
            rings
                .into_iter()
                .zip(bufs)
                .map(|(mut ring, mut b)| {
                    scope.spawn(move || {
                        ring.allreduce_mean(&mut b).unwrap();
                        b
                    })
                })
                .collect::<Vec<_>>()
                .into_iter()
                .map(|h| h.join().unwrap())
                .collect()
        });
        for r in results {
            assert!(r.iter().all(|&v| (v - 5.0).abs() < 1e-6), "{r:?}");
        }
    }

    #[test]
    fn one_member_per_site_degenerates_to_a_leaders_only_ring() {
        let (results, wan) = run_hier(&[0, 1, 2], 33);
        let expect: Vec<f64> = (0..33)
            .map(|i| inputs(3, 33).iter().map(|v| v[i] as f64).sum())
            .collect();
        for r in &results {
            assert_eq!(r, &results[0]);
            for (a, b) in r.iter().zip(&expect) {
                assert!(((*a as f64) - b).abs() < 1e-3 * (1.0 + b.abs()));
            }
        }
        // All traffic is WAN: 2·(S−1)·payload across the 3 leaders.
        assert_eq!(wan, 2 * 2 * (4 * 33) as u64);
    }

    #[test]
    fn site_plan_slices_the_committed_order() {
        let members = vec![
            MemberInfo { rank: 1, ring_port: 11, hier_port: 21, site: 0 },
            MemberInfo { rank: 3, ring_port: 13, hier_port: 23, site: 0 },
            MemberInfo { rank: 0, ring_port: 10, hier_port: 20, site: 2 },
            MemberInfo { rank: 2, ring_port: 12, hier_port: 22, site: 2 },
        ];
        // Leader of site 0.
        let p = site_plan(&members, 1).unwrap();
        assert_eq!(p.intra, vec![(1, 11), (3, 13)]);
        assert_eq!(p.cross, Some(vec![(1, 21), (0, 20)]));
        assert_eq!((p.global_rank, p.total, p.site_count), (0, 4, 2));
        // Non-leader of site 2.
        let p = site_plan(&members, 2).unwrap();
        assert_eq!(p.intra, vec![(0, 10), (2, 12)]);
        assert_eq!(p.cross, None);
        assert_eq!(p.global_rank, 3);
        // Unknown rank and split sites are rejected.
        assert!(site_plan(&members, 9).is_err());
        let mut split = members.clone();
        split.swap(1, 2);
        assert!(site_plan(&split, 1).is_err());
    }

    #[test]
    fn site_sorted_orders_by_site_then_rank() {
        let members = vec![
            MemberInfo { rank: 2, ring_port: 0, hier_port: 0, site: 1 },
            MemberInfo { rank: 0, ring_port: 0, hier_port: 0, site: 1 },
            MemberInfo { rank: 1, ring_port: 0, hier_port: 0, site: 0 },
        ];
        let s = site_sorted(members);
        let key: Vec<(u32, u32)> = s.iter().map(|m| (m.site, m.rank)).collect();
        assert_eq!(key, vec![(0, 1), (1, 0), (1, 2)]);
    }
}
