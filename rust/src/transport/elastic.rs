//! Elastic multi-process coordinator: spawns one `dilocox worker` OS
//! process per cluster, runs DiLoCo-style outer rounds over the TCP ring,
//! and survives worker death mid-round by re-forming the ring with the
//! survivors (the membership epoch protocol documented in
//! [`crate::transport`]).
//!
//! Recovery model: any ring failure (peer death, stall past the socket
//! timeout) makes every survivor report `RingBroken{applied_rounds}` and
//! park on its control socket; the coordinator bumps the epoch, runs the
//! 2PC prepare/commit over the survivors, and the new ring opens with a
//! consensus `allreduce_mean` over θ_g plus an outer-momentum restart, so
//! survivors re-agree on the global parameters before training resumes at
//! `max(applied)+1`.  The pseudo-gradient mean rescales automatically: the
//! collective mean is over the *current* member count.
//!
//! Workloads: the real-numerics PJRT trainer (needs an artifact bundle),
//! or a synthetic per-worker quadratic that exercises the full outer loop
//! (H local steps, pseudo-gradient ring mean, Nesterov outer step) with no
//! artifacts — what the churn integration tests and the zero-dependency
//! demo path run.
//!
//! # Stage-parallel fleet (`pp_stages > 1`)
//!
//! With pipeline parallelism the fleet is one OS process per **(cluster,
//! stage)**: `dp × pp` `dilocox worker --stage s` processes.  Inside a
//! cluster the 1F1B dataflow runs over TCP stage links
//! ([`crate::transport::tcp::TcpStageLink`]: Acts frames down, Grads
//! frames up); across clusters each stage joins its *own* per-stage DP
//! ring, so per-stage pseudo-gradients reduce independently — the §2.2
//! composition of PP with low-communication outer rounds, deployed.
//!
//! Membership is keyed by `(cluster, stage)` but committed at cluster
//! granularity: a cluster is a member only while **all** of its stage
//! processes are alive (a dead stage starves its siblings' dataflow, so
//! the whole cluster is dropped and its orphans are shut down).  The 2PC
//! prepare/commit sends each stage process a *tailored*
//! `StagePrepare` — its own stage ring in committed order plus its
//! downstream neighbor's link port — and every surviving stage ring
//! re-forms on the bumped epoch while the 1F1B dataflow stalls (blocked
//! on its timeouts) and resumes after the commit.  `resume_round` is
//! shared across stages; a stage ring that already completed the final
//! round before a late break simply finishes (bounded staleness, exactly
//! like the single-vector fleet's final-round churn).
//!
//! Invariant worth knowing when reading the recovery code: within one
//! *surviving* cluster every stage always completes the full H local
//! steps of a round before any stage touches its ring (the dataflow is
//! intra-cluster and intact), so the per-stage data streams stay in
//! lockstep across churn — a re-run round re-draws the same number of
//! batches on the first and last stage alike.

use crate::compress::Method;
use crate::config::{ExperimentConfig, FaultConfig, TransportConfig};
use crate::coordinator::RuntimeStagePipeline;
use crate::data::{MarkovCorpus, ShardIter};
use crate::optim::{AdamW, DualOptimizer, Nesterov};
use crate::pipeline::exec::{
    run_stream_step, MpscStageLink, PipelineWorkload, StageCompute, StageLink,
    SyntheticPipeline,
};
use crate::pipeline::{one_f_one_b_schedule, validate_schedule};
use crate::rounds::{movement, DeltaReducer, RingLane, RoundEngine};
use crate::runtime::{Manifest, Runtime};
use crate::transport::faulty::{FaultPlan, FaultyRing};
use crate::transport::frame::{read_msg, write_msg, Msg};
use crate::transport::tcp;
use crate::transport::RingTransport;
use crate::util::rng::Pcg32;
use anyhow::{anyhow, Context, Result};
use std::collections::{BTreeMap, BTreeSet};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::process::{Command, Stdio};
use std::sync::mpsc;
use std::time::{Duration, Instant};

/// What each worker trains between syncs.
#[derive(Clone, Debug)]
pub enum Workload {
    /// Synthetic: worker w owns f_w(θ) = ½·mean((θ − c_w)²) with
    /// c_w = c_shared + 0.1·noise_w; the ring mean drives θ_g to the
    /// member-average target, so convergence is observable without any
    /// artifact bundle.
    Quadratic { dim: usize },
    /// Real numerics through the PJRT runtime (artifact bundle on disk).
    Runtime { artifacts_dir: String },
}

/// Everything a worker process/thread needs (mirrors the CLI flags of
/// `dilocox worker`).
#[derive(Clone, Debug)]
pub struct WorkerOpts {
    /// Coordinator control address, e.g. "127.0.0.1:41234".
    pub coord: String,
    pub rank: u32,
    pub rounds: usize,
    pub local_steps: usize,
    pub inner_lr: f32,
    pub weight_decay: f32,
    pub outer_lr: f32,
    pub outer_momentum: f32,
    pub seed: u64,
    pub workload: Workload,
    pub ring_timeout_ms: u64,
    pub connect_timeout_ms: u64,
    pub faults: Option<FaultPlan>,
}

/// Elastic run parameters (derived from [`ExperimentConfig`] or built
/// directly by tests).
#[derive(Clone, Debug)]
pub struct ElasticConfig {
    pub workers: usize,
    pub rounds: usize,
    pub local_steps: usize,
    pub inner_lr: f32,
    pub weight_decay: f32,
    pub outer_lr: f32,
    pub outer_momentum: f32,
    pub seed: u64,
    pub workload: Workload,
    /// M — pipeline stages per cluster.  1 = the single-vector worker
    /// fleet; > 1 spawns one OS process per (cluster, stage) and routes
    /// the run through the stage-parallel supervisor.
    pub pp_stages: usize,
    /// U — in-flight microbatches per inner step (stage fleet only).
    pub microbatches: usize,
    pub transport: TransportConfig,
    pub faults: FaultConfig,
    /// Hard wall-clock ceiling for the whole run (hang safety net).
    pub wall_timeout_ms: u64,
}

impl ElasticConfig {
    /// Synthetic-quadratic defaults tuned for fast, stable convergence.
    pub fn quadratic(workers: usize, rounds: usize, dim: usize) -> ElasticConfig {
        ElasticConfig {
            workers,
            rounds,
            local_steps: 8,
            inner_lr: 0.25,
            weight_decay: 0.0,
            outer_lr: 0.5,
            outer_momentum: 0.6,
            seed: 1234,
            workload: Workload::Quadratic { dim },
            pp_stages: 1,
            microbatches: 1,
            transport: TransportConfig::default(),
            faults: FaultConfig::default(),
            wall_timeout_ms: 120_000,
        }
    }

    /// Stage-fleet defaults over the artifact-free [`SyntheticPipeline`]
    /// (the depth-`stages` affine chain), tuned like the local executor
    /// tests.
    pub fn synthetic_pipeline(
        clusters: usize,
        stages: usize,
        rounds: usize,
        dim: usize,
    ) -> ElasticConfig {
        let mut c = ElasticConfig::quadratic(clusters, rounds, dim);
        c.pp_stages = stages;
        c.microbatches = 2;
        c.inner_lr = 0.05;
        c.outer_lr = 0.7;
        c.outer_momentum = 0.6;
        c
    }

    /// Lift an experiment config onto the elastic runner.  Runtime
    /// workloads pay per-process artifact load + H real training steps per
    /// round, so the hang safety net scales with the schedule instead of
    /// using the quick-test default.
    pub fn from_experiment(cfg: &ExperimentConfig, workload: Workload) -> ElasticConfig {
        let wall_timeout_ms = match &workload {
            Workload::Quadratic { .. } => 120_000,
            // Generous: artifact load/compile + T rounds of H steps.
            Workload::Runtime { .. } => {
                600_000 + 60_000 * cfg.train.outer_steps as u64
            }
        };
        ElasticConfig {
            workers: cfg.parallel.dp,
            rounds: cfg.train.outer_steps,
            local_steps: cfg.train.local_steps,
            inner_lr: cfg.train.inner_lr,
            weight_decay: cfg.train.weight_decay,
            outer_lr: cfg.train.outer_lr,
            outer_momentum: cfg.train.outer_momentum,
            seed: cfg.train.seed,
            workload,
            pp_stages: cfg.parallel.pp,
            microbatches: cfg.parallel.microbatches,
            transport: cfg.transport.clone(),
            faults: cfg.faults.clone(),
            wall_timeout_ms,
        }
    }
}

/// How the coordinator launches workers.
#[derive(Clone, Debug)]
pub enum SpawnMode {
    /// `std::process::Command` on the given `dilocox` binary — the real
    /// deployment shape: a crashed worker is an EOF, not a crashed run.
    Process { exe: String },
    /// In-process threads (unit tests; injected kills become error
    /// returns instead of `process::exit`).
    Thread,
}

#[derive(Debug)]
pub struct ElasticOutcome {
    pub rounds: usize,
    /// Final committed membership epoch (1 = no churn happened).
    pub epochs: u32,
    pub started: usize,
    pub survivors: Vec<u32>,
    /// Mean of the survivors' final eval losses.
    pub final_loss: f32,
    /// First survivor's parameter digest (full vector up to
    /// [`PARAMS_DIGEST_MAX`] elements, strided sample beyond — see
    /// [`params_digest`]).
    pub final_params: Vec<f32>,
    pub total_wire_bytes: u64,
    /// Heartbeat telemetry: (worker, round, loss).
    pub round_losses: Vec<(u32, u32, f32)>,
}

impl ElasticOutcome {
    /// Heartbeats aggregated per round: (round, mean loss, reporting
    /// workers).  Rounds with no heartbeat (e.g. lost to churn) are
    /// omitted.
    pub fn mean_loss_per_round(&self) -> Vec<(u32, f32, usize)> {
        let mut out = Vec::new();
        for r in 1..=self.rounds as u32 {
            let ls: Vec<f32> = self
                .round_losses
                .iter()
                .filter(|(_, round, _)| *round == r)
                .map(|(_, _, l)| *l)
                .collect();
            if !ls.is_empty() {
                out.push((r, ls.iter().sum::<f32>() / ls.len() as f32, ls.len()));
            }
        }
        out
    }
}

/// Cap on the parameter digest a worker ships in its `Done` report.  The
/// digest exists for the coordinator's cross-worker agreement check and
/// telemetry, not for checkpointing — shipping a 100M-param vector over
/// the control socket would be wasteful and anything over ~268M f32s
/// would blow the 1 GiB frame guard.  Every worker samples the same
/// strided indices, so elementwise comparison stays valid.
pub const PARAMS_DIGEST_MAX: usize = 65_536;

/// Full vector when small, deterministic strided sample when large.
pub fn params_digest(params: &[f32]) -> Vec<f32> {
    if params.len() <= PARAMS_DIGEST_MAX {
        return params.to_vec();
    }
    let stride = params.len().div_ceil(PARAMS_DIGEST_MAX);
    params.iter().step_by(stride).copied().collect()
}

/// Per-(cluster, stage) fault plan for the stage-parallel fleet: the
/// seeded kill targets exactly one stage *process*
/// (`kill_rank`/`kill_stage` at `kill_round`); delays and stragglers
/// follow the cluster rank like the single-vector fleet.
pub fn stage_fault_plan_for(
    faults: &FaultConfig,
    rank: u32,
    stage: u32,
    exit_on_kill: bool,
) -> Option<FaultPlan> {
    if !faults.enabled {
        return None;
    }
    let kill_here = rank as usize == faults.kill_rank
        && stage as usize == faults.kill_stage;
    let plan = FaultPlan {
        seed: faults.seed,
        delay_prob: faults.delay_prob,
        max_delay_ms: faults.delay_ms,
        kill_round: if kill_here { faults.kill_round } else { 0 },
        straggler_ms: if rank as usize == faults.straggler_rank {
            faults.straggler_ms
        } else {
            0
        },
        exit_on_kill,
    };
    if plan.is_quiet() {
        None
    } else {
        Some(plan)
    }
}

/// Per-rank fault plan from the `[faults]` config section.
pub fn fault_plan_for(
    faults: &FaultConfig,
    rank: u32,
    exit_on_kill: bool,
) -> Option<FaultPlan> {
    if !faults.enabled {
        return None;
    }
    let plan = FaultPlan {
        seed: faults.seed,
        delay_prob: faults.delay_prob,
        max_delay_ms: faults.delay_ms,
        kill_round: if rank as usize == faults.kill_rank { faults.kill_round } else { 0 },
        straggler_ms: if rank as usize == faults.straggler_rank {
            faults.straggler_ms
        } else {
            0
        },
        exit_on_kill,
    };
    if plan.is_quiet() {
        None
    } else {
        Some(plan)
    }
}

// ---------------------------------------------------------------------------
// Worker side
// ---------------------------------------------------------------------------

/// What a worker trains between syncs (kept object-safe so the quadratic
/// and PJRT paths share one outer loop).
trait LocalTrainer {
    fn dim(&self) -> usize;
    fn params(&self) -> &[f32];
    fn set_params(&mut self, p: &[f32]);
    /// Run `h` inner steps from the current params; returns the mean loss.
    fn local_round(&mut self, h: usize) -> Result<f32>;
    fn eval(&mut self) -> Result<f32>;
}

struct QuadraticTrainer {
    params: Vec<f32>,
    target: Vec<f32>,
    lr: f32,
}

impl QuadraticTrainer {
    fn new(dim: usize, rank: u32, seed: u64, lr: f32) -> QuadraticTrainer {
        // Shared optimum + small per-worker displacement: the member-mean
        // target is near the shared component, so the global loss falls
        // from ~0.5 to ~the displacement variance as θ_g converges.
        let mut shared = vec![0.0f32; dim];
        Pcg32::new(seed ^ 0x7a67, 0).fill_normal(&mut shared, 0.0, 1.0);
        let mut noise = vec![0.0f32; dim];
        Pcg32::new(seed ^ 0x7a67, 1 + rank as u64).fill_normal(&mut noise, 0.0, 1.0);
        let target: Vec<f32> =
            shared.iter().zip(&noise).map(|(s, n)| s + 0.1 * n).collect();
        QuadraticTrainer { params: vec![0.0; dim], target, lr }
    }

    fn loss(&self) -> f32 {
        let n = self.params.len() as f32;
        0.5 * self
            .params
            .iter()
            .zip(&self.target)
            .map(|(p, t)| (p - t) * (p - t))
            .sum::<f32>()
            / n
    }
}

impl LocalTrainer for QuadraticTrainer {
    fn dim(&self) -> usize {
        self.params.len()
    }

    fn params(&self) -> &[f32] {
        &self.params
    }

    fn set_params(&mut self, p: &[f32]) {
        self.params.copy_from_slice(p);
    }

    fn local_round(&mut self, h: usize) -> Result<f32> {
        // Report the loss at entry (current θ_g) so the round curve is
        // directly comparable to the final eval.
        let loss = self.loss();
        for _ in 0..h {
            for (p, t) in self.params.iter_mut().zip(&self.target) {
                let g = *p - *t;
                *p -= self.lr * g;
            }
        }
        Ok(loss)
    }

    fn eval(&mut self) -> Result<f32> {
        Ok(self.loss())
    }
}

struct RuntimeTrainer {
    rt: Runtime,
    params: Vec<f32>,
    inner: AdamW,
    shard: ShardIter,
    corpus: std::sync::Arc<MarkovCorpus>,
    seed: u64,
    microbatch: usize,
    seq_len: usize,
}

impl RuntimeTrainer {
    fn new(dir: &str, rank: u32, opts: &WorkerOpts) -> Result<RuntimeTrainer> {
        let rt = Runtime::load(dir)
            .with_context(|| format!("loading artifacts from {dir}"))?;
        rt.precompile(&["step_single", "eval_single"])?;
        let man = &rt.manifest;
        let (b, s) = (man.dims.microbatch, man.dims.seq_len);
        let corpus =
            std::sync::Arc::new(MarkovCorpus::new(man.dims.vocab_size, opts.seed));
        let shard =
            ShardIter::new(std::sync::Arc::clone(&corpus), rank as usize, opts.seed, b, s);
        let params = man.read_f32(&man.init["single"].file)?;
        let n = man.param_count;
        Ok(RuntimeTrainer {
            inner: AdamW::new(n, opts.inner_lr, opts.weight_decay),
            params,
            shard,
            corpus,
            seed: opts.seed,
            microbatch: b,
            seq_len: s,
            rt,
        })
    }
}

impl LocalTrainer for RuntimeTrainer {
    fn dim(&self) -> usize {
        self.params.len()
    }

    fn params(&self) -> &[f32] {
        &self.params
    }

    fn set_params(&mut self, p: &[f32]) {
        self.params.copy_from_slice(p);
    }

    fn local_round(&mut self, h: usize) -> Result<f32> {
        let mut acc = 0.0f64;
        for _ in 0..h {
            let (tok, lab) = self.shard.next_batch();
            let (loss, grads) = self.rt.step_single(&self.params, &tok, &lab)?;
            self.inner.step(&mut self.params, &grads);
            acc += loss as f64;
        }
        Ok((acc / h.max(1) as f64) as f32)
    }

    fn eval(&mut self) -> Result<f32> {
        let mut it = ShardIter::new(
            std::sync::Arc::clone(&self.corpus),
            9999,
            self.seed ^ 0xe7a1,
            self.microbatch,
            self.seq_len,
        );
        let mut acc = 0.0f32;
        let batches = 3;
        for _ in 0..batches {
            let (t, l) = it.next_batch();
            acc += self.rt.eval_single(&self.params, &t, &l)?;
        }
        Ok(acc / batches as f32)
    }
}

fn build_trainer(opts: &WorkerOpts) -> Result<Box<dyn LocalTrainer>> {
    Ok(match &opts.workload {
        Workload::Quadratic { dim } => Box::new(QuadraticTrainer::new(
            *dim,
            opts.rank,
            opts.seed,
            opts.inner_lr,
        )),
        Workload::Runtime { artifacts_dir } => {
            Box::new(RuntimeTrainer::new(artifacts_dir, opts.rank, opts)?)
        }
    })
}

/// Single-lane [`DeltaReducer`] over an already-formed ring: raw fp32
/// pseudo-gradient mean, metering actual ring bytes (the elastic wire
/// ships uncompressed; compression lives in the coordinator paths).
struct RingMeanReducer<'a> {
    ring: &'a mut dyn RingTransport,
    wire: u64,
}

impl DeltaReducer for RingMeanReducer<'_> {
    fn begin(&mut self, _deltas: &[Vec<f32>], _round: u64) -> Result<()> {
        Ok(())
    }

    fn complete(&mut self, deltas: &[Vec<f32>], _round: u64) -> Result<Vec<f32>> {
        let mut d = deltas[0].clone();
        let before = self.ring.meter().total();
        self.ring.allreduce_mean(&mut d)?;
        self.wire += self.ring.meter().total() - before;
        Ok(d)
    }
}

/// Block on the control socket until the coordinator commits a membership
/// epoch newer than `after_epoch`; acks every Prepare seen on the way.
fn wait_for_commit(
    coord: &mut TcpStream,
    after_epoch: u32,
) -> Result<(u32, u32, Vec<(u32, u16)>)> {
    coord
        .set_read_timeout(Some(Duration::from_secs(120)))
        .ok();
    let mut prepared: Option<(u32, u32, Vec<(u32, u16)>)> = None;
    loop {
        match read_msg(coord) {
            Ok(Msg::Prepare { epoch, resume_round, members }) if epoch > after_epoch => {
                write_msg(coord, &Msg::PrepareAck { epoch })?;
                prepared = Some((epoch, resume_round, members));
            }
            Ok(Msg::Commit { epoch }) => {
                if let Some(p) = prepared.clone() {
                    if p.0 == epoch {
                        return Ok(p);
                    }
                }
                // A commit for an epoch we never prepared (superseded) —
                // keep waiting for the current one.
            }
            Ok(Msg::Shutdown) => {
                return Err(anyhow!("coordinator shut down before commit"))
            }
            Ok(_) => { /* stale frame — ignore */ }
            Err(e) => {
                return Err(anyhow!("control channel lost waiting for commit: {e:#}"))
            }
        }
    }
}

/// Worker entry point (the `dilocox worker` subcommand body).
pub fn run_worker(opts: &WorkerOpts) -> Result<()> {
    let addr: SocketAddr = opts
        .coord
        .parse()
        .map_err(|_| anyhow!("bad coordinator address '{}'", opts.coord))?;
    let connect_timeout = Duration::from_millis(opts.connect_timeout_ms);
    let ring_timeout = Duration::from_millis(opts.ring_timeout_ms);
    let mut coord = TcpStream::connect_timeout(&addr, connect_timeout)
        .with_context(|| format!("dialing coordinator {addr}"))?;
    coord.set_nodelay(true).ok();
    let listener = TcpListener::bind("127.0.0.1:0").context("binding ring listener")?;
    let ring_port = listener.local_addr()?.port();
    write_msg(&mut coord, &Msg::Hello { rank: opts.rank, ring_port })?;

    let mut trainer = build_trainer(opts)?;
    let dim = trainer.dim();
    // Outer rounds run through the shared engine (sync mode): θ_g moves
    // only by outer updates, and a failed collective leaves it untouched
    // so the next epoch resumes from the last committed state.
    let mut engine = RoundEngine::new(
        trainer.params().to_vec(),
        1,
        Nesterov::new(dim, opts.outer_lr, opts.outer_momentum),
        false,
        false,
    );
    let mut applied: usize = 0;
    let mut wire_total = 0u64;
    let mut epoch = 0u32;

    'epochs: loop {
        let (e, resume_round, members) = wait_for_commit(&mut coord, epoch)?;
        epoch = e;
        let formed = tcp::form_ring(
            opts.rank,
            epoch,
            &members,
            &listener,
            connect_timeout,
            ring_timeout,
        );
        let raw = match formed {
            Ok(r) => r,
            Err(_) => {
                let _ = write_msg(
                    &mut coord,
                    &Msg::RingBroken { epoch, applied_rounds: applied as u32 },
                );
                continue 'epochs;
            }
        };
        let mut ring: Box<dyn RingTransport> = match &opts.faults {
            Some(plan) => Box::new(FaultyRing::new(raw, plan.clone())),
            None => Box::new(raw),
        };

        // Consensus resync: survivors re-agree on θ_g (identical at epoch
        // 1; a true mean after churn) and the outer momentum restarts.
        let mut theta = engine.theta().to_vec();
        if ring.allreduce_mean(&mut theta).is_err() {
            let _ = write_msg(
                &mut coord,
                &Msg::RingBroken { epoch, applied_rounds: applied as u32 },
            );
            continue 'epochs;
        }
        engine.set_theta(&theta);
        engine.reset_outer();
        trainer.set_params(engine.theta());

        let mut round = resume_round as usize;
        while round <= opts.rounds {
            // Fault hook: an injected kill exits here (process mode) or
            // errors out (thread mode) — either way the control socket
            // drops and the coordinator sees a dead member.
            ring.begin_round(round)?;
            let loss = trainer.local_round(opts.local_steps)?;
            let mv = movement(engine.theta(), trainer.params());
            let mut red = RingMeanReducer { ring: ring.as_mut(), wire: 0 };
            if engine.finish_round(vec![mv], round as u64, &mut red).is_err() {
                let _ = write_msg(
                    &mut coord,
                    &Msg::RingBroken { epoch, applied_rounds: applied as u32 },
                );
                continue 'epochs;
            }
            wire_total += red.wire;
            trainer.set_params(engine.theta());
            applied = round;
            let _ = write_msg(&mut coord, &Msg::Heartbeat { round: round as u32, loss });
            round += 1;
        }
        break;
    }

    let final_loss = trainer.eval()?;
    write_msg(
        &mut coord,
        &Msg::Done {
            rounds: applied as u32,
            wire_bytes: wire_total,
            final_loss,
            params: params_digest(engine.theta()),
        },
    )?;
    // Park until Shutdown (or coordinator EOF).
    coord.set_read_timeout(Some(Duration::from_secs(120))).ok();
    let _ = read_msg(&mut coord);
    Ok(())
}

// ---------------------------------------------------------------------------
// Stage worker side (pp_stages > 1: one OS process per (cluster, stage))
// ---------------------------------------------------------------------------

/// Everything one stage process needs (mirrors `dilocox worker --stage`).
#[derive(Clone, Debug)]
pub struct StageWorkerOpts {
    /// Cluster-level options: `rank` is the cluster id; `workload`
    /// selects the pipeline ([`Workload::Quadratic`] =
    /// [`SyntheticPipeline`], [`Workload::Runtime`] = the staged PJRT
    /// bundle).
    pub base: WorkerOpts,
    pub stage: u32,
    pub stages: u32,
    /// U — in-flight microbatches per inner step on the 1F1B schedule.
    pub micros: usize,
    /// Deterministic listener layout base (0 = ephemeral OS ports); see
    /// [`crate::transport::tcp::stage_ports`].
    pub listen_base: u16,
}

/// Build the [`PipelineWorkload`] a stage fleet trains (shared by the
/// stage workers and the coordinator's final assembled eval).
fn build_stage_pipeline(
    workload: &Workload,
    stages: usize,
    micros: usize,
    seed: u64,
) -> Result<Box<dyn PipelineWorkload>> {
    match workload {
        Workload::Quadratic { dim } => Ok(Box::new(SyntheticPipeline::new(
            stages,
            micros.max(1),
            *dim,
            seed,
        ))),
        Workload::Runtime { artifacts_dir } => {
            let man = Manifest::load(artifacts_dir)
                .with_context(|| format!("loading manifest from {artifacts_dir}"))?;
            Ok(Box::new(RuntimeStagePipeline::new(
                artifacts_dir,
                &man,
                micros.max(1),
                seed,
            )?))
        }
    }
}

/// Block on the control socket until the coordinator commits a membership
/// epoch newer than `after_epoch`; acks every StagePrepare seen on the
/// way.  `Ok(None)` = clean Shutdown (our cluster was dropped).
#[allow(clippy::type_complexity)]
fn wait_for_stage_commit(
    coord: &mut TcpStream,
    after_epoch: u32,
) -> Result<Option<(u32, u32, Vec<(u32, u16)>, u16)>> {
    coord
        .set_read_timeout(Some(Duration::from_secs(120)))
        .ok();
    let mut prepared: Option<(u32, u32, Vec<(u32, u16)>, u16)> = None;
    loop {
        match read_msg(coord) {
            Ok(Msg::StagePrepare {
                epoch,
                resume_round,
                ring_members,
                link_down_port,
            }) if epoch > after_epoch => {
                write_msg(coord, &Msg::PrepareAck { epoch })?;
                prepared = Some((epoch, resume_round, ring_members, link_down_port));
            }
            Ok(Msg::Commit { epoch }) => {
                if let Some(p) = prepared.clone() {
                    if p.0 == epoch {
                        return Ok(Some(p));
                    }
                }
                // Commit for an epoch we never prepared (superseded).
            }
            Ok(Msg::Shutdown) => return Ok(None),
            Ok(_) => { /* stale frame — ignore */ }
            Err(e) => {
                return Err(anyhow!(
                    "control channel lost waiting for stage commit: {e:#}"
                ))
            }
        }
    }
}

/// Stage worker entry point (the `dilocox worker --stage` subcommand
/// body): one pipeline stage of one DP cluster as its own OS process.
///
/// Per committed epoch it (re)forms its per-stage DP ring across
/// clusters, its intra-cluster stage-link chain
/// ([`crate::transport::tcp::TcpStageLink`]), resyncs this stage's θ_s
/// by a consensus ring mean, and runs outer rounds through the shared
/// [`RoundEngine`] with the identical inner-step driver
/// ([`run_stream_step`]) as the local threaded executor — the two
/// deployments are bit-for-bit comparable.  Any wire failure mid-round
/// (a dead neighbor's socket timing out, a broken ring collective)
/// reports `RingBroken` and parks for the next epoch.
pub fn run_stage_worker(opts: &StageWorkerOpts) -> Result<()> {
    let w = &opts.base;
    let stages = opts.stages as usize;
    if stages < 2 {
        return Err(anyhow!(
            "stage worker needs --stages >= 2 (the single-stage fleet runs \
             the plain worker)"
        ));
    }
    if opts.stage as usize >= stages {
        return Err(anyhow!(
            "stage {} out of range for {stages} stages",
            opts.stage
        ));
    }
    let addr: SocketAddr = w
        .coord
        .parse()
        .map_err(|_| anyhow!("bad coordinator address '{}'", w.coord))?;
    let connect_timeout = Duration::from_millis(w.connect_timeout_ms);
    let ring_timeout = Duration::from_millis(w.ring_timeout_ms);
    let mut coord = TcpStream::connect_timeout(&addr, connect_timeout)
        .with_context(|| format!("dialing coordinator {addr}"))?;
    coord.set_nodelay(true).ok();
    let (ring_listener, link_listener) = if opts.listen_base > 0 {
        // Validate the full deterministic layout before binding: a base
        // close to 65535 would otherwise wrap in the u16 port arithmetic
        // and bind some unrelated (possibly privileged) port.
        let top = opts.listen_base as u64
            + 2 * (w.rank as u64 * stages as u64 + opts.stage as u64)
            + 1;
        if top > 65535 {
            return Err(anyhow!(
                "--listen-base {} + 2*(rank*stages + stage) + 1 = {top} \
                 overflows the port space (rank {}, stage {}, {stages} \
                 stages); lower the base",
                opts.listen_base,
                w.rank,
                opts.stage
            ));
        }
        let (rp, lp) = tcp::stage_ports(
            opts.listen_base,
            w.rank as usize,
            opts.stage as usize,
            stages,
        );
        (
            TcpListener::bind(("127.0.0.1", rp))
                .with_context(|| format!("binding ring listener on port {rp}"))?,
            TcpListener::bind(("127.0.0.1", lp))
                .with_context(|| format!("binding link listener on port {lp}"))?,
        )
    } else {
        (
            TcpListener::bind("127.0.0.1:0").context("binding ring listener")?,
            TcpListener::bind("127.0.0.1:0").context("binding link listener")?,
        )
    };
    let ring_port = ring_listener.local_addr()?.port();
    let link_port = link_listener.local_addr()?.port();
    write_msg(
        &mut coord,
        &Msg::StageHello { cluster: w.rank, stage: opts.stage, ring_port, link_port },
    )?;

    let workload = build_stage_pipeline(&w.workload, stages, opts.micros, w.seed)?;
    if workload.stages() != stages {
        return Err(anyhow!(
            "workload exports {} stages but the fleet runs {stages}",
            workload.stages()
        ));
    }
    let micros = workload.micros();
    let streams = one_f_one_b_schedule(stages, micros);
    validate_schedule(&streams, micros)
        .map_err(|e| anyhow!("invalid 1F1B schedule: {e}"))?;
    let stream = streams[opts.stage as usize].clone();

    let mut compute = workload.make_stage(w.rank as usize, opts.stage as usize)?;
    let n = compute.numel();
    let mut params = compute.init()?;
    if params.len() != n {
        return Err(anyhow!("init len {} != numel {n}", params.len()));
    }
    let spec = compute.param_spec();
    // §2.2: this process holds only this stage's optimizer pair.
    let DualOptimizer { mut inner, outer } = DualOptimizer::new(
        n,
        w.inner_lr,
        w.weight_decay,
        w.outer_lr,
        w.outer_momentum,
    );
    // Sync-mode engine: overlap stays a local-executor feature for now —
    // the recovery protocol assumes no reduction is in flight across a
    // round boundary.
    let mut engine = RoundEngine::new(params.clone(), 1, outer, false, false);
    // Same per-stage compressor seed derivation as the local executor
    // (inert under Method::None, load-bearing once the fleet compresses).
    let stage_seed =
        w.seed ^ (opts.stage as u64).wrapping_mul(0x9e3779b97f4a7c15);

    let mut applied = 0usize;
    let mut wire_total = 0u64;
    let mut epoch = 0u32;

    'epochs: loop {
        let Some((e, resume_round, ring_members, down_port)) =
            wait_for_stage_commit(&mut coord, epoch)?
        else {
            // Dropped before completion (a sibling stage died and the
            // coordinator removed our whole cluster): exit cleanly.
            return Ok(());
        };
        epoch = e;
        let finishing = resume_round as usize > w.rounds;
        let raw = match tcp::form_ring(
            w.rank,
            epoch,
            &ring_members,
            &ring_listener,
            connect_timeout,
            ring_timeout,
        ) {
            Ok(r) => r,
            Err(_) => {
                let _ = write_msg(
                    &mut coord,
                    &Msg::RingBroken { epoch, applied_rounds: applied as u32 },
                );
                continue 'epochs;
            }
        };
        let mut ring: Box<dyn RingTransport> = match &w.faults {
            Some(plan) => Box::new(FaultyRing::new(raw, plan.clone())),
            None => Box::new(raw),
        };
        // Dataflow links (skipped in a finishing epoch: no rounds left to
        // run, and neighbors that already completed form no links).
        let mut link: Box<dyn StageLink> = if finishing {
            Box::new(MpscStageLink::default())
        } else {
            match tcp::form_stage_links(
                opts.stage,
                epoch,
                &link_listener,
                if down_port == 0 { None } else { Some(down_port) },
                connect_timeout,
                ring_timeout,
            ) {
                Ok(l) => Box::new(l),
                Err(_) => {
                    let _ = write_msg(
                        &mut coord,
                        &Msg::RingBroken { epoch, applied_rounds: applied as u32 },
                    );
                    continue 'epochs;
                }
            }
        };

        // Consensus resync on this stage's ring: survivors re-agree on
        // θ_s (identical at epoch 1; a true mean after churn) and the
        // outer momentum restarts.
        let mut theta = engine.theta().to_vec();
        if ring.allreduce_mean(&mut theta).is_err() {
            let _ = write_msg(
                &mut coord,
                &Msg::RingBroken { epoch, applied_rounds: applied as u32 },
            );
            continue 'epochs;
        }
        engine.set_theta(&theta);
        engine.reset_outer();
        params.copy_from_slice(engine.theta());

        let mut lane =
            RingLane::new(ring, Method::None, stage_seed, spec.clone(), false);
        let mut round = resume_round as usize;
        let mut broke = false;
        while round <= w.rounds {
            // Fault hook: an injected kill exits here (process mode) or
            // errors out (thread mode) — either way the control socket
            // drops and the coordinator sees a dead stage process.
            lane.begin_round(round)?;
            let anchor = params.clone();
            let mut loss_acc = 0.0f64;
            let mut loss_n = 0usize;
            let mut step_err = false;
            for _ in 0..w.local_steps {
                compute.next_step()?;
                let mut grad_acc = vec![0.0f32; n];
                match run_stream_step(
                    compute.as_mut(),
                    &params,
                    &stream,
                    link.as_mut(),
                    &mut grad_acc,
                ) {
                    Ok((ls, ln, _busy)) => {
                        loss_acc += ls;
                        loss_n += ln;
                        let inv = 1.0 / micros as f32;
                        grad_acc.iter_mut().for_each(|g| *g *= inv);
                        inner.step(&mut params, &grad_acc);
                    }
                    Err(_) => {
                        // A dead neighbor surfaces here (link timeout /
                        // EOF): churn, not a fatal error.
                        step_err = true;
                        break;
                    }
                }
            }
            if step_err {
                broke = true;
                break;
            }
            let mv = movement(&anchor, &params);
            if engine.finish_round(vec![mv], round as u64, &mut lane).is_err() {
                broke = true;
                break;
            }
            params.copy_from_slice(engine.theta());
            applied = round;
            // Loss telemetry is real only on the label-bearing stage.
            let loss = if loss_n > 0 {
                (loss_acc / loss_n as f64) as f32
            } else {
                f32::NAN
            };
            let _ = write_msg(
                &mut coord,
                &Msg::Heartbeat { round: round as u32, loss },
            );
            round += 1;
        }
        wire_total += lane.wire_total;
        if broke {
            let _ = write_msg(
                &mut coord,
                &Msg::RingBroken { epoch, applied_rounds: applied as u32 },
            );
            continue 'epochs;
        }
        break;
    }

    write_msg(
        &mut coord,
        &Msg::Done {
            rounds: applied as u32,
            wire_bytes: wire_total,
            // The final eval needs the *assembled* model; the coordinator
            // computes it from the per-stage digests.
            final_loss: f32::NAN,
            params: params_digest(engine.theta()),
        },
    )?;
    // Park until Shutdown (or coordinator EOF).
    coord.set_read_timeout(Some(Duration::from_secs(120))).ok();
    let _ = read_msg(&mut coord);
    Ok(())
}

// ---------------------------------------------------------------------------
// Coordinator side
// ---------------------------------------------------------------------------

struct WorkerHandle {
    writer: TcpStream,
    ring_port: u16,
}

/// One stage process's control handle (stage fleet).
struct StageHandle {
    writer: TcpStream,
    ring_port: u16,
    link_port: u16,
}

/// Control-plane event, keyed by worker rank (`u32`) or by
/// `(cluster, stage)` in the stage fleet.
enum Event<K> {
    Msg(K, Msg),
    Closed(K),
}

/// One reader thread per control socket feeding the supervisor's queue.
fn spawn_reader<K: Copy + Send + 'static>(
    key: K,
    mut rs: TcpStream,
    tx: mpsc::Sender<Event<K>>,
) {
    std::thread::spawn(move || loop {
        match read_msg(&mut rs) {
            Ok(m) => {
                if tx.send(Event::Msg(key, m)).is_err() {
                    break;
                }
            }
            Err(_) => {
                let _ = tx.send(Event::Closed(key));
                break;
            }
        }
    });
}

struct DoneReport {
    wire_bytes: u64,
    final_loss: f32,
    params: Vec<f32>,
}

fn spawn_workers(
    cfg: &ElasticConfig,
    mode: &SpawnMode,
    coord_addr: &str,
) -> Result<Vec<std::process::Child>> {
    let mut children = Vec::new();
    for rank in 0..cfg.workers as u32 {
        let opts = worker_opts_for(cfg, rank, coord_addr, mode);
        match mode {
            SpawnMode::Process { exe } => {
                let mut cmd = Command::new(exe);
                cmd.arg("worker")
                    .arg("--coord")
                    .arg(&opts.coord)
                    .arg("--rank")
                    .arg(rank.to_string())
                    .arg("--rounds")
                    .arg(cfg.rounds.to_string())
                    .arg("--local-steps")
                    .arg(cfg.local_steps.to_string())
                    .arg("--inner-lr")
                    .arg(cfg.inner_lr.to_string())
                    .arg("--weight-decay")
                    .arg(cfg.weight_decay.to_string())
                    .arg("--outer-lr")
                    .arg(cfg.outer_lr.to_string())
                    .arg("--outer-momentum")
                    .arg(cfg.outer_momentum.to_string())
                    .arg("--seed")
                    .arg(cfg.seed.to_string())
                    .arg("--ring-timeout-ms")
                    .arg(cfg.transport.ring_timeout_ms.to_string())
                    .arg("--connect-timeout-ms")
                    .arg(cfg.transport.connect_timeout_ms.to_string());
                match &cfg.workload {
                    Workload::Quadratic { dim } => {
                        cmd.arg("--workload").arg("quad");
                        cmd.arg("--dim").arg(dim.to_string());
                    }
                    Workload::Runtime { artifacts_dir } => {
                        cmd.arg("--workload").arg("runtime");
                        cmd.arg("--artifacts").arg(artifacts_dir);
                    }
                }
                if let Some(plan) = &opts.faults {
                    cmd.arg("--fault-seed")
                        .arg(plan.seed.to_string())
                        .arg("--fault-delay-prob")
                        .arg(plan.delay_prob.to_string())
                        .arg("--fault-delay-ms")
                        .arg(plan.max_delay_ms.to_string())
                        .arg("--fault-kill-round")
                        .arg(plan.kill_round.to_string())
                        .arg("--fault-straggler-ms")
                        .arg(plan.straggler_ms.to_string());
                }
                let child = cmd
                    .stdout(Stdio::null())
                    .stderr(Stdio::inherit())
                    .spawn()
                    .with_context(|| format!("spawning worker {rank} via {exe}"))?;
                children.push(child);
            }
            SpawnMode::Thread => {
                std::thread::spawn(move || {
                    if let Err(e) = run_worker(&opts) {
                        eprintln!("[worker {rank}] exited: {e:#}");
                    }
                });
            }
        }
    }
    Ok(children)
}

fn worker_opts_for(
    cfg: &ElasticConfig,
    rank: u32,
    coord_addr: &str,
    mode: &SpawnMode,
) -> WorkerOpts {
    let exit_on_kill = matches!(mode, SpawnMode::Process { .. });
    WorkerOpts {
        coord: coord_addr.to_string(),
        rank,
        rounds: cfg.rounds,
        local_steps: cfg.local_steps,
        inner_lr: cfg.inner_lr,
        weight_decay: cfg.weight_decay,
        outer_lr: cfg.outer_lr,
        outer_momentum: cfg.outer_momentum,
        seed: cfg.seed,
        workload: cfg.workload.clone(),
        ring_timeout_ms: cfg.transport.ring_timeout_ms,
        connect_timeout_ms: cfg.transport.connect_timeout_ms,
        faults: fault_plan_for(&cfg.faults, rank, exit_on_kill),
    }
}

/// Accept one control connection per worker and read its `Hello`.
fn accept_workers(
    listener: &TcpListener,
    expected: usize,
    deadline: Instant,
) -> Result<BTreeMap<u32, WorkerHandle>> {
    listener.set_nonblocking(true).context("control listener nonblocking")?;
    let mut map = BTreeMap::new();
    while map.len() < expected {
        match listener.accept() {
            Ok((stream, _)) => {
                stream.set_nonblocking(false).ok();
                stream.set_nodelay(true).ok();
                stream.set_read_timeout(Some(Duration::from_secs(10))).ok();
                let mut stream = stream;
                match read_msg(&mut stream) {
                    Ok(Msg::Hello { rank, ring_port }) => {
                        if map.contains_key(&rank) {
                            return Err(anyhow!("duplicate worker rank {rank}"));
                        }
                        stream.set_write_timeout(Some(Duration::from_secs(10))).ok();
                        map.insert(rank, WorkerHandle { writer: stream, ring_port });
                    }
                    _ => { /* not a worker — drop */ }
                }
            }
            Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                if Instant::now() >= deadline {
                    return Err(anyhow!(
                        "only {}/{} workers connected before the deadline",
                        map.len(),
                        expected
                    ));
                }
                std::thread::sleep(Duration::from_millis(10));
            }
            Err(e) => return Err(anyhow!("control accept failed: {e}")),
        }
    }
    Ok(map)
}

/// Reap spawned worker processes: give each a short grace window, then
/// kill.  Runs on every exit path so a failed coordination can't leave
/// orphaned workers training at full CPU.
fn reap_children(children: &mut [std::process::Child]) {
    let reap_deadline = Instant::now() + Duration::from_secs(5);
    for child in children.iter_mut() {
        loop {
            match child.try_wait() {
                Ok(Some(_)) => break,
                Ok(None) => {
                    if Instant::now() >= reap_deadline {
                        let _ = child.kill();
                        let _ = child.wait();
                        break;
                    }
                    std::thread::sleep(Duration::from_millis(20));
                }
                Err(_) => break,
            }
        }
    }
}

/// Run the elastic coordinator to completion.  Dispatches to the
/// stage-parallel fleet supervisor when `pp_stages > 1` (one OS process
/// per (cluster, stage), per-stage rings, intra-cluster TCP dataflow).
pub fn run_elastic(cfg: &ElasticConfig, mode: &SpawnMode) -> Result<ElasticOutcome> {
    if cfg.pp_stages > 1 {
        return run_elastic_stages(cfg, mode);
    }
    if cfg.workers == 0 {
        return Err(anyhow!("need at least one worker"));
    }
    let listener =
        TcpListener::bind("127.0.0.1:0").context("binding coordinator socket")?;
    let coord_addr = listener.local_addr()?.to_string();
    let mut children = spawn_workers(cfg, mode, &coord_addr)?;

    // Supervision can fail at many points (startup timeout, wall timeout,
    // every worker dying); reap the children on ALL of them, then
    // propagate the error.
    let supervised = supervise(cfg, &listener);
    reap_children(&mut children);
    let (epoch, done, round_losses) = supervised?;

    let survivors: Vec<u32> = done.keys().copied().collect();
    if survivors.is_empty() {
        return Err(anyhow!("no worker completed the run"));
    }
    let reports: Vec<&DoneReport> = done.values().collect();
    let p0 = &reports[0].params;
    let mut max_dev = 0.0f32;
    for r in &reports[1..] {
        let dev = p0
            .iter()
            .zip(&r.params)
            .map(|(a, b)| (a - b).abs())
            .fold(0.0f32, f32::max);
        max_dev = max_dev.max(dev);
    }
    if max_dev > 1e-4 {
        if epoch <= 1 {
            // No churn happened: the ring algebra is symmetric, so any
            // divergence is a real bug.
            return Err(anyhow!("workers diverged: max param dev {max_dev}"));
        }
        // With churn, a worker that broke during the *final* round can
        // legitimately miss the last outer update (its peers were already
        // done, so there was no ring left to redo it with).  Bounded
        // staleness, not corruption — report it instead of failing.
        eprintln!(
            "[elastic] survivors differ by max param dev {max_dev} after \
             {epoch} membership epochs (final-round churn staleness)"
        );
    }
    let final_loss =
        reports.iter().map(|r| r.final_loss).sum::<f32>() / reports.len() as f32;
    let total_wire_bytes = reports.iter().map(|r| r.wire_bytes).sum();
    Ok(ElasticOutcome {
        rounds: cfg.rounds,
        epochs: epoch,
        started: cfg.workers,
        survivors,
        final_loss,
        final_params: p0.clone(),
        total_wire_bytes,
        round_losses,
    })
}

/// Accept the fleet, run the 2PC epochs, and watch the run to completion;
/// returns (final epoch, done reports, heartbeat telemetry).  Sends
/// `Shutdown` to the fleet on success; error paths leave process cleanup
/// to the caller's [`reap_children`].
#[allow(clippy::type_complexity)]
fn supervise(
    cfg: &ElasticConfig,
    listener: &TcpListener,
) -> Result<(u32, BTreeMap<u32, DoneReport>, Vec<(u32, u32, f32)>)> {
    let wall_deadline = Instant::now() + Duration::from_millis(cfg.wall_timeout_ms);
    let startup_deadline = Instant::now()
        + Duration::from_millis(cfg.transport.connect_timeout_ms)
        + Duration::from_secs(10);
    let mut live = accept_workers(listener, cfg.workers, startup_deadline)?;

    // One reader thread per worker feeding a single event queue; the
    // handles keep the write half.
    let (tx, rx) = mpsc::channel::<Event<u32>>();
    for (&rank, handle) in live.iter() {
        let rs = handle.writer.try_clone().context("cloning control stream")?;
        rs.set_read_timeout(None).ok();
        spawn_reader(rank, rs, tx.clone());
    }
    drop(tx);

    let grace = Duration::from_millis(cfg.transport.ring_timeout_ms * 2 + 2000);
    let mut epoch: u32 = 0;
    let mut resume_round: u32 = 1;
    let mut done: BTreeMap<u32, DoneReport> = BTreeMap::new();
    let mut round_losses: Vec<(u32, u32, f32)> = Vec::new();

    // Small helper applied to every event everywhere: telemetry +
    // resume-round bookkeeping.
    fn note_progress(
        ev: &Event<u32>,
        resume_round: &mut u32,
        round_losses: &mut Vec<(u32, u32, f32)>,
    ) {
        if let Event::Msg(w, Msg::Heartbeat { round, loss }) = ev {
            round_losses.push((*w, *round, *loss));
            *resume_round = (*resume_round).max(round + 1);
        }
        if let Event::Msg(_, Msg::RingBroken { applied_rounds, .. }) = ev {
            *resume_round = (*resume_round).max(applied_rounds + 1);
        }
    }

    'epochs: loop {
        if Instant::now() >= wall_deadline {
            return Err(anyhow!("elastic run exceeded the wall timeout"));
        }
        if live.is_empty() {
            return Err(anyhow!("all workers died"));
        }
        let pending: Vec<u32> =
            live.keys().copied().filter(|r| !done.contains_key(r)).collect();
        if pending.is_empty() {
            break;
        }

        // -- 2PC prepare/commit over the pending members ------------------
        epoch += 1;
        let members: Vec<(u32, u16)> =
            pending.iter().map(|r| (*r, live[r].ring_port)).collect();
        let mut lost: Vec<u32> = Vec::new();
        for &r in &pending {
            let h = live.get_mut(&r).unwrap();
            if write_msg(
                &mut h.writer,
                &Msg::Prepare { epoch, resume_round, members: members.clone() },
            )
            .is_err()
            {
                lost.push(r);
            }
        }
        if !lost.is_empty() {
            for r in lost {
                live.remove(&r);
            }
            continue 'epochs;
        }

        let mut acked: BTreeSet<u32> = BTreeSet::new();
        let ack_deadline = Instant::now() + grace;
        while !pending
            .iter()
            .all(|r| acked.contains(r) || done.contains_key(r) || !live.contains_key(r))
        {
            let left = ack_deadline.saturating_duration_since(Instant::now());
            if left.is_zero() {
                // Someone never acked (e.g. still stuck in an old ring's
                // timeout window) — supersede with a fresh epoch.
                continue 'epochs;
            }
            match rx.recv_timeout(left) {
                Ok(ev) => {
                    note_progress(&ev, &mut resume_round, &mut round_losses);
                    match ev {
                        Event::Msg(w, Msg::PrepareAck { epoch: e }) if e == epoch => {
                            acked.insert(w);
                        }
                        // A worker can finish (its Done racing our
                        // Prepare) — record it rather than dropping the
                        // completion report; it leaves `pending` via the
                        // loop condition and the next epoch's membership.
                        Event::Msg(w, Msg::Done { wire_bytes, final_loss, params, .. }) => {
                            done.insert(w, DoneReport { wire_bytes, final_loss, params });
                        }
                        Event::Closed(w) => {
                            if !done.contains_key(&w) {
                                live.remove(&w);
                                continue 'epochs;
                            }
                        }
                        _ => {}
                    }
                }
                Err(mpsc::RecvTimeoutError::Timeout) => continue,
                Err(mpsc::RecvTimeoutError::Disconnected) => {
                    return Err(anyhow!("all control channels lost"))
                }
            }
        }

        // A pending member that finished during the ack wait leaves the
        // proposed membership stale — don't commit a ring containing a
        // worker that will never join it; re-prepare without it.
        if pending.iter().any(|r| done.contains_key(r)) {
            continue 'epochs;
        }

        let mut lost: Vec<u32> = Vec::new();
        for &r in &pending {
            if let Some(h) = live.get_mut(&r) {
                if write_msg(&mut h.writer, &Msg::Commit { epoch }).is_err() {
                    lost.push(r);
                }
            }
        }
        if !lost.is_empty() {
            for r in lost {
                live.remove(&r);
            }
            continue 'epochs;
        }

        // -- committed: watch the epoch run -------------------------------
        let mut broken: BTreeSet<u32> = BTreeSet::new();
        loop {
            if Instant::now() >= wall_deadline {
                return Err(anyhow!("elastic run exceeded the wall timeout"));
            }
            let churn = match rx.recv_timeout(Duration::from_millis(200)) {
                Ok(ev) => {
                    note_progress(&ev, &mut resume_round, &mut round_losses);
                    match ev {
                        Event::Msg(w, Msg::Done { wire_bytes, final_loss, params, .. }) => {
                            done.insert(w, DoneReport { wire_bytes, final_loss, params });
                            false
                        }
                        Event::Msg(w, Msg::RingBroken { .. }) => {
                            broken.insert(w);
                            true
                        }
                        Event::Closed(w) => {
                            if done.contains_key(&w) {
                                false
                            } else {
                                live.remove(&w);
                                true
                            }
                        }
                        _ => false,
                    }
                }
                Err(mpsc::RecvTimeoutError::Timeout) => false,
                Err(mpsc::RecvTimeoutError::Disconnected) => {
                    return Err(anyhow!("all control channels lost"))
                }
            };
            if live.keys().all(|r| done.contains_key(r)) {
                break 'epochs;
            }
            if !churn {
                continue;
            }
            // Churn: drain until every live, not-done member has reported
            // its break (or a grace period passes), then re-form.
            let drain_deadline = Instant::now() + grace;
            loop {
                let outstanding = live
                    .keys()
                    .filter(|r| !done.contains_key(r) && !broken.contains(r))
                    .count();
                if outstanding == 0 || Instant::now() >= drain_deadline {
                    break;
                }
                if let Ok(ev) = rx.recv_timeout(Duration::from_millis(100)) {
                    note_progress(&ev, &mut resume_round, &mut round_losses);
                    match ev {
                        Event::Msg(w, Msg::RingBroken { .. }) => {
                            broken.insert(w);
                        }
                        Event::Msg(w, Msg::Done { wire_bytes, final_loss, params, .. }) => {
                            done.insert(w, DoneReport { wire_bytes, final_loss, params });
                        }
                        Event::Closed(w) => {
                            if !done.contains_key(&w) {
                                live.remove(&w);
                            }
                        }
                        _ => {}
                    }
                }
            }
            continue 'epochs;
        }
    }

    // -- success: graceful shutdown (caller reaps the processes) ----------
    for h in live.values_mut() {
        let _ = write_msg(&mut h.writer, &Msg::Shutdown);
    }
    Ok((epoch, done, round_losses))
}

// ---------------------------------------------------------------------------
// Coordinator side: stage-parallel fleet (pp_stages > 1)
// ---------------------------------------------------------------------------

fn stage_worker_opts_for(
    cfg: &ElasticConfig,
    rank: u32,
    stage: u32,
    coord_addr: &str,
    mode: &SpawnMode,
) -> StageWorkerOpts {
    let exit_on_kill = matches!(mode, SpawnMode::Process { .. });
    let mut base = worker_opts_for(cfg, rank, coord_addr, mode);
    base.faults = stage_fault_plan_for(&cfg.faults, rank, stage, exit_on_kill);
    StageWorkerOpts {
        base,
        stage,
        stages: cfg.pp_stages as u32,
        micros: cfg.microbatches.max(1),
        listen_base: cfg.transport.stage_listen_base_port,
    }
}

fn spawn_stage_workers(
    cfg: &ElasticConfig,
    mode: &SpawnMode,
    coord_addr: &str,
) -> Result<Vec<std::process::Child>> {
    let mut children = Vec::new();
    for rank in 0..cfg.workers as u32 {
        for stage in 0..cfg.pp_stages as u32 {
            let opts = stage_worker_opts_for(cfg, rank, stage, coord_addr, mode);
            match mode {
                SpawnMode::Process { exe } => {
                    let mut cmd = Command::new(exe);
                    cmd.arg("worker")
                        .arg("--coord")
                        .arg(&opts.base.coord)
                        .arg("--rank")
                        .arg(rank.to_string())
                        .arg("--stage")
                        .arg(stage.to_string())
                        .arg("--stages")
                        .arg(cfg.pp_stages.to_string())
                        .arg("--micros")
                        .arg(opts.micros.to_string())
                        .arg("--listen-base")
                        .arg(opts.listen_base.to_string())
                        .arg("--rounds")
                        .arg(cfg.rounds.to_string())
                        .arg("--local-steps")
                        .arg(cfg.local_steps.to_string())
                        .arg("--inner-lr")
                        .arg(cfg.inner_lr.to_string())
                        .arg("--weight-decay")
                        .arg(cfg.weight_decay.to_string())
                        .arg("--outer-lr")
                        .arg(cfg.outer_lr.to_string())
                        .arg("--outer-momentum")
                        .arg(cfg.outer_momentum.to_string())
                        .arg("--seed")
                        .arg(cfg.seed.to_string())
                        .arg("--ring-timeout-ms")
                        .arg(cfg.transport.ring_timeout_ms.to_string())
                        .arg("--connect-timeout-ms")
                        .arg(cfg.transport.connect_timeout_ms.to_string());
                    match &cfg.workload {
                        Workload::Quadratic { dim } => {
                            cmd.arg("--workload").arg("quad");
                            cmd.arg("--dim").arg(dim.to_string());
                        }
                        Workload::Runtime { artifacts_dir } => {
                            cmd.arg("--workload").arg("runtime");
                            cmd.arg("--artifacts").arg(artifacts_dir);
                        }
                    }
                    if let Some(plan) = &opts.base.faults {
                        cmd.arg("--fault-seed")
                            .arg(plan.seed.to_string())
                            .arg("--fault-delay-prob")
                            .arg(plan.delay_prob.to_string())
                            .arg("--fault-delay-ms")
                            .arg(plan.max_delay_ms.to_string())
                            .arg("--fault-kill-round")
                            .arg(plan.kill_round.to_string())
                            .arg("--fault-straggler-ms")
                            .arg(plan.straggler_ms.to_string());
                    }
                    let child = cmd
                        .stdout(Stdio::null())
                        .stderr(Stdio::inherit())
                        .spawn()
                        .with_context(|| {
                            format!("spawning stage worker {rank}.{stage} via {exe}")
                        })?;
                    children.push(child);
                }
                SpawnMode::Thread => {
                    std::thread::spawn(move || {
                        if let Err(e) = run_stage_worker(&opts) {
                            eprintln!(
                                "[stage worker {rank}.{stage}] exited: {e:#}"
                            );
                        }
                    });
                }
            }
        }
    }
    Ok(children)
}

/// Accept one control connection per (cluster, stage) process and read
/// its `StageHello`.
fn accept_stage_workers(
    listener: &TcpListener,
    clusters: usize,
    stages: usize,
    deadline: Instant,
) -> Result<BTreeMap<(u32, u32), StageHandle>> {
    listener
        .set_nonblocking(true)
        .context("control listener nonblocking")?;
    let expected = clusters * stages;
    let mut map = BTreeMap::new();
    while map.len() < expected {
        match listener.accept() {
            Ok((stream, _)) => {
                stream.set_nonblocking(false).ok();
                stream.set_nodelay(true).ok();
                stream.set_read_timeout(Some(Duration::from_secs(10))).ok();
                let mut stream = stream;
                match read_msg(&mut stream) {
                    Ok(Msg::StageHello { cluster, stage, ring_port, link_port }) => {
                        if cluster as usize >= clusters || stage as usize >= stages {
                            return Err(anyhow!(
                                "stage hello ({cluster}, {stage}) out of range"
                            ));
                        }
                        if map.contains_key(&(cluster, stage)) {
                            return Err(anyhow!(
                                "duplicate stage worker ({cluster}, {stage})"
                            ));
                        }
                        stream
                            .set_write_timeout(Some(Duration::from_secs(10)))
                            .ok();
                        map.insert(
                            (cluster, stage),
                            StageHandle { writer: stream, ring_port, link_port },
                        );
                    }
                    _ => { /* not a stage worker — drop */ }
                }
            }
            Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                if Instant::now() >= deadline {
                    return Err(anyhow!(
                        "only {}/{} stage workers connected before the deadline",
                        map.len(),
                        expected
                    ));
                }
                std::thread::sleep(Duration::from_millis(10));
            }
            Err(e) => return Err(anyhow!("control accept failed: {e}")),
        }
    }
    Ok(map)
}

/// Drop every cluster missing any stage process: a dead stage starves its
/// siblings' dataflow, so the whole cluster leaves the membership and the
/// orphaned siblings are told to shut down.
fn prune_partial_clusters(
    live: &mut BTreeMap<(u32, u32), StageHandle>,
    stages: u32,
) {
    let clusters: BTreeSet<u32> = live.keys().map(|(c, _)| *c).collect();
    for c in clusters {
        if (0..stages).all(|s| live.contains_key(&(c, s))) {
            continue;
        }
        for s in 0..stages {
            if let Some(mut h) = live.remove(&(c, s)) {
                let _ = write_msg(&mut h.writer, &Msg::Shutdown);
            }
        }
    }
}

/// Run the stage-parallel elastic coordinator to completion: spawn the
/// `dp × pp` stage-process fleet, supervise the per-stage rings through
/// membership epochs, and assemble + evaluate the final model from the
/// survivors' per-stage parameter digests.
fn run_elastic_stages(cfg: &ElasticConfig, mode: &SpawnMode) -> Result<ElasticOutcome> {
    if cfg.workers == 0 {
        return Err(anyhow!("need at least one cluster"));
    }
    let stages = cfg.pp_stages;
    let listener =
        TcpListener::bind("127.0.0.1:0").context("binding coordinator socket")?;
    let coord_addr = listener.local_addr()?.to_string();
    let mut children = spawn_stage_workers(cfg, mode, &coord_addr)?;

    let supervised = supervise_stages(cfg, &listener);
    reap_children(&mut children);
    let (epoch, done, round_losses) = supervised?;

    // Survivor clusters: every stage process completed.
    let clusters: BTreeSet<u32> = done.keys().map(|(c, _)| *c).collect();
    let survivors: Vec<u32> = clusters
        .into_iter()
        .filter(|c| (0..stages as u32).all(|s| done.contains_key(&(*c, s))))
        .collect();
    if survivors.is_empty() {
        return Err(anyhow!("no cluster completed the run"));
    }

    // Assemble per-cluster full vectors from the per-stage digests (stage
    // concatenation == the single flat layout).
    let assemble = |c: u32| -> Vec<f32> {
        let mut full = Vec::new();
        for s in 0..stages as u32 {
            full.extend_from_slice(&done[&(c, s)].params);
        }
        full
    };
    let p0 = assemble(survivors[0]);
    let mut max_dev = 0.0f32;
    for &c in &survivors[1..] {
        let pc = assemble(c);
        let dev = p0
            .iter()
            .zip(&pc)
            .map(|(a, b)| (a - b).abs())
            .fold(0.0f32, f32::max);
        max_dev = max_dev.max(dev);
    }
    if max_dev > 1e-4 {
        if epoch <= 1 {
            // No churn happened: per-stage ring algebra is symmetric, so
            // any divergence is a real bug.
            return Err(anyhow!(
                "stage fleets diverged: max param dev {max_dev}"
            ));
        }
        eprintln!(
            "[elastic] surviving clusters differ by max param dev {max_dev} \
             after {epoch} membership epochs (final-round churn staleness)"
        );
    }

    // Final eval over the assembled model (each stage process holds only
    // its shard, so the coordinator evaluates).  Digests are exact for
    // per-stage shards up to PARAMS_DIGEST_MAX elements; beyond that the
    // eval is skipped rather than run on a strided sample.
    let workload =
        build_stage_pipeline(&cfg.workload, stages, cfg.microbatches, cfg.seed)?;
    let expected: usize = (0..stages).map(|s| workload.stage_numel(s)).sum();
    let final_loss = if p0.len() == expected {
        workload.eval(&p0)?
    } else {
        eprintln!(
            "[elastic] stage param digests truncated ({} of {expected} \
             elements) — skipping the assembled final eval",
            p0.len()
        );
        f32::NAN
    };
    let total_wire_bytes = done.values().map(|r| r.wire_bytes).sum();
    Ok(ElasticOutcome {
        rounds: cfg.rounds,
        epochs: epoch,
        started: cfg.workers,
        survivors,
        final_loss,
        final_params: p0,
        total_wire_bytes,
        round_losses,
    })
}

/// Accept the stage fleet, run the (cluster, stage)-keyed 2PC epochs, and
/// watch the run to completion; returns (final epoch, per-(cluster,
/// stage) done reports, heartbeat telemetry keyed by cluster).
#[allow(clippy::type_complexity)]
fn supervise_stages(
    cfg: &ElasticConfig,
    listener: &TcpListener,
) -> Result<(u32, BTreeMap<(u32, u32), DoneReport>, Vec<(u32, u32, f32)>)> {
    let stages = cfg.pp_stages as u32;
    let wall_deadline = Instant::now() + Duration::from_millis(cfg.wall_timeout_ms);
    let startup_deadline = Instant::now()
        + Duration::from_millis(cfg.transport.connect_timeout_ms)
        + Duration::from_secs(10);
    let mut live =
        accept_stage_workers(listener, cfg.workers, cfg.pp_stages, startup_deadline)?;

    let (tx, rx) = mpsc::channel::<Event<(u32, u32)>>();
    for (&key, handle) in live.iter() {
        let rs = handle.writer.try_clone().context("cloning control stream")?;
        rs.set_read_timeout(None).ok();
        spawn_reader(key, rs, tx.clone());
    }
    drop(tx);

    let grace = Duration::from_millis(cfg.transport.ring_timeout_ms * 2 + 2000);
    let mut epoch: u32 = 0;
    let mut resume_round: u32 = 1;
    let mut done: BTreeMap<(u32, u32), DoneReport> = BTreeMap::new();
    let mut round_losses: Vec<(u32, u32, f32)> = Vec::new();

    // Telemetry + resume-round bookkeeping, applied to every event from a
    // still-live process (orphans of dropped clusters are ignored — their
    // progress reports must not steer the survivors' resume point).
    fn note(
        ev: &Event<(u32, u32)>,
        live: &BTreeMap<(u32, u32), StageHandle>,
        resume_round: &mut u32,
        round_losses: &mut Vec<(u32, u32, f32)>,
    ) {
        let key = match ev {
            Event::Msg(k, _) => k,
            Event::Closed(k) => k,
        };
        if !live.contains_key(key) {
            return;
        }
        if let Event::Msg((c, _), Msg::Heartbeat { round, loss }) = ev {
            if !loss.is_nan() {
                round_losses.push((*c, *round, *loss));
            }
            *resume_round = (*resume_round).max(round + 1);
        }
        if let Event::Msg(_, Msg::RingBroken { applied_rounds, .. }) = ev {
            *resume_round = (*resume_round).max(applied_rounds + 1);
        }
    }

    'epochs: loop {
        if Instant::now() >= wall_deadline {
            return Err(anyhow!("elastic stage run exceeded the wall timeout"));
        }
        prune_partial_clusters(&mut live, stages);
        if live.is_empty() {
            return Err(anyhow!("all clusters died"));
        }
        let clusters: BTreeSet<u32> = live.keys().map(|(c, _)| *c).collect();
        let pending: Vec<u32> = clusters
            .into_iter()
            .filter(|c| (0..stages).any(|s| !done.contains_key(&(*c, s))))
            .collect();
        if pending.is_empty() {
            break;
        }

        // -- 2PC prepare/commit, tailored per stage process ---------------
        epoch += 1;
        // When the shared resume point is already past the schedule, the
        // remaining processes have nothing left to run (their peers
        // completed the final round before a late break): commit size-1
        // rings and no dataflow so they finish immediately.
        let finishing = resume_round as usize > cfg.rounds;
        let recipients: Vec<(u32, u32)> = pending
            .iter()
            .flat_map(|&c| (0..stages).map(move |s| (c, s)))
            .filter(|k| !done.contains_key(k))
            .collect();
        let mut lost: Vec<(u32, u32)> = Vec::new();
        for &(c, s) in &recipients {
            let ring_members: Vec<(u32, u16)> = if finishing {
                vec![(c, live[&(c, s)].ring_port)]
            } else {
                pending
                    .iter()
                    .filter(|&&c2| !done.contains_key(&(c2, s)))
                    .map(|&c2| (c2, live[&(c2, s)].ring_port))
                    .collect()
            };
            let link_down_port = if !finishing
                && s + 1 < stages
                && !done.contains_key(&(c, s + 1))
            {
                live[&(c, s + 1)].link_port
            } else {
                0
            };
            let h = live.get_mut(&(c, s)).unwrap();
            if write_msg(
                &mut h.writer,
                &Msg::StagePrepare {
                    epoch,
                    resume_round,
                    ring_members,
                    link_down_port,
                },
            )
            .is_err()
            {
                lost.push((c, s));
            }
        }
        if !lost.is_empty() {
            for k in lost {
                live.remove(&k);
            }
            continue 'epochs;
        }

        let mut acked: BTreeSet<(u32, u32)> = BTreeSet::new();
        let ack_deadline = Instant::now() + grace;
        while !recipients.iter().all(|k| {
            acked.contains(k) || done.contains_key(k) || !live.contains_key(k)
        }) {
            let left = ack_deadline.saturating_duration_since(Instant::now());
            if left.is_zero() {
                // Someone never acked — supersede with a fresh epoch.
                continue 'epochs;
            }
            match rx.recv_timeout(left) {
                Ok(ev) => {
                    note(&ev, &live, &mut resume_round, &mut round_losses);
                    match ev {
                        Event::Msg(k, Msg::PrepareAck { epoch: e }) if e == epoch => {
                            acked.insert(k);
                        }
                        Event::Msg(k, Msg::Done { wire_bytes, final_loss, params, .. }) => {
                            if live.contains_key(&k) {
                                done.insert(
                                    k,
                                    DoneReport { wire_bytes, final_loss, params },
                                );
                            }
                        }
                        Event::Closed(k) => {
                            if live.contains_key(&k) && !done.contains_key(&k) {
                                live.remove(&k);
                                continue 'epochs;
                            }
                        }
                        _ => {}
                    }
                }
                Err(mpsc::RecvTimeoutError::Timeout) => continue,
                Err(mpsc::RecvTimeoutError::Disconnected) => {
                    return Err(anyhow!("all control channels lost"))
                }
            }
        }
        // Membership changed during the ack wait → the proposal is stale.
        if recipients
            .iter()
            .any(|k| done.contains_key(k) || !live.contains_key(k))
        {
            continue 'epochs;
        }

        let mut lost: Vec<(u32, u32)> = Vec::new();
        for k in &recipients {
            if let Some(h) = live.get_mut(k) {
                if write_msg(&mut h.writer, &Msg::Commit { epoch }).is_err() {
                    lost.push(*k);
                }
            }
        }
        if !lost.is_empty() {
            for k in lost {
                live.remove(&k);
            }
            continue 'epochs;
        }

        // -- committed: watch the epoch run -------------------------------
        let mut broken: BTreeSet<(u32, u32)> = BTreeSet::new();
        loop {
            if Instant::now() >= wall_deadline {
                return Err(anyhow!("elastic stage run exceeded the wall timeout"));
            }
            let churn = match rx.recv_timeout(Duration::from_millis(200)) {
                Ok(ev) => {
                    note(&ev, &live, &mut resume_round, &mut round_losses);
                    match ev {
                        Event::Msg(k, Msg::Done { wire_bytes, final_loss, params, .. }) => {
                            if live.contains_key(&k) {
                                done.insert(
                                    k,
                                    DoneReport { wire_bytes, final_loss, params },
                                );
                            }
                            false
                        }
                        Event::Msg(k, Msg::RingBroken { .. }) => {
                            if live.contains_key(&k) {
                                broken.insert(k);
                                true
                            } else {
                                false
                            }
                        }
                        Event::Closed(k) => {
                            if live.contains_key(&k) && !done.contains_key(&k) {
                                live.remove(&k);
                                true
                            } else {
                                false
                            }
                        }
                        _ => false,
                    }
                }
                Err(mpsc::RecvTimeoutError::Timeout) => false,
                Err(mpsc::RecvTimeoutError::Disconnected) => {
                    return Err(anyhow!("all control channels lost"))
                }
            };
            if live.keys().all(|k| done.contains_key(k)) {
                break 'epochs;
            }
            if !churn {
                continue;
            }
            // Churn: drain until every live, not-done process has reported
            // its break (or a grace period passes), then re-form.
            let drain_deadline = Instant::now() + grace;
            loop {
                let outstanding = live
                    .keys()
                    .filter(|k| !done.contains_key(k) && !broken.contains(k))
                    .count();
                if outstanding == 0 || Instant::now() >= drain_deadline {
                    break;
                }
                if let Ok(ev) = rx.recv_timeout(Duration::from_millis(100)) {
                    note(&ev, &live, &mut resume_round, &mut round_losses);
                    match ev {
                        Event::Msg(k, Msg::RingBroken { .. }) => {
                            broken.insert(k);
                        }
                        Event::Msg(k, Msg::Done { wire_bytes, final_loss, params, .. }) => {
                            if live.contains_key(&k) {
                                done.insert(
                                    k,
                                    DoneReport { wire_bytes, final_loss, params },
                                );
                            }
                        }
                        Event::Closed(k) => {
                            if !done.contains_key(&k) {
                                live.remove(&k);
                            }
                        }
                        _ => {}
                    }
                }
            }
            continue 'epochs;
        }
    }

    // -- success: graceful shutdown (caller reaps the processes) ----------
    for h in live.values_mut() {
        let _ = write_msg(&mut h.writer, &Msg::Shutdown);
    }
    Ok((epoch, done, round_losses))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn quick_cfg(workers: usize) -> ElasticConfig {
        let mut c = ElasticConfig::quadratic(workers, 6, 32);
        c.transport.ring_timeout_ms = 1000;
        c.transport.connect_timeout_ms = 5000;
        c.wall_timeout_ms = 60_000;
        c
    }

    #[test]
    fn thread_mode_three_workers_converge() {
        let out = run_elastic(&quick_cfg(3), &SpawnMode::Thread).unwrap();
        assert_eq!(out.epochs, 1, "no churn expected");
        assert_eq!(out.survivors, vec![0, 1, 2]);
        assert!(out.total_wire_bytes > 0);
        // Round-1 mean loss should beat the final loss decisively.
        let r1: Vec<f32> = out
            .round_losses
            .iter()
            .filter(|(_, r, _)| *r == 1)
            .map(|(_, _, l)| *l)
            .collect();
        assert!(!r1.is_empty());
        let r1_mean = r1.iter().sum::<f32>() / r1.len() as f32;
        assert!(
            out.final_loss < r1_mean * 0.5,
            "final {} vs round-1 {}",
            out.final_loss,
            r1_mean
        );
    }

    #[test]
    fn thread_mode_survives_injected_kill() {
        let mut cfg = quick_cfg(3);
        cfg.faults.enabled = true;
        cfg.faults.kill_rank = 1;
        cfg.faults.kill_round = 2;
        let out = run_elastic(&cfg, &SpawnMode::Thread).unwrap();
        assert_eq!(out.survivors, vec![0, 2]);
        assert!(out.epochs >= 2, "expected a re-formed ring, got {}", out.epochs);
        assert!(out.final_loss.is_finite());
        // Survivors must have completed every round.
        let max_round = out
            .round_losses
            .iter()
            .map(|(_, r, _)| *r)
            .max()
            .unwrap_or(0);
        assert_eq!(max_round as usize, cfg.rounds);
    }

    #[test]
    fn thread_mode_stage_fleet_converges() {
        // 2 clusters × 2 stage processes (threads here): per-stage rings
        // reduce independently, the 1F1B dataflow runs over TCP stage
        // links, and the assembled model converges.
        let mut cfg = ElasticConfig::synthetic_pipeline(2, 2, 5, 16);
        cfg.transport.ring_timeout_ms = 1000;
        cfg.transport.connect_timeout_ms = 5000;
        cfg.wall_timeout_ms = 60_000;
        let out = run_elastic(&cfg, &SpawnMode::Thread).unwrap();
        assert_eq!(out.epochs, 1, "no churn expected");
        assert_eq!(out.survivors, vec![0, 1]);
        assert!(out.total_wire_bytes > 0);
        assert_eq!(out.final_params.len(), 2 * 16);
        let r1: Vec<f32> = out
            .round_losses
            .iter()
            .filter(|(_, r, _)| *r == 1)
            .map(|(_, _, l)| *l)
            .collect();
        assert_eq!(r1.len(), 2, "one labels-bearing heartbeat per cluster");
        let r1_mean = r1.iter().sum::<f32>() / r1.len() as f32;
        assert!(
            out.final_loss < r1_mean * 0.5,
            "final {} vs round-1 {}",
            out.final_loss,
            r1_mean
        );
    }

    #[test]
    fn thread_mode_stage_fleet_survives_stage_kill() {
        // Kill ONE stage process (cluster 1, stage 1) at round 2: its
        // whole cluster drops out, the surviving clusters' per-stage
        // rings re-form, and the run completes with a finite final eval.
        let mut cfg = ElasticConfig::synthetic_pipeline(3, 2, 6, 16);
        cfg.transport.ring_timeout_ms = 1000;
        cfg.transport.connect_timeout_ms = 5000;
        cfg.wall_timeout_ms = 90_000;
        cfg.faults.enabled = true;
        cfg.faults.kill_rank = 1;
        cfg.faults.kill_stage = 1;
        cfg.faults.kill_round = 2;
        let out = run_elastic(&cfg, &SpawnMode::Thread).unwrap();
        assert_eq!(out.survivors, vec![0, 2], "cluster 1 must be gone entirely");
        assert!(
            out.epochs >= 2,
            "expected re-formed stage rings, got {}",
            out.epochs
        );
        assert!(out.final_loss.is_finite());
        // Survivors completed the full schedule after recovery.
        let max_round = out
            .round_losses
            .iter()
            .map(|(_, r, _)| *r)
            .max()
            .unwrap_or(0);
        assert_eq!(max_round as usize, cfg.rounds);
    }

    #[test]
    fn stage_fault_plan_targets_one_process() {
        let f = FaultConfig {
            enabled: true,
            kill_rank: 1,
            kill_stage: 2,
            kill_round: 3,
            ..FaultConfig::default()
        };
        assert!(stage_fault_plan_for(&f, 0, 2, false).is_none());
        assert!(stage_fault_plan_for(&f, 1, 0, false).is_none());
        let p = stage_fault_plan_for(&f, 1, 2, true).unwrap();
        assert_eq!(p.kill_round, 3);
        assert!(p.exit_on_kill);
    }

    #[test]
    fn params_digest_caps_large_vectors() {
        let small = vec![1.0f32; 100];
        assert_eq!(params_digest(&small), small);
        let big: Vec<f32> = (0..200_000).map(|i| i as f32).collect();
        let d = params_digest(&big);
        assert!(d.len() <= PARAMS_DIGEST_MAX, "len={}", d.len());
        assert_eq!(d[0], 0.0);
        // Deterministic: identical vectors digest identically on every
        // worker, so elementwise agreement checks stay valid.
        assert_eq!(d, params_digest(&big));
    }

    #[test]
    fn fault_plan_filtering_by_rank() {
        let f = FaultConfig {
            enabled: true,
            kill_rank: 2,
            kill_round: 3,
            ..FaultConfig::default()
        };
        assert!(fault_plan_for(&f, 0, false).is_none());
        let p = fault_plan_for(&f, 2, true).unwrap();
        assert_eq!(p.kill_round, 3);
        assert!(p.exit_on_kill);
    }
}
