//! Elastic multi-process coordinator: spawns one `dilocox worker` OS
//! process per cluster, runs DiLoCo-style outer rounds over the TCP ring,
//! and survives worker death mid-round by re-forming the ring with the
//! survivors (the membership epoch protocol documented in
//! [`crate::transport`]).
//!
//! Recovery model: any ring failure (peer death, stall past the socket
//! timeout) makes every survivor report
//! `RingBroken{applied_rounds, in_flight_round}` and park on its control
//! socket; the coordinator bumps the epoch, runs the 2PC prepare/commit
//! over the survivors, and the new ring opens with a consensus
//! `allreduce_mean` over θ_g plus an outer-momentum restart, so survivors
//! re-agree on the global parameters before training resumes at
//! `max(applied)+1`.  The pseudo-gradient mean rescales automatically: the
//! collective mean is over the *current* member count.
//!
//! # One-step-delay overlap on the fleet (drain-or-discard)
//!
//! With `overlap = true` every worker holds one δ-reduction in flight
//! across each round boundary (the §2.3 comm/compute overlap), so churn
//! catches reductions mid-flight.  Survivors report the round of their
//! held in-flight delta with `RingBroken`; the coordinator's `Prepare`
//! carries ONE decision per re-formed ring: **drain** — every member of
//! the proposed ring reported the *same* in-flight round t, so the new
//! ring finishes the reduction of δ^t (survivor-rescaled mean) and
//! applies its outer update exactly once — or **discard** — mixed or
//! absent in-flight rounds, so each survivor folds its delta back into
//! the engine's error feedback, where it re-enters the next round's δ.
//! Either way no gradient signal is silently dropped and none is applied
//! twice; the worker-side state machine lives in
//! [`crate::rounds::driver`].  In the stage fleet the decision is
//! per-stage-ring (stage rings can break one round apart under overlap).
//!
//! Workloads: the real-numerics PJRT trainer (needs an artifact bundle),
//! or a synthetic per-worker quadratic that exercises the full outer loop
//! (H local steps, pseudo-gradient ring mean, Nesterov outer step) with no
//! artifacts — what the churn integration tests and the zero-dependency
//! demo path run.
//!
//! # Stage-parallel fleet (`pp_stages > 1`)
//!
//! With pipeline parallelism the fleet is one OS process per **(cluster,
//! stage)**: `dp × pp` `dilocox worker --stage s` processes.  Inside a
//! cluster the 1F1B dataflow runs over TCP stage links
//! ([`crate::transport::tcp::TcpStageLink`]: Acts frames down, Grads
//! frames up); across clusters each stage joins its *own* per-stage DP
//! ring, so per-stage pseudo-gradients reduce independently — the §2.2
//! composition of PP with low-communication outer rounds, deployed.
//!
//! Membership is keyed by `(cluster, stage)` but committed at cluster
//! granularity: a cluster is a member only while **all** of its stage
//! processes are alive (a dead stage starves its siblings' dataflow, so
//! the whole cluster is dropped and its orphans are shut down).  The 2PC
//! prepare/commit sends each stage process a *tailored*
//! `StagePrepare` — its own stage ring in committed order plus its
//! downstream neighbor's link port — and every surviving stage ring
//! re-forms on the bumped epoch while the 1F1B dataflow stalls (blocked
//! on its timeouts) and resumes after the commit.  `resume_round` is
//! shared across stages; a stage ring that already completed the final
//! round before a late break simply finishes (bounded staleness, exactly
//! like the single-vector fleet's final-round churn).
//!
//! Invariant worth knowing when reading the recovery code: under
//! overlap, churn can catch the stages of one surviving cluster a
//! partial round apart (one stage's join succeeds while its sibling's
//! stalls), so the per-stage data streams cannot rely on lockstep across
//! churn.  Every epoch re-entry therefore calls
//! [`crate::pipeline::exec::StageCompute::reset_data`] with the resume
//! round: data-bearing
//! stages re-derive their stream as a pure function of (seed, worker,
//! round), and the first and last stage re-align no matter where the
//! break caught each of them.  The un-churned path never resets, so
//! threaded-vs-fleet bit parity is unaffected.
//!
//! # Where the protocol logic lives
//!
//! This module is deliberately a *shell*: every protocol decision —
//! when to ack a proposal, what a broken collective means, epoch
//! formation, membership pruning, the drain-or-discard ruling, grace
//! draining, fleet completion — is made by the pure state machines in
//! [`crate::protocol`] ([`CoordinatorSm`] on the coordinator side,
//! [`WorkerSm`] in each worker process).  The code here only performs
//! the machines' requested effects (socket I/O, TCP ring formation,
//! the round driver) and feeds the results back as events.  The same
//! machines run under the deterministic simulator in
//! [`crate::protocol::sim`], so every interleaving the simulator
//! verifies is an execution this shell could take.

use crate::comm::ring::build_ring;
use crate::compress::Method;
use crate::config::{ExperimentConfig, FaultConfig, TransportConfig};
use crate::coordinator::{RuntimeStagePipeline, RuntimeStepWork};
use crate::optim::{DualOptimizer, Nesterov};
use crate::pipeline::exec::{
    summarize_step_samples, ChunkedRing, MpscStageLink, PipelineWorkload,
    StageChunk, StageStepWork, StageTimeSummary, SyntheticPipeline,
};
use crate::pipeline::{validate_schedule, ScheduleKind};
use crate::protocol::{
    CoordIn, CoordOut, CoordinatorSm, EpochPlan, Key, WorkerIn, WorkerOut,
    WorkerPhase, WorkerSm,
};
use crate::rounds::driver::{
    EpochEnd, Recovery, RoundDriver, RoundTelemetry, RoundWork,
};
use crate::rounds::{RingLane, RoundEngine};
use crate::runtime::manifest::ParamEntry;
use crate::runtime::Manifest;
use crate::obs::{self, TraceEvent};
use crate::transport::faulty::{FaultPlan, FaultyRing};
use crate::transport::frame::{read_msg, write_msg, MemberInfo, Msg, ProbeLink};
use crate::transport::hier::{self, HierRing};
use crate::transport::probe::{self, LinkMatrix};
use crate::transport::tcp;
use crate::transport::{ReduceTopology, RingTransport};
use crate::util::rng::Pcg32;
use anyhow::{anyhow, Context, Result};
use std::collections::{BTreeMap, BTreeSet, VecDeque};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::process::{Command, Stdio};
use std::sync::mpsc;
use std::time::{Duration, Instant};

/// What each worker trains between syncs.
#[derive(Clone, Debug)]
pub enum Workload {
    /// Synthetic: worker w owns f_w(θ) = ½·mean((θ − c_w)²) with
    /// c_w = c_shared + 0.1·noise_w; the ring mean drives θ_g to the
    /// member-average target, so convergence is observable without any
    /// artifact bundle.
    Quadratic { dim: usize },
    /// Real numerics through the PJRT runtime (artifact bundle on disk).
    Runtime { artifacts_dir: String },
}

/// Everything a worker process/thread needs (mirrors the CLI flags of
/// `dilocox worker`).
#[derive(Clone, Debug)]
pub struct WorkerOpts {
    /// Coordinator control address, e.g. "127.0.0.1:41234".
    pub coord: String,
    pub rank: u32,
    pub rounds: usize,
    pub local_steps: usize,
    pub inner_lr: f32,
    pub weight_decay: f32,
    pub outer_lr: f32,
    pub outer_momentum: f32,
    pub seed: u64,
    pub workload: Workload,
    /// One-step-delay overlap of communication and local training (§2.3)
    /// — works across OS processes via the drain-or-discard recovery
    /// protocol (see the module docs).
    pub overlap: bool,
    pub ring_timeout_ms: u64,
    pub connect_timeout_ms: u64,
    /// Persistent comm-thread pool size (1 = spawn-per-round, the
    /// default; ≥ 2 parks reduce flights and TCP writers on the shared
    /// [`crate::comm::pool`]).
    pub comm_pool_size: usize,
    /// Reduce-pipeline depth for the wire compressor (1 = sequential).
    pub pipeline_depth: usize,
    /// Site tag for the hierarchical topology (`[transport] site` /
    /// `worker --site`); 0 is the default single site.
    pub site: u32,
    /// Which reduce topology this fleet runs (decides whether the worker
    /// answers link probes and how it forms its committed ring).
    pub reduce_topology: ReduceTopology,
    pub faults: Option<FaultPlan>,
}

/// Elastic run parameters (derived from [`ExperimentConfig`] or built
/// directly by tests).
#[derive(Clone, Debug)]
pub struct ElasticConfig {
    pub workers: usize,
    pub rounds: usize,
    pub local_steps: usize,
    pub inner_lr: f32,
    pub weight_decay: f32,
    pub outer_lr: f32,
    pub outer_momentum: f32,
    pub seed: u64,
    pub workload: Workload,
    /// One-step-delay overlap (§2.3) on the fleet: each worker's
    /// δ-reduction runs on a comm thread while it trains the next H
    /// local steps; churn recovers via drain-or-discard.
    pub overlap: bool,
    /// M — pipeline stages per cluster.  1 = the single-vector worker
    /// fleet; > 1 spawns one OS process per (cluster, stage) and routes
    /// the run through the stage-parallel supervisor.
    pub pp_stages: usize,
    /// U — in-flight microbatches per inner step (stage fleet only).
    pub microbatches: usize,
    /// Pipeline schedule name for the stage fleet (parsed by
    /// [`ScheduleKind::parse`]): gpipe | 1f1b | interleaved | zero-bubble.
    pub schedule: String,
    /// v — virtual stages (model chunks) per executor process.  > 1
    /// spawns `pp_stages / v` processes per cluster, each owning v
    /// chunks, and closes the stage-link chain into a ring.
    pub virtual_stages: usize,
    pub transport: TransportConfig,
    pub faults: FaultConfig,
    /// Reduce topology for the fleet's rings: [`ReduceTopology::Flat`]
    /// (historical arbitrary-order ring), `Reordered` (probe links, ship
    /// the max-bottleneck order), or `Hier` (per-site rings + a
    /// leaders-only cross-site ring).
    pub reduce_topology: ReduceTopology,
    /// Per-rank site tags for the hierarchical topology (rank indexes the
    /// vector; missing entries mean site 0, so empty = one site).
    pub sites: Vec<u32>,
    /// Probe payload size in f32 elements (reordered topology).
    pub probe_payload_elems: usize,
    /// Echo trials per probed link (minimum RTT wins).
    pub probe_repeats: usize,
    /// Hard wall-clock ceiling for the whole run (hang safety net).
    pub wall_timeout_ms: u64,
    /// Structured tracing ([`crate::obs`]): workers record spans and ship
    /// them over their control sockets; the coordinator merges them into
    /// [`ElasticOutcome::trace_events`].  Bit-for-bit inert — trace
    /// batches never touch the data plane or the payload byte meter.
    pub trace: bool,
    /// When non-empty, each traced process also tees its drained batches
    /// to `<trace_dir>/<role>.jsonl` (debugging aid; "" = off).
    pub trace_dir: String,
}

impl ElasticConfig {
    /// Synthetic-quadratic defaults tuned for fast, stable convergence.
    pub fn quadratic(workers: usize, rounds: usize, dim: usize) -> ElasticConfig {
        ElasticConfig {
            workers,
            rounds,
            local_steps: 8,
            inner_lr: 0.25,
            weight_decay: 0.0,
            outer_lr: 0.5,
            outer_momentum: 0.6,
            seed: 1234,
            workload: Workload::Quadratic { dim },
            overlap: false,
            pp_stages: 1,
            microbatches: 1,
            schedule: "1f1b".into(),
            virtual_stages: 1,
            transport: TransportConfig::default(),
            faults: FaultConfig::default(),
            reduce_topology: ReduceTopology::Flat,
            sites: Vec::new(),
            probe_payload_elems: 65_536,
            probe_repeats: 3,
            wall_timeout_ms: 120_000,
            trace: false,
            trace_dir: String::new(),
        }
    }

    /// Site of a rank under the configured tags (missing = site 0).
    pub fn site_of(&self, rank: u32) -> u32 {
        self.sites.get(rank as usize).copied().unwrap_or(0)
    }

    /// Executor-process count per cluster: `pp_stages / virtual_stages`
    /// (each process owns `virtual_stages` model chunks).
    pub fn stage_execs(&self) -> usize {
        let v = self.virtual_stages.max(1);
        if self.pp_stages % v == 0 {
            self.pp_stages / v
        } else {
            self.pp_stages
        }
    }

    /// Stage-fleet defaults over the artifact-free [`SyntheticPipeline`]
    /// (the depth-`stages` affine chain), tuned like the local executor
    /// tests.
    pub fn synthetic_pipeline(
        clusters: usize,
        stages: usize,
        rounds: usize,
        dim: usize,
    ) -> ElasticConfig {
        let mut c = ElasticConfig::quadratic(clusters, rounds, dim);
        c.pp_stages = stages;
        c.microbatches = 2;
        c.inner_lr = 0.05;
        c.outer_lr = 0.7;
        c.outer_momentum = 0.6;
        c
    }

    /// Lift an experiment config onto the elastic runner.  Runtime
    /// workloads pay per-process artifact load + H real training steps per
    /// round, so the hang safety net scales with the schedule instead of
    /// using the quick-test default.
    pub fn from_experiment(cfg: &ExperimentConfig, workload: Workload) -> ElasticConfig {
        let wall_timeout_ms = match &workload {
            Workload::Quadratic { .. } => 120_000,
            // Generous: artifact load/compile + T rounds of H steps.
            Workload::Runtime { .. } => {
                600_000 + 60_000 * cfg.train.outer_steps as u64
            }
        };
        ElasticConfig {
            workers: cfg.parallel.dp,
            rounds: cfg.train.outer_steps,
            local_steps: cfg.train.local_steps,
            inner_lr: cfg.train.inner_lr,
            weight_decay: cfg.train.weight_decay,
            outer_lr: cfg.train.outer_lr,
            outer_momentum: cfg.train.outer_momentum,
            seed: cfg.train.seed,
            workload,
            // No silent overlap→sync downgrade: the fleet honors the
            // config's §2.3 overlap flag (regression-tested via the wire
            // ledger — round-t compute overlaps round-(t−1) reduce).
            overlap: cfg.train.overlap,
            pp_stages: cfg.parallel.pp,
            microbatches: cfg.parallel.microbatches,
            schedule: cfg.parallel.schedule.clone(),
            virtual_stages: cfg.parallel.virtual_stages,
            transport: cfg.transport.clone(),
            faults: cfg.faults.clone(),
            // `validate()` already rejected unknown names; a locally
            // spawned fleet shares one machine, hence one site, so the
            // per-rank tags stay empty (every rank = site 0).
            reduce_topology: ReduceTopology::parse(&cfg.transport.reduce_topology)
                .unwrap_or_default(),
            sites: Vec::new(),
            probe_payload_elems: cfg.transport.probe_payload_elems,
            probe_repeats: cfg.transport.probe_repeats,
            wall_timeout_ms,
            trace: cfg.trace.enabled,
            trace_dir: cfg.trace.dir.clone(),
        }
    }
}

/// How the coordinator launches workers.
#[derive(Clone, Debug)]
pub enum SpawnMode {
    /// `std::process::Command` on the given `dilocox` binary — the real
    /// deployment shape: a crashed worker is an EOF, not a crashed run.
    Process { exe: String },
    /// In-process threads (unit tests; injected kills become error
    /// returns instead of `process::exit`).
    Thread,
}

#[derive(Debug)]
pub struct ElasticOutcome {
    pub rounds: usize,
    /// Final committed membership epoch (1 = no churn happened).
    pub epochs: u32,
    pub started: usize,
    pub survivors: Vec<u32>,
    /// Mean of the survivors' final eval losses.
    pub final_loss: f32,
    /// First survivor's parameter digest (full vector up to
    /// [`PARAMS_DIGEST_MAX`] elements, strided sample beyond — see
    /// [`params_digest`]).
    pub final_params: Vec<f32>,
    pub total_wire_bytes: u64,
    /// Heartbeat telemetry: (worker, round, loss).
    pub round_losses: Vec<(u32, u32, f32)>,
    /// Heartbeat wire ledger: (worker/cluster, round, payload bytes of
    /// the reduction completed during that round).  With overlap, every
    /// round-1 entry is 0 and round-2 entries are positive — the ledger
    /// evidence that round-t compute overlapped round-(t−1) reduce.
    pub round_wire: Vec<(u32, u32, u64)>,
    /// Measured per-stage compute times aggregated from heartbeats (the
    /// TCP-fleet counterpart of the threaded executor's
    /// `StageRoundReport::step_secs`; stage 0 for the single-vector
    /// fleet) — what `coordinate --report` ships to the DES calibration.
    pub stage_times: Vec<StageTimeSummary>,
    /// Committed per-epoch recovery decisions: (epoch, stage,
    /// drain_round); drain_round = 0 is a discard/no-op commit.  Tests
    /// assert the drain and discard branches from this ledger.
    pub recoveries: Vec<(u32, u32, u32)>,
    /// Probed directed links `(from, to, gbps, latency_ms)` (reordered
    /// topology only; empty otherwise) — what `coordinate --report`
    /// serializes so link measurements round-trip into the DES the way
    /// `--calibrate-from` does for stage times.
    pub links: Vec<(u32, u32, f64, f64)>,
    /// The merged fleet-wide timeline (empty unless
    /// [`ElasticConfig::trace`]): every span each worker shipped over its
    /// control socket plus the coordinator's own 2PC spans, self-keyed by
    /// (cluster, stage, epoch, round) — feed to [`crate::obs::report`].
    pub trace_events: Vec<TraceEvent>,
}

impl ElasticOutcome {
    /// Heartbeats aggregated per round: (round, mean loss, reporting
    /// workers).  Rounds with no heartbeat (e.g. lost to churn) are
    /// omitted.
    pub fn mean_loss_per_round(&self) -> Vec<(u32, f32, usize)> {
        let mut out = Vec::new();
        for r in 1..=self.rounds as u32 {
            let ls: Vec<f32> = self
                .round_losses
                .iter()
                .filter(|(_, round, _)| *round == r)
                .map(|(_, _, l)| *l)
                .collect();
            if !ls.is_empty() {
                out.push((r, ls.iter().sum::<f32>() / ls.len() as f32, ls.len()));
            }
        }
        out
    }
}

/// Cap on the parameter digest a worker ships in its `Done` report.  The
/// digest exists for the coordinator's cross-worker agreement check and
/// telemetry, not for checkpointing — shipping a 100M-param vector over
/// the control socket would be wasteful and anything over ~268M f32s
/// would blow the 1 GiB frame guard.  Every worker samples the same
/// strided indices, so elementwise comparison stays valid.
pub const PARAMS_DIGEST_MAX: usize = 65_536;

/// Full vector when small, deterministic strided sample when large.
pub fn params_digest(params: &[f32]) -> Vec<f32> {
    if params.len() <= PARAMS_DIGEST_MAX {
        return params.to_vec();
    }
    let stride = params.len().div_ceil(PARAMS_DIGEST_MAX);
    params.iter().step_by(stride).copied().collect()
}

/// Per-(cluster, stage) fault plan for the stage-parallel fleet: the
/// seeded kill targets exactly one stage *process*
/// (`kill_rank`/`kill_stage` at `kill_round`); delays and stragglers
/// follow the cluster rank like the single-vector fleet.
pub fn stage_fault_plan_for(
    faults: &FaultConfig,
    rank: u32,
    stage: u32,
    exit_on_kill: bool,
) -> Option<FaultPlan> {
    if !faults.enabled {
        return None;
    }
    let kill_here = rank as usize == faults.kill_rank
        && stage as usize == faults.kill_stage;
    let plan = FaultPlan {
        seed: faults.seed,
        delay_prob: faults.delay_prob,
        max_delay_ms: faults.delay_ms,
        kill_round: if kill_here { faults.kill_round } else { 0 },
        // The soft break applies to EVERY stage process of the cluster
        // at once, so the intra-cluster data streams stay aligned.
        break_round: if rank as usize == faults.break_rank {
            faults.break_round
        } else {
            0
        },
        straggler_ms: if rank as usize == faults.straggler_rank {
            faults.straggler_ms
        } else {
            0
        },
        exit_on_kill,
    };
    if plan.is_quiet() {
        None
    } else {
        Some(plan)
    }
}

/// Per-rank fault plan from the `[faults]` config section.
pub fn fault_plan_for(
    faults: &FaultConfig,
    rank: u32,
    exit_on_kill: bool,
) -> Option<FaultPlan> {
    if !faults.enabled {
        return None;
    }
    let plan = FaultPlan {
        seed: faults.seed,
        delay_prob: faults.delay_prob,
        max_delay_ms: faults.delay_ms,
        kill_round: if rank as usize == faults.kill_rank { faults.kill_round } else { 0 },
        break_round: if rank as usize == faults.break_rank {
            faults.break_round
        } else {
            0
        },
        straggler_ms: if rank as usize == faults.straggler_rank {
            faults.straggler_ms
        } else {
            0
        },
        exit_on_kill,
    };
    if plan.is_quiet() {
        None
    } else {
        Some(plan)
    }
}

// ---------------------------------------------------------------------------
// Worker side
// ---------------------------------------------------------------------------

/// What a worker trains between syncs: the driver's [`RoundWork`] view
/// plus eval + sizing (kept object-safe so the quadratic and PJRT paths
/// share one outer loop).  `as_work` is the manual upcast to the driver
/// trait (no reliance on dyn trait upcasting).
trait LocalTrainer: RoundWork {
    fn dim(&self) -> usize;
    fn eval(&mut self) -> Result<f32>;
    fn as_work(&mut self) -> &mut dyn RoundWork;
}

struct QuadraticTrainer {
    params: Vec<f32>,
    target: Vec<f32>,
    lr: f32,
}

impl QuadraticTrainer {
    fn new(dim: usize, rank: u32, seed: u64, lr: f32) -> QuadraticTrainer {
        // Shared optimum + small per-worker displacement: the member-mean
        // target is near the shared component, so the global loss falls
        // from ~0.5 to ~the displacement variance as θ_g converges.
        let mut shared = vec![0.0f32; dim];
        Pcg32::new(seed ^ 0x7a67, 0).fill_normal(&mut shared, 0.0, 1.0);
        let mut noise = vec![0.0f32; dim];
        Pcg32::new(seed ^ 0x7a67, 1 + rank as u64).fill_normal(&mut noise, 0.0, 1.0);
        let target: Vec<f32> =
            shared.iter().zip(&noise).map(|(s, n)| s + 0.1 * n).collect();
        QuadraticTrainer { params: vec![0.0; dim], target, lr }
    }

    fn loss(&self) -> f32 {
        let n = self.params.len() as f32;
        0.5 * self
            .params
            .iter()
            .zip(&self.target)
            .map(|(p, t)| (p - t) * (p - t))
            .sum::<f32>()
            / n
    }
}

impl RoundWork for QuadraticTrainer {
    fn params(&self) -> &[f32] {
        &self.params
    }

    fn set_params(&mut self, p: &[f32]) {
        self.params.copy_from_slice(p);
    }

    fn local_round(&mut self, h: usize) -> Result<(f32, f64)> {
        // Report the loss at entry (current θ_g) so the round curve is
        // directly comparable to the final eval.
        let loss = self.loss();
        let t0 = Instant::now();
        for _ in 0..h {
            for (p, t) in self.params.iter_mut().zip(&self.target) {
                let g = *p - *t;
                *p -= self.lr * g;
            }
        }
        Ok((loss, t0.elapsed().as_secs_f64() / h.max(1) as f64))
    }
}

impl LocalTrainer for QuadraticTrainer {
    fn dim(&self) -> usize {
        self.params.len()
    }

    fn eval(&mut self) -> Result<f32> {
        Ok(self.loss())
    }

    fn as_work(&mut self) -> &mut dyn RoundWork {
        self
    }
}

/// The real-numerics trainer is the coordinator's [`RuntimeStepWork`] —
/// ONE copy of the PJRT single-program inner loop, shared with the
/// threaded coordinator; the fleet only adds its eval/sizing view.
impl LocalTrainer for RuntimeStepWork {
    fn dim(&self) -> usize {
        self.params().len()
    }

    fn as_work(&mut self) -> &mut dyn RoundWork {
        self
    }

    fn eval(&mut self) -> Result<f32> {
        self.eval_loss()
    }
}

fn build_trainer(opts: &WorkerOpts) -> Result<Box<dyn LocalTrainer>> {
    Ok(match &opts.workload {
        Workload::Quadratic { dim } => Box::new(QuadraticTrainer::new(
            *dim,
            opts.rank,
            opts.seed,
            opts.inner_lr,
        )),
        Workload::Runtime { artifacts_dir } => Box::new(RuntimeStepWork::new(
            artifacts_dir,
            opts.rank as usize,
            opts.seed,
            opts.inner_lr,
            opts.weight_decay,
        )?),
    })
}

/// Flat parameter spec for the single-vector fleet wire (inert under
/// `Method::None`, the elastic fleet's uncompressed fp32 wire).
fn flat_spec(dim: usize) -> Vec<ParamEntry> {
    vec![ParamEntry { name: "flat".to_string(), shape: vec![dim], offset: 0 }]
}

/// The per-worker epoch-aware driver for the single-vector fleet:
/// overlap/sync selection, fault hooks, and drain-or-discard state all
/// live in [`RoundDriver`]; the fleet only supplies rings per epoch.
fn build_fleet_driver(opts: &WorkerOpts, theta0: Vec<f32>) -> RoundDriver {
    let dim = theta0.len();
    let engine = RoundEngine::new(
        theta0,
        1,
        Nesterov::new(dim, opts.outer_lr, opts.outer_momentum),
        opts.overlap,
        false,
    );
    crate::comm::pool::configure(opts.comm_pool_size);
    let mut lane =
        RingLane::unseeded(Method::None, opts.seed, flat_spec(dim), opts.overlap);
    lane.set_pipeline_depth(opts.pipeline_depth);
    lane.set_use_pool(opts.comm_pool_size >= 2);
    let mut driver = RoundDriver::new(engine, lane, opts.rounds, opts.local_steps);
    if let Some(plan) = &opts.faults {
        driver.set_break_round(plan.break_round);
    }
    driver
}

/// Stops a probe echo thread when the worker leaves scope, so thread-mode
/// fleets don't leak one echo loop per run.
struct EchoGuard(std::sync::Arc<std::sync::atomic::AtomicBool>);

impl Drop for EchoGuard {
    fn drop(&mut self) {
        self.0.store(true, std::sync::atomic::Ordering::Relaxed);
    }
}

/// Ship everything this process has recorded so far to the coordinator
/// as one [`Msg::TraceEvents`] control frame.  Best-effort: a worker
/// must never fail a round because a trace batch did.
fn ship_trace(coord: &mut TcpStream) {
    if !obs::enabled() {
        return;
    }
    let events = obs::drain();
    if !events.is_empty() {
        let _ = write_msg(coord, &Msg::TraceEvents { events });
    }
}

/// A committed epoch's formed-but-not-yet-begun wire rings.  The flat
/// and reordered topologies are one TCP ring (reordering only changes
/// the committed member *order*); hier is the intra-site ring plus, on
/// leaders, the cross-site ring over the members' hier listeners.
enum FormedRing {
    Flat(tcp::TcpRing),
    Hier {
        intra: tcp::TcpRing,
        cross: Option<tcp::TcpRing>,
        global_rank: usize,
        total: usize,
    },
}

/// Form this worker's ring(s) for a committed member list.  Intra-site
/// first under hier: every member of a site joins its intra ring before
/// its leader turns to the cross ring, so cross formation can never
/// starve a non-leader waiting on the same site.
fn form_committed_ring(
    opts: &WorkerOpts,
    members: &[MemberInfo],
    ring_listener: &TcpListener,
    hier_listener: &TcpListener,
    epoch: u32,
    connect_timeout: Duration,
    ring_timeout: Duration,
) -> Result<FormedRing> {
    if opts.reduce_topology != ReduceTopology::Hier {
        let endpoints: Vec<(u32, u16)> =
            members.iter().map(|m| (m.rank, m.ring_port)).collect();
        let r = tcp::form_ring(
            opts.rank,
            epoch,
            &endpoints,
            ring_listener,
            connect_timeout,
            ring_timeout,
        )?;
        return Ok(FormedRing::Flat(r));
    }
    let plan = hier::site_plan(members, opts.rank)?;
    let intra = tcp::form_ring(
        opts.rank,
        epoch,
        &plan.intra,
        ring_listener,
        connect_timeout,
        ring_timeout,
    )?;
    let cross = match &plan.cross {
        Some(leaders) => Some(tcp::form_ring(
            opts.rank,
            epoch,
            leaders,
            hier_listener,
            connect_timeout,
            ring_timeout,
        )?),
        None => None,
    };
    Ok(FormedRing::Hier { intra, cross, global_rank: plan.global_rank, total: plan.total })
}

/// Turn formed wire rings into the transport the driver runs, applying
/// the fault plan.  Under hier the faults wrap the *sub*-rings — never
/// the composed [`HierRing`]: [`FaultyRing`] does not override the
/// composed `allreduce_sum`, so an outermost wrapper would silently run
/// the flat algorithm over hier's raw hops.  The injected kill fires in
/// the intra ring's `begin_round` (`HierRing` enters intra before
/// cross), which covers leader and non-leader deaths alike.
fn assemble_ring(
    formed: FormedRing,
    faults: &Option<FaultPlan>,
) -> Result<Box<dyn RingTransport>> {
    Ok(match formed {
        FormedRing::Flat(raw) => match faults {
            Some(fp) => Box::new(FaultyRing::new(raw, fp.clone())),
            None => Box::new(raw),
        },
        FormedRing::Hier { intra, cross, global_rank, total } => {
            let intra: Box<dyn RingTransport> = match faults {
                Some(fp) => Box::new(FaultyRing::new(intra, fp.clone())),
                None => Box::new(intra),
            };
            let cross = cross.map(|c| Box::new(c) as Box<dyn RingTransport>);
            Box::new(HierRing::new(intra, cross, global_rank, total)?)
        }
    })
}

/// Worker entry point (the `dilocox worker` subcommand body).
///
/// All protocol sequencing — when to ack, when to form the ring, what a
/// broken collective means — lives in the pure [`WorkerSm`]; this loop
/// only performs the machine's requested effects (socket writes, TCP
/// ring formation, the epoch-aware round driver) and feeds the results
/// back as events.  The machine only blocks on the coordinator while in
/// a waiting phase, so the loop reads a control frame exactly when the
/// effect queue runs dry.
pub fn run_worker(opts: &WorkerOpts) -> Result<()> {
    obs::set_scope(opts.rank, 0);
    let addr: SocketAddr = opts
        .coord
        .parse()
        .map_err(|_| anyhow!("bad coordinator address '{}'", opts.coord))?;
    let connect_timeout = Duration::from_millis(opts.connect_timeout_ms);
    let ring_timeout = Duration::from_millis(opts.ring_timeout_ms);
    let mut coord = TcpStream::connect_timeout(&addr, connect_timeout)
        .with_context(|| format!("dialing coordinator {addr}"))?;
    coord.set_nodelay(true).ok();
    coord.set_read_timeout(Some(Duration::from_secs(120))).ok();
    let listener = TcpListener::bind("127.0.0.1:0").context("binding ring listener")?;
    let ring_port = listener.local_addr()?.port();
    // Second listener for the leaders-only cross-site ring.  Bound
    // unconditionally: it is one idle socket, and keeping the Hello shape
    // topology-independent lets the coordinator flip topologies without
    // re-registering the fleet.
    let hier_listener =
        TcpListener::bind("127.0.0.1:0").context("binding hier listener")?;
    let hier_port = hier_listener.local_addr()?.port();
    // The probe echo responder only exists under the reordered topology
    // (port 0 in the Hello = no echo service).
    let (probe_port, _probe_stop) =
        if opts.reduce_topology == ReduceTopology::Reordered {
            let l = TcpListener::bind("127.0.0.1:0")
                .context("binding probe echo listener")?;
            let port = l.local_addr()?.port();
            (port, Some(EchoGuard(probe::spawn_echo_server(l))))
        } else {
            (0, None)
        };
    write_msg(
        &mut coord,
        &Msg::Hello {
            rank: opts.rank,
            ring_port,
            hier_port,
            probe_port,
            site: opts.site,
        },
    )?;

    let mut trainer = build_trainer(opts)?;
    // Outer rounds run through the shared epoch-aware driver: θ_g moves
    // only by outer updates, a failed collective leaves it untouched, and
    // any in-flight overlap delta survives churn for drain-or-discard.
    let mut driver = build_fleet_driver(opts, trainer.params().to_vec());

    let mut sm = WorkerSm::new(opts.rounds as u32, false);
    // Wire-level ring endpoints of acked proposals, keyed by epoch — the
    // machine's plans carry only member ids.
    let mut staged: BTreeMap<u32, Vec<MemberInfo>> = BTreeMap::new();
    let mut formed: Option<FormedRing> = None;
    let mut effects: VecDeque<WorkerOut> = VecDeque::new();
    loop {
        let Some(effect) = effects.pop_front() else {
            // No pending effects: the machine is blocked on the
            // coordinator, so read one control frame and translate it.
            let input = if sm.phase() == WorkerPhase::AwaitShutdown {
                // Done reported: park until Shutdown (or coordinator EOF).
                let _ = read_msg(&mut coord);
                WorkerIn::Shutdown
            } else {
                let _s = obs::span("elastic", "epoch.wait");
                match read_msg(&mut coord) {
                    Ok(Msg::Prepare { epoch, resume_round, members, drain_round }) => {
                        let ids = members.iter().map(|m| m.rank).collect();
                        staged.insert(epoch, members);
                        WorkerIn::Prepare(EpochPlan {
                            epoch,
                            resume_round,
                            members: ids,
                            drain_round,
                        })
                    }
                    Ok(Msg::Commit { epoch }) => WorkerIn::Commit { epoch },
                    Ok(Msg::Shutdown) => WorkerIn::Shutdown,
                    Ok(Msg::ProbeRequest { payload_elems, repeats, peers }) => {
                        // Answered inline: the machine is parked waiting
                        // for a Prepare, so the probe never races an
                        // epoch.  This arm must precede the stale-frame
                        // catch-all or the coordinator would wait out its
                        // report forever.
                        let links = probe::probe_peers(
                            &peers,
                            payload_elems as usize,
                            repeats as usize,
                            ring_timeout,
                        )
                        .into_iter()
                        .map(|(to, gbps, latency_ms)| ProbeLink {
                            to,
                            gbps,
                            latency_ms,
                        })
                        .collect();
                        write_msg(&mut coord, &Msg::ProbeReport { links })?;
                        continue;
                    }
                    Ok(_) => continue, // stale frame — ignore
                    Err(e) => {
                        return Err(anyhow!(
                            "control channel lost waiting for commit: {e:#}"
                        ))
                    }
                }
            };
            effects.extend(sm.handle(input));
            continue;
        };
        match effect {
            WorkerOut::SendAck { epoch } => {
                write_msg(&mut coord, &Msg::PrepareAck { epoch })?;
            }
            WorkerOut::SendBroken { epoch } => {
                // Best-effort: if the control channel is gone too, the
                // coordinator's failure detector covers it.
                let _ = write_msg(
                    &mut coord,
                    &Msg::RingBroken {
                        epoch,
                        applied_rounds: driver.applied() as u32,
                        in_flight_round: driver.in_flight_round(),
                    },
                );
            }
            WorkerOut::FormRing { plan, .. } => {
                obs::set_epoch(plan.epoch);
                // The commit consumed every proposal below this epoch.
                staged.retain(|&e, _| e >= plan.epoch);
                let members = staged.get(&plan.epoch).cloned().unwrap_or_default();
                let ok = {
                    let _s = obs::span("elastic", "ring.form");
                    match form_committed_ring(
                        opts,
                        &members,
                        &listener,
                        &hier_listener,
                        plan.epoch,
                        connect_timeout,
                        ring_timeout,
                    ) {
                        Ok(r) => {
                            formed = Some(r);
                            true
                        }
                        Err(_) => false,
                    }
                };
                effects.extend(sm.handle(WorkerIn::FormResult { ok }));
            }
            WorkerOut::BeginEpoch { plan, .. } => {
                let raw = formed.take().expect("BeginEpoch without a formed ring");
                // Consensus resync + the committed drain-or-discard
                // decision; a failure here is churn on the fresh ring
                // (state preserved).
                let ok = match assemble_ring(raw, &opts.faults) {
                    Ok(ring) => driver.begin_epoch(ring, plan.recovery()).is_ok(),
                    Err(_) => false,
                };
                effects.extend(sm.handle(WorkerIn::BeginResult { ok }));
            }
            WorkerOut::RunRounds { start } => {
                let end = {
                    let coord = &mut coord;
                    driver.run_rounds(
                        start as usize,
                        trainer.as_work(),
                        &mut |t: RoundTelemetry| {
                            let _ = write_msg(
                                coord,
                                &Msg::Heartbeat {
                                    round: t.round as u32,
                                    loss: t.loss,
                                    step_secs: t.step_secs as f32,
                                    wire_bytes: t.wire_bytes,
                                },
                            );
                            // Piggyback this round's trace batch on the
                            // heartbeat (same control socket, so ordering
                            // is preserved).
                            ship_trace(coord);
                        },
                    )?
                };
                let completed = matches!(end, EpochEnd::Completed);
                effects.extend(sm.handle(WorkerIn::RoundsEnd { completed }));
            }
            WorkerOut::Finish => {
                // Trailing in-flight reduction: a peer dying during the
                // final collective is churn like any other — the next
                // epoch's drain decision finishes the held delta.
                let ok = driver.finish(trainer.as_work()).is_ok();
                effects.extend(sm.handle(WorkerIn::FinishResult { ok }));
            }
            WorkerOut::SendDone => {
                let final_loss = trainer.eval()?;
                // Final trace batch (finish()'s drained reduction,
                // recovery spans) BEFORE Done: the coordinator stops
                // reading after the last Done.
                ship_trace(&mut coord);
                write_msg(
                    &mut coord,
                    &Msg::Done {
                        rounds: driver.applied() as u32,
                        wire_bytes: driver.wire_total(),
                        final_loss,
                        params: params_digest(driver.engine().theta()),
                    },
                )?;
            }
            WorkerOut::Exit { error: Some(msg) } => return Err(anyhow!(msg)),
            WorkerOut::Exit { error: None } => return Ok(()),
        }
    }
}

/// In-process reference for the single-vector fleet: the same trainers
/// and the same epoch-aware driver over the **local mpsc ring** — what
/// the loopback-TCP fleet must match bit-for-bit (the TCP ring
/// collective is itself bit-identical to the local ring, and both
/// deployments execute the identical driver sequence, including the
/// epoch-1 consensus resync).  Returns (final params, mean final loss,
/// total reduction payload bytes).
pub fn run_local_reference(cfg: &ElasticConfig) -> Result<(Vec<f32>, f32, u64)> {
    if cfg.pp_stages > 1 {
        return Err(anyhow!(
            "the stage-parallel reference is the threaded executor \
             (pipeline::exec::run_pipeline)"
        ));
    }
    if cfg.workers == 0 {
        return Err(anyhow!("need at least one worker"));
    }
    // The reordered topology intentionally has no bit-for-bit reference:
    // the probed order is a property of the live wire, and float
    // summation is not associative under reordering.  Flat and hier both
    // have one — their schedules are fixed by rank resp. (site, rank).
    let members: Vec<Box<dyn RingTransport>> = match cfg.reduce_topology {
        ReduceTopology::Hier => {
            let sites: Vec<u32> =
                (0..cfg.workers as u32).map(|r| cfg.site_of(r)).collect();
            hier::build_hier_rings(&sites)
                .into_iter()
                .map(|h| Box::new(h) as Box<dyn RingTransport>)
                .collect()
        }
        _ => build_ring(cfg.workers)
            .into_iter()
            .map(|m| Box::new(m) as Box<dyn RingTransport>)
            .collect(),
    };
    let outs: Vec<Result<(Vec<f32>, f32, u64)>> = std::thread::scope(|scope| {
        let handles: Vec<_> = members
            .into_iter()
            .enumerate()
            .map(|(rank, member)| {
                let mut opts =
                    worker_opts_for(cfg, rank as u32, "", &SpawnMode::Thread);
                // The reference is the clean-room baseline: no faults.
                opts.faults = None;
                scope.spawn(move || -> Result<(Vec<f32>, f32, u64)> {
                    let mut trainer = build_trainer(&opts)?;
                    let mut driver =
                        build_fleet_driver(&opts, trainer.params().to_vec());
                    driver.begin_epoch(member, Recovery::Discard)?;
                    match driver.run_rounds(1, trainer.as_work(), &mut |_| {})? {
                        EpochEnd::Completed => {}
                        EpochEnd::Broken(e) => {
                            return Err(
                                e.context("local reference ring broke")
                            )
                        }
                    }
                    driver.finish(trainer.as_work())?;
                    let loss = trainer.eval()?;
                    Ok((
                        driver.engine().theta().to_vec(),
                        loss,
                        driver.wire_total(),
                    ))
                })
            })
            .collect();
        handles.into_iter().map(|h| h.join().unwrap()).collect()
    });
    let mut finals = Vec::new();
    for o in outs {
        finals.push(o?);
    }
    let p0 = finals[0].0.clone();
    for (pi, _, _) in &finals[1..] {
        if p0 != *pi {
            return Err(anyhow!("reference workers diverged"));
        }
    }
    let losses: Vec<f32> = finals.iter().map(|(_, l, _)| *l).collect();
    let mean_loss = losses.iter().sum::<f32>() / losses.len() as f32;
    let wire = finals.iter().map(|(_, _, w)| w).sum();
    Ok((params_digest(&p0), mean_loss, wire))
}

// ---------------------------------------------------------------------------
// Stage worker side (pp_stages > 1: one OS process per (cluster, stage))
// ---------------------------------------------------------------------------

/// Everything one stage process needs (mirrors `dilocox worker --stage`).
#[derive(Clone, Debug)]
pub struct StageWorkerOpts {
    /// Cluster-level options: `rank` is the cluster id; `workload`
    /// selects the pipeline ([`Workload::Quadratic`] =
    /// [`SyntheticPipeline`], [`Workload::Runtime`] = the staged PJRT
    /// bundle).
    pub base: WorkerOpts,
    pub stage: u32,
    /// Total model stages K (the workload's partition count); this
    /// process executes `virtual_stages` chunks of it, so the fleet has
    /// `K / virtual_stages` executor processes per cluster.
    pub stages: u32,
    /// U — in-flight microbatches per inner step of the schedule.
    pub micros: usize,
    /// Schedule name (parsed by [`ScheduleKind::parse`]).
    pub schedule: String,
    /// v — model chunks owned by this executor process.
    pub virtual_stages: usize,
    /// Deterministic listener layout base (0 = ephemeral OS ports); see
    /// [`crate::transport::tcp::stage_ports`].
    pub listen_base: u16,
}

/// Build the [`PipelineWorkload`] a stage fleet trains (shared by the
/// stage workers and the coordinator's final assembled eval).
fn build_stage_pipeline(
    workload: &Workload,
    stages: usize,
    micros: usize,
    seed: u64,
) -> Result<Box<dyn PipelineWorkload>> {
    match workload {
        Workload::Quadratic { dim } => Ok(Box::new(SyntheticPipeline::new(
            stages,
            micros.max(1),
            *dim,
            seed,
        ))),
        Workload::Runtime { artifacts_dir } => {
            let man = Manifest::load(artifacts_dir)
                .with_context(|| format!("loading manifest from {artifacts_dir}"))?;
            Ok(Box::new(RuntimeStagePipeline::new(
                artifacts_dir,
                &man,
                micros.max(1),
                seed,
            )?))
        }
    }
}

/// Stage worker entry point (the `dilocox worker --stage` subcommand
/// body): one pipeline stage of one DP cluster as its own OS process.
///
/// Per committed epoch it (re)forms its per-stage DP ring across
/// clusters, its intra-cluster stage-link chain
/// ([`crate::transport::tcp::TcpStageLink`]), then enters the SAME
/// epoch-aware driver ([`RoundDriver`]) and inner-round work
/// ([`StageStepWork`]) as the local threaded executor — the two
/// deployments are bit-for-bit comparable, in sync and overlap mode
/// alike.  Any wire failure mid-round (a dead neighbor's socket timing
/// out, a broken ring collective, a reduction caught in flight) reports
/// `RingBroken` with the held in-flight round and parks for the next
/// epoch's drain-or-discard decision.
pub fn run_stage_worker(opts: &StageWorkerOpts) -> Result<()> {
    obs::set_scope(opts.base.rank, opts.stage);
    let w = &opts.base;
    let stages = opts.stages as usize;
    if stages < 2 {
        return Err(anyhow!(
            "stage worker needs --stages >= 2 (the single-stage fleet runs \
             the plain worker)"
        ));
    }
    let v = opts.virtual_stages.max(1);
    if stages % v != 0 {
        return Err(anyhow!(
            "{stages} model stages not divisible by {v} virtual stages"
        ));
    }
    let execs = stages / v;
    if execs < 2 {
        return Err(anyhow!(
            "virtual stages {v} leave fewer than 2 executor processes \
             ({stages} model stages)"
        ));
    }
    let kind = ScheduleKind::parse(&opts.schedule).map_err(|e| anyhow!(e))?;
    if opts.stage as usize >= execs {
        return Err(anyhow!(
            "stage {} out of range for {execs} executor processes",
            opts.stage
        ));
    }
    let addr: SocketAddr = w
        .coord
        .parse()
        .map_err(|_| anyhow!("bad coordinator address '{}'", w.coord))?;
    let connect_timeout = Duration::from_millis(w.connect_timeout_ms);
    let ring_timeout = Duration::from_millis(w.ring_timeout_ms);
    let mut coord = TcpStream::connect_timeout(&addr, connect_timeout)
        .with_context(|| format!("dialing coordinator {addr}"))?;
    coord.set_nodelay(true).ok();
    coord.set_read_timeout(Some(Duration::from_secs(120))).ok();
    let (ring_listener, link_listener) = if opts.listen_base > 0 {
        // Validate the full deterministic layout before binding: a base
        // close to 65535 would otherwise wrap in the u16 port arithmetic
        // and bind some unrelated (possibly privileged) port.
        let top = opts.listen_base as u64
            + 2 * (w.rank as u64 * execs as u64 + opts.stage as u64)
            + 1;
        if top > 65535 {
            return Err(anyhow!(
                "--listen-base {} + 2*(rank*execs + stage) + 1 = {top} \
                 overflows the port space (rank {}, stage {}, {execs} \
                 executors); lower the base",
                opts.listen_base,
                w.rank,
                opts.stage
            ));
        }
        let (rp, lp) = tcp::stage_ports(
            opts.listen_base,
            w.rank as usize,
            opts.stage as usize,
            execs,
        );
        (
            TcpListener::bind(("127.0.0.1", rp))
                .with_context(|| format!("binding ring listener on port {rp}"))?,
            TcpListener::bind(("127.0.0.1", lp))
                .with_context(|| format!("binding link listener on port {lp}"))?,
        )
    } else {
        (
            TcpListener::bind("127.0.0.1:0").context("binding ring listener")?,
            TcpListener::bind("127.0.0.1:0").context("binding link listener")?,
        )
    };
    let ring_port = ring_listener.local_addr()?.port();
    let link_port = link_listener.local_addr()?.port();
    write_msg(
        &mut coord,
        &Msg::StageHello { cluster: w.rank, stage: opts.stage, ring_port, link_port },
    )?;

    let workload = build_stage_pipeline(&w.workload, stages, opts.micros, w.seed)?;
    if workload.stages() != stages {
        return Err(anyhow!(
            "workload exports {} stages but the fleet runs {stages}",
            workload.stages()
        ));
    }
    let micros = workload.micros();
    let streams = kind
        .streams(execs, v, micros)
        .map_err(|e| anyhow!("schedule: {e}"))?;
    validate_schedule(&streams, micros)
        .map_err(|e| anyhow!("invalid {} schedule: {e}", kind.name()))?;
    let stream = streams[opts.stage as usize].clone();

    // This executor's chunk computes (model stage c·S + s), concatenated
    // parameter vector, and wire spec — identical to the threaded
    // executor's per-executor layout.
    let mut chunks: Vec<StageChunk> = Vec::with_capacity(v);
    let mut params: Vec<f32> = Vec::new();
    let mut spec: Vec<ParamEntry> = Vec::new();
    for c in 0..v {
        let compute =
            workload.make_stage(w.rank as usize, c * execs + opts.stage as usize)?;
        let numel = compute.numel();
        let init = compute.init()?;
        if init.len() != numel {
            return Err(anyhow!("init len {} != numel {numel}", init.len()));
        }
        let offset = params.len();
        for mut e in compute.param_spec() {
            e.offset += offset;
            spec.push(e);
        }
        params.extend_from_slice(&init);
        chunks.push(StageChunk { compute, offset, numel });
    }
    let chunk_sizes: Vec<usize> = chunks.iter().map(|c| c.numel).collect();
    let n = params.len();
    // §2.2: this process holds only this stage's optimizer pair.
    let DualOptimizer { inner, outer } = DualOptimizer::new(
        n,
        w.inner_lr,
        w.weight_decay,
        w.outer_lr,
        w.outer_momentum,
    );
    // The identical engine/lane/driver stack as the threaded stage
    // executor — including one-step-delay overlap: the drain-or-discard
    // protocol handles reductions caught in flight by churn.
    let engine = RoundEngine::new(params.clone(), 1, outer, w.overlap, false);
    // Same per-stage compressor seed derivation as the local executor
    // (inert under Method::None, load-bearing once the fleet compresses).
    let stage_seed =
        w.seed ^ (opts.stage as u64).wrapping_mul(0x9e3779b97f4a7c15);
    crate::comm::pool::configure(w.comm_pool_size);
    let mut lane = RingLane::unseeded(Method::None, stage_seed, spec, w.overlap);
    lane.set_pipeline_depth(w.pipeline_depth);
    lane.set_use_pool(w.comm_pool_size >= 2);
    let mut work = StageStepWork {
        chunks,
        stream,
        link: Box::new(MpscStageLink::default()),
        params,
        inner,
        micros,
        stages: execs,
    };
    let mut driver = RoundDriver::new(engine, lane, w.rounds, w.local_steps);
    if let Some(plan) = &w.faults {
        driver.set_break_round(plan.break_round);
    }

    // Protocol sequencing lives in the pure machine; `clean_early_shutdown`
    // because a stage process whose cluster was pruned exits Ok.
    let mut sm = WorkerSm::new(w.rounds as u32, true);
    // Wire detail per acked proposal epoch: (ring endpoints, downstream
    // link port) — the machine's plans carry only member ids.
    let mut staged: BTreeMap<u32, (Vec<(u32, u16)>, u16)> = BTreeMap::new();
    let mut formed: Option<tcp::TcpRing> = None;
    let mut effects: VecDeque<WorkerOut> = VecDeque::new();
    loop {
        let Some(effect) = effects.pop_front() else {
            let input = if sm.phase() == WorkerPhase::AwaitShutdown {
                // Done reported: park until Shutdown (or coordinator EOF).
                let _ = read_msg(&mut coord);
                WorkerIn::Shutdown
            } else {
                let _s = obs::span("elastic", "epoch.wait");
                match read_msg(&mut coord) {
                    Ok(Msg::StagePrepare {
                        epoch,
                        resume_round,
                        ring_members,
                        link_down_port,
                        drain_round,
                    }) => {
                        let ids = ring_members.iter().map(|&(c, _)| c).collect();
                        staged.insert(epoch, (ring_members, link_down_port));
                        WorkerIn::Prepare(EpochPlan {
                            epoch,
                            resume_round,
                            members: ids,
                            drain_round,
                        })
                    }
                    Ok(Msg::Commit { epoch }) => WorkerIn::Commit { epoch },
                    Ok(Msg::Shutdown) => WorkerIn::Shutdown,
                    Ok(_) => continue, // stale frame — ignore
                    Err(e) => {
                        return Err(anyhow!(
                            "control channel lost waiting for stage commit: {e:#}"
                        ))
                    }
                }
            };
            effects.extend(sm.handle(input));
            continue;
        };
        match effect {
            WorkerOut::SendAck { epoch } => {
                write_msg(&mut coord, &Msg::PrepareAck { epoch })?;
            }
            WorkerOut::SendBroken { epoch } => {
                let _ = write_msg(
                    &mut coord,
                    &Msg::RingBroken {
                        epoch,
                        applied_rounds: driver.applied() as u32,
                        in_flight_round: driver.in_flight_round(),
                    },
                );
            }
            WorkerOut::FormRing { plan, finishing } => {
                obs::set_epoch(plan.epoch);
                staged.retain(|&e, _| e >= plan.epoch);
                let (ring_members, down_port) =
                    staged.get(&plan.epoch).cloned().unwrap_or_default();
                let ok = {
                    let _s = obs::span("elastic", "ring.form");
                    match tcp::form_ring(
                        w.rank,
                        plan.epoch,
                        &ring_members,
                        &ring_listener,
                        connect_timeout,
                        ring_timeout,
                    ) {
                        Ok(r) => {
                            // Dataflow links (skipped in a finishing
                            // epoch: no rounds left to run — a pending
                            // drain needs only the ring — and neighbors
                            // that already completed form no links).
                            if finishing {
                                formed = Some(r);
                                work.link = Box::new(MpscStageLink::default());
                                true
                            } else {
                                match tcp::form_stage_links(
                                    opts.stage,
                                    plan.epoch,
                                    &link_listener,
                                    if down_port == 0 { None } else { Some(down_port) },
                                    if v > 1 { Some(execs as u32) } else { None },
                                    connect_timeout,
                                    ring_timeout,
                                ) {
                                    Ok(l) => {
                                        formed = Some(r);
                                        work.link = Box::new(l);
                                        true
                                    }
                                    Err(_) => false,
                                }
                            }
                        }
                        Err(_) => false,
                    }
                };
                effects.extend(sm.handle(WorkerIn::FormResult { ok }));
            }
            WorkerOut::BeginEpoch { plan, .. } => {
                let raw = formed.take().expect("BeginEpoch without a formed ring");
                let ring: Box<dyn RingTransport> = match &w.faults {
                    Some(fp) => Box::new(FaultyRing::new(raw, fp.clone())),
                    None => Box::new(raw),
                };
                // With virtual stages the concatenated reduction splits
                // at chunk boundaries over this single TCP ring — the
                // identical slice lengths / ranks / hop order as the
                // threaded executor's per-chunk rings, so the two
                // deployments stay bit-for-bit comparable.
                let ring: Box<dyn RingTransport> = if v > 1 {
                    Box::new(ChunkedRing::new(vec![ring], chunk_sizes.clone())?)
                } else {
                    ring
                };
                // Consensus resync on this stage's ring + this ring's
                // committed drain-or-discard decision.
                let ok = if driver.begin_epoch(ring, plan.recovery()).is_ok() {
                    // Re-align the data stream to the resume round after
                    // churn (overlap can catch sibling stages a partial
                    // round apart; the un-churned path never resets,
                    // preserving threaded-vs-fleet bit parity).
                    if plan.epoch > 1 {
                        for c in work.chunks.iter_mut() {
                            c.compute.reset_data(plan.resume_round as usize)?;
                        }
                    }
                    true
                } else {
                    false
                };
                effects.extend(sm.handle(WorkerIn::BeginResult { ok }));
            }
            WorkerOut::RunRounds { start } => {
                let end = {
                    let coord = &mut coord;
                    driver.run_rounds(
                        start as usize,
                        &mut work,
                        &mut |t: RoundTelemetry| {
                            // Loss telemetry is real only on the
                            // label-bearing stage (NaN elsewhere);
                            // step_secs is per-stage.
                            let _ = write_msg(
                                coord,
                                &Msg::Heartbeat {
                                    round: t.round as u32,
                                    loss: t.loss,
                                    step_secs: t.step_secs as f32,
                                    wire_bytes: t.wire_bytes,
                                },
                            );
                            ship_trace(coord);
                        },
                    )?
                };
                let completed = matches!(end, EpochEnd::Completed);
                effects.extend(sm.handle(WorkerIn::RoundsEnd { completed }));
            }
            WorkerOut::Finish => {
                let ok = driver.finish(&mut work).is_ok();
                effects.extend(sm.handle(WorkerIn::FinishResult { ok }));
            }
            WorkerOut::SendDone => {
                ship_trace(&mut coord);
                write_msg(
                    &mut coord,
                    &Msg::Done {
                        rounds: driver.applied() as u32,
                        wire_bytes: driver.wire_total(),
                        // The final eval needs the *assembled* model; the
                        // coordinator computes it from the per-stage
                        // digests.
                        final_loss: f32::NAN,
                        params: params_digest(driver.engine().theta()),
                    },
                )?;
            }
            WorkerOut::Exit { error: Some(msg) } => return Err(anyhow!(msg)),
            WorkerOut::Exit { error: None } => return Ok(()),
        }
    }
}

// ---------------------------------------------------------------------------
// Coordinator side
// ---------------------------------------------------------------------------

/// One member's control handle: the write half of its control socket
/// plus the listener ports it announced in its Hello.  Both fleet
/// shapes share it — the single-vector fleet has no stage links, so its
/// `link_port` is 0 and unused.
struct CtrlHandle {
    writer: TcpStream,
    ring_port: u16,
    link_port: u16,
    /// Cross-site ring listener (hier topology; 0 for stage workers).
    hier_port: u16,
    /// Probe echo listener (reordered topology; 0 = no echo service).
    probe_port: u16,
    /// Announced site tag (0 for stage workers and untagged fleets).
    site: u32,
}

/// Control-plane event, keyed by protocol [`Key`] — `(rank, 0)` in the
/// single fleet, `(cluster, stage)` in the stage fleet.
enum Event {
    Msg(Key, Msg),
    Closed(Key),
}

/// One reader thread per control socket feeding the supervisor's queue.
fn spawn_reader(key: Key, mut rs: TcpStream, tx: mpsc::Sender<Event>) {
    std::thread::spawn(move || loop {
        match read_msg(&mut rs) {
            Ok(m) => {
                if tx.send(Event::Msg(key, m)).is_err() {
                    break;
                }
            }
            Err(_) => {
                let _ = tx.send(Event::Closed(key));
                break;
            }
        }
    });
}

struct DoneReport {
    wire_bytes: u64,
    final_loss: f32,
    params: Vec<f32>,
}

/// Fleet telemetry accumulated by the supervisors from heartbeats and
/// recovery commits (maps onto [`ElasticOutcome`]).
#[derive(Default)]
struct Telemetry {
    /// (worker/cluster, round, loss) — NaN losses filtered at ingest.
    round_losses: Vec<(u32, u32, f32)>,
    /// (worker/cluster, round, reduction payload bytes).
    round_wire: Vec<(u32, u32, u64)>,
    /// (stage, measured compute secs per inner step) samples.
    step_samples: Vec<(u32, f64)>,
    /// Committed recovery decisions: (epoch, stage, drain_round).
    recoveries: Vec<(u32, u32, u32)>,
    /// Probed directed links (from, to, gbps, latency_ms) — filled by
    /// the pre-epoch probe phase under the reordered topology.
    links: Vec<(u32, u32, f64, f64)>,
    /// Trace batches shipped by the workers (merged fleet timeline).
    trace_events: Vec<TraceEvent>,
}

/// Drive the pure [`CoordinatorSm`] over the live control sockets: spawn
/// one reader thread per member, translate wire frames, closed channels
/// and the grace timer into [`CoordIn`] events, and perform every
/// [`CoordOut`] effect (tailored Prepare frames, commits, shutdowns,
/// telemetry records).  Both fleet shapes run through this one loop;
/// `stages` selects the frame flavor (`Prepare` vs per-stage-tailored
/// `StagePrepare`) alongside the machine's own stage semantics.
///
/// Every membership decision — epoch formation, pruning, the
/// drain-or-discard ruling, ack staleness, grace draining, completion —
/// is the machine's; this loop holds no protocol state beyond the
/// armed timer and the closed-channel dedup.
#[allow(clippy::type_complexity)]
fn drive_coordinator(
    cfg: &ElasticConfig,
    stages: u32,
    mut handles: BTreeMap<Key, CtrlHandle>,
    cluster_order: Vec<u32>,
) -> Result<(u32, BTreeMap<Key, DoneReport>, Telemetry)> {
    // One reader thread per member feeding a single event queue; the
    // handles keep the write half.
    let (tx, rx) = mpsc::channel::<Event>();
    for (&key, handle) in handles.iter() {
        let rs = handle.writer.try_clone().context("cloning control stream")?;
        rs.set_read_timeout(None).ok();
        spawn_reader(key, rs, tx.clone());
    }
    drop(tx);

    let wall_deadline = Instant::now() + Duration::from_millis(cfg.wall_timeout_ms);
    let grace = Duration::from_millis(cfg.transport.ring_timeout_ms * 2 + 2000);
    let mut sm =
        CoordinatorSm::new(handles.keys().copied(), stages, cfg.rounds as u32);
    // Topology-derived ring-order preference (probed max-bottleneck
    // order, or (site, rank) grouping for hier).  A pure layout bias:
    // the machine's membership decisions — and so every model-checked
    // property — are untouched.
    sm.set_cluster_order(cluster_order);
    // Interleaved virtual stages close each cluster's stage-link chain
    // into a ring (last executor dials stage 0's link listener).
    sm.set_wrap_links(stages > 1 && cfg.virtual_stages.max(1) > 1);
    let mut done: BTreeMap<Key, DoneReport> = BTreeMap::new();
    let mut telem = Telemetry::default();
    // The single coordinator timer; the most recently armed token wins
    // (the machine ignores stale tokens regardless).
    let mut timer: Option<(u64, Instant)> = None;
    // Members already reported closed, so the machine sees exactly one
    // Closed per member even when a write failure races the reader EOF.
    let mut closed: BTreeSet<Key> = BTreeSet::new();
    let mut inputs: VecDeque<CoordIn> = VecDeque::from([CoordIn::Start]);

    loop {
        // Perform every effect of every queued event before blocking.
        while let Some(input) = inputs.pop_front() {
            for out in sm.handle(input) {
                match out {
                    CoordOut::Prepare {
                        to,
                        epoch,
                        resume_round,
                        ring,
                        link_down,
                        drain_round,
                    } => {
                        obs::set_epoch(epoch);
                        obs::set_round(resume_round);
                        let _s = obs::span("elastic", "epoch.prepare");
                        let msg = if stages > 1 {
                            Msg::StagePrepare {
                                epoch,
                                resume_round,
                                ring_members: ring
                                    .iter()
                                    .map(|k| (k.0, handles[k].ring_port))
                                    .collect(),
                                link_down_port: link_down
                                    .map_or(0, |k| handles[&k].link_port),
                                drain_round,
                            }
                        } else {
                            Msg::Prepare {
                                epoch,
                                resume_round,
                                members: ring
                                    .iter()
                                    .map(|k| {
                                        let h = &handles[k];
                                        MemberInfo {
                                            rank: k.0,
                                            ring_port: h.ring_port,
                                            hier_port: h.hier_port,
                                            site: h.site,
                                        }
                                    })
                                    .collect(),
                                drain_round,
                            }
                        };
                        let h =
                            handles.get_mut(&to).expect("prepare for unknown member");
                        if write_msg(&mut h.writer, &msg).is_err() && closed.insert(to) {
                            inputs.push_back(CoordIn::Closed { key: to });
                        }
                    }
                    CoordOut::Commit { to, epoch } => {
                        let _s = obs::span("elastic", "epoch.commit");
                        let h =
                            handles.get_mut(&to).expect("commit for unknown member");
                        if write_msg(&mut h.writer, &Msg::Commit { epoch }).is_err()
                            && closed.insert(to)
                        {
                            inputs.push_back(CoordIn::Closed { key: to });
                        }
                    }
                    CoordOut::Shutdown { to } => {
                        if let Some(h) = handles.get_mut(&to) {
                            let _ = write_msg(&mut h.writer, &Msg::Shutdown);
                        }
                    }
                    CoordOut::ArmTimer { token } => {
                        timer = Some((token, Instant::now() + grace));
                    }
                    CoordOut::Committed { epoch, stage, drain_round } => {
                        telem.recoveries.push((epoch, stage, drain_round));
                    }
                    CoordOut::Finished => {}
                    CoordOut::Failed { reason } => return Err(anyhow!(reason)),
                }
            }
        }
        if sm.is_finished() {
            return Ok((sm.epoch(), done, telem));
        }
        if Instant::now() >= wall_deadline {
            return Err(anyhow!(if stages > 1 {
                "elastic stage run exceeded the wall timeout"
            } else {
                "elastic run exceeded the wall timeout"
            }));
        }
        // Fire the armed timer, or wait (bounded) for the next event.
        let wait = match timer {
            Some((token, at)) => {
                let left = at.saturating_duration_since(Instant::now());
                if left.is_zero() {
                    timer = None;
                    inputs.push_back(CoordIn::Timer { token });
                    continue;
                }
                left.min(Duration::from_millis(200))
            }
            None => Duration::from_millis(200),
        };
        match rx.recv_timeout(wait) {
            Ok(Event::Msg(k, msg)) => {
                // Telemetry ingest keeps the historical filters: the
                // single fleet counts every reporter; the stage fleet
                // only still-live members (orphans of a pruned cluster
                // must not steer the survivors' records).
                let counted = stages == 1 || sm.live().contains(&k);
                match msg {
                    Msg::Heartbeat { round, loss, step_secs, wire_bytes } => {
                        if counted {
                            if !loss.is_nan() {
                                telem.round_losses.push((k.0, round, loss));
                            }
                            telem.round_wire.push((k.0, round, wire_bytes));
                            telem.step_samples.push((k.1, step_secs as f64));
                        }
                        inputs.push_back(CoordIn::Heartbeat { key: k, round });
                    }
                    Msg::RingBroken { applied_rounds, in_flight_round, .. } => {
                        inputs.push_back(CoordIn::RingBroken {
                            key: k,
                            applied_rounds,
                            in_flight_round,
                        });
                    }
                    Msg::Done { wire_bytes, final_loss, params, .. } => {
                        if counted {
                            done.insert(
                                k,
                                DoneReport { wire_bytes, final_loss, params },
                            );
                        }
                        inputs.push_back(CoordIn::Done { key: k });
                    }
                    Msg::PrepareAck { epoch } => {
                        inputs.push_back(CoordIn::PrepareAck { key: k, epoch });
                    }
                    Msg::TraceEvents { events } => {
                        if counted {
                            telem.trace_events.extend(events);
                        }
                    }
                    _ => {}
                }
            }
            Ok(Event::Closed(k)) => {
                if closed.insert(k) {
                    inputs.push_back(CoordIn::Closed { key: k });
                }
            }
            Err(mpsc::RecvTimeoutError::Timeout) => {}
            Err(mpsc::RecvTimeoutError::Disconnected) => {
                return Err(anyhow!("all control channels lost"));
            }
        }
    }
}

fn spawn_workers(
    cfg: &ElasticConfig,
    mode: &SpawnMode,
    coord_addr: &str,
) -> Result<Vec<std::process::Child>> {
    let mut children = Vec::new();
    for rank in 0..cfg.workers as u32 {
        let opts = worker_opts_for(cfg, rank, coord_addr, mode);
        match mode {
            SpawnMode::Process { exe } => {
                let mut cmd = Command::new(exe);
                cmd.arg("worker")
                    .arg("--coord")
                    .arg(&opts.coord)
                    .arg("--rank")
                    .arg(rank.to_string())
                    .arg("--rounds")
                    .arg(cfg.rounds.to_string())
                    .arg("--local-steps")
                    .arg(cfg.local_steps.to_string())
                    .arg("--inner-lr")
                    .arg(cfg.inner_lr.to_string())
                    .arg("--weight-decay")
                    .arg(cfg.weight_decay.to_string())
                    .arg("--outer-lr")
                    .arg(cfg.outer_lr.to_string())
                    .arg("--outer-momentum")
                    .arg(cfg.outer_momentum.to_string())
                    .arg("--seed")
                    .arg(cfg.seed.to_string())
                    .arg("--ring-timeout-ms")
                    .arg(cfg.transport.ring_timeout_ms.to_string())
                    .arg("--connect-timeout-ms")
                    .arg(cfg.transport.connect_timeout_ms.to_string())
                    .arg("--comm-pool")
                    .arg(cfg.transport.comm_pool_size.to_string())
                    .arg("--pipeline-depth")
                    .arg(cfg.transport.pipeline_depth.to_string())
                    .arg("--site")
                    .arg(opts.site.to_string())
                    .arg("--reduce-topology")
                    .arg(cfg.reduce_topology.name());
                if cfg.overlap {
                    cmd.arg("--overlap");
                }
                if cfg.trace {
                    cmd.arg("--trace");
                    if !cfg.trace_dir.is_empty() {
                        cmd.arg("--trace-dir").arg(&cfg.trace_dir);
                    }
                }
                match &cfg.workload {
                    Workload::Quadratic { dim } => {
                        cmd.arg("--workload").arg("quad");
                        cmd.arg("--dim").arg(dim.to_string());
                    }
                    Workload::Runtime { artifacts_dir } => {
                        cmd.arg("--workload").arg("runtime");
                        cmd.arg("--artifacts").arg(artifacts_dir);
                    }
                }
                if let Some(plan) = &opts.faults {
                    cmd.arg("--fault-seed")
                        .arg(plan.seed.to_string())
                        .arg("--fault-delay-prob")
                        .arg(plan.delay_prob.to_string())
                        .arg("--fault-delay-ms")
                        .arg(plan.max_delay_ms.to_string())
                        .arg("--fault-kill-round")
                        .arg(plan.kill_round.to_string())
                        .arg("--fault-break-round")
                        .arg(plan.break_round.to_string())
                        .arg("--fault-straggler-ms")
                        .arg(plan.straggler_ms.to_string());
                }
                let child = cmd
                    .stdout(Stdio::null())
                    .stderr(Stdio::inherit())
                    .spawn()
                    .with_context(|| format!("spawning worker {rank} via {exe}"))?;
                children.push(child);
            }
            SpawnMode::Thread => {
                std::thread::spawn(move || {
                    if let Err(e) = run_worker(&opts) {
                        eprintln!("[worker {rank}] exited: {e:#}");
                    }
                });
            }
        }
    }
    Ok(children)
}

fn worker_opts_for(
    cfg: &ElasticConfig,
    rank: u32,
    coord_addr: &str,
    mode: &SpawnMode,
) -> WorkerOpts {
    let exit_on_kill = matches!(mode, SpawnMode::Process { .. });
    WorkerOpts {
        coord: coord_addr.to_string(),
        rank,
        rounds: cfg.rounds,
        local_steps: cfg.local_steps,
        inner_lr: cfg.inner_lr,
        weight_decay: cfg.weight_decay,
        outer_lr: cfg.outer_lr,
        outer_momentum: cfg.outer_momentum,
        seed: cfg.seed,
        workload: cfg.workload.clone(),
        overlap: cfg.overlap,
        ring_timeout_ms: cfg.transport.ring_timeout_ms,
        connect_timeout_ms: cfg.transport.connect_timeout_ms,
        comm_pool_size: cfg.transport.comm_pool_size,
        pipeline_depth: cfg.transport.pipeline_depth,
        site: cfg.site_of(rank),
        reduce_topology: cfg.reduce_topology,
        faults: fault_plan_for(&cfg.faults, rank, exit_on_kill),
    }
}

/// Accept one control connection per worker and read its `Hello`.
/// Workers are keyed `(rank, 0)` — the degenerate stage of the protocol
/// [`Key`] space.
fn accept_workers(
    listener: &TcpListener,
    expected: usize,
    deadline: Instant,
) -> Result<BTreeMap<Key, CtrlHandle>> {
    listener.set_nonblocking(true).context("control listener nonblocking")?;
    let mut map = BTreeMap::new();
    while map.len() < expected {
        match listener.accept() {
            Ok((stream, _)) => {
                stream.set_nonblocking(false).ok();
                stream.set_nodelay(true).ok();
                stream.set_read_timeout(Some(Duration::from_secs(10))).ok();
                let mut stream = stream;
                match read_msg(&mut stream) {
                    Ok(Msg::Hello { rank, ring_port, hier_port, probe_port, site }) => {
                        if map.contains_key(&(rank, 0)) {
                            return Err(anyhow!("duplicate worker rank {rank}"));
                        }
                        stream.set_write_timeout(Some(Duration::from_secs(10))).ok();
                        map.insert(
                            (rank, 0),
                            CtrlHandle {
                                writer: stream,
                                ring_port,
                                link_port: 0,
                                hier_port,
                                probe_port,
                                site,
                            },
                        );
                    }
                    _ => { /* not a worker — drop */ }
                }
            }
            Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                if Instant::now() >= deadline {
                    return Err(anyhow!(
                        "only {}/{} workers connected before the deadline",
                        map.len(),
                        expected
                    ));
                }
                std::thread::sleep(Duration::from_millis(10));
            }
            Err(e) => return Err(anyhow!("control accept failed: {e}")),
        }
    }
    Ok(map)
}

/// Reap spawned worker processes: give each a short grace window, then
/// kill.  Runs on every exit path so a failed coordination can't leave
/// orphaned workers training at full CPU.
fn reap_children(children: &mut [std::process::Child]) {
    let reap_deadline = Instant::now() + Duration::from_secs(5);
    for child in children.iter_mut() {
        loop {
            match child.try_wait() {
                Ok(Some(_)) => break,
                Ok(None) => {
                    if Instant::now() >= reap_deadline {
                        let _ = child.kill();
                        let _ = child.wait();
                        break;
                    }
                    std::thread::sleep(Duration::from_millis(20));
                }
                Err(_) => break,
            }
        }
    }
}

/// Run the elastic coordinator to completion.  Dispatches to the
/// stage-parallel fleet supervisor when `pp_stages > 1` (one OS process
/// per (cluster, stage), per-stage rings, intra-cluster TCP dataflow).
pub fn run_elastic(cfg: &ElasticConfig, mode: &SpawnMode) -> Result<ElasticOutcome> {
    if cfg.trace {
        obs::set_enabled(true);
        if !cfg.trace_dir.is_empty() {
            obs::set_journal(Some(
                std::path::Path::new(&cfg.trace_dir).join("coord.jsonl"),
            ));
        }
    }
    if cfg.pp_stages > 1 {
        return run_elastic_stages(cfg, mode);
    }
    if cfg.workers == 0 {
        return Err(anyhow!("need at least one worker"));
    }
    let listener =
        TcpListener::bind("127.0.0.1:0").context("binding coordinator socket")?;
    let coord_addr = listener.local_addr()?.to_string();
    let mut children = spawn_workers(cfg, mode, &coord_addr)?;

    // Supervision can fail at many points (startup timeout, wall timeout,
    // every worker dying); reap the children on ALL of them, then
    // propagate the error.
    let supervised = supervise(cfg, &listener);
    reap_children(&mut children);
    let (epoch, done, telem) = supervised?;

    let survivors: Vec<u32> = done.keys().copied().collect();
    if survivors.is_empty() {
        return Err(anyhow!("no worker completed the run"));
    }
    let reports: Vec<&DoneReport> = done.values().collect();
    let p0 = &reports[0].params;
    let mut max_dev = 0.0f32;
    for r in &reports[1..] {
        let dev = p0
            .iter()
            .zip(&r.params)
            .map(|(a, b)| (a - b).abs())
            .fold(0.0f32, f32::max);
        max_dev = max_dev.max(dev);
    }
    if max_dev > 1e-4 {
        if epoch <= 1 {
            // No churn happened: the ring algebra is symmetric, so any
            // divergence is a real bug.
            return Err(anyhow!("workers diverged: max param dev {max_dev}"));
        }
        // With churn, a worker that broke during the *final* round can
        // legitimately miss the last outer update (its peers were already
        // done, so there was no ring left to redo it with).  Bounded
        // staleness, not corruption — report it instead of failing.
        eprintln!(
            "[elastic] survivors differ by max param dev {max_dev} after \
             {epoch} membership epochs (final-round churn staleness)"
        );
    }
    let final_loss =
        reports.iter().map(|r| r.final_loss).sum::<f32>() / reports.len() as f32;
    let total_wire_bytes = reports.iter().map(|r| r.wire_bytes).sum();
    // Close the timeline with whatever this process still holds — the
    // coordinator's own 2PC spans, plus (thread mode) any worker batch
    // that flushed after its final ship.
    let mut trace_events = telem.trace_events;
    if cfg.trace {
        trace_events.extend(obs::drain());
    }
    Ok(ElasticOutcome {
        rounds: cfg.rounds,
        epochs: epoch,
        started: cfg.workers,
        survivors,
        final_loss,
        final_params: p0.clone(),
        total_wire_bytes,
        round_losses: telem.round_losses,
        round_wire: telem.round_wire,
        stage_times: summarize_step_samples(&telem.step_samples),
        recoveries: telem.recoveries,
        links: telem.links,
        trace_events,
    })
}

/// Accept the fleet, run the 2PC epochs, and watch the run to completion;
/// returns (final epoch, done reports, heartbeat telemetry).  Sends
/// `Shutdown` to the fleet on success; error paths leave process cleanup
/// to the caller's [`reap_children`].  All protocol decisions are made by
/// [`CoordinatorSm`] inside [`drive_coordinator`].
#[allow(clippy::type_complexity)]
fn supervise(
    cfg: &ElasticConfig,
    listener: &TcpListener,
) -> Result<(u32, BTreeMap<u32, DoneReport>, Telemetry)> {
    obs::set_scope(obs::COORD, 0);
    let startup_deadline = Instant::now()
        + Duration::from_millis(cfg.transport.connect_timeout_ms)
        + Duration::from_secs(10);
    let mut handles = accept_workers(listener, cfg.workers, startup_deadline)?;
    let (order, links) = topology_order(cfg, &mut handles)?;
    let (epoch, done, mut telem) = drive_coordinator(cfg, 1, handles, order)?;
    telem.links = links;
    Ok((epoch, done.into_iter().map(|((r, _), v)| (r, v)).collect(), telem))
}

/// Compute the fleet's ring-order preference (and, under the reordered
/// topology, the measured link ledger) before the first epoch:
///
/// - `flat` — empty preference, the historical ascending order;
/// - `hier` — ranks grouped by announced (site, rank), so every
///   committed member list arrives site-contiguous and
///   [`hier::site_plan`] can slice it;
/// - `reordered` — probe every directed pair over the workers' echo
///   listeners and run the max-bottleneck ordering over the measured
///   matrix.
///
/// The probe runs once at startup, between registration and the first
/// Prepare; later epochs reuse the preference (churn only removes
/// members, and max-bottleneck order is stable under member removal in
/// the greedy sense — re-probing mid-churn would stall recovery).
fn topology_order(
    cfg: &ElasticConfig,
    handles: &mut BTreeMap<Key, CtrlHandle>,
) -> Result<(Vec<u32>, Vec<(u32, u32, f64, f64)>)> {
    match cfg.reduce_topology {
        ReduceTopology::Flat => Ok((Vec::new(), Vec::new())),
        ReduceTopology::Hier => {
            let mut tagged: Vec<(u32, u32)> =
                handles.iter().map(|(&(r, _), h)| (h.site, r)).collect();
            tagged.sort_unstable();
            Ok((tagged.into_iter().map(|(_, r)| r).collect(), Vec::new()))
        }
        ReduceTopology::Reordered => {
            let ranks: Vec<u32> = handles.keys().map(|&(r, _)| r).collect();
            let index: BTreeMap<u32, usize> =
                ranks.iter().enumerate().map(|(i, &r)| (r, i)).collect();
            let peers_all: Vec<(u32, u16)> =
                handles.iter().map(|(&(r, _), h)| (r, h.probe_port)).collect();
            let mut matrix = LinkMatrix::new(ranks.len());
            let mut links = Vec::new();
            let _s = obs::span("elastic", "probe");
            // Sequential on purpose: concurrent probes would contend for
            // the same NICs and measure each other instead of the links.
            for &r in &ranks {
                let peers: Vec<(u32, u16)> = peers_all
                    .iter()
                    .copied()
                    .filter(|&(p, _)| p != r)
                    .collect();
                let h = handles.get_mut(&(r, 0)).expect("probing unknown rank");
                h.writer.set_read_timeout(Some(Duration::from_secs(60))).ok();
                write_msg(
                    &mut h.writer,
                    &Msg::ProbeRequest {
                        payload_elems: cfg.probe_payload_elems.max(1) as u32,
                        repeats: cfg.probe_repeats.max(1) as u32,
                        peers,
                    },
                )
                .with_context(|| format!("sending probe request to worker {r}"))?;
                match read_msg(&mut h.writer) {
                    Ok(Msg::ProbeReport { links: rows }) => {
                        for l in rows {
                            if let Some(&j) = index.get(&l.to) {
                                // An unreachable peer reports 0 Gbps; keep
                                // it as a heavily penalized (never free)
                                // link so the ordering avoids it.
                                matrix.set(
                                    index[&r],
                                    j,
                                    l.gbps.max(1e-6),
                                    l.latency_ms,
                                );
                                links.push((r, l.to, l.gbps, l.latency_ms));
                            }
                        }
                    }
                    Ok(_) => {
                        return Err(anyhow!(
                            "worker {r} answered the link probe with an \
                             unexpected frame"
                        ))
                    }
                    Err(e) => {
                        return Err(anyhow!(
                            "worker {r} lost its control channel during the \
                             link probe: {e:#}"
                        ))
                    }
                }
                h.writer.set_read_timeout(Some(Duration::from_secs(10))).ok();
            }
            let order = probe::ring_order(&matrix);
            Ok((order.into_iter().map(|i| ranks[i]).collect(), links))
        }
    }
}

// ---------------------------------------------------------------------------
// Coordinator side: stage-parallel fleet (pp_stages > 1)
// ---------------------------------------------------------------------------

fn stage_worker_opts_for(
    cfg: &ElasticConfig,
    rank: u32,
    stage: u32,
    coord_addr: &str,
    mode: &SpawnMode,
) -> StageWorkerOpts {
    let exit_on_kill = matches!(mode, SpawnMode::Process { .. });
    let mut base = worker_opts_for(cfg, rank, coord_addr, mode);
    base.faults = stage_fault_plan_for(&cfg.faults, rank, stage, exit_on_kill);
    StageWorkerOpts {
        base,
        stage,
        stages: cfg.pp_stages as u32,
        micros: cfg.microbatches.max(1),
        schedule: cfg.schedule.clone(),
        virtual_stages: cfg.virtual_stages.max(1),
        listen_base: cfg.transport.stage_listen_base_port,
    }
}

fn spawn_stage_workers(
    cfg: &ElasticConfig,
    mode: &SpawnMode,
    coord_addr: &str,
) -> Result<Vec<std::process::Child>> {
    let mut children = Vec::new();
    for rank in 0..cfg.workers as u32 {
        for stage in 0..cfg.stage_execs() as u32 {
            let opts = stage_worker_opts_for(cfg, rank, stage, coord_addr, mode);
            match mode {
                SpawnMode::Process { exe } => {
                    let mut cmd = Command::new(exe);
                    cmd.arg("worker")
                        .arg("--coord")
                        .arg(&opts.base.coord)
                        .arg("--rank")
                        .arg(rank.to_string())
                        .arg("--stage")
                        .arg(stage.to_string())
                        .arg("--stages")
                        .arg(cfg.pp_stages.to_string())
                        .arg("--micros")
                        .arg(opts.micros.to_string())
                        .arg("--schedule")
                        .arg(&opts.schedule)
                        .arg("--virtual-stages")
                        .arg(opts.virtual_stages.to_string())
                        .arg("--listen-base")
                        .arg(opts.listen_base.to_string())
                        .arg("--rounds")
                        .arg(cfg.rounds.to_string())
                        .arg("--local-steps")
                        .arg(cfg.local_steps.to_string())
                        .arg("--inner-lr")
                        .arg(cfg.inner_lr.to_string())
                        .arg("--weight-decay")
                        .arg(cfg.weight_decay.to_string())
                        .arg("--outer-lr")
                        .arg(cfg.outer_lr.to_string())
                        .arg("--outer-momentum")
                        .arg(cfg.outer_momentum.to_string())
                        .arg("--seed")
                        .arg(cfg.seed.to_string())
                        .arg("--ring-timeout-ms")
                        .arg(cfg.transport.ring_timeout_ms.to_string())
                        .arg("--connect-timeout-ms")
                        .arg(cfg.transport.connect_timeout_ms.to_string())
                        .arg("--comm-pool")
                        .arg(cfg.transport.comm_pool_size.to_string())
                        .arg("--pipeline-depth")
                        .arg(cfg.transport.pipeline_depth.to_string());
                    if cfg.overlap {
                        cmd.arg("--overlap");
                    }
                    if cfg.trace {
                        cmd.arg("--trace");
                        if !cfg.trace_dir.is_empty() {
                            cmd.arg("--trace-dir").arg(&cfg.trace_dir);
                        }
                    }
                    match &cfg.workload {
                        Workload::Quadratic { dim } => {
                            cmd.arg("--workload").arg("quad");
                            cmd.arg("--dim").arg(dim.to_string());
                        }
                        Workload::Runtime { artifacts_dir } => {
                            cmd.arg("--workload").arg("runtime");
                            cmd.arg("--artifacts").arg(artifacts_dir);
                        }
                    }
                    if let Some(plan) = &opts.base.faults {
                        cmd.arg("--fault-seed")
                            .arg(plan.seed.to_string())
                            .arg("--fault-delay-prob")
                            .arg(plan.delay_prob.to_string())
                            .arg("--fault-delay-ms")
                            .arg(plan.max_delay_ms.to_string())
                            .arg("--fault-kill-round")
                            .arg(plan.kill_round.to_string())
                            .arg("--fault-break-round")
                            .arg(plan.break_round.to_string())
                            .arg("--fault-straggler-ms")
                            .arg(plan.straggler_ms.to_string());
                    }
                    let child = cmd
                        .stdout(Stdio::null())
                        .stderr(Stdio::inherit())
                        .spawn()
                        .with_context(|| {
                            format!("spawning stage worker {rank}.{stage} via {exe}")
                        })?;
                    children.push(child);
                }
                SpawnMode::Thread => {
                    std::thread::spawn(move || {
                        if let Err(e) = run_stage_worker(&opts) {
                            eprintln!(
                                "[stage worker {rank}.{stage}] exited: {e:#}"
                            );
                        }
                    });
                }
            }
        }
    }
    Ok(children)
}

/// Accept one control connection per (cluster, stage) process and read
/// its `StageHello`.
fn accept_stage_workers(
    listener: &TcpListener,
    clusters: usize,
    stages: usize,
    deadline: Instant,
) -> Result<BTreeMap<Key, CtrlHandle>> {
    listener
        .set_nonblocking(true)
        .context("control listener nonblocking")?;
    let expected = clusters * stages;
    let mut map = BTreeMap::new();
    while map.len() < expected {
        match listener.accept() {
            Ok((stream, _)) => {
                stream.set_nonblocking(false).ok();
                stream.set_nodelay(true).ok();
                stream.set_read_timeout(Some(Duration::from_secs(10))).ok();
                let mut stream = stream;
                match read_msg(&mut stream) {
                    Ok(Msg::StageHello { cluster, stage, ring_port, link_port }) => {
                        if cluster as usize >= clusters || stage as usize >= stages {
                            return Err(anyhow!(
                                "stage hello ({cluster}, {stage}) out of range"
                            ));
                        }
                        if map.contains_key(&(cluster, stage)) {
                            return Err(anyhow!(
                                "duplicate stage worker ({cluster}, {stage})"
                            ));
                        }
                        stream
                            .set_write_timeout(Some(Duration::from_secs(10)))
                            .ok();
                        map.insert(
                            (cluster, stage),
                            CtrlHandle {
                                writer: stream,
                                ring_port,
                                link_port,
                                hier_port: 0,
                                probe_port: 0,
                                site: 0,
                            },
                        );
                    }
                    _ => { /* not a stage worker — drop */ }
                }
            }
            Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                if Instant::now() >= deadline {
                    return Err(anyhow!(
                        "only {}/{} stage workers connected before the deadline",
                        map.len(),
                        expected
                    ));
                }
                std::thread::sleep(Duration::from_millis(10));
            }
            Err(e) => return Err(anyhow!("control accept failed: {e}")),
        }
    }
    Ok(map)
}

/// Run the stage-parallel elastic coordinator to completion: spawn the
/// `dp × pp` stage-process fleet, supervise the per-stage rings through
/// membership epochs, and assemble + evaluate the final model from the
/// survivors' per-stage parameter digests.
fn run_elastic_stages(cfg: &ElasticConfig, mode: &SpawnMode) -> Result<ElasticOutcome> {
    if cfg.workers == 0 {
        return Err(anyhow!("need at least one cluster"));
    }
    let stages = cfg.pp_stages;
    let v = cfg.virtual_stages.max(1);
    if stages % v != 0 {
        return Err(anyhow!(
            "{stages} pipeline stages not divisible by {v} virtual stages"
        ));
    }
    let execs = cfg.stage_execs();
    let listener =
        TcpListener::bind("127.0.0.1:0").context("binding coordinator socket")?;
    let coord_addr = listener.local_addr()?.to_string();
    let mut children = spawn_stage_workers(cfg, mode, &coord_addr)?;

    let supervised = supervise_stages(cfg, &listener);
    reap_children(&mut children);
    let (epoch, done, telem) = supervised?;

    // Survivor clusters: every executor process completed.
    let clusters: BTreeSet<u32> = done.keys().map(|(c, _)| *c).collect();
    let survivors: Vec<u32> = clusters
        .into_iter()
        .filter(|c| (0..execs as u32).all(|s| done.contains_key(&(*c, s))))
        .collect();
    if survivors.is_empty() {
        return Err(anyhow!("no cluster completed the run"));
    }

    // Assemble per-cluster full vectors from the per-executor digests in
    // model-stage order: executor s's concat holds [chunk 0 | chunk 1 |
    // ...] = model stages {s, S+s, 2S+s, ...}; with v = 1 this is the
    // plain stage concatenation.  A truncated digest (PARAMS_DIGEST_MAX)
    // falls back to raw concatenation — the final eval is skipped by its
    // length check anyway.
    let workload =
        build_stage_pipeline(&cfg.workload, stages, cfg.microbatches, cfg.seed)?;
    let exec_len = |s: usize| -> usize {
        (0..v).map(|c| workload.stage_numel(c * execs + s)).sum()
    };
    let assemble = |c: u32| -> Vec<f32> {
        let complete = (0..execs)
            .all(|s| done[&(c, s as u32)].params.len() == exec_len(s));
        let mut full = Vec::new();
        if !complete || v == 1 {
            for s in 0..execs as u32 {
                full.extend_from_slice(&done[&(c, s)].params);
            }
            return full;
        }
        for k in 0..stages {
            let (s, ch) = (k % execs, k / execs);
            let off: usize =
                (0..ch).map(|cc| workload.stage_numel(cc * execs + s)).sum();
            let n = workload.stage_numel(k);
            full.extend_from_slice(&done[&(c, s as u32)].params[off..off + n]);
        }
        full
    };
    let p0 = assemble(survivors[0]);
    let mut max_dev = 0.0f32;
    for &c in &survivors[1..] {
        let pc = assemble(c);
        let dev = p0
            .iter()
            .zip(&pc)
            .map(|(a, b)| (a - b).abs())
            .fold(0.0f32, f32::max);
        max_dev = max_dev.max(dev);
    }
    if max_dev > 1e-4 {
        if epoch <= 1 {
            // No churn happened: per-stage ring algebra is symmetric, so
            // any divergence is a real bug.
            return Err(anyhow!(
                "stage fleets diverged: max param dev {max_dev}"
            ));
        }
        eprintln!(
            "[elastic] surviving clusters differ by max param dev {max_dev} \
             after {epoch} membership epochs (final-round churn staleness)"
        );
    }

    // Final eval over the assembled model (each stage process holds only
    // its shard, so the coordinator evaluates).  Digests are exact for
    // per-stage shards up to PARAMS_DIGEST_MAX elements; beyond that the
    // eval is skipped rather than run on a strided sample.
    let expected: usize = (0..stages).map(|s| workload.stage_numel(s)).sum();
    let final_loss = if p0.len() == expected {
        workload.eval(&p0)?
    } else {
        eprintln!(
            "[elastic] stage param digests truncated ({} of {expected} \
             elements) — skipping the assembled final eval",
            p0.len()
        );
        f32::NAN
    };
    let total_wire_bytes = done.values().map(|r| r.wire_bytes).sum();
    let mut trace_events = telem.trace_events;
    if cfg.trace {
        trace_events.extend(obs::drain());
    }
    Ok(ElasticOutcome {
        rounds: cfg.rounds,
        epochs: epoch,
        started: cfg.workers,
        survivors,
        final_loss,
        final_params: p0,
        total_wire_bytes,
        round_losses: telem.round_losses,
        round_wire: telem.round_wire,
        stage_times: summarize_step_samples(&telem.step_samples),
        recoveries: telem.recoveries,
        links: telem.links,
        trace_events,
    })
}

/// Accept the stage fleet, run the (cluster, stage)-keyed 2PC epochs, and
/// watch the run to completion; returns (final epoch, per-(cluster,
/// stage) done reports, heartbeat telemetry keyed by cluster).  Stage
/// semantics — whole-cluster pruning, per-stage drain decisions,
/// finishing epochs with solo rings and link teardown — live in
/// [`CoordinatorSm`]; [`drive_coordinator`] performs them on the wire.
#[allow(clippy::type_complexity)]
fn supervise_stages(
    cfg: &ElasticConfig,
    listener: &TcpListener,
) -> Result<(u32, BTreeMap<(u32, u32), DoneReport>, Telemetry)> {
    obs::set_scope(obs::COORD, 0);
    let startup_deadline = Instant::now()
        + Duration::from_millis(cfg.transport.connect_timeout_ms)
        + Duration::from_secs(10);
    let execs = cfg.stage_execs();
    let handles =
        accept_stage_workers(listener, cfg.workers, execs, startup_deadline)?;
    // Stage fleets keep the flat per-stage rings: `StageHello` carries no
    // site tag or probe listener, so the order preference stays empty.
    drive_coordinator(cfg, execs as u32, handles, Vec::new())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn quick_cfg(workers: usize) -> ElasticConfig {
        let mut c = ElasticConfig::quadratic(workers, 6, 32);
        c.transport.ring_timeout_ms = 1000;
        c.transport.connect_timeout_ms = 5000;
        c.wall_timeout_ms = 60_000;
        c
    }

    #[test]
    fn thread_mode_three_workers_converge() {
        let out = run_elastic(&quick_cfg(3), &SpawnMode::Thread).unwrap();
        assert_eq!(out.epochs, 1, "no churn expected");
        assert_eq!(out.survivors, vec![0, 1, 2]);
        assert!(out.total_wire_bytes > 0);
        // Round-1 mean loss should beat the final loss decisively.
        let r1: Vec<f32> = out
            .round_losses
            .iter()
            .filter(|(_, r, _)| *r == 1)
            .map(|(_, _, l)| *l)
            .collect();
        assert!(!r1.is_empty());
        let r1_mean = r1.iter().sum::<f32>() / r1.len() as f32;
        assert!(
            out.final_loss < r1_mean * 0.5,
            "final {} vs round-1 {}",
            out.final_loss,
            r1_mean
        );
    }

    #[test]
    fn thread_mode_overlap_converges_and_wire_ledger_defers() {
        // The §2.3 overlap on the fleet: the wire ledger must show the
        // one-step delay — round-1 heartbeats completed no reduction,
        // round-2 heartbeats completed round 1's.  (The regression for
        // the old silent overlap→sync downgrade: a downgraded fleet
        // would ship in round 1.)
        let mut cfg = quick_cfg(3);
        cfg.overlap = true;
        let out = run_elastic(&cfg, &SpawnMode::Thread).unwrap();
        assert_eq!(out.epochs, 1, "no churn expected");
        assert_eq!(out.survivors, vec![0, 1, 2]);
        assert!(out.final_loss.is_finite());
        let wire_at = |round: u32| -> Vec<u64> {
            out.round_wire
                .iter()
                .filter(|(_, r, _)| *r == round)
                .map(|(_, _, b)| *b)
                .collect()
        };
        assert_eq!(wire_at(1).len(), 3);
        assert!(wire_at(1).iter().all(|&b| b == 0), "{:?}", out.round_wire);
        assert!(wire_at(2).iter().all(|&b| b > 0), "{:?}", out.round_wire);
        // Convergence still decisive despite the one-round delay.
        let r1: Vec<f32> = out
            .round_losses
            .iter()
            .filter(|(_, r, _)| *r == 1)
            .map(|(_, _, l)| *l)
            .collect();
        let r1_mean = r1.iter().sum::<f32>() / r1.len() as f32;
        assert!(out.final_loss < r1_mean * 0.5);
        // Heartbeats carried measured step times (stage 0 for the DP
        // fleet) — the TCP-fleet side of the DES calibration loop.
        assert_eq!(out.stage_times.len(), 1);
        assert!(out.stage_times[0].samples > 0);

        // Control: the sync fleet ships in round 1.
        let sync = run_elastic(&quick_cfg(2), &SpawnMode::Thread).unwrap();
        assert!(sync
            .round_wire
            .iter()
            .filter(|(_, r, _)| *r == 1)
            .all(|(_, _, b)| *b > 0));
    }

    #[test]
    fn thread_mode_overlap_kill_recovers_via_drain() {
        // Kill one worker mid-run under overlap: the survivors both
        // stall joining the same in-flight round, so the coordinator
        // commits a DRAIN — the re-formed ring finishes that reduction
        // with survivor-rescaled means and the run completes.
        let mut cfg = quick_cfg(3);
        cfg.overlap = true;
        cfg.faults.enabled = true;
        cfg.faults.kill_rank = 1;
        cfg.faults.kill_round = 2;
        let out = run_elastic(&cfg, &SpawnMode::Thread).unwrap();
        assert_eq!(out.survivors, vec![0, 2]);
        assert!(out.epochs >= 2, "epochs={}", out.epochs);
        assert!(
            out.recoveries.iter().any(|&(_, _, d)| d > 0),
            "expected a drain commit, got {:?}",
            out.recoveries
        );
        assert!(out.final_loss.is_finite());
        let max_round = out
            .round_losses
            .iter()
            .map(|(_, r, _)| *r)
            .max()
            .unwrap_or(0);
        assert_eq!(max_round as usize, cfg.rounds);
    }

    #[test]
    fn thread_mode_overlap_soft_break_recovers_via_discard() {
        // A soft break (worker parks without dying) leaves the breaker
        // one in-flight round behind its peers — mixed evidence, so the
        // coordinator must DISCARD (each survivor folds its delta into
        // error feedback) and everyone — breaker included — completes.
        let mut cfg = quick_cfg(3);
        cfg.overlap = true;
        cfg.faults.enabled = true;
        cfg.faults.break_rank = 1;
        cfg.faults.break_round = 3;
        let out = run_elastic(&cfg, &SpawnMode::Thread).unwrap();
        assert_eq!(out.survivors, vec![0, 1, 2], "nobody died");
        assert!(out.epochs >= 2, "epochs={}", out.epochs);
        assert!(
            out.recoveries.iter().all(|&(_, _, d)| d == 0),
            "mixed in-flight must discard, got {:?}",
            out.recoveries
        );
        assert!(out.final_loss.is_finite());
        let max_round = out
            .round_losses
            .iter()
            .map(|(_, r, _)| *r)
            .max()
            .unwrap_or(0);
        assert_eq!(max_round as usize, cfg.rounds);
    }

    #[test]
    fn thread_mode_overlap_kill_drains_with_pool_and_pipeline() {
        // Same drain scenario as above, but with the persistent comm pool
        // and the pipelined reducer enabled: a parked pool thread must not
        // outlive `RingLane::reseed`, and the drain branch must still
        // finish the in-flight reduction on the re-formed ring.
        let mut cfg = quick_cfg(3);
        cfg.overlap = true;
        cfg.transport.comm_pool_size = 2;
        cfg.transport.pipeline_depth = 2;
        cfg.faults.enabled = true;
        cfg.faults.kill_rank = 1;
        cfg.faults.kill_round = 2;
        let out = run_elastic(&cfg, &SpawnMode::Thread).unwrap();
        assert_eq!(out.survivors, vec![0, 2]);
        assert!(out.epochs >= 2, "epochs={}", out.epochs);
        assert!(
            out.recoveries.iter().any(|&(_, _, d)| d > 0),
            "expected a drain commit, got {:?}",
            out.recoveries
        );
        assert!(out.final_loss.is_finite());
        let max_round = out
            .round_losses
            .iter()
            .map(|(_, r, _)| *r)
            .max()
            .unwrap_or(0);
        assert_eq!(max_round as usize, cfg.rounds);
        // Thread-count convergence (no leak across epochs) is asserted on
        // private pools in `comm::pool::tests`; the shared pool's counters
        // are cross-test global, so here the probe is behavioral: every
        // pooled flight was joined (the run completed) and the re-formed
        // ring produced the full schedule.
    }

    #[test]
    fn thread_mode_overlap_soft_break_discards_with_pool_and_pipeline() {
        // The discard branch under pool + pipelined reduce: the breaker's
        // stale in-flight flight is joined and thrown away, and its pooled
        // comm thread parks instead of leaking.
        let mut cfg = quick_cfg(3);
        cfg.overlap = true;
        cfg.transport.comm_pool_size = 2;
        cfg.transport.pipeline_depth = 2;
        cfg.faults.enabled = true;
        cfg.faults.break_rank = 1;
        cfg.faults.break_round = 3;
        let out = run_elastic(&cfg, &SpawnMode::Thread).unwrap();
        assert_eq!(out.survivors, vec![0, 1, 2], "nobody died");
        assert!(out.epochs >= 2, "epochs={}", out.epochs);
        assert!(
            out.recoveries.iter().all(|&(_, _, d)| d == 0),
            "mixed in-flight must discard, got {:?}",
            out.recoveries
        );
        assert!(out.final_loss.is_finite());
        let max_round = out
            .round_losses
            .iter()
            .map(|(_, r, _)| *r)
            .max()
            .unwrap_or(0);
        assert_eq!(max_round as usize, cfg.rounds);
    }

    #[test]
    fn thread_mode_stage_fleet_overlap_converges() {
        // Overlap on the stage fleet: per-stage reductions run on comm
        // threads while the 1F1B dataflow trains the next H steps.
        let mut cfg = ElasticConfig::synthetic_pipeline(2, 2, 6, 16);
        cfg.overlap = true;
        // One-step-delayed outer updates oscillate at high gain on the
        // fast-converging affine chain (see the executor's overlap test).
        cfg.outer_lr = 0.3;
        cfg.outer_momentum = 0.3;
        cfg.transport.ring_timeout_ms = 1000;
        cfg.transport.connect_timeout_ms = 5000;
        cfg.wall_timeout_ms = 60_000;
        let out = run_elastic(&cfg, &SpawnMode::Thread).unwrap();
        assert_eq!(out.epochs, 1, "no churn expected");
        assert_eq!(out.survivors, vec![0, 1]);
        // Wire ledger: every stage process defers its first reduction.
        assert!(out
            .round_wire
            .iter()
            .filter(|(_, r, _)| *r == 1)
            .all(|(_, _, b)| *b == 0));
        assert!(out
            .round_wire
            .iter()
            .filter(|(_, r, _)| *r == 2)
            .all(|(_, _, b)| *b > 0));
        // Per-stage step telemetry covers both stages.
        assert_eq!(out.stage_times.len(), 2);
        assert!(out.stage_times.iter().all(|t| t.samples > 0));
        let r1: Vec<f32> = out
            .round_losses
            .iter()
            .filter(|(_, r, _)| *r == 1)
            .map(|(_, _, l)| *l)
            .collect();
        let r1_mean = r1.iter().sum::<f32>() / r1.len() as f32;
        assert!(
            out.final_loss < r1_mean,
            "final {} vs round-1 {}",
            out.final_loss,
            r1_mean
        );
    }

    #[test]
    fn thread_mode_survives_injected_kill() {
        let mut cfg = quick_cfg(3);
        cfg.faults.enabled = true;
        cfg.faults.kill_rank = 1;
        cfg.faults.kill_round = 2;
        let out = run_elastic(&cfg, &SpawnMode::Thread).unwrap();
        assert_eq!(out.survivors, vec![0, 2]);
        assert!(out.epochs >= 2, "expected a re-formed ring, got {}", out.epochs);
        assert!(out.final_loss.is_finite());
        // Survivors must have completed every round.
        let max_round = out
            .round_losses
            .iter()
            .map(|(_, r, _)| *r)
            .max()
            .unwrap_or(0);
        assert_eq!(max_round as usize, cfg.rounds);
    }

    #[test]
    fn thread_mode_stage_fleet_converges() {
        // 2 clusters × 2 stage processes (threads here): per-stage rings
        // reduce independently, the 1F1B dataflow runs over TCP stage
        // links, and the assembled model converges.
        let mut cfg = ElasticConfig::synthetic_pipeline(2, 2, 5, 16);
        cfg.transport.ring_timeout_ms = 1000;
        cfg.transport.connect_timeout_ms = 5000;
        cfg.wall_timeout_ms = 60_000;
        let out = run_elastic(&cfg, &SpawnMode::Thread).unwrap();
        assert_eq!(out.epochs, 1, "no churn expected");
        assert_eq!(out.survivors, vec![0, 1]);
        assert!(out.total_wire_bytes > 0);
        assert_eq!(out.final_params.len(), 2 * 16);
        let r1: Vec<f32> = out
            .round_losses
            .iter()
            .filter(|(_, r, _)| *r == 1)
            .map(|(_, _, l)| *l)
            .collect();
        assert_eq!(r1.len(), 2, "one labels-bearing heartbeat per cluster");
        let r1_mean = r1.iter().sum::<f32>() / r1.len() as f32;
        assert!(
            out.final_loss < r1_mean * 0.5,
            "final {} vs round-1 {}",
            out.final_loss,
            r1_mean
        );
    }

    #[test]
    fn thread_mode_stage_fleet_survives_stage_kill() {
        // Kill ONE stage process (cluster 1, stage 1) at round 2: its
        // whole cluster drops out, the surviving clusters' per-stage
        // rings re-form, and the run completes with a finite final eval.
        let mut cfg = ElasticConfig::synthetic_pipeline(3, 2, 6, 16);
        cfg.transport.ring_timeout_ms = 1000;
        cfg.transport.connect_timeout_ms = 5000;
        cfg.wall_timeout_ms = 90_000;
        cfg.faults.enabled = true;
        cfg.faults.kill_rank = 1;
        cfg.faults.kill_stage = 1;
        cfg.faults.kill_round = 2;
        let out = run_elastic(&cfg, &SpawnMode::Thread).unwrap();
        assert_eq!(out.survivors, vec![0, 2], "cluster 1 must be gone entirely");
        assert!(
            out.epochs >= 2,
            "expected re-formed stage rings, got {}",
            out.epochs
        );
        assert!(out.final_loss.is_finite());
        // Survivors completed the full schedule after recovery.
        let max_round = out
            .round_losses
            .iter()
            .map(|(_, r, _)| *r)
            .max()
            .unwrap_or(0);
        assert_eq!(max_round as usize, cfg.rounds);
    }

    #[test]
    fn thread_mode_zero_bubble_stage_fleet_kill_drains() {
        // Churn under the ZB-H1 stream: kill one stage process of
        // cluster 1 mid-run with overlap on.  The split-backward
        // schedule must not change the drain story — the survivors
        // finish the held per-stage reductions (≥ 1 drain commit) and
        // complete every round.
        let mut cfg = ElasticConfig::synthetic_pipeline(3, 2, 5, 16);
        cfg.schedule = "zero-bubble".into();
        cfg.overlap = true;
        cfg.outer_lr = 0.3;
        cfg.outer_momentum = 0.3;
        cfg.transport.ring_timeout_ms = 1000;
        cfg.transport.connect_timeout_ms = 5000;
        cfg.wall_timeout_ms = 90_000;
        cfg.faults.enabled = true;
        cfg.faults.kill_rank = 1;
        cfg.faults.kill_stage = 0;
        cfg.faults.kill_round = 2;
        let out = run_elastic(&cfg, &SpawnMode::Thread).unwrap();
        assert_eq!(out.survivors, vec![0, 2], "cluster 1 must be gone entirely");
        assert!(out.epochs >= 2, "epochs={}", out.epochs);
        assert!(
            out.recoveries.iter().any(|&(_, _, d)| d > 0),
            "expected at least one per-stage drain commit, got {:?}",
            out.recoveries
        );
        assert!(out.final_loss.is_finite());
        let max_round = out
            .round_losses
            .iter()
            .map(|(_, r, _)| *r)
            .max()
            .unwrap_or(0);
        assert_eq!(max_round as usize, cfg.rounds);
    }

    #[test]
    fn thread_mode_zero_bubble_stage_fleet_soft_break_discards() {
        // Soft cluster-wide break on the zero-bubble fleet: cluster 1
        // parks at round 3 holding stale deltas while the others run
        // ahead — mixed in-flight evidence, so every stage ring must
        // DISCARD; nobody dies and the fleet completes.
        let mut cfg = ElasticConfig::synthetic_pipeline(3, 2, 6, 16);
        cfg.schedule = "zero-bubble".into();
        cfg.overlap = true;
        cfg.outer_lr = 0.3;
        cfg.outer_momentum = 0.3;
        cfg.transport.ring_timeout_ms = 1000;
        cfg.transport.connect_timeout_ms = 5000;
        cfg.wall_timeout_ms = 90_000;
        cfg.faults.enabled = true;
        cfg.faults.break_rank = 1;
        cfg.faults.break_round = 3;
        let out = run_elastic(&cfg, &SpawnMode::Thread).unwrap();
        assert_eq!(out.survivors, vec![0, 1, 2], "nobody died");
        assert!(out.epochs >= 2, "epochs={}", out.epochs);
        assert!(
            out.recoveries.iter().all(|&(_, _, d)| d == 0),
            "mixed in-flight must discard, got {:?}",
            out.recoveries
        );
        assert!(out.final_loss.is_finite());
        let max_round = out
            .round_losses
            .iter()
            .map(|(_, r, _)| *r)
            .max()
            .unwrap_or(0);
        assert_eq!(max_round as usize, cfg.rounds);
    }

    #[test]
    fn thread_mode_interleaved_stage_fleet_converges() {
        // v=2 virtual stages on a 4-stage model: each cluster runs
        // pp_stages / v = 2 executor processes owning 2 chunks each, the
        // stage-link chain closes into a ring (chunk wrap hops), and the
        // assembled 4-stage model still converges.
        let mut cfg = ElasticConfig::synthetic_pipeline(2, 4, 5, 16);
        cfg.schedule = "interleaved".into();
        cfg.virtual_stages = 2;
        cfg.transport.ring_timeout_ms = 1000;
        cfg.transport.connect_timeout_ms = 5000;
        cfg.wall_timeout_ms = 60_000;
        assert_eq!(cfg.stage_execs(), 2);
        let out = run_elastic(&cfg, &SpawnMode::Thread).unwrap();
        assert_eq!(out.epochs, 1, "no churn expected");
        assert_eq!(out.survivors, vec![0, 1]);
        assert!(out.total_wire_bytes > 0);
        assert_eq!(out.final_params.len(), 4 * 16);
        let r1: Vec<f32> = out
            .round_losses
            .iter()
            .filter(|(_, r, _)| *r == 1)
            .map(|(_, _, l)| *l)
            .collect();
        assert!(!r1.is_empty());
        let r1_mean = r1.iter().sum::<f32>() / r1.len() as f32;
        assert!(
            out.final_loss < r1_mean,
            "final {} vs round-1 {}",
            out.final_loss,
            r1_mean
        );
    }

    #[test]
    fn stage_fault_plan_targets_one_process() {
        let f = FaultConfig {
            enabled: true,
            kill_rank: 1,
            kill_stage: 2,
            kill_round: 3,
            ..FaultConfig::default()
        };
        assert!(stage_fault_plan_for(&f, 0, 2, false).is_none());
        assert!(stage_fault_plan_for(&f, 1, 0, false).is_none());
        let p = stage_fault_plan_for(&f, 1, 2, true).unwrap();
        assert_eq!(p.kill_round, 3);
        assert!(p.exit_on_kill);
    }

    #[test]
    fn params_digest_caps_large_vectors() {
        let small = vec![1.0f32; 100];
        assert_eq!(params_digest(&small), small);
        let big: Vec<f32> = (0..200_000).map(|i| i as f32).collect();
        let d = params_digest(&big);
        assert!(d.len() <= PARAMS_DIGEST_MAX, "len={}", d.len());
        assert_eq!(d[0], 0.0);
        // Deterministic: identical vectors digest identically on every
        // worker, so elementwise agreement checks stay valid.
        assert_eq!(d, params_digest(&big));
    }

    #[test]
    fn fault_plan_filtering_by_rank() {
        let f = FaultConfig {
            enabled: true,
            kill_rank: 2,
            kill_round: 3,
            ..FaultConfig::default()
        };
        assert!(fault_plan_for(&f, 0, false).is_none());
        let p = fault_plan_for(&f, 2, true).unwrap();
        assert_eq!(p.kill_round, 3);
        assert!(p.exit_on_kill);
    }

    fn hier_cfg(sites: &[u32]) -> ElasticConfig {
        let mut c = quick_cfg(sites.len());
        c.reduce_topology = ReduceTopology::Hier;
        c.sites = sites.to_vec();
        c
    }

    /// Tentpole determinism contract, leg 1: the hierarchical loopback
    /// TCP fleet is bit-for-bit the hierarchical local-mpsc fleet —
    /// params, mean loss, and the wire ledger — because the hier float
    /// schedule is a pure function of (site, rank) order.
    #[test]
    fn thread_mode_hier_fleet_matches_local_reference_bit_for_bit() {
        let cfg = hier_cfg(&[0, 0, 1, 1]);
        let (ref_params, ref_loss, ref_wire) = run_local_reference(&cfg).unwrap();
        let out = run_elastic(&cfg, &SpawnMode::Thread).unwrap();
        assert_eq!(out.epochs, 1, "no churn expected");
        assert_eq!(out.survivors, vec![0, 1, 2, 3]);
        assert_eq!(out.final_params, ref_params, "hier TCP != hier mpsc");
        assert_eq!(out.final_loss, ref_loss);
        assert_eq!(out.total_wire_bytes, ref_wire, "wire ledger diverged");
    }

    /// Tentpole determinism contract, leg 2: a single-site hierarchical
    /// run degenerates to a pure delegation and is bit-for-bit today's
    /// flat ring — reference vs reference AND deployed fleet vs both.
    #[test]
    fn hier_single_site_is_bit_for_bit_the_flat_ring() {
        let flat = quick_cfg(3);
        let mut hier = quick_cfg(3);
        hier.reduce_topology = ReduceTopology::Hier;
        hier.sites = vec![7, 7, 7];
        let (fp, fl, fw) = run_local_reference(&flat).unwrap();
        let (hp, hl, hw) = run_local_reference(&hier).unwrap();
        assert_eq!(fp, hp, "single-site hier mpsc != flat mpsc");
        assert_eq!(fl, hl);
        assert_eq!(fw, hw);
        let out = run_elastic(&hier, &SpawnMode::Thread).unwrap();
        assert_eq!(out.final_params, fp, "single-site hier TCP != flat");
        assert_eq!(out.final_loss, fl);
        assert_eq!(out.total_wire_bytes, fw);
    }

    /// Leader death under hier + overlap: kill the site-1 leader (rank 2,
    /// first member of its site in (site, rank) order) mid-run.  The
    /// survivors re-form, leadership of site 1 falls to rank 3 purely by
    /// position in the committed order, and the drain branch finishes the
    /// in-flight reduction — the `recoveries` ledger shows the commit.
    #[test]
    fn thread_mode_hier_leader_kill_recovers_via_drain() {
        let mut cfg = hier_cfg(&[0, 0, 1, 1]);
        cfg.overlap = true;
        cfg.faults.enabled = true;
        cfg.faults.kill_rank = 2;
        cfg.faults.kill_round = 2;
        let out = run_elastic(&cfg, &SpawnMode::Thread).unwrap();
        assert_eq!(out.survivors, vec![0, 1, 3]);
        assert!(out.epochs >= 2, "epochs={}", out.epochs);
        assert!(
            out.recoveries.iter().any(|&(_, _, d)| d > 0),
            "expected a drain commit, got {:?}",
            out.recoveries
        );
        assert!(out.final_loss.is_finite());
        let max_round =
            out.round_losses.iter().map(|(_, r, _)| *r).max().unwrap_or(0);
        assert_eq!(max_round as usize, cfg.rounds);
    }

    /// The discard branch under hier: a soft break (rank 1 parks without
    /// dying) leaves mixed in-flight evidence, so the coordinator must
    /// discard — and everyone, breaker included, completes.
    #[test]
    fn thread_mode_hier_soft_break_recovers_via_discard() {
        let mut cfg = hier_cfg(&[0, 0, 1, 1]);
        cfg.overlap = true;
        cfg.faults.enabled = true;
        cfg.faults.break_rank = 1;
        cfg.faults.break_round = 3;
        let out = run_elastic(&cfg, &SpawnMode::Thread).unwrap();
        assert_eq!(out.survivors, vec![0, 1, 2, 3], "nobody died");
        assert!(out.epochs >= 2, "epochs={}", out.epochs);
        assert!(
            out.recoveries.iter().all(|&(_, _, d)| d == 0),
            "mixed in-flight must discard, got {:?}",
            out.recoveries
        );
        assert!(out.final_loss.is_finite());
        let max_round =
            out.round_losses.iter().map(|(_, r, _)| *r).max().unwrap_or(0);
        assert_eq!(max_round as usize, cfg.rounds);
    }

    /// The reordered topology over loopback: the probe phase measures
    /// every directed pair, the fleet completes on the reordered ring,
    /// and the measured links surface in the outcome ledger (what
    /// `coordinate --report` serializes for the DES round-trip).
    #[test]
    fn thread_mode_reordered_fleet_probes_and_converges() {
        let mut cfg = quick_cfg(3);
        cfg.reduce_topology = ReduceTopology::Reordered;
        // Small probe payload: this is a wiring test, not a benchmark.
        cfg.probe_payload_elems = 2048;
        cfg.probe_repeats = 2;
        let out = run_elastic(&cfg, &SpawnMode::Thread).unwrap();
        assert_eq!(out.epochs, 1, "no churn expected");
        assert_eq!(out.survivors, vec![0, 1, 2]);
        assert_eq!(out.links.len(), 6, "3 workers = 6 directed links");
        assert!(
            out.links.iter().all(|&(_, _, g, _)| g > 0.0),
            "loopback links must all measure: {:?}",
            out.links
        );
        assert!(out.final_loss.is_finite());
        let max_round =
            out.round_losses.iter().map(|(_, r, _)| *r).max().unwrap_or(0);
        assert_eq!(max_round as usize, cfg.rounds);
    }
}
