//! Elastic multi-process coordinator: spawns one `dilocox worker` OS
//! process per cluster, runs DiLoCo-style outer rounds over the TCP ring,
//! and survives worker death mid-round by re-forming the ring with the
//! survivors (the membership epoch protocol documented in
//! [`crate::transport`]).
//!
//! Recovery model: any ring failure (peer death, stall past the socket
//! timeout) makes every survivor report `RingBroken{applied_rounds}` and
//! park on its control socket; the coordinator bumps the epoch, runs the
//! 2PC prepare/commit over the survivors, and the new ring opens with a
//! consensus `allreduce_mean` over θ_g plus an outer-momentum restart, so
//! survivors re-agree on the global parameters before training resumes at
//! `max(applied)+1`.  The pseudo-gradient mean rescales automatically: the
//! collective mean is over the *current* member count.
//!
//! Workloads: the real-numerics PJRT trainer (needs an artifact bundle),
//! or a synthetic per-worker quadratic that exercises the full outer loop
//! (H local steps, pseudo-gradient ring mean, Nesterov outer step) with no
//! artifacts — what the churn integration tests and the zero-dependency
//! demo path run.

use crate::config::{ExperimentConfig, FaultConfig, TransportConfig};
use crate::data::{MarkovCorpus, ShardIter};
use crate::optim::{AdamW, Nesterov};
use crate::rounds::{movement, DeltaReducer, RoundEngine};
use crate::runtime::Runtime;
use crate::transport::faulty::{FaultPlan, FaultyRing};
use crate::transport::frame::{read_msg, write_msg, Msg};
use crate::transport::tcp;
use crate::transport::RingTransport;
use crate::util::rng::Pcg32;
use anyhow::{anyhow, Context, Result};
use std::collections::{BTreeMap, BTreeSet};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::process::{Command, Stdio};
use std::sync::mpsc;
use std::time::{Duration, Instant};

/// What each worker trains between syncs.
#[derive(Clone, Debug)]
pub enum Workload {
    /// Synthetic: worker w owns f_w(θ) = ½·mean((θ − c_w)²) with
    /// c_w = c_shared + 0.1·noise_w; the ring mean drives θ_g to the
    /// member-average target, so convergence is observable without any
    /// artifact bundle.
    Quadratic { dim: usize },
    /// Real numerics through the PJRT runtime (artifact bundle on disk).
    Runtime { artifacts_dir: String },
}

/// Everything a worker process/thread needs (mirrors the CLI flags of
/// `dilocox worker`).
#[derive(Clone, Debug)]
pub struct WorkerOpts {
    /// Coordinator control address, e.g. "127.0.0.1:41234".
    pub coord: String,
    pub rank: u32,
    pub rounds: usize,
    pub local_steps: usize,
    pub inner_lr: f32,
    pub weight_decay: f32,
    pub outer_lr: f32,
    pub outer_momentum: f32,
    pub seed: u64,
    pub workload: Workload,
    pub ring_timeout_ms: u64,
    pub connect_timeout_ms: u64,
    pub faults: Option<FaultPlan>,
}

/// Elastic run parameters (derived from [`ExperimentConfig`] or built
/// directly by tests).
#[derive(Clone, Debug)]
pub struct ElasticConfig {
    pub workers: usize,
    pub rounds: usize,
    pub local_steps: usize,
    pub inner_lr: f32,
    pub weight_decay: f32,
    pub outer_lr: f32,
    pub outer_momentum: f32,
    pub seed: u64,
    pub workload: Workload,
    pub transport: TransportConfig,
    pub faults: FaultConfig,
    /// Hard wall-clock ceiling for the whole run (hang safety net).
    pub wall_timeout_ms: u64,
}

impl ElasticConfig {
    /// Synthetic-quadratic defaults tuned for fast, stable convergence.
    pub fn quadratic(workers: usize, rounds: usize, dim: usize) -> ElasticConfig {
        ElasticConfig {
            workers,
            rounds,
            local_steps: 8,
            inner_lr: 0.25,
            weight_decay: 0.0,
            outer_lr: 0.5,
            outer_momentum: 0.6,
            seed: 1234,
            workload: Workload::Quadratic { dim },
            transport: TransportConfig::default(),
            faults: FaultConfig::default(),
            wall_timeout_ms: 120_000,
        }
    }

    /// Lift an experiment config onto the elastic runner.  Runtime
    /// workloads pay per-process artifact load + H real training steps per
    /// round, so the hang safety net scales with the schedule instead of
    /// using the quick-test default.
    pub fn from_experiment(cfg: &ExperimentConfig, workload: Workload) -> ElasticConfig {
        let wall_timeout_ms = match &workload {
            Workload::Quadratic { .. } => 120_000,
            // Generous: artifact load/compile + T rounds of H steps.
            Workload::Runtime { .. } => {
                600_000 + 60_000 * cfg.train.outer_steps as u64
            }
        };
        ElasticConfig {
            workers: cfg.parallel.dp,
            rounds: cfg.train.outer_steps,
            local_steps: cfg.train.local_steps,
            inner_lr: cfg.train.inner_lr,
            weight_decay: cfg.train.weight_decay,
            outer_lr: cfg.train.outer_lr,
            outer_momentum: cfg.train.outer_momentum,
            seed: cfg.train.seed,
            workload,
            transport: cfg.transport.clone(),
            faults: cfg.faults.clone(),
            wall_timeout_ms,
        }
    }
}

/// How the coordinator launches workers.
#[derive(Clone, Debug)]
pub enum SpawnMode {
    /// `std::process::Command` on the given `dilocox` binary — the real
    /// deployment shape: a crashed worker is an EOF, not a crashed run.
    Process { exe: String },
    /// In-process threads (unit tests; injected kills become error
    /// returns instead of `process::exit`).
    Thread,
}

#[derive(Debug)]
pub struct ElasticOutcome {
    pub rounds: usize,
    /// Final committed membership epoch (1 = no churn happened).
    pub epochs: u32,
    pub started: usize,
    pub survivors: Vec<u32>,
    /// Mean of the survivors' final eval losses.
    pub final_loss: f32,
    /// First survivor's parameter digest (full vector up to
    /// [`PARAMS_DIGEST_MAX`] elements, strided sample beyond — see
    /// [`params_digest`]).
    pub final_params: Vec<f32>,
    pub total_wire_bytes: u64,
    /// Heartbeat telemetry: (worker, round, loss).
    pub round_losses: Vec<(u32, u32, f32)>,
}

impl ElasticOutcome {
    /// Heartbeats aggregated per round: (round, mean loss, reporting
    /// workers).  Rounds with no heartbeat (e.g. lost to churn) are
    /// omitted.
    pub fn mean_loss_per_round(&self) -> Vec<(u32, f32, usize)> {
        let mut out = Vec::new();
        for r in 1..=self.rounds as u32 {
            let ls: Vec<f32> = self
                .round_losses
                .iter()
                .filter(|(_, round, _)| *round == r)
                .map(|(_, _, l)| *l)
                .collect();
            if !ls.is_empty() {
                out.push((r, ls.iter().sum::<f32>() / ls.len() as f32, ls.len()));
            }
        }
        out
    }
}

/// Cap on the parameter digest a worker ships in its `Done` report.  The
/// digest exists for the coordinator's cross-worker agreement check and
/// telemetry, not for checkpointing — shipping a 100M-param vector over
/// the control socket would be wasteful and anything over ~268M f32s
/// would blow the 1 GiB frame guard.  Every worker samples the same
/// strided indices, so elementwise comparison stays valid.
pub const PARAMS_DIGEST_MAX: usize = 65_536;

/// Full vector when small, deterministic strided sample when large.
pub fn params_digest(params: &[f32]) -> Vec<f32> {
    if params.len() <= PARAMS_DIGEST_MAX {
        return params.to_vec();
    }
    let stride = params.len().div_ceil(PARAMS_DIGEST_MAX);
    params.iter().step_by(stride).copied().collect()
}

/// Per-rank fault plan from the `[faults]` config section.
pub fn fault_plan_for(
    faults: &FaultConfig,
    rank: u32,
    exit_on_kill: bool,
) -> Option<FaultPlan> {
    if !faults.enabled {
        return None;
    }
    let plan = FaultPlan {
        seed: faults.seed,
        delay_prob: faults.delay_prob,
        max_delay_ms: faults.delay_ms,
        kill_round: if rank as usize == faults.kill_rank { faults.kill_round } else { 0 },
        straggler_ms: if rank as usize == faults.straggler_rank {
            faults.straggler_ms
        } else {
            0
        },
        exit_on_kill,
    };
    if plan.is_quiet() {
        None
    } else {
        Some(plan)
    }
}

// ---------------------------------------------------------------------------
// Worker side
// ---------------------------------------------------------------------------

/// What a worker trains between syncs (kept object-safe so the quadratic
/// and PJRT paths share one outer loop).
trait LocalTrainer {
    fn dim(&self) -> usize;
    fn params(&self) -> &[f32];
    fn set_params(&mut self, p: &[f32]);
    /// Run `h` inner steps from the current params; returns the mean loss.
    fn local_round(&mut self, h: usize) -> Result<f32>;
    fn eval(&mut self) -> Result<f32>;
}

struct QuadraticTrainer {
    params: Vec<f32>,
    target: Vec<f32>,
    lr: f32,
}

impl QuadraticTrainer {
    fn new(dim: usize, rank: u32, seed: u64, lr: f32) -> QuadraticTrainer {
        // Shared optimum + small per-worker displacement: the member-mean
        // target is near the shared component, so the global loss falls
        // from ~0.5 to ~the displacement variance as θ_g converges.
        let mut shared = vec![0.0f32; dim];
        Pcg32::new(seed ^ 0x7a67, 0).fill_normal(&mut shared, 0.0, 1.0);
        let mut noise = vec![0.0f32; dim];
        Pcg32::new(seed ^ 0x7a67, 1 + rank as u64).fill_normal(&mut noise, 0.0, 1.0);
        let target: Vec<f32> =
            shared.iter().zip(&noise).map(|(s, n)| s + 0.1 * n).collect();
        QuadraticTrainer { params: vec![0.0; dim], target, lr }
    }

    fn loss(&self) -> f32 {
        let n = self.params.len() as f32;
        0.5 * self
            .params
            .iter()
            .zip(&self.target)
            .map(|(p, t)| (p - t) * (p - t))
            .sum::<f32>()
            / n
    }
}

impl LocalTrainer for QuadraticTrainer {
    fn dim(&self) -> usize {
        self.params.len()
    }

    fn params(&self) -> &[f32] {
        &self.params
    }

    fn set_params(&mut self, p: &[f32]) {
        self.params.copy_from_slice(p);
    }

    fn local_round(&mut self, h: usize) -> Result<f32> {
        // Report the loss at entry (current θ_g) so the round curve is
        // directly comparable to the final eval.
        let loss = self.loss();
        for _ in 0..h {
            for (p, t) in self.params.iter_mut().zip(&self.target) {
                let g = *p - *t;
                *p -= self.lr * g;
            }
        }
        Ok(loss)
    }

    fn eval(&mut self) -> Result<f32> {
        Ok(self.loss())
    }
}

struct RuntimeTrainer {
    rt: Runtime,
    params: Vec<f32>,
    inner: AdamW,
    shard: ShardIter,
    corpus: std::sync::Arc<MarkovCorpus>,
    seed: u64,
    microbatch: usize,
    seq_len: usize,
}

impl RuntimeTrainer {
    fn new(dir: &str, rank: u32, opts: &WorkerOpts) -> Result<RuntimeTrainer> {
        let rt = Runtime::load(dir)
            .with_context(|| format!("loading artifacts from {dir}"))?;
        rt.precompile(&["step_single", "eval_single"])?;
        let man = &rt.manifest;
        let (b, s) = (man.dims.microbatch, man.dims.seq_len);
        let corpus =
            std::sync::Arc::new(MarkovCorpus::new(man.dims.vocab_size, opts.seed));
        let shard =
            ShardIter::new(std::sync::Arc::clone(&corpus), rank as usize, opts.seed, b, s);
        let params = man.read_f32(&man.init["single"].file)?;
        let n = man.param_count;
        Ok(RuntimeTrainer {
            inner: AdamW::new(n, opts.inner_lr, opts.weight_decay),
            params,
            shard,
            corpus,
            seed: opts.seed,
            microbatch: b,
            seq_len: s,
            rt,
        })
    }
}

impl LocalTrainer for RuntimeTrainer {
    fn dim(&self) -> usize {
        self.params.len()
    }

    fn params(&self) -> &[f32] {
        &self.params
    }

    fn set_params(&mut self, p: &[f32]) {
        self.params.copy_from_slice(p);
    }

    fn local_round(&mut self, h: usize) -> Result<f32> {
        let mut acc = 0.0f64;
        for _ in 0..h {
            let (tok, lab) = self.shard.next_batch();
            let (loss, grads) = self.rt.step_single(&self.params, &tok, &lab)?;
            self.inner.step(&mut self.params, &grads);
            acc += loss as f64;
        }
        Ok((acc / h.max(1) as f64) as f32)
    }

    fn eval(&mut self) -> Result<f32> {
        let mut it = ShardIter::new(
            std::sync::Arc::clone(&self.corpus),
            9999,
            self.seed ^ 0xe7a1,
            self.microbatch,
            self.seq_len,
        );
        let mut acc = 0.0f32;
        let batches = 3;
        for _ in 0..batches {
            let (t, l) = it.next_batch();
            acc += self.rt.eval_single(&self.params, &t, &l)?;
        }
        Ok(acc / batches as f32)
    }
}

fn build_trainer(opts: &WorkerOpts) -> Result<Box<dyn LocalTrainer>> {
    Ok(match &opts.workload {
        Workload::Quadratic { dim } => Box::new(QuadraticTrainer::new(
            *dim,
            opts.rank,
            opts.seed,
            opts.inner_lr,
        )),
        Workload::Runtime { artifacts_dir } => {
            Box::new(RuntimeTrainer::new(artifacts_dir, opts.rank, opts)?)
        }
    })
}

/// Single-lane [`DeltaReducer`] over an already-formed ring: raw fp32
/// pseudo-gradient mean, metering actual ring bytes (the elastic wire
/// ships uncompressed; compression lives in the coordinator paths).
struct RingMeanReducer<'a> {
    ring: &'a mut dyn RingTransport,
    wire: u64,
}

impl DeltaReducer for RingMeanReducer<'_> {
    fn begin(&mut self, _deltas: &[Vec<f32>], _round: u64) -> Result<()> {
        Ok(())
    }

    fn complete(&mut self, deltas: &[Vec<f32>], _round: u64) -> Result<Vec<f32>> {
        let mut d = deltas[0].clone();
        let before = self.ring.meter().total();
        self.ring.allreduce_mean(&mut d)?;
        self.wire += self.ring.meter().total() - before;
        Ok(d)
    }
}

/// Block on the control socket until the coordinator commits a membership
/// epoch newer than `after_epoch`; acks every Prepare seen on the way.
fn wait_for_commit(
    coord: &mut TcpStream,
    after_epoch: u32,
) -> Result<(u32, u32, Vec<(u32, u16)>)> {
    coord
        .set_read_timeout(Some(Duration::from_secs(120)))
        .ok();
    let mut prepared: Option<(u32, u32, Vec<(u32, u16)>)> = None;
    loop {
        match read_msg(coord) {
            Ok(Msg::Prepare { epoch, resume_round, members }) if epoch > after_epoch => {
                write_msg(coord, &Msg::PrepareAck { epoch })?;
                prepared = Some((epoch, resume_round, members));
            }
            Ok(Msg::Commit { epoch }) => {
                if let Some(p) = prepared.clone() {
                    if p.0 == epoch {
                        return Ok(p);
                    }
                }
                // A commit for an epoch we never prepared (superseded) —
                // keep waiting for the current one.
            }
            Ok(Msg::Shutdown) => {
                return Err(anyhow!("coordinator shut down before commit"))
            }
            Ok(_) => { /* stale frame — ignore */ }
            Err(e) => {
                return Err(anyhow!("control channel lost waiting for commit: {e:#}"))
            }
        }
    }
}

/// Worker entry point (the `dilocox worker` subcommand body).
pub fn run_worker(opts: &WorkerOpts) -> Result<()> {
    let addr: SocketAddr = opts
        .coord
        .parse()
        .map_err(|_| anyhow!("bad coordinator address '{}'", opts.coord))?;
    let connect_timeout = Duration::from_millis(opts.connect_timeout_ms);
    let ring_timeout = Duration::from_millis(opts.ring_timeout_ms);
    let mut coord = TcpStream::connect_timeout(&addr, connect_timeout)
        .with_context(|| format!("dialing coordinator {addr}"))?;
    coord.set_nodelay(true).ok();
    let listener = TcpListener::bind("127.0.0.1:0").context("binding ring listener")?;
    let ring_port = listener.local_addr()?.port();
    write_msg(&mut coord, &Msg::Hello { rank: opts.rank, ring_port })?;

    let mut trainer = build_trainer(opts)?;
    let dim = trainer.dim();
    // Outer rounds run through the shared engine (sync mode): θ_g moves
    // only by outer updates, and a failed collective leaves it untouched
    // so the next epoch resumes from the last committed state.
    let mut engine = RoundEngine::new(
        trainer.params().to_vec(),
        1,
        Nesterov::new(dim, opts.outer_lr, opts.outer_momentum),
        false,
        false,
    );
    let mut applied: usize = 0;
    let mut wire_total = 0u64;
    let mut epoch = 0u32;

    'epochs: loop {
        let (e, resume_round, members) = wait_for_commit(&mut coord, epoch)?;
        epoch = e;
        let formed = tcp::form_ring(
            opts.rank,
            epoch,
            &members,
            &listener,
            connect_timeout,
            ring_timeout,
        );
        let raw = match formed {
            Ok(r) => r,
            Err(_) => {
                let _ = write_msg(
                    &mut coord,
                    &Msg::RingBroken { epoch, applied_rounds: applied as u32 },
                );
                continue 'epochs;
            }
        };
        let mut ring: Box<dyn RingTransport> = match &opts.faults {
            Some(plan) => Box::new(FaultyRing::new(raw, plan.clone())),
            None => Box::new(raw),
        };

        // Consensus resync: survivors re-agree on θ_g (identical at epoch
        // 1; a true mean after churn) and the outer momentum restarts.
        let mut theta = engine.theta().to_vec();
        if ring.allreduce_mean(&mut theta).is_err() {
            let _ = write_msg(
                &mut coord,
                &Msg::RingBroken { epoch, applied_rounds: applied as u32 },
            );
            continue 'epochs;
        }
        engine.set_theta(&theta);
        engine.reset_outer();
        trainer.set_params(engine.theta());

        let mut round = resume_round as usize;
        while round <= opts.rounds {
            // Fault hook: an injected kill exits here (process mode) or
            // errors out (thread mode) — either way the control socket
            // drops and the coordinator sees a dead member.
            ring.begin_round(round)?;
            let loss = trainer.local_round(opts.local_steps)?;
            let mv = movement(engine.theta(), trainer.params());
            let mut red = RingMeanReducer { ring: ring.as_mut(), wire: 0 };
            if engine.finish_round(vec![mv], round as u64, &mut red).is_err() {
                let _ = write_msg(
                    &mut coord,
                    &Msg::RingBroken { epoch, applied_rounds: applied as u32 },
                );
                continue 'epochs;
            }
            wire_total += red.wire;
            trainer.set_params(engine.theta());
            applied = round;
            let _ = write_msg(&mut coord, &Msg::Heartbeat { round: round as u32, loss });
            round += 1;
        }
        break;
    }

    let final_loss = trainer.eval()?;
    write_msg(
        &mut coord,
        &Msg::Done {
            rounds: applied as u32,
            wire_bytes: wire_total,
            final_loss,
            params: params_digest(engine.theta()),
        },
    )?;
    // Park until Shutdown (or coordinator EOF).
    coord.set_read_timeout(Some(Duration::from_secs(120))).ok();
    let _ = read_msg(&mut coord);
    Ok(())
}

// ---------------------------------------------------------------------------
// Coordinator side
// ---------------------------------------------------------------------------

struct WorkerHandle {
    writer: TcpStream,
    ring_port: u16,
}

enum Event {
    Msg(u32, Msg),
    Closed(u32),
}

struct DoneReport {
    wire_bytes: u64,
    final_loss: f32,
    params: Vec<f32>,
}

fn spawn_workers(
    cfg: &ElasticConfig,
    mode: &SpawnMode,
    coord_addr: &str,
) -> Result<Vec<std::process::Child>> {
    let mut children = Vec::new();
    for rank in 0..cfg.workers as u32 {
        let opts = worker_opts_for(cfg, rank, coord_addr, mode);
        match mode {
            SpawnMode::Process { exe } => {
                let mut cmd = Command::new(exe);
                cmd.arg("worker")
                    .arg("--coord")
                    .arg(&opts.coord)
                    .arg("--rank")
                    .arg(rank.to_string())
                    .arg("--rounds")
                    .arg(cfg.rounds.to_string())
                    .arg("--local-steps")
                    .arg(cfg.local_steps.to_string())
                    .arg("--inner-lr")
                    .arg(cfg.inner_lr.to_string())
                    .arg("--weight-decay")
                    .arg(cfg.weight_decay.to_string())
                    .arg("--outer-lr")
                    .arg(cfg.outer_lr.to_string())
                    .arg("--outer-momentum")
                    .arg(cfg.outer_momentum.to_string())
                    .arg("--seed")
                    .arg(cfg.seed.to_string())
                    .arg("--ring-timeout-ms")
                    .arg(cfg.transport.ring_timeout_ms.to_string())
                    .arg("--connect-timeout-ms")
                    .arg(cfg.transport.connect_timeout_ms.to_string());
                match &cfg.workload {
                    Workload::Quadratic { dim } => {
                        cmd.arg("--workload").arg("quad");
                        cmd.arg("--dim").arg(dim.to_string());
                    }
                    Workload::Runtime { artifacts_dir } => {
                        cmd.arg("--workload").arg("runtime");
                        cmd.arg("--artifacts").arg(artifacts_dir);
                    }
                }
                if let Some(plan) = &opts.faults {
                    cmd.arg("--fault-seed")
                        .arg(plan.seed.to_string())
                        .arg("--fault-delay-prob")
                        .arg(plan.delay_prob.to_string())
                        .arg("--fault-delay-ms")
                        .arg(plan.max_delay_ms.to_string())
                        .arg("--fault-kill-round")
                        .arg(plan.kill_round.to_string())
                        .arg("--fault-straggler-ms")
                        .arg(plan.straggler_ms.to_string());
                }
                let child = cmd
                    .stdout(Stdio::null())
                    .stderr(Stdio::inherit())
                    .spawn()
                    .with_context(|| format!("spawning worker {rank} via {exe}"))?;
                children.push(child);
            }
            SpawnMode::Thread => {
                std::thread::spawn(move || {
                    if let Err(e) = run_worker(&opts) {
                        eprintln!("[worker {rank}] exited: {e:#}");
                    }
                });
            }
        }
    }
    Ok(children)
}

fn worker_opts_for(
    cfg: &ElasticConfig,
    rank: u32,
    coord_addr: &str,
    mode: &SpawnMode,
) -> WorkerOpts {
    let exit_on_kill = matches!(mode, SpawnMode::Process { .. });
    WorkerOpts {
        coord: coord_addr.to_string(),
        rank,
        rounds: cfg.rounds,
        local_steps: cfg.local_steps,
        inner_lr: cfg.inner_lr,
        weight_decay: cfg.weight_decay,
        outer_lr: cfg.outer_lr,
        outer_momentum: cfg.outer_momentum,
        seed: cfg.seed,
        workload: cfg.workload.clone(),
        ring_timeout_ms: cfg.transport.ring_timeout_ms,
        connect_timeout_ms: cfg.transport.connect_timeout_ms,
        faults: fault_plan_for(&cfg.faults, rank, exit_on_kill),
    }
}

/// Accept one control connection per worker and read its `Hello`.
fn accept_workers(
    listener: &TcpListener,
    expected: usize,
    deadline: Instant,
) -> Result<BTreeMap<u32, WorkerHandle>> {
    listener.set_nonblocking(true).context("control listener nonblocking")?;
    let mut map = BTreeMap::new();
    while map.len() < expected {
        match listener.accept() {
            Ok((stream, _)) => {
                stream.set_nonblocking(false).ok();
                stream.set_nodelay(true).ok();
                stream.set_read_timeout(Some(Duration::from_secs(10))).ok();
                let mut stream = stream;
                match read_msg(&mut stream) {
                    Ok(Msg::Hello { rank, ring_port }) => {
                        if map.contains_key(&rank) {
                            return Err(anyhow!("duplicate worker rank {rank}"));
                        }
                        stream.set_write_timeout(Some(Duration::from_secs(10))).ok();
                        map.insert(rank, WorkerHandle { writer: stream, ring_port });
                    }
                    _ => { /* not a worker — drop */ }
                }
            }
            Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                if Instant::now() >= deadline {
                    return Err(anyhow!(
                        "only {}/{} workers connected before the deadline",
                        map.len(),
                        expected
                    ));
                }
                std::thread::sleep(Duration::from_millis(10));
            }
            Err(e) => return Err(anyhow!("control accept failed: {e}")),
        }
    }
    Ok(map)
}

/// Reap spawned worker processes: give each a short grace window, then
/// kill.  Runs on every exit path so a failed coordination can't leave
/// orphaned workers training at full CPU.
fn reap_children(children: &mut [std::process::Child]) {
    let reap_deadline = Instant::now() + Duration::from_secs(5);
    for child in children.iter_mut() {
        loop {
            match child.try_wait() {
                Ok(Some(_)) => break,
                Ok(None) => {
                    if Instant::now() >= reap_deadline {
                        let _ = child.kill();
                        let _ = child.wait();
                        break;
                    }
                    std::thread::sleep(Duration::from_millis(20));
                }
                Err(_) => break,
            }
        }
    }
}

/// Run the elastic coordinator to completion.
pub fn run_elastic(cfg: &ElasticConfig, mode: &SpawnMode) -> Result<ElasticOutcome> {
    if cfg.workers == 0 {
        return Err(anyhow!("need at least one worker"));
    }
    let listener =
        TcpListener::bind("127.0.0.1:0").context("binding coordinator socket")?;
    let coord_addr = listener.local_addr()?.to_string();
    let mut children = spawn_workers(cfg, mode, &coord_addr)?;

    // Supervision can fail at many points (startup timeout, wall timeout,
    // every worker dying); reap the children on ALL of them, then
    // propagate the error.
    let supervised = supervise(cfg, &listener);
    reap_children(&mut children);
    let (epoch, done, round_losses) = supervised?;

    let survivors: Vec<u32> = done.keys().copied().collect();
    if survivors.is_empty() {
        return Err(anyhow!("no worker completed the run"));
    }
    let reports: Vec<&DoneReport> = done.values().collect();
    let p0 = &reports[0].params;
    let mut max_dev = 0.0f32;
    for r in &reports[1..] {
        let dev = p0
            .iter()
            .zip(&r.params)
            .map(|(a, b)| (a - b).abs())
            .fold(0.0f32, f32::max);
        max_dev = max_dev.max(dev);
    }
    if max_dev > 1e-4 {
        if epoch <= 1 {
            // No churn happened: the ring algebra is symmetric, so any
            // divergence is a real bug.
            return Err(anyhow!("workers diverged: max param dev {max_dev}"));
        }
        // With churn, a worker that broke during the *final* round can
        // legitimately miss the last outer update (its peers were already
        // done, so there was no ring left to redo it with).  Bounded
        // staleness, not corruption — report it instead of failing.
        eprintln!(
            "[elastic] survivors differ by max param dev {max_dev} after \
             {epoch} membership epochs (final-round churn staleness)"
        );
    }
    let final_loss =
        reports.iter().map(|r| r.final_loss).sum::<f32>() / reports.len() as f32;
    let total_wire_bytes = reports.iter().map(|r| r.wire_bytes).sum();
    Ok(ElasticOutcome {
        rounds: cfg.rounds,
        epochs: epoch,
        started: cfg.workers,
        survivors,
        final_loss,
        final_params: p0.clone(),
        total_wire_bytes,
        round_losses,
    })
}

/// Accept the fleet, run the 2PC epochs, and watch the run to completion;
/// returns (final epoch, done reports, heartbeat telemetry).  Sends
/// `Shutdown` to the fleet on success; error paths leave process cleanup
/// to the caller's [`reap_children`].
#[allow(clippy::type_complexity)]
fn supervise(
    cfg: &ElasticConfig,
    listener: &TcpListener,
) -> Result<(u32, BTreeMap<u32, DoneReport>, Vec<(u32, u32, f32)>)> {
    let wall_deadline = Instant::now() + Duration::from_millis(cfg.wall_timeout_ms);
    let startup_deadline = Instant::now()
        + Duration::from_millis(cfg.transport.connect_timeout_ms)
        + Duration::from_secs(10);
    let mut live = accept_workers(listener, cfg.workers, startup_deadline)?;

    // One reader thread per worker feeding a single event queue; the
    // handles keep the write half.
    let (tx, rx) = mpsc::channel::<Event>();
    for (&rank, handle) in live.iter() {
        let mut rs = handle.writer.try_clone().context("cloning control stream")?;
        rs.set_read_timeout(None).ok();
        let tx = tx.clone();
        std::thread::spawn(move || loop {
            match read_msg(&mut rs) {
                Ok(m) => {
                    if tx.send(Event::Msg(rank, m)).is_err() {
                        break;
                    }
                }
                Err(_) => {
                    let _ = tx.send(Event::Closed(rank));
                    break;
                }
            }
        });
    }
    drop(tx);

    let grace = Duration::from_millis(cfg.transport.ring_timeout_ms * 2 + 2000);
    let mut epoch: u32 = 0;
    let mut resume_round: u32 = 1;
    let mut done: BTreeMap<u32, DoneReport> = BTreeMap::new();
    let mut round_losses: Vec<(u32, u32, f32)> = Vec::new();

    // Small helper applied to every event everywhere: telemetry +
    // resume-round bookkeeping.
    fn note_progress(
        ev: &Event,
        resume_round: &mut u32,
        round_losses: &mut Vec<(u32, u32, f32)>,
    ) {
        if let Event::Msg(w, Msg::Heartbeat { round, loss }) = ev {
            round_losses.push((*w, *round, *loss));
            *resume_round = (*resume_round).max(round + 1);
        }
        if let Event::Msg(_, Msg::RingBroken { applied_rounds, .. }) = ev {
            *resume_round = (*resume_round).max(applied_rounds + 1);
        }
    }

    'epochs: loop {
        if Instant::now() >= wall_deadline {
            return Err(anyhow!("elastic run exceeded the wall timeout"));
        }
        if live.is_empty() {
            return Err(anyhow!("all workers died"));
        }
        let pending: Vec<u32> =
            live.keys().copied().filter(|r| !done.contains_key(r)).collect();
        if pending.is_empty() {
            break;
        }

        // -- 2PC prepare/commit over the pending members ------------------
        epoch += 1;
        let members: Vec<(u32, u16)> =
            pending.iter().map(|r| (*r, live[r].ring_port)).collect();
        let mut lost: Vec<u32> = Vec::new();
        for &r in &pending {
            let h = live.get_mut(&r).unwrap();
            if write_msg(
                &mut h.writer,
                &Msg::Prepare { epoch, resume_round, members: members.clone() },
            )
            .is_err()
            {
                lost.push(r);
            }
        }
        if !lost.is_empty() {
            for r in lost {
                live.remove(&r);
            }
            continue 'epochs;
        }

        let mut acked: BTreeSet<u32> = BTreeSet::new();
        let ack_deadline = Instant::now() + grace;
        while !pending
            .iter()
            .all(|r| acked.contains(r) || done.contains_key(r) || !live.contains_key(r))
        {
            let left = ack_deadline.saturating_duration_since(Instant::now());
            if left.is_zero() {
                // Someone never acked (e.g. still stuck in an old ring's
                // timeout window) — supersede with a fresh epoch.
                continue 'epochs;
            }
            match rx.recv_timeout(left) {
                Ok(ev) => {
                    note_progress(&ev, &mut resume_round, &mut round_losses);
                    match ev {
                        Event::Msg(w, Msg::PrepareAck { epoch: e }) if e == epoch => {
                            acked.insert(w);
                        }
                        // A worker can finish (its Done racing our
                        // Prepare) — record it rather than dropping the
                        // completion report; it leaves `pending` via the
                        // loop condition and the next epoch's membership.
                        Event::Msg(w, Msg::Done { wire_bytes, final_loss, params, .. }) => {
                            done.insert(w, DoneReport { wire_bytes, final_loss, params });
                        }
                        Event::Closed(w) => {
                            if !done.contains_key(&w) {
                                live.remove(&w);
                                continue 'epochs;
                            }
                        }
                        _ => {}
                    }
                }
                Err(mpsc::RecvTimeoutError::Timeout) => continue,
                Err(mpsc::RecvTimeoutError::Disconnected) => {
                    return Err(anyhow!("all control channels lost"))
                }
            }
        }

        // A pending member that finished during the ack wait leaves the
        // proposed membership stale — don't commit a ring containing a
        // worker that will never join it; re-prepare without it.
        if pending.iter().any(|r| done.contains_key(r)) {
            continue 'epochs;
        }

        let mut lost: Vec<u32> = Vec::new();
        for &r in &pending {
            if let Some(h) = live.get_mut(&r) {
                if write_msg(&mut h.writer, &Msg::Commit { epoch }).is_err() {
                    lost.push(r);
                }
            }
        }
        if !lost.is_empty() {
            for r in lost {
                live.remove(&r);
            }
            continue 'epochs;
        }

        // -- committed: watch the epoch run -------------------------------
        let mut broken: BTreeSet<u32> = BTreeSet::new();
        loop {
            if Instant::now() >= wall_deadline {
                return Err(anyhow!("elastic run exceeded the wall timeout"));
            }
            let churn = match rx.recv_timeout(Duration::from_millis(200)) {
                Ok(ev) => {
                    note_progress(&ev, &mut resume_round, &mut round_losses);
                    match ev {
                        Event::Msg(w, Msg::Done { wire_bytes, final_loss, params, .. }) => {
                            done.insert(w, DoneReport { wire_bytes, final_loss, params });
                            false
                        }
                        Event::Msg(w, Msg::RingBroken { .. }) => {
                            broken.insert(w);
                            true
                        }
                        Event::Closed(w) => {
                            if done.contains_key(&w) {
                                false
                            } else {
                                live.remove(&w);
                                true
                            }
                        }
                        _ => false,
                    }
                }
                Err(mpsc::RecvTimeoutError::Timeout) => false,
                Err(mpsc::RecvTimeoutError::Disconnected) => {
                    return Err(anyhow!("all control channels lost"))
                }
            };
            if live.keys().all(|r| done.contains_key(r)) {
                break 'epochs;
            }
            if !churn {
                continue;
            }
            // Churn: drain until every live, not-done member has reported
            // its break (or a grace period passes), then re-form.
            let drain_deadline = Instant::now() + grace;
            loop {
                let outstanding = live
                    .keys()
                    .filter(|r| !done.contains_key(r) && !broken.contains(r))
                    .count();
                if outstanding == 0 || Instant::now() >= drain_deadline {
                    break;
                }
                if let Ok(ev) = rx.recv_timeout(Duration::from_millis(100)) {
                    note_progress(&ev, &mut resume_round, &mut round_losses);
                    match ev {
                        Event::Msg(w, Msg::RingBroken { .. }) => {
                            broken.insert(w);
                        }
                        Event::Msg(w, Msg::Done { wire_bytes, final_loss, params, .. }) => {
                            done.insert(w, DoneReport { wire_bytes, final_loss, params });
                        }
                        Event::Closed(w) => {
                            if !done.contains_key(&w) {
                                live.remove(&w);
                            }
                        }
                        _ => {}
                    }
                }
            }
            continue 'epochs;
        }
    }

    // -- success: graceful shutdown (caller reaps the processes) ----------
    for h in live.values_mut() {
        let _ = write_msg(&mut h.writer, &Msg::Shutdown);
    }
    Ok((epoch, done, round_losses))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn quick_cfg(workers: usize) -> ElasticConfig {
        let mut c = ElasticConfig::quadratic(workers, 6, 32);
        c.transport.ring_timeout_ms = 1000;
        c.transport.connect_timeout_ms = 5000;
        c.wall_timeout_ms = 60_000;
        c
    }

    #[test]
    fn thread_mode_three_workers_converge() {
        let out = run_elastic(&quick_cfg(3), &SpawnMode::Thread).unwrap();
        assert_eq!(out.epochs, 1, "no churn expected");
        assert_eq!(out.survivors, vec![0, 1, 2]);
        assert!(out.total_wire_bytes > 0);
        // Round-1 mean loss should beat the final loss decisively.
        let r1: Vec<f32> = out
            .round_losses
            .iter()
            .filter(|(_, r, _)| *r == 1)
            .map(|(_, _, l)| *l)
            .collect();
        assert!(!r1.is_empty());
        let r1_mean = r1.iter().sum::<f32>() / r1.len() as f32;
        assert!(
            out.final_loss < r1_mean * 0.5,
            "final {} vs round-1 {}",
            out.final_loss,
            r1_mean
        );
    }

    #[test]
    fn thread_mode_survives_injected_kill() {
        let mut cfg = quick_cfg(3);
        cfg.faults.enabled = true;
        cfg.faults.kill_rank = 1;
        cfg.faults.kill_round = 2;
        let out = run_elastic(&cfg, &SpawnMode::Thread).unwrap();
        assert_eq!(out.survivors, vec![0, 2]);
        assert!(out.epochs >= 2, "expected a re-formed ring, got {}", out.epochs);
        assert!(out.final_loss.is_finite());
        // Survivors must have completed every round.
        let max_round = out
            .round_losses
            .iter()
            .map(|(_, r, _)| *r)
            .max()
            .unwrap_or(0);
        assert_eq!(max_round as usize, cfg.rounds);
    }

    #[test]
    fn params_digest_caps_large_vectors() {
        let small = vec![1.0f32; 100];
        assert_eq!(params_digest(&small), small);
        let big: Vec<f32> = (0..200_000).map(|i| i as f32).collect();
        let d = params_digest(&big);
        assert!(d.len() <= PARAMS_DIGEST_MAX, "len={}", d.len());
        assert_eq!(d[0], 0.0);
        // Deterministic: identical vectors digest identically on every
        // worker, so elementwise agreement checks stay valid.
        assert_eq!(d, params_digest(&big));
    }

    #[test]
    fn fault_plan_filtering_by_rank() {
        let f = FaultConfig {
            enabled: true,
            kill_rank: 2,
            kill_round: 3,
            ..FaultConfig::default()
        };
        assert!(fault_plan_for(&f, 0, false).is_none());
        let p = fault_plan_for(&f, 2, true).unwrap();
        assert_eq!(p.kill_round, 3);
        assert!(p.exit_on_kill);
    }
}
