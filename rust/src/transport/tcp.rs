//! Loopback-TCP ring backend: one `dilocox worker` OS process per cluster
//! (or per (cluster, stage) in the stage-parallel fleet), length-delimited
//! [`frame`](crate::transport::frame) messages over 127.0.0.1 sockets.
//! Ring formation is dial-successor / accept-predecessor with an
//! epoch-checked `RingHello` handshake; sockets carry read/write timeouts
//! so a dead or stalled peer surfaces as an error mid-collective instead
//! of a hang (the elastic coordinator's failure signal).
//!
//! Besides the ring ([`TcpRing`]) this module provides the TCP side of
//! the pipeline dataflow: [`TcpStageLink`] implements
//! [`StageLink`](crate::pipeline::exec::StageLink) over two neighbor
//! sockets (upstream carries Acts down / Grads up; downstream the
//! mirror), formed per membership epoch by [`form_stage_links`] with the
//! same epoch-checked handshake as the ring.  [`stage_ports`] defines the
//! deterministic listener layout used when
//! `[transport] stage_listen_base_port` is set.

use crate::pipeline::exec::StageLink;
use crate::transport::frame::{read_msg, write_msg, write_msg_with, Msg};
use crate::transport::{ByteMeter, RingTransport};
use anyhow::{anyhow, Context, Result};
use std::net::{TcpListener, TcpStream};
use std::sync::mpsc;
use std::time::{Duration, Instant};

/// One member's pair of ring links.  `None` links only for size-1 rings
/// (a single survivor keeps training; its collectives are no-ops).
///
/// Sends are decoupled onto a writer thread: every member of a ring step
/// sends *then* receives, so if all members blocked synchronously in
/// `write` on chunks larger than the socket buffers, the cycle would
/// deadlock until the write timeout.  Queueing the frame and returning
/// keeps the caller free to reach its `recv` — the classic full-duplex
/// requirement of ring collectives.  A dead peer still surfaces: the
/// writer thread exits on a write error, the next `send_next` sees the
/// hung-up queue, and `recv_prev` times out.
pub struct TcpRing {
    pos: usize,
    size: usize,
    tx_next: Option<mpsc::Sender<Vec<f32>>>,
    rx_prev: Option<TcpStream>,
    meter: ByteMeter,
    /// Payload buffers the writer has finished encoding, handed back so
    /// `send_next` reuses them instead of allocating per hop.
    spent_rx: Option<mpsc::Receiver<Vec<f32>>>,
    /// Spent receive buffers from the collective (via `recycle`).
    pool: Vec<Vec<f32>>,
}

impl RingTransport for TcpRing {
    fn rank(&self) -> usize {
        self.pos
    }

    fn size(&self) -> usize {
        self.size
    }

    fn send_next(&mut self, chunk: &[f32]) -> Result<()> {
        let tx = self
            .tx_next
            .as_ref()
            .ok_or_else(|| anyhow!("size-1 ring has no successor link"))?;
        // Prefer a recycled receive buffer, then a payload the writer has
        // already put on the wire; allocate only while the pool warms up.
        let mut buf = self
            .pool
            .pop()
            .or_else(|| self.spent_rx.as_ref().and_then(|rx| rx.try_recv().ok()))
            .unwrap_or_default();
        buf.clear();
        buf.extend_from_slice(chunk);
        tx.send(buf)
            .map_err(|_| anyhow!("tcp ring send: successor link closed"))
    }

    fn recv_prev(&mut self) -> Result<Vec<f32>> {
        let s = self
            .rx_prev
            .as_mut()
            .ok_or_else(|| anyhow!("size-1 ring has no predecessor link"))?;
        match read_msg(s).context("tcp ring recv")? {
            Msg::Data { payload } => Ok(payload),
            other => Err(anyhow!("expected Data frame, got {}", other.name())),
        }
    }

    fn meter(&self) -> &ByteMeter {
        &self.meter
    }

    fn recycle(&mut self, buf: Vec<f32>) {
        if self.pool.len() < 4 {
            self.pool.push(buf);
        }
    }
}

/// Dial `127.0.0.1:port` until it accepts or `deadline` passes.
fn dial_retry(port: u16, deadline: Instant) -> Result<TcpStream> {
    loop {
        match TcpStream::connect(("127.0.0.1", port)) {
            Ok(s) => return Ok(s),
            Err(e) => {
                if Instant::now() >= deadline {
                    return Err(anyhow!("dialing 127.0.0.1:{port} timed out: {e}"));
                }
                std::thread::sleep(Duration::from_millis(20));
            }
        }
    }
}

/// Dial `port` and run the epoch-checked `RingHello` handshake until the
/// peer acks as `expect_rank` on `epoch` (or `deadline` passes).  A peer
/// still on an older epoch silently drops us, which surfaces as a failed
/// ack read; we retry until the deadline.  Shared by ring-successor and
/// stage-link formation.
fn dial_handshake(
    port: u16,
    my_rank: u32,
    expect_rank: u32,
    epoch: u32,
    deadline: Instant,
    io_timeout: Duration,
) -> Result<TcpStream> {
    loop {
        let mut s = dial_retry(port, deadline)?;
        s.set_nodelay(true).ok();
        s.set_write_timeout(Some(io_timeout)).ok();
        s.set_read_timeout(Some(io_timeout)).ok();
        if write_msg(&mut s, &Msg::RingHello { rank: my_rank, epoch }).is_ok() {
            if let Ok(Msg::RingHello { rank, epoch: e }) = read_msg(&mut s) {
                if rank == expect_rank && e == epoch {
                    return Ok(s);
                }
            }
        }
        if Instant::now() >= deadline {
            return Err(anyhow!(
                "handshake with rank {expect_rank} (epoch {epoch}) timed out"
            ));
        }
        std::thread::sleep(Duration::from_millis(20));
    }
}

/// Accept the predecessor's connection on `listener`, discarding
/// connections whose `RingHello` names the wrong rank or a stale epoch.
/// A valid predecessor gets a `RingHello` ack back (so the dialer can
/// detect a wrong-epoch drop instead of sending into the void).
fn accept_predecessor(
    listener: TcpListener,
    my_rank: u32,
    expect_rank: u32,
    expect_epoch: u32,
    deadline: Instant,
    ring_timeout: Duration,
) -> Result<TcpStream> {
    listener
        .set_nonblocking(true)
        .context("listener nonblocking")?;
    loop {
        match listener.accept() {
            Ok((stream, _)) => {
                stream.set_nonblocking(false).ok();
                stream.set_read_timeout(Some(ring_timeout)).ok();
                stream.set_write_timeout(Some(ring_timeout)).ok();
                let mut stream = stream;
                match read_msg(&mut stream) {
                    Ok(Msg::RingHello { rank, epoch })
                        if rank == expect_rank && epoch == expect_epoch =>
                    {
                        if write_msg(
                            &mut stream,
                            &Msg::RingHello { rank: my_rank, epoch: expect_epoch },
                        )
                        .is_ok()
                        {
                            return Ok(stream);
                        }
                        // Ack failed — predecessor is gone; keep accepting.
                    }
                    _ => { /* stale or foreign connection — drop it */ }
                }
            }
            Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                if Instant::now() >= deadline {
                    return Err(anyhow!(
                        "timed out waiting for ring predecessor {expect_rank} \
                         (epoch {expect_epoch})"
                    ));
                }
                std::thread::sleep(Duration::from_millis(10));
            }
            Err(e) => return Err(anyhow!("ring accept failed: {e}")),
        }
    }
}

/// Form this member's ring links for one committed epoch.
///
/// `members` is the committed ring order, `(rank, ring_port)` on
/// 127.0.0.1; `my_rank` must appear in it.  Each member dials its
/// successor and accepts its predecessor concurrently; both sides give up
/// at `connect_timeout`.  The formed sockets carry `ring_timeout`
/// read/write timeouts.
pub fn form_ring(
    my_rank: u32,
    epoch: u32,
    members: &[(u32, u16)],
    listener: &TcpListener,
    connect_timeout: Duration,
    ring_timeout: Duration,
) -> Result<TcpRing> {
    let pos = members
        .iter()
        .position(|(r, _)| *r == my_rank)
        .ok_or_else(|| anyhow!("rank {my_rank} not in committed member list"))?;
    let c = members.len();
    if c == 1 {
        return Ok(TcpRing {
            pos: 0,
            size: 1,
            tx_next: None,
            rx_prev: None,
            meter: ByteMeter::default(),
            spent_rx: None,
            pool: Vec::new(),
        });
    }
    let (succ_rank, succ_port) = members[(pos + 1) % c];
    let pred_rank = members[(pos + c - 1) % c].0;
    let deadline = Instant::now() + connect_timeout;

    let accept_listener = listener.try_clone().context("cloning ring listener")?;
    let acceptor = std::thread::spawn(move || {
        accept_predecessor(
            accept_listener,
            my_rank,
            pred_rank,
            epoch,
            deadline,
            ring_timeout,
        )
    });

    let dial =
        dial_handshake(succ_port, my_rank, succ_rank, epoch, deadline, ring_timeout);

    let accepted = acceptor
        .join()
        .map_err(|_| anyhow!("ring accept thread panicked"))?;
    let rx_prev = accepted?;
    let mut tx_stream = dial?;
    rx_prev.set_nodelay(true).ok();
    rx_prev.set_read_timeout(Some(ring_timeout)).ok();

    // Writer: drains queued chunks onto the successor socket (see the
    // TcpRing docs for why sends must not block the caller).  The loop
    // ends when the TcpRing (and so the queue sender) is dropped, or on
    // a socket error.  Encoding goes through one persistent scratch
    // buffer, and each payload is handed back over the spent channel so
    // `send_next` recirculates it instead of allocating.  With the comm
    // pool enabled the loop parks a pool worker for the connection's
    // lifetime instead of owning a fresh OS thread.
    let (tx, rx) = mpsc::channel::<Vec<f32>>();
    let (spent_tx, spent_rx) = mpsc::channel::<Vec<f32>>();
    let writer = move || {
        let mut scratch: Vec<u8> = Vec::new();
        while let Ok(chunk) = rx.recv() {
            let msg = Msg::Data { payload: chunk };
            let ok = write_msg_with(&mut tx_stream, &mut scratch, &msg).is_ok();
            if !ok {
                break;
            }
            if let Msg::Data { payload } = msg {
                let _ = spent_tx.send(payload);
            }
        }
    };
    if crate::comm::pool::enabled() {
        crate::comm::pool::shared().submit(writer);
    } else {
        std::thread::spawn(writer);
    }

    Ok(TcpRing {
        pos,
        size: c,
        tx_next: Some(tx),
        rx_prev: Some(rx_prev),
        meter: ByteMeter::default(),
        spent_rx: Some(spent_rx),
        pool: Vec::new(),
    })
}

// ---------------------------------------------------------------------------
// Stage links: the pipeline-schedule dataflow over TCP (one OS process
// per stage executor; chain for 1F1B/GPipe/ZB, ring for interleaved)
// ---------------------------------------------------------------------------

/// Deterministic listener layout for the stage-parallel fleet when
/// `[transport] stage_listen_base_port` is set: process (cluster c,
/// stage s) of an M-stage pipeline binds its per-stage DP ring listener
/// at `base + 2·(c·M + s)` and its stage-link listener one port above.
/// Config validation guarantees the whole `2·D·M` block fits below
/// 65536.  With base = 0 every listener binds an ephemeral OS port and
/// the layout is carried by `StageHello` instead.
pub fn stage_ports(base: u16, cluster: usize, stage: usize, stages: usize) -> (u16, u16) {
    let idx = 2 * (cluster * stages + stage) as u32;
    let ring = base as u32 + idx;
    (ring as u16, (ring + 1) as u16)
}

/// One direction-neighbor socket of a stage process.  Writes are
/// decoupled onto a writer thread for the same reason as [`TcpRing`]:
/// the schedule steady state has both neighbors sending into each other
/// (acts down, grads up), and synchronous writes larger than the socket
/// buffers would deadlock the pair.  A dead peer still surfaces: the
/// writer thread exits on a write error, the next send sees the hung-up
/// queue, and the next read times out.
struct LinkHalf {
    tx: mpsc::Sender<Msg>,
    rx: TcpStream,
}

fn link_half(stream: TcpStream) -> Result<LinkHalf> {
    let mut write_stream = stream.try_clone().context("cloning link stream")?;
    let (tx, rx) = mpsc::channel::<Msg>();
    // Same persistent-scratch + pool routing as the ring writer: with the
    // comm pool enabled the drain loop parks a pool worker instead of
    // holding a dedicated OS thread per neighbor socket.
    let writer = move || {
        let mut scratch: Vec<u8> = Vec::new();
        while let Ok(m) = rx.recv() {
            if write_msg_with(&mut write_stream, &mut scratch, &m).is_err() {
                break;
            }
        }
    };
    if crate::comm::pool::enabled() {
        crate::comm::pool::shared().submit(writer);
    } else {
        std::thread::spawn(writer);
    }
    Ok(LinkHalf { tx, rx: stream })
}

/// [`StageLink`] over loopback TCP: `up` talks to stage s−1 (receives
/// Acts, sends Grads), `down` to stage s+1 (sends Acts, receives Grads).
/// Stage 0 has no `up`; the last stage has no `down`.
pub struct TcpStageLink {
    up: Option<LinkHalf>,
    down: Option<LinkHalf>,
}

impl StageLink for TcpStageLink {
    fn has_upstream(&self) -> bool {
        self.up.is_some()
    }

    fn has_downstream(&self) -> bool {
        self.down.is_some()
    }

    fn send_acts(&mut self, chunk: usize, micro: usize, acts: Vec<f32>) -> Result<()> {
        let d = self
            .down
            .as_ref()
            .ok_or_else(|| anyhow!("last stage has no downstream link"))?;
        d.tx.send(Msg::Acts {
            chunk: chunk as u32,
            micro: micro as u32,
            payload: acts,
        })
        .map_err(|_| anyhow!("downstream stage link closed"))
    }

    fn recv_acts(&mut self) -> Result<(usize, usize, Vec<f32>)> {
        let u = self
            .up
            .as_mut()
            .ok_or_else(|| anyhow!("first stage has no upstream link"))?;
        match read_msg(&mut u.rx).context("stage link recv acts")? {
            Msg::Acts { chunk, micro, payload } => {
                Ok((chunk as usize, micro as usize, payload))
            }
            other => Err(anyhow!("expected Acts frame, got {}", other.name())),
        }
    }

    fn send_grads(&mut self, chunk: usize, micro: usize, grads: Vec<f32>) -> Result<()> {
        let u = self
            .up
            .as_ref()
            .ok_or_else(|| anyhow!("first stage has no upstream link"))?;
        u.tx.send(Msg::Grads {
            chunk: chunk as u32,
            micro: micro as u32,
            payload: grads,
        })
        .map_err(|_| anyhow!("upstream stage link closed"))
    }

    fn recv_grads(&mut self) -> Result<(usize, usize, Vec<f32>)> {
        let d = self
            .down
            .as_mut()
            .ok_or_else(|| anyhow!("last stage has no downstream link"))?;
        match read_msg(&mut d.rx).context("stage link recv grads")? {
            Msg::Grads { chunk, micro, payload } => {
                Ok((chunk as usize, micro as usize, payload))
            }
            other => Err(anyhow!("expected Grads frame, got {}", other.name())),
        }
    }
}

/// Form one stage process's intra-cluster dataflow links for a committed
/// membership epoch.
///
/// The chain forms upstream-first: stage s (s > 0) accepts stage s−1 on
/// its own link listener (epoch-checked `RingHello` handshake, stale
/// connections dropped), then dials `down_port` — the link listener of
/// stage s+1 in the same cluster (`None` on the last stage, or in a
/// finishing epoch that runs no dataflow).  The chain has no cycle, so
/// the sequential accept-then-dial unwinds from stage 0.  All sockets
/// carry `io_timeout` read/write timeouts so a dead neighbor surfaces
/// mid-schedule as an error (churn signal), never a hang.
///
/// With `wrap_stages = Some(S)` the links close into a ring (interleaved
/// virtual stages route the last model chunk's acts back to executor 0):
/// the last stage's `down_port` is stage 0's link listener, and stage 0
/// dials *first* and accepts second — a cycle of accept-then-dial would
/// deadlock, while dial-first unwinds because stage 1 is already
/// accepting when stage 0 dials.  `Some(1)` forms a self-loop on the
/// stage's own listener (no handshake needed: the connection in the
/// backlog is our own).
pub fn form_stage_links(
    stage: u32,
    epoch: u32,
    link_listener: &TcpListener,
    down_port: Option<u16>,
    wrap_stages: Option<u32>,
    connect_timeout: Duration,
    io_timeout: Duration,
) -> Result<TcpStageLink> {
    let deadline = Instant::now() + connect_timeout;
    if wrap_stages == Some(1) {
        // Self-loop: connect() completes via the backlog before accept().
        let addr = link_listener.local_addr().context("link listener addr")?;
        let dial = TcpStream::connect(addr).context("self-loop dial")?;
        dial.set_nodelay(true).ok();
        dial.set_read_timeout(Some(io_timeout)).ok();
        dial.set_write_timeout(Some(io_timeout)).ok();
        link_listener.set_nonblocking(false).ok();
        let (acc, _) = link_listener.accept().context("self-loop accept")?;
        acc.set_nodelay(true).ok();
        acc.set_read_timeout(Some(io_timeout)).ok();
        acc.set_write_timeout(Some(io_timeout)).ok();
        return Ok(TcpStageLink {
            up: Some(link_half(acc)?),
            down: Some(link_half(dial)?),
        });
    }
    let up_peer = match wrap_stages {
        Some(s_total) => Some((stage + s_total - 1) % s_total),
        None if stage > 0 => Some(stage - 1),
        None => None,
    };
    let down_peer = match wrap_stages {
        Some(s_total) => (stage + 1) % s_total,
        None => stage + 1,
    };
    let dial_down = |deadline: Instant| -> Result<Option<LinkHalf>> {
        match down_port {
            Some(port) => {
                let s = dial_handshake(port, stage, down_peer, epoch, deadline, io_timeout)?;
                Ok(Some(link_half(s)?))
            }
            None => Ok(None),
        }
    };
    let accept_up = |deadline: Instant| -> Result<Option<LinkHalf>> {
        match up_peer {
            Some(peer) => {
                let l = link_listener.try_clone().context("cloning link listener")?;
                let s = accept_predecessor(l, stage, peer, epoch, deadline, io_timeout)?;
                Ok(Some(link_half(s)?))
            }
            None => Ok(None),
        }
    };
    let (up, down) = if wrap_stages.is_some() && stage == 0 {
        let down = dial_down(deadline)?;
        (accept_up(deadline)?, down)
    } else {
        let up = accept_up(deadline)?;
        (up, dial_down(deadline)?)
    };
    Ok(TcpStageLink { up, down })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::comm::ring::build_ring;
    use crate::util::rng::Pcg32;

    fn inputs(c: usize, n: usize) -> Vec<Vec<f32>> {
        let mut rng = Pcg32::seed_from(99);
        (0..c)
            .map(|_| {
                let mut v = vec![0.0f32; n];
                rng.fill_normal(&mut v, 0.0, 1.0);
                v
            })
            .collect()
    }

    fn run_local(bufs: &[Vec<f32>]) -> Vec<Vec<f32>> {
        let members = build_ring(bufs.len());
        std::thread::scope(|scope| {
            let handles: Vec<_> = members
                .into_iter()
                .zip(bufs.to_vec())
                .map(|(mut m, mut b)| {
                    scope.spawn(move || {
                        m.allreduce_mean(&mut b).unwrap();
                        b
                    })
                })
                .collect();
            handles.into_iter().map(|h| h.join().unwrap()).collect()
        })
    }

    fn run_tcp(bufs: &[Vec<f32>]) -> Vec<Vec<f32>> {
        let c = bufs.len();
        let listeners: Vec<TcpListener> = (0..c)
            .map(|_| TcpListener::bind("127.0.0.1:0").unwrap())
            .collect();
        let members: Vec<(u32, u16)> = listeners
            .iter()
            .enumerate()
            .map(|(i, l)| (i as u32, l.local_addr().unwrap().port()))
            .collect();
        std::thread::scope(|scope| {
            let handles: Vec<_> = listeners
                .iter()
                .zip(bufs.to_vec())
                .enumerate()
                .map(|(i, (listener, mut b))| {
                    let members = members.clone();
                    scope.spawn(move || {
                        let mut ring = form_ring(
                            i as u32,
                            1,
                            &members,
                            listener,
                            Duration::from_secs(10),
                            Duration::from_secs(10),
                        )
                        .unwrap();
                        ring.allreduce_mean(&mut b).unwrap();
                        b
                    })
                })
                .collect();
            handles.into_iter().map(|h| h.join().unwrap()).collect()
        })
    }

    #[test]
    fn tcp_allreduce_matches_local_bit_for_bit() {
        let bufs = inputs(3, 257); // non-divisible chunking on purpose
        let local = run_local(&bufs);
        let tcp = run_tcp(&bufs);
        // Identical schedule + identical fp order ⇒ exact equality.
        assert_eq!(local, tcp);
    }

    #[test]
    fn tcp_handles_fewer_elements_than_members() {
        // n < c: two of the four chunk bounds collapse to zero length, so
        // empty `Data` frames must round-trip the wire; the result still
        // matches the local mpsc ring bit-for-bit.
        let bufs = inputs(4, 3);
        assert_eq!(run_local(&bufs), run_tcp(&bufs));
        // n = 0: every frame is empty — the degenerate collective is a
        // no-op on the values but still a valid wire exchange.
        let empty = vec![Vec::new(); 3];
        assert_eq!(run_local(&empty), run_tcp(&empty));
    }

    #[test]
    fn size_one_ring_is_noop() {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let members = vec![(0u32, listener.local_addr().unwrap().port())];
        let mut ring = form_ring(
            0,
            1,
            &members,
            &listener,
            Duration::from_secs(1),
            Duration::from_secs(1),
        )
        .unwrap();
        let mut b = vec![4.0f32, 5.0];
        ring.allreduce_mean(&mut b).unwrap();
        assert_eq!(b, vec![4.0, 5.0]);
        assert_eq!(ring.meter().total(), 0);
    }

    #[test]
    fn stage_links_carry_acts_down_and_grads_up() {
        // Two stage processes (threads here) of one cluster: stage 0 dials
        // stage 1's link listener; acts flow down, grads flow up, each
        // tagged with its (chunk, microbatch) index.
        let l0 = TcpListener::bind("127.0.0.1:0").unwrap();
        let l1 = TcpListener::bind("127.0.0.1:0").unwrap();
        let p1 = l1.local_addr().unwrap().port();
        let t = Duration::from_secs(5);
        let upstream = std::thread::spawn(move || {
            let mut link =
                form_stage_links(0, 1, &l0, Some(p1), None, t, t).unwrap();
            assert!(!link.has_upstream() && link.has_downstream());
            link.send_acts(0, 0, vec![1.0, 2.0]).unwrap();
            link.send_acts(1, 1, vec![3.0]).unwrap();
            let (ci, mi, g) = link.recv_grads().unwrap();
            assert_eq!((ci, mi, g), (2, 0, vec![-1.0]));
            // Endpoint misuse errors instead of hanging.
            assert!(link.recv_acts().is_err());
        });
        let mut link = form_stage_links(1, 1, &l1, None, None, t, t).unwrap();
        assert!(link.has_upstream() && !link.has_downstream());
        assert_eq!(link.recv_acts().unwrap(), (0, 0, vec![1.0, 2.0]));
        assert_eq!(link.recv_acts().unwrap(), (1, 1, vec![3.0]));
        link.send_grads(2, 0, vec![-1.0]).unwrap();
        assert!(link.send_acts(0, 0, vec![0.0]).is_err());
        upstream.join().unwrap();
    }

    #[test]
    fn stage_links_wrap_into_a_ring() {
        // Three stages with wrap: every stage has both neighbors, and a
        // frame sent down by the last stage arrives at stage 0's upstream
        // receiver (the interleaved chunk hand-off path).
        let ls: Vec<TcpListener> =
            (0..3).map(|_| TcpListener::bind("127.0.0.1:0").unwrap()).collect();
        let ports: Vec<u16> =
            ls.iter().map(|l| l.local_addr().unwrap().port()).collect();
        let t = Duration::from_secs(5);
        let handles: Vec<_> = ls
            .into_iter()
            .enumerate()
            .map(|(s, l)| {
                let down = ports[(s + 1) % 3];
                std::thread::spawn(move || {
                    let mut link = form_stage_links(
                        s as u32,
                        7,
                        &l,
                        Some(down),
                        Some(3),
                        t,
                        t,
                    )
                    .unwrap();
                    assert!(link.has_upstream() && link.has_downstream());
                    link.send_acts(s, s * 10, vec![s as f32]).unwrap();
                    let (ci, mi, p) = link.recv_acts().unwrap();
                    let prev = (s + 2) % 3;
                    assert_eq!((ci, mi, p), (prev, prev * 10, vec![prev as f32]));
                })
            })
            .collect();
        for h in handles {
            h.join().unwrap();
        }
    }

    #[test]
    fn stage_link_self_loop_round_trips() {
        // wrap_stages = 1: a single executor owning every chunk talks to
        // itself over its own listener.
        let l = TcpListener::bind("127.0.0.1:0").unwrap();
        let t = Duration::from_secs(5);
        let mut link = form_stage_links(0, 1, &l, None, Some(1), t, t).unwrap();
        assert!(link.has_upstream() && link.has_downstream());
        link.send_acts(1, 4, vec![9.0]).unwrap();
        assert_eq!(link.recv_acts().unwrap(), (1, 4, vec![9.0]));
        link.send_grads(0, 2, vec![-3.0]).unwrap();
        assert_eq!(link.recv_grads().unwrap(), (0, 2, vec![-3.0]));
    }

    #[test]
    fn stage_port_layout_is_dense_and_disjoint() {
        let (dp, m) = (3usize, 4usize);
        let mut seen = std::collections::BTreeSet::new();
        for c in 0..dp {
            for s in 0..m {
                let (rp, lp) = stage_ports(42000, c, s, m);
                assert_eq!(lp, rp + 1);
                assert!(seen.insert(rp), "ring port {rp} reused");
                assert!(seen.insert(lp), "link port {lp} reused");
            }
        }
        assert_eq!(seen.len(), 2 * dp * m);
        assert_eq!(stage_ports(42000, 0, 0, m).0, 42000);
        assert_eq!(stage_ports(42000, 1, 0, m).0, 42000 + 2 * m as u16);
    }

    #[test]
    fn wrong_epoch_dialer_is_rejected() {
        // Acceptor expects epoch 2; a dialer on epoch 1 must be dropped and
        // the accept must time out (no valid predecessor ever arrives).
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let port = listener.local_addr().unwrap().port();
        let dialer = std::thread::spawn(move || {
            let mut s = TcpStream::connect(("127.0.0.1", port)).unwrap();
            write_msg(&mut s, &Msg::RingHello { rank: 0, epoch: 1 }).unwrap();
            // Hold the socket open so the acceptor's verdict is about the
            // handshake, not a racey disconnect.
            std::thread::sleep(Duration::from_millis(400));
        });
        let got = accept_predecessor(
            listener,
            1,
            0,
            2,
            Instant::now() + Duration::from_millis(300),
            Duration::from_millis(200),
        );
        assert!(got.is_err());
        dialer.join().unwrap();
    }
}
