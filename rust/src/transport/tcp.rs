//! Loopback-TCP ring backend: one `dilocox worker` OS process per cluster,
//! length-delimited [`frame`](crate::transport::frame) messages over
//! 127.0.0.1 sockets.  Ring formation is dial-successor / accept-
//! predecessor with an epoch-checked `RingHello` handshake; sockets carry
//! read/write timeouts so a dead or stalled peer surfaces as an error
//! mid-collective instead of a hang (the elastic coordinator's failure
//! signal).

use crate::transport::frame::{read_msg, write_msg, Msg};
use crate::transport::{ByteMeter, RingTransport};
use anyhow::{anyhow, Context, Result};
use std::net::{TcpListener, TcpStream};
use std::sync::mpsc;
use std::time::{Duration, Instant};

/// One member's pair of ring links.  `None` links only for size-1 rings
/// (a single survivor keeps training; its collectives are no-ops).
///
/// Sends are decoupled onto a writer thread: every member of a ring step
/// sends *then* receives, so if all members blocked synchronously in
/// `write` on chunks larger than the socket buffers, the cycle would
/// deadlock until the write timeout.  Queueing the frame and returning
/// keeps the caller free to reach its `recv` — the classic full-duplex
/// requirement of ring collectives.  A dead peer still surfaces: the
/// writer thread exits on a write error, the next `send_next` sees the
/// hung-up queue, and `recv_prev` times out.
pub struct TcpRing {
    pos: usize,
    size: usize,
    tx_next: Option<mpsc::Sender<Vec<f32>>>,
    rx_prev: Option<TcpStream>,
    meter: ByteMeter,
}

impl RingTransport for TcpRing {
    fn rank(&self) -> usize {
        self.pos
    }

    fn size(&self) -> usize {
        self.size
    }

    fn send_next(&mut self, chunk: &[f32]) -> Result<()> {
        let tx = self
            .tx_next
            .as_ref()
            .ok_or_else(|| anyhow!("size-1 ring has no successor link"))?;
        tx.send(chunk.to_vec())
            .map_err(|_| anyhow!("tcp ring send: successor link closed"))
    }

    fn recv_prev(&mut self) -> Result<Vec<f32>> {
        let s = self
            .rx_prev
            .as_mut()
            .ok_or_else(|| anyhow!("size-1 ring has no predecessor link"))?;
        match read_msg(s).context("tcp ring recv")? {
            Msg::Data { payload } => Ok(payload),
            other => Err(anyhow!("expected Data frame, got {}", other.name())),
        }
    }

    fn meter(&self) -> &ByteMeter {
        &self.meter
    }
}

/// Dial `127.0.0.1:port` until it accepts or `deadline` passes.
fn dial_retry(port: u16, deadline: Instant) -> Result<TcpStream> {
    loop {
        match TcpStream::connect(("127.0.0.1", port)) {
            Ok(s) => return Ok(s),
            Err(e) => {
                if Instant::now() >= deadline {
                    return Err(anyhow!("dialing 127.0.0.1:{port} timed out: {e}"));
                }
                std::thread::sleep(Duration::from_millis(20));
            }
        }
    }
}

/// Accept the predecessor's connection on `listener`, discarding
/// connections whose `RingHello` names the wrong rank or a stale epoch.
/// A valid predecessor gets a `RingHello` ack back (so the dialer can
/// detect a wrong-epoch drop instead of sending into the void).
fn accept_predecessor(
    listener: TcpListener,
    my_rank: u32,
    expect_rank: u32,
    expect_epoch: u32,
    deadline: Instant,
    ring_timeout: Duration,
) -> Result<TcpStream> {
    listener
        .set_nonblocking(true)
        .context("listener nonblocking")?;
    loop {
        match listener.accept() {
            Ok((stream, _)) => {
                stream.set_nonblocking(false).ok();
                stream.set_read_timeout(Some(ring_timeout)).ok();
                stream.set_write_timeout(Some(ring_timeout)).ok();
                let mut stream = stream;
                match read_msg(&mut stream) {
                    Ok(Msg::RingHello { rank, epoch })
                        if rank == expect_rank && epoch == expect_epoch =>
                    {
                        if write_msg(
                            &mut stream,
                            &Msg::RingHello { rank: my_rank, epoch: expect_epoch },
                        )
                        .is_ok()
                        {
                            return Ok(stream);
                        }
                        // Ack failed — predecessor is gone; keep accepting.
                    }
                    _ => { /* stale or foreign connection — drop it */ }
                }
            }
            Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                if Instant::now() >= deadline {
                    return Err(anyhow!(
                        "timed out waiting for ring predecessor {expect_rank} \
                         (epoch {expect_epoch})"
                    ));
                }
                std::thread::sleep(Duration::from_millis(10));
            }
            Err(e) => return Err(anyhow!("ring accept failed: {e}")),
        }
    }
}

/// Form this member's ring links for one committed epoch.
///
/// `members` is the committed ring order, `(rank, ring_port)` on
/// 127.0.0.1; `my_rank` must appear in it.  Each member dials its
/// successor and accepts its predecessor concurrently; both sides give up
/// at `connect_timeout`.  The formed sockets carry `ring_timeout`
/// read/write timeouts.
pub fn form_ring(
    my_rank: u32,
    epoch: u32,
    members: &[(u32, u16)],
    listener: &TcpListener,
    connect_timeout: Duration,
    ring_timeout: Duration,
) -> Result<TcpRing> {
    let pos = members
        .iter()
        .position(|(r, _)| *r == my_rank)
        .ok_or_else(|| anyhow!("rank {my_rank} not in committed member list"))?;
    let c = members.len();
    if c == 1 {
        return Ok(TcpRing {
            pos: 0,
            size: 1,
            tx_next: None,
            rx_prev: None,
            meter: ByteMeter::default(),
        });
    }
    let (succ_rank, succ_port) = members[(pos + 1) % c];
    let pred_rank = members[(pos + c - 1) % c].0;
    let deadline = Instant::now() + connect_timeout;

    let accept_listener = listener.try_clone().context("cloning ring listener")?;
    let acceptor = std::thread::spawn(move || {
        accept_predecessor(
            accept_listener,
            my_rank,
            pred_rank,
            epoch,
            deadline,
            ring_timeout,
        )
    });

    let dial = (|| -> Result<TcpStream> {
        loop {
            let mut s = dial_retry(succ_port, deadline)?;
            s.set_nodelay(true).ok();
            s.set_write_timeout(Some(ring_timeout)).ok();
            s.set_read_timeout(Some(ring_timeout)).ok();
            // Handshake: identify ourselves, then require the successor's
            // ack — a successor still on an older epoch silently drops us,
            // which surfaces here as a failed ack read; retry until the
            // deadline.
            if write_msg(&mut s, &Msg::RingHello { rank: my_rank, epoch }).is_ok() {
                if let Ok(Msg::RingHello { rank, epoch: e }) = read_msg(&mut s) {
                    if rank == succ_rank && e == epoch {
                        return Ok(s);
                    }
                }
            }
            if Instant::now() >= deadline {
                return Err(anyhow!("ring successor handshake timed out"));
            }
            std::thread::sleep(Duration::from_millis(20));
        }
    })();

    let accepted = acceptor
        .join()
        .map_err(|_| anyhow!("ring accept thread panicked"))?;
    let rx_prev = accepted?;
    let mut tx_stream = dial?;
    rx_prev.set_nodelay(true).ok();
    rx_prev.set_read_timeout(Some(ring_timeout)).ok();

    // Writer thread: drains queued chunks onto the successor socket (see
    // the TcpRing docs for why sends must not block the caller).  The
    // thread ends when the TcpRing (and so the queue sender) is dropped,
    // or on a socket error.
    let (tx, rx) = mpsc::channel::<Vec<f32>>();
    std::thread::spawn(move || {
        while let Ok(chunk) = rx.recv() {
            if write_msg(&mut tx_stream, &Msg::Data { payload: chunk }).is_err() {
                break;
            }
        }
    });

    Ok(TcpRing {
        pos,
        size: c,
        tx_next: Some(tx),
        rx_prev: Some(rx_prev),
        meter: ByteMeter::default(),
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::comm::ring::build_ring;
    use crate::util::rng::Pcg32;

    fn inputs(c: usize, n: usize) -> Vec<Vec<f32>> {
        let mut rng = Pcg32::seed_from(99);
        (0..c)
            .map(|_| {
                let mut v = vec![0.0f32; n];
                rng.fill_normal(&mut v, 0.0, 1.0);
                v
            })
            .collect()
    }

    fn run_local(bufs: &[Vec<f32>]) -> Vec<Vec<f32>> {
        let members = build_ring(bufs.len());
        std::thread::scope(|scope| {
            let handles: Vec<_> = members
                .into_iter()
                .zip(bufs.to_vec())
                .map(|(mut m, mut b)| {
                    scope.spawn(move || {
                        m.allreduce_mean(&mut b).unwrap();
                        b
                    })
                })
                .collect();
            handles.into_iter().map(|h| h.join().unwrap()).collect()
        })
    }

    fn run_tcp(bufs: &[Vec<f32>]) -> Vec<Vec<f32>> {
        let c = bufs.len();
        let listeners: Vec<TcpListener> = (0..c)
            .map(|_| TcpListener::bind("127.0.0.1:0").unwrap())
            .collect();
        let members: Vec<(u32, u16)> = listeners
            .iter()
            .enumerate()
            .map(|(i, l)| (i as u32, l.local_addr().unwrap().port()))
            .collect();
        std::thread::scope(|scope| {
            let handles: Vec<_> = listeners
                .iter()
                .zip(bufs.to_vec())
                .enumerate()
                .map(|(i, (listener, mut b))| {
                    let members = members.clone();
                    scope.spawn(move || {
                        let mut ring = form_ring(
                            i as u32,
                            1,
                            &members,
                            listener,
                            Duration::from_secs(10),
                            Duration::from_secs(10),
                        )
                        .unwrap();
                        ring.allreduce_mean(&mut b).unwrap();
                        b
                    })
                })
                .collect();
            handles.into_iter().map(|h| h.join().unwrap()).collect()
        })
    }

    #[test]
    fn tcp_allreduce_matches_local_bit_for_bit() {
        let bufs = inputs(3, 257); // non-divisible chunking on purpose
        let local = run_local(&bufs);
        let tcp = run_tcp(&bufs);
        // Identical schedule + identical fp order ⇒ exact equality.
        assert_eq!(local, tcp);
    }

    #[test]
    fn size_one_ring_is_noop() {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let members = vec![(0u32, listener.local_addr().unwrap().port())];
        let mut ring = form_ring(
            0,
            1,
            &members,
            &listener,
            Duration::from_secs(1),
            Duration::from_secs(1),
        )
        .unwrap();
        let mut b = vec![4.0f32, 5.0];
        ring.allreduce_mean(&mut b).unwrap();
        assert_eq!(b, vec![4.0, 5.0]);
        assert_eq!(ring.meter().total(), 0);
    }

    #[test]
    fn wrong_epoch_dialer_is_rejected() {
        // Acceptor expects epoch 2; a dialer on epoch 1 must be dropped and
        // the accept must time out (no valid predecessor ever arrives).
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let port = listener.local_addr().unwrap().port();
        let dialer = std::thread::spawn(move || {
            let mut s = TcpStream::connect(("127.0.0.1", port)).unwrap();
            write_msg(&mut s, &Msg::RingHello { rank: 0, epoch: 1 }).unwrap();
            // Hold the socket open so the acceptor's verdict is about the
            // handshake, not a racey disconnect.
            std::thread::sleep(Duration::from_millis(400));
        });
        let got = accept_predecessor(
            listener,
            1,
            0,
            2,
            Instant::now() + Duration::from_millis(300),
            Duration::from_millis(200),
        );
        assert!(got.is_err());
        dialer.join().unwrap();
    }
}
