//! Link probing and bandwidth-aware ring ordering.
//!
//! The flat chunked ring AllReduce sends every hop over whatever member
//! order the coordinator happened to commit — on a heterogeneous WAN that
//! means 2·(C−1) synchronous steps each paced by the *slowest* link that
//! the arbitrary order put on the cycle.  This module makes the topology a
//! measured quantity instead:
//!
//! * [`LinkMatrix`] — directed per-pair throughput (Gbps) and latency (ms),
//!   filled either from a live probe ([`measure_link`] against each peer's
//!   echo listener, [`serve_echo`]) or from a `netsim`-style model.
//! * [`ring_order`] — a max-bottleneck ring order over the matrix: greedy
//!   nearest-neighbor construction followed by 2-opt segment reversals,
//!   maximizing the minimum link bandwidth on the directed cycle (ties
//!   broken by lower total hop latency, then lexicographically).
//! * [`ring_step_seconds`] — the synchronous-ring cost model the ordering
//!   optimizes: 2·(C−1) steps, each paced by the slowest hop on the cycle.
//!
//! # Invariants
//!
//! * `ring_order` is **deterministic**: the same matrix always yields the
//!   same order, rotated so member 0 leads (a ring is rotation-invariant).
//!   Fleet determinism therefore only depends on the matrix the
//!   coordinator measured, which it ships to every worker as the
//!   `Prepare.members` order — workers never reorder locally.
//! * On a homogeneous matrix (all links equal) the order is the identity,
//!   so probing never perturbs a fleet whose links are symmetric — the
//!   bit-for-bit loopback contracts for the flat ring are unaffected.
//! * The live probe runs strictly *before* the first membership epoch on
//!   dedicated echo listeners; it never touches ring sockets, so a probe
//!   failure degrades to the natural (rank-sorted) order rather than
//!   poisoning ring formation.

use crate::transport::frame::{read_msg, write_msg, Msg};
use crate::util::rng::Pcg32;
use anyhow::{anyhow, Context, Result};
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::time::{Duration, Instant};

/// Directed link measurements for `n` fleet members: `gbps[from][to]` and
/// `latency_ms[from][to]`, stored dense.  Self-links are ignored by every
/// consumer.  Unmeasured links default to infinite bandwidth / zero
/// latency so a partially filled matrix never penalizes a link nobody
/// measured.
#[derive(Clone, Debug)]
pub struct LinkMatrix {
    n: usize,
    gbps: Vec<f64>,
    latency_ms: Vec<f64>,
}

impl LinkMatrix {
    pub fn new(n: usize) -> LinkMatrix {
        LinkMatrix {
            n,
            gbps: vec![f64::INFINITY; n * n],
            latency_ms: vec![0.0; n * n],
        }
    }

    /// All links identical — the homogeneous (e.g. loopback) baseline.
    pub fn homogeneous(n: usize, gbps: f64, latency_ms: f64) -> LinkMatrix {
        LinkMatrix {
            n,
            gbps: vec![gbps; n * n],
            latency_ms: vec![latency_ms; n * n],
        }
    }

    pub fn n(&self) -> usize {
        self.n
    }

    pub fn set(&mut self, from: usize, to: usize, gbps: f64, latency_ms: f64) {
        self.gbps[from * self.n + to] = gbps;
        self.latency_ms[from * self.n + to] = latency_ms;
    }

    pub fn gbps(&self, from: usize, to: usize) -> f64 {
        self.gbps[from * self.n + to]
    }

    pub fn latency_ms(&self, from: usize, to: usize) -> f64 {
        self.latency_ms[from * self.n + to]
    }

    /// Flatten to `(from, to, gbps, latency_ms)` rows (off-diagonal only)
    /// — the shape the run report serializes and `--calibrate-from` reads
    /// back.
    pub fn entries(&self) -> Vec<(u32, u32, f64, f64)> {
        let mut out = Vec::new();
        for f in 0..self.n {
            for t in 0..self.n {
                if f != t {
                    out.push((
                        f as u32,
                        t as u32,
                        self.gbps(f, t),
                        self.latency_ms(f, t),
                    ));
                }
            }
        }
        out
    }

    pub fn from_entries(n: usize, rows: &[(u32, u32, f64, f64)]) -> LinkMatrix {
        let mut m = LinkMatrix::new(n);
        for &(f, t, g, l) in rows {
            if (f as usize) < n && (t as usize) < n {
                m.set(f as usize, t as usize, g, l);
            }
        }
        m
    }
}

/// Bottleneck bandwidth (min Gbps over the directed cycle's links) and
/// total hop latency of a ring order — the objective [`ring_order`]
/// maximizes (bottleneck first, then lower latency).
pub fn ring_bottleneck(m: &LinkMatrix, order: &[usize]) -> (f64, f64) {
    let c = order.len();
    if c <= 1 {
        return (f64::INFINITY, 0.0);
    }
    let mut min_gbps = f64::INFINITY;
    let mut lat = 0.0;
    for i in 0..c {
        let from = order[i];
        let to = order[(i + 1) % c];
        min_gbps = min_gbps.min(m.gbps(from, to));
        lat += m.latency_ms(from, to);
    }
    (min_gbps, lat)
}

/// Seconds for one chunked ring all-reduce of `payload_bytes` over the
/// measured links in the given order: the ring is synchronous, so each of
/// the 2·(C−1) steps is paced by the slowest hop on the cycle.
pub fn ring_step_seconds(
    m: &LinkMatrix,
    order: &[usize],
    payload_bytes: u64,
) -> f64 {
    let c = order.len();
    if c <= 1 {
        return 0.0;
    }
    let chunk = payload_bytes as f64 / c as f64;
    let mut step = 0.0f64;
    for i in 0..c {
        let from = order[i];
        let to = order[(i + 1) % c];
        let bw = m.gbps(from, to) * 1e9 / 8.0; // bytes/sec
        let t = chunk / bw + m.latency_ms(from, to) * 1e-3;
        step = step.max(t);
    }
    2.0 * (c as f64 - 1.0) * step
}

/// `(bottleneck, latency)` strictly better than the incumbent?
fn better(cand: (f64, f64), best: (f64, f64)) -> bool {
    cand.0 > best.0 || (cand.0 == best.0 && cand.1 < best.1)
}

/// Max-bottleneck ring order over a measured link matrix: greedy
/// nearest-neighbor construction (highest-bandwidth outgoing link first,
/// ties by lower latency then lower index) followed by 2-opt segment
/// reversals accepted only when they strictly improve
/// `(bottleneck ↑, total latency ↓)`.  Deterministic; returned rotated so
/// index 0 leads.
pub fn ring_order(m: &LinkMatrix) -> Vec<usize> {
    let n = m.n();
    if n <= 2 {
        return (0..n).collect();
    }
    // Greedy nearest-neighbor from 0.
    let mut order = Vec::with_capacity(n);
    let mut used = vec![false; n];
    order.push(0usize);
    used[0] = true;
    while order.len() < n {
        let cur = *order.last().unwrap();
        let mut best: Option<usize> = None;
        for cand in 0..n {
            if used[cand] {
                continue;
            }
            let score = (m.gbps(cur, cand), -m.latency_ms(cur, cand));
            let take = match best {
                None => true,
                Some(b) => {
                    let bs = (m.gbps(cur, b), -m.latency_ms(cur, b));
                    score.0 > bs.0 || (score.0 == bs.0 && score.1 > bs.1)
                }
            };
            if take {
                best = Some(cand);
            }
        }
        let next = best.unwrap();
        used[next] = true;
        order.push(next);
    }
    // 2-opt: reverse order[i..=j]; each acceptance strictly improves the
    // lexicographic objective, so the loop terminates.
    let mut score = ring_bottleneck(m, &order);
    loop {
        let mut improved = false;
        'outer: for i in 1..n - 1 {
            for j in i + 1..n {
                order[i..=j].reverse();
                let cand = ring_bottleneck(m, &order);
                if better(cand, score) {
                    score = cand;
                    improved = true;
                    break 'outer;
                }
                order[i..=j].reverse(); // undo
            }
        }
        if !improved {
            break;
        }
    }
    // Canonical rotation: member 0 leads.
    let zero = order.iter().position(|&v| v == 0).unwrap();
    order.rotate_left(zero);
    order
}

// ---------------------------------------------------------------------------
// Live probe: echo server + directed link measurement
// ---------------------------------------------------------------------------

/// Elements in the small echo used to estimate latency.
const LATENCY_ELEMS: usize = 16;

/// Serve echo connections until `stop` is set: each accepted connection
/// gets every `Data` frame written straight back.  Probes arrive one at a
/// time (the coordinator probes workers sequentially), so connections are
/// handled inline.
pub fn serve_echo(listener: TcpListener, stop: Arc<AtomicBool>) {
    if listener.set_nonblocking(true).is_err() {
        return;
    }
    while !stop.load(Ordering::Relaxed) {
        match listener.accept() {
            Ok((mut conn, _)) => {
                let _ = conn.set_nodelay(true);
                let _ = conn.set_read_timeout(Some(Duration::from_secs(10)));
                let _ = conn.set_nonblocking(false);
                loop {
                    match read_msg(&mut conn) {
                        Ok(Msg::Data { payload }) => {
                            let echo = Msg::Data { payload };
                            if write_msg(&mut conn, &echo).is_err() {
                                break;
                            }
                        }
                        _ => break,
                    }
                }
            }
            Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                std::thread::sleep(Duration::from_millis(10));
            }
            Err(_) => break,
        }
    }
}

/// Spawn [`serve_echo`] on its own thread; the returned flag stops it.
pub fn spawn_echo_server(listener: TcpListener) -> Arc<AtomicBool> {
    let stop = Arc::new(AtomicBool::new(false));
    let flag = Arc::clone(&stop);
    std::thread::Builder::new()
        .name("probe-echo".into())
        .spawn(move || serve_echo(listener, flag))
        .expect("spawn probe echo thread");
    stop
}

/// Measure the directed link to one peer's echo listener: seeded payload
/// echo, `repeats` trials, minimum taken (the cleanest sample of an
/// otherwise noisy path).  Returns `(gbps, latency_ms)`.
pub fn measure_link(
    addr: &str,
    payload_elems: usize,
    repeats: usize,
    timeout: Duration,
) -> Result<(f64, f64)> {
    let mut conn = TcpStream::connect(addr)
        .with_context(|| format!("probe dial {addr}"))?;
    conn.set_nodelay(true).ok();
    conn.set_read_timeout(Some(timeout)).ok();
    conn.set_write_timeout(Some(timeout)).ok();
    let repeats = repeats.max(1);
    // Latency: tiny echo round-trips, min RTT / 2.
    let mut rng = Pcg32::new(0x9b0b, 0);
    let mut small = vec![0.0f32; LATENCY_ELEMS];
    rng.fill_normal(&mut small, 0.0, 1.0);
    let mut rtt_min = f64::INFINITY;
    for _ in 0..repeats {
        let t0 = Instant::now();
        write_msg(&mut conn, &Msg::Data { payload: small.clone() })?;
        match read_msg(&mut conn)? {
            Msg::Data { payload: v } if v.len() == small.len() => {}
            _ => return Err(anyhow!("probe echo returned a foreign frame")),
        }
        rtt_min = rtt_min.min(t0.elapsed().as_secs_f64());
    }
    // Throughput: big echo, min elapsed, RTT subtracted.
    let elems = payload_elems.max(LATENCY_ELEMS);
    let mut payload = vec![0.0f32; elems];
    rng.fill_normal(&mut payload, 0.0, 1.0);
    let mut big_min = f64::INFINITY;
    for _ in 0..repeats {
        let t0 = Instant::now();
        write_msg(&mut conn, &Msg::Data { payload: payload.clone() })?;
        match read_msg(&mut conn)? {
            Msg::Data { payload: v } if v.len() == payload.len() => {}
            _ => return Err(anyhow!("probe echo returned a foreign frame")),
        }
        big_min = big_min.min(t0.elapsed().as_secs_f64());
    }
    let bytes = (2 * 4 * elems) as f64; // both directions count
    let net = (big_min - rtt_min).max(1e-9);
    // Loopback can be effectively infinite; cap so downstream math stays
    // finite and comparisons stay total.
    let gbps = (bytes * 8.0 / net / 1e9).min(1e6);
    Ok((gbps, (rtt_min / 2.0 * 1e3).max(0.0)))
}

/// Probe every peer in turn (the worker side of `ProbeRequest`).
/// Returns `(peer_rank, gbps, latency_ms)` rows; a peer that cannot be
/// measured is reported with zero bandwidth so the coordinator sees the
/// degraded link instead of a hole.
pub fn probe_peers(
    peers: &[(u32, u16)],
    payload_elems: usize,
    repeats: usize,
    timeout: Duration,
) -> Vec<(u32, f64, f64)> {
    peers
        .iter()
        .map(|&(rank, port)| {
            match measure_link(
                &format!("127.0.0.1:{port}"),
                payload_elems,
                repeats,
                timeout,
            ) {
                Ok((g, l)) => (rank, g, l),
                Err(_) => (rank, 0.0, 0.0),
            }
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    /// 4 members, two fast islands {0,2} and {1,3} (interleaved on
    /// purpose, so the natural rank order crosses a slow boundary on
    /// every hop) with one decent cross link each way.
    fn two_island_matrix() -> LinkMatrix {
        let mut m = LinkMatrix::homogeneous(4, 0.5, 20.0); // slow default
        for (a, b) in [(0, 2), (2, 0), (1, 3), (3, 1)] {
            m.set(a, b, 100.0, 0.1); // fast intra-island
        }
        // One decent cross link each way.
        m.set(2, 1, 2.0, 10.0);
        m.set(3, 0, 2.0, 10.0);
        m
    }

    #[test]
    fn homogeneous_matrix_keeps_identity_order() {
        let m = LinkMatrix::homogeneous(5, 1.0, 1.0);
        assert_eq!(ring_order(&m), vec![0, 1, 2, 3, 4]);
    }

    #[test]
    fn ring_order_raises_the_bottleneck() {
        let m = two_island_matrix();
        let natural: Vec<usize> = (0..4).collect();
        // Natural order 0→1→2→3→0 rides the 0.5 Gbps default on its first
        // three hops; the optimizer chains the islands via fast links and
        // crosses the boundary exactly twice at 2.0 Gbps.
        let picked = ring_order(&m);
        let (b_nat, _) = ring_bottleneck(&m, &natural);
        let (b_opt, _) = ring_bottleneck(&m, &picked);
        assert!(b_opt > b_nat, "{b_opt} vs {b_nat}");
        assert_eq!(picked, vec![0, 2, 1, 3], "islands chained via fast links");
        assert_eq!(b_opt, 2.0);
        assert_eq!(b_nat, 0.5);
    }

    #[test]
    fn ring_order_is_deterministic_and_rotated_to_zero() {
        let mut m = LinkMatrix::homogeneous(6, 1.0, 5.0);
        // Scatter heterogeneous links (deterministic pattern).
        for f in 0..6usize {
            for t in 0..6usize {
                if f != t {
                    let g = 1.0 + ((f * 7 + t * 3) % 11) as f64;
                    m.set(f, t, g, 1.0 + ((f + t) % 4) as f64);
                }
            }
        }
        let a = ring_order(&m);
        let b = ring_order(&m);
        assert_eq!(a, b);
        assert_eq!(a[0], 0);
        let mut sorted = a.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, vec![0, 1, 2, 3, 4, 5]);
        // 2-opt never loses to the natural order.
        let (b_opt, _) = ring_bottleneck(&m, &a);
        let (b_nat, _) = ring_bottleneck(&m, &(0..6).collect::<Vec<_>>());
        assert!(b_opt >= b_nat);
    }

    #[test]
    fn step_model_prefers_the_reordered_ring() {
        let m = two_island_matrix();
        let payload = 4_000_000u64;
        // The natural rank order crosses the slow 0.5 links.
        let bad = vec![0, 1, 2, 3];
        let good = ring_order(&m);
        assert!(
            ring_step_seconds(&m, &good, payload)
                < ring_step_seconds(&m, &bad, payload)
        );
        // c <= 1 is free.
        assert_eq!(ring_step_seconds(&m, &[0], payload), 0.0);
    }

    #[test]
    fn entries_roundtrip() {
        let m = two_island_matrix();
        let rows = m.entries();
        let back = LinkMatrix::from_entries(4, &rows);
        for f in 0..4 {
            for t in 0..4 {
                if f != t {
                    assert_eq!(m.gbps(f, t), back.gbps(f, t));
                    assert_eq!(m.latency_ms(f, t), back.latency_ms(f, t));
                }
            }
        }
    }

    #[test]
    fn live_probe_measures_loopback_fast_and_close() {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let port = listener.local_addr().unwrap().port();
        let stop = spawn_echo_server(listener);
        let (gbps, lat_ms) = measure_link(
            &format!("127.0.0.1:{port}"),
            16 * 1024,
            2,
            Duration::from_secs(5),
        )
        .unwrap();
        stop.store(true, Ordering::Relaxed);
        assert!(gbps > 0.0, "loopback bandwidth must be positive: {gbps}");
        assert!(lat_ms < 1000.0, "loopback latency is sub-second: {lat_ms}");
        // probe_peers degrades an unreachable peer to zero bandwidth
        // instead of failing the whole report.
        let rows = probe_peers(&[(7, 1)], 64, 1, Duration::from_millis(200));
        assert_eq!(rows.len(), 1);
        assert_eq!(rows[0].0, 7);
        assert_eq!(rows[0].1, 0.0);
    }
}
