//! Deterministic fault injection over any [`RingTransport`]: seeded
//! (Pcg32) message delays, a persistent straggler, and a worker kill at a
//! configured round.  Faults are a *wrapper*, not a fourth wire — the same
//! plan drives churn scenarios over both the local and the TCP backends,
//! and the same seed reproduces the same schedule.

use crate::transport::{ByteMeter, RingTransport};
use crate::util::rng::Pcg32;
use anyhow::{anyhow, Result};
use std::time::Duration;

/// Per-worker fault schedule (already filtered for this rank).
#[derive(Clone, Debug)]
pub struct FaultPlan {
    /// Seed for the delay stream (combined with the worker rank by the
    /// caller so every worker draws an independent, reproducible stream).
    pub seed: u64,
    /// Probability a sent message is delayed.
    pub delay_prob: f64,
    /// Maximum injected delay per message, milliseconds.
    pub max_delay_ms: u64,
    /// Kill this worker at the start of this round (0 = never).
    pub kill_round: usize,
    /// Soft churn: report a broken ring at the start of this round (0 =
    /// never) WITHOUT dying — the worker parks for the next membership
    /// epoch while its peers time out mid-collective.  Consumed by the
    /// epoch-aware round driver ([`crate::rounds::driver`]), not by this
    /// wrapper: a soft break is a worker-loop event, not a wire fault.
    /// Deterministically exercises the *discard* branch of overlap
    /// recovery (the breaker holds an older in-flight round than its
    /// peers, so the coordinator cannot drain).
    pub break_round: usize,
    /// Fixed extra latency on every send (a persistent straggler), ms.
    pub straggler_ms: u64,
    /// Process mode: kill = `std::process::exit`; thread mode (tests):
    /// kill = error return.
    pub exit_on_kill: bool,
}

impl FaultPlan {
    /// A plan that injects nothing (useful as a base to mutate).
    pub fn quiet(seed: u64) -> FaultPlan {
        FaultPlan {
            seed,
            delay_prob: 0.0,
            max_delay_ms: 0,
            kill_round: 0,
            break_round: 0,
            straggler_ms: 0,
            exit_on_kill: false,
        }
    }

    pub fn is_quiet(&self) -> bool {
        self.delay_prob <= 0.0
            && self.kill_round == 0
            && self.break_round == 0
            && self.straggler_ms == 0
    }
}

/// The `faulty` wrapper backend.
pub struct FaultyRing<T: RingTransport> {
    inner: T,
    plan: FaultPlan,
    rng: Pcg32,
}

impl<T: RingTransport> FaultyRing<T> {
    pub fn new(inner: T, plan: FaultPlan) -> FaultyRing<T> {
        let rng = Pcg32::new(plan.seed, 0x66au64 ^ inner.rank() as u64);
        FaultyRing { inner, plan, rng }
    }

    pub fn into_inner(self) -> T {
        self.inner
    }
}

impl<T: RingTransport> RingTransport for FaultyRing<T> {
    fn rank(&self) -> usize {
        self.inner.rank()
    }

    fn size(&self) -> usize {
        self.inner.size()
    }

    fn send_next(&mut self, chunk: &[f32]) -> Result<()> {
        if self.plan.straggler_ms > 0 {
            std::thread::sleep(Duration::from_millis(self.plan.straggler_ms));
        }
        if self.plan.delay_prob > 0.0
            && self.plan.max_delay_ms > 0
            && self.rng.next_f64() < self.plan.delay_prob
        {
            let ms = self.rng.below(self.plan.max_delay_ms as u32 + 1) as u64;
            std::thread::sleep(Duration::from_millis(ms));
        }
        self.inner.send_next(chunk)
    }

    fn recv_prev(&mut self) -> Result<Vec<f32>> {
        self.inner.recv_prev()
    }

    fn meter(&self) -> &ByteMeter {
        self.inner.meter()
    }

    fn recycle(&mut self, buf: Vec<f32>) {
        // Delegate so the inner backend's buffer pool keeps circulating;
        // the default no-op would silently starve it back to allocating.
        self.inner.recycle(buf)
    }

    fn begin_round(&mut self, round: usize) -> Result<()> {
        self.inner.begin_round(round)?;
        if self.plan.kill_round != 0 && round == self.plan.kill_round {
            if self.plan.exit_on_kill {
                eprintln!(
                    "[fault] worker rank {} exiting at round {round} (injected kill)",
                    self.inner.rank()
                );
                std::process::exit(101);
            }
            return Err(anyhow!(
                "fault injection: worker rank {} killed at round {round}",
                self.inner.rank()
            ));
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::comm::ring::build_ring;

    #[test]
    fn quiet_plan_is_transparent() {
        let members = build_ring(2);
        let mut it = members.into_iter();
        let (a, b) = (it.next().unwrap(), it.next().unwrap());
        let h = std::thread::spawn(move || {
            let mut w = FaultyRing::new(b, FaultPlan::quiet(1));
            let mut buf = vec![4.0f32; 10];
            w.allreduce_mean(&mut buf).unwrap();
            buf
        });
        let mut w = FaultyRing::new(a, FaultPlan::quiet(1));
        let mut buf = vec![2.0f32; 10];
        w.allreduce_mean(&mut buf).unwrap();
        let other = h.join().unwrap();
        assert!(buf.iter().all(|&v| (v - 3.0).abs() < 1e-6));
        assert_eq!(buf, other);
        assert!(FaultPlan::quiet(1).is_quiet());
    }

    #[test]
    fn kill_round_errors_in_thread_mode() {
        let members = build_ring(1);
        let m = members.into_iter().next().unwrap();
        let mut plan = FaultPlan::quiet(7);
        plan.kill_round = 2;
        let mut w = FaultyRing::new(m, plan);
        assert!(w.begin_round(1).is_ok());
        let err = w.begin_round(2).unwrap_err();
        assert!(format!("{err:#}").contains("killed at round 2"), "{err:#}");
    }

    #[test]
    fn delays_are_deterministic_per_seed() {
        // Two wrappers with the same seed+rank draw the same delay
        // decisions; a different seed diverges (checked via the rng stream,
        // not wall time, to keep the test instant).
        let mut a = Pcg32::new(11, 0x66a ^ 0);
        let mut b = Pcg32::new(11, 0x66a ^ 0);
        let mut c = Pcg32::new(12, 0x66a ^ 0);
        let da: Vec<u32> = (0..32).map(|_| (a.next_f64() < 0.3) as u32).collect();
        let db: Vec<u32> = (0..32).map(|_| (b.next_f64() < 0.3) as u32).collect();
        let dc: Vec<u32> = (0..32).map(|_| (c.next_f64() < 0.3) as u32).collect();
        assert_eq!(da, db);
        assert_ne!(da, dc);
    }
}
