//! Pluggable transport subsystem: the collective wire behind a trait.
//!
//! The coordinator's chunked ring AllReduce used to be welded to one
//! process-local mpsc implementation; this module abstracts the wire so
//! the same collective algebra runs over three backends:
//!
//! * **local** ([`crate::comm::ring::RingMember`]) — in-memory mpsc
//!   channels, one OS thread per cluster.  Fast, zero-config, but no fault
//!   isolation: a panicking worker takes the process down.
//! * **tcp** ([`tcp::TcpRing`]) — length-delimited frames over loopback
//!   TCP, one OS *process* per cluster (`dilocox worker`), spawned by the
//!   elastic coordinator ([`elastic`]).  A crashed worker is just a closed
//!   socket.  With pipeline parallelism the unit becomes one process per
//!   *(cluster, stage)*: the 1F1B dataflow crosses processes as
//!   `Acts`/`Grads` frames ([`tcp::TcpStageLink`]) and each stage joins
//!   its own cross-cluster ring.
//! * **faulty** ([`faulty::FaultyRing`]) — a deterministic, Pcg32-seeded
//!   wrapper over any backend that injects message delays, stragglers, and
//!   worker kills at configured rounds (WAN churn scenarios).
//! * **hier** ([`hier::HierRing`]) — a composition layer, not a fourth
//!   wire: fast intra-site rings plus a leaders-only cross-site ring, so
//!   WAN links carry 2·(S−1)/S of the payload instead of 2·(C−1)/C.
//!
//! The member *order* those rings form in is itself a measured quantity:
//! [`probe`] fills a directed link matrix (seeded payload echo against
//! per-worker echo listeners) and computes a max-bottleneck ring order
//! that the elastic coordinator ships as the committed `Prepare.members`
//! order (see [`ReduceTopology`]).
//!
//! # Frame format (tcp backend)
//!
//! Every message is one frame:
//!
//! ```text
//! u32 LE  length of (kind + body) in bytes
//! u8      kind tag (see frame::Msg)
//! [u8]    body — fixed-width LE integers / f32 bit patterns
//! ```
//!
//! Frames carry both the data plane (`Data` = one ring chunk of f32s) and
//! the control plane (membership/epoch handshake below).  The format is
//! hand-rolled little-endian (no serde offline) — see [`frame`].
//!
//! # Membership epoch protocol (elastic ring recovery)
//!
//! The elastic coordinator owns a monotonically increasing **epoch**; each
//! epoch has a committed member list.  Membership changes are a 2PC-style
//! prepare/commit over the per-worker control sockets:
//!
//! 1. worker → coordinator: `Hello{rank, ring_port}` once at startup.
//! 2. coordinator → workers: `Prepare{epoch, resume_round, members,
//!    drain_round}`.  Workers tear down any old ring and answer
//!    `PrepareAck{epoch}`.
//! 3. coordinator → workers: `Commit{epoch}` once every live member acked.
//!    Workers then re-dial the ring (each dials its successor, accepts its
//!    predecessor, with an epoch-checked `RingHello` handshake so stale
//!    connections from an older epoch are rejected).
//! 4. After every (re)formation the members run one consensus
//!    `allreduce_mean` over the global parameters and restart the outer
//!    momentum — survivors of a churn event re-agree on θ before training
//!    resumes, and the pseudo-gradient mean automatically rescales to the
//!    new member count — then act on the committed **drain-or-discard**
//!    decision for any δ-reduction that was in flight under one-step-delay
//!    overlap: `drain_round > 0` means every member of this epoch reported
//!    the SAME in-flight round, so the fresh ring finishes that reduction
//!    and applies its outer update once; `drain_round = 0` means each
//!    survivor folds its own in-flight delta back into error feedback
//!    (see [`crate::rounds::driver`]).
//!
//! Failure detection: ring sockets carry read/write timeouts, so a dead or
//! stalled peer surfaces as an error mid-collective; the worker reports
//! `RingBroken{epoch, applied_rounds, in_flight_round}` on its control
//! socket and waits for the next Prepare.  The coordinator additionally
//! watches control sockets for EOF (process death).  `resume_round` is
//! max(applied)+1 over the survivors (max(drained)+1 after a drain), so no
//! committed outer update is replayed.
//!
//! The protocol *logic* — when to ack, when membership is stale, the
//! drain-or-discard ruling, grace draining, completion — lives as pure
//! state machines in [`crate::protocol`] ([`crate::protocol::CoordinatorSm`]
//! and [`crate::protocol::WorkerSm`]); [`elastic`] is the I/O shell that
//! runs them over these wire frames, and [`crate::protocol::sim`] runs
//! the very same machines under a deterministic interleaving explorer.

pub mod elastic;
pub mod faulty;
pub mod frame;
pub mod hier;
pub mod probe;
pub mod tcp;

use anyhow::{anyhow, Result};
use std::sync::atomic::{AtomicU64, Ordering};

/// Byte meter shared by all ring members (one per "link budget").
#[derive(Default, Debug)]
pub struct ByteMeter {
    pub sent: AtomicU64,
    pub messages: AtomicU64,
}

impl ByteMeter {
    pub fn add(&self, bytes: u64) {
        self.sent.fetch_add(bytes, Ordering::Relaxed);
        self.messages.fetch_add(1, Ordering::Relaxed);
    }

    pub fn total(&self) -> u64 {
        self.sent.load(Ordering::Relaxed)
    }
}

/// One member's view of a ring collective, independent of the wire.
///
/// Implementors provide point-to-point hops (send to successor, receive
/// from predecessor) plus identity; the chunked ring AllReduce algebra is
/// a provided method so every backend runs the *identical* floating-point
/// schedule — `local` and `tcp` results agree bit-for-bit.
pub trait RingTransport: Send {
    /// This member's position in the ring (0-based, dense).
    fn rank(&self) -> usize;
    /// Number of ring members.
    fn size(&self) -> usize;
    /// Send one chunk to the successor (rank + 1 mod size).
    fn send_next(&mut self, chunk: &[f32]) -> Result<()>;
    /// Receive one chunk from the predecessor (rank − 1 mod size).
    fn recv_prev(&mut self) -> Result<Vec<f32>>;
    /// Payload byte meter (4 bytes per f32; framing overhead excluded so
    /// backends stay comparable).
    fn meter(&self) -> &ByteMeter;

    /// Hook called at every outer-round boundary; fault-injecting wrappers
    /// use it to kill or stall a worker at a configured round.
    fn begin_round(&mut self, _round: usize) -> Result<()> {
        Ok(())
    }

    /// Hand a spent receive buffer back to the transport for reuse.  The
    /// ring collective returns every chunk it consumed; backends with a
    /// buffer pool (local mpsc, TCP) feed them back into `send_next` so
    /// the hot path stops allocating per hop.  Default: drop it.
    /// Wrappers (`Box`, `faulty`) must delegate or the inner pool
    /// starves back to allocating.
    fn recycle(&mut self, _buf: Vec<f32>) {}

    /// In-place chunked ring all-reduce (sum) across all members
    /// (Baidu 2017): reduce-scatter (C−1 hops) then all-gather (C−1 hops);
    /// each member sends 2·(C−1)/C·payload bytes total — the §2.4.1
    /// factor.  Every member must call this with an equal-length buffer.
    fn allreduce_sum(&mut self, buf: &mut [f32]) -> Result<()> {
        let c = self.size();
        if c <= 1 {
            return Ok(());
        }
        let rank = self.rank();
        let n = buf.len();
        // Chunk boundaries (c chunks, last absorbs the remainder).
        let bounds: Vec<(usize, usize)> = (0..c)
            .map(|i| (i * n / c, (i + 1) * n / c))
            .collect();

        // Phase 1: reduce-scatter.  At step s, send chunk (rank - s) and
        // accumulate incoming chunk (rank - s - 1).
        for s in 0..c - 1 {
            let send_idx = (rank + c - s) % c;
            let (lo, hi) = bounds[send_idx];
            let hop = crate::obs::span("ring", "hop").bytes(4 * (hi - lo) as u64);
            self.meter().add(4 * (hi - lo) as u64);
            self.send_next(&buf[lo..hi])?;
            let incoming = self.recv_prev()?;
            drop(hop);
            let recv_idx = (rank + c - s - 1) % c;
            let (lo, hi) = bounds[recv_idx];
            if incoming.len() != hi - lo {
                return Err(anyhow!(
                    "ring chunk size mismatch: got {}, want {}",
                    incoming.len(),
                    hi - lo
                ));
            }
            for (dst, src) in buf[lo..hi].iter_mut().zip(&incoming) {
                *dst += src;
            }
            self.recycle(incoming);
        }
        // Phase 2: all-gather.  Send the chunk just completed.
        for s in 0..c - 1 {
            let send_idx = (rank + 1 + c - s) % c;
            let (lo, hi) = bounds[send_idx];
            let hop = crate::obs::span("ring", "hop").bytes(4 * (hi - lo) as u64);
            self.meter().add(4 * (hi - lo) as u64);
            self.send_next(&buf[lo..hi])?;
            let incoming = self.recv_prev()?;
            drop(hop);
            let recv_idx = (rank + c - s) % c;
            let (lo, hi) = bounds[recv_idx];
            if incoming.len() != hi - lo {
                return Err(anyhow!(
                    "ring chunk size mismatch: got {}, want {}",
                    incoming.len(),
                    hi - lo
                ));
            }
            buf[lo..hi].copy_from_slice(&incoming);
            self.recycle(incoming);
        }
        Ok(())
    }

    /// Mean across members.
    fn allreduce_mean(&mut self, buf: &mut [f32]) -> Result<()> {
        self.allreduce_sum(buf)?;
        let inv = 1.0 / self.size() as f32;
        for v in buf.iter_mut() {
            *v *= inv;
        }
        Ok(())
    }
}

/// Boxed transports are transports: delegate every method (including the
/// provided ones — a wrapper like `faulty` may override `begin_round`) so
/// composition layers such as the stage-parallel executor can wrap
/// already-boxed backends.
impl<T: RingTransport + ?Sized> RingTransport for Box<T> {
    fn rank(&self) -> usize {
        (**self).rank()
    }

    fn size(&self) -> usize {
        (**self).size()
    }

    fn send_next(&mut self, chunk: &[f32]) -> Result<()> {
        (**self).send_next(chunk)
    }

    fn recv_prev(&mut self) -> Result<Vec<f32>> {
        (**self).recv_prev()
    }

    fn meter(&self) -> &ByteMeter {
        (**self).meter()
    }

    fn begin_round(&mut self, round: usize) -> Result<()> {
        (**self).begin_round(round)
    }

    fn recycle(&mut self, buf: Vec<f32>) {
        (**self).recycle(buf)
    }

    fn allreduce_sum(&mut self, buf: &mut [f32]) -> Result<()> {
        (**self).allreduce_sum(buf)
    }

    fn allreduce_mean(&mut self, buf: &mut [f32]) -> Result<()> {
        (**self).allreduce_mean(buf)
    }
}

/// Which wire the coordinator should run the collective over.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum TransportBackend {
    /// In-memory mpsc ring, worker threads in one process.
    Local,
    /// Loopback TCP ring, one `dilocox worker` process per cluster.
    Tcp,
}

impl TransportBackend {
    pub fn parse(s: &str) -> Result<TransportBackend> {
        Ok(match s.to_ascii_lowercase().as_str() {
            "local" | "mpsc" | "thread" => TransportBackend::Local,
            "tcp" | "process" => TransportBackend::Tcp,
            other => {
                return Err(anyhow!(
                    "unknown transport backend '{other}' (local | tcp)"
                ))
            }
        })
    }

    pub fn name(&self) -> &'static str {
        match self {
            TransportBackend::Local => "local",
            TransportBackend::Tcp => "tcp",
        }
    }
}

/// How the elastic coordinator arranges the reduction across the fleet.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum ReduceTopology {
    /// One flat ring in natural (rank-ascending) member order — the
    /// historical behavior.
    #[default]
    Flat,
    /// One flat ring, but in the max-bottleneck order computed from the
    /// measured link matrix ([`probe::ring_order`]); the order ships as
    /// the committed `Prepare.members` order, so churn re-runs it.
    Reordered,
    /// Two-level hierarchical reduce ([`hier::HierRing`]): intra-site
    /// rings plus a leaders-only cross-site ring, members committed in
    /// (site, rank) order.
    Hier,
}

impl ReduceTopology {
    pub fn parse(s: &str) -> Result<ReduceTopology> {
        Ok(match s.to_ascii_lowercase().as_str() {
            "flat" | "ring" => ReduceTopology::Flat,
            "reordered" | "reorder" | "bandwidth" => ReduceTopology::Reordered,
            "hier" | "hierarchical" | "two-level" => ReduceTopology::Hier,
            other => {
                return Err(anyhow!(
                    "unknown reduce topology '{other}' (flat | reordered | hier)"
                ))
            }
        })
    }

    pub fn name(&self) -> &'static str {
        match self {
            ReduceTopology::Flat => "flat",
            ReduceTopology::Reordered => "reordered",
            ReduceTopology::Hier => "hier",
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::comm::ring::{build_ring, RingMember};
    use crate::transport::faulty::{FaultPlan, FaultyRing};
    use crate::transport::hier::HierRing;
    use std::sync::atomic::{AtomicUsize, Ordering as AtomicOrdering};
    use std::sync::Arc;

    /// Wrapper that counts how many spent buffers flow back through
    /// `recycle` — the delegation contract every composition layer
    /// (`Box`, `faulty`, `hier`) must honor, or the inner pool silently
    /// starves back to allocating per hop.
    struct CountingRing<T: RingTransport> {
        inner: T,
        recycled: Arc<AtomicUsize>,
    }

    impl<T: RingTransport> RingTransport for CountingRing<T> {
        fn rank(&self) -> usize {
            self.inner.rank()
        }

        fn size(&self) -> usize {
            self.inner.size()
        }

        fn send_next(&mut self, chunk: &[f32]) -> Result<()> {
            self.inner.send_next(chunk)
        }

        fn recv_prev(&mut self) -> Result<Vec<f32>> {
            self.inner.recv_prev()
        }

        fn meter(&self) -> &ByteMeter {
            self.inner.meter()
        }

        fn recycle(&mut self, buf: Vec<f32>) {
            self.recycled.fetch_add(1, AtomicOrdering::Relaxed);
            self.inner.recycle(buf)
        }
    }

    fn counting_pair() -> (Vec<CountingRing<RingMember>>, Vec<Arc<AtomicUsize>>) {
        let counters: Vec<Arc<AtomicUsize>> =
            (0..2).map(|_| Arc::new(AtomicUsize::new(0))).collect();
        let rings = build_ring(2)
            .into_iter()
            .zip(&counters)
            .map(|(m, c)| CountingRing { inner: m, recycled: Arc::clone(c) })
            .collect();
        (rings, counters)
    }

    #[test]
    fn boxed_transport_delegates_recycle() {
        let (rings, counters) = counting_pair();
        std::thread::scope(|s| {
            for r in rings {
                s.spawn(move || {
                    let mut b: Box<dyn RingTransport> = Box::new(r);
                    let mut buf = vec![1.0f32; 32];
                    b.allreduce_sum(&mut buf).unwrap();
                });
            }
        });
        for c in &counters {
            // The provided collective consumes 2·(C−1) incoming chunks.
            assert_eq!(c.load(AtomicOrdering::Relaxed), 2);
        }
    }

    #[test]
    fn faulty_ring_delegates_recycle() {
        let (rings, counters) = counting_pair();
        std::thread::scope(|s| {
            for r in rings {
                s.spawn(move || {
                    let mut f = FaultyRing::new(r, FaultPlan::quiet(3));
                    let mut buf = vec![1.0f32; 32];
                    f.allreduce_sum(&mut buf).unwrap();
                });
            }
        });
        for c in &counters {
            assert_eq!(c.load(AtomicOrdering::Relaxed), 2);
        }
    }

    #[test]
    fn hier_ring_delegates_recycle_through_both_levels() {
        // 2 sites × 2 members: each member recycles 2·(C_site−1) = 2
        // buffers in the intra reduce; each NON-leader additionally
        // recycles the one store-and-forward broadcast buffer.
        let counters: Vec<Arc<AtomicUsize>> =
            (0..4).map(|_| Arc::new(AtomicUsize::new(0))).collect();
        let mut cross: Vec<Option<Box<dyn RingTransport>>> = build_ring(2)
            .into_iter()
            .map(|m| Some(Box::new(m) as Box<dyn RingTransport>))
            .collect();
        let mut members: Vec<HierRing> = Vec::new();
        for (si, site) in [build_ring(2), build_ring(2)].into_iter().enumerate() {
            for (pos, m) in site.into_iter().enumerate() {
                let idx = si * 2 + pos;
                let counting = CountingRing {
                    inner: m,
                    recycled: Arc::clone(&counters[idx]),
                };
                let cross_ring = if pos == 0 { cross[si].take() } else { None };
                members.push(
                    HierRing::new(Box::new(counting), cross_ring, idx, 4)
                        .unwrap(),
                );
            }
        }
        std::thread::scope(|s| {
            for mut m in members {
                s.spawn(move || {
                    let mut buf = vec![1.0f32; 16];
                    m.allreduce_sum(&mut buf).unwrap();
                });
            }
        });
        for (idx, expect) in [(0usize, 2usize), (2, 2), (1, 3), (3, 3)] {
            assert_eq!(
                counters[idx].load(AtomicOrdering::Relaxed),
                expect,
                "member {idx}"
            );
        }
    }

    #[test]
    fn backend_parse_names() {
        assert_eq!(TransportBackend::parse("tcp").unwrap(), TransportBackend::Tcp);
        assert_eq!(
            TransportBackend::parse("Local").unwrap(),
            TransportBackend::Local
        );
        assert!(TransportBackend::parse("carrier-pigeon").is_err());
        assert_eq!(TransportBackend::Tcp.name(), "tcp");
    }

    #[test]
    fn topology_parse_names() {
        assert_eq!(ReduceTopology::parse("flat").unwrap(), ReduceTopology::Flat);
        assert_eq!(
            ReduceTopology::parse("Reordered").unwrap(),
            ReduceTopology::Reordered
        );
        assert_eq!(
            ReduceTopology::parse("hierarchical").unwrap(),
            ReduceTopology::Hier
        );
        assert!(ReduceTopology::parse("gossip").is_err());
        assert_eq!(ReduceTopology::Hier.name(), "hier");
        assert_eq!(ReduceTopology::default(), ReduceTopology::Flat);
    }
}
