//! The worker side of the elastic 2PC epoch protocol as a pure state
//! machine.
//!
//! [`WorkerSm`] sequences one worker's life across epochs — ack a
//! proposal, form the ring on commit, enter the epoch (consensus
//! resync + drain/discard recovery), run rounds, drain the trailing
//! flight, report Done, wait for Shutdown — without performing any of
//! those effects itself.  The effects come back as [`WorkerOut`]
//! requests; their results return as [`WorkerIn`] events.  The TCP
//! worker loop in [`crate::transport::elastic`] and the simulator's
//! virtual workers ([`super::sim`]) both drive this machine, so the
//! sequencing logic exists exactly once.
//!
//! Ring membership is carried as opaque member ids (`u32`): cluster
//! ranks for the single fleet, cluster ids for a stage fleet.  The
//! shell keeps the wire-level detail (ports, link endpoints) keyed by
//! epoch and resolves it when the machine asks it to form the ring.

use super::Recovery;

/// One committed or proposed epoch, as seen by a worker.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct EpochPlan {
    pub epoch: u32,
    pub resume_round: u32,
    /// Reduce-ring members (ids; the shell maps them to endpoints).
    pub members: Vec<u32>,
    /// Committed drain-or-discard ruling (wire encoding, 0 = discard).
    pub drain_round: u32,
}

impl EpochPlan {
    pub fn recovery(&self) -> Recovery {
        Recovery::from_wire(self.drain_round)
    }
}

/// Events fed into the worker machine.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum WorkerIn {
    /// 2PC phase one from the coordinator.
    Prepare(EpochPlan),
    /// 2PC phase two: commit a previously acked proposal.
    Commit { epoch: u32 },
    /// Coordinator closed the run (or this member's control channel).
    Shutdown,
    /// Result of the [`WorkerOut::FormRing`] request.
    FormResult { ok: bool },
    /// Result of the [`WorkerOut::BeginEpoch`] request.
    BeginResult { ok: bool },
    /// The round loop ended: `completed` when every round through the
    /// configured horizon finished, `false` when the ring broke (peer
    /// failure or an injected soft break).
    RoundsEnd { completed: bool },
    /// Result of the [`WorkerOut::Finish`] trailing drain.
    FinishResult { ok: bool },
}

/// Effects the worker machine requests from its shell.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum WorkerOut {
    /// Send `PrepareAck{epoch}` to the coordinator.
    SendAck { epoch: u32 },
    /// Send `RingBroken` for this epoch (the shell fills in the
    /// applied/in-flight rounds from its driver).
    SendBroken { epoch: u32 },
    /// Dial/accept the reduce ring (and, in a stage fleet, the
    /// inter-stage links — skipped when `finishing`).  Answer with
    /// [`WorkerIn::FormResult`].
    FormRing { plan: EpochPlan, finishing: bool },
    /// Enter the committed epoch: consensus resync, then apply the
    /// recovery ruling via [`super::resume_plan`].  Answer with
    /// [`WorkerIn::BeginResult`].
    BeginEpoch { plan: EpochPlan, finishing: bool },
    /// Run outer rounds starting at `start`.  Answer with
    /// [`WorkerIn::RoundsEnd`].
    RunRounds { start: u32 },
    /// Drain the trailing in-flight reduction.  Answer with
    /// [`WorkerIn::FinishResult`].
    Finish,
    /// Send the final `Done` report to the coordinator.
    SendDone,
    /// Leave the protocol loop.  `error` is `Some` when the shutdown
    /// arrived before this worker ever completed (single-fleet
    /// semantics: a premature shutdown is an error).
    Exit { error: Option<&'static str> },
}

/// Observable phase of the worker machine (see the state diagram in
/// the [module docs](super)).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum WorkerPhase {
    /// Blocking on the coordinator channel for Prepare/Commit.
    Waiting,
    /// Ring formation in progress.
    Forming,
    /// Consensus resync + recovery in progress.
    Beginning,
    /// Outer rounds in progress.
    Running,
    /// Trailing drain in progress.
    Finishing,
    /// Done reported; blocking on the coordinator for Shutdown.
    AwaitShutdown,
    Exited,
}

/// Pure worker machine for the elastic membership protocol.
#[derive(Clone, Debug)]
pub struct WorkerSm {
    /// Last *committed* epoch (acked proposals don't advance this).
    epoch: u32,
    /// Configured outer-round horizon.
    rounds: u32,
    /// Whether a Shutdown while still waiting is a clean exit (stage
    /// fleets shut orphans down mid-run; the single fleet treats a
    /// pre-completion shutdown as an error).
    clean_early_shutdown: bool,
    /// Acked-but-not-committed proposal.
    prepared: Option<EpochPlan>,
    /// The committed epoch currently being executed.
    committed: Option<EpochPlan>,
    phase: WorkerPhase,
}

impl WorkerSm {
    pub fn new(rounds: u32, clean_early_shutdown: bool) -> WorkerSm {
        WorkerSm {
            epoch: 0,
            rounds,
            clean_early_shutdown,
            prepared: None,
            committed: None,
            phase: WorkerPhase::Waiting,
        }
    }

    pub fn epoch(&self) -> u32 {
        self.epoch
    }

    pub fn phase(&self) -> WorkerPhase {
        self.phase
    }

    /// True when the machine is blocked on the coordinator channel —
    /// the only states in which the shell should read control frames.
    pub fn wants_read(&self) -> bool {
        matches!(self.phase, WorkerPhase::Waiting | WorkerPhase::AwaitShutdown)
    }

    /// The epoch plan currently being executed, if any.
    pub fn current_plan(&self) -> Option<&EpochPlan> {
        self.committed.as_ref()
    }

    /// Feed one event; returns every effect it causes, in order.
    pub fn handle(&mut self, input: WorkerIn) -> Vec<WorkerOut> {
        let mut out = Vec::new();
        match (self.phase, input) {
            (WorkerPhase::Waiting, WorkerIn::Prepare(plan)) => {
                // Only proposals beyond the committed generation are
                // ackable; a stale re-delivery is ignored.
                if plan.epoch > self.epoch {
                    out.push(WorkerOut::SendAck { epoch: plan.epoch });
                    self.prepared = Some(plan);
                }
            }
            (WorkerPhase::Waiting, WorkerIn::Commit { epoch }) => {
                // A commit for anything but the acked proposal is
                // stale (a superseded generation) and ignored.
                if self.prepared.as_ref().map(|p| p.epoch) == Some(epoch) {
                    let plan = self.prepared.take().unwrap();
                    self.epoch = plan.epoch;
                    let finishing = plan.resume_round > self.rounds;
                    self.committed = Some(plan.clone());
                    self.phase = WorkerPhase::Forming;
                    out.push(WorkerOut::FormRing { plan, finishing });
                }
            }
            (WorkerPhase::Waiting, WorkerIn::Shutdown) => {
                self.phase = WorkerPhase::Exited;
                let error = if self.clean_early_shutdown {
                    None
                } else {
                    Some("coordinator shut down before commit")
                };
                out.push(WorkerOut::Exit { error });
            }
            (WorkerPhase::Forming, WorkerIn::FormResult { ok: true }) => {
                let plan = self.committed.clone().expect("forming without a committed plan");
                let finishing = plan.resume_round > self.rounds;
                self.phase = WorkerPhase::Beginning;
                out.push(WorkerOut::BeginEpoch { plan, finishing });
            }
            (WorkerPhase::Forming, WorkerIn::FormResult { ok: false }) => self.broken(&mut out),
            (WorkerPhase::Beginning, WorkerIn::BeginResult { ok: true }) => {
                let start = self.committed.as_ref().expect("beginning without a plan").resume_round;
                self.phase = WorkerPhase::Running;
                out.push(WorkerOut::RunRounds { start });
            }
            (WorkerPhase::Beginning, WorkerIn::BeginResult { ok: false }) => self.broken(&mut out),
            (WorkerPhase::Running, WorkerIn::RoundsEnd { completed: true }) => {
                self.phase = WorkerPhase::Finishing;
                out.push(WorkerOut::Finish);
            }
            (WorkerPhase::Running, WorkerIn::RoundsEnd { completed: false }) => {
                self.broken(&mut out)
            }
            (WorkerPhase::Finishing, WorkerIn::FinishResult { ok: true }) => {
                self.phase = WorkerPhase::AwaitShutdown;
                out.push(WorkerOut::SendDone);
            }
            (WorkerPhase::Finishing, WorkerIn::FinishResult { ok: false }) => self.broken(&mut out),
            (WorkerPhase::AwaitShutdown, WorkerIn::Shutdown) => {
                self.phase = WorkerPhase::Exited;
                out.push(WorkerOut::Exit { error: None });
            }
            // Everything else — commits for unacked epochs, shutdown
            // races, results landing after a phase change — is inert.
            _ => {}
        }
        out
    }

    /// The current epoch's ring failed: report it and fall back to
    /// waiting for the next proposal.
    fn broken(&mut self, out: &mut Vec<WorkerOut>) {
        out.push(WorkerOut::SendBroken { epoch: self.epoch });
        self.committed = None;
        self.phase = WorkerPhase::Waiting;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn plan(epoch: u32, resume: u32, drain: u32) -> EpochPlan {
        EpochPlan { epoch, resume_round: resume, members: vec![0, 1], drain_round: drain }
    }

    /// Drive one healthy epoch end-to-end through the machine.
    #[test]
    fn happy_path_epoch() {
        let mut sm = WorkerSm::new(4, false);
        let out = sm.handle(WorkerIn::Prepare(plan(1, 1, 0)));
        assert_eq!(out, vec![WorkerOut::SendAck { epoch: 1 }]);
        assert!(sm.wants_read());
        let out = sm.handle(WorkerIn::Commit { epoch: 1 });
        assert!(matches!(out[0], WorkerOut::FormRing { ref plan, finishing: false } if plan.epoch == 1));
        assert_eq!(sm.epoch(), 1);
        assert!(!sm.wants_read());
        let out = sm.handle(WorkerIn::FormResult { ok: true });
        assert!(matches!(out[0], WorkerOut::BeginEpoch { .. }));
        let out = sm.handle(WorkerIn::BeginResult { ok: true });
        assert_eq!(out, vec![WorkerOut::RunRounds { start: 1 }]);
        let out = sm.handle(WorkerIn::RoundsEnd { completed: true });
        assert_eq!(out, vec![WorkerOut::Finish]);
        let out = sm.handle(WorkerIn::FinishResult { ok: true });
        assert_eq!(out, vec![WorkerOut::SendDone]);
        assert_eq!(sm.phase(), WorkerPhase::AwaitShutdown);
        let out = sm.handle(WorkerIn::Shutdown);
        assert_eq!(out, vec![WorkerOut::Exit { error: None }]);
    }

    #[test]
    fn broken_ring_reports_and_rejoins_next_epoch() {
        let mut sm = WorkerSm::new(4, false);
        sm.handle(WorkerIn::Prepare(plan(1, 1, 0)));
        sm.handle(WorkerIn::Commit { epoch: 1 });
        sm.handle(WorkerIn::FormResult { ok: true });
        sm.handle(WorkerIn::BeginResult { ok: true });
        // The ring breaks mid-rounds.
        let out = sm.handle(WorkerIn::RoundsEnd { completed: false });
        assert_eq!(out, vec![WorkerOut::SendBroken { epoch: 1 }]);
        assert_eq!(sm.phase(), WorkerPhase::Waiting);
        // Next epoch carries a drain ruling and a bumped resume round.
        let out = sm.handle(WorkerIn::Prepare(plan(2, 4, 3)));
        assert_eq!(out, vec![WorkerOut::SendAck { epoch: 2 }]);
        let out = sm.handle(WorkerIn::Commit { epoch: 2 });
        let WorkerOut::FormRing { plan: p, .. } = &out[0] else { panic!("want FormRing") };
        assert_eq!(p.recovery(), Recovery::Drain { round: 3 });
        assert_eq!(p.resume_round, 4);
    }

    #[test]
    fn stale_prepare_and_commit_are_ignored() {
        let mut sm = WorkerSm::new(4, false);
        sm.handle(WorkerIn::Prepare(plan(3, 1, 0)));
        sm.handle(WorkerIn::Commit { epoch: 3 });
        sm.handle(WorkerIn::FormResult { ok: false }); // back to Waiting
        // A proposal at or below the committed generation is stale.
        assert!(sm.handle(WorkerIn::Prepare(plan(3, 1, 0))).is_empty());
        assert_eq!(sm.phase(), WorkerPhase::Waiting);
        // A commit without a matching acked proposal is stale.
        assert!(sm.handle(WorkerIn::Commit { epoch: 4 }).is_empty());
        // A fresh proposal supersedes: ack + commit works.
        assert_eq!(
            sm.handle(WorkerIn::Prepare(plan(4, 2, 0))),
            vec![WorkerOut::SendAck { epoch: 4 }]
        );
        assert!(matches!(
            sm.handle(WorkerIn::Commit { epoch: 4 })[0],
            WorkerOut::FormRing { .. }
        ));
    }

    /// Satellite edge case: a soft break arriving during a *finishing*
    /// epoch (resume already past the round horizon).  The machine
    /// must report the break and re-enter the wait — never report Done
    /// for work it did not finish.
    #[test]
    fn soft_break_during_finishing_epoch() {
        let mut sm = WorkerSm::new(2, true);
        // resume 3 > rounds 2: a finishing epoch draining round 2.
        sm.handle(WorkerIn::Prepare(plan(5, 3, 2)));
        let out = sm.handle(WorkerIn::Commit { epoch: 5 });
        let WorkerOut::FormRing { finishing, .. } = out[0] else { panic!("want FormRing") };
        assert!(finishing, "resume past the horizon must flag finishing");
        // The drain collective itself breaks (a peer soft-broke).
        sm.handle(WorkerIn::FormResult { ok: true });
        let out = sm.handle(WorkerIn::BeginResult { ok: false });
        assert_eq!(out, vec![WorkerOut::SendBroken { epoch: 5 }]);
        assert_eq!(sm.phase(), WorkerPhase::Waiting);
        // The re-proposed finishing epoch still carries the drain.
        sm.handle(WorkerIn::Prepare(plan(6, 3, 2)));
        let out = sm.handle(WorkerIn::Commit { epoch: 6 });
        assert!(matches!(out[0], WorkerOut::FormRing { finishing: true, .. }));
    }

    #[test]
    fn early_shutdown_semantics_differ_by_fleet_kind() {
        // Single fleet: premature shutdown is an error.
        let mut single = WorkerSm::new(4, false);
        let out = single.handle(WorkerIn::Shutdown);
        assert_eq!(
            out,
            vec![WorkerOut::Exit { error: Some("coordinator shut down before commit") }]
        );
        // Stage fleet: orphans are shut down mid-run, cleanly.
        let mut staged = WorkerSm::new(4, true);
        let out = staged.handle(WorkerIn::Shutdown);
        assert_eq!(out, vec![WorkerOut::Exit { error: None }]);
    }

    /// A prepared-but-uncommitted proposal survives an intervening
    /// break cycle only if its epoch is still ahead of the committed
    /// one — mirroring the shell's per-wait proposal stash.
    #[test]
    fn reprepare_supersedes_stash() {
        let mut sm = WorkerSm::new(4, false);
        sm.handle(WorkerIn::Prepare(plan(1, 1, 0)));
        // Coordinator re-prepares before committing (ack timeout).
        sm.handle(WorkerIn::Prepare(plan(2, 1, 0)));
        // The old commit no longer matches the stash.
        assert!(sm.handle(WorkerIn::Commit { epoch: 1 }).is_empty());
        assert!(matches!(
            sm.handle(WorkerIn::Commit { epoch: 2 })[0],
            WorkerOut::FormRing { .. }
        ));
    }
}
