//! Deterministic virtual-time simulation of the elastic protocol:
//! a bounded exhaustive interleaving explorer and a seeded
//! random-schedule fuzzer over the pure machines.
//!
//! The harness runs one [`CoordinatorSm`] and N [`WorkerSm`]s with
//! every I/O edge replaced by a FIFO queue and every blocking
//! collective replaced by a ring rendezvous.  A *scheduler action* is
//! one atomic step: deliver one queued message, complete or fail one
//! worker's parked collective, inject a crash or soft break, or fire
//! the armed grace timer.  An execution is a sequence of actions run
//! to quiescence; the explorer and fuzzer walk many executions and
//! assert the protocol's safety invariants after every step:
//!
//! - at most one membership is committed per epoch number, never
//!   containing a departed or finished member;
//! - a committed drain round is actually held in flight by every ring
//!   member (the unanimity rule matched ground truth);
//! - each round's outer update lands **at most once per worker**
//!   (drain, late join and normal rounds share one ledger);
//! - a discarded delta folds into error feedback at most once before
//!   it re-enters the next completed round's delta.
//!
//! At quiescence a liveness check runs: the coordinator must have
//! finished (or failed with every worker crashed), and every
//! non-crashed worker must have completed its rounds and exited
//! cleanly.  A deadlocked schedule — enabled actions exhausted short
//! of that — is reported as a violation with its minimized schedule.
//!
//! Faithfulness notes: message queues are per-peer FIFO (TCP order),
//! a crashed worker's `Closed` is queued *behind* everything it
//! already sent (reader-thread EOF order), collectives can complete
//! for one member and fail for another (partial drains), and the fate
//! of an abandoned in-flight reduction — completed before the epoch
//! turned, or not — is a scheduler choice, because on a real network
//! it is a race.

use std::collections::{BTreeMap, BTreeSet, VecDeque};
use std::fmt;

use super::coordinator::{CoordIn, CoordOut, CoordinatorSm};
use super::worker::{EpochPlan, WorkerIn, WorkerOut, WorkerPhase, WorkerSm};
use super::{resume_plan, Recovery, ResumePlan};
use crate::util::rng::Pcg32;

/// Hard per-execution step bound; exceeding it is reported as a
/// livelock violation rather than spinning forever.
const STEP_LIMIT: u32 = 20_000;

/// Fleet shape and fault budgets for one batch of executions.
#[derive(Clone, Copy, Debug)]
pub struct SimConfig {
    pub workers: u32,
    pub rounds: u32,
    /// One-step-delay overlap (in-flight reductions across round
    /// boundaries) — the mode the drain/discard machinery exists for.
    pub overlap: bool,
    /// Crash injections allowed per execution (worker dies, channel
    /// closes after its queued traffic).
    pub crashes: u32,
    /// Soft-break injections allowed per execution (a worker aborts
    /// its round loop but stays alive, like an injected fault plan).
    pub breaks: u32,
}

impl SimConfig {
    pub fn small() -> SimConfig {
        SimConfig { workers: 3, rounds: 2, overlap: true, crashes: 1, breaks: 1 }
    }
}

/// A schedule that violated an invariant: the deviation list replays
/// it deterministically (at step `s`, take enabled-action index `c`;
/// every other step takes index 0).
#[derive(Clone, Debug)]
pub struct Violation {
    pub deviations: Vec<(u32, u32)>,
    pub msg: String,
}

impl fmt::Display for Violation {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "protocol violation: {} :: repro deviations={:?}", self.msg, self.deviations)
    }
}

#[derive(Clone, Copy, Debug, Default)]
pub struct ExploreStats {
    /// Distinct executions run to quiescence.
    pub executions: u64,
    /// Longest execution observed, in scheduler steps.
    pub max_steps: u32,
    /// True when the execution cap stopped further branching.
    pub capped: bool,
}

/// One scheduler step.  Ordering in the enabled list is the *default
/// schedule*: deliveries first (a healthy network), then collective
/// completions, then failures and fault injections.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
enum Action {
    /// Deliver the next coordinator→worker control frame.
    DeliverDown(usize),
    /// Deliver the next worker→coordinator event.
    DeliverUp(usize),
    /// Complete the worker's parked collective.  For a Begin holding
    /// an abandoned flight the bool is the flight's fate: `true` if
    /// the old collective completed before the epoch turned (late
    /// join), `false` if it died with the ring (discard).
    Complete(usize, bool),
    /// Fail the worker's parked collective (only enabled when some
    /// ring peer observably diverged — crashed, broke out, moved on).
    Fail(usize),
    /// Inject a soft break: the worker aborts its round loop.
    SoftBreak(usize),
    /// Inject a crash: the worker dies, its channel EOFs behind its
    /// queued traffic.
    Crash(usize),
    /// Fire the armed coordinator timer (grace expiry).  Only offered
    /// when nothing else can run, as a deadlock backstop.
    FireTimer,
}

/// What a worker's shell would be blocked on in a real deployment.
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord)]
enum JobKind {
    Form,
    Begin,
    Round(u32),
    Fin,
}

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
struct Job {
    epoch: u32,
    kind: JobKind,
}

/// Worker → coordinator control events (per-worker FIFO).
#[derive(Clone, Debug)]
enum UpMsg {
    Ack { epoch: u32 },
    Broken { applied: u32, in_flight: u32 },
    Heartbeat { round: u32 },
    Done,
    Closed,
}

/// Pure model of [`crate::rounds::driver::RoundDriver`]'s round/flight
/// arithmetic, sharing [`resume_plan`] with the real driver, plus the
/// per-worker safety ledgers the invariants are asserted against.
#[derive(Clone, Debug)]
struct VirtualDriver {
    overlap: bool,
    applied: u32,
    in_flight: Option<u32>,
    /// Round of a discarded delta folded into error feedback, awaiting
    /// re-entry into the next completed round's delta.
    pending_error: Option<u32>,
    /// Ledger: rounds whose outer update landed on this worker.
    applied_set: BTreeSet<u32>,
}

impl VirtualDriver {
    fn new(overlap: bool) -> VirtualDriver {
        VirtualDriver {
            overlap,
            applied: 0,
            in_flight: None,
            pending_error: None,
            applied_set: BTreeSet::new(),
        }
    }

    /// Land round `r`'s outer update — the invariant: at most once.
    fn apply(&mut self, r: u32) -> Result<(), String> {
        if !self.applied_set.insert(r) {
            return Err(format!("round {r} outer update applied twice on one worker"));
        }
        self.applied = self.applied.max(r);
        Ok(())
    }

    /// Enter a committed epoch: resolve the held flight per the
    /// committed recovery ruling (consensus resync has no ledger
    /// effect).  `flight_completed` is the scheduler-chosen fate of
    /// the abandoned collective.
    fn begin_epoch(&mut self, recovery: Recovery, flight_completed: bool) -> Result<(), String> {
        let plan = resume_plan(recovery, self.in_flight.map(u64::from), flight_completed);
        match plan {
            ResumePlan::Nothing => Ok(()),
            ResumePlan::Drain { round } | ResumePlan::LateJoin { round } => {
                self.in_flight = None;
                self.apply(round as u32)
            }
            ResumePlan::Discard { round } => {
                if let Some(held) = self.pending_error {
                    return Err(format!(
                        "discarded round {round} while round {held} still awaits re-entry"
                    ));
                }
                self.pending_error = Some(round as u32);
                self.in_flight = None;
                Ok(())
            }
        }
    }

    /// Complete round `r`: under overlap, join (apply) the previous
    /// flight and launch this round's; synchronously, apply directly.
    /// Forming this round's delta consumes any pending error fold.
    fn complete_round(&mut self, r: u32) -> Result<(), String> {
        if self.overlap {
            if let Some(f) = self.in_flight.take() {
                self.apply(f)?;
            }
            self.in_flight = Some(r);
        } else {
            self.apply(r)?;
        }
        self.pending_error = None;
        Ok(())
    }

    /// Trailing drain at the end of the round loop.
    fn finish(&mut self) -> Result<(), String> {
        if let Some(f) = self.in_flight.take() {
            self.apply(f)?;
        }
        Ok(())
    }
}

#[derive(Clone, Debug)]
struct Node {
    sm: WorkerSm,
    driver: VirtualDriver,
    crashed: bool,
    /// The collective this worker's shell is parked on, if any.
    job: Option<Job>,
    /// Sent its Done report.
    completed: bool,
    /// `Some(clean)` once the machine exited.
    exited: Option<bool>,
}

/// One simulated fleet: machines, queues, barrier bookkeeping and the
/// safety ledgers.  Cloneable so the explorer can branch.
#[derive(Clone, Debug)]
struct Sim {
    cfg: SimConfig,
    coord: CoordinatorSm,
    nodes: Vec<Node>,
    c2w: Vec<VecDeque<WorkerIn>>,
    w2c: Vec<VecDeque<UpMsg>>,
    /// Armed coordinator timer token (one-shot).
    timer: Option<u64>,
    finished: bool,
    failed: Option<String>,
    crashes_left: u32,
    breaks_left: u32,
    steps: u32,
    /// Proposed ring (member ids) per epoch.
    epoch_rings: BTreeMap<u32, Vec<u32>>,
    /// Committed drain ruling per epoch.
    epoch_drains: BTreeMap<u32, u32>,
    /// Epochs that reached commit (safety: each at most once).
    committed_epochs: BTreeSet<u32>,
    /// Members that completed a collective instance, for rendezvous.
    done_jobs: BTreeMap<(u32, JobKind), BTreeSet<u32>>,
}

impl Sim {
    fn new(cfg: SimConfig) -> Result<Sim, String> {
        let n = cfg.workers as usize;
        let mut sim = Sim {
            cfg,
            coord: CoordinatorSm::new((0..cfg.workers).map(|w| (w, 0)), 1, cfg.rounds),
            nodes: (0..n)
                .map(|_| Node {
                    sm: WorkerSm::new(cfg.rounds, false),
                    driver: VirtualDriver::new(cfg.overlap),
                    crashed: false,
                    job: None,
                    completed: false,
                    exited: None,
                })
                .collect(),
            c2w: vec![VecDeque::new(); n],
            w2c: vec![VecDeque::new(); n],
            timer: None,
            finished: false,
            failed: None,
            crashes_left: cfg.crashes,
            breaks_left: cfg.breaks,
            steps: 0,
            epoch_rings: BTreeMap::new(),
            epoch_drains: BTreeMap::new(),
            committed_epochs: BTreeSet::new(),
            done_jobs: BTreeMap::new(),
        };
        let outs = sim.coord.handle(CoordIn::Start);
        sim.process_coord_out(outs)?;
        Ok(sim)
    }

    fn deliver_down(&mut self, w: usize, msg: WorkerIn) {
        if !self.nodes[w].crashed {
            self.c2w[w].push_back(msg);
        }
    }

    /// Route one batch of coordinator outputs, checking the commit
    /// safety invariants as they pass by.
    fn process_coord_out(&mut self, outs: Vec<CoordOut>) -> Result<(), String> {
        let mut committed_this_call = None;
        for o in outs {
            match o {
                CoordOut::Prepare { to, epoch, resume_round, ring, drain_round, .. } => {
                    let members: Vec<u32> = ring.iter().map(|&(c, _)| c).collect();
                    match self.epoch_rings.get(&epoch) {
                        Some(prev) if *prev != members => {
                            return Err(format!(
                                "epoch {epoch} proposed with two different rings: {prev:?} vs {members:?}"
                            ));
                        }
                        Some(_) => {}
                        None => {
                            self.epoch_rings.insert(epoch, members.clone());
                            self.epoch_drains.insert(epoch, drain_round);
                        }
                    }
                    let plan = EpochPlan { epoch, resume_round, members, drain_round };
                    self.deliver_down(to.0 as usize, WorkerIn::Prepare(plan));
                }
                CoordOut::Commit { to, epoch } => {
                    if committed_this_call != Some(epoch) {
                        committed_this_call = Some(epoch);
                        if !self.committed_epochs.insert(epoch) {
                            return Err(format!("epoch {epoch} committed twice"));
                        }
                        let drain = self.epoch_drains.get(&epoch).copied().unwrap_or(0);
                        for &m in self.epoch_rings.get(&epoch).into_iter().flatten() {
                            if !self.coord.live().contains(&(m, 0)) {
                                return Err(format!(
                                    "epoch {epoch} committed a ring containing departed member {m}"
                                ));
                            }
                            // The unanimity ruling must match ground
                            // truth: a committed drain is drainable by
                            // every member.
                            if drain > 0 && self.nodes[m as usize].driver.in_flight != Some(drain) {
                                return Err(format!(
                                    "epoch {epoch} committed drain of round {drain} but member {m} holds {:?}",
                                    self.nodes[m as usize].driver.in_flight
                                ));
                            }
                        }
                    }
                    if self.coord.done().contains(&to) {
                        return Err(format!("epoch {epoch} committed to finished member {to:?}"));
                    }
                    self.deliver_down(to.0 as usize, WorkerIn::Commit { epoch });
                }
                CoordOut::Shutdown { to } => self.deliver_down(to.0 as usize, WorkerIn::Shutdown),
                CoordOut::ArmTimer { token } => self.timer = Some(token),
                CoordOut::Committed { .. } => {}
                CoordOut::Finished => self.finished = true,
                CoordOut::Failed { reason } => self.failed = Some(reason),
            }
        }
        Ok(())
    }

    /// Feed one event into a worker machine and execute the local
    /// (non-blocking) effects it requests; blocking collectives park
    /// the worker on a job instead.
    fn feed_worker(&mut self, w: usize, input: WorkerIn) -> Result<(), String> {
        let mut inputs = VecDeque::from([input]);
        while let Some(i) = inputs.pop_front() {
            let outs = self.nodes[w].sm.handle(i);
            for o in outs {
                match o {
                    WorkerOut::SendAck { epoch } => self.w2c[w].push_back(UpMsg::Ack { epoch }),
                    WorkerOut::SendBroken { .. } => {
                        let d = &self.nodes[w].driver;
                        self.w2c[w].push_back(UpMsg::Broken {
                            applied: d.applied,
                            in_flight: d.in_flight.unwrap_or(0),
                        });
                    }
                    WorkerOut::FormRing { plan, .. } => {
                        self.nodes[w].job = Some(Job { epoch: plan.epoch, kind: JobKind::Form });
                    }
                    WorkerOut::BeginEpoch { plan, .. } => {
                        self.nodes[w].job = Some(Job { epoch: plan.epoch, kind: JobKind::Begin });
                    }
                    WorkerOut::RunRounds { start } => {
                        if start > self.cfg.rounds {
                            inputs.push_back(WorkerIn::RoundsEnd { completed: true });
                        } else {
                            let epoch = self.nodes[w].sm.epoch();
                            self.nodes[w].job = Some(Job { epoch, kind: JobKind::Round(start) });
                        }
                    }
                    WorkerOut::Finish => {
                        if self.nodes[w].driver.in_flight.is_some() {
                            let epoch = self.nodes[w].sm.epoch();
                            self.nodes[w].job = Some(Job { epoch, kind: JobKind::Fin });
                        } else {
                            inputs.push_back(WorkerIn::FinishResult { ok: true });
                        }
                    }
                    WorkerOut::SendDone => {
                        self.nodes[w].completed = true;
                        self.w2c[w].push_back(UpMsg::Done);
                    }
                    WorkerOut::Exit { error } => {
                        self.nodes[w].exited = Some(error.is_none());
                        self.nodes[w].job = None;
                    }
                }
            }
        }
        Ok(())
    }

    fn feed_coord(&mut self, w: usize, msg: UpMsg) -> Result<(), String> {
        let key = (w as u32, 0);
        let input = match msg {
            UpMsg::Ack { epoch } => CoordIn::PrepareAck { key, epoch },
            UpMsg::Broken { applied, in_flight } => {
                CoordIn::RingBroken { key, applied_rounds: applied, in_flight_round: in_flight }
            }
            UpMsg::Heartbeat { round } => CoordIn::Heartbeat { key, round },
            UpMsg::Done => CoordIn::Done { key },
            UpMsg::Closed => CoordIn::Closed { key },
        };
        let outs = self.coord.handle(input);
        self.process_coord_out(outs)
    }

    /// Member `m` has reached (is parked at, or already completed)
    /// this collective instance — its contribution is available.
    fn reached(&self, m: u32, job: Job) -> bool {
        self.nodes[m as usize].job == Some(job)
            || self
                .done_jobs
                .get(&(job.epoch, job.kind))
                .is_some_and(|s| s.contains(&m))
    }

    /// Member `m` can never reach this instance: it died, broke out of
    /// the epoch, exited, or committed past it.
    fn diverged(&self, m: u32, job: Job) -> bool {
        let n = &self.nodes[m as usize];
        n.crashed
            || n.sm.epoch() > job.epoch
            || (n.sm.epoch() == job.epoch
                && matches!(n.sm.phase(), WorkerPhase::Waiting | WorkerPhase::Exited))
    }

    fn can_complete(&self, w: usize) -> bool {
        let node = &self.nodes[w];
        if node.crashed {
            return false;
        }
        let Some(job) = node.job else { return false };
        let Some(ring) = self.epoch_rings.get(&job.epoch) else { return false };
        ring.iter().all(|&m| self.reached(m, job))
    }

    fn can_fail(&self, w: usize) -> bool {
        let node = &self.nodes[w];
        if node.crashed {
            return false;
        }
        let Some(job) = node.job else { return false };
        let Some(ring) = self.epoch_rings.get(&job.epoch) else { return false };
        ring.iter().any(|&m| m as usize != w && self.diverged(m, job))
    }

    /// Whether a Begin completion's outcome depends on the abandoned
    /// flight's fate (would otherwise discard — a completed flight
    /// late-joins instead).
    fn fate_matters(&self, w: usize) -> bool {
        let node = &self.nodes[w];
        let Some(plan) = node.sm.current_plan() else { return false };
        matches!(
            resume_plan(plan.recovery(), node.driver.in_flight.map(u64::from), false),
            ResumePlan::Discard { .. }
        )
    }

    fn enabled_actions(&self) -> Vec<Action> {
        let mut acts = Vec::new();
        for (w, node) in self.nodes.iter().enumerate() {
            if !node.crashed && node.sm.wants_read() && !self.c2w[w].is_empty() {
                acts.push(Action::DeliverDown(w));
            }
        }
        for w in 0..self.nodes.len() {
            if !self.w2c[w].is_empty() {
                acts.push(Action::DeliverUp(w));
            }
        }
        for (w, node) in self.nodes.iter().enumerate() {
            if self.can_complete(w) {
                acts.push(Action::Complete(w, false));
                if node.job.map(|j| j.kind) == Some(JobKind::Begin) && self.fate_matters(w) {
                    acts.push(Action::Complete(w, true));
                }
            }
        }
        for w in 0..self.nodes.len() {
            if self.can_fail(w) {
                acts.push(Action::Fail(w));
            }
        }
        if self.breaks_left > 0 {
            for (w, node) in self.nodes.iter().enumerate() {
                if !node.crashed && matches!(node.job.map(|j| j.kind), Some(JobKind::Round(_))) {
                    acts.push(Action::SoftBreak(w));
                }
            }
        }
        if self.crashes_left > 0 {
            for (w, node) in self.nodes.iter().enumerate() {
                if !node.crashed && node.sm.phase() != WorkerPhase::Exited {
                    acts.push(Action::Crash(w));
                }
            }
        }
        if acts.is_empty() && self.timer.is_some() {
            acts.push(Action::FireTimer);
        }
        acts
    }

    fn complete_job(&mut self, w: usize, fate: bool) -> Result<(), String> {
        let job = self.nodes[w].job.take().expect("complete without a parked job");
        self.done_jobs.entry((job.epoch, job.kind)).or_default().insert(w as u32);
        match job.kind {
            JobKind::Form => self.feed_worker(w, WorkerIn::FormResult { ok: true }),
            JobKind::Begin => {
                let plan =
                    self.nodes[w].sm.current_plan().cloned().expect("begin without a plan");
                self.nodes[w].driver.begin_epoch(plan.recovery(), fate)?;
                self.feed_worker(w, WorkerIn::BeginResult { ok: true })
            }
            JobKind::Round(r) => {
                self.nodes[w].driver.complete_round(r)?;
                self.w2c[w].push_back(UpMsg::Heartbeat { round: r });
                if r + 1 > self.cfg.rounds {
                    self.feed_worker(w, WorkerIn::RoundsEnd { completed: true })
                } else {
                    self.nodes[w].job = Some(Job { epoch: job.epoch, kind: JobKind::Round(r + 1) });
                    Ok(())
                }
            }
            JobKind::Fin => {
                self.nodes[w].driver.finish()?;
                self.feed_worker(w, WorkerIn::FinishResult { ok: true })
            }
        }
    }

    fn fail_job(&mut self, w: usize) -> Result<(), String> {
        let job = self.nodes[w].job.take().expect("fail without a parked job");
        let input = match job.kind {
            JobKind::Form => WorkerIn::FormResult { ok: false },
            JobKind::Begin => WorkerIn::BeginResult { ok: false },
            JobKind::Round(_) => WorkerIn::RoundsEnd { completed: false },
            JobKind::Fin => WorkerIn::FinishResult { ok: false },
        };
        self.feed_worker(w, input)
    }

    fn apply(&mut self, a: Action) -> Result<(), String> {
        self.steps += 1;
        match a {
            Action::DeliverDown(w) => {
                let msg = self.c2w[w].pop_front().expect("empty c2w");
                self.feed_worker(w, msg)
            }
            Action::DeliverUp(w) => {
                let msg = self.w2c[w].pop_front().expect("empty w2c");
                self.feed_coord(w, msg)
            }
            Action::Complete(w, fate) => self.complete_job(w, fate),
            Action::Fail(w) => self.fail_job(w),
            Action::SoftBreak(w) => {
                self.breaks_left -= 1;
                self.fail_job(w)
            }
            Action::Crash(w) => {
                self.crashes_left -= 1;
                self.nodes[w].crashed = true;
                // EOF lands behind everything already sent.
                self.w2c[w].push_back(UpMsg::Closed);
                Ok(())
            }
            Action::FireTimer => {
                let token = self.timer.take().expect("no armed timer");
                let outs = self.coord.handle(CoordIn::Timer { token });
                self.process_coord_out(outs)
            }
        }
    }

    /// Liveness: a quiescent state must be a proper terminal state.
    fn check_quiescent(&self) -> Result<(), String> {
        if let Some(reason) = &self.failed {
            if self.nodes.iter().all(|n| n.crashed) {
                return Ok(());
            }
            return Err(format!("coordinator failed ({reason}) with workers still alive"));
        }
        if !self.finished {
            return Err("deadlock: no enabled actions but the coordinator never finished".into());
        }
        for (w, n) in self.nodes.iter().enumerate() {
            if n.crashed {
                continue;
            }
            if !n.completed {
                return Err(format!("worker {w} never completed its rounds"));
            }
            if n.exited != Some(true) {
                return Err(format!("worker {w} did not exit cleanly (exited: {:?})", n.exited));
            }
        }
        Ok(())
    }
}

/// Run one schedule described by a deviation list: at step `s` take
/// enabled-action index `c`, otherwise index 0.  Returns the failure
/// message if the schedule violates an invariant.
pub fn replay(cfg: SimConfig, deviations: &[(u32, u32)]) -> Result<(), String> {
    let mut sim = Sim::new(cfg)?;
    loop {
        let actions = sim.enabled_actions();
        if actions.is_empty() {
            return sim.check_quiescent();
        }
        if sim.steps > STEP_LIMIT {
            return Err("execution exceeded the step limit (livelock?)".into());
        }
        let choice = deviations
            .iter()
            .find(|d| d.0 == sim.steps)
            .map(|d| d.1 as usize)
            .unwrap_or(0)
            .min(actions.len() - 1);
        sim.apply(actions[choice])?;
    }
}

/// Bounded exhaustive explorer: depth-first over schedules, where
/// following the default action (index 0) is free and each deviation
/// consumes one unit of `preemptions` budget — the classic
/// context-bounding that keeps small-fleet exploration tractable
/// while still covering crash/soft-break injection at every protocol
/// point (fault injections are deviations like any other).
pub fn explore(
    cfg: SimConfig,
    preemptions: u32,
    max_execs: u64,
) -> Result<ExploreStats, Violation> {
    let sim = Sim::new(cfg).map_err(|msg| Violation { deviations: Vec::new(), msg })?;
    let mut stats = ExploreStats::default();
    let mut trail = Vec::new();
    dfs(sim, preemptions, max_execs, &mut trail, &mut stats)?;
    Ok(stats)
}

fn dfs(
    mut sim: Sim,
    budget: u32,
    cap: u64,
    trail: &mut Vec<(u32, u32)>,
    stats: &mut ExploreStats,
) -> Result<(), Violation> {
    loop {
        let actions = sim.enabled_actions();
        if actions.is_empty() {
            stats.executions += 1;
            stats.max_steps = stats.max_steps.max(sim.steps);
            return sim
                .check_quiescent()
                .map_err(|msg| Violation { deviations: trail.clone(), msg });
        }
        if sim.steps > STEP_LIMIT {
            return Err(Violation {
                deviations: trail.clone(),
                msg: "execution exceeded the step limit (livelock?)".into(),
            });
        }
        if budget > 0 {
            for (i, &a) in actions.iter().enumerate().skip(1) {
                if stats.executions >= cap {
                    stats.capped = true;
                    break;
                }
                let mut alt = sim.clone();
                trail.push((sim.steps, i as u32));
                let step = match alt.apply(a) {
                    Ok(()) => dfs(alt, budget - 1, cap, trail, stats),
                    Err(msg) => Err(Violation { deviations: trail.clone(), msg }),
                };
                trail.pop();
                step?;
            }
        }
        sim.apply(actions[0]).map_err(|msg| Violation { deviations: trail.clone(), msg })?;
    }
}

/// Seeded random-schedule fuzzer: `seeds` independent Pcg32 walks over
/// the enabled-action lists.  On a violation the failing schedule is
/// minimized (greedily resetting choices to the default) before being
/// reported, so the repro line stays short.
pub fn fuzz(cfg: SimConfig, seeds: u32, base_seed: u64) -> Result<u32, Violation> {
    for seed in 0..seeds {
        let mut rng = Pcg32::seed_from(base_seed.wrapping_add(seed as u64));
        let mut choices: Vec<u32> = Vec::new();
        let mut sim = match Sim::new(cfg) {
            Ok(s) => s,
            Err(msg) => return Err(Violation { deviations: Vec::new(), msg }),
        };
        let failure = loop {
            let actions = sim.enabled_actions();
            if actions.is_empty() {
                break sim.check_quiescent().err();
            }
            if sim.steps > STEP_LIMIT {
                break Some("execution exceeded the step limit (livelock?)".to_string());
            }
            let c = rng.below(actions.len() as u32);
            choices.push(c);
            if let Err(e) = sim.apply(actions[c as usize]) {
                break Some(e);
            }
        };
        if let Some(msg) = failure {
            return Err(minimize(cfg, choices, msg));
        }
    }
    Ok(seeds)
}

/// Greedy schedule minimization: reset each non-default choice to the
/// default (back to front) and keep the reset whenever the schedule
/// still fails.
fn minimize(cfg: SimConfig, mut choices: Vec<u32>, mut msg: String) -> Violation {
    for i in (0..choices.len()).rev() {
        if choices[i] == 0 {
            continue;
        }
        let saved = choices[i];
        choices[i] = 0;
        match run_choices(cfg, &choices) {
            Some(m) => msg = m,
            None => choices[i] = saved,
        }
    }
    let deviations = choices
        .iter()
        .enumerate()
        .filter(|&(_, &c)| c != 0)
        .map(|(i, &c)| (i as u32, c))
        .collect();
    Violation { deviations, msg }
}

/// Replay a full choice vector (indexed by step, clamped to the
/// enabled-action count); `Some(msg)` when the schedule fails.
fn run_choices(cfg: SimConfig, choices: &[u32]) -> Option<String> {
    let mut sim = match Sim::new(cfg) {
        Ok(s) => s,
        Err(msg) => return Some(msg),
    };
    loop {
        let actions = sim.enabled_actions();
        if actions.is_empty() {
            return sim.check_quiescent().err();
        }
        if sim.steps > STEP_LIMIT {
            return Some("execution exceeded the step limit (livelock?)".to_string());
        }
        let c = choices.get(sim.steps as usize).copied().unwrap_or(0) as usize;
        let c = c.min(actions.len() - 1);
        if let Err(e) = sim.apply(actions[c]) {
            return Some(e);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// The fault-free default schedule is a healthy fleet run.
    #[test]
    fn default_schedule_completes() {
        let cfg = SimConfig { workers: 3, rounds: 2, overlap: true, crashes: 0, breaks: 0 };
        let stats = explore(cfg, 0, 1).expect("default schedule must hold invariants");
        assert_eq!(stats.executions, 1);
        assert!(stats.max_steps > 20, "a real execution ran ({} steps)", stats.max_steps);
    }

    /// Acceptance gate: ≥ 1000 distinct executions for a 3-worker
    /// fleet with crash and soft-break injection available at every
    /// protocol point, every invariant holding.
    #[test]
    fn exhaustive_three_workers_with_faults() {
        let stats = match explore(SimConfig::small(), 2, 20_000) {
            Ok(s) => s,
            Err(v) => panic!("{v}"),
        };
        assert!(
            stats.executions >= 1000,
            "explorer must enumerate >= 1000 executions, got {}",
            stats.executions
        );
    }

    /// Delivery-order permutations alone (no faults) must all converge
    /// to the same terminal shape.
    #[test]
    fn exhaustive_no_fault_permutations() {
        let cfg = SimConfig { workers: 3, rounds: 2, overlap: true, crashes: 0, breaks: 0 };
        let stats = match explore(cfg, 2, 10_000) {
            Ok(s) => s,
            Err(v) => panic!("{v}"),
        };
        assert!(stats.executions >= 100, "got {}", stats.executions);
    }

    /// Synchronous (non-overlap) mode: nothing is ever in flight, so
    /// every recovery is a discard-of-nothing.
    #[test]
    fn exhaustive_sync_mode() {
        let cfg = SimConfig { workers: 2, rounds: 2, overlap: false, crashes: 1, breaks: 1 };
        if let Err(v) = explore(cfg, 2, 10_000) {
            panic!("{v}");
        }
    }

    /// Seeded fuzz walks over a slightly larger fleet/horizon.
    #[test]
    fn fuzz_holds_invariants() {
        let cfg = SimConfig { workers: 3, rounds: 3, overlap: true, crashes: 1, breaks: 1 };
        if let Err(v) = fuzz(cfg, 60, 0x51b0_77ed) {
            panic!("{v}");
        }
    }

    /// Two-worker fleet where both crash: the coordinator must fail
    /// (never hang), and that terminal shape passes liveness.
    #[test]
    fn all_crashed_fleet_fails_cleanly() {
        let cfg = SimConfig { workers: 2, rounds: 2, overlap: true, crashes: 2, breaks: 0 };
        if let Err(v) = explore(cfg, 2, 10_000) {
            panic!("{v}");
        }
    }

    /// A violation repro line replays deterministically: an
    /// artificially broken invariant check is out of reach here, so
    /// instead assert that replaying the default schedule succeeds and
    /// that deviations index real decision points.
    #[test]
    fn replay_is_deterministic() {
        let cfg = SimConfig::small();
        assert_eq!(replay(cfg, &[]), Ok(()));
        // A deviation at step 0 still terminates cleanly.
        assert_eq!(replay(cfg, &[(0, 1)]), Ok(()));
    }
}
